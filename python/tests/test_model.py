"""L2 correctness: stage partitioning composes to the full model, shapes
chain, parameter accounting matches, and the AOT manifest is coherent."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    ModelConfig,
    example_input,
    full_model,
    init_params,
    make_stage_fns,
    param_count,
)

jax.config.update("jax_platform_name", "cpu")

CFG = ModelConfig()


@pytest.fixture(scope="module")
def params():
    return init_params(CFG)


@pytest.fixture(scope="module")
def stages(params):
    return make_stage_fns(CFG, params)


def test_stage_shapes_chain(stages):
    for a, b in zip(stages, stages[1:]):
        assert a["out_shape"] == b["in_shape"]
        assert a["out_dtype"] == b["in_dtype"]
    assert stages[0]["in_dtype"] == "i32"
    assert stages[-1]["out_shape"] == (CFG.batch, CFG.seq_len, CFG.vocab)


def test_stage_composition_equals_full_model(params, stages):
    tokens = example_input(CFG)
    x = tokens
    for st in stages:
        x = st["fn"](x)
        assert x.shape == st["out_shape"], st["name"]
    full = full_model(CFG, params)(tokens)
    np.testing.assert_allclose(np.asarray(x), np.asarray(full), rtol=1e-5, atol=1e-5)


def test_param_accounting(params, stages):
    total = sum(st["params"] for st in stages)
    assert total == param_count(params)


def test_layer_split_covers_all_layers():
    for n_stages in (1, 2, 3, 4):
        cfg = ModelConfig(n_stages=n_stages)
        split = cfg.layer_split()
        assert sum(split) == cfg.n_layers
        assert len(split) == n_stages
        assert all(s >= 0 for s in split)


def test_deterministic_weights():
    a = init_params(CFG)
    b = init_params(CFG)
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert all(np.array_equal(x, y) for x, y in zip(la, lb))


def test_logits_are_finite_and_sensitive_to_input(params):
    fn = full_model(CFG, params)
    t1 = example_input(CFG, seed=1)
    t2 = example_input(CFG, seed=2)
    l1, l2 = fn(t1), fn(t2)
    assert np.isfinite(np.asarray(l1)).all()
    assert not np.allclose(np.asarray(l1), np.asarray(l2))


def test_causality_end_to_end(params):
    # Changing the last token must not change logits at earlier positions.
    fn = full_model(CFG, params)
    tokens = example_input(CFG, seed=3)
    l1 = np.asarray(fn(tokens))
    tokens2 = tokens.at[:, -1].set((tokens[:, -1] + 1) % CFG.vocab)
    l2 = np.asarray(fn(tokens2))
    np.testing.assert_allclose(l1[:, :-1, :], l2[:, :-1, :], rtol=1e-5, atol=1e-5)
    assert not np.allclose(l1[:, -1, :], l2[:, -1, :])


def test_aot_manifest_consistency(tmp_path):
    from compile.aot import build

    cfg = ModelConfig(n_layers=2, n_stages=2, d_model=32, d_ff=64, batch=2, seq_len=8)
    manifest = build(cfg, str(tmp_path), quiet=True)
    on_disk = json.loads((tmp_path / "model.json").read_text())
    assert on_disk == manifest
    assert len(manifest["stages"]) == 2
    for st in manifest["stages"]:
        hlo = (tmp_path / st["hlo"]).read_text()
        assert "ENTRY" in hlo
        assert "{...}" not in hlo, "large constants must not be elided"
    golden = json.loads((tmp_path / "golden.json").read_text())
    assert golden["logits_shape"] == [2, 8, cfg.vocab]
    assert len(golden["tokens"]) == 2 * 8
    assert np.isfinite(golden["logits_checksum"])


def test_hlo_text_has_single_parameter(tmp_path):
    # Stage artifacts must be pure Tensor→Tensor functions: exactly one
    # entry parameter (weights baked as constants).
    from compile.aot import build

    cfg = ModelConfig(n_layers=1, n_stages=1, d_model=32, d_ff=64, batch=2, seq_len=4)
    build(cfg, str(tmp_path), quiet=True)
    text = (tmp_path / "stage_0.hlo.txt").read_text()
    # ENTRY is the last computation in the module, so everything after it
    # is the entry body (slicing to the first '}' would stop at a layout
    # annotation like `{1,0}`).
    entry = text[text.index("ENTRY") :]
    n_params = entry.count("parameter(")
    assert n_params == 1, f"expected 1 entry parameter, found {n_params}"
