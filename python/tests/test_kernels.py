"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes and dtypes; every case asserts allclose
against `ref.py`. This is the kernel-level correctness gate the build
runs before artifacts ship.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, layernorm, mlp, ref

jax.config.update("jax_platform_name", "cpu")

F32_TOL = dict(rtol=2e-5, atol=2e-5)
BF16_TOL = dict(rtol=2e-2, atol=2e-2)


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(key, shape, dtype=jnp.float32) * scale).astype(dtype)


# ----------------------------------------------------------------- attention

@settings(max_examples=25, deadline=None)
@given(
    bh=st.integers(min_value=1, max_value=12),
    seq=st.sampled_from([1, 2, 4, 8, 16, 32]),
    dh=st.sampled_from([4, 8, 16, 32, 64]),
    causal=st.booleans(),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_attention_matches_ref_f32(bh, seq, dh, causal, seed):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = rand(k1, (bh, seq, dh))
    k = rand(k2, (bh, seq, dh))
    v = rand(k3, (bh, seq, dh))
    out = attention(q, k, v, causal=causal)
    expect = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(out, expect, **F32_TOL)


@settings(max_examples=8, deadline=None)
@given(
    seq=st.sampled_from([4, 16]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_attention_bf16(seq, seed):
    keys = jax.random.split(jax.random.PRNGKey(seed), 3)
    q, k, v = [rand(kk, (4, seq, 16), dtype=jnp.bfloat16) for kk in keys]
    out = attention(q, k, v)
    assert out.dtype == jnp.bfloat16
    expect = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(
        out.astype(jnp.float32), expect.astype(jnp.float32), **BF16_TOL
    )


def test_attention_causal_ignores_future():
    # Perturbing future positions of K/V must not change earlier outputs.
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    q = rand(ks[0], (2, 8, 16))
    k = rand(ks[1], (2, 8, 16))
    v = rand(ks[2], (2, 8, 16))
    base = attention(q, k, v, causal=True)
    k2 = k.at[:, -1, :].add(100.0)
    v2 = v.at[:, -1, :].add(-50.0)
    pert = attention(q, k2, v2, causal=True)
    np.testing.assert_allclose(base[:, :-1, :], pert[:, :-1, :], **F32_TOL)
    assert not np.allclose(base[:, -1, :], pert[:, -1, :])


def test_attention_softmax_rows_bounded():
    # Output of attention is a convex combination of V rows.
    keys = jax.random.split(jax.random.PRNGKey(7), 3)
    q, k, v = [rand(kk, (3, 16, 8)) for kk in keys]
    out = np.asarray(attention(q, k, v, causal=False))
    vmin, vmax = np.asarray(v).min(axis=1), np.asarray(v).max(axis=1)
    assert (out <= vmax[:, None, :] + 1e-4).all()
    assert (out >= vmin[:, None, :] - 1e-4).all()


def test_attention_shape_mismatch_raises():
    q = jnp.zeros((2, 4, 8))
    k = jnp.zeros((2, 4, 16))
    with pytest.raises(ValueError):
        attention(q, k, k)


# ----------------------------------------------------------------------- mlp

@settings(max_examples=20, deadline=None)
@given(
    n=st.sampled_from([1, 3, 8, 64, 128, 200]),
    d=st.sampled_from([8, 32, 64]),
    f=st.sampled_from([16, 64, 256]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_mlp_matches_ref(n, d, f, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = rand(ks[0], (n, d))
    w1 = rand(ks[1], (d, f), scale=0.3)
    b1 = rand(ks[2], (f,), scale=0.1)
    w2 = rand(ks[3], (f, d), scale=0.3)
    b2 = rand(ks[4], (d,), scale=0.1)
    out = mlp(x, w1, b1, w2, b2)
    expect = ref.mlp_ref(x, w1, b1, w2, b2)
    np.testing.assert_allclose(out, expect, rtol=5e-5, atol=5e-5)


def test_mlp_shape_mismatch_raises():
    with pytest.raises(ValueError):
        mlp(
            jnp.zeros((4, 8)),
            jnp.zeros((9, 16)),
            jnp.zeros(16),
            jnp.zeros((16, 8)),
            jnp.zeros(8),
        )


# ----------------------------------------------------------------- layernorm

@settings(max_examples=20, deadline=None)
@given(
    n=st.sampled_from([1, 2, 16, 128, 384]),
    d=st.sampled_from([8, 64, 128]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_layernorm_matches_ref(n, d, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = rand(ks[0], (n, d), scale=3.0)
    gamma = rand(ks[1], (d,), scale=0.5) + 1.0
    beta = rand(ks[2], (d,), scale=0.5)
    out = layernorm(x, gamma, beta)
    expect = ref.layernorm_ref(x, gamma, beta)
    np.testing.assert_allclose(out, expect, rtol=5e-5, atol=5e-5)


def test_layernorm_output_standardized():
    x = rand(jax.random.PRNGKey(3), (32, 64), scale=10.0)
    out = np.asarray(layernorm(x, jnp.ones(64), jnp.zeros(64)))
    np.testing.assert_allclose(out.mean(axis=-1), 0.0, atol=1e-4)
    np.testing.assert_allclose(out.std(axis=-1), 1.0, atol=1e-2)
