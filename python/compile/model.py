"""Layer-2 JAX model: a small decoder-only transformer, partitioned into
pipeline stages for the serving experiments.

The model is deliberately self-contained: weights are generated from a
fixed PRNG seed and *baked into the HLO as constants* by `aot.py`, so a
stage artifact is a pure function Tensor→Tensor and the Rust runtime
never handles parameters.

Stage map (n_stages = 3 by default, matching the paper's Fig. 2
three-stage pipeline with the middle stage as the replication target):

  stage_0: tokens  i32[B, S]      → embeddings + first block(s) → f32[B, S, D]
  stage_k: hidden  f32[B, S, D]   → transformer block(s)        → f32[B, S, D]
  stage_N: hidden  f32[B, S, D]   → final LN + LM head          → f32[B, S, V]

Every block calls the Layer-1 Pallas kernels (`kernels.attention`,
`kernels.mlp`, `kernels.layernorm`) so the kernels lower into the same
HLO the Rust coordinator executes.
"""

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp

from .kernels import attention, layernorm, mlp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Shapes for the served model and its pipeline split."""

    name: str = "tiny-transformer"
    vocab: int = 256
    d_model: int = 64
    n_heads: int = 4
    n_layers: int = 4
    d_ff: int = 256
    seq_len: int = 16
    batch: int = 8
    n_stages: int = 3
    seed: int = 0

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def layer_split(self):
        """Distribute n_layers across n_stages (first/last also carry
        embedding / head)."""
        assert 1 <= self.n_stages <= self.n_layers + 2
        base = self.n_layers // self.n_stages
        extra = self.n_layers % self.n_stages
        return [base + (1 if i < extra else 0) for i in range(self.n_stages)]


def init_params(cfg: ModelConfig):
    """Deterministic parameter pytree."""
    key = jax.random.PRNGKey(cfg.seed)
    keys = iter(jax.random.split(key, 4 + 8 * cfg.n_layers))
    scale_emb = 1.0 / math.sqrt(cfg.d_model)
    params = {
        "tok_emb": jax.random.normal(next(keys), (cfg.vocab, cfg.d_model)) * scale_emb,
        "pos_emb": jax.random.normal(next(keys), (cfg.seq_len, cfg.d_model)) * scale_emb,
        "ln_f": {"gamma": jnp.ones(cfg.d_model), "beta": jnp.zeros(cfg.d_model)},
        "head": jax.random.normal(next(keys), (cfg.d_model, cfg.vocab)) * scale_emb,
        "blocks": [],
    }
    scale_attn = 1.0 / math.sqrt(cfg.d_model)
    scale_ff = 1.0 / math.sqrt(cfg.d_ff)
    for _ in range(cfg.n_layers):
        params["blocks"].append(
            {
                "ln1": {"gamma": jnp.ones(cfg.d_model), "beta": jnp.zeros(cfg.d_model)},
                "wqkv": jax.random.normal(next(keys), (cfg.d_model, 3 * cfg.d_model)) * scale_attn,
                "wo": jax.random.normal(next(keys), (cfg.d_model, cfg.d_model)) * scale_attn,
                "ln2": {"gamma": jnp.ones(cfg.d_model), "beta": jnp.zeros(cfg.d_model)},
                "w1": jax.random.normal(next(keys), (cfg.d_model, cfg.d_ff)) * scale_attn,
                "b1": jnp.zeros(cfg.d_ff),
                "w2": jax.random.normal(next(keys), (cfg.d_ff, cfg.d_model)) * scale_ff,
                "b2": jnp.zeros(cfg.d_model),
            }
        )
    return params


def param_count(tree) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(tree))


def block_apply(cfg: ModelConfig, bp, x):
    """One pre-LN transformer block over x: [B, S, D], via Pallas kernels."""
    b, s, d = x.shape
    h = cfg.n_heads
    dh = cfg.head_dim

    # Attention sublayer.
    xn = layernorm(x.reshape(b * s, d), bp["ln1"]["gamma"], bp["ln1"]["beta"]).reshape(b, s, d)
    qkv = xn @ bp["wqkv"]  # [B, S, 3D]
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def heads(t):  # [B, S, D] -> [B*H, S, Dh]
        return t.reshape(b, s, h, dh).transpose(0, 2, 1, 3).reshape(b * h, s, dh)

    def unheads(t):  # [B*H, S, Dh] -> [B, S, D]
        return t.reshape(b, h, s, dh).transpose(0, 2, 1, 3).reshape(b, s, d)

    attn = unheads(attention(heads(q), heads(k), heads(v), causal=True))
    x = x + attn @ bp["wo"]

    # MLP sublayer (fused Pallas kernel over flattened rows).
    xn = layernorm(x.reshape(b * s, d), bp["ln2"]["gamma"], bp["ln2"]["beta"])
    y = mlp(xn, bp["w1"], bp["b1"], bp["w2"], bp["b2"])
    return x + y.reshape(b, s, d)


def make_stage_fns(cfg: ModelConfig, params):
    """Build the per-stage pure functions plus IO metadata.

    Returns a list of dicts: {fn, in_shape, out_shape, in_dtype,
    out_dtype, params} — the manifest `aot.py` serializes.
    """
    split = cfg.layer_split()
    stages = []
    layer_idx = 0
    for si, n_blocks in enumerate(split):
        blocks = params["blocks"][layer_idx : layer_idx + n_blocks]
        layer_idx += n_blocks
        first = si == 0
        last = si == len(split) - 1

        def stage_fn(x, blocks=blocks, first=first, last=last):
            if first:
                tok = x  # i32 [B, S]
                x = params["tok_emb"][tok] + params["pos_emb"][None, :, :]
            for bp in blocks:
                x = block_apply(cfg, bp, x)
            if last:
                b, s, d = x.shape
                xn = layernorm(
                    x.reshape(b * s, d), params["ln_f"]["gamma"], params["ln_f"]["beta"]
                ).reshape(b, s, d)
                x = xn @ params["head"]  # logits [B, S, V]
            return x

        n_params = sum(param_count(bp) for bp in blocks)
        if first:
            n_params += param_count(params["tok_emb"]) + param_count(params["pos_emb"])
        if last:
            n_params += param_count(params["ln_f"]) + param_count(params["head"])
        stages.append(
            {
                "name": f"stage_{si}",
                "fn": stage_fn,
                "in_shape": (cfg.batch, cfg.seq_len) if first else (cfg.batch, cfg.seq_len, cfg.d_model),
                "out_shape": (cfg.batch, cfg.seq_len, cfg.vocab)
                if last
                else (cfg.batch, cfg.seq_len, cfg.d_model),
                "in_dtype": "i32" if first else "f32",
                "out_dtype": "f32",
                "params": n_params,
            }
        )
    return stages


@functools.lru_cache(maxsize=4)
def _cached(cfg: ModelConfig):
    params = init_params(cfg)
    return params


def full_model(cfg: ModelConfig, params=None):
    """The unpartitioned model (reference for stage-composition tests and
    the single-executable baseline)."""
    if params is None:
        params = _cached(cfg)
    stages = make_stage_fns(cfg, params)

    def fn(tokens):
        x = tokens
        for st in stages:
            x = st["fn"](x)
        return x

    return fn


def example_input(cfg: ModelConfig, seed: int = 1234):
    key = jax.random.PRNGKey(seed)
    return jax.random.randint(key, (cfg.batch, cfg.seq_len), 0, cfg.vocab, dtype=jnp.int32)
