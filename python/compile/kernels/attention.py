"""Layer-1 Pallas kernel: fused causal attention.

TPU-shaped rather than GPU-ported (DESIGN.md §Hardware-Adaptation): the
grid iterates over (batch × heads); each step pulls one head's Q, K and
V tiles from HBM into VMEM via `BlockSpec`, runs QKᵀ → masked softmax →
PV entirely in VMEM, and writes the output tile back. The matmuls are
[S, Dh] × [Dh, S] and [S, S] × [S, Dh]; fp32 accumulation throughout
(`preferred_element_type`), which is the MXU contract.

`interpret=True` is mandatory on this image: real TPU lowering emits a
Mosaic custom-call that the CPU PJRT plugin cannot execute. Numerics are
validated against `ref.attention_ref` by pytest.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, causal: bool):
    """One grid step == one (batch, head) pair; refs are [1, S, Dh] VMEM."""
    q = q_ref[0, ...].astype(jnp.float32)
    k = k_ref[0, ...].astype(jnp.float32)
    v = v_ref[0, ...].astype(jnp.float32)
    s = q.shape[0]
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], dtype=jnp.float32))
    # [S, S] score tile in VMEM — the MXU-shaped contraction.
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if causal:
        row = jax.lax.broadcasted_iota(jnp.int32, (s, s), 0)
        col = jax.lax.broadcasted_iota(jnp.int32, (s, s), 1)
        scores = jnp.where(row >= col, scores, -1e30)
    # Numerically-stable softmax, staying in VMEM.
    m = scores.max(axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    p = p / p.sum(axis=-1, keepdims=True)
    out = jnp.dot(p, v, preferred_element_type=jnp.float32)
    o_ref[0, ...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal",))
def attention(q, k, v, *, causal: bool = True):
    """Fused attention over [BH, S, Dh] tensors (one grid row per head).

    VMEM per grid step ≈ 4 × S × Dh × 4 B (q, k, v, o) + S² × 4 B for the
    score tile; with S, Dh ≤ 128 that is ≤ 320 KiB — comfortably inside a
    TPU core's ~16 MiB VMEM with double-buffering headroom.
    """
    if q.shape != k.shape or q.shape != v.shape:
        raise ValueError(f"q/k/v shape mismatch: {q.shape} {k.shape} {v.shape}")
    bh, s, dh = q.shape
    block = pl.BlockSpec((1, s, dh), lambda i: (i, 0, 0))
    return pl.pallas_call(
        functools.partial(_attn_kernel, causal=causal),
        grid=(bh,),
        in_specs=[block, block, block],
        out_specs=block,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=True,  # CPU-PJRT execution; Mosaic is TPU-only
    )(q, k, v)
