"""Layer-1 Pallas kernel: fused transformer MLP (GEMM → GELU → GEMM).

The HBM↔VMEM schedule: the grid tiles the row dimension of the
activations; each step keeps an [bn, D] activation tile plus the full
W1 [D, F] and W2 [F, D] weight panels resident in VMEM and fuses the
intermediate GELU so the [bn, F] hidden tile never round-trips to HBM —
the fusion that on GPU would be done inside one threadblock is expressed
here purely through `BlockSpec`.

VMEM per step with D=128, F=512, bn=128, fp32:
  x (64 KiB) + w1 (256 KiB) + h (256 KiB) + w2 (256 KiB) + out (64 KiB)
  ≈ 0.9 MiB — well inside budget; the two GEMMs are 128-multiple shaped
for the MXU. interpret=True for CPU-PJRT (see attention.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gelu(x):
    c = jnp.sqrt(jnp.asarray(2.0 / jnp.pi, dtype=jnp.float32))
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x**3)))


def _mlp_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    w1 = w1_ref[...].astype(jnp.float32)
    b1 = b1_ref[...].astype(jnp.float32)
    w2 = w2_ref[...].astype(jnp.float32)
    b2 = b2_ref[...].astype(jnp.float32)
    h = _gelu(jnp.dot(x, w1, preferred_element_type=jnp.float32) + b1)
    out = jnp.dot(h, w2, preferred_element_type=jnp.float32) + b2
    o_ref[...] = out.astype(o_ref.dtype)


def _pick_block(n: int, target: int = 128) -> int:
    """Largest divisor of n that is ≤ target (rows per grid step)."""
    best = 1
    for cand in range(1, min(n, target) + 1):
        if n % cand == 0:
            best = cand
    return best


@jax.jit
def mlp(x, w1, b1, w2, b2):
    """Fused MLP over x: [N, D] with w1: [D, F], w2: [F, D]."""
    n, d = x.shape
    f = w1.shape[1]
    if w1.shape[0] != d or w2.shape != (f, d) or b1.shape != (f,) or b2.shape != (d,):
        raise ValueError(
            f"mlp shape mismatch: x{x.shape} w1{w1.shape} b1{b1.shape} w2{w2.shape} b2{b2.shape}"
        )
    bn = _pick_block(n)
    grid = (n // bn,)
    return pl.pallas_call(
        _mlp_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),  # activation tile
            pl.BlockSpec((d, f), lambda i: (0, 0)),   # W1 panel (resident)
            pl.BlockSpec((f,), lambda i: (0,)),
            pl.BlockSpec((f, d), lambda i: (0, 0)),   # W2 panel (resident)
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bn, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=True,
    )(x, w1, b1, w2, b2)


@functools.partial(jax.jit, static_argnames=("eps",))
def layernorm(x, gamma, beta, eps: float = 1e-5):
    """LayerNorm over the last axis as a Pallas kernel (VPU-side op).

    Rows are tiled like `mlp`; per-step state is one [bn, D] tile plus
    the [D] scale/shift vectors.
    """
    n, d = x.shape
    bn = _pick_block(n)

    def kernel(x_ref, g_ref, b_ref, o_ref):
        xv = x_ref[...].astype(jnp.float32)
        mu = xv.mean(axis=-1, keepdims=True)
        var = ((xv - mu) ** 2).mean(axis=-1, keepdims=True)
        y = (xv - mu) / jnp.sqrt(var + eps)
        y = y * g_ref[...].astype(jnp.float32) + b_ref[...].astype(jnp.float32)
        o_ref[...] = y.astype(o_ref.dtype)

    return pl.pallas_call(
        kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bn, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=True,
    )(x, gamma, beta)
