"""Pure-jnp oracles for the Pallas kernels.

These are the CORE correctness signal for Layer 1: `pytest python/tests`
asserts the Pallas kernels (run in interpret mode) match these
references to tight tolerances across shape/dtype sweeps.
"""

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal=True):
    """Scaled dot-product attention over [B, S, Dh] per-head tensors.

    Args:
      q, k, v: [batch_heads, seq, head_dim]
      causal: apply a lower-triangular mask.

    Returns:
      [batch_heads, seq, head_dim]
    """
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], dtype=jnp.float32))
    scores = jnp.einsum("bsd,btd->bst", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    if causal:
        s = q.shape[1]
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        scores = jnp.where(mask[None, :, :], scores, -1e30)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bst,btd->bsd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def gelu_ref(x):
    """tanh-approximation GELU (matches the kernel's formula exactly)."""
    x32 = x.astype(jnp.float32)
    c = jnp.sqrt(jnp.asarray(2.0 / jnp.pi, dtype=jnp.float32))
    y = 0.5 * x32 * (1.0 + jnp.tanh(c * (x32 + 0.044715 * x32**3)))
    return y.astype(x.dtype)


def mlp_ref(x, w1, b1, w2, b2):
    """Fused transformer MLP: gelu(x @ w1 + b1) @ w2 + b2.

    Args:
      x: [n, d]; w1: [d, f]; b1: [f]; w2: [f, d]; b2: [d]
    """
    h = gelu_ref(x.astype(jnp.float32) @ w1.astype(jnp.float32) + b1.astype(jnp.float32))
    out = h @ w2.astype(jnp.float32) + b2.astype(jnp.float32)
    return out.astype(x.dtype)


def layernorm_ref(x, gamma, beta, eps=1e-5):
    """LayerNorm over the last axis."""
    x32 = x.astype(jnp.float32)
    mu = x32.mean(axis=-1, keepdims=True)
    var = ((x32 - mu) ** 2).mean(axis=-1, keepdims=True)
    y = (x32 - mu) / jnp.sqrt(var + eps) * gamma.astype(jnp.float32) + beta.astype(jnp.float32)
    return y.astype(x.dtype)
