"""Layer-1 Pallas kernels (build-time only; lowered into stage HLO)."""

from .attention import attention
from .mlp import layernorm, mlp
from . import ref

__all__ = ["attention", "mlp", "layernorm", "ref"]
