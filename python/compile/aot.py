"""AOT pipeline: lower every pipeline stage (and the full model) to HLO
*text* and emit the manifest the Rust runtime consumes.

Interchange format is HLO text, NOT `.serialize()`: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids that the image's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --out ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import ModelConfig, example_input, full_model, init_params, make_stage_fns


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text (return_tuple=True so the
    Rust side unwraps with `to_tuple1`).

    CRITICAL: the default printer elides large constants as `{...}`,
    which the XLA text parser silently reads back as *zeros* — the baked
    model weights would vanish. `print_large_constants=True` keeps them.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    opts = xc._xla.HloPrintOptions.short_parsable()
    opts.print_large_constants = True
    return comp.as_hlo_module().to_string(opts)


def lower_stage(fn, in_shape, in_dtype):
    dtype = {"i32": jnp.int32, "f32": jnp.float32}[in_dtype]
    spec = jax.ShapeDtypeStruct(in_shape, dtype)
    return to_hlo_text(jax.jit(fn).lower(spec))


def build(cfg: ModelConfig, out_dir: str, quiet: bool = False) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    params = init_params(cfg)
    stages = make_stage_fns(cfg, params)

    manifest = {
        "model": cfg.name,
        "d_model": cfg.d_model,
        "n_layers": cfg.n_layers,
        "vocab": cfg.vocab,
        "seq_len": cfg.seq_len,
        "batch": cfg.batch,
        "stages": [],
    }

    for st in stages:
        hlo = lower_stage(st["fn"], st["in_shape"], st["in_dtype"])
        fname = f"{st['name']}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(hlo)
        if not quiet:
            print(f"  {fname}: {len(hlo)} chars, {st['params']} params")
        manifest["stages"].append(
            {
                "name": st["name"],
                "hlo": fname,
                "in_shape": list(st["in_shape"]),
                "out_shape": list(st["out_shape"]),
                "in_dtype": st["in_dtype"],
                "out_dtype": st["out_dtype"],
                "params": st["params"],
            }
        )

    # The monolithic model, for the single-executable baseline and for
    # stage-composition checks from Rust.
    hlo = lower_stage(full_model(cfg, params), (cfg.batch, cfg.seq_len), "i32")
    with open(os.path.join(out_dir, "full_model.hlo.txt"), "w") as f:
        f.write(hlo)
    manifest["full_model"] = "full_model.hlo.txt"

    # A golden input/output pair so the Rust runtime can self-check
    # numerics end to end without Python in the loop.
    tokens = example_input(cfg)
    logits = jax.jit(full_model(cfg, params))(tokens)
    golden = {
        "tokens": [int(t) for t in tokens.reshape(-1)],
        "tokens_shape": list(tokens.shape),
        "logits_sample": [float(x) for x in jnp.asarray(logits).reshape(-1)[:64]],
        "logits_shape": list(logits.shape),
        "logits_checksum": float(jnp.abs(logits).sum()),
    }
    with open(os.path.join(out_dir, "golden.json"), "w") as f:
        json.dump(golden, f)

    with open(os.path.join(out_dir, "model.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    if not quiet:
        print(f"wrote {out_dir}/model.json ({len(manifest['stages'])} stages)")
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--stages", type=int, default=3)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=16)
    args = ap.parse_args()
    cfg = ModelConfig(
        n_stages=args.stages,
        n_layers=args.layers,
        d_model=args.d_model,
        batch=args.batch,
        seq_len=args.seq_len,
    )
    build(cfg, args.out)


if __name__ == "__main__":
    main()
