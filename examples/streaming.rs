//! Continuous-batching streaming demo — artifact-free (forward-only
//! workers, no PJRT). Two parts:
//!
//! 1. **A live token stream**: multi-token requests submitted through
//!    the always-on ingress come back as `RequestHandle` token streams;
//!    the demo drains one handle event by event, printing tokens as the
//!    decode loop materializes them.
//! 2. **Iteration-level vs gang scheduling**: the same saturated
//!    mixed-budget workload run twice over the identical streaming wire
//!    — once with per-step admission (continuous batching) and once
//!    with `MW_DECODE_GANG`-style run-to-completion admission — to show
//!    where the throughput comes from.
//!
//! Run: `cargo run --release --example streaming`
//! (`MW_BENCH_QUICK=1` trims the run for CI smoke.)

use multiworld::bench::scenarios::streaming_serve;
use multiworld::config::ServingConfig;
use multiworld::launch::InProcCluster;
use multiworld::mwccl::WorldOptions;
use multiworld::serving::controller::ScalingPolicy;
use multiworld::serving::topology::Topology;
use multiworld::serving::{Outcome, RequestGen, StreamEvent};
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("MW_BENCH_QUICK").as_deref() == Ok("1");
    let opts = || WorldOptions::shm().with_init_timeout(Duration::from_secs(120));

    println!("== token stream (one request, budget 12) ==");
    let topo = Topology::pipeline("streaming-demo", &[1], 62_300);
    let cluster = InProcCluster::start_forward_only(
        topo,
        opts(),
        ScalingPolicy { recover: false, ..Default::default() },
        &ServingConfig { batch_timeout_ms: 2, ..Default::default() },
        4,  // batch
        8,  // seq_len
        32, // vocab
    )?;
    let mut gen = RequestGen::new(0x57E4, 8, 32, None);
    let (req, _) = gen.next();
    let handle = cluster.leader.submit(req.with_max_tokens(12));
    anyhow::ensure!(handle.is_streaming(), "multi-token requests stream");
    let deadline = Instant::now() + Duration::from_secs(60);
    let mut tokens = Vec::new();
    let outcome = loop {
        match handle.next_event(deadline) {
            Some(StreamEvent::Token(t)) => {
                tokens.push(t);
                print!("{t} ");
            }
            Some(StreamEvent::Done(o)) => break o,
            None => anyhow::bail!("stream timed out"),
        }
    };
    println!("\n{} tokens, final outcome: {outcome:?}", tokens.len());
    anyhow::ensure!(tokens.len() == 12, "the full decode budget streams back");
    anyhow::ensure!(matches!(outcome, Outcome::Response(_)));
    cluster.shutdown();

    // Mixed budgets at saturation: 1-in-8 requests decode 16 tokens,
    // the rest 2 — the workload shape where per-step slot re-fill pays.
    let n = if quick { 16 } else { 48 };
    println!("\n== gang scheduling ({n} requests, run-to-completion ablation) ==");
    let gang = streaming_serve(n, 8, 16, 2, true, opts(), 62_700)?;
    println!(
        "completed {} | {:.1} req/s | {:.0} tok/s | ttft p99 {:.2} ms | itl p99 {:.2} ms",
        gang.completed, gang.requests_per_s, gang.tokens_per_s, gang.ttft_p99_ms, gang.itl_p99_ms
    );
    anyhow::ensure!(gang.completed == n, "gang leg finishes every request");

    println!("\n== continuous batching ({n} requests, iteration-level admission) ==");
    let cont = streaming_serve(n, 8, 16, 2, false, opts(), 63_100)?;
    println!(
        "completed {} | {:.1} req/s | {:.0} tok/s | ttft p99 {:.2} ms | itl p99 {:.2} ms",
        cont.completed, cont.requests_per_s, cont.tokens_per_s, cont.ttft_p99_ms, cont.itl_p99_ms
    );
    anyhow::ensure!(cont.completed == n, "continuous leg finishes every request");
    anyhow::ensure!(
        cont.requests_per_s > gang.requests_per_s,
        "iteration-level admission must out-run gang scheduling"
    );

    println!(
        "\ncontinuous batching: {:.1}x request throughput over gang scheduling",
        cont.requests_per_s / gang.requests_per_s
    );
    Ok(())
}
