//! Quickstart: the MultiWorld API in 60 lines.
//!
//! One process (here: the main thread) joins TWO worlds at once — the
//! thing a classic CCL cannot do — moves tensors through both, survives
//! one world's peer dying, and keeps using the other.
//!
//! Run: `cargo run --release --example quickstart`

use multiworld::multiworld::WorldManager;
use multiworld::mwccl::{Rendezvous, WorldOptions};
use multiworld::tensor::Tensor;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    // A worker-side manager: watchdog, per-world state, communicator.
    let mgr = WorldManager::new();
    let comm = mgr.communicator();

    // Join two independent 2-member worlds (peers run on threads here;
    // across processes it is the same call with a shared store port).
    let mut peers = Vec::new();
    for name in ["alpha", "beta"] {
        let worlds = Rendezvous::single_process(name, 2, WorldOptions::shm())?;
        let mut it = worlds.into_iter();
        mgr.adopt(it.next().unwrap()).map_err(|e| anyhow::anyhow!("{e}"))?;
        peers.push(it.next().unwrap());
    }
    println!("member of worlds: {:?}", mgr.world_names());

    // Peers send one tensor each; receive from BOTH worlds, in whichever
    // order they land (async ops + wait_any — §3.2's non-blocking CCL).
    let senders: Vec<_> = peers
        .into_iter()
        .enumerate()
        .map(|(i, w)| {
            std::thread::spawn(move || {
                let t = Tensor::from_f32(&[2], &[i as f32, 42.0]);
                w.send(t, 0, 0).unwrap();
                w // keep the world alive until the send is delivered
            })
        })
        .collect();
    let works = vec![
        comm.recv("alpha", 1, 0).map_err(|e| anyhow::anyhow!("{e}"))?,
        comm.recv("beta", 1, 0).map_err(|e| anyhow::anyhow!("{e}"))?,
    ];
    let first = comm.wait_any(&works).unwrap();
    println!("first tensor arrived from world #{first}");
    for (name, w) in ["alpha", "beta"].iter().zip(&works) {
        let t = w.wait().map_err(|e| anyhow::anyhow!("{e}"))?.unwrap();
        println!("  {name}: {:?} -> {:?}", t.shape(), t.as_f32());
    }

    // Fault isolation: kill beta's peer; alpha keeps working.
    let mut saved = Vec::new();
    for s in senders {
        saved.push(s.join().unwrap());
    }
    let beta_peer = saved.pop().unwrap();
    let alpha_peer = saved.pop().unwrap();
    drop(beta_peer); // "process crash"
    std::thread::sleep(Duration::from_millis(100));
    let err = comm.recv_blocking("beta", 1, 1).unwrap_err();
    println!("beta is broken as expected: {err}");

    let h = std::thread::spawn(move || {
        alpha_peer.send(Tensor::from_f32(&[1], &[7.0]), 0, 1).unwrap();
    });
    let t = comm.recv_blocking("alpha", 1, 1).map_err(|e| anyhow::anyhow!("{e}"))?;
    h.join().unwrap();
    println!("alpha still works after beta died: {:?}", t.as_f32());
    println!("remaining worlds: {:?}", mgr.world_names());
    Ok(())
}
