//! Closed-loop autoscaling demo — artifact-free (forward-only workers,
//! no PJRT): open-loop traffic flows through the always-on
//! `Leader::submit` ingress while the cluster's `Autoscaler` samples
//! live queue-depth signals and grows/shrinks the replica set. Two
//! arrival curves:
//!
//! * **burst** — a hard front-loaded spike, then near-idle: scale out
//!   under the spike, drain and scale back in after it;
//! * **diurnal** — a sinusoidal day/night cycle.
//!
//! Run: `cargo run --release --example autoscale`
//! (`MW_BENCH_QUICK=1` trims the run for CI smoke.)

use multiworld::bench::scenarios::{autoscale_serve, ArrivalCurve};
use multiworld::mwccl::WorldOptions;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("MW_BENCH_QUICK").as_deref() == Ok("1");
    let secs = if quick { 2.0 } else { 6.0 };
    let opts = || WorldOptions::shm().with_init_timeout(Duration::from_secs(120));

    println!("== burst curve ({secs:.0}s open-loop) ==");
    let r = autoscale_serve(
        ArrivalCurve::Burst { high_rps: 600.0, low_rps: 10.0, burst_frac: 0.4 },
        Duration::from_secs_f64(secs),
        opts(),
        51_000,
    )?;
    println!(
        "submitted {} | completed {} | rejected {} | dropped {} | \
         scaled out {} | scaled in {} | p99 {:.1} ms",
        r.submitted, r.completed, r.rejected, r.dropped, r.scaled_out, r.scaled_in, r.p99_ms
    );
    anyhow::ensure!(r.completed > 0, "burst traffic must flow");

    println!("\n== diurnal curve ({secs:.0}s open-loop) ==");
    let r = autoscale_serve(
        ArrivalCurve::Diurnal { peak_rps: 500.0, trough_rps: 20.0, cycles: 1.0 },
        Duration::from_secs_f64(secs),
        opts(),
        51_400,
    )?;
    println!(
        "submitted {} | completed {} | rejected {} | dropped {} | \
         scaled out {} | scaled in {} | p99 {:.1} ms",
        r.submitted, r.completed, r.rejected, r.dropped, r.scaled_out, r.scaled_in, r.p99_ms
    );
    anyhow::ensure!(r.completed > 0, "diurnal traffic must flow");

    println!("\nclosed-loop autoscaling under live traffic: OK");
    Ok(())
}
