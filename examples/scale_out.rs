//! Online scaling demo (Fig. 2c): start a 1-1-1 pipeline, drive load,
//! let the controller's policy scale the middle stage out when queue
//! depth builds, and show both replicas taking traffic — all without
//! restarting any existing worker.
//!
//! Requires `make artifacts`. Run:
//! `cargo run --release --example scale_out`

use multiworld::config::ServingConfig;
use multiworld::launch::InProcCluster;
use multiworld::mwccl::WorldOptions;
use multiworld::runtime::artifacts_dir;
use multiworld::serving::controller::ScalingPolicy;
use multiworld::serving::topology::Topology;
use multiworld::serving::RequestGen;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    if !artifacts_dir().join("model.json").exists() {
        anyhow::bail!("artifacts missing — run `make artifacts` first");
    }
    let topo = Topology::pipeline("scale", &[1, 1, 1], 44_000);
    let cfg = ServingConfig { heartbeat_ms: 100, batch_timeout_ms: 2, ..Default::default() };
    let cluster = InProcCluster::start(
        topo,
        artifacts_dir(),
        WorldOptions::shm().with_init_timeout(Duration::from_secs(180)),
        ScalingPolicy { scale_up_depth: 8.0, max_replicas: 2, recover: false },
        &cfg,
    )?;
    let manifest = cluster.manifest.clone();
    println!("pipeline 1x1x1 up; scale-out threshold: 8 queued batches per replica");

    // A policy thread watching the leader's queue depth (the loop the
    // controller would run in a deployment).
    let leader = cluster.leader.clone();
    let controller = cluster.controller.clone();
    let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    let stop2 = stop.clone();
    let policy = std::thread::spawn(move || {
        while !stop2.load(std::sync::atomic::Ordering::Relaxed) {
            let depth = leader.depth_per_replica();
            if depth.is_finite() {
                if let Ok(Some(action)) = controller.maybe_scale_out(1, depth) {
                    println!("  [controller] {action:?} (queue depth {depth:.0})");
                }
            }
            std::thread::sleep(Duration::from_millis(100));
        }
    });

    // Open-loop burst: far more than one middle replica keeps up with.
    let n = manifest.batch * 24;
    println!("driving a burst of {n} requests…");
    let mut gen = RequestGen::new(3, manifest.seq_len, manifest.vocab, None);
    let report = cluster
        .leader
        .serve(gen.take(n), Some(2_000.0), Duration::from_secs(180));
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    let _ = policy.join();

    println!(
        "burst done: {}/{} answered, p50 {:.1} ms, p99 {:.1} ms, throughput {:.1} req/s",
        report.completed, n, report.p50_ms, report.p99_ms, report.throughput_rps
    );
    println!("controller actions: {:?}", cluster.controller.actions());
    println!("live workers after scaling: {:?}", cluster.live_workers());
    println!("topology now: {} (replica ids are append-only)", cluster.controller.topology().shape());
    cluster.shutdown();
    Ok(())
}
