//! Elastic pipeline with REAL OS processes: spawns `multiworld worker`
//! subprocesses for a 3-stage pipeline, streams requests through it,
//! then SIGKILLs a worker to show fault isolation at true process
//! granularity (closed sockets / silent rings, watchdog detection).
//!
//! Requires `make artifacts` and `cargo build --release` (the workers
//! run from `target/release/multiworld`; set `MW_BIN` to override).
//!
//! Run: `cargo run --release --example elastic_pipeline`

use multiworld::config::ServingConfig;
use multiworld::launch::ProcessCluster;
use multiworld::multiworld::{StatePolicy, WatchdogConfig, WorldManager};
use multiworld::mwccl::WorldOptions;
use multiworld::runtime::artifacts_dir;
use multiworld::serving::topology::{NodeId, Topology};
use multiworld::serving::{Leader, Outcome, RequestGen};
use multiworld::util::time::Clock;
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    if !artifacts_dir().join("model.json").exists() {
        anyhow::bail!("artifacts missing — run `make artifacts` first");
    }
    let bin = multiworld::baselines::multiproc::multiworld_bin()
        .map_err(|_| anyhow::anyhow!("build the binary first: cargo build --release"))?;
    std::env::set_var("MW_BIN", &bin);

    // 1-2-1 rhombus across real processes.
    let topo = Topology::pipeline("proc", &[1, 2, 1], 42_000);
    println!("spawning {} worker processes…", topo.workers().len());
    let cluster = ProcessCluster::start(topo.clone(), artifacts_dir(), "tcp")?;

    // The leader lives in THIS process.
    let cfg = ServingConfig { heartbeat_ms: 150, miss_threshold: 3, ..Default::default() };
    let mgr = WorldManager::with_options(
        StatePolicy::Kv,
        WatchdogConfig {
            heartbeat: Duration::from_millis(cfg.heartbeat_ms),
            miss_threshold: cfg.miss_threshold,
        },
        Clock::system(),
    );
    let manifest = multiworld::config::ModelManifest::load(artifacts_dir().join("model.json"))?;
    let opts = WorldOptions::tcp().with_init_timeout(Duration::from_secs(180));
    let leader = Leader::new(
        mgr,
        &topo,
        &opts,
        manifest.batch,
        manifest.seq_len,
        manifest.vocab,
        &cfg,
    )?;
    println!("pipeline up: {} worlds established across 5 processes", topo.worlds.len());

    // Phase 1: serve through real processes.
    let mut gen = RequestGen::new(7, manifest.seq_len, manifest.vocab, None);
    let r1 = leader.serve(gen.take(64), Some(200.0), Duration::from_secs(120));
    println!(
        "[healthy]  {}/{} answered, p50 {:.1} ms, throughput {:.1} req/s",
        r1.completed, 64, r1.p50_ms, r1.throughput_rps
    );

    // Phase 2: SIGKILL the replicated middle stage's second replica,
    // then drive the always-on ingress directly: each `submit` returns
    // a handle that resolves to a response, an SLO drop, or an
    // admission rejection — here all 64 must come back as responses,
    // rerouted through the surviving replica.
    println!("SIGKILLing worker s1r1…");
    cluster.kill(NodeId::worker(1, 1))?;
    let mut handles = Vec::with_capacity(64);
    for r in gen.take(64) {
        handles.push(leader.submit(r));
        std::thread::sleep(Duration::from_secs_f64(1.0 / 200.0));
    }
    let mut answered = 0usize;
    let mut lost = 0usize;
    for h in &handles {
        match h.wait_deadline(Instant::now() + Duration::from_secs(120)) {
            Some(Outcome::Response(_)) => answered += 1,
            other => {
                lost += 1;
                eprintln!("request {} did not complete: {other:?}", h.id());
            }
        }
    }
    println!(
        "[degraded] {answered}/64 answered via submit handles, {lost} lost \
         (traffic rerouted through s1r0)"
    );
    assert_eq!(answered, 64, "service must survive the process kill");

    leader.stop_runtime();
    println!("fault isolation across real processes: OK");
    Ok(())
}
