//! Tensor-parallel serving demo: a `tp=2, replicas=2, stages=2`
//! pipeline whose replicas are split into shards joined by multi-member
//! intra-replica TP worlds — the activation is `broadcast` across each
//! replica's shards and the partial outputs are combined with
//! `all_reduce` on every batch, then a shard is killed mid-flight and
//! the controller re-mints the replica's worlds and respawns exactly
//! the dead shard.
//!
//! Forward-only (no PJRT, no artifacts) so it runs anywhere, CI
//! included. Pick the collective algorithm with `MW_COLL_ALGO`
//! (`flat`/`ring`/`auto`).
//!
//! Run: `cargo run --release --example tensor_parallel`

use multiworld::config::ServingConfig;
use multiworld::launch::InProcCluster;
use multiworld::mwccl::WorldOptions;
use multiworld::serving::controller::{Action, ScalingPolicy};
use multiworld::serving::topology::{NodeId, Topology};
use multiworld::serving::RequestGen;
use std::time::Duration;

const BATCH: usize = 4;
const SEQ_LEN: usize = 8;
const VOCAB: usize = 32;

fn tp_collectives_seen() -> (u64, u64, u64, u64) {
    let g = multiworld::metrics::global();
    (
        g.counter("serving.tp.broadcast.flat").get(),
        g.counter("serving.tp.broadcast.ring").get(),
        g.counter("serving.tp.all_reduce.flat").get(),
        g.counter("serving.tp.all_reduce.ring").get(),
    )
}

fn main() -> anyhow::Result<()> {
    // 2 stages × 2 replicas × 2 shards = 8 workers; edge worlds
    // terminate at replica heads, every replica gets a tp-s{i}r{r}
    // world with rank == shard.
    let topo = Topology::pipeline_tp("tpdemo", &[2, 2], &[2, 2], 47_000);
    println!(
        "topology {}: {} workers, {} worlds ({} TP worlds of size 2)",
        topo.shape(),
        topo.workers().len(),
        topo.worlds.len(),
        topo.worlds.iter().filter(|w| w.is_tp()).count(),
    );
    let cfg = ServingConfig { heartbeat_ms: 100, batch_timeout_ms: 2, ..Default::default() };
    let cluster = InProcCluster::start_forward_only(
        topo,
        WorldOptions::tcp().with_init_timeout(Duration::from_secs(120)),
        ScalingPolicy { recover: true, ..Default::default() },
        &cfg,
        BATCH,
        SEQ_LEN,
        VOCAB,
    )?;
    println!("cluster up; serving phase 1 (healthy)…");

    let mut gen = RequestGen::new(7, SEQ_LEN, VOCAB, None);
    let total = BATCH * 8;
    let r1 = cluster.leader.serve(gen.take(total), None, Duration::from_secs(60));
    let (bf, br, af, ar) = tp_collectives_seen();
    println!(
        "[healthy]  {}/{} answered, p50 {:.1} ms — TP collectives ran: \
         broadcast flat={bf} ring={br}, all_reduce flat={af} ring={ar}",
        r1.completed, total, r1.p50_ms
    );
    anyhow::ensure!(r1.completed == total, "phase 1 lost requests");
    anyhow::ensure!(bf + br > 0 && af + ar > 0, "TP collectives must have run");

    // Kill one shard mid-traffic; the controller re-mints the replica's
    // worlds and respawns exactly the dead shard.
    let victim = NodeId::Worker { stage: 1, replica: 1, shard: 1 };
    println!("killing shard {victim} mid-traffic…");
    let cluster_ref = &cluster;
    let r2 = std::thread::scope(|s| {
        let killer = s.spawn(move || {
            std::thread::sleep(Duration::from_millis(100));
            assert!(cluster_ref.kill(victim));
        });
        let r = cluster_ref
            .leader
            .serve(gen.take(total), Some(300.0), Duration::from_secs(90));
        killer.join().unwrap();
        r
    });
    println!(
        "[degraded] {}/{} answered, retries {} (leader re-dispatched lost batches)",
        r2.completed, total, r2.retries
    );
    anyhow::ensure!(r2.completed == total, "phase 2 lost requests");

    // Wait for the shard-granularity recovery.
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    loop {
        let recovered = cluster.controller.actions().into_iter().find(|a| {
            matches!(a, Action::Recovered { dead, .. } if *dead == victim)
        });
        if let Some(Action::Recovered { dead, replacement }) = recovered {
            println!("controller recovered {dead} as {replacement} (same shard id, fresh worlds)");
            break;
        }
        anyhow::ensure!(std::time::Instant::now() < deadline, "recovery never happened");
        std::thread::sleep(Duration::from_millis(50));
    }
    let tp_world = cluster
        .controller
        .topology()
        .tp_world_of(victim)
        .map(|w| w.name.clone())
        .unwrap();
    println!("replica's fresh TP world: {tp_world}");
    anyhow::ensure!(tp_world.contains("#g"), "fresh worlds are generation-tagged");

    // Serve once more through the recovered replica.
    let r3 = cluster.leader.serve(gen.take(total), None, Duration::from_secs(60));
    println!("[recovered] {}/{} answered, p50 {:.1} ms", r3.completed, total, r3.p50_ms);
    anyhow::ensure!(r3.completed == total, "phase 3 lost requests");

    println!("tensor-parallel serving with shard-granularity recovery: OK");
    Ok(())
}
