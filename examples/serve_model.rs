//! End-to-end serving driver (the mandated E7 experiment): load the
//! AOT-compiled transformer, deploy the paper's 1-2-1 rhombus pipeline,
//! serve batched Poisson traffic, kill the replicated middle stage's
//! replica mid-run, let the controller recover it, and report
//! latency/throughput for each phase.
//!
//! Requires `make artifacts`. Run:
//! `cargo run --release --example serve_model [-- --requests 256 --rate 300]`
//!
//! Results of a reference run are recorded in EXPERIMENTS.md §E7.

use multiworld::config::ServingConfig;
use multiworld::launch::InProcCluster;
use multiworld::mwccl::WorldOptions;
use multiworld::runtime::artifacts_dir;
use multiworld::serving::controller::ScalingPolicy;
use multiworld::serving::topology::{NodeId, Topology};
use multiworld::serving::RequestGen;
use multiworld::util::args::Command;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let cli = Command::new("serve_model", "end-to-end elastic serving demo")
        .opt("requests", "requests per phase", Some("192"))
        .opt("rate", "arrival rate (req/s)", Some("300"))
        .opt("transport", "shm|tcp", Some("tcp"));
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let m = cli.parse(&argv).map_err(|e| anyhow::anyhow!("{e}"))?;
    let n_requests: usize = m.usize("requests").map_err(anyhow::Error::msg)?;
    let rate: f64 = m.f64("rate").map_err(anyhow::Error::msg)?;
    let opts = match m.get_or("transport", "tcp").as_str() {
        "shm" => WorldOptions::shm(),
        _ => WorldOptions::tcp(),
    }
    .with_init_timeout(Duration::from_secs(180));

    if !artifacts_dir().join("model.json").exists() {
        anyhow::bail!("artifacts missing — run `make artifacts` first");
    }

    println!("== deploying 1-2-1 pipeline (leader + 4 workers, one world per edge) ==");
    let topo = Topology::pipeline("serve", &[1, 2, 1], 40_000);
    println!("worlds: {:?}", topo.worlds.iter().map(|w| w.name.as_str()).collect::<Vec<_>>());
    let cfg = ServingConfig { heartbeat_ms: 100, miss_threshold: 3, ..ServingConfig::from_env() };
    let cluster = InProcCluster::start(
        topo,
        artifacts_dir(),
        opts,
        ScalingPolicy { recover: true, ..Default::default() },
        &cfg,
    )?;
    let manifest = cluster.manifest.clone();
    println!(
        "model: {} — {} params, {} stages, batch {}, seq {}",
        manifest.model,
        manifest.total_params(),
        manifest.stages.len(),
        manifest.batch,
        manifest.seq_len
    );

    let mut gen = RequestGen::new(0xE7, manifest.seq_len, manifest.vocab, None);

    // Phase 1 — healthy pipeline.
    println!("\n== phase 1: healthy pipeline, {n_requests} requests at {rate}/s ==");
    let r1 = cluster
        .leader
        .serve(gen.take(n_requests), Some(rate), Duration::from_secs(120));
    print_report("healthy", &r1);

    // Phase 2 — kill the middle replica mid-run; retries + the other
    // replica absorb the traffic; the controller spawns a replacement.
    println!("\n== phase 2: killing s1r1 mid-run ==");
    let killer = {
        let c: &InProcCluster = &cluster;
        std::thread::scope(|s| {
            let h = s.spawn(move || {
                std::thread::sleep(Duration::from_millis(300));
                let killed = c.kill(NodeId::worker(1, 1));
                println!("  [failure injector] killed s1r1: {killed}");
            });
            let r = c
                .leader
                .serve(gen.take(n_requests), Some(rate), Duration::from_secs(180));
            h.join().unwrap();
            r
        })
    };
    print_report("with failure + recovery", &killer);

    // Give the controller a beat, then show the healed topology.
    std::thread::sleep(Duration::from_secs(2));
    println!(
        "\ncontroller actions: {:?}",
        cluster.controller.actions()
    );
    println!("live workers: {:?}", cluster.live_workers());

    // Phase 3 — steady state after recovery.
    println!("\n== phase 3: post-recovery steady state ==");
    let r3 = cluster
        .leader
        .serve(gen.take(n_requests), Some(rate), Duration::from_secs(120));
    print_report("recovered", &r3);

    cluster.shutdown();
    println!("\nE7 complete — record these numbers in EXPERIMENTS.md §E7.");
    Ok(())
}

fn print_report(phase: &str, r: &multiworld::serving::LeaderReport) {
    println!(
        "  [{phase}] completed {}  throughput {:.1} req/s  p50 {:.1} ms  p99 {:.1} ms  mean {:.1} ms  retries {}",
        r.completed, r.throughput_rps, r.p50_ms, r.p99_ms, r.mean_ms, r.retries
    );
}
