//! Worlds over the per-host-pair multiplexed connection: socket-count
//! scaling as worlds are minted, and gray-failure isolation between
//! lanes sharing one connection (both the fault-injection layer wrapping
//! mux lanes and raw credit backpressure).

use multiworld::config::CollAlgo;
use multiworld::mwccl::transport::fault::TEST_SERIAL;
use multiworld::mwccl::transport::mux;
use multiworld::mwccl::{
    fault_registry, EdgePattern, FaultKind, FaultPlan, FaultRule, Rendezvous, ReduceOp,
    WorldOptions,
};
use multiworld::tensor::Tensor;
use std::time::Duration;

fn uniq(name: &str) -> String {
    use std::sync::atomic::{AtomicU64, Ordering};
    static N: AtomicU64 = AtomicU64::new(0);
    format!(
        "mx-{name}-{}-{}",
        std::process::id(),
        N.fetch_add(1, Ordering::Relaxed)
    )
}

/// A 2-rank split-host tcp world: the single edge crosses hosts, so all
/// its traffic rides a mux lane of `domain`'s host-pair connection.
fn split_opts(domain: &str) -> WorldOptions {
    WorldOptions::tcp()
        .with_hostmap("0,1")
        .with_mux_domain(domain)
        .with_coll_algo(CollAlgo::Flat)
        .with_op_timeout(Duration::from_secs(60))
}

fn int_tensor(elems: usize, rank: usize) -> Tensor {
    let vals: Vec<f32> = (0..elems)
        .map(|i| ((i as u64 * 31 + rank as u64 * 7 + 3) % 101) as f32)
        .collect();
    Tensor::from_f32(&[elems], &vals)
}

#[test]
fn minting_worlds_keeps_sockets_per_host_pair_constant() {
    // Before multiplexing, every world minted its own sockets — N worlds
    // between two hosts cost N connections. Over mux the connection
    // count must stay flat while the lane count grows with the worlds.
    let domain = uniq("mint");
    let mut kept = Vec::new();
    let mut lanes_prev = 0;
    for i in 0..5 {
        let worlds =
            Rendezvous::single_process(&uniq(&format!("w{i}")), 2, split_opts(&domain))
                .unwrap();
        let s = mux::stats(&domain);
        assert_eq!(
            s.conns, 2,
            "world {i}: sockets per host pair must stay O(1) (2 in-process endpoints)"
        );
        assert!(
            s.lanes > lanes_prev,
            "world {i}: each minted world must add lanes ({} vs {lanes_prev})",
            s.lanes
        );
        lanes_prev = s.lanes;
        kept.push(worlds);
    }
    // Every world stays live and correct over the one shared connection.
    let want = {
        let mut acc = int_tensor(50_000, 0).as_f32().to_vec();
        for (a, b) in acc.iter_mut().zip(int_tensor(50_000, 1).as_f32()) {
            *a += *b;
        }
        Tensor::from_f32(&[50_000], &acc).checksum()
    };
    let handles: Vec<_> = kept
        .into_iter()
        .flatten()
        .map(|w| {
            let t = int_tensor(50_000, w.rank());
            std::thread::spawn(move || w.all_reduce(t, ReduceOp::Sum).unwrap().checksum())
        })
        .collect();
    for h in handles {
        assert_eq!(h.join().unwrap(), want);
    }
}

#[test]
fn stalled_lane_under_fault_injection_spares_sibling_worlds() {
    // FaultLink wraps mux lanes like any other transport: a stall
    // injected on world A's cross-host edge wedges A alone, while world
    // B — sharing the same host-pair connection — keeps serving. When
    // the fault heals, A's held traffic flushes in order.
    let _serial = TEST_SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    fault_registry().reset();
    let domain = uniq("gray");
    let wa_name = uniq("wa");
    let wb_name = uniq("wb");
    let o = || split_opts(&domain).with_fault_plan(FaultPlan::empty(7));
    let wa = Rendezvous::single_process(&wa_name, 2, o()).unwrap();
    let wb = Rendezvous::single_process(&wb_name, 2, o()).unwrap();
    let id = fault_registry().inject(FaultRule::always(
        EdgePattern::new(&wa_name, Some(0), Some(1)),
        FaultKind::Stall,
    ));

    let payload = int_tensor(100_000, 3);
    let want_a = payload.checksum();
    let a_handles: Vec<_> = wa
        .into_iter()
        .map(|w| {
            let t = (w.rank() == 0).then(|| payload.clone());
            std::thread::spawn(move || w.broadcast(t, 0).unwrap().checksum())
        })
        .collect();

    // With A's lane wedged, B completes a run of collectives over the
    // same shared connection.
    let want_b = {
        let mut acc = int_tensor(20_000, 0).as_f32().to_vec();
        for (a, b) in acc.iter_mut().zip(int_tensor(20_000, 1).as_f32()) {
            *a += *b;
        }
        Tensor::from_f32(&[20_000], &acc).checksum()
    };
    let b_handles: Vec<_> = wb
        .into_iter()
        .map(|w| {
            std::thread::spawn(move || {
                for _ in 0..5 {
                    let t = int_tensor(20_000, w.rank());
                    assert_eq!(w.all_reduce(t, ReduceOp::Sum).unwrap().checksum(), want_b);
                }
            })
        })
        .collect();
    for h in b_handles {
        h.join().unwrap(); // B finished while A is still stalled
    }
    let stalled = |name: &str| {
        fault_registry()
            .events()
            .into_iter()
            .any(|e| e.world == name && e.kind == "stall")
    };
    // A's root sends on its own thread; give the injection a moment to
    // be observed before asserting it fired.
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    while !stalled(&wa_name) && std::time::Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(stalled(&wa_name), "the stall must actually have held A's traffic");

    // Heal: A's held broadcast flushes and completes.
    fault_registry().heal(id);
    for h in a_handles {
        assert_eq!(h.join().unwrap(), want_a);
    }
}

#[test]
fn credit_starved_world_spares_siblings_on_shared_connection() {
    // No fault injection here — raw per-lane flow control. World A's
    // sender pushes an 8 MiB message at a receiver that is not yet
    // draining, exhausting its 4 MiB lane window and blocking mid-send.
    // That blocked sender must not hold the shared connection's writer:
    // world B's collectives proceed on sibling lanes the whole time.
    let domain = uniq("credit");
    let wa = Rendezvous::single_process(&uniq("big"), 2, split_opts(&domain)).unwrap();
    let wb = Rendezvous::single_process(&uniq("sib"), 2, split_opts(&domain)).unwrap();

    let big = int_tensor(2_000_000, 5); // 8 MiB > the 4 MiB lane window
    let want_big = big.checksum();
    let (release_tx, release_rx) = std::sync::mpsc::channel::<()>();
    let mut a_handles = Vec::new();
    for w in wa {
        if w.rank() == 0 {
            let t = big.clone();
            a_handles.push(std::thread::spawn(move || {
                w.send(t, 1, 77).unwrap();
                0
            }));
        } else {
            a_handles.push(std::thread::spawn(move || {
                // Hold off receiving until B has proven the connection
                // stays usable while A's lane is starved.
                release_rx.recv().unwrap();
                w.recv(0, 77).unwrap().checksum()
            }));
        }
    }

    let want_b = {
        let mut acc = int_tensor(30_000, 0).as_f32().to_vec();
        for (a, b) in acc.iter_mut().zip(int_tensor(30_000, 1).as_f32()) {
            *a += *b;
        }
        Tensor::from_f32(&[30_000], &acc).checksum()
    };
    let b_handles: Vec<_> = wb
        .into_iter()
        .map(|w| {
            std::thread::spawn(move || {
                for _ in 0..5 {
                    let t = int_tensor(30_000, w.rank());
                    assert_eq!(w.all_reduce(t, ReduceOp::Sum).unwrap().checksum(), want_b);
                }
            })
        })
        .collect();
    for h in b_handles {
        h.join().unwrap(); // B completed while A's receiver never ran
    }
    release_tx.send(()).unwrap();
    for h in a_handles {
        let cs = h.join().unwrap();
        if cs != 0 {
            assert_eq!(cs, want_big, "the starved lane must deliver intact after release");
        }
    }
}
