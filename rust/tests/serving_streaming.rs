//! The streaming decode loop end to end: continuous batching over a
//! live forward-only cluster — deterministic token streams, the
//! one-shot (`max_tokens=1`) reduction to the legacy wire, and the
//! zero-loss re-prefill guarantee under a mid-decode worker kill.
//!
//! The re-prefill contract under test: the leader owns all decode
//! state (generated tokens live leader-side, worker slots are soft),
//! so a killed lane costs recomputation — the victims re-prefill
//! (prompt + everything generated so far) on the next live lane and
//! their streams continue exactly where they left off. No request is
//! lost, no token is duplicated.

use multiworld::config::ServingConfig;
use multiworld::launch::InProcCluster;
use multiworld::mwccl::WorldOptions;
use multiworld::serving::controller::ScalingPolicy;
use multiworld::serving::decode::token_hash;
use multiworld::serving::topology::{NodeId, Topology};
use multiworld::serving::{Outcome, RequestGen, RequestHandle, StreamEvent};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

const BATCH: usize = 4;
const SEQ_LEN: usize = 8;
const VOCAB: usize = 32;

fn uniq(name: &str) -> String {
    use std::sync::atomic::AtomicU64;
    static N: AtomicU64 = AtomicU64::new(0);
    format!("ss-{name}-{}-{}", std::process::id(), N.fetch_add(1, Ordering::Relaxed))
}

fn opts() -> WorldOptions {
    WorldOptions::shm().with_init_timeout(Duration::from_secs(120))
}

fn start(
    name: &str,
    replicas: usize,
    recover: bool,
    base_port: u16,
    cfg: ServingConfig,
) -> InProcCluster {
    let topo = Topology::pipeline(&uniq(name), &[replicas], base_port);
    InProcCluster::start_forward_only(
        topo,
        opts(),
        ScalingPolicy { recover, ..Default::default() },
        &cfg,
        BATCH,
        SEQ_LEN,
        VOCAB,
    )
    .expect("cluster start")
}

/// Drain one handle's stream to completion; returns (tokens, outcome).
fn drain(
    h: &RequestHandle,
    deadline: Instant,
    counter: Option<&AtomicUsize>,
) -> (Vec<i32>, Option<Outcome>) {
    let mut tokens = Vec::new();
    loop {
        match h.next_event(deadline) {
            Some(StreamEvent::Token(t)) => {
                tokens.push(t);
                if let Some(c) = counter {
                    c.fetch_add(1, Ordering::Relaxed);
                }
            }
            Some(StreamEvent::Done(o)) => return (tokens, Some(o)),
            None => return (tokens, None),
        }
    }
}

#[test]
fn streams_are_deterministic_and_deliver_the_full_budget() {
    let base = 44_000 + (std::process::id() % 40) as u16 * 24;
    let cluster = start(
        "det",
        1,
        false,
        base,
        ServingConfig { batch_timeout_ms: 2, ..Default::default() },
    );
    let mut gen = RequestGen::new(0xD0D0, SEQ_LEN, VOCAB, None);
    let handles: Vec<_> = (0..6)
        .map(|i| {
            let (req, _) = gen.next();
            cluster.leader.submit(req.with_max_tokens(3 + i as u32))
        })
        .collect();
    let deadline = Instant::now() + Duration::from_secs(60);
    for (i, h) in handles.iter().enumerate() {
        assert!(h.is_streaming(), "multi-token requests stream");
        let (tokens, outcome) = drain(h, deadline, None);
        assert!(matches!(outcome, Some(Outcome::Response(_))), "req {i}: {outcome:?}");
        assert_eq!(tokens.len(), 3 + i, "req {i} decodes its exact budget");
        // Forward-only workers echo i32 activations (no logits), so the
        // leader synthesizes tokens via the deterministic token_hash —
        // the property the re-prefill test below leans on.
        for (p, t) in tokens.iter().enumerate() {
            assert_eq!(*t, token_hash(h.id(), p as u32, VOCAB), "req {i} token {p}");
        }
    }
    cluster.shutdown();
}

#[test]
fn one_shot_requests_reduce_to_the_legacy_path() {
    let base = 45_100 + (std::process::id() % 40) as u16 * 24;
    // Default config: max_tokens = 1 — the pre-streaming configuration.
    let cluster = start(
        "oneshot",
        1,
        false,
        base,
        ServingConfig { batch_timeout_ms: 2, ..Default::default() },
    );
    let mut gen = RequestGen::new(0x1507, SEQ_LEN, VOCAB, None);
    let handles: Vec<_> = (0..12).map(|_| cluster.leader.submit(gen.next().0)).collect();
    let deadline = Instant::now() + Duration::from_secs(60);
    for h in &handles {
        assert!(!h.is_streaming(), "one-shot handles carry no token stream");
        assert!(
            matches!(h.wait_deadline(deadline), Some(Outcome::Response(_))),
            "one-shot request resolves through the legacy path"
        );
    }
    // The decode loop never ran for this leader: its per-instance token
    // window stayed empty (instance-local, so concurrent tests in this
    // binary can't perturb it — unlike the process-global counters).
    assert_eq!(cluster.leader.tokens_per_s(), 0.0, "no decode tokens on the one-shot path");
    assert_eq!(cluster.leader.recent_ttft_p99_ms(), 0.0, "no TTFT samples either");
    cluster.shutdown();
}

#[test]
fn mid_decode_worker_kill_loses_zero_requests() {
    const N_REQ: usize = 8;
    const BUDGET: u32 = 256;
    let base = 45_900 + (std::process::id() % 40) as u16 * 24;
    // Two replicas, recovery on, fast detection: the victim's requests
    // must re-prefill on the surviving lane (and the re-minted one once
    // recovery lands) without losing a single request or token.
    let cluster = start(
        "kill",
        2,
        true,
        base,
        ServingConfig {
            batch_timeout_ms: 2,
            heartbeat_ms: 25,
            miss_threshold: 2,
            retry_timeout_ms: 200,
            ..Default::default()
        },
    );
    let mut gen = RequestGen::new(0x0C11, SEQ_LEN, VOCAB, None);
    let seen = Arc::new(AtomicUsize::new(0));
    let deadline = Instant::now() + Duration::from_secs(120);
    let consumers: Vec<_> = (0..N_REQ)
        .map(|_| {
            let (req, _) = gen.next();
            let h = cluster.leader.submit(req.with_max_tokens(BUDGET));
            let seen = seen.clone();
            std::thread::spawn(move || {
                let (tokens, outcome) = drain(&h, deadline, Some(&*seen));
                (h.id(), tokens, outcome)
            })
        })
        .collect();
    // Wait until decode is demonstrably mid-flight, then kill.
    let warm_by = Instant::now() + Duration::from_secs(30);
    while seen.load(Ordering::Relaxed) < 32 && Instant::now() < warm_by {
        std::thread::sleep(Duration::from_millis(1));
    }
    let at_kill = seen.load(Ordering::Relaxed);
    assert!(at_kill >= 32, "decode must be producing tokens before the kill");
    assert!(
        at_kill < N_REQ * BUDGET as usize,
        "the kill must land mid-decode, not after completion"
    );
    assert!(cluster.kill(NodeId::worker(0, 1)), "victim replica must exist");
    for c in consumers {
        let (id, tokens, outcome) = c.join().unwrap();
        assert!(
            matches!(outcome, Some(Outcome::Response(_))),
            "req {id} must survive the kill: {outcome:?}"
        );
        assert_eq!(
            tokens.len(),
            BUDGET as usize,
            "req {id}: full budget despite the mid-decode kill"
        );
        // Deterministic sequence check: re-prefill resumed exactly where
        // the dead lane left off — no token lost, none duplicated.
        for (p, t) in tokens.iter().enumerate() {
            assert_eq!(*t, token_hash(id, p as u32, VOCAB), "req {id}: token {p} continuity");
        }
    }
    cluster.shutdown();
}
