//! Runtime integration: load the AOT artifacts, run the staged pipeline
//! on PJRT-CPU and verify numerics against the JAX golden output.
//!
//! Requires `make artifacts` (skips with a message otherwise, so
//! `cargo test` works on a fresh checkout).

use multiworld::runtime::{artifacts_dir, ModelRuntime};
use multiworld::tensor::{DType, Tensor};

fn runtime_or_skip() -> Option<ModelRuntime> {
    if cfg!(not(all(feature = "pjrt", feature = "xla-backend"))) {
        eprintln!("SKIP: PJRT engine stubbed (needs --features pjrt,xla-backend)");
        return None;
    }
    let dir = artifacts_dir();
    if !dir.join("model.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(ModelRuntime::load(&dir).expect("load artifacts"))
}

#[test]
fn pipeline_matches_jax_golden() {
    let Some(rt) = runtime_or_skip() else { return };
    rt.verify_golden(artifacts_dir()).unwrap();
}

#[test]
fn stage_shapes_chain() {
    let Some(rt) = runtime_or_skip() else { return };
    for w in rt.manifest.stages.windows(2) {
        assert_eq!(w[0].out_shape, w[1].in_shape);
        assert_eq!(w[0].out_dtype, w[1].in_dtype);
    }
    assert_eq!(rt.manifest.stages[0].in_dtype, DType::I32);
    assert_eq!(
        rt.manifest.stages.last().unwrap().out_shape.last().copied(),
        Some(rt.manifest.vocab)
    );
}

#[test]
fn stage_rejects_wrong_shape() {
    let Some(rt) = runtime_or_skip() else { return };
    let bad = Tensor::zeros(DType::F32, &[1, 2, 3]);
    assert!(rt.stages[1].run(&bad).is_err());
    let bad_dtype = Tensor::zeros(DType::F32, &rt.manifest.stages[0].in_shape.clone());
    assert!(rt.stages[0].run(&bad_dtype).is_err());
}

#[test]
fn deterministic_across_runs() {
    let Some(rt) = runtime_or_skip() else { return };
    let shape = rt.manifest.stages[0].in_shape.clone();
    let tokens: Vec<i32> = (0..shape.iter().product::<usize>())
        .map(|i| (i % rt.manifest.vocab) as i32)
        .collect();
    let input = Tensor::from_i32(&shape, &tokens);
    let a = rt.run_pipeline(&input).unwrap();
    let b = rt.run_pipeline(&input).unwrap();
    assert_eq!(a.checksum(), b.checksum());
}

#[test]
fn exec_latency_is_recorded() {
    let Some(rt) = runtime_or_skip() else { return };
    let shape = rt.manifest.stages[0].in_shape.clone();
    let tokens: Vec<i32> = vec![1; shape.iter().product()];
    let input = Tensor::from_i32(&shape, &tokens);
    rt.run_pipeline(&input).unwrap();
    for st in &rt.stages {
        assert!(st.exec_time.count() >= 1, "{} latency recorded", st.spec().name);
        assert!(st.mean_exec().as_micros() > 0);
    }
}
