//! Gray-failure chaos suite: composed scenarios where the network
//! *degrades* instead of dying — stalled links, silently dropped
//! frames, truncation mid-message, one-way partitions, slow links under
//! scale-out — driven by the deterministic `FaultLink` injector
//! (`mwccl::transport::fault`). Every scenario asserts on the
//! `fault.injected.*` counters (the injection demonstrably happened,
//! not hoped-for timing), on failure attribution (no healthy rank is
//! ever convicted on gray evidence), and on zero request loss wherever
//! recovery is expected.
//!
//! Runs in the default CI build and under the `MW_COLL_ALGO`
//! {flat,ring,auto} matrix; the `chaos` CI job additionally runs it
//! under three fixed `MW_FAULT_SEED`s and uploads
//! `target/chaos/*.log` (the injection event logs written by
//! [`EventDump`]) when a scenario fails.

use multiworld::config::ServingConfig;
use multiworld::launch::InProcCluster;
use multiworld::metrics;
use multiworld::mwccl::{
    fault_registry, EdgePattern, FaultKind, FaultPlan, FaultRule, Rendezvous, WorldOptions,
};
use multiworld::serving::autoscaler::AutoscalePolicy;
use multiworld::serving::controller::{Action, ScalingPolicy};
use multiworld::serving::topology::{NodeId, Topology};
use multiworld::serving::{Outcome, RequestGen};
use multiworld::tensor::Tensor;
use std::collections::HashSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Serialize: clusters spawn many threads and the fault registry is
/// process-global.
static SERIAL: Mutex<()> = Mutex::new(());

const BATCH: usize = 4;
const SEQ_LEN: usize = 8;
const VOCAB: usize = 32;

fn uniq(prefix: &str) -> String {
    static N: AtomicU64 = AtomicU64::new(0);
    format!(
        "{prefix}{}-{}",
        std::process::id() % 1000,
        N.fetch_add(1, Ordering::Relaxed)
    )
}

fn base_port() -> u16 {
    static NEXT: AtomicU64 = AtomicU64::new(0);
    49_000 + (NEXT.fetch_add(1, Ordering::Relaxed) as u16 % 120) * 110
        + (std::process::id() % 83) as u16
}

/// The chaos seed: `MW_FAULT_SEED` (the CI chaos matrix) or a fixed
/// default, so plain `cargo test` is deterministic too.
fn seed() -> u64 {
    std::env::var("MW_FAULT_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(42)
}

fn counter(name: &str) -> u64 {
    metrics::global().counter(name).get()
}

fn injected(kind: &str) -> u64 {
    counter(&format!("fault.injected.{kind}"))
}

/// Gray scenarios assert that *nothing* breaks spuriously, so the
/// watchdog is deliberately relaxed (2 s deadline): a loaded CI box
/// stalling a worker thread briefly must never register as a failure —
/// detection in these tests comes from transport evidence and op
/// timeouts, not heartbeats. Retries are quick so silently lost batches
/// re-dispatch well inside each scenario's budget.
///
/// Built on `from_env` so CI's chaos matrix reaches in: the
/// `MW_SPARES=2` leg runs every gray scenario with a warm spare pool,
/// chaos-testing promotion (the assertions hold either way — recovery
/// is recovery, pooled or cold).
fn gray_cfg() -> ServingConfig {
    ServingConfig {
        heartbeat_ms: 250,
        miss_threshold: 8,
        batch_timeout_ms: 3,
        retry_timeout_ms: 400,
        retry_max_attempts: 50,
        ..ServingConfig::from_env()
    }
}

fn recoveries(cluster: &InProcCluster) -> Vec<Action> {
    cluster
        .controller
        .actions()
        .into_iter()
        .filter(|a| matches!(a, Action::Recovered { .. }))
        .collect()
}

/// Writes the fault-injection event log to `target/chaos/<name>.log` on
/// scope exit — including panic unwinds, so a failing scenario leaves
/// its injection evidence behind for the CI artifact upload.
struct EventDump(&'static str);

impl Drop for EventDump {
    fn drop(&mut self) {
        let dir = std::path::Path::new("target/chaos");
        let _ = std::fs::create_dir_all(dir);
        let _ = std::fs::write(
            dir.join(format!("{}.log", self.0)),
            fault_registry().render_events(),
        );
    }
}

// ---------------------------------------------------------------------
// Scenario 1: a frame truncated mid-message (sender "crashes
// mid-frame") on one replica's forward edge. The receiver's pooled
// inbox must detect the short message (never deliver it, never unwind
// the reader), attribute the edge, and the batch must be re-dispatched
// to the surviving replica with zero request loss — and *nobody* gets
// convicted: the RemoteError names the leader's rank, which the
// controller correctly refuses to "recover".
// ---------------------------------------------------------------------
#[test]
fn truncated_frame_redispatches_without_loss_or_spurious_recovery() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    fault_registry().reset();
    let _dump = EventDump("truncated_frame");
    let trunc_before = injected("truncate");
    let corrupt_before = counter("transport.corrupt_frames");

    let topo = Topology::pipeline(&uniq("gtrunc"), &[2], base_port());
    let cluster = InProcCluster::start_forward_only(
        topo,
        WorldOptions::tcp()
            .with_init_timeout(Duration::from_secs(120))
            .with_fault_plan(FaultPlan::empty(seed())),
        ScalingPolicy { recover: true, ..Default::default() },
        &gray_cfg(),
        BATCH,
        SEQ_LEN,
        VOCAB,
    )
    .unwrap();
    // Exactly one message on the leader → replica-1 forward edge is cut
    // short mid-stream.
    cluster.faults().inject(
        FaultRule::always(
            EdgePattern::new("*-in-s0r1*", Some(0), Some(1)),
            FaultKind::Truncate { keep: 9 },
        )
        .with_count(1),
    );

    let total = BATCH * 6;
    let mut gen = RequestGen::new(3, SEQ_LEN, VOCAB, None);
    let report = cluster
        .leader
        .serve(gen.take(total), None, Duration::from_secs(90));

    assert_eq!(
        injected("truncate") - trunc_before,
        1,
        "the truncation must demonstrably fire"
    );
    assert!(
        counter("transport.corrupt_frames") > corrupt_before,
        "the receiver must detect the short message"
    );
    assert_eq!(
        report.completed, total,
        "zero request loss via redispatch (retries: {})",
        report.retries
    );
    assert!(
        recoveries(&cluster).is_empty(),
        "a corrupt frame from the leader's edge must convict nobody: {:?}",
        cluster.controller.actions()
    );
    assert_eq!(
        cluster.live_workers().len(),
        2,
        "both replicas stay alive through the gray failure"
    );
    cluster.shutdown();
}

// ---------------------------------------------------------------------
// Scenario 2: a silently dropped frame — no error anywhere, the batch
// just never arrives. Nothing breaks, nothing is convicted; the
// leader's retry sweep re-dispatches and every request completes.
// ---------------------------------------------------------------------
#[test]
fn dropped_frame_is_redispatched_with_zero_loss_and_no_broken_world() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    fault_registry().reset();
    let _dump = EventDump("dropped_frame");
    let drop_before = injected("drop");
    let broken_before = counter("manager.worlds_broken");

    let topo = Topology::pipeline(&uniq("gdrop"), &[2], base_port());
    let cluster = InProcCluster::start_forward_only(
        topo,
        WorldOptions::tcp()
            .with_init_timeout(Duration::from_secs(120))
            .with_fault_plan(FaultPlan::empty(seed())),
        ScalingPolicy { recover: true, ..Default::default() },
        &gray_cfg(),
        BATCH,
        SEQ_LEN,
        VOCAB,
    )
    .unwrap();
    cluster.faults().inject(
        FaultRule::always(
            EdgePattern::new("*-in-s0r0*", Some(0), Some(1)),
            FaultKind::Drop,
        )
        .with_count(1),
    );

    let total = BATCH * 6;
    let mut gen = RequestGen::new(5, SEQ_LEN, VOCAB, None);
    let report = cluster
        .leader
        .serve(gen.take(total), None, Duration::from_secs(90));

    assert_eq!(injected("drop") - drop_before, 1, "the drop must demonstrably fire");
    assert_eq!(report.completed, total, "zero request loss via retry");
    assert!(
        report.retries >= 1,
        "the silently lost batch is only recoverable through the sweep"
    );
    assert_eq!(
        counter("manager.worlds_broken"),
        broken_before,
        "a lost frame is gray: no world may break over it"
    );
    assert!(recoveries(&cluster).is_empty());
    cluster.shutdown();
}

// ---------------------------------------------------------------------
// Scenario 3: one-way partition of a forward edge mid-batch — sends
// vanish while the reverse path stays healthy. Requests re-dispatch
// with zero loss; when the partition heals, the same worlds serve
// again: no world was re-minted, no generation tag appeared, nobody was
// recovered.
// ---------------------------------------------------------------------
#[test]
fn one_way_partition_mid_batch_heals_without_remint() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    fault_registry().reset();
    let _dump = EventDump("partition_heals");
    let part_before = injected("partition");
    let broken_before = counter("manager.worlds_broken");

    let topo = Topology::pipeline(&uniq("gpart"), &[2], base_port());
    let cluster = InProcCluster::start_forward_only(
        topo,
        WorldOptions::tcp()
            .with_init_timeout(Duration::from_secs(120))
            .with_fault_plan(FaultPlan::empty(seed())),
        ScalingPolicy { recover: true, ..Default::default() },
        &ServingConfig { retry_timeout_ms: 300, ..gray_cfg() },
        BATCH,
        SEQ_LEN,
        VOCAB,
    )
    .unwrap();
    let worlds_before: HashSet<String> = cluster
        .controller
        .topology()
        .worlds
        .iter()
        .map(|w| w.name.clone())
        .collect();

    let total = BATCH * 8;
    let mut gen = RequestGen::new(7, SEQ_LEN, VOCAB, None);
    let requests = gen.take(total);
    let cluster_ref = &cluster;
    let report = std::thread::scope(|s| {
        s.spawn(move || {
            // Partition replica 0's forward edge mid-traffic…
            std::thread::sleep(Duration::from_millis(100));
            let id = cluster_ref.faults().inject(FaultRule::always(
                EdgePattern::new("*-in-s0r0*", Some(0), Some(1)),
                FaultKind::Partition,
            ));
            // …and heal it while requests are still in flight.
            std::thread::sleep(Duration::from_millis(700));
            cluster_ref.faults().heal(id);
        });
        cluster_ref
            .leader
            .serve(requests, Some(60.0), Duration::from_secs(90))
    });

    assert!(
        injected("partition") - part_before >= 1,
        "the partition must demonstrably swallow traffic"
    );
    assert_eq!(
        report.completed, total,
        "zero request loss across the partition window (retries: {})",
        report.retries
    );
    let worlds_after: HashSet<String> = cluster
        .controller
        .topology()
        .worlds
        .iter()
        .map(|w| w.name.clone())
        .collect();
    assert_eq!(
        worlds_before, worlds_after,
        "a healed partition must not re-mint any world"
    );
    assert!(
        worlds_after.iter().all(|w| !w.contains("#g")),
        "no generation-tagged (re-minted) names may appear"
    );
    assert_eq!(
        counter("manager.worlds_broken"),
        broken_before,
        "a one-way partition that heals must not break worlds"
    );
    assert!(recoveries(&cluster).is_empty(), "no spurious recovery");
    cluster.shutdown();
}

// ---------------------------------------------------------------------
// Scenario 4: a stalled TP link — the hardest attribution case. The
// head's sends into its replica's TP world are held (the link is
// wedged, both ends alive); the head's collective times out, it breaks
// the TP world *deliberately* and announces the teardown, so its
// healthy shard neighbor observes `Aborted` — not peer death — and the
// controller, with culprit-less TP-only evidence, convicts NOBODY.
// Traffic re-routes to the healthy replica with zero loss. (Before the
// farewell mechanism, the neighbor's RemoteError on the 2-member TP
// world convicted the *head* — a live rank — and respawned it over a
// running worker.)
// ---------------------------------------------------------------------
#[test]
fn stalled_tp_link_convicts_nobody_and_serves_through_the_other_replica() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    fault_registry().reset();
    let _dump = EventDump("stalled_tp_link");
    let stall_before = injected("stall");
    let broken_before = counter("manager.worlds_broken");

    // Stage 1: two replicas of two shards each; stage 0 unsharded.
    let topo = Topology::pipeline_tp(&uniq("gstall"), &[1, 2], &[1, 2], base_port());
    let n_workers = topo.workers().len();
    assert_eq!(n_workers, 5);
    let cluster = InProcCluster::start_forward_only(
        topo,
        WorldOptions::tcp()
            .with_init_timeout(Duration::from_secs(120))
            // The only way out of a wedged collective on a live link:
            // the op deadline.
            .with_op_timeout(Duration::from_secs(3))
            .with_fault_plan(FaultPlan::empty(seed())),
        ScalingPolicy { recover: true, ..Default::default() },
        &gray_cfg(),
        BATCH,
        SEQ_LEN,
        VOCAB,
    )
    .unwrap();
    // Wedge the head → shard-1 direction of replica (1,1)'s TP world.
    cluster.faults().inject(FaultRule::always(
        EdgePattern::new("*-tp-s1r1*", Some(0), Some(1)),
        FaultKind::Stall,
    ));

    let total = BATCH * 6;
    let mut gen = RequestGen::new(11, SEQ_LEN, VOCAB, None);
    let report = cluster
        .leader
        .serve(gen.take(total), None, Duration::from_secs(90));

    assert!(
        injected("stall") - stall_before >= 1,
        "the stall must demonstrably hold TP traffic"
    );
    assert_eq!(
        report.completed, total,
        "zero request loss: the healthy replica serves everything (retries: {})",
        report.retries
    );
    // Wait for the op timeout to fire and the teardown reports to
    // drain: the TP world demonstrably breaks…
    let deadline = Instant::now() + Duration::from_secs(20);
    while counter("manager.worlds_broken") == broken_before {
        assert!(
            Instant::now() < deadline,
            "the stalled TP world never broke (op timeout did not fire?)"
        );
        std::thread::sleep(Duration::from_millis(50));
    }
    std::thread::sleep(Duration::from_millis(300));
    // …and still, nobody is convicted: TP-only, culprit-less evidence
    // (the farewell made the neighbor see Aborted, not RemoteError).
    assert!(
        recoveries(&cluster).is_empty(),
        "a stalled link must convict no one — both ranks are alive: {:?}",
        cluster.controller.actions()
    );
    assert_eq!(
        cluster.live_workers().len(),
        n_workers,
        "every worker (stalled replica included) is still alive"
    );
    fault_registry().release_stalls();
    cluster.shutdown();
}

// ---------------------------------------------------------------------
// Scenario 5: a slow link during scale-out. A static (seeded,
// replayable) delay plan throttles the only replica's forward edge;
// the queue backs up under a burst, the autoscaler scales out, and the
// *fresh* replica — whose edge is not matched by the plan — is
// verified actually serving. Every submitted request resolves to a
// response.
// ---------------------------------------------------------------------
#[test]
fn slow_link_during_scale_out_fresh_replica_verified_serving() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    fault_registry().reset();
    let _dump = EventDump("slow_link_scale_out");
    let delay_before = injected("delay");

    let topo = Topology::pipeline(&uniq("gslow"), &[1], base_port());
    let plan = FaultPlan::new(
        vec![FaultRule::always(
            EdgePattern::new("*-in-s0r0*", Some(0), Some(1)),
            FaultKind::Delay { ms: 25 },
        )],
        seed(),
    );
    let cluster = InProcCluster::start_forward_only(
        topo,
        WorldOptions::shm()
            .with_init_timeout(Duration::from_secs(120))
            .with_fault_plan(plan),
        ScalingPolicy { scale_up_depth: 8.0, max_replicas: 2, recover: true },
        &ServingConfig {
            heartbeat_ms: 100,
            miss_threshold: 5,
            batch_timeout_ms: 3,
            ..ServingConfig::from_env()
        },
        BATCH,
        SEQ_LEN,
        VOCAB,
    )
    .unwrap();
    let edges_before: HashSet<String> =
        cluster.leader.dispatch_counts().keys().cloned().collect();
    cluster.start_autoscaler(AutoscalePolicy {
        stage: 0,
        interval: Duration::from_millis(15),
        cooldown: Duration::from_millis(300),
        high_depth: 8.0,
        slo_p99_ms: 0.0,
        slo_ttft_ms: 0.0,
        high_samples: 1,
        low_samples: 6,
        min_replicas: 1,
        drain_timeout: Duration::from_secs(5),
    });

    let mut gen = RequestGen::new(13, SEQ_LEN, VOCAB, None);
    let mut handles = Vec::new();
    let scaled_out = |c: &InProcCluster| {
        c.controller
            .actions()
            .iter()
            .filter(|a| matches!(a, Action::ScaledOut { .. }))
            .count()
    };
    // Burst until the throttled replica's backlog triggers scale-out.
    let deadline = Instant::now() + Duration::from_secs(30);
    while scaled_out(&cluster) == 0 {
        assert!(
            Instant::now() < deadline,
            "slow link never drove a scale-out; actions: {:?}",
            cluster.controller.actions()
        );
        for r in gen.take(50) {
            handles.push(cluster.leader.submit(r));
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    // The fresh replica demonstrably serves traffic on its own (fast)
    // edge.
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let counts = cluster.leader.dispatch_counts();
        if counts.iter().any(|(e, &c)| !edges_before.contains(e) && c > 0) {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "fresh replica took no traffic: {counts:?}"
        );
        for r in gen.take(50) {
            handles.push(cluster.leader.submit(r));
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(
        injected("delay") - delay_before >= 1,
        "the slow link must demonstrably delay traffic"
    );
    // Zero request loss: every submitted request resolves to a response
    // (no SLO, unbounded admission).
    let grace = Instant::now() + Duration::from_secs(120);
    for h in &handles {
        match h.wait_deadline(grace) {
            Some(Outcome::Response(_)) => {}
            other => panic!("request {} lost: {other:?}", h.id()),
        }
    }
    assert!(recoveries(&cluster).is_empty(), "nothing to recover — the link was slow, not dead");
    cluster.shutdown();
}

// ---------------------------------------------------------------------
// Scenario 6: replayability — the acceptance criterion itself. The same
// `MW_FAULT_SEED` + plan must reproduce the identical injection
// sequence, asserted by comparing the fault-event logs of two runs
// (worlds named differently on purpose: decisions are a function of
// seed, edge ranks and send index — never of names or thread timing).
// ---------------------------------------------------------------------
#[test]
fn same_seed_and_plan_reproduce_identical_injection_sequence() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let _dump = EventDump("determinism");
    let plan = FaultPlan::parse(
        "edge=*:0->1 kind=delay ms=1 prob=0.35; edge=*:0->1 kind=drop prob=0.2 count=4",
        seed(),
    )
    .unwrap();

    let run = |world: &str| -> Vec<(usize, usize, u64, &'static str)> {
        fault_registry().reset();
        let worlds = Rendezvous::single_process(
            world,
            2,
            WorldOptions::tcp()
                .with_init_timeout(Duration::from_secs(120))
                .with_fault_plan(plan.clone()),
        )
        .unwrap();
        let mut it = worlds.into_iter();
        let w0 = it.next().unwrap();
        let keep_peer_alive = it.next().unwrap();
        let t = Tensor::from_f32(&[4], &[1.0, 2.0, 3.0, 4.0]);
        for k in 0..60u64 {
            w0.send(t.clone(), 1, k).unwrap();
        }
        drop(keep_peer_alive);
        fault_registry()
            .take_events()
            .into_iter()
            .map(|e| e.canon())
            .collect()
    };

    let first = run(&uniq("gdet"));
    let second = run(&uniq("gdet"));
    assert!(
        !first.is_empty(),
        "prob 0.35 + 0.2 over 60 sends must inject something"
    );
    assert_eq!(
        first, second,
        "same MW_FAULT_SEED + plan must reproduce the identical injection sequence"
    );
}

// ---------------------------------------------------------------------
// Soak: nightly-style randomized gray-fault rounds (kept out of the
// default run; the CI chaos job runs it fail-soft with a single seed
// and a longer duration via MW_CHAOS_SOAK_MS).
// ---------------------------------------------------------------------
#[test]
#[ignore = "chaos soak — run explicitly (CI nightly-style fail-soft step)"]
fn soak_randomized_gray_faults_never_lose_requests() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    fault_registry().reset();
    let _dump = EventDump("soak");
    let soak_ms: u64 = std::env::var("MW_CHAOS_SOAK_MS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(5_000);

    let topo = Topology::pipeline(&uniq("gsoak"), &[2], base_port());
    let cluster = InProcCluster::start_forward_only(
        topo,
        WorldOptions::tcp()
            .with_init_timeout(Duration::from_secs(120))
            .with_fault_plan(FaultPlan::empty(seed())),
        ScalingPolicy { recover: true, ..Default::default() },
        &ServingConfig { retry_timeout_ms: 300, ..gray_cfg() },
        BATCH,
        SEQ_LEN,
        VOCAB,
    )
    .unwrap();
    let mut rng = multiworld::util::prng::Rng::new(seed());
    let mut gen = RequestGen::new(17, SEQ_LEN, VOCAB, None);
    let t0 = Instant::now();
    let mut round = 0u64;
    while t0.elapsed() < Duration::from_millis(soak_ms) {
        round += 1;
        let replica = rng.below(2);
        let pattern = EdgePattern::new(&format!("*-in-s0r{replica}*"), Some(0), Some(1));
        let rule = match rng.below(3) {
            0 => FaultRule::always(pattern, FaultKind::Delay { ms: 10 }).with_count(20),
            1 => FaultRule::always(pattern, FaultKind::Drop).with_count(2),
            _ => FaultRule::always(pattern, FaultKind::Partition),
        };
        let kind = rule.kind;
        let id = cluster.faults().inject(rule);
        let total = BATCH * 4;
        let report = cluster
            .leader
            .serve(gen.take(total), None, Duration::from_secs(60));
        cluster.faults().heal(id);
        assert_eq!(
            report.completed, total,
            "soak round {round} ({kind:?}) lost requests (retries: {})",
            report.retries
        );
    }
    assert!(round >= 1, "soak must run at least one round");
    cluster.shutdown();
}

/// The dead-shard path still works with the chaos layer wrapped around
/// every link (the injector must be transparent to clean kills): kill a
/// shard mid-traffic under an installed-but-empty plan and require the
/// classic exactly-one-recovery outcome.
#[test]
fn clean_kill_still_recovers_exactly_once_under_wrapped_links() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    fault_registry().reset();
    let _dump = EventDump("clean_kill_wrapped");

    let topo = Topology::pipeline_tp(&uniq("gkill"), &[1, 2], &[1, 2], base_port());
    let cluster = InProcCluster::start_forward_only(
        topo,
        WorldOptions::tcp()
            .with_init_timeout(Duration::from_secs(120))
            .with_fault_plan(FaultPlan::empty(seed())),
        ScalingPolicy { recover: true, ..Default::default() },
        &gray_cfg(),
        BATCH,
        SEQ_LEN,
        VOCAB,
    )
    .unwrap();
    let victim = NodeId::Worker { stage: 1, replica: 1, shard: 1 };

    let total = BATCH * 8;
    let mut gen = RequestGen::new(19, SEQ_LEN, VOCAB, None);
    let requests = gen.take(total);
    let cluster_ref = &cluster;
    let report = std::thread::scope(|s| {
        let killer = s.spawn(move || {
            std::thread::sleep(Duration::from_millis(150));
            assert!(cluster_ref.kill(victim), "victim shard must be alive to kill");
        });
        let report = cluster_ref
            .leader
            .serve(requests, Some(300.0), Duration::from_secs(90));
        killer.join().unwrap();
        report
    });
    assert_eq!(report.completed, total, "no request loss (retries: {})", report.retries);

    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let rec = recoveries(&cluster);
        if !rec.is_empty() {
            assert_eq!(
                rec,
                vec![Action::Recovered { dead: victim, replacement: victim }],
                "exactly one recovery, of the dead shard itself"
            );
            break;
        }
        assert!(
            Instant::now() < deadline,
            "controller never recovered the shard under wrapped links"
        );
        std::thread::sleep(Duration::from_millis(50));
    }
    cluster.shutdown();
}
