//! Spare-pool edge cases (`MW_SPARES`): the pre-warmed standby workers
//! that turn respawn-from-scratch recovery into near-zero-MTTR
//! promotion. Forward-only clusters — no PJRT, no artifacts — so the
//! whole suite runs in the default CI build.
//!
//! Covered: an idle spare dying is a non-event for the serving plane
//! (reap + backfill, no replica touched); two near-simultaneous kills
//! racing for the pool get exactly one spare per pop (promotions and
//! cold respawns together recover both, zero request loss); promotion
//! landing in the middle of an autoscale scale-out never double-spawns
//! an identity; and `MW_SPARES=0` leaves the original recovery path —
//! counters included — untouched.

use multiworld::config::ServingConfig;
use multiworld::launch::InProcCluster;
use multiworld::mwccl::WorldOptions;
use multiworld::serving::autoscaler::AutoscalePolicy;
use multiworld::serving::controller::{Action, ScalingPolicy};
use multiworld::serving::topology::{NodeId, Topology};
use multiworld::serving::{Outcome, RequestGen};
use std::collections::HashSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Serialize cluster tests (they spawn many threads and fixed-range
/// store ports, and assert on process-global metric deltas).
static SERIAL: Mutex<()> = Mutex::new(());

const BATCH: usize = 4;
const SEQ_LEN: usize = 8;
const VOCAB: usize = 32;

fn uniq(prefix: &str) -> String {
    static N: AtomicU64 = AtomicU64::new(0);
    format!(
        "{prefix}{}-{}",
        std::process::id() % 1000,
        N.fetch_add(1, Ordering::Relaxed)
    )
}

fn base_port() -> u16 {
    static NEXT: AtomicU64 = AtomicU64::new(0);
    43_000 + (NEXT.fetch_add(1, Ordering::Relaxed) as u16 % 20) * 120
        + (std::process::id() % 97) as u16
}

fn counter(name: &str) -> u64 {
    multiworld::metrics::global().counter(name).get()
}

fn cfg(spares: usize) -> ServingConfig {
    ServingConfig {
        heartbeat_ms: 50,
        miss_threshold: 3,
        batch_timeout_ms: 3,
        retry_timeout_ms: 300,
        spares,
        ..Default::default()
    }
}

fn start(topo: Topology, opts: WorldOptions, spares: usize) -> InProcCluster {
    InProcCluster::start_forward_only(
        topo,
        opts.with_init_timeout(Duration::from_secs(120)),
        ScalingPolicy { scale_up_depth: 8.0, max_replicas: 4, recover: true },
        &cfg(spares),
        BATCH,
        SEQ_LEN,
        VOCAB,
    )
    .unwrap()
}

fn recovered_count(cluster: &InProcCluster) -> usize {
    cluster
        .controller
        .actions()
        .iter()
        .filter(|a| matches!(a, Action::Recovered { .. }))
        .count()
}

fn wait_for_spares(cluster: &InProcCluster, n: usize) {
    let deadline = Instant::now() + Duration::from_secs(30);
    while cluster.spare_count() < n {
        assert!(
            Instant::now() < deadline,
            "pool never reached {n} spares (at {})",
            cluster.spare_count()
        );
        std::thread::sleep(Duration::from_millis(5));
    }
}

#[test]
fn idle_spare_death_backfills_without_touching_replicas() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let backfilled_before = counter("serving.spares.backfilled");
    let topo = Topology::pipeline(&uniq("spidle"), &[2], base_port());
    let cluster = start(topo, WorldOptions::shm(), 2);
    wait_for_spares(&cluster, 2);
    let live_before = cluster.live_workers();
    let actions_before = cluster.controller.actions().len();

    assert!(cluster.kill_spare(), "a pooled spare must be killable");
    // The keeper reaps the corpse and backfills to the target.
    wait_for_spares(&cluster, 2);
    assert!(
        counter("serving.spares.backfilled") > backfilled_before,
        "backfill must be counted"
    );

    // A spare dying idle is a non-event for the serving plane: no
    // replica touched, no recovery, no scaling.
    assert_eq!(cluster.live_workers(), live_before, "no replica touched");
    assert_eq!(
        cluster.controller.actions().len(),
        actions_before,
        "no controller action from an idle spare death: {:?}",
        cluster.controller.actions()
    );
    cluster.shutdown();
}

#[test]
fn simultaneous_kills_race_the_pool_with_zero_request_loss() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let promoted_before = counter("serving.spares.promoted");
    let topo = Topology::pipeline(&uniq("sprace"), &[3], base_port());
    // TCP: failures are detectable without waiting out the watchdog.
    let cluster = start(topo, WorldOptions::tcp(), 1);
    wait_for_spares(&cluster, 1);

    let mut gen = RequestGen::new(0x5BA2E, SEQ_LEN, VOCAB, None);
    let mut handles = Vec::new();
    for r in gen.take(100) {
        handles.push(cluster.leader.submit(r));
    }
    // Two kills back to back: both verdicts race for the single pooled
    // spare. The pop is atomic, so one recovery promotes it and the
    // other takes a cold respawn (or a keeper backfill — either way no
    // spare is ever handed out twice).
    assert!(cluster.kill(NodeId::worker(0, 1)));
    assert!(cluster.kill(NodeId::worker(0, 2)));

    let deadline = Instant::now() + Duration::from_secs(60);
    while recovered_count(&cluster) < 2 {
        assert!(
            Instant::now() < deadline,
            "wanted 2 recoveries, got: {:?}",
            cluster.controller.actions()
        );
        for r in gen.take(20) {
            handles.push(cluster.leader.submit(r));
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    let promoted = counter("serving.spares.promoted") - promoted_before;
    assert!(promoted >= 1, "the pooled spare must win one of the recoveries");

    // Each recovery minted a distinct replacement identity.
    let replacements: Vec<NodeId> = cluster
        .controller
        .actions()
        .iter()
        .filter_map(|a| match a {
            Action::Recovered { replacement, .. } => Some(*replacement),
            _ => None,
        })
        .collect();
    let distinct: HashSet<NodeId> = replacements.iter().copied().collect();
    assert_eq!(distinct.len(), replacements.len(), "no identity spawned twice");

    // Zero request loss through the double kill.
    for h in &handles {
        match h.wait_deadline(Instant::now() + Duration::from_secs(90)) {
            Some(Outcome::Response(_)) => {}
            other => panic!("request {} lost: {other:?}", h.id()),
        }
    }
    cluster.shutdown();
}

#[test]
fn promotion_during_inflight_scale_out_never_double_spawns() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let topo = Topology::pipeline(&uniq("spscale"), &[2], base_port());
    let cluster = start(topo, WorldOptions::tcp(), 1);
    wait_for_spares(&cluster, 1);
    // Aggressive scale-out trigger (one caught deep sample), no
    // scale-in: the recovery below lands while scale-outs are in flight.
    cluster.start_autoscaler(AutoscalePolicy {
        stage: 0,
        interval: Duration::from_millis(15),
        cooldown: Duration::from_millis(300),
        high_depth: 8.0,
        slo_p99_ms: 0.0,
        slo_ttft_ms: 0.0,
        high_samples: 1,
        low_samples: 100_000,
        min_replicas: 1,
        drain_timeout: Duration::from_secs(5),
    });

    let victim = NodeId::worker(0, 1);
    let mut gen = RequestGen::new(0xD0_5E, SEQ_LEN, VOCAB, None);
    let mut handles = Vec::new();
    for r in gen.take(200) {
        handles.push(cluster.leader.submit(r));
    }
    assert!(cluster.kill(victim), "victim must be alive to kill");
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        let actions = cluster.controller.actions();
        let recovered = actions
            .iter()
            .any(|a| matches!(a, Action::Recovered { dead, .. } if *dead == victim));
        let scaled = actions.iter().any(|a| matches!(a, Action::ScaledOut { .. }));
        if recovered && scaled {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "wanted Recovered({victim}) + ScaledOut, got: {actions:?}"
        );
        for r in gen.take(50) {
            handles.push(cluster.leader.submit(r));
        }
        std::thread::sleep(Duration::from_millis(5));
    }

    // The no-double-spawn invariant: every identity the controller ever
    // brought up — recovery replacements and scale-outs alike — is
    // distinct, whether it came from the pool or a cold thread.
    let spawned: Vec<NodeId> = cluster
        .controller
        .actions()
        .iter()
        .filter_map(|a| match a {
            Action::Recovered { replacement, .. } => Some(*replacement),
            Action::ScaledOut { node, .. } => Some(*node),
            _ => None,
        })
        .collect();
    let distinct: HashSet<NodeId> = spawned.iter().copied().collect();
    assert_eq!(distinct.len(), spawned.len(), "identity spawned twice: {spawned:?}");

    for h in &handles {
        match h.wait_deadline(Instant::now() + Duration::from_secs(90)) {
            Some(Outcome::Response(_)) => {}
            other => panic!("request {} lost: {other:?}", h.id()),
        }
    }
    cluster.shutdown();
}

#[test]
fn spares_zero_keeps_the_original_recovery_path() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let promoted_before = counter("serving.spares.promoted");
    let backfilled_before = counter("serving.spares.backfilled");
    let cache_before =
        counter("serving.weight_cache.hits") + counter("serving.weight_cache.misses");
    let topo = Topology::pipeline(&uniq("spzero"), &[2], base_port());
    let cluster = start(topo, WorldOptions::tcp(), 0);
    assert_eq!(cluster.spare_count(), 0, "MW_SPARES=0 keeps no pool");

    let victim = NodeId::worker(0, 1);
    let mut gen = RequestGen::new(0x2E20, SEQ_LEN, VOCAB, None);
    let mut handles = Vec::new();
    for r in gen.take(100) {
        handles.push(cluster.leader.submit(r));
    }
    assert!(cluster.kill(victim));
    let deadline = Instant::now() + Duration::from_secs(60);
    while recovered_count(&cluster) < 1 {
        assert!(
            Instant::now() < deadline,
            "recovery must still work with no pool: {:?}",
            cluster.controller.actions()
        );
        for r in gen.take(20) {
            handles.push(cluster.leader.submit(r));
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    for h in &handles {
        match h.wait_deadline(Instant::now() + Duration::from_secs(90)) {
            Some(Outcome::Response(_)) => {}
            other => panic!("request {} lost: {other:?}", h.id()),
        }
    }

    // Byte-identical to the pre-spares world: a forward-only manifest
    // carries no weights (`params: 0`), so the cold respawn touches
    // neither the pool nor the weight cache.
    assert_eq!(cluster.spare_count(), 0);
    assert_eq!(counter("serving.spares.promoted"), promoted_before);
    assert_eq!(counter("serving.spares.backfilled"), backfilled_before);
    assert_eq!(
        counter("serving.weight_cache.hits") + counter("serving.weight_cache.misses"),
        cache_before,
        "spares=0 + zero-param stages must never touch the weight cache"
    );
    cluster.shutdown();
}
