//! End-to-end serving integration: a full in-process pipeline (leader +
//! stage workers over real transports + PJRT stage execution), the
//! Fig. 2 fault-tolerance story, and controller-driven recovery.
//!
//! Requires `make artifacts`; tests skip politely otherwise.

use multiworld::config::ServingConfig;
use multiworld::launch::InProcCluster;
use multiworld::mwccl::WorldOptions;
use multiworld::runtime::artifacts_dir;
use multiworld::serving::controller::ScalingPolicy;
use multiworld::serving::topology::{NodeId, Topology};
use multiworld::serving::RequestGen;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Cluster tests compile several PJRT executables each on a small CI
/// box; run them one at a time and give rendezvous generous room.
static SERIAL: Mutex<()> = Mutex::new(());

fn opts_shm() -> WorldOptions {
    WorldOptions::shm().with_init_timeout(Duration::from_secs(180))
}

fn opts_tcp() -> WorldOptions {
    WorldOptions::tcp().with_init_timeout(Duration::from_secs(180))
}

fn have_artifacts() -> bool {
    if cfg!(not(all(feature = "pjrt", feature = "xla-backend"))) {
        eprintln!("SKIP: PJRT engine stubbed (needs --features pjrt,xla-backend)");
        return false;
    }
    let ok = artifacts_dir().join("model.json").exists();
    if !ok {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
    }
    ok
}

fn uniq(prefix: &str) -> String {
    static N: AtomicU64 = AtomicU64::new(0);
    format!(
        "{prefix}{}-{}",
        std::process::id() % 1000,
        N.fetch_add(1, Ordering::Relaxed)
    )
}

fn fast_cfg() -> ServingConfig {
    ServingConfig {
        heartbeat_ms: 50,
        miss_threshold: 3,
        batch_timeout_ms: 3,
        ..Default::default()
    }
}

fn base_port() -> u16 {
    // Spread port ranges between tests to avoid collisions.
    static NEXT: AtomicU64 = AtomicU64::new(0);
    34_000 + (NEXT.fetch_add(1, Ordering::Relaxed) as u16 % 200) * 120
        + (std::process::id() % 97) as u16
}

#[test]
fn straight_pipeline_serves_requests() {
    if !have_artifacts() {
        return;
    }
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let topo = Topology::pipeline(&uniq("sp"), &[1, 1, 1], base_port());
    let cluster = InProcCluster::start(
        topo,
        artifacts_dir(),
        opts_shm(),
        ScalingPolicy { recover: false, ..Default::default() },
        &fast_cfg(),
    )
    .unwrap();
    let m = &cluster.manifest;
    let mut gen = RequestGen::new(7, m.seq_len, m.vocab, None);
    let requests = gen.take(m.batch * 4);
    let report = cluster
        .leader
        .serve(requests, None, Duration::from_secs(60));
    assert_eq!(report.completed, m.batch * 4, "all requests answered");
    assert!(report.p50_ms > 0.0);
    // Tokens are model argmax outputs — check they're in-vocab.
    for r in cluster.leader.responses() {
        assert!((0..m.vocab as i32).contains(&r.next_token));
    }
    cluster.shutdown();
}

#[test]
fn rhombus_pipeline_balances_replicas() {
    if !have_artifacts() {
        return;
    }
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    // The paper's 1-2-1 rhombus: middle stage replicated.
    let topo = Topology::pipeline(&uniq("rh"), &[1, 2, 1], base_port());
    let cluster = InProcCluster::start(
        topo,
        artifacts_dir(),
        opts_shm(),
        ScalingPolicy { recover: false, ..Default::default() },
        &fast_cfg(),
    )
    .unwrap();
    let m = &cluster.manifest;
    let mut gen = RequestGen::new(8, m.seq_len, m.vocab, None);
    let report = cluster
        .leader
        .serve(gen.take(m.batch * 6), None, Duration::from_secs(60));
    assert_eq!(report.completed, m.batch * 6);
    cluster.shutdown();
}

#[test]
fn replica_death_degrades_but_does_not_stop_service() {
    if !have_artifacts() {
        return;
    }
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let topo = Topology::pipeline(&uniq("ft"), &[1, 2, 1], base_port());
    let cluster = InProcCluster::start(
        topo,
        artifacts_dir(),
        opts_tcp(), // detectable failures: no watchdog wait
        ScalingPolicy { recover: false, ..Default::default() },
        &fast_cfg(),
    )
    .unwrap();
    let m = &cluster.manifest;
    let total = m.batch * 8;
    let mut gen = RequestGen::new(9, m.seq_len, m.vocab, None);
    let requests = gen.take(total);

    // Kill P3 (middle replica 1) shortly after serving starts.
    let cluster_ref = &cluster;
    let killer = std::thread::scope(|s| {
        let h = s.spawn(move || {
            std::thread::sleep(Duration::from_millis(150));
            assert!(cluster_ref.kill(NodeId::worker(1, 1)));
        });
        let report = cluster_ref
            .leader
            .serve(requests, Some(400.0), Duration::from_secs(90));
        h.join().unwrap();
        report
    });
    assert_eq!(
        killer.completed, total,
        "all requests must complete despite the replica death (retries: {})",
        killer.retries
    );
    assert_eq!(cluster.live_workers().len(), 3);
    cluster.shutdown();
}

#[test]
fn controller_recovers_dead_replica() {
    if !have_artifacts() {
        return;
    }
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let topo = Topology::pipeline(&uniq("rc"), &[1, 2, 1], base_port());
    let cluster = InProcCluster::start(
        topo,
        artifacts_dir(),
        opts_tcp(),
        ScalingPolicy { recover: true, ..Default::default() },
        &fast_cfg(),
    )
    .unwrap();
    let dead = NodeId::worker(1, 1);
    assert!(cluster.kill(dead));
    // The workers' event forwarders report the broken edges; the
    // controller declares the node dead and spawns a replacement.
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    loop {
        let actions = cluster.controller.actions();
        if actions.iter().any(|a| {
            matches!(a, multiworld::serving::controller::Action::Recovered { dead: d, .. } if *d == dead)
        }) {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "controller never recovered; actions: {actions:?}"
        );
        std::thread::sleep(Duration::from_millis(50));
    }
    // The replacement joins the cluster's live workers.
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    while !cluster
        .live_workers()
        .contains(&NodeId::worker(1, 2))
    {
        assert!(std::time::Instant::now() < deadline);
        std::thread::sleep(Duration::from_millis(50));
    }
    // And serving works end to end afterwards.
    let m = &cluster.manifest;
    let mut gen = RequestGen::new(10, m.seq_len, m.vocab, None);
    let report = cluster
        .leader
        .serve(gen.take(m.batch * 2), None, Duration::from_secs(60));
    assert_eq!(report.completed, m.batch * 2);
    cluster.shutdown();
}

#[test]
fn scale_out_adds_replica_live() {
    if !have_artifacts() {
        return;
    }
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let topo = Topology::pipeline(&uniq("so"), &[1, 1, 1], base_port());
    let cluster = InProcCluster::start(
        topo,
        artifacts_dir(),
        opts_shm(),
        ScalingPolicy { recover: false, max_replicas: 2, scale_up_depth: 1.0 },
        &fast_cfg(),
    )
    .unwrap();
    // Manually trigger scale-out of the middle stage (as the policy
    // loop would under queue pressure).
    let action = cluster.controller.maybe_scale_out(1, 100.0).unwrap().unwrap();
    assert!(matches!(
        action,
        multiworld::serving::controller::Action::ScaledOut { stage: 1, .. }
    ));
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    while cluster.live_workers().len() < 4 {
        assert!(std::time::Instant::now() < deadline);
        std::thread::sleep(Duration::from_millis(50));
    }
    // Serve through the grown pipeline.
    let m = &cluster.manifest;
    let mut gen = RequestGen::new(11, m.seq_len, m.vocab, None);
    let report = cluster
        .leader
        .serve(gen.take(m.batch * 4), None, Duration::from_secs(60));
    assert_eq!(report.completed, m.batch * 4);
    cluster.shutdown();
}
