//! Hierarchical (topology-aware) collectives: checksum equivalence with
//! the flat and ring algorithms across `MW_HOSTMAP` layouts and both
//! transports, `Auto`'s host-count gate, and the prologue-skip
//! invariant (negotiation rounds only happen when a non-flat algorithm
//! is actually selectable).
//!
//! Reduction test data is integer-valued f32, so sums are exact and
//! order-independent — any fold order (flat rank-order, ring
//! neighbour-order, hier host-then-leader order) must produce identical
//! checksums.

use multiworld::config::{CollAlgo, CollOp};
use multiworld::mwccl::{Rendezvous, ReduceOp, WorldOptions};
use multiworld::tensor::Tensor;
use std::time::Duration;

fn uniq(name: &str) -> String {
    use std::sync::atomic::{AtomicU64, Ordering};
    static N: AtomicU64 = AtomicU64::new(0);
    format!(
        "ch-{name}-{}-{}",
        std::process::id(),
        N.fetch_add(1, Ordering::Relaxed)
    )
}

/// World options for one (transport, algorithm, placement) cell. An
/// empty layout leaves the world single-host (the historical default).
fn opts(transport: &str, algo: CollAlgo, layout: &str) -> WorldOptions {
    let base = match transport {
        "shm" => WorldOptions::shm(),
        "tcp" => WorldOptions::tcp(),
        other => panic!("unknown transport {other}"),
    };
    let base = base
        .with_coll_algo(algo)
        .with_op_timeout(Duration::from_secs(60));
    if layout.is_empty() {
        base
    } else {
        base.with_hostmap(layout)
    }
}

fn int_tensor(elems: usize, rank: usize) -> Tensor {
    let vals: Vec<f32> = (0..elems)
        .map(|i| ((i as u64 * 31 + rank as u64 * 7 + 3) % 101) as f32)
        .collect();
    Tensor::from_f32(&[elems], &vals)
}

fn expected_sum(elems: usize, size: usize) -> Tensor {
    let mut acc = vec![0.0f32; elems];
    for r in 0..size {
        for (a, b) in acc.iter_mut().zip(int_tensor(elems, r).as_f32()) {
            *a += *b;
        }
    }
    Tensor::from_f32(&[elems], &acc)
}

/// The placement grid the equivalence tests sweep: single host (forced
/// `Hier` must degrade), symmetric blocks both ways, and an asymmetric
/// layout with a single-rank host.
const LAYOUTS: [(&str, usize); 4] = [("", 8), ("2x4", 8), ("4x2", 8), ("0,0,0,1", 4)];

#[test]
fn hier_matches_flat_and_ring_for_all_four_ops_across_layouts() {
    for transport in ["shm", "tcp"] {
        for (layout, size) in LAYOUTS {
            // Non-leader root on a non-zero host (layout "2x4" puts rank
            // 5 on host 1; "0,0,0,1" puts rank 1 mid-host-0) — exercises
            // the hier origin-relay paths, not just the easy leader-root
            // case.
            let root = if size == 8 { 5 } else { 1 };
            let ar_want = expected_sum(100_000, size).checksum();
            let rd_want = expected_sum(60_000, size).checksum();
            let bc_src = int_tensor(75_000, 42); // 300 KB, multi-chunk
            let bc_want = bc_src.checksum();
            let mut ag_per_algo = Vec::new();
            for algo in [CollAlgo::Flat, CollAlgo::Ring, CollAlgo::Hier] {
                let worlds =
                    Rendezvous::single_process(&uniq("hq"), size, opts(transport, algo, layout))
                        .unwrap();
                let handles: Vec<_> = worlds
                    .into_iter()
                    .map(|w| {
                        let src = bc_src.clone();
                        std::thread::spawn(move || {
                            let ar = w
                                .all_reduce(int_tensor(100_000, w.rank()), ReduceOp::Sum)
                                .unwrap()
                                .checksum();
                            let picked =
                                w.last_algo(CollOp::AllReduce).unwrap_or("?").to_string();
                            let bt = (w.rank() == root).then(|| src);
                            let bc = w.broadcast(bt, root).unwrap().checksum();
                            let rd = w
                                .reduce(int_tensor(60_000, w.rank()), root, ReduceOp::Sum)
                                .unwrap();
                            let rd = match rd {
                                Some(t) => {
                                    assert_eq!(w.rank(), root, "only the root gets the reduction");
                                    Some(t.checksum())
                                }
                                None => None,
                            };
                            let rows = w.rank() + 1; // unequal parts, width 3
                            let vals: Vec<f32> = (0..rows * 3)
                                .map(|i| (w.rank() * 100 + i) as f32)
                                .collect();
                            let ag = w.all_gather(Tensor::from_f32(&[rows, 3], &vals)).unwrap();
                            let total_rows: usize = (1..=w.size()).sum();
                            assert_eq!(ag.shape(), &[total_rows, 3]);
                            (w.rank(), ar, picked, bc, rd, ag.checksum())
                        })
                    })
                    .collect();
                let mut ag_cs = None;
                for h in handles {
                    let (rank, ar, picked, bc, rd, ag) = h.join().unwrap();
                    let ctx = format!("{transport} layout={layout:?} {algo:?} rank={rank}");
                    assert_eq!(ar, ar_want, "{ctx}: all_reduce");
                    assert_eq!(bc, bc_want, "{ctx}: broadcast");
                    if rank == root {
                        assert_eq!(rd, Some(rd_want), "{ctx}: reduce");
                    }
                    if let Some(prev) = ag_cs {
                        assert_eq!(ag, prev, "{ctx}: ranks disagree on all_gather");
                    }
                    ag_cs = Some(ag);
                    if algo == CollAlgo::Hier {
                        // Forced hier runs hierarchically whenever more
                        // than one host exists, and degrades to the ring
                        // on a single host — never silently to flat.
                        let want = if layout.is_empty() { "ring" } else { "hier" };
                        assert_eq!(picked, want, "{ctx}: forced-hier selection");
                    }
                }
                ag_per_algo.push(ag_cs.unwrap());
            }
            assert_eq!(ag_per_algo[0], ag_per_algo[1], "flat vs ring all_gather");
            assert_eq!(ag_per_algo[0], ag_per_algo[2], "flat vs hier all_gather");
        }
    }
}

#[test]
fn hier_all_reduce_avg_scales_exactly_once() {
    // Avg rides the hier fan-in/ring/fan-out as a Sum and is scaled by
    // the world size exactly once; size 8 keeps integer sums exact
    // under the 1/8 scale.
    let size = 8;
    let elems = 20_000;
    let worlds = Rendezvous::single_process(
        &uniq("havg"),
        size,
        opts("shm", CollAlgo::Hier, "2x4"),
    )
    .unwrap();
    let handles: Vec<_> = worlds
        .into_iter()
        .map(|w| {
            let t = int_tensor(elems, w.rank());
            std::thread::spawn(move || w.all_reduce(t, ReduceOp::Avg).unwrap())
        })
        .collect();
    let mut expect = expected_sum(elems, size).as_f32().to_vec();
    for a in expect.iter_mut() {
        *a /= size as f32;
    }
    for h in handles {
        assert_eq!(h.join().unwrap().as_f32(), expect.as_slice());
    }
}

#[test]
fn forced_hier_gather_scatter_degrade_is_counted_once() {
    // gather/scatter have no hierarchical variant (`CollOp::has_hier`:
    // per-rank-distinct payloads), so a forced-Hier multi-host world
    // silently runs them on the ring. That degrade must be observable:
    // the first such op per world bumps `coll.hier_degraded` (and logs
    // a `coll.hier_degraded` event) — once per world, however many
    // degraded ops follow.
    //
    // The counter is process-global and other tests in this binary also
    // create forced-hier worlds (each fires at most once thanks to the
    // latch), so the assertions are inequalities: the first degrading
    // op adds at least this world's bump, and a burst of N follow-ups
    // adds far fewer than N (N·ranks if the latch ever regressed).
    let degraded = || multiworld::metrics::global().counter("coll.hier_degraded").get();
    let worlds =
        Rendezvous::single_process(&uniq("hdeg"), 4, opts("shm", CollAlgo::Hier, "2x2"))
            .unwrap();
    let c0 = degraded();
    let worlds: Vec<_> = worlds
        .into_iter()
        .map(|w| {
            std::thread::spawn(move || {
                let g = w.gather(int_tensor(64, w.rank()), 0).unwrap();
                assert_eq!(g.is_some(), w.rank() == 0);
                assert_eq!(
                    w.last_algo(CollOp::Gather),
                    Some("ring"),
                    "forced hier degrades gather to the ring, never silently to flat"
                );
                w
            })
        })
        .collect::<Vec<_>>()
        .into_iter()
        .map(|h| h.join().unwrap())
        .collect();
    let c1 = degraded();
    assert!(c1 > c0, "the first degraded op must bump coll.hier_degraded");

    const BURST: u64 = 10;
    let worlds: Vec<_> = worlds
        .into_iter()
        .map(|w| {
            std::thread::spawn(move || {
                for _ in 0..BURST {
                    let parts = (w.rank() == 0).then(|| {
                        (0..w.size()).map(|i| int_tensor(32, i)).collect::<Vec<_>>()
                    });
                    w.scatter(parts, 0).unwrap();
                }
                assert_eq!(w.last_algo(CollOp::Scatter), Some("ring"));
                w
            })
        })
        .collect::<Vec<_>>()
        .into_iter()
        .map(|h| h.join().unwrap())
        .collect();
    let c2 = degraded();
    assert!(
        c2 - c1 < BURST,
        "per-world latch must keep the counter one-shot ({} bumps over {BURST} ops)",
        c2 - c1
    );

    // Positive control: ops *with* a hierarchical variant still run
    // hier on this very world — the degrade is per-op capability, not a
    // whole-policy downgrade.
    let handles: Vec<_> = worlds
        .into_iter()
        .map(|w| {
            std::thread::spawn(move || {
                w.all_reduce(int_tensor(1024, w.rank()), ReduceOp::Sum).unwrap();
                assert_eq!(w.last_algo(CollOp::AllReduce), Some("hier"));
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
}

#[test]
fn auto_picks_hier_only_when_hosts_exceed_one() {
    // The same 1 MiB all_reduce that rings on a single host must go
    // hierarchical once the world spans hosts — and sub-threshold
    // payloads stay flat either way.
    for (layout, want_big) in [("", "ring"), ("2x4", "hier")] {
        let size = 8;
        let worlds = Rendezvous::single_process(
            &uniq("hauto"),
            size,
            opts("shm", CollAlgo::Auto, layout),
        )
        .unwrap();
        let handles: Vec<_> = worlds
            .into_iter()
            .map(|w| {
                std::thread::spawn(move || {
                    w.all_reduce(int_tensor(256, w.rank()), ReduceOp::Sum).unwrap();
                    let small_pick = w.last_algo(CollOp::AllReduce).unwrap();
                    // 1 MiB == RING_MIN_BYTES: clears the byte gate.
                    w.all_reduce(int_tensor(1 << 18, w.rank()), ReduceOp::Sum).unwrap();
                    let big_pick = w.last_algo(CollOp::AllReduce).unwrap();
                    (small_pick, big_pick)
                })
            })
            .collect();
        for h in handles {
            let (small_pick, big_pick) = h.join().unwrap();
            assert_eq!(small_pick, "flat", "layout={layout:?}: small payloads stay flat");
            assert_eq!(big_pick, want_big, "layout={layout:?}: 1 MiB all_reduce");
        }
    }
}

#[test]
fn auto_skips_prologue_when_only_flat_is_selectable() {
    // Regression: root-sized ops below the ring's minimum world (and in
    // any world where neither ring nor hier could be picked) must not
    // pay the negotiation prologue — `Auto` resolves to flat up front.
    // Other tests in this binary never negotiate (forced algorithms and
    // locally-sized ops decide without a prologue), so the process-wide
    // counter deltas are attributable to these worlds alone.
    let prologues = || multiworld::metrics::global().counter("coll_prologue_rounds").get();
    let c0 = prologues();
    let worlds = Rendezvous::single_process(&uniq("plg2"), 2, opts("tcp", CollAlgo::Auto, ""))
        .unwrap();
    let handles: Vec<_> = worlds
        .into_iter()
        .map(|w| {
            std::thread::spawn(move || {
                // gather/scatter/broadcast are root-sized: without the
                // skip they would each negotiate even though a 2-rank
                // world can only ever run flat.
                let g = w.gather(int_tensor(64, w.rank()), 0).unwrap();
                assert_eq!(g.is_some(), w.rank() == 0);
                let parts = (w.rank() == 1).then(|| {
                    (0..2).map(|i| int_tensor(32, i)).collect::<Vec<_>>()
                });
                w.scatter(parts, 1).unwrap();
                let bt = (w.rank() == 0).then(|| int_tensor(128, 9));
                w.broadcast(bt, 0).unwrap();
                assert_eq!(w.last_algo(CollOp::Broadcast), Some("flat"));
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let c1 = prologues();
    assert_eq!(c1, c0, "flat-only worlds must not pay negotiation rounds");

    // Positive control: a ring-eligible world's root-sized op does
    // negotiate, so the counter is live and the zero delta above is
    // meaningful.
    let worlds = Rendezvous::single_process(&uniq("plg4"), 4, opts("tcp", CollAlgo::Auto, ""))
        .unwrap();
    let handles: Vec<_> = worlds
        .into_iter()
        .map(|w| {
            std::thread::spawn(move || {
                let bt = (w.rank() == 0).then(|| int_tensor(128, 9));
                w.broadcast(bt, 0).unwrap();
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    assert!(prologues() > c1, "ring-eligible negotiation must round-trip the prologue");
}
