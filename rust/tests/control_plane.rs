//! Control-plane regression tests: world minting must stay O(1) store
//! round trips per member (the batched-rendezvous property), ranks of
//! one world must share a single pooled store connection, and watchdog
//! verdicts must survive fault injection on the store channel itself.
//!
//! Every test serializes on `fault::TEST_SERIAL`: the first two read
//! process-global `store.client.*` counters and the last mutates the
//! process-global fault registry, so they cannot overlap with each
//! other (other test binaries are separate processes and don't
//! interfere).

use multiworld::multiworld::{Watchdog, WatchdogConfig};
use multiworld::mwccl::transport::fault::{self, STORE_EDGE};
use multiworld::mwccl::{fault_registry, EdgePattern, FaultKind, FaultRule};
use multiworld::mwccl::{Rendezvous, WorldOptions};
use multiworld::store::{StoreClient, StoreServer};
use multiworld::util::time::Clock;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

fn uniq(name: &str) -> String {
    static N: AtomicU64 = AtomicU64::new(0);
    format!("{name}-{}-{}", std::process::id(), N.fetch_add(1, Ordering::Relaxed))
}

fn store_ops() -> u64 {
    multiworld::metrics::global().counter("store.client.ops").get()
}

/// Mint one tcp world of `size` and return the store ops it cost.
fn ops_to_mint(size: usize) -> u64 {
    let before = store_ops();
    let worlds =
        Rendezvous::single_process(&uniq("cp-o1"), size, WorldOptions::tcp()).unwrap();
    let delta = store_ops() - before;
    drop(worlds);
    delta
}

#[test]
fn world_minting_round_trips_are_constant_in_member_count() {
    let _serial = fault::TEST_SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    // Publish(SET) + collect(WAIT_MANY) + barrier add + barrier wait is
    // 4 ops per member, plus one go-key SET for the whole world. The
    // pre-batching protocol waited on each peer's address individually,
    // which made per-member cost grow linearly with world size — that
    // regression is what this test pins.
    let per_member_4 = ops_to_mint(4) as f64 / 4.0;
    let per_member_8 = ops_to_mint(8) as f64 / 8.0;
    assert!(
        (per_member_8 - per_member_4).abs() <= 1.0,
        "per-member store ops must not grow with world size \
         (size 4: {per_member_4:.2}, size 8: {per_member_8:.2})"
    );
    assert!(
        per_member_8 <= 6.0,
        "minting a rank should take ~4 store ops, got {per_member_8:.2}"
    );
}

#[test]
fn ranks_of_one_world_share_a_pooled_store_connection() {
    let _serial = fault::TEST_SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let conns = multiworld::metrics::global().counter("store.client.conns_opened");
    let before = conns.get();
    let worlds =
        Rendezvous::single_process(&uniq("cp-pool"), 4, WorldOptions::tcp()).unwrap();
    assert_eq!(
        conns.get() - before,
        1,
        "all four ranks talk to one store — the pool must open exactly one socket"
    );
    drop(worlds);
}

/// The FaultLink gap the store pseudo-edge closes: injecting delay and
/// drop on the watchdog's own channel must not corrupt the verdict —
/// the silent peer is still convicted, with the right rank attributed.
#[test]
fn watchdog_verdict_survives_store_delay_and_drop() {
    let _serial = fault::TEST_SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    fault_registry().reset();

    let server = StoreServer::bind_any().unwrap();
    let store = Arc::new(StoreClient::connect(server.addr(), Duration::from_secs(2)).unwrap());
    let broken: Arc<Mutex<Vec<(String, Option<usize>)>>> = Arc::new(Mutex::new(Vec::new()));
    let b2 = broken.clone();
    let clock = Clock::manual();
    let wd = Watchdog::start(
        // Effectively-infinite daemon period: the test drives ticks.
        WatchdogConfig { heartbeat: Duration::from_millis(3_600_000), miss_threshold: 3 },
        clock.clone(),
        Arc::new(move |w: &str, _r: &str, c: Option<usize>| {
            b2.lock().unwrap().push((w.to_string(), c))
        }),
    );
    let world = uniq("cp-chaos");
    wd.watch(&world, 0, 2, store.clone());
    store
        .set(&format!("mw/{world}/hb/1"), clock.now_millis().to_string().as_bytes())
        .unwrap();
    wd.tick(); // fresh stamp — healthy
    assert!(broken.lock().unwrap().is_empty());

    // Degrade the store channel: the next request is "lost" (drop on a
    // reliable control channel means an RTO pause + retransmit, not
    // silent data loss — the watchdog must not misread injected loss as
    // a dead leader), and every request after that is delayed.
    let drop_id = fault_registry().inject(
        FaultRule::always(EdgePattern::new(STORE_EDGE, None, None), FaultKind::Drop)
            .with_count(1),
    );
    let delay_id = fault_registry().inject(FaultRule::always(
        EdgePattern::new(STORE_EDGE, None, None),
        FaultKind::Delay { ms: 5 },
    ));

    // Peer 1 goes silent past the threshold. The conviction tick's own
    // store traffic (heartbeat publish + peer mget) eats the injected
    // drop and delay.
    clock.advance(Duration::from_secs(3 * 3600 + 10));
    wd.tick();
    {
        let broken = broken.lock().unwrap();
        assert_eq!(broken.len(), 1, "exactly one verdict despite channel chaos");
        assert_eq!(broken[0].0, world);
        assert_eq!(broken[0].1, Some(1), "the silent rank is still convicted");
    }
    let events = fault_registry().events();
    assert!(
        events.iter().any(|e| e.world == "store" && e.kind == "drop"),
        "the drop must demonstrably have hit the store channel"
    );
    assert!(
        events.iter().any(|e| e.world == "store" && e.kind == "delay"),
        "the delay must demonstrably have hit the store channel"
    );

    fault_registry().heal(delay_id);
    fault_registry().heal(drop_id);
    wd.shutdown();
    fault_registry().reset();
}
