//! Elasticity end-to-end for **sharded** replicas: forward-only serving
//! pipelines whose stages are split into `tp` tensor-parallel shards
//! joined by multi-member intra-replica worlds. No PJRT, no artifacts —
//! these tests run in the default CI build and under the
//! `MW_COLL_ALGO={flat,ring,auto}` matrix like the tier-1 suite (the
//! TP worlds follow the env-selected algorithm policy).
//!
//! Covered: a `tp=2, replicas=2, stages=2` pipeline serving a batch end
//! to end with the TP broadcast/all_reduce demonstrably running (global
//! `serving.tp.*` counters, fed from `World::last_algo`); a shard
//! killed mid-traffic yielding exactly one `Recovered` action, fresh
//! generation-tagged world names and zero request loss; and a dead
//! *head* shard whose edge worlds are re-minted along with the TP
//! world.

use multiworld::config::ServingConfig;
use multiworld::launch::InProcCluster;
use multiworld::mwccl::WorldOptions;
use multiworld::serving::controller::{Action, ScalingPolicy};
use multiworld::serving::topology::{NodeId, Topology};
use multiworld::serving::RequestGen;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Serialize cluster tests (they spawn many threads and fixed-range
/// store ports).
static SERIAL: Mutex<()> = Mutex::new(());

const BATCH: usize = 4;
const SEQ_LEN: usize = 8;
const VOCAB: usize = 32;

fn uniq(prefix: &str) -> String {
    static N: AtomicU64 = AtomicU64::new(0);
    format!(
        "{prefix}{}-{}",
        std::process::id() % 1000,
        N.fetch_add(1, Ordering::Relaxed)
    )
}

fn base_port() -> u16 {
    static NEXT: AtomicU64 = AtomicU64::new(0);
    46_000 + (NEXT.fetch_add(1, Ordering::Relaxed) as u16 % 150) * 120
        + (std::process::id() % 89) as u16
}

/// `from_env` base so CI's `MW_SPARES=2` chaos leg runs these kills
/// against a warm spare pool (promotion instead of cold respawn).
fn fast_cfg() -> ServingConfig {
    ServingConfig {
        heartbeat_ms: 50,
        miss_threshold: 3,
        batch_timeout_ms: 3,
        ..ServingConfig::from_env()
    }
}

fn cluster(
    topo: Topology,
    opts: WorldOptions,
    policy: ScalingPolicy,
) -> InProcCluster {
    InProcCluster::start_forward_only(topo, opts, policy, &fast_cfg(), BATCH, SEQ_LEN, VOCAB)
        .unwrap()
}

fn tp_counter_sum(op: &str) -> u64 {
    let g = multiworld::metrics::global();
    g.counter(&format!("serving.tp.{op}.flat")).get()
        + g.counter(&format!("serving.tp.{op}.ring")).get()
}

#[test]
fn tp2_pipeline_serves_batches_through_tp_collectives() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    // The acceptance topology: 2 stages × 2 replicas × 2 shards.
    let topo = Topology::pipeline_tp(&uniq("tpsrv"), &[2, 2], &[2, 2], base_port());
    assert_eq!(topo.workers().len(), 8);
    let bcast_before = tp_counter_sum("broadcast");
    let ar_before = tp_counter_sum("all_reduce");
    let cluster = cluster(
        topo,
        WorldOptions::shm().with_init_timeout(Duration::from_secs(120)),
        ScalingPolicy { recover: false, ..Default::default() },
    );
    let mut gen = RequestGen::new(7, SEQ_LEN, VOCAB, None);
    let total = BATCH * 4;
    let report = cluster
        .leader
        .serve(gen.take(total), None, Duration::from_secs(60));
    assert_eq!(report.completed, total, "all requests answered through sharded replicas");
    // The TP inner loop demonstrably ran: every processed batch did one
    // broadcast + one all_reduce inside a TP world, and the workers
    // recorded the algorithm `World::last_algo` reported for each.
    assert!(
        tp_counter_sum("broadcast") > bcast_before,
        "TP broadcasts must be recorded (flat or ring)"
    );
    assert!(
        tp_counter_sum("all_reduce") > ar_before,
        "TP all_reduces must be recorded (flat or ring)"
    );
    cluster.shutdown();
}

#[test]
fn killing_a_shard_mid_traffic_recovers_once_without_request_loss() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    // Stage 1 is replicated (so service survives the gap) and sharded.
    let topo = Topology::pipeline_tp(&uniq("tpchaos"), &[1, 2], &[1, 2], base_port());
    let cluster = cluster(
        topo,
        // TCP: failures are detectable without waiting out the watchdog.
        WorldOptions::tcp().with_init_timeout(Duration::from_secs(120)),
        ScalingPolicy { recover: true, ..Default::default() },
    );
    let victim = NodeId::Worker { stage: 1, replica: 1, shard: 1 };
    let old_tp_world = cluster
        .controller
        .topology()
        .tp_world_of(victim)
        .unwrap()
        .name
        .clone();

    let total = BATCH * 8;
    let mut gen = RequestGen::new(9, SEQ_LEN, VOCAB, None);
    let requests = gen.take(total);
    let cluster_ref = &cluster;
    let report = std::thread::scope(|s| {
        let killer = s.spawn(move || {
            std::thread::sleep(Duration::from_millis(150));
            assert!(cluster_ref.kill(victim), "victim shard must be alive to kill");
        });
        let report = cluster_ref
            .leader
            .serve(requests, Some(300.0), Duration::from_secs(90));
        killer.join().unwrap();
        report
    });
    assert_eq!(
        report.completed, total,
        "no request loss after drain (retries: {})",
        report.retries
    );

    // Exactly one Recovered action, for the victim shard, under its own
    // id (shard-granularity recovery keeps replica and shard ids).
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    loop {
        let recoveries: Vec<Action> = cluster
            .controller
            .actions()
            .into_iter()
            .filter(|a| matches!(a, Action::Recovered { .. }))
            .collect();
        if !recoveries.is_empty() {
            assert_eq!(
                recoveries,
                vec![Action::Recovered { dead: victim, replacement: victim }],
                "exactly one recovery, of the dead shard itself"
            );
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "controller never recovered the shard"
        );
        std::thread::sleep(Duration::from_millis(50));
    }
    // The respawned shard is live again and its TP world name is fresh.
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    while !cluster.live_workers().contains(&victim) {
        assert!(std::time::Instant::now() < deadline);
        std::thread::sleep(Duration::from_millis(50));
    }
    let new_tp_world = cluster
        .controller
        .topology()
        .tp_world_of(victim)
        .unwrap()
        .name
        .clone();
    assert_ne!(new_tp_world, old_tp_world, "broken world names are never reused");
    assert!(new_tp_world.contains("#g"), "fresh names are generation-tagged: {new_tp_world}");

    // And the pipeline serves through the recovered replica afterwards.
    let report = cluster
        .leader
        .serve(gen.take(BATCH * 2), None, Duration::from_secs(60));
    assert_eq!(report.completed, BATCH * 2);
    cluster.shutdown();
}

#[test]
fn killing_a_head_shard_reminted_edges_and_resumes() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let topo = Topology::pipeline_tp(&uniq("tphead"), &[1, 2], &[1, 2], base_port());
    let cluster = cluster(
        topo,
        WorldOptions::tcp().with_init_timeout(Duration::from_secs(120)),
        ScalingPolicy { recover: true, ..Default::default() },
    );
    let head = NodeId::worker(1, 0);
    let old_worlds: Vec<String> = cluster
        .controller
        .topology()
        .worlds_of(head)
        .iter()
        .map(|w| w.name.clone())
        .collect();
    assert_eq!(old_worlds.len(), 3, "in-edge + out-edge + tp world");
    assert!(cluster.kill(head));

    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    loop {
        if cluster.controller.actions().iter().any(
            |a| matches!(a, Action::Recovered { dead, .. } if *dead == head),
        ) {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "controller never recovered the head; actions: {:?}",
            cluster.controller.actions()
        );
        std::thread::sleep(Duration::from_millis(50));
    }
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    while !cluster.live_workers().contains(&head) {
        assert!(std::time::Instant::now() < deadline);
        std::thread::sleep(Duration::from_millis(50));
    }
    // The replica kept its id but every one of its worlds is fresh.
    let topo = cluster.controller.topology();
    let new_worlds: Vec<String> =
        topo.worlds_of(head).iter().map(|w| w.name.clone()).collect();
    assert_eq!(new_worlds.len(), 3);
    for w in &new_worlds {
        assert!(!old_worlds.contains(w), "world {w} must be re-minted");
        assert!(w.contains("#g"), "fresh names are generation-tagged: {w}");
    }
    // Service works end to end through the re-minted replica.
    let mut gen = RequestGen::new(11, SEQ_LEN, VOCAB, None);
    let report = cluster
        .leader
        .serve(gen.take(BATCH * 2), None, Duration::from_secs(60));
    assert_eq!(report.completed, BATCH * 2);
    cluster.shutdown();
}
