//! Collectives at production-ish world sizes (4 and 8) over both
//! transports, proving the ring algorithms — now for all six
//! collectives — agree bit-for-bit with the flat star the seed shipped
//! with, and that size-aware `Auto` resolves root-only-size ops through
//! the prologue negotiation.
//!
//! Reduction test data is integer-valued f32, so sums are exact and
//! order-independent — flat (rank-order fold at the root) and ring
//! (neighbour-order fold) must then produce identical checksums.

use multiworld::config::{CollAlgo, CollOp};
use multiworld::mwccl::{Rendezvous, ReduceOp, WorldOptions};
use multiworld::tensor::Tensor;
use std::time::Duration;

fn uniq(name: &str) -> String {
    use std::sync::atomic::{AtomicU64, Ordering};
    static N: AtomicU64 = AtomicU64::new(0);
    format!(
        "cs-{name}-{}-{}",
        std::process::id(),
        N.fetch_add(1, Ordering::Relaxed)
    )
}

fn opts(transport: &str, algo: CollAlgo) -> WorldOptions {
    let base = match transport {
        "shm" => WorldOptions::shm(),
        "tcp" => WorldOptions::tcp(),
        other => panic!("unknown transport {other}"),
    };
    // A generous deadline converts any algorithm mismatch into a clean
    // Timeout instead of a hung test.
    base.with_coll_algo(algo)
        .with_op_timeout(Duration::from_secs(60))
}

/// Integer-valued pseudo-random tensor: exact under f32 summation for
/// any world size tested here, so fold order cannot change the result.
fn int_tensor(elems: usize, rank: usize) -> Tensor {
    let vals: Vec<f32> = (0..elems)
        .map(|i| ((i as u64 * 31 + rank as u64 * 7 + 3) % 101) as f32)
        .collect();
    Tensor::from_f32(&[elems], &vals)
}

fn expected_sum(elems: usize, size: usize) -> Tensor {
    let mut acc = vec![0.0f32; elems];
    for r in 0..size {
        for (a, b) in acc.iter_mut().zip(int_tensor(elems, r).as_f32()) {
            *a += *b;
        }
    }
    Tensor::from_f32(&[elems], &acc)
}

/// Run `all_reduce(Sum)` over a fresh world and return the per-rank
/// result checksums (asserted identical across ranks).
fn all_reduce_checksum(transport: &str, size: usize, elems: usize, algo: CollAlgo) -> u64 {
    let worlds =
        Rendezvous::single_process(&uniq("ar"), size, opts(transport, algo)).unwrap();
    let handles: Vec<_> = worlds
        .into_iter()
        .map(|w| {
            let t = int_tensor(elems, w.rank());
            std::thread::spawn(move || w.all_reduce(t, ReduceOp::Sum).unwrap().checksum())
        })
        .collect();
    let sums: Vec<u64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    for &s in &sums[1..] {
        assert_eq!(s, sums[0], "ranks disagree on the all_reduce result");
    }
    sums[0]
}

#[test]
fn all_reduce_flat_ring_equivalence_sizes_4_and_8() {
    for transport in ["shm", "tcp"] {
        for size in [4usize, 8] {
            let elems = 100_000; // 400 KB — multi-chunk per ring slice at size 4
            let want = expected_sum(elems, size).checksum();
            let flat = all_reduce_checksum(transport, size, elems, CollAlgo::Flat);
            let ring = all_reduce_checksum(transport, size, elems, CollAlgo::Ring);
            assert_eq!(flat, want, "{transport} size={size}: flat != reference");
            assert_eq!(ring, want, "{transport} size={size}: ring != reference");
        }
    }
}

#[test]
fn ring_all_reduce_odd_sizes_and_tiny_tensors() {
    // Non-divisible element counts (uneven ring slices) and tensors
    // smaller than the world (empty slices on some ranks).
    for elems in [100_003usize, 7, 3, 1] {
        let want = expected_sum(elems, 4).checksum();
        let ring = all_reduce_checksum("shm", 4, elems, CollAlgo::Ring);
        assert_eq!(ring, want, "elems={elems}");
    }
}

#[test]
fn ring_all_reduce_world_of_two() {
    let want = expected_sum(5_000, 2).checksum();
    assert_eq!(all_reduce_checksum("shm", 2, 5_000, CollAlgo::Ring), want);
}

#[test]
fn ring_all_reduce_avg_and_max() {
    for (op, combine) in [
        (ReduceOp::Avg, None),
        (ReduceOp::Max, Some(f32::max as fn(f32, f32) -> f32)),
    ] {
        let size = 4;
        let elems = 10_000;
        let worlds = Rendezvous::single_process(
            &uniq("avgmax"),
            size,
            opts("shm", CollAlgo::Ring),
        )
        .unwrap();
        let handles: Vec<_> = worlds
            .into_iter()
            .map(|w| {
                let t = int_tensor(elems, w.rank());
                std::thread::spawn(move || w.all_reduce(t, op).unwrap())
            })
            .collect();
        let mut expect = vec![0.0f32; elems];
        match combine {
            None => {
                for r in 0..size {
                    for (a, b) in expect.iter_mut().zip(int_tensor(elems, r).as_f32()) {
                        *a += *b;
                    }
                }
                for a in expect.iter_mut() {
                    *a /= size as f32; // size 4: exact for integer sums
                }
            }
            Some(f) => {
                expect = int_tensor(elems, 0).as_f32().to_vec();
                for r in 1..size {
                    for (a, b) in expect.iter_mut().zip(int_tensor(elems, r).as_f32()) {
                        *a = f(*a, *b);
                    }
                }
            }
        }
        for h in handles {
            assert_eq!(h.join().unwrap().as_f32(), expect.as_slice(), "{op:?}");
        }
    }
}

#[test]
fn broadcast_flat_ring_equivalence_multi_chunk() {
    // 1.2 MB tensor (several SEG_MAX chunks) from a non-zero root.
    for transport in ["shm", "tcp"] {
        for size in [4usize, 8] {
            let src = int_tensor(300_000, 17);
            let want = src.checksum();
            for algo in [CollAlgo::Flat, CollAlgo::Ring] {
                let worlds =
                    Rendezvous::single_process(&uniq("bc"), size, opts(transport, algo))
                        .unwrap();
                let handles: Vec<_> = worlds
                    .into_iter()
                    .map(|w| {
                        let t = if w.rank() == 1 { Some(src.clone()) } else { None };
                        std::thread::spawn(move || w.broadcast(t, 1).unwrap().checksum())
                    })
                    .collect();
                for h in handles {
                    assert_eq!(
                        h.join().unwrap(),
                        want,
                        "{transport} size={size} {algo:?}"
                    );
                }
            }
        }
    }
}

#[test]
fn all_gather_flat_ring_equivalence_unequal_parts() {
    // Per-rank contributions of different axis-0 lengths must concat in
    // rank order identically under both algorithms.
    let size = 4;
    let mut results = Vec::new();
    for algo in [CollAlgo::Flat, CollAlgo::Ring] {
        let worlds =
            Rendezvous::single_process(&uniq("ag"), size, opts("tcp", algo)).unwrap();
        let handles: Vec<_> = worlds
            .into_iter()
            .map(|w| {
                let rows = w.rank() + 1; // 1..=4 rows of width 3
                let vals: Vec<f32> =
                    (0..rows * 3).map(|i| (w.rank() * 100 + i) as f32).collect();
                let t = Tensor::from_f32(&[rows, 3], &vals);
                std::thread::spawn(move || w.all_gather(t).unwrap())
            })
            .collect();
        let tensors: Vec<Tensor> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        for t in &tensors[1..] {
            assert_eq!(t.checksum(), tensors[0].checksum());
        }
        assert_eq!(tensors[0].shape(), &[1 + 2 + 3 + 4, 3]);
        results.push(tensors[0].checksum());
    }
    assert_eq!(results[0], results[1], "flat and ring all_gather differ");
}

/// Run `reduce(Sum)` to `root` over a fresh world and return the root's
/// result checksum (asserting non-roots get `None`).
fn reduce_checksum(
    transport: &str,
    size: usize,
    elems: usize,
    algo: CollAlgo,
    root: usize,
) -> u64 {
    let worlds =
        Rendezvous::single_process(&uniq("rd"), size, opts(transport, algo)).unwrap();
    let handles: Vec<_> = worlds
        .into_iter()
        .map(|w| {
            let t = int_tensor(elems, w.rank());
            std::thread::spawn(move || (w.rank(), w.reduce(t, root, ReduceOp::Sum).unwrap()))
        })
        .collect();
    let mut cs = None;
    for h in handles {
        let (rank, res) = h.join().unwrap();
        if rank == root {
            cs = Some(res.expect("root must get the reduction").checksum());
        } else {
            assert!(res.is_none(), "non-root rank {rank} must get None");
        }
    }
    cs.unwrap()
}

#[test]
fn reduce_flat_ring_equivalence_sizes_4_and_8() {
    // Non-zero root exercises the ring's wrapped slice hand-off.
    for transport in ["shm", "tcp"] {
        for size in [4usize, 8] {
            let elems = 100_000; // 400 KB — multi-chunk per ring slice
            let want = expected_sum(elems, size).checksum();
            let flat = reduce_checksum(transport, size, elems, CollAlgo::Flat, 2);
            let ring = reduce_checksum(transport, size, elems, CollAlgo::Ring, 2);
            assert_eq!(flat, want, "{transport} size={size}: flat != reference");
            assert_eq!(ring, want, "{transport} size={size}: ring != reference");
        }
    }
}

#[test]
fn ring_reduce_odd_sizes_and_tiny_tensors() {
    // Non-divisible element counts (uneven ring slices) and tensors
    // smaller than the world (empty slices on some ranks).
    for elems in [100_003usize, 7, 3, 1] {
        let want = expected_sum(elems, 4).checksum();
        let ring = reduce_checksum("shm", 4, elems, CollAlgo::Ring, 1);
        assert_eq!(ring, want, "elems={elems}");
    }
}

#[test]
fn ring_reduce_avg_divides_once() {
    // Avg must scale exactly once (each owner scales its slice before
    // the hand-off; the root must not rescale).
    let size = 4;
    let elems = 10_000;
    let worlds =
        Rendezvous::single_process(&uniq("rdavg"), size, opts("shm", CollAlgo::Ring)).unwrap();
    let handles: Vec<_> = worlds
        .into_iter()
        .map(|w| {
            let t = int_tensor(elems, w.rank());
            std::thread::spawn(move || (w.rank(), w.reduce(t, 0, ReduceOp::Avg).unwrap()))
        })
        .collect();
    let mut expect = expected_sum(elems, size).as_f32().to_vec();
    for a in expect.iter_mut() {
        *a /= size as f32; // size 4: exact for integer sums
    }
    for h in handles {
        let (rank, res) = h.join().unwrap();
        if rank == 0 {
            assert_eq!(res.unwrap().as_f32(), expect.as_slice());
        }
    }
}

#[test]
fn gather_flat_ring_equivalence_unequal_parts() {
    // Per-rank contributions of different axis-0 lengths must concat in
    // rank order identically under both algorithms, from a non-zero
    // root, at both tested world sizes over both transports.
    for transport in ["shm", "tcp"] {
        for size in [4usize, 8] {
            let mut results = Vec::new();
            for algo in [CollAlgo::Flat, CollAlgo::Ring] {
                let worlds =
                    Rendezvous::single_process(&uniq("ga"), size, opts(transport, algo))
                        .unwrap();
                let handles: Vec<_> = worlds
                    .into_iter()
                    .map(|w| {
                        let rows = w.rank() + 1;
                        let vals: Vec<f32> = (0..rows * 3)
                            .map(|i| (w.rank() * 100 + i) as f32)
                            .collect();
                        let t = Tensor::from_f32(&[rows, 3], &vals);
                        std::thread::spawn(move || (w.rank(), w.gather(t, 1).unwrap()))
                    })
                    .collect();
                for h in handles {
                    let (rank, res) = h.join().unwrap();
                    if rank == 1 {
                        let cat = res.expect("root must get the concatenation");
                        let total_rows: usize = (1..=size).sum();
                        assert_eq!(cat.shape(), &[total_rows, 3], "{transport} {algo:?}");
                        results.push(cat.checksum());
                    } else {
                        assert!(res.is_none());
                    }
                }
            }
            assert_eq!(
                results[0], results[1],
                "{transport} size={size}: flat and ring gather differ"
            );
        }
    }
}

#[test]
fn scatter_flat_ring_equivalence_sizes_4_and_8() {
    // Parts of differing sizes, non-zero root: every rank must receive
    // exactly its part under both algorithms.
    for transport in ["shm", "tcp"] {
        for size in [4usize, 8] {
            for algo in [CollAlgo::Flat, CollAlgo::Ring] {
                let root = 1;
                let part_elems = |i: usize| 80_000 + 5_000 * i; // multi-frame, uneven
                let worlds =
                    Rendezvous::single_process(&uniq("sc8"), size, opts(transport, algo))
                        .unwrap();
                let handles: Vec<_> = worlds
                    .into_iter()
                    .map(|w| {
                        let parts = if w.rank() == root {
                            Some(
                                (0..size)
                                    .map(|i| int_tensor(part_elems(i), i))
                                    .collect::<Vec<_>>(),
                            )
                        } else {
                            None
                        };
                        std::thread::spawn(move || (w.rank(), w.scatter(parts, root).unwrap()))
                    })
                    .collect();
                for h in handles {
                    let (rank, t) = h.join().unwrap();
                    assert_eq!(
                        t.checksum(),
                        int_tensor(part_elems(rank), rank).checksum(),
                        "{transport} size={size} {algo:?} rank={rank}"
                    );
                }
            }
        }
    }
}

#[test]
fn auto_prologue_keeps_small_root_sized_ops_flat() {
    // World 4 is ring-eligible under Auto, but the payload size is only
    // known at the root for broadcast/all_gather — the root's prologue
    // byte must keep sub-threshold ops on the flat fast path and switch
    // outsized ones to the ring, consistently on every rank.
    let size = 4;
    for transport in ["shm", "tcp"] {
        let worlds = Rendezvous::single_process(
            &uniq("autoplg"),
            size,
            opts(transport, CollAlgo::Auto),
        )
        .unwrap();
        let handles: Vec<_> = worlds
            .into_iter()
            .map(|w| {
                std::thread::spawn(move || {
                    let t = if w.rank() == 1 { Some(int_tensor(256, 1)) } else { None };
                    let small = w.broadcast(t, 1).unwrap();
                    assert_eq!(small.checksum(), int_tensor(256, 1).checksum());
                    let small_pick = w.last_algo(CollOp::Broadcast).unwrap();
                    w.all_gather(int_tensor(64, w.rank())).unwrap();
                    let ag_pick = w.last_algo(CollOp::AllGather).unwrap();
                    let t = if w.rank() == 1 {
                        Some(int_tensor(1 << 20, 1)) // 4 MB ≥ RING_MIN_BYTES
                    } else {
                        None
                    };
                    w.broadcast(t, 1).unwrap();
                    let big_pick = w.last_algo(CollOp::Broadcast).unwrap();
                    (small_pick, ag_pick, big_pick)
                })
            })
            .collect();
        for h in handles {
            let (small_pick, ag_pick, big_pick) = h.join().unwrap();
            assert_eq!(
                small_pick, "flat",
                "{transport}: sub-threshold broadcast must stay flat"
            );
            assert_eq!(
                ag_pick, "flat",
                "{transport}: sub-threshold all_gather must stay flat"
            );
            assert_eq!(big_pick, "ring", "{transport}: 4 MB broadcast must ring");
        }
    }
}

#[test]
fn auto_gather_estimate_clamps_to_observed_contributions() {
    // Skewed per-rank sizes: the negotiation root contributes 400 B
    // while every other rank contributes 400 KB. The root's first
    // own-contribution × N estimate under-picks flat; from the second
    // invocation on, the estimate is clamped by the largest
    // contribution observed in round one and the op rings. Results must
    // be identical either way.
    use multiworld::config::{CollPolicy, RingThreshold};
    let size = 4;
    let row = RingThreshold { min_world: 4, min_bytes: 600_000 };
    let policy = CollPolicy::new(CollAlgo::Auto)
        .with_threshold(CollOp::Gather, row)
        .with_threshold(CollOp::AllGather, row);
    for op in ["gather", "all_gather"] {
        let worlds = Rendezvous::single_process(
            &uniq("clamp"),
            size,
            opts("tcp", CollAlgo::Auto).with_coll_policy(policy),
        )
        .unwrap();
        let handles: Vec<_> = worlds
            .into_iter()
            .map(|w| {
                let op = op.to_string();
                std::thread::spawn(move || {
                    // Root (rank 0) is tiny; everyone else is large.
                    let elems = if w.rank() == 0 { 100 } else { 100_000 };
                    let contrib = || int_tensor(elems, w.rank());
                    let run = |w: &multiworld::mwccl::World| match op.as_str() {
                        "gather" => {
                            let res = w.gather(contrib(), 0).unwrap();
                            (res.map(|t| t.checksum()), w.last_algo(CollOp::Gather))
                        }
                        _ => {
                            let t = w.all_gather(contrib()).unwrap();
                            (Some(t.checksum()), w.last_algo(CollOp::AllGather))
                        }
                    };
                    let first = run(&w);
                    let second = run(&w);
                    (w.rank(), first, second)
                })
            })
            .collect();
        for h in handles {
            let (rank, (cs1, algo1), (cs2, algo2)) = h.join().unwrap();
            if rank == 0 {
                assert_eq!(
                    algo1,
                    Some("flat"),
                    "{op}: first round under-estimates from the tiny root contribution"
                );
                assert_eq!(
                    algo2,
                    Some("ring"),
                    "{op}: clamp from round-one contributions must flip the pick"
                );
                assert_eq!(cs1, cs2, "{op}: flat and ring results must agree");
            }
        }
    }
}

#[test]
fn reduce_arrival_order_folds_stragglers() {
    // Peers contribute with staggered delays; the root folds whichever
    // arrives first. Result must equal the rank-order reference.
    let size = 4;
    let elems = 5_000;
    let root = 2;
    let worlds = Rendezvous::single_process(&uniq("red"), size, opts("tcp", CollAlgo::Flat))
        .unwrap();
    let handles: Vec<_> = worlds
        .into_iter()
        .map(|w| {
            let t = int_tensor(elems, w.rank());
            std::thread::spawn(move || {
                if w.rank() != root {
                    // Reverse-staggered: higher ranks land first.
                    std::thread::sleep(Duration::from_millis(
                        20 * (size - w.rank()) as u64,
                    ));
                }
                (w.rank(), w.reduce(t, root, ReduceOp::Sum).unwrap())
            })
        })
        .collect();
    let want = expected_sum(elems, size).checksum();
    for h in handles {
        let (rank, res) = h.join().unwrap();
        if rank == root {
            assert_eq!(res.unwrap().checksum(), want);
        } else {
            assert!(res.is_none());
        }
    }
}

/// Per-rank values chosen so f32 summation is *order-sensitive*: rank 0
/// contributes ~+3e7, rank 2 ~−3e7, ranks 1/3 small values that vanish
/// into the big magnitudes unless folded in the right order. Any change
/// of fold order moves the result by whole units, not ulps.
fn sensitive_tensor(elems: usize, rank: usize) -> Tensor {
    let vals: Vec<f32> = (0..elems)
        .map(|i| match rank {
            0 => 3.0e7 + (i % 13) as f32,
            1 => 1.0 + (i % 7) as f32 * 0.25,
            2 => -3.0e7 - (i % 11) as f32,
            _ => 0.125 + (i % 3) as f32,
        })
        .collect();
    Tensor::from_f32(&[elems], &vals)
}

/// The exact fold `reduce_impl` promises: rank order 0, 1, …, N−1,
/// elementwise, root's own contribution in its rank slot.
fn rank_order_reference(elems: usize, size: usize) -> Tensor {
    let mut acc = sensitive_tensor(elems, 0).as_f32().to_vec();
    for r in 1..size {
        for (a, b) in acc.iter_mut().zip(sensitive_tensor(elems, r).as_f32()) {
            *a += *b;
        }
    }
    Tensor::from_f32(&[elems], &acc)
}

#[test]
fn flat_reduce_bitwise_deterministic_under_adversarial_arrival() {
    // Regression for the arrival-order fold the seed shipped with: the
    // flat reduce must produce the *bitwise-identical* rank-order result
    // no matter how the network reorders contributions. FaultLink delay
    // rules force three different arrival orders at the root; every run
    // must match the rank-order reference exactly.
    use multiworld::mwccl::{EdgePattern, FaultKind, FaultPlan, FaultRule};
    let (size, elems, root) = (4usize, 2_000usize, 2usize);
    let want = rank_order_reference(elems, size);

    // Guard: the inputs really are order-sensitive — folding rank 3
    // before ranks 1 and 2 must give a *different* f32 result, or this
    // test would pass vacuously.
    let mut reordered = sensitive_tensor(elems, 0).as_f32().to_vec();
    for r in [3usize, 1, 2] {
        for (a, b) in reordered.iter_mut().zip(sensitive_tensor(elems, r).as_f32()) {
            *a += *b;
        }
    }
    assert_ne!(
        Tensor::from_f32(&[elems], &reordered).checksum(),
        want.checksum(),
        "test inputs must be fold-order sensitive"
    );

    // Three arrival orders: undelayed, rank 1 straggling, rank 3
    // straggling (delays land on the straggler's send to the root).
    let plans: Vec<Option<FaultPlan>> = vec![
        None,
        Some(FaultPlan::new(
            vec![FaultRule::always(
                EdgePattern::new("*", Some(1), Some(root)),
                FaultKind::Delay { ms: 60 },
            )],
            1,
        )),
        Some(FaultPlan::new(
            vec![FaultRule::always(
                EdgePattern::new("*", Some(3), Some(root)),
                FaultKind::Delay { ms: 60 },
            )],
            1,
        )),
    ];
    for plan in plans {
        let mut o = opts("tcp", CollAlgo::Flat);
        if let Some(p) = plan.clone() {
            o = o.with_fault_plan(p);
        }
        let worlds = Rendezvous::single_process(&uniq("detred"), 4, o).unwrap();
        let handles: Vec<_> = worlds
            .into_iter()
            .map(|w| {
                let t = sensitive_tensor(elems, w.rank());
                std::thread::spawn(move || {
                    (w.rank(), w.reduce(t, root, ReduceOp::Sum).unwrap())
                })
            })
            .collect();
        for h in handles {
            let (rank, res) = h.join().unwrap();
            if rank == root {
                assert_eq!(
                    res.unwrap().as_f32(),
                    want.as_f32(),
                    "flat reduce must be bitwise rank-order deterministic \
                     (plan: {plan:?})"
                );
            }
        }
    }
}

#[test]
fn flat_and_ring_reduce_agree_bitwise_on_exact_inputs() {
    // With integer-valued (exactly representable) contributions the fold
    // order cannot round: flat and ring must agree bit for bit, not just
    // to a tolerance — pinned via the raw f32 words, at both roots'
    // parities, over tcp.
    let (size, elems) = (4usize, 10_000usize);
    for root in [0usize, 2] {
        let mut results: Vec<Vec<f32>> = Vec::new();
        for algo in [CollAlgo::Flat, CollAlgo::Ring] {
            let worlds =
                Rendezvous::single_process(&uniq("bitred"), size, opts("tcp", algo)).unwrap();
            let handles: Vec<_> = worlds
                .into_iter()
                .map(|w| {
                    let t = int_tensor(elems, w.rank());
                    std::thread::spawn(move || {
                        (w.rank(), w.reduce(t, root, ReduceOp::Sum).unwrap())
                    })
                })
                .collect();
            for h in handles {
                let (rank, res) = h.join().unwrap();
                if rank == root {
                    results.push(res.unwrap().as_f32().to_vec());
                }
            }
        }
        assert_eq!(
            results[0], results[1],
            "root={root}: flat and ring reduce must agree bitwise on exact inputs"
        );
    }
}

#[test]
fn scatter_size_4_distributes_without_root_clone() {
    let size = 4;
    let worlds = Rendezvous::single_process(&uniq("sc"), size, opts("shm", CollAlgo::Flat))
        .unwrap();
    let handles: Vec<_> = worlds
        .into_iter()
        .map(|w| {
            let parts = if w.rank() == 0 {
                Some(
                    (0..size)
                        .map(|i| Tensor::from_f32(&[2], &[i as f32, i as f32 + 0.5]))
                        .collect::<Vec<_>>(),
                )
            } else {
                None
            };
            std::thread::spawn(move || (w.rank(), w.scatter(parts, 0).unwrap()))
        })
        .collect();
    for h in handles {
        let (rank, t) = h.join().unwrap();
        assert_eq!(t.as_f32(), &[rank as f32, rank as f32 + 0.5]);
    }
}

#[test]
fn mixed_async_ops_in_flight_ring() {
    // Issue all six collectives back-to-back (all in flight) before
    // waiting on any — submission order is the CCL contract; the ring
    // tags must never cross-match between ops.
    for transport in ["shm", "tcp"] {
        let size = 4;
        let elems = 20_000;
        let worlds = Rendezvous::single_process(
            &uniq("mix"),
            size,
            opts(transport, CollAlgo::Ring),
        )
        .unwrap();
        let src = int_tensor(elems, 99);
        let bc_want = src.checksum();
        let ar_want = expected_sum(elems, size).checksum();
        let rd_want = ar_want;
        let handles: Vec<_> = worlds
            .into_iter()
            .map(|w| {
                let bct = if w.rank() == 0 { Some(src.clone()) } else { None };
                let art = int_tensor(elems, w.rank());
                let agt = Tensor::from_f32(&[1], &[w.rank() as f32]);
                let rdt = int_tensor(elems, w.rank());
                let gat = Tensor::from_f32(&[1], &[10.0 + w.rank() as f32]);
                let sct = if w.rank() == 3 {
                    Some(
                        (0..size)
                            .map(|i| Tensor::from_f32(&[1], &[20.0 + i as f32]))
                            .collect::<Vec<_>>(),
                    )
                } else {
                    None
                };
                std::thread::spawn(move || {
                    let bc = w.ibroadcast(bct, 0);
                    let ar = w.iall_reduce(art, ReduceOp::Sum);
                    let ag = w.iall_gather(agt);
                    let rd = w.ireduce(rdt, 1, ReduceOp::Sum);
                    let ga = w.igather(gat, 2);
                    let sc = w.iscatter(sct, 3);
                    let bc = bc.wait().unwrap().unwrap();
                    let ar = ar.wait().unwrap().unwrap();
                    let ag = ag.wait().unwrap().unwrap();
                    let rd = rd.wait().unwrap();
                    let ga = ga.wait().unwrap();
                    let sc = sc.wait().unwrap().unwrap();
                    (w.rank(), bc.checksum(), ar.checksum(), ag, rd, ga, sc)
                })
            })
            .collect();
        for h in handles {
            let (rank, bc, ar, ag, rd, ga, sc) = h.join().unwrap();
            assert_eq!(bc, bc_want, "{transport} broadcast");
            assert_eq!(ar, ar_want, "{transport} all_reduce");
            assert_eq!(ag.as_f32(), &[0.0, 1.0, 2.0, 3.0], "{transport} all_gather");
            if rank == 1 {
                assert_eq!(rd.unwrap().checksum(), rd_want, "{transport} reduce");
            } else {
                assert!(rd.is_none(), "{transport} reduce non-root");
            }
            if rank == 2 {
                assert_eq!(
                    ga.unwrap().as_f32(),
                    &[10.0, 11.0, 12.0, 13.0],
                    "{transport} gather"
                );
            } else {
                assert!(ga.is_none(), "{transport} gather non-root");
            }
            assert_eq!(sc.as_f32(), &[20.0 + rank as f32], "{transport} scatter");
        }
    }
}

#[test]
fn auto_policy_correct_across_sizes() {
    // Auto picks flat at size 2 and ring at size 8 (large tensor); both
    // must be correct — this guards the selector's rank-consistency.
    for (size, elems) in [(2usize, 2_000), (8, 300_000)] {
        let want = expected_sum(elems, size).checksum();
        assert_eq!(
            all_reduce_checksum("shm", size, elems, CollAlgo::Auto),
            want,
            "auto size={size}"
        );
    }
}

#[test]
fn ring_large_tensor_through_small_shm_rings() {
    // 2 MB tensor, ring algorithm, shm transport: chunk trains stream
    // cut-through via the mmap rings without ever holding whole slices.
    let elems = 500_000;
    let want = expected_sum(elems, 4).checksum();
    assert_eq!(all_reduce_checksum("shm", 4, elems, CollAlgo::Ring), want);
}
