//! Property-based tests (via the in-tree proptest-lite harness) over the
//! coordinator's pure invariants: routing, batching, topology algebra,
//! tensor framing and the envelope codec.

use multiworld::serving::batcher::DynamicBatcher;
use multiworld::serving::router::{DispatchToken, ReplicaRouter};
use multiworld::serving::stage_worker::Envelope;
use multiworld::serving::topology::{NodeId, Topology};
use multiworld::serving::Request;
use multiworld::tensor::{serialize, DType, Tensor};
use multiworld::util::prop::{check, usize_in, vec_f32, Gen};
use multiworld::util::prng::Rng;
use std::time::Duration;

#[test]
fn prop_router_never_exceeds_inflight_cap() {
    check("router-cap", &usize_in(1, 8), |&cap| {
        let r = ReplicaRouter::new(cap);
        for i in 0..4 {
            r.add_replica(&format!("r{i}"));
        }
        let mut rng = Rng::new(cap as u64);
        let mut outstanding: Vec<DispatchToken> = Vec::new();
        for _ in 0..300 {
            if rng.chance(0.6) {
                if let Some(t) = r.pick() {
                    outstanding.push(t);
                }
            } else if let Some(t) = outstanding.pop() {
                r.complete(&t);
            }
            if r.inflight() > cap * 4 {
                return Err(format!("inflight {} > cap {} × replicas", r.inflight(), cap));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_router_dispatch_conserved() {
    // Every pick() is recorded against exactly one replica.
    check("router-conserve", &usize_in(1, 200), |&n| {
        let r = ReplicaRouter::new(0);
        for i in 0..3 {
            r.add_replica(&format!("r{i}"));
        }
        for _ in 0..n {
            let t = r.pick().ok_or("pick failed")?;
            r.complete(&t);
        }
        let total: u64 = r.dispatch_counts().values().sum();
        if total == n as u64 {
            Ok(())
        } else {
            Err(format!("dispatched {total} != picks {n}"))
        }
    });
}

#[test]
fn prop_router_balance_within_one() {
    // With immediate completion, round-robin keeps loads within 1.
    check("router-balance", &usize_in(1, 300), |&n| {
        let r = ReplicaRouter::new(0);
        for i in 0..4 {
            r.add_replica(&format!("r{i}"));
        }
        for _ in 0..n {
            let t = r.pick().ok_or("pick failed")?;
            r.complete(&t);
        }
        let counts = r.dispatch_counts();
        let max = counts.values().max().copied().unwrap_or(0);
        let min = counts.values().min().copied().unwrap_or(0);
        if max - min <= 1 {
            Ok(())
        } else {
            Err(format!("imbalance: {counts:?}"))
        }
    });
}

#[test]
fn prop_batcher_preserves_every_request_once_in_order() {
    check("batcher-fifo", &usize_in(0, 100), |&n| {
        let b = DynamicBatcher::new(7, Duration::from_millis(1));
        for i in 0..n {
            b.push(Request::new(i as u64, vec![0; 4]));
        }
        b.close();
        let mut seen = Vec::new();
        while let Some(batch) = b.next_batch() {
            if batch.len() > 7 {
                return Err(format!("batch of {} > max 7", batch.len()));
            }
            seen.extend(batch.into_iter().map(|r| r.id));
        }
        let expect: Vec<u64> = (0..n as u64).collect();
        if seen == expect {
            Ok(())
        } else {
            Err(format!("requests lost/reordered: {} of {}", seen.len(), n))
        }
    });
}

#[test]
fn prop_topology_edges_consistent() {
    // For any replica vector: every world has distinct members, each
    // worker has ≥1 in-edge and ≥1 out-edge, in/out views partition the
    // world set, and store ports are unique.
    let gen = Gen::new(|r: &mut Rng| {
        let stages = r.range(1, 4);
        (0..stages).map(|_| r.range(1, 3)).collect::<Vec<usize>>()
    });
    check("topology-edges", &gen, |replicas| {
        let t = Topology::pipeline("p", replicas, 10_000);
        let mut ports = std::collections::HashSet::new();
        for w in &t.worlds {
            if w.members[0] == w.members[1] {
                return Err(format!("self-loop world {}", w.name));
            }
            if !ports.insert(w.store_port) {
                return Err(format!("duplicate port {}", w.store_port));
            }
        }
        let mut in_total = 0;
        let mut out_total = 0;
        for node in t.workers() {
            let ins = t.in_edges(node).len();
            let outs = t.out_edges(node).len();
            if ins == 0 || outs == 0 {
                return Err(format!("{node} has ins={ins} outs={outs}"));
            }
            in_total += ins;
            out_total += outs;
        }
        // Leader's edges complete the partition.
        in_total += t.in_edges(NodeId::Leader).len();
        out_total += t.out_edges(NodeId::Leader).len();
        if in_total != t.worlds.len() || out_total != t.worlds.len() {
            return Err(format!(
                "in {}, out {} != worlds {}",
                in_total,
                out_total,
                t.worlds.len()
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_topology_remove_then_add_replica_keeps_connectivity() {
    let gen = Gen::new(|r: &mut Rng| (r.range(2, 3), r.range(1, 3), r.range(0, 1)));
    check("topology-heal", &gen, |&(stages, mid, _)| {
        let mut replicas = vec![1usize; stages];
        replicas[stages / 2] = mid + 1;
        let mut t = Topology::pipeline("h", &replicas, 11_000);
        let victim = NodeId::worker(stages / 2, 0);
        t.remove_node(victim);
        let (node, fresh) = t.add_replica(stages / 2, 12_000);
        if fresh.is_empty() {
            return Err("replacement has no worlds".into());
        }
        let ins = t.in_edges(node).len();
        let outs = t.out_edges(node).len();
        if ins == 0 || outs == 0 {
            return Err(format!("replacement not connected: ins={ins} outs={outs}"));
        }
        // Replacement never reuses a dead replica id.
        if node == victim {
            return Err("burned replica id reused".into());
        }
        Ok(())
    });
}

#[test]
fn prop_tensor_frame_roundtrip() {
    check("tensor-frame", &vec_f32(0, 4096), |v| {
        let t = Tensor::from_f32(&[v.len()], v);
        let mut buf = Vec::new();
        serialize::write_tensor(&mut buf, &t).map_err(|e| e.to_string())?;
        let back = serialize::read_tensor(&mut buf.as_slice()).map_err(|e| e.to_string())?;
        if back.checksum() == t.checksum() {
            Ok(())
        } else {
            Err("checksum mismatch".into())
        }
    });
}

#[test]
fn prop_envelope_roundtrip_any_id_and_payload() {
    let gen = Gen::new(|r: &mut Rng| {
        let id = r.next_u64();
        let n = r.range(0, 2048);
        let mut v = vec![0.0f32; n];
        r.fill_f32(&mut v);
        (id, v)
    });
    check("envelope-roundtrip", &gen, |(id, v)| {
        let env = Envelope { id: *id, tensor: Tensor::from_f32(&[v.len()], v) };
        let back = Envelope::unpack(&env.pack()).map_err(|e| e.to_string())?;
        if back.id == *id && back.tensor.checksum() == env.tensor.checksum() {
            Ok(())
        } else {
            Err("envelope mismatch".into())
        }
    });
}

#[test]
fn prop_chunk_concat_identity() {
    let gen = Gen::new(|r: &mut Rng| {
        let parts = r.range(1, 6);
        let rows_per = r.range(1, 5);
        let cols = r.range(1, 8);
        (parts, rows_per, cols, r.next_u64())
    });
    check("chunk-concat", &gen, |&(parts, rows_per, cols, seed)| {
        let mut rng = Rng::new(seed);
        let t = Tensor::rand_f32(&[parts * rows_per, cols], &mut rng);
        let chunks = t.chunk(parts).map_err(|e| e.to_string())?;
        let back = Tensor::concat(&chunks).map_err(|e| e.to_string())?;
        if back == t {
            Ok(())
        } else {
            Err("chunk∘concat ≠ id".into())
        }
    });
}

#[test]
fn prop_dtype_header_rejects_corruption() {
    // Flipping any single header byte must never yield a tensor that
    // passes validation with different geometry silently.
    let gen = Gen::new(|r: &mut Rng| (r.range(1, 64), r.range(0, 63), r.range(1, 255) as u8));
    check("header-corruption", &gen, |&(n, byte, xor)| {
        let t = Tensor::zeros(DType::F32, &[n]);
        let mut buf = Vec::new();
        serialize::write_tensor(&mut buf, &t).map_err(|e| e.to_string())?;
        buf[byte] ^= xor;
        match serialize::read_tensor(&mut buf.as_slice()) {
            // Either rejected…
            Err(_) => Ok(()),
            // …or decoded to exactly the same geometry (the flip hit a
            // don't-care byte such as reserved padding).
            Ok(back) => {
                if back.dtype() == DType::F32 && back.shape() == t.shape() {
                    Ok(())
                } else {
                    Err(format!(
                        "corrupted header accepted: {:?} {:?}",
                        back.dtype(),
                        back.shape()
                    ))
                }
            }
        }
    });
}
