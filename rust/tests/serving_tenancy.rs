//! Multi-tenant serving end to end: per-tenant SLO classes under
//! weighted-fair admission on a live forward-only cluster.
//!
//! The headline isolation contract: a low-weight tenant flooding at
//! ~10× the steady tenant's solo service rate must not move the steady
//! tenant's tail latency — the burster sheds *its own* traffic at its
//! own per-tenant admission bound, the steady tenant sheds nothing,
//! and its p99 stays at its solo baseline. Also covered: unknown
//! tenants folding into the implicit default class, per-tenant
//! completion accounting, and the no-table deployment keeping the
//! single-tenant metric surface untouched.

use multiworld::bench::scenarios::multi_tenant_serve;
use multiworld::config::{ServingConfig, TenantSpec};
use multiworld::launch::InProcCluster;
use multiworld::mwccl::WorldOptions;
use multiworld::serving::controller::ScalingPolicy;
use multiworld::serving::topology::Topology;
use multiworld::serving::{Outcome, RequestGen};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

const BATCH: usize = 4;
const SEQ_LEN: usize = 8;
const VOCAB: usize = 32;

fn uniq(prefix: &str) -> String {
    static N: AtomicU64 = AtomicU64::new(0);
    format!(
        "ten-{prefix}{}-{}",
        std::process::id() % 1000,
        N.fetch_add(1, Ordering::Relaxed)
    )
}

fn base_port() -> u16 {
    static NEXT: AtomicU64 = AtomicU64::new(0);
    36_000 + (NEXT.fetch_add(1, Ordering::Relaxed) as u16 % 20) * 120
        + (std::process::id() % 97) as u16
}

fn opts() -> WorldOptions {
    WorldOptions::shm().with_init_timeout(Duration::from_secs(120))
}

fn counter(name: &str) -> u64 {
    multiworld::metrics::global().counter(name).get()
}

fn start(name: &str, tenants: Vec<TenantSpec>) -> InProcCluster {
    let topo = Topology::pipeline(&uniq(name), &[1], base_port());
    let cfg = ServingConfig { batch_timeout_ms: 2, tenants, ..Default::default() };
    InProcCluster::start_forward_only(
        topo,
        opts(),
        ScalingPolicy { recover: false, ..Default::default() },
        &cfg,
        BATCH,
        SEQ_LEN,
        VOCAB,
    )
    .expect("cluster start")
}

/// A 10×-share flood from a weight-1 burster must leave the weight-4
/// steady tenant at its solo baseline: zero steady sheds, p99 within
/// 20% (+ a small absolute slack for scheduler noise on a
/// few-millisecond baseline), while the burster demonstrably sheds at
/// its own per-tenant bound. Timing-sensitive on a shared test box, so
/// the tolerance check gets a couple of fresh-deployment retries; the
/// accounting invariants are asserted on every attempt.
#[test]
fn ten_x_flood_leaves_the_steady_tenant_at_its_solo_baseline() {
    let completed0 = counter("serving.completed.tenant.steady");
    let shed0 = counter("serving.rejected.queue_full.tenant.burst");
    const N: usize = 32;
    const ATTEMPTS: usize = 3;
    let mut last = None;
    for attempt in 0..ATTEMPTS {
        let r = multi_tenant_serve(N, opts(), base_port()).expect("multi_tenant_serve");
        // Hard accounting invariants, every attempt: the steady tenant
        // never loses or sheds a request, the burster always overflows
        // its own bound yet still completes at its spare share.
        assert_eq!(r.steady_completed, N, "steady tenant lost requests: {r:?}");
        assert_eq!(r.steady_shed, 0, "the flood leaked into the steady queue: {r:?}");
        assert!(r.burst_shed > 0, "the burster's bound never engaged: {r:?}");
        assert!(r.burst_completed > 0, "the burster was starved outright: {r:?}");
        let limit = r.solo_p99_ms * 1.2 + 3.0;
        if r.steady_p99_ms <= limit {
            last = Some(r);
            break;
        }
        assert!(
            attempt + 1 < ATTEMPTS,
            "steady p99 {:.2} ms above isolation limit {:.2} ms \
             (solo {:.2} ms) on every attempt: {r:?}",
            r.steady_p99_ms,
            limit,
            r.solo_p99_ms
        );
    }
    let r = last.expect("at least one attempt within tolerance");
    // Per-tenant accounting flowed: both phases completed N steady
    // requests each, and every burst shed was counted against the
    // burster (global counters — concurrent tests can only inflate).
    assert!(
        counter("serving.completed.tenant.steady") >= completed0 + 2 * N as u64,
        "per-tenant completion counter must track the steady tenant"
    );
    assert!(
        counter("serving.rejected.queue_full.tenant.burst") >= shed0 + r.burst_shed as u64,
        "per-tenant shed counter must track the burster"
    );
}

/// Requests naming a tenant absent from the table — and untagged
/// requests — fold into the implicit `default` class: they serve
/// normally and account against `serving.completed.tenant.default`.
#[test]
fn unknown_and_untagged_tenants_fold_into_the_default_class() {
    let default0 = counter("serving.completed.tenant.default");
    let cluster = start("fold", vec![TenantSpec { weight: 4, ..TenantSpec::named("gold") }]);
    let mut gen = RequestGen::new(0xF01D, SEQ_LEN, VOCAB, None);
    let deadline = Instant::now() + Duration::from_secs(60);
    let mut handles = Vec::new();
    for i in 0..8 {
        let (req, _) = gen.next();
        handles.push(match i % 2 {
            0 => cluster.leader.submit(req.with_tenant("mystery")),
            _ => cluster.leader.submit(req),
        });
    }
    for h in &handles {
        match h.wait_deadline(deadline) {
            Some(Outcome::Response(_)) => {}
            other => panic!("folded request did not complete: {other:?}"),
        }
    }
    assert!(
        counter("serving.completed.tenant.default") >= default0 + 8,
        "unknown + untagged requests must account to the default class"
    );
    cluster.shutdown();
}

/// A deployment with no tenant table is the single-tenant runtime:
/// requests serve exactly as before and **no** per-tenant accounting
/// happens — the labelled counters never move, keeping the metric
/// surface byte-identical to the pre-tenancy runtime.
#[test]
fn no_tenant_table_keeps_the_single_tenant_metric_surface() {
    // The probe tenant name is unique to this test, so the assertion
    // can't race the other tenancy tests on the process-global
    // registry (unlike `...tenant.default`, which the fold test bumps).
    let cluster = start("plain", Vec::new());
    let mut gen = RequestGen::new(0x91A1, SEQ_LEN, VOCAB, None);
    let deadline = Instant::now() + Duration::from_secs(60);
    let mut handles = Vec::new();
    for _ in 0..8 {
        let (req, _) = gen.next();
        handles.push(cluster.leader.submit(req.with_tenant("tableless_probe")));
    }
    for h in &handles {
        match h.wait_deadline(deadline) {
            Some(Outcome::Response(_)) => {}
            other => panic!("request did not complete: {other:?}"),
        }
    }
    assert_eq!(
        counter("serving.completed.tenant.tableless_probe"),
        0,
        "a table-less deployment must not account per-tenant, even for tagged requests"
    );
    cluster.shutdown();
}
