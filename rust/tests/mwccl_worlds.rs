//! Integration tests for the CCL substrate: rendezvous, the eight
//! collectives over both transports, NCCL-faithful failure semantics,
//! and the single-fault-domain contract.

use multiworld::mwccl::{CclError, Rendezvous, ReduceOp, TransportKind, WorldOptions, World};
use multiworld::tensor::Tensor;
use multiworld::util::prng::Rng;
use std::sync::Arc;
use std::time::Duration;

fn uniq(name: &str) -> String {
    use std::sync::atomic::{AtomicU64, Ordering};
    static N: AtomicU64 = AtomicU64::new(0);
    format!(
        "{name}-{}-{}",
        std::process::id(),
        N.fetch_add(1, Ordering::Relaxed)
    )
}

fn both_transports() -> Vec<(&'static str, WorldOptions)> {
    vec![
        ("shm", WorldOptions::shm()),
        ("tcp", WorldOptions::tcp()),
    ]
}

#[test]
fn p2p_send_recv_roundtrip() {
    for (label, opts) in both_transports() {
        let worlds = Rendezvous::single_process(&uniq("p2p"), 2, opts).unwrap();
        let (w0, w1) = (worlds[0].clone(), worlds[1].clone());
        let mut rng = Rng::new(1);
        let t = Tensor::rand_f32(&[64, 32], &mut rng);
        let csum = t.checksum();
        let sender = std::thread::spawn(move || w1.send(t, 0, 7).unwrap());
        let got = w0.recv(1, 7).unwrap();
        sender.join().unwrap();
        assert_eq!(got.checksum(), csum, "transport {label}");
        assert_eq!(got.shape(), &[64, 32]);
    }
}

#[test]
fn isend_irecv_are_nonblocking() {
    let worlds = Rendezvous::single_process(&uniq("async"), 2, WorldOptions::shm()).unwrap();
    let w0 = worlds[0].clone();
    let w1 = worlds[1].clone();
    // Post the recv before the send exists: must not block the caller.
    let recv_work = w0.irecv(1, 3);
    assert!(!recv_work.is_completed());
    let mut rng = Rng::new(2);
    let t = Tensor::f32_1d(1000, &mut rng);
    let send_work = w1.isend(t.clone(), 0, 3);
    send_work.wait().unwrap();
    let got = recv_work.wait().unwrap().unwrap();
    assert_eq!(got.checksum(), t.checksum());
}

#[test]
fn out_of_order_tags_match_correctly() {
    let worlds = Rendezvous::single_process(&uniq("tags"), 2, WorldOptions::shm()).unwrap();
    let (w0, w1) = (worlds[0].clone(), worlds[1].clone());
    let a = Tensor::from_f32(&[1], &[1.0]);
    let b = Tensor::from_f32(&[1], &[2.0]);
    w1.send(a, 0, 100).unwrap();
    w1.send(b, 0, 200).unwrap();
    // Receive in reverse tag order.
    let got_b = w0.recv(1, 200).unwrap();
    let got_a = w0.recv(1, 100).unwrap();
    assert_eq!(got_b.as_f32(), &[2.0]);
    assert_eq!(got_a.as_f32(), &[1.0]);
}

#[test]
fn broadcast_all_sizes() {
    for (label, opts) in both_transports() {
        for size in [2usize, 3, 4] {
            let worlds = Rendezvous::single_process(&uniq("bcast"), size, opts.clone()).unwrap();
            let mut rng = Rng::new(9);
            let t = Tensor::rand_f32(&[16], &mut rng);
            let csum = t.checksum();
            let handles: Vec<_> = worlds
                .into_iter()
                .map(|w| {
                    let t = if w.rank() == 0 { Some(t.clone()) } else { None };
                    std::thread::spawn(move || w.broadcast(t, 0).unwrap())
                })
                .collect();
            for h in handles {
                assert_eq!(h.join().unwrap().checksum(), csum, "{label} size={size}");
            }
        }
    }
}

#[test]
fn all_reduce_sum_and_avg_and_max() {
    let size = 3;
    for (op, expect) in [
        (ReduceOp::Sum, vec![0.0 + 1.0 + 2.0, 3.0 * 10.0 + 0.0 + 1.0 + 2.0]),
        (ReduceOp::Avg, vec![1.0, 11.0]),
        (ReduceOp::Max, vec![2.0, 12.0]),
    ] {
        let worlds = Rendezvous::single_process(&uniq("ar"), size, WorldOptions::shm()).unwrap();
        let handles: Vec<_> = worlds
            .into_iter()
            .map(|w| {
                let r = w.rank() as f32;
                let t = Tensor::from_f32(&[2], &[r, 10.0 + r]);
                std::thread::spawn(move || w.all_reduce(t, op).unwrap())
            })
            .collect();
        for h in handles {
            let got = h.join().unwrap();
            assert_eq!(got.as_f32(), expect.as_slice(), "{op:?}");
        }
    }
}

#[test]
fn reduce_only_root_gets_result() {
    let worlds = Rendezvous::single_process(&uniq("red"), 3, WorldOptions::shm()).unwrap();
    let handles: Vec<_> = worlds
        .into_iter()
        .map(|w| {
            let t = Tensor::from_f32(&[1], &[w.rank() as f32 + 1.0]);
            std::thread::spawn(move || (w.rank(), w.reduce(t, 1, ReduceOp::Sum).unwrap()))
        })
        .collect();
    for h in handles {
        let (rank, res) = h.join().unwrap();
        if rank == 1 {
            assert_eq!(res.unwrap().as_f32(), &[6.0]);
        } else {
            assert!(res.is_none());
        }
    }
}

#[test]
fn gather_concatenates_in_rank_order() {
    let worlds = Rendezvous::single_process(&uniq("gat"), 3, WorldOptions::tcp()).unwrap();
    let handles: Vec<_> = worlds
        .into_iter()
        .map(|w| {
            let r = w.rank() as f32;
            let t = Tensor::from_f32(&[1, 2], &[r, r * 10.0]);
            std::thread::spawn(move || (w.rank(), w.gather(t, 0).unwrap()))
        })
        .collect();
    for h in handles {
        let (rank, res) = h.join().unwrap();
        if rank == 0 {
            let t = res.unwrap();
            assert_eq!(t.shape(), &[3, 2]);
            assert_eq!(t.as_f32(), &[0.0, 0.0, 1.0, 10.0, 2.0, 20.0]);
        } else {
            assert!(res.is_none());
        }
    }
}

#[test]
fn all_gather_everyone_gets_concat() {
    let worlds = Rendezvous::single_process(&uniq("ag"), 3, WorldOptions::shm()).unwrap();
    let handles: Vec<_> = worlds
        .into_iter()
        .map(|w| {
            let t = Tensor::from_f32(&[1], &[w.rank() as f32]);
            std::thread::spawn(move || w.all_gather(t).unwrap())
        })
        .collect();
    for h in handles {
        let got = h.join().unwrap();
        assert_eq!(got.as_f32(), &[0.0, 1.0, 2.0]);
    }
}

#[test]
fn scatter_distributes_parts() {
    let worlds = Rendezvous::single_process(&uniq("sc"), 3, WorldOptions::shm()).unwrap();
    let handles: Vec<_> = worlds
        .into_iter()
        .map(|w| {
            let parts = if w.rank() == 0 {
                Some(
                    (0..3)
                        .map(|i| Tensor::from_f32(&[2], &[i as f32, i as f32 + 0.5]))
                        .collect::<Vec<_>>(),
                )
            } else {
                None
            };
            std::thread::spawn(move || (w.rank(), w.scatter(parts, 0).unwrap()))
        })
        .collect();
    for h in handles {
        let (rank, t) = h.join().unwrap();
        assert_eq!(t.as_f32(), &[rank as f32, rank as f32 + 0.5]);
    }
}

#[test]
fn world_of_one_degenerates_gracefully() {
    let worlds = Rendezvous::single_process(&uniq("solo"), 1, WorldOptions::shm()).unwrap();
    let w = &worlds[0];
    let t = Tensor::from_f32(&[2], &[5.0, 6.0]);
    assert_eq!(w.broadcast(Some(t.clone()), 0).unwrap().as_f32(), &[5.0, 6.0]);
    assert_eq!(w.all_reduce(t.clone(), ReduceOp::Sum).unwrap().as_f32(), &[5.0, 6.0]);
    assert_eq!(w.all_gather(t.clone()).unwrap().as_f32(), &[5.0, 6.0]);
}

#[test]
fn invalid_usage_is_rejected_without_breaking_world() {
    let worlds = Rendezvous::single_process(&uniq("bad"), 2, WorldOptions::shm()).unwrap();
    let w0 = &worlds[0];
    let t = Tensor::from_f32(&[1], &[0.0]);
    // Send to self.
    assert!(matches!(
        w0.isend(t.clone(), 0, 1).wait(),
        Err(CclError::InvalidUsage(_))
    ));
    // Rank out of range.
    assert!(matches!(
        w0.isend(t.clone(), 5, 1).wait(),
        Err(CclError::InvalidUsage(_))
    ));
    // World still healthy afterwards.
    assert!(!w0.is_broken());
    let w1 = worlds[1].clone();
    let sender = std::thread::spawn(move || w1.send(Tensor::from_f32(&[1], &[3.0]), 0, 9).unwrap());
    assert_eq!(w0.recv(1, 9).unwrap().as_f32(), &[3.0]);
    sender.join().unwrap();
}

// ---------------------------------------------------------------- failure

#[test]
fn tcp_peer_death_breaks_world_with_remote_error() {
    let worlds = Rendezvous::single_process(&uniq("die-tcp"), 2, WorldOptions::tcp()).unwrap();
    let w0 = worlds[0].clone();
    let w1 = worlds.into_iter().nth(1).unwrap();
    let pending = w0.irecv(1, 1);
    // Kill the peer (dropping the World closes its sockets — same signal
    // the kernel gives when the process dies).
    drop(w1);
    let err = pending.wait().unwrap_err();
    assert!(
        matches!(err, CclError::RemoteError { .. } | CclError::Aborted(_)),
        "got {err:?}"
    );
    // The world is now broken: subsequent ops fail fast.
    std::thread::sleep(Duration::from_millis(50));
    assert!(w0.is_broken());
    let again = w0.irecv(1, 2).wait().unwrap_err();
    assert!(matches!(again, CclError::WorldBroken(_)), "got {again:?}");
}

#[test]
fn shm_peer_death_is_silent_until_aborted() {
    // The NCCL-over-shared-memory gap (§3.2): peer death raises nothing.
    let worlds = Rendezvous::single_process(&uniq("die-shm"), 2, WorldOptions::shm()).unwrap();
    let w0 = worlds[0].clone();
    let w1 = worlds.into_iter().nth(1).unwrap();
    let pending = w0.irecv(1, 1);
    drop(w1); // peer vanishes
    assert!(
        pending.wait_timeout(Duration::from_millis(300)).is_none(),
        "shm recv must hang silently after peer death"
    );
    assert!(!w0.is_broken(), "no error may be raised on the shm path");
    // The watchdog's remedy: abort the world locally.
    w0.abort("watchdog: missed heartbeats");
    let err = pending.wait().unwrap_err();
    assert!(matches!(err, CclError::Aborted(_) | CclError::WorldBroken(_)));
    assert!(w0.is_broken());
}

#[test]
fn fault_domain_isolation_two_worlds() {
    // Leader belongs to two worlds (the MultiWorld premise). Killing the
    // peer of world B must not disturb world A.
    let mut wa = Rendezvous::single_process(&uniq("iso-a"), 2, WorldOptions::tcp()).unwrap();
    let mut wb = Rendezvous::single_process(&uniq("iso-b"), 2, WorldOptions::tcp()).unwrap();
    let a1 = wa.pop().unwrap();
    let a0 = wa.pop().unwrap();
    let b1 = wb.pop().unwrap();
    let b0 = wb.pop().unwrap();
    // Kill B's worker.
    drop(b1);
    std::thread::sleep(Duration::from_millis(50));
    let _ = b0.irecv(1, 1).wait(); // drives B into broken state
    assert!(b0.is_broken());
    // A is untouched: traffic still flows.
    assert!(!a0.is_broken());
    let sender = std::thread::spawn(move || {
        a1.send(Tensor::from_f32(&[1], &[42.0]), 0, 5).unwrap();
    });
    assert_eq!(a0.recv(1, 5).unwrap().as_f32(), &[42.0]);
    sender.join().unwrap();
}

#[test]
fn work_handles_surface_broken_world_to_all_waiters() {
    let worlds = Rendezvous::single_process(&uniq("multi-wait"), 2, WorldOptions::shm()).unwrap();
    let w0 = worlds[0].clone();
    let pendings: Vec<_> = (0..4).map(|i| w0.irecv(1, i)).collect();
    w0.abort("test abort");
    for p in pendings {
        assert!(p.wait().is_err());
    }
}

#[test]
fn rate_limited_world_caps_throughput() {
    use multiworld::mwccl::transport::ratelimit::RateLimiter;
    let limiter = Arc::new(RateLimiter::new(50.0e6)); // 50 MB/s
    let opts = WorldOptions::tcp_limited(limiter);
    let worlds = Rendezvous::single_process(&uniq("rate"), 2, opts).unwrap();
    let (w0, w1) = (worlds[0].clone(), worlds[1].clone());
    let mut rng = Rng::new(4);
    let t = Tensor::f32_1d(500_000, &mut rng); // 2 MB
    let t0 = std::time::Instant::now();
    let sender = std::thread::spawn(move || w1.send(t, 0, 1).unwrap());
    let got = w0.recv(1, 1).unwrap();
    sender.join().unwrap();
    let dt = t0.elapsed().as_secs_f64();
    assert_eq!(got.byte_len(), 2_000_000);
    assert!(dt > 0.025, "2MB at 50MB/s should take ≥~35ms, took {dt}s");
}

#[test]
fn many_concurrent_worlds_one_process() {
    // A process can be a member of many worlds at once — the property
    // MultiWorld builds on. 6 worlds, all moving traffic concurrently.
    let mut handles = Vec::new();
    for i in 0..6 {
        let worlds =
            Rendezvous::single_process(&uniq(&format!("multi{i}")), 2, WorldOptions::shm())
                .unwrap();
        let (w0, w1) = (worlds[0].clone(), worlds[1].clone());
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(i as u64);
            for k in 0..20u64 {
                let t = Tensor::f32_1d(1000, &mut rng);
                let c = t.checksum();
                let send = w1.isend(t, 0, k);
                let got = w0.recv(1, k).unwrap();
                send.wait().unwrap();
                assert_eq!(got.checksum(), c);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
}

#[test]
fn worlds_are_static_no_late_joiners() {
    // CCL contract: a 2-rank world cannot accept rank 2 — init with an
    // out-of-range rank fails immediately.
    let port = multiworld::util::free_port();
    let addr: std::net::SocketAddr = format!("127.0.0.1:{port}").parse().unwrap();
    let err = World::init(&uniq("static"), 2, 2, addr, WorldOptions::shm()).unwrap_err();
    assert!(matches!(err, CclError::InvalidUsage(_)));
}

#[test]
fn collective_sequence_interleaving() {
    // Multiple different collectives back-to-back keep their ordering.
    let worlds = Rendezvous::single_process(&uniq("seq"), 2, WorldOptions::shm()).unwrap();
    let handles: Vec<_> = worlds
        .into_iter()
        .map(|w| {
            std::thread::spawn(move || {
                let r = w.rank() as f32;
                let b = w
                    .broadcast(if w.rank() == 0 { Some(Tensor::from_f32(&[1], &[7.0])) } else { None }, 0)
                    .unwrap();
                let s = w.all_reduce(Tensor::from_f32(&[1], &[r + 1.0]), ReduceOp::Sum).unwrap();
                let g = w.all_gather(Tensor::from_f32(&[1], &[r])).unwrap();
                (b, s, g)
            })
        })
        .collect();
    for h in handles {
        let (b, s, g) = h.join().unwrap();
        assert_eq!(b.as_f32(), &[7.0]);
        assert_eq!(s.as_f32(), &[3.0]);
        assert_eq!(g.as_f32(), &[0.0, 1.0]);
    }
}

#[test]
fn transport_kind_debug_labels() {
    let t = TransportKind::Shm { ring_bytes: 1024 };
    assert!(format!("{t:?}").contains("Shm"));
}
