//! Closed-loop autoscaling end to end: forward-only serving pipelines
//! under **open-loop** traffic submitted through the always-on
//! `Leader::submit` ingress, with the cluster's `Autoscaler` making
//! real decisions from live queue-depth signals — no hand-fed depths
//! anywhere. No PJRT, no artifacts: these tests run in the default CI
//! build and under the `MW_COLL_ALGO={flat,ring,auto}` matrix like the
//! tier-1 suite.
//!
//! Covered: a burst that drives exactly one `ScaledOut` (fresh replica
//! verified to take traffic via router dispatch counts and the
//! `serving.autoscale.*` counters) followed by an idle period that
//! drives exactly one graceful `ScaledIn` with zero request loss; a
//! replica killed under live traffic composing recovery with
//! autoscaler-driven scale-out in the same run; bounded-admission load
//! shedding; and SLO-deadline drops happening before dispatch.

use multiworld::config::ServingConfig;
use multiworld::launch::InProcCluster;
use multiworld::mwccl::WorldOptions;
use multiworld::serving::autoscaler::AutoscalePolicy;
use multiworld::serving::controller::{Action, ScalingPolicy};
use multiworld::serving::topology::{NodeId, Topology};
use multiworld::serving::{Outcome, RejectReason, RequestGen};
use std::collections::HashSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Serialize cluster tests (they spawn many threads and fixed-range
/// store ports).
static SERIAL: Mutex<()> = Mutex::new(());

const BATCH: usize = 4;
const SEQ_LEN: usize = 8;
const VOCAB: usize = 32;

fn uniq(prefix: &str) -> String {
    static N: AtomicU64 = AtomicU64::new(0);
    format!(
        "{prefix}{}-{}",
        std::process::id() % 1000,
        N.fetch_add(1, Ordering::Relaxed)
    )
}

fn base_port() -> u16 {
    static NEXT: AtomicU64 = AtomicU64::new(0);
    52_000 + (NEXT.fetch_add(1, Ordering::Relaxed) as u16 % 20) * 120
        + (std::process::id() % 97) as u16
}

fn counter(name: &str) -> u64 {
    multiworld::metrics::global().counter(name).get()
}

fn scaled_out_count(cluster: &InProcCluster) -> usize {
    cluster
        .controller
        .actions()
        .iter()
        .filter(|a| matches!(a, Action::ScaledOut { .. }))
        .count()
}

fn scaled_in_count(cluster: &InProcCluster) -> usize {
    cluster
        .controller
        .actions()
        .iter()
        .filter(|a| matches!(a, Action::ScaledIn { .. }))
        .count()
}

#[test]
fn burst_scales_out_and_idle_scales_in_with_zero_loss() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let out_before = counter("serving.autoscale.out");
    let in_before = counter("serving.autoscale.in");
    let topo = Topology::pipeline(&uniq("asb"), &[1], base_port());
    // Nothing is killed in this test: a relaxed watchdog keeps a loaded
    // CI box from spuriously breaking worlds under the burst.
    let cfg = ServingConfig {
        heartbeat_ms: 100,
        miss_threshold: 5,
        batch_timeout_ms: 3,
        ..Default::default()
    };
    let cluster = InProcCluster::start_forward_only(
        topo,
        WorldOptions::shm().with_init_timeout(Duration::from_secs(120)),
        ScalingPolicy { scale_up_depth: 8.0, max_replicas: 2, recover: true },
        &cfg,
        BATCH,
        SEQ_LEN,
        VOCAB,
    )
    .unwrap();
    let edges_before: HashSet<String> =
        cluster.leader.dispatch_counts().keys().cloned().collect();
    // high_samples: 1 — forward-only workers drain the queue within
    // milliseconds, so requiring *consecutive* deep samples would race
    // the sampling clock against the drain (the hysteresis logic itself
    // is covered by the autoscaler unit tests). One caught deep sample
    // is the deterministic e2e trigger; the ceiling and cooldown still
    // bound the reaction to exactly one scale-out.
    cluster.start_autoscaler(AutoscalePolicy {
        stage: 0,
        interval: Duration::from_millis(15),
        cooldown: Duration::from_millis(300),
        high_depth: 8.0,
        slo_p99_ms: 0.0,
        slo_ttft_ms: 0.0,
        high_samples: 1,
        low_samples: 6,
        min_replicas: 1,
        drain_timeout: Duration::from_secs(5),
    });

    let mut gen = RequestGen::new(0xA11, SEQ_LEN, VOCAB, None);
    let mut handles = Vec::new();
    // Hard burst: queue depth jumps far past the threshold; keep
    // re-bursting until a sampling tick catches the pressure.
    let deadline = Instant::now() + Duration::from_secs(30);
    while scaled_out_count(&cluster) == 0 {
        assert!(
            Instant::now() < deadline,
            "autoscaler never scaled out; actions: {:?}",
            cluster.controller.actions()
        );
        for r in gen.take(100) {
            handles.push(cluster.leader.submit(r));
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    // The fresh replica serves traffic: a new in-edge appears in the
    // leader's router and its dispatch count grows. Heavy pressure here
    // also keeps the loop busy enough that no idle streak can retire
    // the fresh replica before it proves itself.
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let counts = cluster.leader.dispatch_counts();
        if counts.iter().any(|(e, &c)| !edges_before.contains(e) && c > 0) {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "fresh replica took no traffic: {counts:?}"
        );
        for r in gen.take(100) {
            handles.push(cluster.leader.submit(r));
        }
        std::thread::sleep(Duration::from_millis(5));
    }

    // Zero request loss: every submitted request resolves to a response
    // (no SLO, unbounded admission — nothing may shed or drop).
    for h in &handles {
        match h.wait_deadline(Instant::now() + Duration::from_secs(60)) {
            Some(Outcome::Response(_)) => {}
            other => panic!("request {} lost: {other:?}", h.id()),
        }
    }

    // Idle now: the autoscaler drains and retires the fresh replica.
    let deadline = Instant::now() + Duration::from_secs(30);
    while scaled_in_count(&cluster) == 0 {
        assert!(
            Instant::now() < deadline,
            "autoscaler never scaled in; actions: {:?}",
            cluster.controller.actions()
        );
        std::thread::sleep(Duration::from_millis(20));
    }
    // Exactly one of each: the ceiling (2 replicas), the floor (1
    // replica) and the cooldown forbid any flapping.
    assert_eq!(scaled_out_count(&cluster), 1, "{:?}", cluster.controller.actions());
    assert_eq!(scaled_in_count(&cluster), 1, "{:?}", cluster.controller.actions());
    assert_eq!(counter("serving.autoscale.out") - out_before, 1);
    assert_eq!(counter("serving.autoscale.in") - in_before, 1);

    // The retired worker's thread exits and is reaped.
    let deadline = Instant::now() + Duration::from_secs(30);
    while cluster.live_workers().len() != 1 {
        assert!(
            Instant::now() < deadline,
            "retired worker never exited: {:?}",
            cluster.live_workers()
        );
        std::thread::sleep(Duration::from_millis(20));
    }
    cluster.shutdown();
}

#[test]
fn replica_kill_recovery_and_scale_out_compose_under_live_traffic() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let topo = Topology::pipeline(&uniq("asc"), &[2], base_port());
    let cfg = ServingConfig {
        heartbeat_ms: 50,
        miss_threshold: 3,
        batch_timeout_ms: 3,
        retry_timeout_ms: 300,
        ..Default::default()
    };
    let cluster = InProcCluster::start_forward_only(
        topo,
        // TCP: failures are detectable without waiting out the watchdog.
        WorldOptions::tcp().with_init_timeout(Duration::from_secs(120)),
        ScalingPolicy { scale_up_depth: 8.0, max_replicas: 4, recover: true },
        &cfg,
        BATCH,
        SEQ_LEN,
        VOCAB,
    )
    .unwrap();
    // high_samples: 1 for a deterministic trigger (see the burst test).
    cluster.start_autoscaler(AutoscalePolicy {
        stage: 0,
        interval: Duration::from_millis(15),
        cooldown: Duration::from_millis(300),
        high_depth: 8.0,
        slo_p99_ms: 0.0,
        slo_ttft_ms: 0.0,
        high_samples: 1,
        low_samples: 100_000, // never scale in during this test
        min_replicas: 1,
        drain_timeout: Duration::from_secs(5),
    });

    let victim = NodeId::worker(0, 1);
    let mut gen = RequestGen::new(0xC4A05, SEQ_LEN, VOCAB, None);
    let mut handles = Vec::new();
    for r in gen.take(200) {
        handles.push(cluster.leader.submit(r));
    }
    assert!(cluster.kill(victim), "victim must be alive to kill");
    // Keep traffic flowing through the chaos until the controller has
    // both recovered the victim and scaled out on the load.
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        let actions = cluster.controller.actions();
        let recovered = actions
            .iter()
            .any(|a| matches!(a, Action::Recovered { dead, .. } if *dead == victim));
        let scaled = actions.iter().any(|a| matches!(a, Action::ScaledOut { .. }));
        if recovered && scaled {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "wanted Recovered({victim}) + ScaledOut, got: {actions:?}"
        );
        for r in gen.take(50) {
            handles.push(cluster.leader.submit(r));
        }
        std::thread::sleep(Duration::from_millis(5));
    }

    // Zero request loss through kill + recovery + scale-out.
    for h in &handles {
        match h.wait_deadline(Instant::now() + Duration::from_secs(90)) {
            Some(Outcome::Response(_)) => {}
            other => panic!("request {} lost: {other:?}", h.id()),
        }
    }
    cluster.shutdown();
}

#[test]
fn bounded_admission_sheds_load_instead_of_queueing() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let shed_before = counter("serving.rejected.queue_full");
    let topo = Topology::pipeline(&uniq("ashed"), &[1], base_port());
    let cfg = ServingConfig {
        heartbeat_ms: 100,
        miss_threshold: 5,
        batch_timeout_ms: 3,
        admission_depth: 2,
        ..Default::default()
    };
    let cluster = InProcCluster::start_forward_only(
        topo,
        WorldOptions::shm().with_init_timeout(Duration::from_secs(120)),
        ScalingPolicy { recover: false, ..Default::default() },
        &cfg,
        BATCH,
        SEQ_LEN,
        VOCAB,
    )
    .unwrap();
    let mut gen = RequestGen::new(0x5ED, SEQ_LEN, VOCAB, None);
    let handles: Vec<_> = gen
        .take(256)
        .into_iter()
        .map(|r| cluster.leader.submit(r))
        .collect();
    let (mut ok, mut shed) = (0usize, 0usize);
    for h in &handles {
        match h.wait_deadline(Instant::now() + Duration::from_secs(60)) {
            Some(Outcome::Response(_)) => ok += 1,
            Some(Outcome::Rejected(RejectReason::QueueFull)) => shed += 1,
            other => panic!("request {}: unexpected outcome {other:?}", h.id()),
        }
    }
    assert_eq!(ok + shed, 256, "every request resolves");
    assert!(ok > 0, "admitted requests complete");
    assert!(shed > 0, "a 2-deep queue must shed an instant 256-burst");
    assert!(counter("serving.rejected.queue_full") > shed_before);
    cluster.shutdown();
}

#[test]
fn slo_expired_requests_drop_before_dispatch() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let dropped_before = counter("serving.dropped.deadline");
    let topo = Topology::pipeline(&uniq("aslo"), &[1], base_port());
    let cfg = ServingConfig {
        heartbeat_ms: 100,
        miss_threshold: 5,
        batch_timeout_ms: 3,
        slo_ms: 2, // far tighter than a 1000-request queue can honor
        ..Default::default()
    };
    let cluster = InProcCluster::start_forward_only(
        topo,
        WorldOptions::shm().with_init_timeout(Duration::from_secs(120)),
        ScalingPolicy { recover: false, ..Default::default() },
        &cfg,
        BATCH,
        SEQ_LEN,
        VOCAB,
    )
    .unwrap();
    let mut gen = RequestGen::new(0x51_0, SEQ_LEN, VOCAB, None);
    let handles: Vec<_> = gen
        .take(1_000)
        .into_iter()
        .map(|r| cluster.leader.submit(r))
        .collect();
    let (mut ok, mut deadline_drops) = (0usize, 0usize);
    for h in &handles {
        match h.wait_deadline(Instant::now() + Duration::from_secs(60)) {
            Some(Outcome::Response(_)) => ok += 1,
            Some(Outcome::Dropped(_)) => deadline_drops += 1,
            other => panic!("request {}: unexpected outcome {other:?}", h.id()),
        }
    }
    assert_eq!(ok + deadline_drops, 1_000, "every request resolves");
    assert!(
        deadline_drops > 0,
        "a 2 ms SLO must expire most of a 1000-deep queue"
    );
    assert!(
        counter("serving.dropped.deadline") > dropped_before,
        "queue-head expiry is counted"
    );
    cluster.shutdown();
}
