//! Integration tests for the MultiWorld layer: multi-world membership,
//! watchdog-driven fault handling on the silent shm path, remote-error
//! handling on the tcp path, online instantiation, and event delivery.
//!
//! These tests recreate the paper's Figure 2 scenarios in-process: the
//! transports and stores are the real ones (sockets + mmap rings); only
//! process boundaries are collapsed to threads (the kill signal a peer
//! sees — closed socket / silent ring — is identical).

use multiworld::multiworld::{MwError, PollStrategy, WatchdogConfig, WorldEvent, WorldManager};
use multiworld::multiworld::state::StatePolicy;
use multiworld::mwccl::{Rendezvous, WorldOptions};
use multiworld::tensor::Tensor;
use multiworld::util::prng::Rng;
use multiworld::util::time::Clock;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

fn uniq(name: &str) -> String {
    static N: AtomicU64 = AtomicU64::new(0);
    format!(
        "{name}-{}-{}",
        std::process::id(),
        N.fetch_add(1, Ordering::Relaxed)
    )
}

fn fast_wd() -> WatchdogConfig {
    WatchdogConfig { heartbeat: Duration::from_millis(40), miss_threshold: 3 }
}

#[test]
fn manager_lifecycle_and_events() {
    let mgr = WorldManager::new();
    let events = mgr.subscribe();
    let name = uniq("life");
    let worlds = Rendezvous::single_process(&name, 2, WorldOptions::shm()).unwrap();
    let mut it = worlds.into_iter();
    mgr.adopt(it.next().unwrap()).unwrap();
    assert_eq!(mgr.world_names(), vec![name.clone()]);
    assert_eq!(events.try_recv().unwrap(), WorldEvent::Added(name.clone()));
    // Double-adopt rejected.
    let dup = Rendezvous::single_process(&name, 1, WorldOptions::shm());
    // (same name, fresh world object)
    if let Ok(mut d) = dup {
        assert!(matches!(mgr.adopt(d.remove(0)), Err(MwError::AlreadyExists(_))));
    }
    mgr.remove_world(&name).unwrap();
    assert!(mgr.world_names().is_empty());
    assert_eq!(events.try_recv().unwrap(), WorldEvent::Removed(name.clone()));
    assert!(matches!(
        mgr.remove_world(&name),
        Err(MwError::UnknownWorld(_))
    ));
}

#[test]
fn communicator_moves_tensors_across_two_worlds() {
    // One "leader" thread member of two worlds (the Fig. 2 rhombus edge
    // pattern), receiving from both in arbitrary order.
    let mgr = WorldManager::new();
    let comm = mgr.communicator().with_strategy(PollStrategy::SpinYield);
    let wa = uniq("wa");
    let wb = uniq("wb");
    let a = Rendezvous::single_process(&wa, 2, WorldOptions::shm()).unwrap();
    let b = Rendezvous::single_process(&wb, 2, WorldOptions::shm()).unwrap();
    let mut a = a.into_iter();
    let mut b = b.into_iter();
    mgr.adopt(a.next().unwrap()).unwrap();
    mgr.adopt(b.next().unwrap()).unwrap();
    let a1 = a.next().unwrap();
    let b1 = b.next().unwrap();

    // Workers send on their own schedule.
    let mut rng = Rng::new(5);
    let ta = Tensor::rand_f32(&[256], &mut rng);
    let tb = Tensor::rand_f32(&[512], &mut rng);
    let (ca, cb) = (ta.checksum(), tb.checksum());
    let ha = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(30));
        a1.send(ta, 0, 1).unwrap();
        a1
    });
    let hb = std::thread::spawn(move || {
        b1.send(tb, 0, 1).unwrap();
        b1
    });

    // Leader: post both receives, harvest in completion order.
    let ra = comm.recv(&wa, 1, 1).unwrap();
    let rb = comm.recv(&wb, 1, 1).unwrap();
    let works = vec![ra, rb];
    let first = comm.wait_any(&works).unwrap();
    let results = comm.wait_all(&works);
    let got_a = results[0].as_ref().unwrap().clone().unwrap();
    let got_b = results[1].as_ref().unwrap().clone().unwrap();
    assert_eq!(got_a.checksum(), ca);
    assert_eq!(got_b.checksum(), cb);
    // b sent immediately, a after 30 ms — b should usually complete first,
    // but ordering is not guaranteed; just check the index is valid.
    assert!(first < 2);
    ha.join().unwrap();
    hb.join().unwrap();
}

#[test]
fn watchdog_breaks_silent_shm_world_and_isolates_the_other() {
    // THE paper scenario (Fig. 2b): P3 dies; worlds containing P3 break;
    // the world not containing it keeps working.
    let mgr = WorldManager::with_options(StatePolicy::Kv, fast_wd(), Clock::system());
    let events = mgr.subscribe();
    let comm = mgr.communicator();
    let w_live = uniq("live");
    let w_dead = uniq("dead");
    let live = Rendezvous::single_process(&w_live, 2, WorldOptions::shm()).unwrap();
    let dead = Rendezvous::single_process(&w_dead, 2, WorldOptions::shm()).unwrap();
    let mut live = live.into_iter();
    let mut dead = dead.into_iter();
    mgr.adopt(live.next().unwrap()).unwrap();
    mgr.adopt(dead.next().unwrap()).unwrap();
    let live_peer = live.next().unwrap();
    let dead_peer = dead.next().unwrap();

    // The live peer heartbeats (simulating its own watchdog) and serves
    // traffic; the dead peer never heartbeats and "dies" silently.
    drop(dead_peer);
    let live_store = live_peer.store().unwrap();
    let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    let stop2 = stop.clone();
    let w_live2 = w_live.clone();
    let hb = std::thread::spawn(move || {
        while !stop2.load(Ordering::Relaxed) {
            let now = multiworld::util::time::unix_millis();
            let _ = live_store.set(&format!("mw/{w_live2}/hb/1"), now.to_string().as_bytes());
            std::thread::sleep(Duration::from_millis(20));
        }
        live_peer
    });

    // Post a recv on the dead world — it hangs silently (shm path).
    let pending = comm.recv(&w_dead, 1, 9).unwrap();
    assert!(pending.wait_timeout(Duration::from_millis(100)).is_none());

    // The watchdog (40 ms × 3) fires and the manager cleans up (skip the
    // Added events from adoption).
    loop {
        match events.recv_timeout(Duration::from_secs(5)).unwrap() {
            WorldEvent::Broken { world, reason, culprit } => {
                assert_eq!(world, w_dead);
                assert!(reason.contains("missed heartbeats"), "{reason}");
                assert_eq!(culprit, Some(1), "watchdog attributes the dead rank");
                break;
            }
            WorldEvent::Added(_) => continue,
            other => panic!("expected Broken, got {other:?}"),
        }
    }
    // Pending op was aborted with an exception the app can handle.
    let err = pending.wait().unwrap_err();
    assert!(err.is_fatal_to_world());
    // Ops on the dead world now fail fast with Broken.
    assert!(matches!(
        comm.recv(&w_dead, 1, 10),
        Err(MwError::Broken(..)) | Err(MwError::UnknownWorld(_))
    ));

    // The live world is untouched: move a tensor through it.
    let live_peer = {
        stop.store(true, Ordering::Relaxed);
        hb.join().unwrap()
    };
    let t = Tensor::from_f32(&[3], &[1.0, 2.0, 3.0]);
    let c = t.checksum();
    let send = std::thread::spawn(move || live_peer.send(t, 0, 2).unwrap());
    let got = comm.recv_blocking(&w_live, 1, 2).unwrap();
    assert_eq!(got.checksum(), c);
    send.join().unwrap();
    assert_eq!(mgr.world_names(), vec![w_live]);
}

#[test]
fn tcp_remote_error_guides_world_to_quarantine() {
    let mgr = WorldManager::with_options(StatePolicy::Kv, fast_wd(), Clock::system());
    let comm = mgr.communicator();
    let name = uniq("tcpdeath");
    let worlds = Rendezvous::single_process(&name, 2, WorldOptions::tcp()).unwrap();
    let mut it = worlds.into_iter();
    mgr.adopt(it.next().unwrap()).unwrap();
    let peer = it.next().unwrap();
    drop(peer); // socket closes -> RemoteError on the leader's link
    let err = comm.recv_blocking(&name, 1, 1).unwrap_err();
    match err {
        MwError::Ccl(e) => assert!(e.is_fatal_to_world(), "{e:?}"),
        other => panic!("unexpected {other:?}"),
    }
    // recv_blocking routed the failure through break_world.
    assert!(matches!(
        comm.recv(&name, 1, 2),
        Err(MwError::Broken(..))
    ));
    assert!(mgr.world_names().is_empty());
}

#[test]
fn online_instantiation_adds_world_without_stalling_existing() {
    // Fig. 5's property: while the leader waits for W2's joiner, W1
    // traffic keeps flowing (async init on a separate thread).
    let mgr = WorldManager::with_options(StatePolicy::Kv, fast_wd(), Clock::system());
    let comm = mgr.communicator();
    let w1 = uniq("w1");
    let w2 = uniq("w2");
    let worlds = Rendezvous::single_process(&w1, 2, WorldOptions::shm()).unwrap();
    let mut it = worlds.into_iter();
    mgr.adopt(it.next().unwrap()).unwrap();
    let w1_peer = it.next().unwrap();

    // Kick off W2 init; its peer arrives only after a delay.
    let port = multiworld::util::free_port();
    let addr: std::net::SocketAddr = format!("127.0.0.1:{port}").parse().unwrap();
    let init = mgr.initialize_world_async(&w2, 0, 2, addr, WorldOptions::shm());
    assert!(!init.is_done());

    // W1 traffic during the wait — must not block.
    let w1_name = w1.clone();
    let sender = std::thread::spawn(move || {
        for k in 0..20u64 {
            w1_peer.send(Tensor::from_f32(&[4], &[k as f32; 4]), 0, k).unwrap();
        }
        w1_peer
    });
    for k in 0..20u64 {
        let t = comm.recv_blocking(&w1_name, 1, k).unwrap();
        assert_eq!(t.as_f32()[0], k as f32);
    }
    let w1_peer = sender.join().unwrap();
    assert!(!init.is_done(), "W2 joiner hasn't arrived yet");

    // The joiner arrives (paper: 20 ms join).
    let w2_name = w2.clone();
    let joiner = std::thread::spawn(move || {
        multiworld::mwccl::World::init(&w2_name, 1, 2, addr, WorldOptions::shm()).unwrap()
    });
    init.wait().unwrap();
    let w2_peer = joiner.join().unwrap();
    assert_eq!(mgr.world_names().len(), 2);

    // Traffic now flows on both worlds.
    let t = Tensor::from_f32(&[1], &[9.0]);
    let s = std::thread::spawn(move || w2_peer.send(t, 0, 0).unwrap());
    assert_eq!(comm.recv_blocking(&w2, 1, 0).unwrap().as_f32(), &[9.0]);
    s.join().unwrap();
    drop(w1_peer);
}

#[test]
fn swap_policy_functionally_equivalent() {
    // The ablation's premise: swap-based state management is slower but
    // *correct*; results must match kv exactly.
    for policy in [StatePolicy::Kv, StatePolicy::Swap] {
        let mgr = WorldManager::with_options(policy, fast_wd(), Clock::system());
        let comm = mgr.communicator();
        let names: Vec<String> = (0..3).map(|i| uniq(&format!("sp{i}"))).collect();
        let mut peers = Vec::new();
        for n in &names {
            let worlds = Rendezvous::single_process(n, 2, WorldOptions::shm()).unwrap();
            let mut it = worlds.into_iter();
            mgr.adopt(it.next().unwrap()).unwrap();
            peers.push(it.next().unwrap());
        }
        // Round-robin traffic over the three worlds.
        let handles: Vec<_> = peers
            .into_iter()
            .enumerate()
            .map(|(i, p)| {
                std::thread::spawn(move || {
                    for k in 0..10u64 {
                        p.send(Tensor::from_f32(&[1], &[(i * 100 + k as usize) as f32]), 0, k)
                            .unwrap();
                    }
                })
            })
            .collect();
        for k in 0..10u64 {
            for (i, n) in names.iter().enumerate() {
                let t = comm.recv_blocking(n, 1, k).unwrap();
                assert_eq!(t.as_f32(), &[(i * 100 + k as usize) as f32], "{policy:?}");
            }
        }
        for h in handles {
            h.join().unwrap();
        }
    }
}

#[test]
fn node_failure_breaks_all_its_worlds() {
    // "Since node failure can be translated into failures of workers
    // running in the node, MultiWorld can handle node failure as well."
    // One peer thread participates in two worlds; its death breaks both.
    let mgr = WorldManager::with_options(StatePolicy::Kv, fast_wd(), Clock::system());
    let events = mgr.subscribe();
    let n1 = uniq("node1");
    let n2 = uniq("node2");
    let a = Rendezvous::single_process(&n1, 2, WorldOptions::shm()).unwrap();
    let b = Rendezvous::single_process(&n2, 2, WorldOptions::shm()).unwrap();
    let mut a = a.into_iter();
    let mut b = b.into_iter();
    mgr.adopt(a.next().unwrap()).unwrap();
    mgr.adopt(b.next().unwrap()).unwrap();
    // The "node" holds both peers and dies without ever heartbeating.
    let node = (a.next().unwrap(), b.next().unwrap());
    drop(node);
    let mut broken = Vec::new();
    while broken.len() < 2 {
        match events.recv_timeout(Duration::from_secs(5)).unwrap() {
            WorldEvent::Broken { world, .. } => broken.push(world),
            _ => {}
        }
    }
    broken.sort();
    let mut expect = vec![n1, n2];
    expect.sort();
    assert_eq!(broken, expect);
    assert!(mgr.world_names().is_empty());
}
