//! Figure 4 — fault tolerance timeline. A leader receives from two
//! workers; the second worker is killed after its 10th tensor.
//!
//! * **Single world** (left plot): the leader, W1-R1 and W1-R2 share one
//!   world. W1-R2's death breaks it; the leader receives a couple more
//!   tensors already in flight from W1-R1 and then stops entirely.
//! * **MultiWorld** (right plot): W1-R1 and W2-R1 live in separate
//!   worlds. W2's death breaks only W2; W1 traffic continues.
//!
//! Time is scaled 20× vs the paper (sends every 50/100 ms instead of
//! 1/2 s) so the bench finishes in seconds; the *event order* is the
//! reproduced result. Output: a printed event log + CSV timeline.

use multiworld::bench::scenarios::recovery_mttr;
use multiworld::bench::write_csv;
use multiworld::metrics::Timeline;
use multiworld::multiworld::{StatePolicy, WatchdogConfig, WorldManager};
use multiworld::mwccl::{Rendezvous, WorldOptions};
use multiworld::tensor::Tensor;
use multiworld::util::time::since_epoch;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

const PERIOD_FAST: Duration = Duration::from_millis(50); // paper: 1 s
const PERIOD_SLOW: Duration = Duration::from_millis(100); // paper: 2 s
const KILL_AFTER: usize = 10; // paper: terminated after the 10th tensor
const OBSERVE: Duration = Duration::from_secs(3);

fn uniq(p: &str) -> String {
    static N: AtomicU64 = AtomicU64::new(0);
    format!("{p}-{}-{}", std::process::id(), N.fetch_add(1, Ordering::Relaxed))
}

fn sender_loop(world: multiworld::mwccl::World, period: Duration, max: Option<usize>) {
    let mut rng = multiworld::util::prng::Rng::new(world.rank() as u64);
    let t = Tensor::f32_1d(1_000, &mut rng);
    let mut k = 0u64;
    loop {
        if let Some(m) = max {
            if k as usize >= m {
                return; // thread exits; worlds drop = worker death
            }
        }
        if world.send(t.clone(), 0, k).is_err() {
            return;
        }
        k += 1;
        std::thread::sleep(period);
    }
}

/// Single-world run: returns the receive timeline.
fn run_single_world(tl: &Timeline) {
    let worlds =
        Rendezvous::single_process(&uniq("fig4-sw"), 3, WorldOptions::tcp()).unwrap();
    let mut it = worlds.into_iter();
    let leader = it.next().unwrap();
    let w1r1 = it.next().unwrap();
    let w1r2 = it.next().unwrap();
    let s1 = std::thread::spawn(move || sender_loop(w1r1, PERIOD_FAST, None));
    let s2 = std::thread::spawn(move || sender_loop(w1r2, PERIOD_SLOW, Some(KILL_AFTER)));

    let t_end = since_epoch() + OBSERVE.as_secs_f64();
    let mut pending = vec![
        ("W1-R1", 1usize, leader.irecv(1, 0), 1u64),
        ("W1-R2", 2usize, leader.irecv(2, 0), 1u64),
    ];
    while since_epoch() < t_end && !pending.is_empty() {
        let mut i = 0;
        while i < pending.len() {
            if pending[i].2.is_completed() {
                let (series, src, work, next) = pending.swap_remove(i);
                match work.wait() {
                    Ok(_) => {
                        tl.record(&format!("SW/{series}"), 1.0);
                        pending.push((series, src, leader.irecv(src, next), next + 1));
                    }
                    Err(e) => {
                        tl.record_labeled(&format!("SW/{series}"), 0.0, &format!("error: {e}"));
                        // Single fault domain: the world is broken; every
                        // other pending op dies too (observed naturally —
                        // don't repost).
                    }
                }
            } else {
                i += 1;
            }
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    tl.record_labeled("SW/leader", 0.0, "observation end");
    drop(leader);
    let _ = s2.join();
    let _ = s1.join();
}

/// A MultiWorld sender: its own `WorldManager` (watchdog heartbeats and
/// all — every MultiWorld worker runs the full §3.3 stack). Exiting the
/// thread drops the manager: heartbeats stop, sockets close — process
/// death as peers observe it.
fn mw_sender_loop(world: multiworld::mwccl::World, period: Duration, max: Option<usize>) {
    let mgr = WorldManager::with_options(
        StatePolicy::Kv,
        WatchdogConfig { heartbeat: Duration::from_millis(50), miss_threshold: 3 },
        multiworld::util::time::Clock::system(),
    );
    let name = world.name().to_string();
    mgr.adopt(world).unwrap();
    let comm = mgr.communicator();
    let mut rng = multiworld::util::prng::Rng::new(1);
    let t = Tensor::f32_1d(1_000, &mut rng);
    let mut k = 0u64;
    loop {
        if let Some(m) = max {
            if k as usize >= m {
                return;
            }
        }
        if comm.send_blocking(&name, t.clone(), 0, k).is_err() {
            return;
        }
        k += 1;
        std::thread::sleep(period);
    }
}

/// MultiWorld run.
fn run_multiworld(tl: &Timeline) {
    let mgr = WorldManager::with_options(
        StatePolicy::Kv,
        WatchdogConfig { heartbeat: Duration::from_millis(50), miss_threshold: 3 },
        multiworld::util::time::Clock::system(),
    );
    let comm = mgr.communicator();
    let w1 = uniq("fig4-w1");
    let w2 = uniq("fig4-w2");
    let mut peers = Vec::new();
    for name in [&w1, &w2] {
        let worlds = Rendezvous::single_process(name, 2, WorldOptions::tcp()).unwrap();
        let mut it = worlds.into_iter();
        mgr.adopt(it.next().unwrap()).unwrap();
        peers.push(it.next().unwrap());
    }
    let w2_peer = peers.pop().unwrap();
    let w1_peer = peers.pop().unwrap();
    let s1 = std::thread::spawn(move || mw_sender_loop(w1_peer, PERIOD_FAST, None));
    let s2 = std::thread::spawn(move || mw_sender_loop(w2_peer, PERIOD_SLOW, Some(KILL_AFTER)));

    let t_end = since_epoch() + OBSERVE.as_secs_f64();
    let mut pending = vec![
        ("W1-R1", w1.clone(), comm.recv(&w1, 1, 0).unwrap(), 1u64),
        ("W2-R1", w2.clone(), comm.recv(&w2, 1, 0).unwrap(), 1u64),
    ];
    while since_epoch() < t_end && !pending.is_empty() {
        let mut i = 0;
        while i < pending.len() {
            if pending[i].2.is_completed() {
                let (series, world, work, next) = pending.swap_remove(i);
                match work.wait() {
                    Ok(_) => {
                        tl.record(&format!("MW/{series}"), 1.0);
                        if let Ok(w) = comm.recv(&world, 1, next) {
                            pending.push((series, world, w, next + 1));
                        }
                    }
                    Err(e) => {
                        tl.record_labeled(
                            &format!("MW/{series}"),
                            0.0,
                            &format!("world broken: {e}"),
                        );
                        // MultiWorld: only this world is gone; the other
                        // series keeps flowing.
                    }
                }
            } else {
                i += 1;
            }
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    tl.record_labeled("MW/leader", 0.0, "observation end");
    // Tear the leader down first so the unbounded W1 sender observes the
    // closed sockets and exits.
    drop(pending);
    drop(comm);
    drop(mgr);
    let _ = s2.join();
    let _ = s1.join();
}

fn main() {
    let tl = Timeline::new();
    println!("\n=== Fig 4 — fault tolerance (time scaled 20×; kill after 10th tensor) ===");
    run_single_world(&tl);
    run_multiworld(&tl);

    // Summarize: tensors received before/after the failure per series.
    for arch in ["SW", "MW"] {
        let failure_t = tl
            .points()
            .iter()
            .find(|p| p.series.starts_with(arch) && !p.label.is_empty() && p.value == 0.0)
            .map(|p| p.t);
        for series in ["W1-R1", "W1-R2", "W2-R1"] {
            let name = format!("{arch}/{series}");
            let pts = tl.series(&name);
            if pts.is_empty() {
                continue;
            }
            let recvd = pts.iter().filter(|p| p.value > 0.0).count();
            let after = failure_t
                .map(|ft| pts.iter().filter(|p| p.value > 0.0 && p.t > ft).count())
                .unwrap_or(0);
            println!("{name:>10}: {recvd:3} tensors received, {after:3} after the failure");
        }
    }
    println!(
        "paper shape: SW leader stops entirely after W1-R2 dies; MW leader keeps receiving from W1-R1"
    );
    write_csv("fig4_fault_tolerance", &tl.to_csv());

    // Machine-checkable assertions of the reproduced shape.
    let mw_w1: Vec<_> = tl.series("MW/W1-R1");
    let fail_t = tl
        .points()
        .iter()
        .find(|p| p.series == "MW/W2-R1" && p.value == 0.0)
        .map(|p| p.t)
        .expect("W2 must break");
    let after = mw_w1.iter().filter(|p| p.value > 0.0 && p.t > fail_t + 0.2).count();
    assert!(after > 3, "MW/W1-R1 must keep flowing after W2 broke (got {after})");
    let sw_fail = tl
        .points()
        .iter()
        .find(|p| p.series.starts_with("SW/") && p.value == 0.0 && !p.label.contains("end"))
        .map(|p| p.t)
        .expect("SW world must break");
    let sw_after = tl
        .series("SW/W1-R1")
        .iter()
        .filter(|p| p.value > 0.0 && p.t > sw_fail + 0.5)
        .count();
    assert_eq!(sw_after, 0, "SW leader must stop receiving after the world broke");
    println!("shape assertions passed ✓");

    // Recovery wall-time, measured with the exact kill→`Recovered` span
    // the chaos_serve / serving_trajectory artifact uses — so the Fig. 4
    // story and BENCH_serving.json agree on what "recovery" means
    // (previously this bench only showed the detection timeline).
    let base = 45_000 + (std::process::id() % 60) as u16 * 24;
    let mttr = recovery_mttr(
        1,
        0,
        true,
        0,
        WorldOptions::shm().with_init_timeout(Duration::from_secs(120)),
        base,
    )
    .expect("recovery_mttr");
    println!(
        "recovery wall-time (kill → controller `Recovered`, chaos_serve span): {:.1} ms",
        mttr.max_ms
    );
}
