//! Figure 5 — online instantiation. A leader receives 4 MB tensors from
//! W1-R1 at full speed; part-way through, it initializes W2 on a
//! separate thread (blocking rendezvous), the W2-R1 joiner arrives
//! later, and both stream concurrently.
//!
//! Reproduced shape: W1 throughput is *unaffected* while the leader
//! waits for W2's joiner (the init blocks only its own thread); the
//! join itself takes ~tens of ms; after the join both worlds stream at
//! roughly equal rates. Absolute GB/s is CPU memcpy, not NVLink.

use multiworld::bench::write_csv;
use multiworld::metrics::Timeline;
use multiworld::multiworld::{StatePolicy, WatchdogConfig, WorldManager};
use multiworld::mwccl::{Rendezvous, World, WorldOptions};
use multiworld::tensor::Tensor;
use multiworld::util::fmt_rate;
use multiworld::util::time::since_epoch;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

const ELEMS: usize = 1_000_000; // "a 32-bit floating point tensor whose length is 1M" = 4 MB
const WINDOW: usize = 25; // tensors per throughput sample (paper: 5000)

fn uniq(p: &str) -> String {
    static N: AtomicU64 = AtomicU64::new(0);
    format!("{p}-{}-{}", std::process::id(), N.fetch_add(1, Ordering::Relaxed))
}

fn spam(world: World, stop: Arc<AtomicBool>) {
    // Publish watchdog heartbeats like a real MultiWorld worker would
    // (the leader's watchdog monitors this world's store).
    let hb_stop = Arc::new(AtomicBool::new(false));
    let hb = {
        let store = world.store();
        let name = world.name().to_string();
        let rank = world.rank();
        let hb_stop = hb_stop.clone();
        std::thread::spawn(move || {
            if let Some(store) = store {
                while !hb_stop.load(Ordering::Relaxed) {
                    let now = multiworld::util::time::unix_millis();
                    let _ = store.set(&format!("mw/{name}/hb/{rank}"), now.to_string().as_bytes());
                    std::thread::sleep(Duration::from_millis(100));
                }
            }
        })
    };
    let mut rng = multiworld::util::prng::Rng::new(world.rank() as u64);
    let t = Tensor::f32_1d(ELEMS, &mut rng);
    let mut k = 0u64;
    while !stop.load(Ordering::Relaxed) {
        if world.send(t.clone(), 0, k).is_err() {
            break;
        }
        k += 1;
    }
    hb_stop.store(true, Ordering::Relaxed);
    let _ = hb.join();
}

/// Drain `n` tensors from a world, recording a throughput point per
/// WINDOW into the timeline.
struct Drainer {
    series: &'static str,
    window_start: Instant,
    in_window: usize,
}

impl Drainer {
    fn new(series: &'static str) -> Self {
        Drainer { series, window_start: Instant::now(), in_window: 0 }
    }

    fn on_tensor(&mut self, tl: &Timeline) {
        self.in_window += 1;
        if self.in_window == WINDOW {
            let dt = self.window_start.elapsed().as_secs_f64();
            let bps = (WINDOW * ELEMS * 4) as f64 / dt;
            tl.record(self.series, bps / 1e9); // GB/s
            self.in_window = 0;
            self.window_start = Instant::now();
        }
    }
}

fn main() {
    let tl = Timeline::new();
    let mgr = WorldManager::with_options(
        StatePolicy::Kv,
        WatchdogConfig::default(),
        multiworld::util::time::Clock::system(),
    );
    let comm = mgr.communicator();
    let stop = Arc::new(AtomicBool::new(false));

    // W1 up, streaming.
    let w1 = uniq("fig5-w1");
    let worlds = Rendezvous::single_process(&w1, 2, WorldOptions::shm()).unwrap();
    let mut it = worlds.into_iter();
    mgr.adopt(it.next().unwrap()).unwrap();
    let w1_peer = it.next().unwrap();
    let stop1 = stop.clone();
    let s1 = std::thread::spawn(move || spam(w1_peer, stop1));
    tl.record_labeled("event", 1.0, "W1 initialized");

    // Leader drains W1; W2 init fires at +1 s; joiner arrives at +2 s.
    let w2 = uniq("fig5-w2");
    let port = multiworld::util::free_port();
    let addr: std::net::SocketAddr = format!("127.0.0.1:{port}").parse().unwrap();
    let t0 = since_epoch();
    let mut d1 = Drainer::new("W1-R1");
    let mut d2 = Drainer::new("W2-R1");
    let mut k1 = 0u64;
    let mut k2 = 0u64;
    let mut init_handle = None;
    let mut joiner: Option<std::thread::JoinHandle<World>> = None;
    let mut join_started = None;
    let mut w2_live = false;
    let mut s2: Option<std::thread::JoinHandle<()>> = None;
    let mut pending = vec![(1u8, comm.recv(&w1, 1, k1).unwrap())];
    k1 += 1;

    let run_for = 5.0;
    while since_epoch() - t0 < run_for {
        let now = since_epoch() - t0;
        if now >= 1.0 && init_handle.is_none() {
            // Paper: leader initializes W2 at the 10 s mark (scaled).
            init_handle = Some(mgr.initialize_world_async(&w2, 0, 2, addr, WorldOptions::shm()));
            tl.record_labeled("event", 1.0, "leader starts W2 init (async)");
        }
        if now >= 2.0 && joiner.is_none() {
            // Paper: W2-R1 joins at the 20 s mark; the join takes ~20 ms.
            let w2n = w2.clone();
            join_started = Some(Instant::now());
            joiner = Some(std::thread::spawn(move || {
                World::init(&w2n, 1, 2, addr, WorldOptions::shm()).unwrap()
            }));
            tl.record_labeled("event", 1.0, "W2-R1 joining");
        }
        if let Some(h) = &init_handle {
            if h.is_done() && !w2_live {
                let join_ms = join_started
                    .map(|t| t.elapsed().as_secs_f64() * 1e3)
                    .unwrap_or(0.0);
                tl.record_labeled("event", 1.0, &format!("W2 join complete ({join_ms:.0} ms)"));
                println!("join took {join_ms:.1} ms (paper: ≈20 ms)");
                w2_live = true;
                let peer = joiner.take().unwrap().join().unwrap();
                let stop2 = stop.clone();
                s2 = Some(std::thread::spawn(move || spam(peer, stop2)));
                pending.push((2u8, comm.recv(&w2, 1, k2).unwrap()));
                k2 += 1;
            }
        }
        // Drain whichever world has data.
        let works: Vec<_> = pending.iter().map(|(_, w)| w.clone()).collect();
        if let Some(idx) = comm.wait_any_deadline(&works, Some(Duration::from_millis(10))) {
            let (which, work) = pending.swap_remove(idx);
            if work.wait().is_ok() {
                if which == 1 {
                    d1.on_tensor(&tl);
                    pending.push((1, comm.recv(&w1, 1, k1).unwrap()));
                    k1 += 1;
                } else {
                    d2.on_tensor(&tl);
                    pending.push((2, comm.recv(&w2, 1, k2).unwrap()));
                    k2 += 1;
                }
            }
        }
    }
    stop.store(true, Ordering::Relaxed);
    // Drain remaining sends so sender threads can exit.
    drop(pending);
    let _ = s1.join();
    if let Some(s) = s2 {
        let _ = s.join();
    }

    // Report.
    println!("\n=== Fig 5 — online instantiation (time scaled 10×, {} MB tensors) ===", ELEMS * 4 / 1_000_000);
    let mean = |pts: &[multiworld::metrics::TimelinePoint]| {
        if pts.is_empty() { 0.0 } else { pts.iter().map(|p| p.value).sum::<f64>() / pts.len() as f64 }
    };
    let w1_pts = tl.series("W1-R1");
    let before: Vec<_> = w1_pts.iter().filter(|p| p.t - t0 < 1.0).cloned().collect();
    let waiting: Vec<_> = w1_pts
        .iter()
        .filter(|p| p.t - t0 >= 1.0 && p.t - t0 < 2.0)
        .cloned()
        .collect();
    let after: Vec<_> = w1_pts.iter().filter(|p| p.t - t0 >= 2.5).cloned().collect();
    let w2_after: Vec<_> = tl.series("W2-R1").iter().filter(|p| p.t - t0 >= 2.5).cloned().collect();
    println!("W1 throughput before init     : {}", fmt_rate(mean(&before) * 1e9));
    println!("W1 throughput while waiting   : {}", fmt_rate(mean(&waiting) * 1e9));
    println!("W1 throughput after W2 joined : {}", fmt_rate(mean(&after) * 1e9));
    println!("W2 throughput after joining   : {}", fmt_rate(mean(&w2_after) * 1e9));
    write_csv("fig5_online_instantiation", &tl.to_csv());

    // Shape assertions: waiting-phase throughput within 25% of before;
    // both worlds produce data after the join.
    if mean(&before) > 0.0 {
        let ratio = mean(&waiting) / mean(&before);
        println!("W1 while-waiting / before ratio: {ratio:.2} (paper: ≈1.0)");
        assert!(ratio > 0.5, "W1 must not stall while leader waits for W2 (ratio {ratio:.2})");
    }
    assert!(!w2_after.is_empty(), "W2 must stream after joining");
    println!("shape assertions passed ✓");

    // Epilogue — what online instantiation costs in sockets now that
    // inter-host traffic is multiplexed per host pair: each world
    // minted between the same two hosts adds lanes on the established
    // connection, never sockets, so the instantiation rate the figure
    // measures no longer scales the fd count.
    let domain = uniq("fig5-mux");
    let mint_opts = WorldOptions::tcp()
        .with_hostmap("0,1")
        .with_mux_domain(&domain)
        .with_op_timeout(Duration::from_secs(60));
    let mut minted = Vec::new();
    println!("\n=== world minting over the host-pair mux ===");
    println!("{:>6}  {:>5}  {:>5}", "worlds", "conns", "lanes");
    for i in 0..6 {
        minted.push(
            Rendezvous::single_process(&uniq("fig5-mint"), 2, mint_opts.clone()).unwrap(),
        );
        let s = multiworld::mwccl::transport::mux::stats(&domain);
        println!("{:>6}  {:>5}  {:>5}", i + 1, s.conns, s.lanes);
        assert_eq!(s.conns, 2, "O(1) sockets per host pair while minting worlds");
    }
    println!("sockets stayed O(1) per host pair across {} minted worlds ✓", minted.len());

    // === Control plane at scale ===
    // The figure above touches ~8 worlds total; this phase mints ~100×
    // that through the sharded store + batched rendezvous (one SET to
    // publish, one WAIT_MANY to collect all peer addresses, push-based
    // server waits) and reports minting throughput as the
    // BENCH_control_plane.json trajectory artifact.
    let quick = std::env::var("MW_BENCH_QUICK").as_deref() == Ok("1");
    const CP_THREADS: usize = 8;
    const CP_PER_THREAD: usize = 100; // 8 × 100 = 800 worlds ≈ 100× the figure's own count
    let ops = multiworld::metrics::global().counter("store.client.ops");
    let conns = multiworld::metrics::global().counter("store.client.conns_opened");
    let (ops0, conns0) = (ops.get(), conns.get());
    println!(
        "\n=== control plane: minting {} worlds across {CP_THREADS} threads ===",
        CP_THREADS * CP_PER_THREAD
    );
    let t_cp = Instant::now();
    let lanes: Vec<_> = (0..CP_THREADS)
        .map(|t| {
            std::thread::spawn(move || {
                for i in 0..CP_PER_THREAD {
                    let name = uniq(&format!("fig5-cp-{t}-{i}"));
                    // Minted and immediately retired: the phase measures
                    // control-plane throughput, not steady-state worlds.
                    drop(
                        Rendezvous::single_process(&name, 2, WorldOptions::tcp())
                            .expect("mint world"),
                    );
                }
            })
        })
        .collect();
    for h in lanes {
        h.join().expect("mint thread");
    }
    let cp_secs = t_cp.elapsed().as_secs_f64();
    let cp_worlds = (CP_THREADS * CP_PER_THREAD) as f64;
    let worlds_per_s = cp_worlds / cp_secs;
    let ops_per_world = (ops.get() - ops0) as f64 / cp_worlds;
    println!(
        "minted {cp_worlds:.0} worlds in {cp_secs:.2} s → {worlds_per_s:.0} worlds/s \
         ({ops_per_world:.1} store ops/world, {} conns opened)",
        conns.get() - conns0
    );
    use multiworld::util::json::Json;
    multiworld::bench::write_json(
        "BENCH_control_plane",
        &Json::obj(vec![
            ("meta", multiworld::bench::bench_meta()),
            ("quick", Json::num(if quick { 1.0 } else { 0.0 })),
            (
                "control_plane",
                Json::obj(vec![
                    ("worlds", Json::num(cp_worlds)),
                    ("threads", Json::num(CP_THREADS as f64)),
                    ("world_size", Json::num(2.0)),
                    ("secs", Json::num(cp_secs)),
                    ("worlds_per_s", Json::num(worlds_per_s)),
                    ("store_ops", Json::num((ops.get() - ops0) as f64)),
                    ("store_ops_per_world", Json::num(ops_per_world)),
                    ("conns_opened", Json::num((conns.get() - conns0) as f64)),
                ]),
            ),
        ]),
    );
}
