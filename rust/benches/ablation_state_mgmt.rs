//! Ablation — per-world state management (§3.2): the paper's key-value
//! design vs the save/restore *swap* baseline, as the number of worlds a
//! worker belongs to grows.
//!
//! Measures (a) raw `activate` cost per op for both managers and (b)
//! end-to-end fan-in throughput with the full stack under each policy.
//! Expected shape: kv stays flat; swap degrades as world count (and
//! therefore switch frequency) rises.

use multiworld::bench::scenarios::mw_fanin_throughput;
use multiworld::bench::Table;
use multiworld::multiworld::state::{
    make_state_manager, StatePolicy, WorldState,
};
use multiworld::multiworld::PollStrategy;
use multiworld::mwccl::WorldOptions;
use multiworld::util::fmt_rate;
use std::time::Instant;

/// Raw state-activation microbenchmark: round-robin ops across N worlds.
fn activate_ns_per_op(policy: StatePolicy, n_worlds: usize, blob: usize) -> f64 {
    let m = make_state_manager(policy);
    for i in 0..n_worlds {
        m.insert(WorldState::new(&format!("w{i}"), 0, 2, blob));
    }
    let ops = 20_000usize;
    let t0 = Instant::now();
    for k in 0..ops {
        m.next_seq(&format!("w{}", k % n_worlds)).unwrap();
    }
    t0.elapsed().as_nanos() as f64 / ops as f64
}

fn main() {
    let quick = std::env::var("MW_BENCH_QUICK").as_deref() == Ok("1");
    let blob = 64 * 1024; // NCCL-communicator-scale state per world

    let mut micro = Table::new(
        "Ablation A1a — state activation cost (64 KiB state blob per world)",
        &["worlds", "kv ns/op", "swap ns/op", "swap/kv"],
    );
    for n in [1usize, 2, 4, 8, 16, 32] {
        let kv = activate_ns_per_op(StatePolicy::Kv, n, blob);
        let swap = activate_ns_per_op(StatePolicy::Swap, n, blob);
        micro.row(&[
            n.to_string(),
            format!("{kv:.0}"),
            format!("{swap:.0}"),
            format!("{:.1}×", swap / kv),
        ]);
    }
    micro.emit("ablation_state_micro");

    let mut e2e = Table::new(
        "Ablation A1b — fan-in throughput under each state policy (40 KB tensors)",
        &["worlds(senders)", "kv", "swap", "swap/kv"],
    );
    for senders in [1usize, 2, 4] {
        let msgs = if quick { 64 } else { 512 };
        let kv = mw_fanin_throughput(
            senders,
            10_000,
            msgs,
            WorldOptions::shm(),
            StatePolicy::Kv,
            PollStrategy::SpinYield,
        );
        let swap = mw_fanin_throughput(
            senders,
            10_000,
            msgs,
            WorldOptions::shm(),
            StatePolicy::Swap,
            PollStrategy::SpinYield,
        );
        e2e.row(&[
            senders.to_string(),
            fmt_rate(kv),
            fmt_rate(swap),
            format!("{:.3}", swap / kv),
        ]);
    }
    e2e.emit("ablation_state_e2e");
    println!("expected shape: kv flat in #worlds; swap degrades with switch frequency");
}
