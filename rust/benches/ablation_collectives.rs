//! Ablation — flat (root star) vs ring (pipelined) algorithms for all
//! six collectives, across world sizes and payload sizes, on the
//! multi-host topology: TCP with a **per-rank** 10 Gbps NIC
//! (`WorldOptions::tcp_per_rank_limited`), so the flat root's NIC is the
//! bottleneck the rings remove or shrink.
//!
//! Expected shape: at world size 2 the two algorithms are within noise
//! (rings degenerate to one exchange); from world size 4 upward the
//! bandwidth-bound rings (all_reduce, broadcast, reduce) win on large
//! payloads (flat moves ~N×S through the root's NIC, the rings ~S–2S
//! through every NIC concurrently), while the circulation rings
//! (gather, all_gather, scatter) trade root-NIC serialization for hop
//! pipelining. `Auto` follows the measured crossover per op.
//!
//! Checksums of both paths are asserted identical per cell
//! (integer-valued tensors make f32 summation order-independent).
//!
//! The CSV (`target/bench-results/ablation_collectives.csv`) is
//! machine-readable — `op,world,bytes,flat_ms,ring_ms,speedup,auto` —
//! and consumed by CI's `crossover-matrix` job via
//! `tools/check_crossover.py`, which warns when the measured knee
//! disagrees with the configured `RING_MIN_WORLD`/`RING_MIN_BYTES`
//! defaults.

use multiworld::bench::Table;
use multiworld::config::{CollAlgo, CollOp, CollPolicy};
use multiworld::mwccl::transport::ratelimit::RATE_10GBPS;
use multiworld::mwccl::{Rendezvous, ReduceOp, World, WorldOptions};
use multiworld::tensor::Tensor;
use std::time::{Duration, Instant};

fn uniq(name: &str) -> String {
    use std::sync::atomic::{AtomicU64, Ordering};
    static N: AtomicU64 = AtomicU64::new(0);
    format!(
        "abl-{name}-{}-{}",
        std::process::id(),
        N.fetch_add(1, Ordering::Relaxed)
    )
}

/// Integer-valued tensor: exact, order-independent f32 sums.
fn int_tensor(elems: usize, rank: usize) -> Tensor {
    let vals: Vec<f32> = (0..elems)
        .map(|i| ((i as u64 * 13 + rank as u64 * 5 + 1) % 97) as f32)
        .collect();
    Tensor::from_f32(&[elems], &vals)
}

/// Prebuilt per-rank input for one op — constructed once per world,
/// *outside* the timed loop, so the O(elems) tensor fill never pollutes
/// the flat/ring columns (iterations only pay a memcpy clone, like the
/// tensor the caller would already hold).
enum OpInput {
    /// Every-rank contribution (all_reduce, reduce, gather, all_gather).
    Tensor(Tensor),
    /// Broadcast source (root only).
    Root(Option<Tensor>),
    /// Scatter parts (root only).
    Parts(Option<Vec<Tensor>>),
}

/// Build rank-local input for `op`. `elems` is the *total* payload of
/// the cell (the gather/scatter family contributes `elems / size` per
/// rank so every op moves comparable bytes).
fn make_input(op: CollOp, rank: usize, size: usize, elems: usize) -> OpInput {
    match op {
        CollOp::AllReduce | CollOp::Reduce => OpInput::Tensor(int_tensor(elems, rank)),
        CollOp::Gather | CollOp::AllGather => OpInput::Tensor(int_tensor(elems / size, rank)),
        CollOp::Broadcast => {
            OpInput::Root(if rank == 0 { Some(int_tensor(elems, 0)) } else { None })
        }
        CollOp::Scatter => OpInput::Parts(if rank == 0 {
            Some((0..size).map(|i| int_tensor(elems / size, i)).collect())
        } else {
            None
        }),
    }
}

/// One iteration of `op` on one rank. Returns a checksum of the rank's
/// visible result (0 where the op yields nothing on this rank).
fn run_once(op: CollOp, w: &World, input: &OpInput) -> u64 {
    match (op, input) {
        (CollOp::AllReduce, OpInput::Tensor(t)) => {
            w.all_reduce(t.clone(), ReduceOp::Sum).unwrap().checksum()
        }
        (CollOp::Reduce, OpInput::Tensor(t)) => w
            .reduce(t.clone(), 0, ReduceOp::Sum)
            .unwrap()
            .map(|t| t.checksum())
            .unwrap_or(0),
        (CollOp::Broadcast, OpInput::Root(t)) => w.broadcast(t.clone(), 0).unwrap().checksum(),
        (CollOp::Gather, OpInput::Tensor(t)) => w
            .gather(t.clone(), 0)
            .unwrap()
            .map(|t| t.checksum())
            .unwrap_or(0),
        (CollOp::AllGather, OpInput::Tensor(t)) => w.all_gather(t.clone()).unwrap().checksum(),
        (CollOp::Scatter, OpInput::Parts(p)) => w.scatter(p.clone(), 0).unwrap().checksum(),
        _ => unreachable!("input built for a different op"),
    }
}

/// Mean seconds per op (slowest rank) plus the combined result checksum.
fn time_op(op: CollOp, size: usize, elems: usize, iters: usize, algo: CollAlgo) -> (f64, u64) {
    let opts = WorldOptions::tcp_per_rank_limited(RATE_10GBPS)
        .with_coll_algo(algo)
        .with_op_timeout(Duration::from_secs(120));
    let worlds = Rendezvous::single_process(&uniq(op.name()), size, opts).unwrap();
    let handles: Vec<_> = worlds
        .into_iter()
        .map(|w| {
            std::thread::spawn(move || {
                let input = make_input(op, w.rank(), w.size(), elems);
                // Warmup synchronizes all ranks and fills buffer pools.
                let _ = run_once(op, &w, &input);
                let t0 = Instant::now();
                let mut cs = 0u64;
                for _ in 0..iters {
                    cs = run_once(op, &w, &input);
                }
                (t0.elapsed().as_secs_f64(), cs)
            })
        })
        .collect();
    let mut worst = 0.0f64;
    let mut checksum = 0u64;
    for h in handles {
        let (dt, cs) = h.join().unwrap();
        worst = worst.max(dt);
        // Combine across ranks so single-result ops (reduce, gather)
        // contribute the root's value and symmetric ops every rank's.
        checksum = checksum.wrapping_add(cs);
    }
    (worst / iters as f64, checksum)
}

/// The negotiated small-message fast path, printed so the CI quick
/// ablation shows `Auto` keeping tiny root-sized ops flat.
fn show_auto_prologue() {
    let opts = WorldOptions::tcp()
        .with_coll_algo(CollAlgo::Auto)
        .with_op_timeout(Duration::from_secs(60));
    let worlds = Rendezvous::single_process(&uniq("auto-prologue"), 4, opts).unwrap();
    let handles: Vec<_> = worlds
        .into_iter()
        .map(|w| {
            std::thread::spawn(move || {
                let small = if w.rank() == 0 { Some(int_tensor(1024, 0)) } else { None };
                w.broadcast(small, 0).unwrap();
                let small_pick = w.last_algo(CollOp::Broadcast).unwrap();
                let big = if w.rank() == 0 { Some(int_tensor(1 << 20, 0)) } else { None };
                w.broadcast(big, 0).unwrap();
                (small_pick, w.last_algo(CollOp::Broadcast).unwrap())
            })
        })
        .collect();
    for h in handles {
        let (small_pick, big_pick) = h.join().unwrap();
        assert_eq!(small_pick, "flat", "Auto must keep a 4 KB broadcast flat");
        assert_eq!(big_pick, "ring", "Auto must ring a 4 MB broadcast");
    }
    println!(
        "auto prologue @ world 4: broadcast 4 KB -> flat, 4 MB -> ring \
         (root-decided algo byte; non-roots never see the size)"
    );
}

fn main() {
    let quick = std::env::var("MW_BENCH_QUICK").as_deref() == Ok("1");
    let policy = CollPolicy::from_env();
    let mut table = Table::new(
        "Ablation — flat vs ring, all six collectives, tcp with per-rank 10 Gbps NICs",
        &["op", "world", "bytes", "flat_ms", "ring_ms", "speedup", "auto"],
    );
    let sizes: &[usize] = if quick { &[2, 4] } else { &[2, 4, 8] };
    let elem_counts: &[usize] = if quick {
        &[65_536, 1_048_576]
    } else {
        &[65_536, 262_144, 1_048_576, 4_194_304]
    };
    for op in CollOp::ALL {
        for &world in sizes {
            for &elems in elem_counts {
                let iters = if elems >= 1_048_576 { 3 } else { 5 };
                let (flat_s, flat_cs) = time_op(op, world, elems, iters, CollAlgo::Flat);
                let (ring_s, ring_cs) = time_op(op, world, elems, iters, CollAlgo::Ring);
                assert_eq!(
                    flat_cs,
                    ring_cs,
                    "flat and ring {} disagree at world={world} elems={elems}",
                    op.name()
                );
                let bytes = elems * 4;
                let auto = if policy.ring_for_bytes(op, world, bytes) { "ring" } else { "flat" };
                table.row(&[
                    op.name().to_string(),
                    world.to_string(),
                    bytes.to_string(),
                    format!("{:.3}", flat_s * 1e3),
                    format!("{:.3}", ring_s * 1e3),
                    format!("{:.2}", flat_s / ring_s),
                    auto.to_string(),
                ]);
            }
        }
    }
    table.emit("ablation_collectives");
    show_auto_prologue();
    println!(
        "paper shape: parity at world 2; bandwidth-bound rings (all_reduce, \
         broadcast, reduce) win on >=4MB payloads at world >=4 (root NIC is \
         the flat bottleneck); Auto crossover per the MW_RING_MIN_* policy table"
    );
}
