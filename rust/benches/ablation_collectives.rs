//! Ablation — flat (root star) vs ring (pipelined) vs hier (two-level
//! topology-aware) algorithms for the collectives.
//!
//! Two grids:
//!
//! * **Single-host grid** — all six collectives, flat vs ring, TCP with
//!   a **per-rank** 10 Gbps NIC (`WorldOptions::tcp_per_rank_limited`),
//!   so the flat root's NIC is the bottleneck the rings remove or
//!   shrink. This is the historical flat↔ring crossover surface the
//!   `RING_MIN_WORLD`/`RING_MIN_BYTES` policy defaults are tuned
//!   against (hier is not selectable on one host; its column is blank).
//! * **Multi-host scale sweep** — the bandwidth-bound hier ops at
//!   64–256-rank worlds placed on simulated hosts via a blocked
//!   `MW_HOSTMAP` layout, every rank's traffic riding the per-host-pair
//!   mux (`with_intra_over_mux`, so the sweep also measures the shared
//!   connections, not per-world sockets), cross-host bytes squeezed
//!   through one shared 10 Gbps NIC per host. The ring column goes
//!   blank past `RING_MAX_WORLD` (128) — the whole-world ring is not
//!   selectable there and hier is the only non-flat option.
//!
//! Expected shape: parity at world 2; from world 4 the bandwidth-bound
//! rings win large payloads on the single-host grid; on the sweep the
//! hier algorithm beats the flat star everywhere and beats the
//! whole-world ring from ~16 ranks × 2 hosts upward (2(H-1) leader
//! steps instead of 2(N-1) full-ring steps, intra-host hops off the
//! NIC), which is the knee `Auto` encodes as "hier once the world
//! spans hosts and clears the byte threshold".
//!
//! Checksums of all measured paths are asserted identical per cell
//! (integer-valued tensors make f32 summation order-independent).
//!
//! The CSV (`target/bench-results/ablation_collectives.csv`) is
//! machine-readable — `op,world,hosts,bytes,flat_ms,ring_ms,hier_ms,
//! speedup_ring,speedup_hier,auto` (blank cell = algorithm not
//! selectable there) — and consumed by CI's `crossover-matrix` job via
//! `tools/check_crossover.py`, which warns when a measured knee
//! disagrees with the configured policy-table defaults. A compact
//! trajectory artifact (`BENCH_collectives.json`) rides along for
//! cross-commit comparison.

use multiworld::bench::{bench_meta, write_json, Table};
use multiworld::config::{AlgoDecision, CollAlgo, CollOp, CollPolicy};
use multiworld::mwccl::transport::ratelimit::RATE_10GBPS;
use multiworld::mwccl::{Rendezvous, ReduceOp, World, WorldOptions};
use multiworld::tensor::Tensor;
use multiworld::util::json::Json;
use std::time::{Duration, Instant};

fn uniq(name: &str) -> String {
    use std::sync::atomic::{AtomicU64, Ordering};
    static N: AtomicU64 = AtomicU64::new(0);
    format!(
        "abl-{name}-{}-{}",
        std::process::id(),
        N.fetch_add(1, Ordering::Relaxed)
    )
}

/// Integer-valued tensor: exact, order-independent f32 sums.
fn int_tensor(elems: usize, rank: usize) -> Tensor {
    let vals: Vec<f32> = (0..elems)
        .map(|i| ((i as u64 * 13 + rank as u64 * 5 + 1) % 97) as f32)
        .collect();
    Tensor::from_f32(&[elems], &vals)
}

/// Prebuilt per-rank input for one op — constructed once per world,
/// *outside* the timed loop, so the O(elems) tensor fill never pollutes
/// the timing columns (iterations only pay a memcpy clone, like the
/// tensor the caller would already hold).
enum OpInput {
    /// Every-rank contribution (all_reduce, reduce, gather, all_gather).
    Tensor(Tensor),
    /// Broadcast source (root only).
    Root(Option<Tensor>),
    /// Scatter parts (root only).
    Parts(Option<Vec<Tensor>>),
}

/// Build rank-local input for `op`. `elems` is the *total* payload of
/// the cell (the gather/scatter family contributes `elems / size` per
/// rank so every op moves comparable bytes).
fn make_input(op: CollOp, rank: usize, size: usize, elems: usize) -> OpInput {
    match op {
        CollOp::AllReduce | CollOp::Reduce => OpInput::Tensor(int_tensor(elems, rank)),
        CollOp::Gather | CollOp::AllGather => OpInput::Tensor(int_tensor(elems / size, rank)),
        CollOp::Broadcast => {
            OpInput::Root(if rank == 0 { Some(int_tensor(elems, 0)) } else { None })
        }
        CollOp::Scatter => OpInput::Parts(if rank == 0 {
            Some((0..size).map(|i| int_tensor(elems / size, i)).collect())
        } else {
            None
        }),
    }
}

/// One iteration of `op` on one rank. Returns a checksum of the rank's
/// visible result (0 where the op yields nothing on this rank).
fn run_once(op: CollOp, w: &World, input: &OpInput) -> u64 {
    match (op, input) {
        (CollOp::AllReduce, OpInput::Tensor(t)) => {
            w.all_reduce(t.clone(), ReduceOp::Sum).unwrap().checksum()
        }
        (CollOp::Reduce, OpInput::Tensor(t)) => w
            .reduce(t.clone(), 0, ReduceOp::Sum)
            .unwrap()
            .map(|t| t.checksum())
            .unwrap_or(0),
        (CollOp::Broadcast, OpInput::Root(t)) => w.broadcast(t.clone(), 0).unwrap().checksum(),
        (CollOp::Gather, OpInput::Tensor(t)) => w
            .gather(t.clone(), 0)
            .unwrap()
            .map(|t| t.checksum())
            .unwrap_or(0),
        (CollOp::AllGather, OpInput::Tensor(t)) => w.all_gather(t.clone()).unwrap().checksum(),
        (CollOp::Scatter, OpInput::Parts(p)) => w.scatter(p.clone(), 0).unwrap().checksum(),
        _ => unreachable!("input built for a different op"),
    }
}

/// Mean seconds per op (slowest rank) plus the combined result
/// checksum. `layout = None` is the single-host grid (plain per-rank
/// NICs); `Some(spec)` places the world on simulated hosts, with all
/// traffic — intra-host included — over the shared host-pair mux and
/// cross-host bytes through one 10 Gbps NIC per host.
fn time_op(
    op: CollOp,
    size: usize,
    elems: usize,
    iters: usize,
    algo: CollAlgo,
    layout: Option<&str>,
) -> (f64, u64) {
    let mut opts = WorldOptions::tcp_per_rank_limited(RATE_10GBPS)
        .with_coll_algo(algo)
        .with_op_timeout(Duration::from_secs(300));
    if let Some(spec) = layout {
        opts = opts.with_hostmap(spec).with_intra_over_mux();
    }
    let worlds = Rendezvous::single_process(&uniq(op.name()), size, opts).unwrap();
    let handles: Vec<_> = worlds
        .into_iter()
        .map(|w| {
            std::thread::spawn(move || {
                let input = make_input(op, w.rank(), w.size(), elems);
                // Warmup synchronizes all ranks and fills buffer pools.
                let _ = run_once(op, &w, &input);
                let t0 = Instant::now();
                let mut cs = 0u64;
                for _ in 0..iters {
                    cs = run_once(op, &w, &input);
                }
                (t0.elapsed().as_secs_f64(), cs)
            })
        })
        .collect();
    let mut worst = 0.0f64;
    let mut checksum = 0u64;
    for h in handles {
        let (dt, cs) = h.join().unwrap();
        worst = worst.max(dt);
        // Combine across ranks so single-result ops (reduce, gather)
        // contribute the root's value and symmetric ops every rank's.
        checksum = checksum.wrapping_add(cs);
    }
    (worst / iters as f64, checksum)
}

fn decision_name(d: AlgoDecision) -> &'static str {
    match d {
        AlgoDecision::Flat => "flat",
        AlgoDecision::Ring => "ring",
        AlgoDecision::Hier => "hier",
        AlgoDecision::Negotiate => "negotiate",
    }
}

fn ms(s: f64) -> String {
    format!("{:.3}", s * 1e3)
}

fn speedup(base: f64, other: Option<f64>) -> String {
    other.map(|o| format!("{:.2}", base / o)).unwrap_or_default()
}

/// The negotiated small-message fast path, printed so the CI quick
/// ablation shows `Auto` keeping tiny root-sized ops flat.
fn show_auto_prologue() {
    let opts = WorldOptions::tcp()
        .with_coll_algo(CollAlgo::Auto)
        .with_op_timeout(Duration::from_secs(60));
    let worlds = Rendezvous::single_process(&uniq("auto-prologue"), 4, opts).unwrap();
    let handles: Vec<_> = worlds
        .into_iter()
        .map(|w| {
            std::thread::spawn(move || {
                let small = if w.rank() == 0 { Some(int_tensor(1024, 0)) } else { None };
                w.broadcast(small, 0).unwrap();
                let small_pick = w.last_algo(CollOp::Broadcast).unwrap();
                let big = if w.rank() == 0 { Some(int_tensor(1 << 20, 0)) } else { None };
                w.broadcast(big, 0).unwrap();
                (small_pick, w.last_algo(CollOp::Broadcast).unwrap())
            })
        })
        .collect();
    for h in handles {
        let (small_pick, big_pick) = h.join().unwrap();
        assert_eq!(small_pick, "flat", "Auto must keep a 4 KB broadcast flat");
        assert_eq!(big_pick, "ring", "Auto must ring a 4 MB broadcast");
    }
    println!(
        "auto prologue @ world 4: broadcast 4 KB -> flat, 4 MB -> ring \
         (root-decided algo byte; non-roots never see the size)"
    );
}

fn main() {
    let quick = std::env::var("MW_BENCH_QUICK").as_deref() == Ok("1");
    let policy = CollPolicy::from_env();
    let mut table = Table::new(
        "Ablation — flat vs ring vs hier, tcp, 10 Gbps NICs (per rank on \
         the single-host grid, per host on the multi-host sweep)",
        &[
            "op", "world", "hosts", "bytes", "flat_ms", "ring_ms", "hier_ms", "speedup_ring",
            "speedup_hier", "auto",
        ],
    );
    let mut traj: Vec<Json> = Vec::new();
    let mut cell = |table: &mut Table,
                    op: CollOp,
                    world: usize,
                    hosts: usize,
                    bytes: usize,
                    flat: f64,
                    ring: Option<f64>,
                    hier: Option<f64>| {
        let auto = decision_name(policy.decide(op, world, hosts, Some(bytes)));
        table.row(&[
            op.name().to_string(),
            world.to_string(),
            hosts.to_string(),
            bytes.to_string(),
            ms(flat),
            ring.map(ms).unwrap_or_default(),
            hier.map(ms).unwrap_or_default(),
            speedup(flat, ring),
            speedup(flat, hier),
            auto.to_string(),
        ]);
        let mut pairs = vec![
            ("op", Json::str(op.name())),
            ("world", Json::num(world as f64)),
            ("hosts", Json::num(hosts as f64)),
            ("bytes", Json::num(bytes as f64)),
            ("flat_ms", Json::num(flat * 1e3)),
        ];
        if let Some(r) = ring {
            pairs.push(("ring_ms", Json::num(r * 1e3)));
        }
        if let Some(h) = hier {
            pairs.push(("hier_ms", Json::num(h * 1e3)));
        }
        pairs.push(("auto", Json::str(auto)));
        traj.push(Json::obj(pairs));
    };

    // ---- single-host grid: the flat <-> ring crossover surface ----
    let sizes: &[usize] = if quick { &[2, 4] } else { &[2, 4, 8] };
    let elem_counts: &[usize] = if quick {
        &[65_536, 1_048_576]
    } else {
        &[65_536, 262_144, 1_048_576, 4_194_304]
    };
    for op in CollOp::ALL {
        for &world in sizes {
            for &elems in elem_counts {
                let iters = if elems >= 1_048_576 { 3 } else { 5 };
                let (flat_s, flat_cs) = time_op(op, world, elems, iters, CollAlgo::Flat, None);
                let (ring_s, ring_cs) = time_op(op, world, elems, iters, CollAlgo::Ring, None);
                assert_eq!(
                    flat_cs,
                    ring_cs,
                    "flat and ring {} disagree at world={world} elems={elems}",
                    op.name()
                );
                cell(&mut table, op, world, 1, elems * 4, flat_s, Some(ring_s), None);
            }
        }
    }

    // ---- multi-host scale sweep: the ring <-> hier crossover ----
    // Blocked layouts (`<H>x<L>`) keep ring neighbours mostly
    // intra-host, so the whole-world ring gets its best case and the
    // hier win measured here is the honest one. Past RING_MAX_WORLD the
    // ring cell is blank: the policy cannot select it there.
    let sweep_worlds: &[usize] = if quick { &[16, 64] } else { &[16, 64, 128, 256] };
    let sweep_elems: &[usize] = if quick {
        &[262_144]
    } else {
        &[262_144, 1_048_576]
    };
    let sweep_ops: &[CollOp] = if quick {
        &[CollOp::AllReduce]
    } else {
        &[CollOp::AllReduce, CollOp::Broadcast]
    };
    for &op in sweep_ops {
        for &world in sweep_worlds {
            let hosts = (world / 16).max(2);
            let layout = format!("{hosts}x{}", world / hosts);
            for &elems in sweep_elems {
                let iters = 2;
                let (flat_s, flat_cs) =
                    time_op(op, world, elems, iters, CollAlgo::Flat, Some(&layout));
                let (hier_s, hier_cs) =
                    time_op(op, world, elems, iters, CollAlgo::Hier, Some(&layout));
                assert_eq!(
                    flat_cs,
                    hier_cs,
                    "flat and hier {} disagree at world={world} layout={layout}",
                    op.name()
                );
                let ring = if world <= CollAlgo::RING_MAX_WORLD {
                    let (ring_s, ring_cs) =
                        time_op(op, world, elems, iters, CollAlgo::Ring, Some(&layout));
                    assert_eq!(
                        flat_cs,
                        ring_cs,
                        "flat and ring {} disagree at world={world} layout={layout}",
                        op.name()
                    );
                    Some(ring_s)
                } else {
                    None
                };
                cell(&mut table, op, world, hosts, elems * 4, flat_s, ring, Some(hier_s));
            }
        }
    }

    table.emit("ablation_collectives");
    write_json(
        "BENCH_collectives",
        &Json::obj(vec![
            ("bench", Json::str("ablation_collectives")),
            ("meta", bench_meta()),
            ("quick", Json::num(if quick { 1.0 } else { 0.0 })),
            ("cells", Json::arr(traj)),
        ]),
    );
    show_auto_prologue();
    println!(
        "paper shape: parity at world 2; bandwidth-bound rings (all_reduce, \
         broadcast, reduce) win on >=4MB payloads at world >=4 (root NIC is \
         the flat bottleneck); hier beats the whole-world ring from ~16 ranks \
         x 2 hosts and is the only non-flat choice past {} ranks; Auto \
         crossovers per the MW_RING_MIN_* policy table",
        CollAlgo::RING_MAX_WORLD
    );
}
