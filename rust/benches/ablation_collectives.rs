//! Ablation — flat (root star) vs ring (pipelined reduce-scatter +
//! all-gather) collectives, across world sizes and tensor sizes, on the
//! multi-host topology: TCP with a **per-rank** 10 Gbps NIC
//! (`WorldOptions::tcp_per_rank_limited`), so the flat root's NIC is the
//! bottleneck the ring removes.
//!
//! Expected shape: at world size 2 the two algorithms are within noise
//! (the ring degenerates to one exchange); from world size 4 upward the
//! ring wins ~size/2× on ≥4 MB tensors (flat moves ~N×S through the
//! root's NIC, ring ~2S through every NIC concurrently). `Auto` follows
//! the measured crossover: ring at ≥4 ranks and ≥1 MB.
//!
//! Checksums of both paths are asserted identical per cell
//! (integer-valued tensors make f32 summation order-independent).

use multiworld::bench::Table;
use multiworld::config::CollAlgo;
use multiworld::mwccl::transport::ratelimit::RATE_10GBPS;
use multiworld::mwccl::{Rendezvous, ReduceOp, WorldOptions};
use multiworld::tensor::Tensor;
use std::time::{Duration, Instant};

fn uniq(name: &str) -> String {
    use std::sync::atomic::{AtomicU64, Ordering};
    static N: AtomicU64 = AtomicU64::new(0);
    format!(
        "abl-{name}-{}-{}",
        std::process::id(),
        N.fetch_add(1, Ordering::Relaxed)
    )
}

/// Integer-valued tensor: exact, order-independent f32 sums.
fn int_tensor(elems: usize, rank: usize) -> Tensor {
    let vals: Vec<f32> = (0..elems)
        .map(|i| ((i as u64 * 13 + rank as u64 * 5 + 1) % 97) as f32)
        .collect();
    Tensor::from_f32(&[elems], &vals)
}

/// Mean seconds per all_reduce plus the (rank-0) result checksum.
fn time_all_reduce(size: usize, elems: usize, iters: usize, algo: CollAlgo) -> (f64, u64) {
    let opts = WorldOptions::tcp_per_rank_limited(RATE_10GBPS)
        .with_coll_algo(algo)
        .with_op_timeout(Duration::from_secs(120));
    let worlds = Rendezvous::single_process(&uniq("ar"), size, opts).unwrap();
    let handles: Vec<_> = worlds
        .into_iter()
        .map(|w| {
            let t = int_tensor(elems, w.rank());
            std::thread::spawn(move || {
                // Warmup synchronizes all ranks and fills buffer pools.
                let _ = w.all_reduce(t.clone(), ReduceOp::Sum).unwrap();
                let t0 = Instant::now();
                let mut cs = 0u64;
                for _ in 0..iters {
                    cs = w.all_reduce(t.clone(), ReduceOp::Sum).unwrap().checksum();
                }
                (t0.elapsed().as_secs_f64(), cs)
            })
        })
        .collect();
    let mut worst = 0.0f64;
    let mut checksum = 0u64;
    for h in handles {
        let (dt, cs) = h.join().unwrap();
        worst = worst.max(dt);
        checksum = cs; // identical on every rank (asserted by tests)
    }
    (worst / iters as f64, checksum)
}

fn main() {
    let quick = std::env::var("MW_BENCH_QUICK").as_deref() == Ok("1");
    let mut table = Table::new(
        "Ablation — flat vs ring all_reduce, tcp with per-rank 10 Gbps NICs",
        &["world", "tensor", "flat", "ring", "ring/flat speedup", "auto picks"],
    );
    let sizes: &[usize] = if quick { &[2, 4] } else { &[2, 4, 8] };
    let elem_counts: &[(usize, &str)] = if quick {
        &[(65_536, "256 KB"), (1_048_576, "4 MB")]
    } else {
        &[
            (65_536, "256 KB"),
            (262_144, "1 MB"),
            (1_048_576, "4 MB"),
            (4_194_304, "16 MB"),
        ]
    };
    for &world in sizes {
        for &(elems, label) in elem_counts {
            let iters = if elems >= 1_048_576 { 3 } else { 5 };
            let (flat_s, flat_cs) = time_all_reduce(world, elems, iters, CollAlgo::Flat);
            let (ring_s, ring_cs) = time_all_reduce(world, elems, iters, CollAlgo::Ring);
            assert_eq!(
                flat_cs, ring_cs,
                "flat and ring all_reduce disagree at world={world} elems={elems}"
            );
            let auto = if CollAlgo::Auto.use_ring(world, Some(elems * 4)) {
                "ring"
            } else {
                "flat"
            };
            table.row(&[
                world.to_string(),
                label.to_string(),
                format!("{:.1} ms", flat_s * 1e3),
                format!("{:.1} ms", ring_s * 1e3),
                format!("{:.2}x", flat_s / ring_s),
                auto.to_string(),
            ]);
        }
    }
    table.emit("ablation_collectives");
    println!(
        "paper shape: parity at world 2; ring ≥2x on ≥4MB tensors at world ≥4 \
         (root NIC is the flat bottleneck); Auto crossover at ≥4 ranks / ≥1MB"
    );
}
