//! Figure 1 — tensor forwarding through a Kafka-style message bus:
//! throughput by tensor size plus the sender/receiver time split across
//! device-copy / serialize / network.
//!
//! Paper numbers for shape comparison: ≈147 MB/s at 400 KB tensors; up
//! to 45% of sender time and 53% of receiver time spent in the copy +
//! serialize stages. Our "device" copy is a 3 GB/s-paced memcpy
//! (DESIGN.md documents the PCIe substitution).

use multiworld::baselines::msgbus::{Broker, BusClient, DeviceStage};
use multiworld::bench::Table;
use multiworld::tensor::Tensor;
use multiworld::util::fmt_rate;
use multiworld::util::prng::Rng;
use std::time::{Duration, Instant};

fn main() {
    let quick = std::env::var("MW_BENCH_QUICK").as_deref() == Ok("1");
    let sizes: [(usize, &str); 4] =
        [(1_000, "4K"), (10_000, "40K"), (100_000, "400K"), (1_000_000, "4M")];
    let mut table = Table::new(
        "Fig 1 — tensor forwarding via message bus",
        &["size", "throughput", "send copy%", "send ser%", "recv copy%", "recv ser%"],
    );
    for (elems, label) in sizes {
        let msgs = if quick { 16 } else { 64.min(20_000_000 / (elems * 4)).max(8) };
        let broker = Broker::start().unwrap();
        let producer = BusClient::connect(broker.addr(), DeviceStage::pcie()).unwrap();
        let consumer = BusClient::connect(broker.addr(), DeviceStage::pcie()).unwrap();
        let mut rng = Rng::new(1);
        let t = Tensor::f32_1d(elems, &mut rng);
        let topic = format!("acts-{label}");
        let bytes = (elems * 4 * msgs) as f64;
        let t0 = Instant::now();
        let feeder = std::thread::spawn(move || {
            for _ in 0..msgs {
                producer.publish_tensor(&topic, &t).unwrap();
            }
            producer
        });
        let topic2 = format!("acts-{label}");
        for k in 0..msgs {
            consumer
                .fetch_tensor(&topic2, k as u64, Duration::from_secs(30))
                .unwrap()
                .expect("record");
        }
        let dt = t0.elapsed().as_secs_f64();
        let producer = feeder.join().unwrap();
        let split = |c: &BusClient| {
            let copy = *c.time_copy.lock().unwrap();
            let ser = *c.time_serialize.lock().unwrap();
            let net = *c.time_network.lock().unwrap();
            let total = (copy + ser + net).max(1e-12);
            (100.0 * copy / total, 100.0 * ser / total)
        };
        let (s_copy, s_ser) = split(&producer);
        let (r_copy, r_ser) = split(&consumer);
        table.row(&[
            label.to_string(),
            fmt_rate(bytes / dt),
            format!("{s_copy:.0}%"),
            format!("{s_ser:.0}%"),
            format!("{r_copy:.0}%"),
            format!("{r_ser:.0}%"),
        ]);
    }
    table.emit("fig1_msgbus");
    println!("paper shape: ~147 MB/s @400K; copy+serialize ≈45% send / ≈53% recv");
}
