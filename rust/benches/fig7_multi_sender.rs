//! Figure 7 (a–d) — aggregate throughput at one receiver as the number
//! of senders grows from 1 to 3, for tensor sizes 4 KB / 40 KB / 400 KB
//! / 4 MB, MultiWorld vs single world (intra-host path).
//!
//! Paper shape to reproduce: MW within 1.4–4.3% of SW in most cells;
//! worst case ≈14.6% behind at (3 senders, 400 KB); negligible at 4 MB.

use multiworld::bench::scenarios::{best_of, msgs_for, mw_fanin_throughput, sw_fanin_throughput, PAPER_SIZES};
use multiworld::bench::Table;
use multiworld::multiworld::{PollStrategy, StatePolicy};
use multiworld::mwccl::WorldOptions;
use multiworld::util::fmt_rate;

fn main() {
    let quick = std::env::var("MW_BENCH_QUICK").as_deref() == Ok("1");
    for (elems, label) in PAPER_SIZES {
        let mut table = Table::new(
            &format!("Fig 7 — aggregate throughput, tensor size {label}"),
            &["senders", "MW", "SW", "MW/SW", "overhead"],
        );
        for senders in 1..=3usize {
            let msgs = (if quick { msgs_for(elems) / 8 } else { msgs_for(elems) } / senders)
                .max(8);
            let reps = if quick { 2 } else { 3 };
            let mw = best_of(reps, || {
                mw_fanin_throughput(
                    senders,
                    elems,
                    msgs,
                    WorldOptions::shm(),
                    StatePolicy::Kv,
                    PollStrategy::SpinYield,
                )
            });
            let sw = best_of(reps, || sw_fanin_throughput(senders, elems, msgs, WorldOptions::shm()));
            let overhead = 100.0 * (1.0 - mw / sw);
            table.row(&[
                senders.to_string(),
                fmt_rate(mw),
                fmt_rate(sw),
                format!("{:.3}", mw / sw),
                format!("{overhead:+.1}%"),
            ]);
        }
        table.emit(&format!("fig7_{label}"));
    }
    println!("paper shape: overhead 1.4–4.3% typical, worst ≈14.6% at (3 senders, 400K)");
}
