//! Figure 6b — host-to-host throughput: one sender, one receiver over
//! TCP capped at the paper's 10 Gbps inter-VM bandwidth.
//!
//! Paper shape to reproduce: MW ≈ SW and both saturate the link as the
//! tensor grows; MP is poor at small sizes but becomes comparable at
//! 4 MB (the link, not the IPC, is the bottleneck there).

use multiworld::bench::scenarios::{
    best_of, mp_p2p_throughput, msgs_for, mw_fanin_throughput, sw_fanin_throughput, PAPER_SIZES,
};
use multiworld::bench::Table;
use multiworld::multiworld::{PollStrategy, StatePolicy};
use multiworld::mwccl::transport::ratelimit::{RateLimiter, RATE_10GBPS};
use multiworld::mwccl::WorldOptions;
use multiworld::util::fmt_rate;
use std::sync::Arc;

fn main() {
    let quick = std::env::var("MW_BENCH_QUICK").as_deref() == Ok("1");
    let mut table = Table::new(
        "Fig 6b — host-to-host (tcp @ 10 Gbps) throughput, 1 sender → 1 receiver",
        &["size", "MP", "MW", "SW", "MW/SW", "link-util(SW)"],
    );
    for (elems, label) in PAPER_SIZES {
        let msgs = (if quick { msgs_for(elems) / 8 } else { msgs_for(elems) })
            .min(if elems >= 1_000_000 { 48 } else { 512 })
            .max(8);
        // Each architecture gets its own fresh 10 Gbps "NIC".
        let reps = if quick { 2 } else { 3 };
        let mw = best_of(reps, || {
            mw_fanin_throughput(
                1,
                elems,
                msgs,
                WorldOptions::tcp_limited(Arc::new(RateLimiter::new(RATE_10GBPS))),
                StatePolicy::Kv,
                PollStrategy::SpinYield,
            )
        });
        let sw = best_of(reps, || {
            sw_fanin_throughput(
                1,
                elems,
                msgs,
                WorldOptions::tcp_limited(Arc::new(RateLimiter::new(RATE_10GBPS))),
            )
        });
        // MP's proxies use plain tcp (loopback is far faster than
        // 10 Gbps, so the pipe hop remains MP's limiting factor at small
        // sizes, matching the paper's crossover at 4 MB).
        let mp = best_of(reps, || mp_p2p_throughput(elems, msgs.min(128), "tcp").unwrap_or(0.0));
        table.row(&[
            label.to_string(),
            fmt_rate(mp),
            fmt_rate(mw),
            fmt_rate(sw),
            format!("{:.3}", mw / sw),
            format!("{:.0}%", 100.0 * sw / RATE_10GBPS),
        ]);
    }
    table.emit("fig6b_interhost");
    println!("paper shape: MW≈SW saturating 10 Gbps at 4M; MP catches up only at 4M");
}
