//! Serving trajectory artifact (`BENCH_serving.json`): the headline
//! serving numbers CI uploads on every push so regressions in
//! throughput, tail latency, or recovery time are visible across
//! commits — built from the same `bench::scenarios` the paper-figure
//! benches and integration tests use:
//!
//! * `tp_pipeline` — 2-stage × tp=2 forward-only pipeline, closed-loop:
//!   end-to-end throughput and p99 through the full leader/batching/
//!   collective stack;
//! * `autoscale` — open-loop burst curve through the always-on ingress
//!   with the closed-loop autoscaler live: completion accounting, p99,
//!   and the scale-out/in action counts;
//! * `chaos` — gray partition + hard replica kill under traffic:
//!   zero-loss completion, retry count, and MTTR (kill → controller's
//!   `Recovered` action);
//! * `mttr` — the recovery-latency *distribution*: repeated kills on a
//!   weight-heavy pipeline, spares=0/cache-off vs spares>0/cache-on, so
//!   `tools/check_mttr.py` can gate recovery-time regressions in CI.
//! * `continuous_batching` — the streaming decode loop at saturation
//!   with mixed decode budgets, iteration-level admission vs the gang
//!   (run-to-completion) ablation over the identical wire: request and
//!   token throughput plus client-side TTFT/ITL percentiles per leg,
//!   and the headline ≥2× throughput gate.
//! * `multi_tenant` — two tenant classes under weighted-fair admission:
//!   the steady tenant's solo-baseline latency vs. its latency while a
//!   low-weight burster floods at ~10× the solo rate, plus the
//!   burster's shed count — the isolation artifact
//!   `tools/check_tenant_isolation.py` gates fail-soft in CI.
//!
//! Every artifact carries a `meta` provenance block
//! ([`multiworld::bench::bench_meta`]): commit, branch, CI run, knobs.

use multiworld::bench::scenarios::{
    autoscale_serve, chaos_serve, multi_tenant_serve, recovery_mttr, streaming_serve,
    tp_pipeline_serve, ArrivalCurve, MttrReport, StreamReport,
};
use multiworld::bench::{bench_meta, write_json};
use multiworld::mwccl::{FaultPlan, WorldOptions};
use multiworld::util::json::Json;
use std::time::Duration;

fn stream_json(r: &StreamReport) -> Json {
    Json::obj(vec![
        ("completed", Json::num(r.completed as f64)),
        ("dropped", Json::num(r.dropped as f64)),
        ("total_tokens", Json::num(r.total_tokens as f64)),
        ("requests_per_s", Json::num(r.requests_per_s)),
        ("tokens_per_s", Json::num(r.tokens_per_s)),
        ("ttft_p50_ms", Json::num(r.ttft_p50_ms)),
        ("ttft_p99_ms", Json::num(r.ttft_p99_ms)),
        ("itl_p50_ms", Json::num(r.itl_p50_ms)),
        ("itl_p99_ms", Json::num(r.itl_p99_ms)),
    ])
}

fn mttr_json(r: &MttrReport) -> Json {
    Json::obj(vec![
        ("kills", Json::num(r.samples_ms.len() as f64)),
        ("p50_ms", Json::num(r.p50_ms)),
        ("p99_ms", Json::num(r.p99_ms)),
        ("max_ms", Json::num(r.max_ms)),
        ("promoted", Json::num(r.promoted as f64)),
        ("backfilled", Json::num(r.backfilled as f64)),
        ("samples_ms", Json::arr(r.samples_ms.iter().map(|&s| Json::num(s)).collect())),
    ])
}

fn main() {
    let quick = std::env::var("MW_BENCH_QUICK").as_deref() == Ok("1");
    let opts = || WorldOptions::shm().with_init_timeout(Duration::from_secs(120));
    // Port ranges spaced the same way the integration tests space
    // theirs, so a bench run and a test run on one box don't collide.
    let jitter = (std::process::id() % 80) as u16 * 24;

    let n_requests = if quick { 32 } else { 128 };
    let tp = tp_pipeline_serve(2, 1, 2, n_requests, opts(), 46_000 + jitter)
        .expect("tp_pipeline_serve");
    assert_eq!(tp.completed, n_requests, "tp pipeline must answer every request");
    println!(
        "tp_pipeline: {} reqs, {:.1} req/s, p99 {:.2} ms",
        tp.completed, tp.throughput_rps, tp.p99_ms
    );

    let duration = Duration::from_millis(if quick { 1_500 } else { 6_000 });
    let auto = autoscale_serve(
        ArrivalCurve::Burst { high_rps: 300.0, low_rps: 20.0, burst_frac: 0.5 },
        duration,
        opts(),
        48_200 + jitter,
    )
    .expect("autoscale_serve");
    assert_eq!(
        auto.completed + auto.rejected + auto.dropped,
        auto.submitted,
        "every submitted request resolves to exactly one outcome"
    );
    println!(
        "autoscale: {}/{} completed, p99 {:.2} ms, {} scale-outs / {} scale-ins",
        auto.completed, auto.submitted, auto.p99_ms, auto.scaled_out, auto.scaled_in
    );

    // The chaos scenario uses tcp (FaultLink wraps every link kind, but
    // the partition under test is the leader's forward edge).
    let n_chaos = if quick { 24 } else { 64 };
    let chaos = chaos_serve(
        FaultPlan::empty(7),
        n_chaos,
        WorldOptions::tcp().with_init_timeout(Duration::from_secs(120)),
        50_400 + jitter,
    )
    .expect("chaos_serve");
    assert_eq!(chaos.completed, n_chaos, "zero request loss through partition + kill");
    println!(
        "chaos: {} reqs, {} retries, {} recovered, MTTR {:.1} ms",
        chaos.completed, chaos.retries, chaos.recovered, chaos.mttr_ms
    );

    // Recovery-latency distribution: same kill count both legs, weights
    // sized so a cold load visibly dominates the re-mint. The cold leg
    // also disables the weight cache so every respawn pays the full
    // load — the pre-spares recovery path.
    let kills = if quick { 4 } else { 8 };
    let params: u64 = if quick { 4_000_000 } else { 16_000_000 };
    let cold = recovery_mttr(kills, 0, false, params, opts(), 53_000 + jitter)
        .expect("recovery_mttr cold");
    let warm = recovery_mttr(kills, 2, true, params, opts(), 54_200 + jitter)
        .expect("recovery_mttr warm");
    assert!(warm.promoted >= 1, "the spares leg must actually promote");
    println!(
        "mttr: cold p50 {:.1} / p99 {:.1} ms, spares p50 {:.1} / p99 {:.1} ms ({} promoted)",
        cold.p50_ms, cold.p99_ms, warm.p50_ms, warm.p99_ms, warm.promoted
    );

    // Continuous batching vs the gang ablation: same request mix, same
    // wire, same box — the admission rule is the only variable. The mix
    // (1-in-8 heavy) makes the structural iteration-count ratio ≈ 2.9×,
    // so the ≥2× gate holds with margin on any scheduler-noisy box.
    let n_stream = if quick { 32 } else { 64 };
    let gang = streaming_serve(n_stream, 8, 32, 2, true, opts(), 56_600 + jitter)
        .expect("streaming_serve gang");
    let cont = streaming_serve(n_stream, 8, 32, 2, false, opts(), 57_800 + jitter)
        .expect("streaming_serve continuous");
    assert_eq!(cont.completed, n_stream, "continuous leg must finish every request");
    assert_eq!(gang.completed, n_stream, "gang leg must finish every request");
    assert!(
        cont.requests_per_s >= 2.0 * gang.requests_per_s,
        "iteration-level scheduling must hold ≥2× request throughput over \
         gang scheduling at saturation: continuous {:.1} req/s vs gang {:.1} req/s",
        cont.requests_per_s,
        gang.requests_per_s
    );
    println!(
        "continuous_batching: {:.1} req/s ({:.0} tok/s, ttft p99 {:.2} ms, itl p99 {:.2} ms) \
         vs gang {:.1} req/s — {:.1}x",
        cont.requests_per_s,
        cont.tokens_per_s,
        cont.ttft_p99_ms,
        cont.itl_p99_ms,
        gang.requests_per_s,
        cont.requests_per_s / gang.requests_per_s
    );

    // Multi-tenant isolation: the steady tenant's latency with and
    // without a co-resident flood. The hard assertions here are only
    // accounting (the tolerance check is fail-soft in CI, where box
    // noise is expected).
    let n_tenant = if quick { 24 } else { 96 };
    let tenant = multi_tenant_serve(n_tenant, opts(), 59_000 + jitter)
        .expect("multi_tenant_serve");
    assert_eq!(
        tenant.steady_completed, n_tenant,
        "the steady tenant must never lose a request to the flood"
    );
    assert!(tenant.burst_shed > 0, "the flood must overflow the burster's own bound");
    println!(
        "multi_tenant: steady p99 {:.2} ms (solo {:.2} ms), steady {:.1} req/s, \
         burst {} submitted / {} completed / {} shed",
        tenant.steady_p99_ms,
        tenant.solo_p99_ms,
        tenant.steady_rps,
        tenant.burst_submitted,
        tenant.burst_completed,
        tenant.burst_shed
    );

    write_json(
        "BENCH_serving",
        &Json::obj(vec![
            ("bench", Json::str("serving_trajectory")),
            ("meta", bench_meta()),
            ("quick", Json::num(if quick { 1.0 } else { 0.0 })),
            (
                "tp_pipeline",
                Json::obj(vec![
                    ("requests", Json::num(tp.completed as f64)),
                    ("throughput_rps", Json::num(tp.throughput_rps)),
                    ("p50_ms", Json::num(tp.p50_ms)),
                    ("p99_ms", Json::num(tp.p99_ms)),
                ]),
            ),
            (
                "autoscale",
                Json::obj(vec![
                    ("submitted", Json::num(auto.submitted as f64)),
                    ("completed", Json::num(auto.completed as f64)),
                    ("rejected", Json::num(auto.rejected as f64)),
                    ("dropped", Json::num(auto.dropped as f64)),
                    ("p99_ms", Json::num(auto.p99_ms)),
                    ("scaled_out", Json::num(auto.scaled_out as f64)),
                    ("scaled_in", Json::num(auto.scaled_in as f64)),
                ]),
            ),
            (
                "chaos",
                Json::obj(vec![
                    ("requests", Json::num(chaos.completed as f64)),
                    ("retries", Json::num(chaos.retries as f64)),
                    ("recovered", Json::num(chaos.recovered as f64)),
                    ("mttr_ms", Json::num(chaos.mttr_ms)),
                ]),
            ),
            (
                "mttr",
                Json::obj(vec![
                    ("stage_params", Json::num(params as f64)),
                    ("spares0", mttr_json(&cold)),
                    ("spares2", mttr_json(&warm)),
                ]),
            ),
            (
                "continuous_batching",
                Json::obj(vec![
                    ("requests", Json::num(n_stream as f64)),
                    (
                        "speedup",
                        Json::num(cont.requests_per_s / gang.requests_per_s.max(1e-9)),
                    ),
                    ("continuous", stream_json(&cont)),
                    ("gang", stream_json(&gang)),
                ]),
            ),
            (
                "multi_tenant",
                Json::obj(vec![
                    ("steady_requests", Json::num(n_tenant as f64)),
                    ("solo_p50_ms", Json::num(tenant.solo_p50_ms)),
                    ("solo_p99_ms", Json::num(tenant.solo_p99_ms)),
                    ("solo_rps", Json::num(tenant.solo_rps)),
                    ("steady_p50_ms", Json::num(tenant.steady_p50_ms)),
                    ("steady_p99_ms", Json::num(tenant.steady_p99_ms)),
                    ("steady_rps", Json::num(tenant.steady_rps)),
                    ("steady_shed", Json::num(tenant.steady_shed as f64)),
                    ("burst_submitted", Json::num(tenant.burst_submitted as f64)),
                    ("burst_completed", Json::num(tenant.burst_completed as f64)),
                    ("burst_shed", Json::num(tenant.burst_shed as f64)),
                ]),
            ),
        ]),
    );
}
