//! Serving trajectory artifact (`BENCH_serving.json`): the headline
//! serving numbers CI uploads on every push so regressions in
//! throughput, tail latency, or recovery time are visible across
//! commits — built from the same `bench::scenarios` the paper-figure
//! benches and integration tests use:
//!
//! * `tp_pipeline` — 2-stage × tp=2 forward-only pipeline, closed-loop:
//!   end-to-end throughput and p99 through the full leader/batching/
//!   collective stack;
//! * `autoscale` — open-loop burst curve through the always-on ingress
//!   with the closed-loop autoscaler live: completion accounting, p99,
//!   and the scale-out/in action counts;
//! * `chaos` — gray partition + hard replica kill under traffic:
//!   zero-loss completion, retry count, and MTTR (kill → controller's
//!   `Recovered` action);
//! * `mttr` — the recovery-latency *distribution*: repeated kills on a
//!   weight-heavy pipeline, spares=0/cache-off vs spares>0/cache-on, so
//!   `tools/check_mttr.py` can gate recovery-time regressions in CI.
//!
//! Every artifact carries a `meta` provenance block
//! ([`multiworld::bench::bench_meta`]): commit, branch, CI run, knobs.

use multiworld::bench::scenarios::{
    autoscale_serve, chaos_serve, recovery_mttr, tp_pipeline_serve, ArrivalCurve,
    MttrReport,
};
use multiworld::bench::{bench_meta, write_json};
use multiworld::mwccl::{FaultPlan, WorldOptions};
use multiworld::util::json::Json;
use std::time::Duration;

fn mttr_json(r: &MttrReport) -> Json {
    Json::obj(vec![
        ("kills", Json::num(r.samples_ms.len() as f64)),
        ("p50_ms", Json::num(r.p50_ms)),
        ("p99_ms", Json::num(r.p99_ms)),
        ("max_ms", Json::num(r.max_ms)),
        ("promoted", Json::num(r.promoted as f64)),
        ("backfilled", Json::num(r.backfilled as f64)),
        ("samples_ms", Json::arr(r.samples_ms.iter().map(|&s| Json::num(s)).collect())),
    ])
}

fn main() {
    let quick = std::env::var("MW_BENCH_QUICK").as_deref() == Ok("1");
    let opts = || WorldOptions::shm().with_init_timeout(Duration::from_secs(120));
    // Port ranges spaced the same way the integration tests space
    // theirs, so a bench run and a test run on one box don't collide.
    let jitter = (std::process::id() % 80) as u16 * 24;

    let n_requests = if quick { 32 } else { 128 };
    let tp = tp_pipeline_serve(2, 1, 2, n_requests, opts(), 46_000 + jitter)
        .expect("tp_pipeline_serve");
    assert_eq!(tp.completed, n_requests, "tp pipeline must answer every request");
    println!(
        "tp_pipeline: {} reqs, {:.1} req/s, p99 {:.2} ms",
        tp.completed, tp.throughput_rps, tp.p99_ms
    );

    let duration = Duration::from_millis(if quick { 1_500 } else { 6_000 });
    let auto = autoscale_serve(
        ArrivalCurve::Burst { high_rps: 300.0, low_rps: 20.0, burst_frac: 0.5 },
        duration,
        opts(),
        48_200 + jitter,
    )
    .expect("autoscale_serve");
    assert_eq!(
        auto.completed + auto.rejected + auto.dropped,
        auto.submitted,
        "every submitted request resolves to exactly one outcome"
    );
    println!(
        "autoscale: {}/{} completed, p99 {:.2} ms, {} scale-outs / {} scale-ins",
        auto.completed, auto.submitted, auto.p99_ms, auto.scaled_out, auto.scaled_in
    );

    // The chaos scenario uses tcp (FaultLink wraps every link kind, but
    // the partition under test is the leader's forward edge).
    let n_chaos = if quick { 24 } else { 64 };
    let chaos = chaos_serve(
        FaultPlan::empty(7),
        n_chaos,
        WorldOptions::tcp().with_init_timeout(Duration::from_secs(120)),
        50_400 + jitter,
    )
    .expect("chaos_serve");
    assert_eq!(chaos.completed, n_chaos, "zero request loss through partition + kill");
    println!(
        "chaos: {} reqs, {} retries, {} recovered, MTTR {:.1} ms",
        chaos.completed, chaos.retries, chaos.recovered, chaos.mttr_ms
    );

    // Recovery-latency distribution: same kill count both legs, weights
    // sized so a cold load visibly dominates the re-mint. The cold leg
    // also disables the weight cache so every respawn pays the full
    // load — the pre-spares recovery path.
    let kills = if quick { 4 } else { 8 };
    let params: u64 = if quick { 4_000_000 } else { 16_000_000 };
    let cold = recovery_mttr(kills, 0, false, params, opts(), 53_000 + jitter)
        .expect("recovery_mttr cold");
    let warm = recovery_mttr(kills, 2, true, params, opts(), 54_200 + jitter)
        .expect("recovery_mttr warm");
    assert!(warm.promoted >= 1, "the spares leg must actually promote");
    println!(
        "mttr: cold p50 {:.1} / p99 {:.1} ms, spares p50 {:.1} / p99 {:.1} ms ({} promoted)",
        cold.p50_ms, cold.p99_ms, warm.p50_ms, warm.p99_ms, warm.promoted
    );

    write_json(
        "BENCH_serving",
        &Json::obj(vec![
            ("bench", Json::str("serving_trajectory")),
            ("meta", bench_meta()),
            ("quick", Json::num(if quick { 1.0 } else { 0.0 })),
            (
                "tp_pipeline",
                Json::obj(vec![
                    ("requests", Json::num(tp.completed as f64)),
                    ("throughput_rps", Json::num(tp.throughput_rps)),
                    ("p50_ms", Json::num(tp.p50_ms)),
                    ("p99_ms", Json::num(tp.p99_ms)),
                ]),
            ),
            (
                "autoscale",
                Json::obj(vec![
                    ("submitted", Json::num(auto.submitted as f64)),
                    ("completed", Json::num(auto.completed as f64)),
                    ("rejected", Json::num(auto.rejected as f64)),
                    ("dropped", Json::num(auto.dropped as f64)),
                    ("p99_ms", Json::num(auto.p99_ms)),
                    ("scaled_out", Json::num(auto.scaled_out as f64)),
                    ("scaled_in", Json::num(auto.scaled_in as f64)),
                ]),
            ),
            (
                "chaos",
                Json::obj(vec![
                    ("requests", Json::num(chaos.completed as f64)),
                    ("retries", Json::num(chaos.retries as f64)),
                    ("recovered", Json::num(chaos.recovered as f64)),
                    ("mttr_ms", Json::num(chaos.mttr_ms)),
                ]),
            ),
            (
                "mttr",
                Json::obj(vec![
                    ("stage_params", Json::num(params as f64)),
                    ("spares0", mttr_json(&cold)),
                    ("spares2", mttr_json(&warm)),
                ]),
            ),
        ]),
    );
}
