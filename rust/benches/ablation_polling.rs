//! Ablation — completion-poll strategy (§3.2/§3.3): the paper mitigates
//! polling-induced throughput loss with busy waiting, paying one CPU
//! core. This bench quantifies the trade across strategies:
//!
//! * `BusyWait`  — pure spin (the paper's choice)
//! * `SpinYield` — spin briefly, then yield (our default)
//! * `Sleep(1ms)`— naive polling (what the paper warns loses throughput)

use multiworld::bench::scenarios::mw_fanin_throughput;
use multiworld::bench::Table;
use multiworld::multiworld::{PollStrategy, StatePolicy};
use multiworld::mwccl::WorldOptions;
use multiworld::util::fmt_rate;
use std::time::Duration;

fn main() {
    let quick = std::env::var("MW_BENCH_QUICK").as_deref() == Ok("1");
    let strategies: [(&str, PollStrategy); 3] = [
        ("busy-wait", PollStrategy::BusyWait),
        ("spin+yield", PollStrategy::SpinYield),
        ("sleep 1ms", PollStrategy::Sleep(Duration::from_millis(1))),
    ];
    for (elems, label) in [(1_000usize, "4K"), (100_000usize, "400K")] {
        let mut table = Table::new(
            &format!("Ablation A2 — poll strategy, 2 senders, {label} tensors"),
            &["strategy", "throughput", "vs busy-wait"],
        );
        let msgs = if quick { 64 } else { 1024.min(40_000_000 / (elems * 4)).max(32) };
        let mut base = 0.0f64;
        for (name, strat) in strategies {
            let bps = mw_fanin_throughput(
                2,
                elems,
                msgs,
                WorldOptions::shm(),
                StatePolicy::Kv,
                strat,
            );
            if base == 0.0 {
                base = bps;
            }
            table.row(&[
                name.to_string(),
                fmt_rate(bps),
                format!("{:.2}×", bps / base),
            ]);
        }
        table.emit(&format!("ablation_polling_{label}"));
    }
    println!("paper: busy waiting trades one CPU core for throughput; naive sleeping loses it");
}
