//! Figure 6a — GPU-to-GPU (intra-host) throughput: one sender, one
//! receiver over the shared-memory transport, MultiProcessing (MP) vs
//! MultiWorld (MW) vs single world (SW).
//!
//! Paper shape to reproduce: MW ≈ SW at every size; MP far behind at
//! small tensors (IPC serialization dominates) and still ~30% of MW/SW
//! at 4 MB. Absolute GB/s here are CPU-memcpy numbers, not NVLink.

use multiworld::bench::scenarios::{
    best_of, mp_p2p_throughput, msgs_for, mw_fanin_throughput, sw_fanin_throughput, PAPER_SIZES,
};
use multiworld::bench::Table;
use multiworld::multiworld::{PollStrategy, StatePolicy};
use multiworld::mwccl::WorldOptions;
use multiworld::util::fmt_rate;

fn main() {
    let quick = std::env::var("MW_BENCH_QUICK").as_deref() == Ok("1");
    let mut table = Table::new(
        "Fig 6a — intra-host (shm) throughput, 1 sender → 1 receiver",
        &["size", "MP", "MW", "SW", "MW/SW"],
    );
    for (elems, label) in PAPER_SIZES {
        let msgs = if quick { msgs_for(elems) / 8 } else { msgs_for(elems) }.max(8);
        let reps = if quick { 2 } else { 3 };
        let mp = best_of(reps, || mp_p2p_throughput(elems, msgs.min(256), "shm").unwrap_or(0.0));
        let mw = best_of(reps, || {
            mw_fanin_throughput(
                1,
                elems,
                msgs,
                WorldOptions::shm(),
                StatePolicy::Kv,
                PollStrategy::SpinYield,
            )
        });
        let sw = best_of(reps, || sw_fanin_throughput(1, elems, msgs, WorldOptions::shm()));
        table.row(&[
            label.to_string(),
            fmt_rate(mp),
            fmt_rate(mw),
            fmt_rate(sw),
            format!("{:.3}", mw / sw),
        ]);
    }
    table.emit("fig6a_intrahost");
    println!(
        "paper shape: MW≈SW (1.4–4.3% gap), MP ≪ at small sizes and ≈30% of MW at 4M"
    );
}
