//! Element types the serving path moves around.

/// Supported element types. `BF16` is opaque 2-byte words to the
//  coordinator (PJRT does the math); `U8` carries serialized payloads.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum DType {
    F32 = 0,
    BF16 = 1,
    I32 = 2,
    U8 = 3,
}

impl DType {
    /// Bytes per element.
    pub const fn size(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::BF16 => 2,
            DType::U8 => 1,
        }
    }

    /// Wire tag → dtype.
    pub fn from_u8(v: u8) -> anyhow::Result<Self> {
        Ok(match v {
            0 => DType::F32,
            1 => DType::BF16,
            2 => DType::I32,
            3 => DType::U8,
            _ => anyhow::bail!("unknown dtype tag {v}"),
        })
    }

    /// Name as it appears in the AOT manifest ("f32", "bf16", ...).
    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::BF16 => "bf16",
            DType::I32 => "i32",
            DType::U8 => "u8",
        }
    }

    /// Parse a manifest name.
    pub fn from_name(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "f32" | "float32" => DType::F32,
            "bf16" | "bfloat16" => DType::BF16,
            "i32" | "int32" => DType::I32,
            "u8" | "uint8" => DType::U8,
            _ => anyhow::bail!("unknown dtype name {s:?}"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes() {
        assert_eq!(DType::F32.size(), 4);
        assert_eq!(DType::BF16.size(), 2);
        assert_eq!(DType::I32.size(), 4);
        assert_eq!(DType::U8.size(), 1);
    }

    #[test]
    fn tag_roundtrip() {
        for d in [DType::F32, DType::BF16, DType::I32, DType::U8] {
            assert_eq!(DType::from_u8(d as u8).unwrap(), d);
        }
        assert!(DType::from_u8(200).is_err());
    }

    #[test]
    fn name_roundtrip() {
        for d in [DType::F32, DType::BF16, DType::I32, DType::U8] {
            assert_eq!(DType::from_name(d.name()).unwrap(), d);
        }
        assert!(DType::from_name("f64").is_err());
    }
}
