//! Tensors as they travel between pipeline workers.
//!
//! A [`Tensor`] is a shaped, typed, contiguous byte buffer. The CCL moves
//! raw bytes; dtype/shape ride in a fixed 64-byte header so a receiver
//! can pre-validate before copying into its own buffer (NCCL-style ops
//! require both sides to agree on element count, which the collectives
//! enforce).
//!
//! bf16 is carried as raw u16 words — the coordinator never does math on
//! bf16, it only moves buffers between PJRT executables, so no software
//! float conversion sits on the hot path.

mod dtype;
pub mod serialize;

pub use dtype::DType;
pub use serialize::{read_tensor, write_tensor, HEADER_LEN};

use crate::util::prng::Rng;
use std::fmt;

/// Maximum rank we serialize in the fixed header.
pub const MAX_RANK: usize = 8;

/// A shaped, typed byte buffer. Data is always contiguous row-major.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    dtype: DType,
    shape: Vec<usize>,
    data: Vec<u8>,
}

impl Tensor {
    /// Zero-filled tensor.
    pub fn zeros(dtype: DType, shape: &[usize]) -> Self {
        assert!(shape.len() <= MAX_RANK, "rank {} > {}", shape.len(), MAX_RANK);
        let elems: usize = shape.iter().product();
        Tensor { dtype, shape: shape.to_vec(), data: vec![0u8; elems * dtype.size()] }
    }

    /// Build from an f32 slice.
    pub fn from_f32(shape: &[usize], values: &[f32]) -> Self {
        let elems: usize = shape.iter().product();
        assert_eq!(elems, values.len(), "shape/value mismatch");
        let mut t = Tensor::zeros(DType::F32, shape);
        t.data.copy_from_slice(bytes_of_f32(values));
        t
    }

    /// Build from an i32 slice (token ids).
    pub fn from_i32(shape: &[usize], values: &[i32]) -> Self {
        let elems: usize = shape.iter().product();
        assert_eq!(elems, values.len(), "shape/value mismatch");
        let mut t = Tensor::zeros(DType::I32, shape);
        let bytes = unsafe {
            std::slice::from_raw_parts(values.as_ptr() as *const u8, values.len() * 4)
        };
        t.data.copy_from_slice(bytes);
        t
    }

    /// Build from raw parts (validates length).
    pub fn from_bytes(dtype: DType, shape: &[usize], data: Vec<u8>) -> anyhow::Result<Self> {
        let elems: usize = shape.iter().product();
        anyhow::ensure!(
            data.len() == elems * dtype.size(),
            "byte length {} != {} elems × {}B",
            data.len(),
            elems,
            dtype.size()
        );
        anyhow::ensure!(shape.len() <= MAX_RANK, "rank too large");
        Ok(Tensor { dtype, shape: shape.to_vec(), data })
    }

    /// Random-uniform f32 tensor in [-1, 1) — synthetic activations. The
    /// paper's throughput experiments forward "a 32-bit floating point
    /// tensor whose length is 1M" etc.; this is that generator.
    pub fn rand_f32(shape: &[usize], rng: &mut Rng) -> Self {
        let mut t = Tensor::zeros(DType::F32, shape);
        rng.fill_f32(t.as_f32_mut());
        t
    }

    /// 1-D f32 tensor of `len` elements (paper sizes: 1K, 10K, … 1M).
    pub fn f32_1d(len: usize, rng: &mut Rng) -> Self {
        Self::rand_f32(&[len], rng)
    }

    pub fn dtype(&self) -> DType {
        self.dtype
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn byte_len(&self) -> usize {
        self.data.len()
    }

    pub fn bytes(&self) -> &[u8] {
        &self.data
    }

    pub fn bytes_mut(&mut self) -> &mut [u8] {
        &mut self.data
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.data
    }

    /// View as f32 (panics on other dtypes).
    pub fn as_f32(&self) -> &[f32] {
        assert_eq!(self.dtype, DType::F32, "as_f32 on {:?}", self.dtype);
        unsafe {
            std::slice::from_raw_parts(self.data.as_ptr() as *const f32, self.data.len() / 4)
        }
    }

    pub fn as_f32_mut(&mut self) -> &mut [f32] {
        assert_eq!(self.dtype, DType::F32, "as_f32_mut on {:?}", self.dtype);
        unsafe {
            std::slice::from_raw_parts_mut(
                self.data.as_mut_ptr() as *mut f32,
                self.data.len() / 4,
            )
        }
    }

    /// View as i32 (panics on other dtypes).
    pub fn as_i32(&self) -> &[i32] {
        assert_eq!(self.dtype, DType::I32, "as_i32 on {:?}", self.dtype);
        unsafe {
            std::slice::from_raw_parts(self.data.as_ptr() as *const i32, self.data.len() / 4)
        }
    }

    /// Reshape in place (element count must match).
    pub fn reshape(mut self, shape: &[usize]) -> anyhow::Result<Self> {
        let new: usize = shape.iter().product();
        anyhow::ensure!(new == self.elems(), "reshape {:?} -> {:?}", self.shape, shape);
        anyhow::ensure!(shape.len() <= MAX_RANK, "rank too large");
        self.shape = shape.to_vec();
        Ok(self)
    }

    /// FNV-1a checksum over dtype, shape and data — used by integration
    /// tests to prove bytes survive transport unmodified.
    pub fn checksum(&self) -> u64 {
        let mut h = 0xcbf29ce484222325u64;
        let mut eat = |b: u8| {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        };
        eat(self.dtype as u8);
        for &d in &self.shape {
            for b in (d as u64).to_le_bytes() {
                eat(b);
            }
        }
        for &b in &self.data {
            eat(b);
        }
        h
    }

    /// Element-wise sum into self (f32 only) — the reduction kernel for
    /// all_reduce/reduce with `ReduceOp::Sum`.
    pub fn add_assign(&mut self, other: &Tensor) {
        assert_eq!(self.dtype, DType::F32);
        assert_eq!(self.shape, other.shape, "add_assign shape mismatch");
        let a = self.as_f32_mut();
        let b = other.as_f32();
        for (x, y) in a.iter_mut().zip(b) {
            *x += *y;
        }
    }

    /// Element-wise max into self (f32 only).
    pub fn max_assign(&mut self, other: &Tensor) {
        assert_eq!(self.dtype, DType::F32);
        assert_eq!(self.shape, other.shape, "max_assign shape mismatch");
        let a = self.as_f32_mut();
        let b = other.as_f32();
        for (x, y) in a.iter_mut().zip(b) {
            *x = x.max(*y);
        }
    }

    /// Scale all elements (f32 only) — `ReduceOp::Avg` divides by world size.
    pub fn scale(&mut self, k: f32) {
        for x in self.as_f32_mut() {
            *x *= k;
        }
    }

    /// Split a rank-≥1 tensor into `n` equal chunks along axis 0
    /// (scatter). Errors if axis 0 is not divisible by `n`.
    pub fn chunk(&self, n: usize) -> anyhow::Result<Vec<Tensor>> {
        anyhow::ensure!(self.rank() >= 1, "chunk on rank-0 tensor");
        anyhow::ensure!(n > 0 && self.shape[0] % n == 0, "axis0 {} not divisible by {n}", self.shape[0]);
        let rows = self.shape[0] / n;
        let mut sub_shape = self.shape.clone();
        sub_shape[0] = rows;
        let chunk_bytes = self.data.len() / n;
        Ok((0..n)
            .map(|i| Tensor {
                dtype: self.dtype,
                shape: sub_shape.clone(),
                data: self.data[i * chunk_bytes..(i + 1) * chunk_bytes].to_vec(),
            })
            .collect())
    }

    /// Concatenate along axis 0 (all_gather/gather inverse of `chunk`).
    pub fn concat(parts: &[Tensor]) -> anyhow::Result<Tensor> {
        anyhow::ensure!(!parts.is_empty(), "concat of nothing");
        let first = &parts[0];
        let mut shape = first.shape.clone();
        let mut data = Vec::with_capacity(parts.iter().map(|p| p.data.len()).sum());
        let mut rows = 0usize;
        for p in parts {
            anyhow::ensure!(p.dtype == first.dtype, "dtype mismatch in concat");
            anyhow::ensure!(p.shape[1..] == first.shape[1..], "trailing shape mismatch");
            rows += p.shape[0];
            data.extend_from_slice(&p.data);
        }
        shape[0] = rows;
        Ok(Tensor { dtype: first.dtype, shape, data })
    }
}

fn bytes_of_f32(v: &[f32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "Tensor<{:?}>{:?} ({} bytes, fnv={:016x})",
            self.dtype,
            self.shape,
            self.byte_len(),
            self.checksum()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_views() {
        let t = Tensor::zeros(DType::F32, &[2, 3]);
        assert_eq!(t.elems(), 6);
        assert_eq!(t.byte_len(), 24);
        assert!(t.as_f32().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn from_f32_roundtrip() {
        let t = Tensor::from_f32(&[4], &[1.0, -2.5, 3.25, 0.0]);
        assert_eq!(t.as_f32(), &[1.0, -2.5, 3.25, 0.0]);
    }

    #[test]
    fn checksum_detects_mutation() {
        let mut r = Rng::new(1);
        let mut t = Tensor::rand_f32(&[128], &mut r);
        let before = t.checksum();
        t.as_f32_mut()[7] += 1.0;
        assert_ne!(before, t.checksum());
    }

    #[test]
    fn checksum_covers_shape() {
        let t = Tensor::zeros(DType::F32, &[2, 8]);
        let u = Tensor::zeros(DType::F32, &[4, 4]);
        assert_ne!(t.checksum(), u.checksum());
    }

    #[test]
    fn add_assign_sums() {
        let mut a = Tensor::from_f32(&[3], &[1.0, 2.0, 3.0]);
        let b = Tensor::from_f32(&[3], &[10.0, 20.0, 30.0]);
        a.add_assign(&b);
        assert_eq!(a.as_f32(), &[11.0, 22.0, 33.0]);
    }

    #[test]
    fn chunk_concat_inverse() {
        let mut r = Rng::new(2);
        let t = Tensor::rand_f32(&[8, 5], &mut r);
        let parts = t.chunk(4).unwrap();
        assert_eq!(parts.len(), 4);
        assert_eq!(parts[0].shape(), &[2, 5]);
        let back = Tensor::concat(&parts).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn chunk_rejects_indivisible() {
        let t = Tensor::zeros(DType::F32, &[7, 2]);
        assert!(t.chunk(3).is_err());
    }

    #[test]
    fn reshape_checks_elems() {
        let t = Tensor::zeros(DType::F32, &[6]);
        assert!(t.clone().reshape(&[2, 3]).is_ok());
        assert!(t.reshape(&[4, 2]).is_err());
    }

    #[test]
    fn i32_tokens() {
        let t = Tensor::from_i32(&[2, 2], &[1, 2, 3, 4]);
        assert_eq!(t.as_i32(), &[1, 2, 3, 4]);
        assert_eq!(t.dtype(), DType::I32);
    }
}
