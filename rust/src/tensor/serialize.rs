//! Fixed-header tensor framing for streams and ring buffers.
//!
//! Layout (little-endian), total 64 bytes of header then the payload:
//!
//! ```text
//!   0..4    magic  "MWT1"
//!   4..5    dtype tag
//!   5..6    rank
//!   6..8    reserved (zero)
//!   8..16   payload byte length (u64)
//!  16..64   shape dims, 6×u64 used (MAX_RANK=8 dims packed as u48 would
//!           be cute; we keep 6 u64 slots and spill ranks 7..8 into the
//!           first two via validation — in practice serving tensors are
//!           rank ≤ 4)
//! ```
//!
//! The header is deliberately fixed-size so the shm ring can reserve
//! space without a second pass, and so a receiver can sanity-check the
//! length *before* allocating.

use super::{DType, Tensor, MAX_RANK};
use std::io::{Read, Write};

/// Serialized header length in bytes.
pub const HEADER_LEN: usize = 64;

const MAGIC: &[u8; 4] = b"MWT1";
/// Shape slots in the fixed header.
const SHAPE_SLOTS: usize = 6;

/// Encode the header into a 64-byte array.
pub fn encode_header(t: &Tensor) -> anyhow::Result<[u8; HEADER_LEN]> {
    anyhow::ensure!(
        t.rank() <= SHAPE_SLOTS,
        "rank {} exceeds wire limit {SHAPE_SLOTS}",
        t.rank()
    );
    let mut h = [0u8; HEADER_LEN];
    h[0..4].copy_from_slice(MAGIC);
    h[4] = t.dtype() as u8;
    h[5] = t.rank() as u8;
    h[8..16].copy_from_slice(&(t.byte_len() as u64).to_le_bytes());
    for (i, &d) in t.shape().iter().enumerate() {
        let off = 16 + i * 8;
        h[off..off + 8].copy_from_slice(&(d as u64).to_le_bytes());
    }
    Ok(h)
}

/// Decode a header; returns (dtype, shape, payload_len).
pub fn decode_header(h: &[u8]) -> anyhow::Result<(DType, Vec<usize>, usize)> {
    anyhow::ensure!(h.len() >= HEADER_LEN, "short header");
    anyhow::ensure!(&h[0..4] == MAGIC, "bad tensor magic {:?}", &h[0..4]);
    let dtype = DType::from_u8(h[4])?;
    let rank = h[5] as usize;
    anyhow::ensure!(rank <= MAX_RANK.min(SHAPE_SLOTS), "bad rank {rank}");
    let payload = u64::from_le_bytes(h[8..16].try_into().unwrap()) as usize;
    let mut shape = Vec::with_capacity(rank);
    for i in 0..rank {
        let off = 16 + i * 8;
        shape.push(u64::from_le_bytes(h[off..off + 8].try_into().unwrap()) as usize);
    }
    // Checked arithmetic: a corrupted header must be rejected, not
    // overflow (found by prop_dtype_header_rejects_corruption).
    let elems = shape
        .iter()
        .try_fold(1usize, |acc, &d| acc.checked_mul(d))
        .ok_or_else(|| anyhow::anyhow!("shape element product overflows"))?;
    let expect = elems
        .checked_mul(dtype.size())
        .ok_or_else(|| anyhow::anyhow!("byte length overflows"))?;
    anyhow::ensure!(
        payload == expect,
        "header inconsistent: payload {payload} != {elems} elems × {}B",
        dtype.size()
    );
    Ok((dtype, shape, payload))
}

/// Write header + payload to a stream.
pub fn write_tensor<W: Write>(w: &mut W, t: &Tensor) -> anyhow::Result<()> {
    let h = encode_header(t)?;
    w.write_all(&h)?;
    w.write_all(t.bytes())?;
    Ok(())
}

/// Read one tensor from a stream (blocking until complete).
pub fn read_tensor<R: Read>(r: &mut R) -> anyhow::Result<Tensor> {
    let mut h = [0u8; HEADER_LEN];
    r.read_exact(&mut h)?;
    let (dtype, shape, payload) = decode_header(&h)?;
    let mut data = vec![0u8; payload];
    r.read_exact(&mut data)?;
    Tensor::from_bytes(dtype, &shape, data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn stream_roundtrip() {
        let mut rng = Rng::new(5);
        for shape in [vec![1usize], vec![16, 8], vec![2, 3, 4, 5]] {
            let t = Tensor::rand_f32(&shape, &mut rng);
            let mut buf = Vec::new();
            write_tensor(&mut buf, &t).unwrap();
            assert_eq!(buf.len(), HEADER_LEN + t.byte_len());
            let back = read_tensor(&mut buf.as_slice()).unwrap();
            assert_eq!(back, t);
            assert_eq!(back.checksum(), t.checksum());
        }
    }

    #[test]
    fn rejects_bad_magic() {
        let t = Tensor::zeros(DType::F32, &[4]);
        let mut buf = Vec::new();
        write_tensor(&mut buf, &t).unwrap();
        buf[0] = b'X';
        assert!(read_tensor(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn rejects_inconsistent_length() {
        let t = Tensor::zeros(DType::F32, &[4]);
        let mut buf = Vec::new();
        write_tensor(&mut buf, &t).unwrap();
        // Corrupt payload length.
        buf[8] = 0xFF;
        assert!(read_tensor(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn rejects_high_rank_on_wire() {
        let t = Tensor::zeros(DType::F32, &[1, 1, 1, 1, 1, 1, 1]);
        assert!(encode_header(&t).is_err());
    }

    #[test]
    fn empty_tensor_roundtrip() {
        let t = Tensor::zeros(DType::U8, &[0]);
        let mut buf = Vec::new();
        write_tensor(&mut buf, &t).unwrap();
        let back = read_tensor(&mut buf.as_slice()).unwrap();
        assert_eq!(back.elems(), 0);
    }
}
