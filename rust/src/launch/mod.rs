//! Deployment: bring a pipeline topology to life.
//!
//! Two launchers share all serving code:
//!
//! * [`inproc::InProcCluster`] — every node is a thread in this process.
//!   Transports, stores, watchdogs and failure signals are the real
//!   ones (sockets, mmap rings); only the process boundary is
//!   collapsed. Used by tests and most benches; supports abrupt "kill"
//!   of a worker.
//! * [`process::ProcessCluster`] — every worker is a real OS process
//!   running `multiworld worker`; kill(2) is the failure injector. Used
//!   by the examples for end-to-end fidelity.
//!
//! [`control::ControlPlane`] carries topology updates (online
//! instantiation) to worker processes through a cluster-wide TCPStore;
//! in-process workers get the same updates over their mpsc control
//! channels directly.

pub mod control;
pub mod inproc;
pub mod process;

pub use control::{ControlPlane, LoadSample};
pub use inproc::InProcCluster;
pub use process::ProcessCluster;
