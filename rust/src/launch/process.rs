//! Process cluster: workers are real OS processes running
//! `multiworld worker`; failure injection is `SIGKILL`. The leader stays
//! in the calling process; topology updates reach workers through the
//! [`super::ControlPlane`] store.
//!
//! **Spares.** With `spares > 0` ([`ProcessCluster::start_with_spares`],
//! or `MW_SPARES` via [`crate::config::ServingConfig`] at the call
//! site), the cluster also launches that many `multiworld worker
//! --spare-id N` processes. A spare loads the full model runtime at
//! startup — every stage AOT-compiled, weights resident, the expensive
//! half of a cold spawn — then blocks on the cluster store key
//! `spare/{N}/assign`. [`ProcessCluster::promote_spare`] publishes a
//! node identity (plus an optional fresh-worlds override file) under
//! that key, turning the spare into a regular worker without paying the
//! load again; [`ProcessCluster::backfill_spares`] tops the pool back
//! up asynchronously. Spares are torn down *before* workers on drop so
//! a dying pool never publishes half-finished joins into live worlds.

use crate::serving::topology::{NodeId, Topology, WorldDef};
use crate::store::{StoreClient, StoreServer};
use crate::util::free_port;
use std::collections::HashMap;
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::{Arc, Mutex};

/// A worker subprocess.
struct ProcHandle {
    child: Child,
}

/// An idle pre-warmed subprocess, blocked on its assignment key.
struct SpareProc {
    id: usize,
    child: Child,
}

/// See module docs.
pub struct ProcessCluster {
    pub topology: Topology,
    pub artifacts: PathBuf,
    /// Cluster store hosting the control plane.
    pub cluster_store: Arc<StoreServer>,
    /// Cached client to the cluster store — promotions publish through
    /// this instead of dialing per call (the pooled client shares one
    /// socket process-wide anyway; caching also skips the pool lookup).
    cluster_client: Arc<StoreClient>,
    pub cluster_port: u16,
    topo_file: PathBuf,
    procs: Mutex<HashMap<NodeId, ProcHandle>>,
    spares: Mutex<Vec<SpareProc>>,
    /// Pool size to restore on [`Self::backfill_spares`].
    spare_target: usize,
    /// Monotonic spare id — assignment keys are never reused.
    spare_seq: std::sync::atomic::AtomicUsize,
    transport: String,
}

impl ProcessCluster {
    /// Write the topology file, host the cluster store and spawn one
    /// `multiworld worker` process per worker node. The caller then
    /// builds its `Leader` against the same topology.
    pub fn start(
        topo: Topology,
        artifacts: PathBuf,
        transport: &str,
    ) -> anyhow::Result<ProcessCluster> {
        Self::start_with_spares(topo, artifacts, transport, 0)
    }

    /// [`Self::start`] plus a pool of `spares` pre-warmed standby
    /// processes (see module docs).
    pub fn start_with_spares(
        topo: Topology,
        artifacts: PathBuf,
        transport: &str,
        spares: usize,
    ) -> anyhow::Result<ProcessCluster> {
        let cluster_port = free_port();
        let cluster_store = Arc::new(StoreServer::bind(&format!("127.0.0.1:{cluster_port}"))?);
        let cluster_client = Arc::new(StoreClient::connect(
            format!("127.0.0.1:{cluster_port}").parse()?,
            std::time::Duration::from_secs(5),
        )?);
        let topo_file =
            std::env::temp_dir().join(format!("mw-topo-{}-{cluster_port}.json", std::process::id()));
        topo.save(&topo_file)?;
        let cluster = ProcessCluster {
            topology: topo,
            artifacts,
            cluster_store,
            cluster_client,
            cluster_port,
            topo_file,
            procs: Mutex::new(HashMap::new()),
            spares: Mutex::new(Vec::new()),
            spare_target: spares,
            spare_seq: std::sync::atomic::AtomicUsize::new(0),
            transport: transport.to_string(),
        };
        for node in cluster.topology.workers() {
            cluster.spawn_worker(node, None)?;
        }
        for _ in 0..spares {
            cluster.spawn_spare()?;
        }
        Ok(cluster)
    }

    /// Spawn one worker process. `extra_worlds` (for replacements) is a
    /// JSON file of additional world defs beyond the topology file.
    pub fn spawn_worker(
        &self,
        node: NodeId,
        extra_worlds: Option<&[WorldDef]>,
    ) -> anyhow::Result<()> {
        let exe = std::env::current_exe()?;
        let mut cmd = Command::new(exe);
        cmd.arg("worker")
            .arg("--topology")
            .arg(&self.topo_file)
            .arg("--node")
            .arg(node.to_string())
            .arg("--artifacts")
            .arg(&self.artifacts)
            .arg("--cluster-port")
            .arg(self.cluster_port.to_string())
            .arg("--transport")
            .arg(&self.transport)
            .stdout(Stdio::inherit())
            .stderr(Stdio::inherit());
        if let Some(worlds) = extra_worlds {
            // Replacement workers join only their fresh worlds, passed
            // through a dedicated file.
            cmd.arg("--worlds-override")
                .arg(self.write_worlds_override(node, worlds)?);
        }
        let child = cmd.spawn()?;
        self.procs.lock().unwrap().insert(node, ProcHandle { child });
        Ok(())
    }

    /// World-override file for a replacement worker joining only its
    /// fresh worlds (shared by [`Self::spawn_worker`] and
    /// [`Self::promote_spare`]).
    fn write_worlds_override(
        &self,
        node: NodeId,
        worlds: &[WorldDef],
    ) -> anyhow::Result<PathBuf> {
        let mut t = Topology {
            replicas: self.topology.replicas.clone(),
            tp: self.topology.tp.clone(),
            worlds: worlds.to_vec(),
            prefix: self.topology.prefix.clone(),
            generation: self.topology.generation,
            hosts: self.topology.hosts.clone(),
        };
        t.worlds.retain(|w| w.rank_of(node).is_some());
        let path = std::env::temp_dir()
            .join(format!("mw-worlds-{}-{node}.json", std::process::id()));
        t.save(&path)?;
        Ok(path)
    }

    /// Launch one pre-warmed standby process (no node identity yet).
    pub fn spawn_spare(&self) -> anyhow::Result<()> {
        let id = self.spare_seq.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let exe = std::env::current_exe()?;
        let child = Command::new(exe)
            .arg("worker")
            .arg("--spare-id")
            .arg(id.to_string())
            .arg("--topology")
            .arg(&self.topo_file)
            .arg("--artifacts")
            .arg(&self.artifacts)
            .arg("--cluster-port")
            .arg(self.cluster_port.to_string())
            .arg("--transport")
            .arg(&self.transport)
            .stdout(Stdio::inherit())
            .stderr(Stdio::inherit())
            .spawn()?;
        let mut pool = self.spares.lock().unwrap();
        pool.push(SpareProc { id, child });
        crate::metrics::global().gauge("serving.spares.pool").set(pool.len() as i64);
        Ok(())
    }

    /// Hand a dead worker's identity to a pooled spare by publishing it
    /// under the spare's assignment key. Returns `false` when the pool
    /// is empty (caller falls back to [`Self::spawn_worker`]).
    pub fn promote_spare(
        &self,
        node: NodeId,
        extra_worlds: Option<&[WorldDef]>,
    ) -> anyhow::Result<bool> {
        let spare = {
            let mut pool = self.spares.lock().unwrap();
            let s = pool.pop();
            crate::metrics::global().gauge("serving.spares.pool").set(pool.len() as i64);
            s
        };
        let Some(spare) = spare else { return Ok(false) };
        let worlds_path = match extra_worlds {
            Some(w) => self
                .write_worlds_override(node, w)?
                .to_string_lossy()
                .into_owned(),
            None => String::new(),
        };
        let payload = format!("{node}\n{worlds_path}");
        self.cluster_client
            .set(&format!("spare/{}/assign", spare.id), payload.as_bytes())?;
        self.procs.lock().unwrap().insert(node, ProcHandle { child: spare.child });
        crate::metrics::global().counter("serving.spares.promoted").inc();
        Ok(true)
    }

    /// Top the pool back up to the configured size (reaping spares that
    /// died on their own first). Returns how many were launched.
    pub fn backfill_spares(&self) -> anyhow::Result<usize> {
        {
            let mut pool = self.spares.lock().unwrap();
            pool.retain_mut(|s| match s.child.try_wait() {
                Ok(Some(_)) => false,
                _ => true,
            });
        }
        let mut launched = 0;
        while self.spare_count() < self.spare_target {
            self.spawn_spare()?;
            crate::metrics::global().counter("serving.spares.backfilled").inc();
            launched += 1;
        }
        Ok(launched)
    }

    /// Idle spares currently pooled.
    pub fn spare_count(&self) -> usize {
        self.spares.lock().unwrap().len()
    }

    /// SIGKILL a worker — the real failure injector.
    pub fn kill(&self, node: NodeId) -> anyhow::Result<bool> {
        let handle = self.procs.lock().unwrap().remove(&node);
        match handle {
            Some(mut h) => {
                h.child.kill()?;
                let _ = h.child.wait();
                Ok(true)
            }
            None => Ok(false),
        }
    }

    /// Wait for a worker to exit by itself (drain/shutdown).
    pub fn wait(&self, node: NodeId) -> anyhow::Result<Option<i32>> {
        let handle = self.procs.lock().unwrap().remove(&node);
        match handle {
            Some(mut h) => {
                let status = h.child.wait()?;
                Ok(status.code())
            }
            None => Ok(None),
        }
    }

    pub fn live_workers(&self) -> Vec<NodeId> {
        let mut v: Vec<NodeId> = self.procs.lock().unwrap().keys().copied().collect();
        v.sort();
        v
    }
}

impl Drop for ProcessCluster {
    fn drop(&mut self) {
        // Spares first: an idle spare that outlives the workers could
        // win an assignment race against teardown and join a world
        // that's already being dismantled.
        let mut spares = self.spares.lock().unwrap();
        for s in spares.iter_mut() {
            let _ = s.child.kill();
            let _ = s.child.wait();
        }
        spares.clear();
        drop(spares);
        let mut procs = self.procs.lock().unwrap();
        for (_, h) in procs.iter_mut() {
            let _ = h.child.kill();
            let _ = h.child.wait();
        }
        procs.clear();
        let _ = std::fs::remove_file(&self.topo_file);
    }
}
