//! Process cluster: workers are real OS processes running
//! `multiworld worker`; failure injection is `SIGKILL`. The leader stays
//! in the calling process; topology updates reach workers through the
//! [`super::ControlPlane`] store.

use crate::serving::topology::{NodeId, Topology, WorldDef};
use crate::store::StoreServer;
use crate::util::free_port;
use std::collections::HashMap;
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::{Arc, Mutex};

/// A worker subprocess.
struct ProcHandle {
    child: Child,
}

/// See module docs.
pub struct ProcessCluster {
    pub topology: Topology,
    pub artifacts: PathBuf,
    /// Cluster store hosting the control plane.
    pub cluster_store: Arc<StoreServer>,
    pub cluster_port: u16,
    topo_file: PathBuf,
    procs: Mutex<HashMap<NodeId, ProcHandle>>,
    transport: String,
}

impl ProcessCluster {
    /// Write the topology file, host the cluster store and spawn one
    /// `multiworld worker` process per worker node. The caller then
    /// builds its `Leader` against the same topology.
    pub fn start(
        topo: Topology,
        artifacts: PathBuf,
        transport: &str,
    ) -> anyhow::Result<ProcessCluster> {
        let cluster_port = free_port();
        let cluster_store =
            Arc::new(StoreServer::bind(&format!("127.0.0.1:{cluster_port}"))?);
        let topo_file =
            std::env::temp_dir().join(format!("mw-topo-{}-{cluster_port}.json", std::process::id()));
        topo.save(&topo_file)?;
        let cluster = ProcessCluster {
            topology: topo,
            artifacts,
            cluster_store,
            cluster_port,
            topo_file,
            procs: Mutex::new(HashMap::new()),
            transport: transport.to_string(),
        };
        for node in cluster.topology.workers() {
            cluster.spawn_worker(node, None)?;
        }
        Ok(cluster)
    }

    /// Spawn one worker process. `extra_worlds` (for replacements) is a
    /// JSON file of additional world defs beyond the topology file.
    pub fn spawn_worker(
        &self,
        node: NodeId,
        extra_worlds: Option<&[WorldDef]>,
    ) -> anyhow::Result<()> {
        let exe = std::env::current_exe()?;
        let mut cmd = Command::new(exe);
        cmd.arg("worker")
            .arg("--topology")
            .arg(&self.topo_file)
            .arg("--node")
            .arg(node.to_string())
            .arg("--artifacts")
            .arg(&self.artifacts)
            .arg("--cluster-port")
            .arg(self.cluster_port.to_string())
            .arg("--transport")
            .arg(&self.transport)
            .stdout(Stdio::inherit())
            .stderr(Stdio::inherit());
        if let Some(worlds) = extra_worlds {
            // Replacement workers join only their fresh worlds, passed
            // through a dedicated file.
            let mut t = Topology {
                replicas: self.topology.replicas.clone(),
                tp: self.topology.tp.clone(),
                worlds: worlds.to_vec(),
                prefix: self.topology.prefix.clone(),
                generation: self.topology.generation,
                hosts: self.topology.hosts.clone(),
            };
            t.worlds.retain(|w| w.rank_of(node).is_some());
            let path = std::env::temp_dir().join(format!(
                "mw-worlds-{}-{node}.json",
                std::process::id()
            ));
            t.save(&path)?;
            cmd.arg("--worlds-override").arg(path);
        }
        let child = cmd.spawn()?;
        self.procs.lock().unwrap().insert(node, ProcHandle { child });
        Ok(())
    }

    /// SIGKILL a worker — the real failure injector.
    pub fn kill(&self, node: NodeId) -> anyhow::Result<bool> {
        let handle = self.procs.lock().unwrap().remove(&node);
        match handle {
            Some(mut h) => {
                h.child.kill()?;
                let _ = h.child.wait();
                Ok(true)
            }
            None => Ok(false),
        }
    }

    /// Wait for a worker to exit by itself (drain/shutdown).
    pub fn wait(&self, node: NodeId) -> anyhow::Result<Option<i32>> {
        let handle = self.procs.lock().unwrap().remove(&node);
        match handle {
            Some(mut h) => {
                let status = h.child.wait()?;
                Ok(status.code())
            }
            None => Ok(None),
        }
    }

    pub fn live_workers(&self) -> Vec<NodeId> {
        let mut v: Vec<NodeId> = self.procs.lock().unwrap().keys().copied().collect();
        v.sort();
        v
    }
}

impl Drop for ProcessCluster {
    fn drop(&mut self) {
        let mut procs = self.procs.lock().unwrap();
        for (_, h) in procs.iter_mut() {
            let _ = h.child.kill();
            let _ = h.child.wait();
        }
        procs.clear();
        let _ = std::fs::remove_file(&self.topo_file);
    }
}
