//! In-process cluster: every pipeline node (every *shard*) is a thread
//! with its own `WorldManager` (its own watchdog, store clients and
//! links) and its own PJRT engine — the xla wrapper types are not
//! `Send`, so each worker thread compiles its stage executable itself,
//! exactly as a worker process would. Faithful down to the transport:
//! killing a worker drops its sockets and rings exactly like process
//! death (TCP peers see resets; shm peers see silence until the
//! watchdog fires).
//!
//! Two construction modes share all wiring:
//!
//! * [`InProcCluster::start`] — PJRT-backed: loads the AOT manifest and
//!   compiles one stage executable per worker thread.
//! * [`InProcCluster::start_forward_only`] — no artifacts, no engine:
//!   workers echo activations through (and still drive the TP
//!   broadcast/all_reduce inner loop on sharded replicas), so the full
//!   serving + elasticity stack is testable in CI without a PJRT build.

use crate::config::{ModelManifest, ServingConfig, StageSpec};
use crate::multiworld::{StatePolicy, WatchdogConfig, WorldEvent, WorldManager};
use crate::mwccl::WorldOptions;
use crate::runtime::Engine;
use crate::serving::autoscaler::{AutoscalePolicy, Autoscaler, AutoscalerHandle, LoadSignals};
use crate::serving::controller::{Controller, ScalingPolicy, SparePoolView, Spawner};
use crate::serving::stage_worker::{run_stage_worker, StageWorkerConfig, TopoUpdate};
use crate::serving::topology::{NodeId, Topology, WorldDef};
use crate::serving::{Leader, WorkerStats};
use crate::util::time::Clock;
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Mutex};

use std::time::Duration;

struct WorkerHandle {
    stop: Arc<AtomicBool>,
    ctrl: Sender<TopoUpdate>,
    thread: Option<std::thread::JoinHandle<anyhow::Result<WorkerStats>>>,
}

/// Assignment handed to a waiting spare: become `node`, join `worlds`.
struct SpareAssign {
    node: NodeId,
    worlds: Vec<WorldDef>,
}

/// A pre-warmed spare worker thread: weights cached, engine hot,
/// blocked on its assignment channel. Promotion turns it into a
/// [`WorkerHandle`] (same stop flag, same control channel — the
/// channels were minted at pre-warm time so nothing is created on the
/// recovery path).
struct SpareHandle {
    stop: Arc<AtomicBool>,
    assign: Sender<SpareAssign>,
    ctrl: Sender<TopoUpdate>,
    thread: Option<std::thread::JoinHandle<anyhow::Result<WorkerStats>>>,
}

impl SpareHandle {
    fn is_dead(&self) -> bool {
        match &self.thread {
            Some(t) => t.is_finished(),
            None => true,
        }
    }
}

/// The controller/autoscaler's read-only view of the pool.
struct PoolView {
    pool: Arc<Mutex<Vec<SpareHandle>>>,
}

impl SparePoolView for PoolView {
    fn available(&self) -> usize {
        self.pool.lock().unwrap().iter().filter(|s| !s.is_dead()).count()
    }
}

/// Everything a worker thread needs to become `node` — shared between
/// the cold-spawn path and spare promotion, so the two paths are
/// behaviorally identical after the load step.
struct WorkerSeed {
    node: NodeId,
    /// Private topology already retained to this node's worlds.
    topology: Topology,
    /// `(hlo_path, spec)` to compile; `None` in forward-only mode.
    stage_src: Option<(PathBuf, StageSpec)>,
    /// Spec for host→device weight-load modeling (forward-only mode;
    /// zero-sized unless the manifest carries real `params`).
    load_spec: Option<StageSpec>,
    deployment: String,
    use_cache: bool,
    opts: WorldOptions,
    wd_cfg: WatchdogConfig,
    broken_tx: Sender<(String, Option<usize>)>,
    ctrl_rx: Receiver<TopoUpdate>,
    stop: Arc<AtomicBool>,
}

/// The worker thread body: load (through the host weight cache), join
/// worlds, serve. Runs inside a freshly spawned thread (cold path) or
/// inside a promoted spare (warm path — the cache hits).
fn run_worker_seed(seed: WorkerSeed) -> anyhow::Result<WorkerStats> {
    let WorkerSeed {
        node,
        topology,
        stage_src,
        load_spec,
        deployment,
        use_cache,
        opts,
        wd_cfg,
        broken_tx,
        ctrl_rx,
        stop,
    } = seed;
    // Host→device weight load for this stage (a warm hit when a spare —
    // or any earlier spawn on this host — already materialized it).
    if let (Some(spec), NodeId::Worker { stage, .. }) = (&load_spec, node) {
        if spec.params > 0 {
            let _weights = crate::serving::spares::host_cache()
                .stage_weights(&deployment, stage, spec, use_cache);
        }
    }
    // Per-worker PJRT client, like a real worker process (skipped
    // entirely in forward-only mode). The artifact's disk read goes
    // through the host cache first.
    let stage_runner = match stage_src {
        Some((hlo_path, spec)) => {
            let _ = crate::serving::spares::host_cache().hlo_bytes(&hlo_path, use_cache);
            let engine = Engine::cpu()?;
            Some(Arc::new(engine.load_stage(&hlo_path, &spec)?))
        }
        None => None,
    };
    let mgr = WorldManager::with_options(StatePolicy::Kv, wd_cfg, Clock::system());
    // Forward this worker's broken-world events to the shared report
    // channel (mid-pipeline failures are invisible to the leader
    // otherwise); the cluster drains it into the controller.
    {
        let events = mgr.subscribe();
        std::thread::Builder::new()
            .name(format!("evt-fwd-{node}"))
            .spawn(move || {
                while let Ok(evt) = events.recv() {
                    if let WorldEvent::Broken { world, culprit, .. } = evt {
                        if broken_tx.send((world, culprit)).is_err() {
                            return;
                        }
                    }
                }
            })?;
    }
    crate::serving::stage_worker::init_node_worlds(&mgr, &topology, node, &opts)?;
    run_stage_worker(
        mgr,
        StageWorkerConfig {
            node,
            topology,
            stage: stage_runner,
            opts,
            control: Some(ctrl_rx),
            stop,
        },
    )
}

/// A whole pipeline in one process. See module docs.
pub struct InProcCluster {
    pub leader: Arc<Leader>,
    pub controller: Arc<Controller>,
    pub manifest: ModelManifest,
    opts: WorldOptions,
    serving_cfg: ServingConfig,
    workers: Arc<Mutex<HashMap<NodeId, WorkerHandle>>>,
    spawner: Arc<SpawnerInner>,
    /// Spare-pool keeper loop (reap + backfill), when `spares > 0`.
    keeper: Mutex<Option<(Arc<AtomicBool>, std::thread::JoinHandle<()>)>>,
    forwarders: Mutex<Vec<std::thread::JoinHandle<()>>>,
    autoscaler: Mutex<Option<AutoscalerHandle>>,
}

struct SpawnerInner {
    artifacts: PathBuf,
    manifest: ModelManifest,
    /// No PJRT engine, no artifacts: workers run stage-less.
    forward_only: bool,
    /// Spares the keeper maintains (`ServingConfig::spares`).
    spare_target: usize,
    /// Route spawns through the host [`crate::serving::WeightCache`].
    weight_cache: bool,
    opts: WorldOptions,
    wd_cfg: WatchdogConfig,
    workers: Arc<Mutex<HashMap<NodeId, WorkerHandle>>>,
    /// Pre-warmed spares awaiting promotion (see [`SpareHandle`]).
    pool: Arc<Mutex<Vec<SpareHandle>>>,
    spare_seq: AtomicUsize,
    controller: Mutex<Option<Arc<Controller>>>,
    topology_template: Topology,
    /// Broken-world reports (name + attributed culprit rank) from every
    /// node, drained into the controller once it exists (workers spawn
    /// before the controller).
    broken_tx: Sender<(String, Option<usize>)>,
}

impl SpawnerInner {
    /// The stage's `(hlo_path, spec)` for PJRT compilation (`None` in
    /// forward-only mode; `Err` when the manifest has no such stage).
    fn stage_src(&self, stage: usize) -> anyhow::Result<Option<(PathBuf, StageSpec)>> {
        if self.forward_only {
            return Ok(None);
        }
        let spec = self
            .manifest
            .stages
            .get(stage)
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("no stage {stage} in manifest"))?;
        Ok(Some((self.manifest.hlo_path(&spec), spec)))
    }

    /// A private topology containing only `node`'s worlds.
    fn private_topology(template: &Topology, node: NodeId, worlds: Vec<WorldDef>) -> Topology {
        let mut topo = Topology {
            replicas: template.replicas.clone(),
            tp: template.tp.clone(),
            worlds,
            prefix: template.prefix.clone(),
            generation: 0,
            hosts: template.hosts.clone(),
        };
        topo.worlds.retain(|w| w.rank_of(node).is_some());
        topo
    }

    /// Bring up `node`: promote a warm spare when one is standing by
    /// (near-zero MTTR — its weights are cached and its thread is hot,
    /// it only joins the fresh worlds), else start a cold worker
    /// thread. The pop is atomic under the pool lock, so two
    /// near-simultaneous spawns racing for one spare get exactly one
    /// promotion and one cold spawn.
    fn spawn_node(&self, node: NodeId, worlds: Vec<WorldDef>) -> anyhow::Result<()> {
        let NodeId::Worker { stage, .. } = node else {
            anyhow::bail!("can only spawn workers");
        };
        loop {
            let spare = self.pool.lock().unwrap().pop();
            let Some(mut spare) = spare else { break };
            if spare
                .assign
                .send(SpareAssign { node, worlds: worlds.clone() })
                .is_ok()
            {
                self.workers.lock().unwrap().insert(
                    node,
                    WorkerHandle {
                        stop: spare.stop,
                        ctrl: spare.ctrl,
                        thread: spare.thread.take(),
                    },
                );
                let g = crate::metrics::global();
                g.counter("serving.spares.promoted").inc();
                g.gauge("serving.spares.pool")
                    .set(self.pool.lock().unwrap().len() as i64);
                crate::metrics::log_event(
                    "spares.promoted",
                    &[("node", node.to_string().as_str())],
                );
                return Ok(());
            }
            // This spare died while idle (its assignment receiver is
            // gone): reap it and try the next; the keeper backfills.
            if let Some(t) = spare.thread.take() {
                let _ = t.join();
            }
        }
        let stage_src = self.stage_src(stage)?;
        let stop = Arc::new(AtomicBool::new(false));
        let (ctrl_tx, ctrl_rx) = std::sync::mpsc::channel();
        let seed = WorkerSeed {
            node,
            topology: Self::private_topology(&self.topology_template, node, worlds),
            stage_src,
            load_spec: self.manifest.stages.get(stage).cloned(),
            deployment: self.topology_template.prefix.clone(),
            use_cache: self.weight_cache,
            opts: self.opts.clone(),
            wd_cfg: self.wd_cfg.clone(),
            broken_tx: self.broken_tx.clone(),
            ctrl_rx,
            stop: stop.clone(),
        };
        let thread = std::thread::Builder::new()
            .name(format!("worker-{node}"))
            .spawn(move || run_worker_seed(seed))?;
        self.workers.lock().unwrap().insert(
            node,
            WorkerHandle { stop, ctrl: ctrl_tx, thread: Some(thread) },
        );
        Ok(())
    }

    /// Start one pre-warmed spare: its thread warms the host weight
    /// cache for *every* stage (promotion can land it anywhere in the
    /// pipeline), then blocks on its assignment channel. Both its
    /// channels exist from birth, so promotion creates nothing.
    fn spawn_spare(self: &Arc<Self>) -> anyhow::Result<()> {
        let id = self.spare_seq.fetch_add(1, Ordering::Relaxed);
        let stop = Arc::new(AtomicBool::new(false));
        let (assign_tx, assign_rx) = std::sync::mpsc::channel::<SpareAssign>();
        let (ctrl_tx, ctrl_rx) = std::sync::mpsc::channel();
        let inner = self.clone();
        let stop2 = stop.clone();
        let thread = std::thread::Builder::new()
            .name(format!("spare-{id}"))
            .spawn(move || -> anyhow::Result<WorkerStats> {
                let cache = crate::serving::spares::host_cache();
                let deployment = inner.topology_template.prefix.clone();
                if inner.weight_cache {
                    cache.warm(&deployment, &inner.manifest);
                }
                if !inner.forward_only {
                    for spec in &inner.manifest.stages {
                        let _ = cache
                            .hlo_bytes(&inner.manifest.hlo_path(spec), inner.weight_cache);
                    }
                }
                // Warm and ready: wait for promotion (or teardown).
                let assign = loop {
                    if stop2.load(Ordering::Relaxed) {
                        return Ok(WorkerStats::default());
                    }
                    match assign_rx.recv_timeout(Duration::from_millis(20)) {
                        Ok(a) => break a,
                        Err(std::sync::mpsc::RecvTimeoutError::Timeout) => continue,
                        Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                            return Ok(WorkerStats::default())
                        }
                    }
                };
                let SpareAssign { node, worlds } = assign;
                let NodeId::Worker { stage, .. } = node else {
                    anyhow::bail!("spares can only become workers");
                };
                let seed = WorkerSeed {
                    node,
                    topology: Self::private_topology(
                        &inner.topology_template,
                        node,
                        worlds,
                    ),
                    stage_src: inner.stage_src(stage)?,
                    load_spec: inner.manifest.stages.get(stage).cloned(),
                    deployment,
                    use_cache: inner.weight_cache,
                    opts: inner.opts.clone(),
                    wd_cfg: inner.wd_cfg.clone(),
                    broken_tx: inner.broken_tx.clone(),
                    ctrl_rx,
                    stop: stop2,
                };
                run_worker_seed(seed)
            })?;
        let mut pool = self.pool.lock().unwrap();
        pool.push(SpareHandle { stop, assign: assign_tx, ctrl: ctrl_tx, thread: Some(thread) });
        crate::metrics::global()
            .gauge("serving.spares.pool")
            .set(pool.len() as i64);
        Ok(())
    }

    /// One keeper pass: reap spares that died idle, backfill the pool
    /// to `spare_target`. Returns how many were backfilled.
    fn keep_spares(self: &Arc<Self>) -> usize {
        let deficit = {
            let mut pool = self.pool.lock().unwrap();
            pool.retain_mut(|s| {
                if s.is_dead() {
                    if let Some(t) = s.thread.take() {
                        let _ = t.join();
                    }
                    false
                } else {
                    true
                }
            });
            crate::metrics::global()
                .gauge("serving.spares.pool")
                .set(pool.len() as i64);
            self.spare_target.saturating_sub(pool.len())
        };
        let mut filled = 0;
        for _ in 0..deficit {
            if self.spawn_spare().is_ok() {
                crate::metrics::global().counter("serving.spares.backfilled").inc();
                filled += 1;
            }
        }
        if filled > 0 {
            crate::metrics::log_event(
                "spares.backfilled",
                &[("count", filled.to_string().as_str())],
            );
        }
        filled
    }
}

/// Spawner that launches worker threads inside this cluster.
struct ThreadSpawner {
    inner: Arc<SpawnerInner>,
}

impl Spawner for ThreadSpawner {
    fn spawn(&self, node: NodeId, worlds: Vec<WorldDef>) -> anyhow::Result<()> {
        self.inner.spawn_node(node, worlds)?;
        // Register the fresh worker's control channel with the controller.
        if let Some(ctl) = self.inner.controller.lock().unwrap().clone() {
            if let Some(h) = self.inner.workers.lock().unwrap().get(&node) {
                ctl.register_worker(node, h.ctrl.clone());
            }
        }
        Ok(())
    }
}

impl InProcCluster {
    /// Bring up leader + all workers of `topo` with PJRT-compiled stage
    /// executables, wire the controller, and wait until every world is
    /// established.
    pub fn start(
        topo: Topology,
        artifacts: PathBuf,
        opts: WorldOptions,
        policy: ScalingPolicy,
        serving_cfg: &ServingConfig,
    ) -> anyhow::Result<InProcCluster> {
        let manifest = ModelManifest::load(artifacts.join("model.json"))?;
        Self::start_inner(topo, artifacts, manifest, false, opts, policy, serving_cfg)
    }

    /// Bring up a forward-only cluster: no artifacts, no PJRT — workers
    /// echo activations (sharded replicas still run the TP
    /// broadcast/all_reduce inner loop). `batch`/`seq_len`/`vocab`
    /// shape the leader's synthetic request tensors.
    pub fn start_forward_only(
        topo: Topology,
        opts: WorldOptions,
        policy: ScalingPolicy,
        serving_cfg: &ServingConfig,
        batch: usize,
        seq_len: usize,
        vocab: usize,
    ) -> anyhow::Result<InProcCluster> {
        let manifest = ModelManifest::synthetic(topo.n_stages(), batch, seq_len, vocab);
        Self::start_inner(topo, PathBuf::new(), manifest, true, opts, policy, serving_cfg)
    }

    /// [`Self::start_forward_only`] with a caller-built manifest —
    /// benches size `StageSpec::params` to make the host→device weight
    /// load a real cost, which is what the spare pool + weight cache
    /// exist to elide (the default synthetic manifest has `params: 0`).
    pub fn start_forward_only_with_manifest(
        topo: Topology,
        manifest: ModelManifest,
        opts: WorldOptions,
        policy: ScalingPolicy,
        serving_cfg: &ServingConfig,
    ) -> anyhow::Result<InProcCluster> {
        Self::start_inner(topo, PathBuf::new(), manifest, true, opts, policy, serving_cfg)
    }

    #[allow(clippy::too_many_arguments)]
    fn start_inner(
        topo: Topology,
        artifacts: PathBuf,
        manifest: ModelManifest,
        forward_only: bool,
        opts: WorldOptions,
        policy: ScalingPolicy,
        serving_cfg: &ServingConfig,
    ) -> anyhow::Result<InProcCluster> {
        let wd_cfg = WatchdogConfig {
            heartbeat: Duration::from_millis(serving_cfg.heartbeat_ms),
            miss_threshold: serving_cfg.miss_threshold,
        };
        let workers = Arc::new(Mutex::new(HashMap::new()));
        let (broken_tx, broken_rx) = std::sync::mpsc::channel::<(String, Option<usize>)>();
        let spawner_inner = Arc::new(SpawnerInner {
            artifacts: artifacts.clone(),
            manifest: manifest.clone(),
            forward_only,
            spare_target: serving_cfg.spares,
            weight_cache: serving_cfg.weight_cache,
            opts: opts.clone(),
            wd_cfg: wd_cfg.clone(),
            workers: workers.clone(),
            pool: Arc::new(Mutex::new(Vec::new())),
            spare_seq: AtomicUsize::new(0),
            controller: Mutex::new(None),
            topology_template: topo.clone(),
            broken_tx: broken_tx.clone(),
        });

        // Workers first (their world inits block until peers arrive, so
        // spawn all, then the leader joins and everything rendezvouses).
        for node in topo.workers() {
            let worlds: Vec<WorldDef> =
                topo.worlds_of(node).into_iter().cloned().collect();
            spawner_inner.spawn_node(node, worlds)?;
        }

        let leader_mgr =
            WorldManager::with_options(StatePolicy::Kv, wd_cfg, Clock::system());
        let leader = Leader::new(
            leader_mgr,
            &topo,
            &opts,
            manifest.batch,
            manifest.seq_len,
            manifest.vocab,
            serving_cfg,
        )?;

        // Controller wiring.
        let leader_for_join = leader.clone();
        let opts_for_join = opts.clone();
        let controller = Arc::new(Controller::new(
            topo.clone(),
            policy,
            Box::new(ThreadSpawner { inner: spawner_inner.clone() }),
            move |def| leader_for_join.join_world(def, &opts_for_join),
        ));
        *spawner_inner.controller.lock().unwrap() = Some(controller.clone());
        {
            let ws = workers.lock().unwrap();
            for (node, h) in ws.iter() {
                controller.register_worker(*node, h.ctrl.clone());
            }
        }

        // Leader's own broken-world events also feed the report channel…
        let events = leader.manager().subscribe();
        let leader_tx = broken_tx.clone();
        let fwd = std::thread::spawn(move || {
            while let Ok(evt) = events.recv() {
                if let WorldEvent::Broken { world, culprit, .. } = evt {
                    if leader_tx.send((world, culprit)).is_err() {
                        return;
                    }
                }
            }
        });
        // …and one drainer routes every report into the controller
        // (reports queued before the controller existed included; the
        // controller's own metrics/log_event make each report visible).
        let ctl2 = controller.clone();
        let drainer = std::thread::spawn(move || {
            while let Ok((world, culprit)) = broken_rx.recv() {
                if let Err(e) = ctl2.on_world_broken(&world, culprit) {
                    // Recovery failures must be visible, not swallowed —
                    // the controller already counted/logged specifics.
                    crate::metrics::log_event(
                        "cluster.recovery_error",
                        &[("world", world.as_str()), ("error", e.to_string().as_str())],
                    );
                }
            }
        });
        let _ = &spawner_inner.artifacts; // reserved for worlds-override spawns

        // Spare pool (`MW_SPARES`): pre-warm the configured number of
        // spares synchronously — callers may kill a worker right after
        // start and the first promotion must find a warm pool — then
        // hand the keeper loop the reap/backfill duty and give the
        // controller its headroom view.
        let keeper = if serving_cfg.spares > 0 {
            for _ in 0..serving_cfg.spares {
                spawner_inner.spawn_spare()?;
            }
            controller.set_spare_pool(Arc::new(PoolView {
                pool: spawner_inner.pool.clone(),
            }));
            let keeper_stop = Arc::new(AtomicBool::new(false));
            let ks = keeper_stop.clone();
            let inner = spawner_inner.clone();
            let thread = std::thread::Builder::new()
                .name("spare-keeper".into())
                .spawn(move || {
                    while !ks.load(Ordering::Relaxed) {
                        std::thread::sleep(Duration::from_millis(20));
                        if ks.load(Ordering::Relaxed) {
                            break;
                        }
                        inner.keep_spares();
                    }
                })?;
            Some((keeper_stop, thread))
        } else {
            None
        };

        Ok(InProcCluster {
            leader,
            controller,
            manifest,
            opts,
            serving_cfg: serving_cfg.clone(),
            workers,
            spawner: spawner_inner,
            keeper: Mutex::new(keeper),
            forwarders: Mutex::new(vec![fwd, drainer]),
            autoscaler: Mutex::new(None),
        })
    }

    /// Start the closed-loop autoscaler: samples the leader's live load
    /// signals (queue depth, recent p99, replica liveness) and drives
    /// the controller's scale-out/in with hysteresis + cooldown.
    /// Idempotent per cluster: a second call replaces the loop.
    pub fn start_autoscaler(&self, policy: AutoscalePolicy) {
        self.leader.start_runtime();
        let signals: Arc<dyn LoadSignals> = self.leader.clone();
        let scaler = Autoscaler::new(self.controller.clone(), signals, policy);
        *self.autoscaler.lock().unwrap() = Some(scaler.start());
    }

    /// [`Self::start_autoscaler`] with the policy derived from the
    /// cluster's `ServingConfig` (so the `MW_SLO_MS` /
    /// `MW_AUTOSCALE_{INTERVAL,COOLDOWN}_MS` env knobs apply when the
    /// config came from `ServingConfig::from_env`).
    pub fn start_autoscaler_default(&self) {
        self.start_autoscaler(AutoscalePolicy::from_config(&self.serving_cfg));
    }

    /// Abruptly kill a worker: its thread exits without any goodbye, its
    /// manager drops (heartbeats stop, sockets close). Equivalent to
    /// SIGKILL at the transport level.
    pub fn kill(&self, node: NodeId) -> bool {
        let handle = self.workers.lock().unwrap().remove(&node);
        match handle {
            Some(h) => {
                h.stop.store(true, Ordering::Relaxed);
                if let Some(t) = h.thread {
                    let _ = t.join();
                }
                true
            }
            None => false,
        }
    }

    /// Graceful scale-in of a worker's replica (drain + retire).
    pub fn retire(&self, node: NodeId) -> anyhow::Result<()> {
        self.controller.scale_in(node)?;
        let NodeId::Worker { stage, replica, .. } = node else {
            return Ok(());
        };
        let shards: Vec<NodeId> = {
            let ws = self.workers.lock().unwrap();
            ws.keys()
                .filter(|n| n.in_replica(stage, replica))
                .copied()
                .collect()
        };
        for shard in shards {
            self.kill(shard);
        }
        Ok(())
    }

    /// Living worker nodes (every shard). Workers whose threads exited
    /// (graceful scale-in retirement) are reaped here.
    pub fn live_workers(&self) -> Vec<NodeId> {
        let mut ws = self.workers.lock().unwrap();
        let done: Vec<NodeId> = ws
            .iter()
            .filter(|(_, h)| match &h.thread {
                None => true,
                Some(t) => t.is_finished(),
            })
            .map(|(n, _)| *n)
            .collect();
        for n in done {
            if let Some(mut h) = ws.remove(&n) {
                if let Some(t) = h.thread.take() {
                    let _ = t.join();
                }
            }
        }
        let mut v: Vec<NodeId> = ws.keys().copied().collect();
        v.sort();
        v
    }

    pub fn world_options(&self) -> &WorldOptions {
        &self.opts
    }

    /// The runtime fault handle: inject/heal network faults on the
    /// cluster's **live** links mid-traffic (stalls, partitions, drops,
    /// truncations — see [`crate::mwccl::transport::fault`]). Links are
    /// only fault-controllable when the cluster's [`WorldOptions`]
    /// carry a [`crate::mwccl::FaultPlan`]
    /// (`WorldOptions::with_fault_plan`, or the `MW_FAULT_PLAN` /
    /// `MW_FAULT_SEED` env knobs); the registry itself is process-wide,
    /// exposed here so chaos drivers reach it through the cluster they
    /// are attacking.
    pub fn faults(&self) -> &'static crate::mwccl::FaultRegistry {
        crate::mwccl::fault_registry()
    }

    /// Spares currently warm in the pool (dead-but-unreaped spares are
    /// not counted).
    pub fn spare_count(&self) -> usize {
        self.spawner.pool.lock().unwrap().iter().filter(|s| !s.is_dead()).count()
    }

    /// Kill one idle spare (abruptly, like [`Self::kill`]): its thread
    /// exits without touching any serving replica; the keeper backfills
    /// the pool. Returns `false` when the pool is empty.
    pub fn kill_spare(&self) -> bool {
        let spare = self.spawner.pool.lock().unwrap().pop();
        match spare {
            Some(mut s) => {
                s.stop.store(true, Ordering::Relaxed);
                drop(s.assign);
                if let Some(t) = s.thread.take() {
                    let _ = t.join();
                }
                true
            }
            None => false,
        }
    }

    /// Stop everything (leader worlds drop with the Leader): keeper
    /// first (no backfills against a dying cluster), then the
    /// autoscaler (no scaling decisions either), then the leader's
    /// runtime threads, then the spares, then the workers.
    pub fn shutdown(&self) {
        if let Some((stop, thread)) = self.keeper.lock().unwrap().take() {
            stop.store(true, Ordering::Relaxed);
            let _ = thread.join();
        }
        if let Some(h) = self.autoscaler.lock().unwrap().take() {
            h.stop();
        }
        self.leader.stop_runtime();
        {
            let mut pool = self.spawner.pool.lock().unwrap();
            for s in pool.iter_mut() {
                s.stop.store(true, Ordering::Relaxed);
            }
            for mut s in pool.drain(..) {
                drop(s.assign);
                if let Some(t) = s.thread.take() {
                    let _ = t.join();
                }
            }
        }
        crate::serving::spares::host_cache().evict(&self.spawner.topology_template.prefix);
        let mut ws = self.workers.lock().unwrap();
        for (_, h) in ws.iter_mut() {
            h.stop.store(true, Ordering::Relaxed);
            let _ = h.ctrl.send(TopoUpdate::Shutdown);
        }
        for (_, h) in ws.iter_mut() {
            if let Some(t) = h.thread.take() {
                let _ = t.join();
            }
        }
        ws.clear();
        self.forwarders.lock().unwrap().clear();
    }
}

impl Drop for InProcCluster {
    fn drop(&mut self) {
        self.shutdown();
    }
}
