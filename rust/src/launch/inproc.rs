//! In-process cluster: every pipeline node (every *shard*) is a thread
//! with its own `WorldManager` (its own watchdog, store clients and
//! links) and its own PJRT engine — the xla wrapper types are not
//! `Send`, so each worker thread compiles its stage executable itself,
//! exactly as a worker process would. Faithful down to the transport:
//! killing a worker drops its sockets and rings exactly like process
//! death (TCP peers see resets; shm peers see silence until the
//! watchdog fires).
//!
//! Two construction modes share all wiring:
//!
//! * [`InProcCluster::start`] — PJRT-backed: loads the AOT manifest and
//!   compiles one stage executable per worker thread.
//! * [`InProcCluster::start_forward_only`] — no artifacts, no engine:
//!   workers echo activations through (and still drive the TP
//!   broadcast/all_reduce inner loop on sharded replicas), so the full
//!   serving + elasticity stack is testable in CI without a PJRT build.

use crate::config::{ModelManifest, ServingConfig};
use crate::multiworld::{StatePolicy, WatchdogConfig, WorldEvent, WorldManager};
use crate::mwccl::WorldOptions;
use crate::runtime::Engine;
use crate::serving::autoscaler::{AutoscalePolicy, Autoscaler, AutoscalerHandle, LoadSignals};
use crate::serving::controller::{Controller, ScalingPolicy, Spawner};
use crate::serving::stage_worker::{run_stage_worker, StageWorkerConfig, TopoUpdate};
use crate::serving::topology::{NodeId, Topology, WorldDef};
use crate::serving::{Leader, WorkerStats};
use crate::util::time::Clock;
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Mutex};

use std::time::Duration;

struct WorkerHandle {
    stop: Arc<AtomicBool>,
    ctrl: Sender<TopoUpdate>,
    thread: Option<std::thread::JoinHandle<anyhow::Result<WorkerStats>>>,
}

/// A whole pipeline in one process. See module docs.
pub struct InProcCluster {
    pub leader: Arc<Leader>,
    pub controller: Arc<Controller>,
    pub manifest: ModelManifest,
    opts: WorldOptions,
    serving_cfg: ServingConfig,
    workers: Arc<Mutex<HashMap<NodeId, WorkerHandle>>>,
    forwarders: Mutex<Vec<std::thread::JoinHandle<()>>>,
    autoscaler: Mutex<Option<AutoscalerHandle>>,
}

struct SpawnerInner {
    artifacts: PathBuf,
    manifest: ModelManifest,
    /// No PJRT engine, no artifacts: workers run stage-less.
    forward_only: bool,
    opts: WorldOptions,
    wd_cfg: WatchdogConfig,
    workers: Arc<Mutex<HashMap<NodeId, WorkerHandle>>>,
    controller: Mutex<Option<Arc<Controller>>>,
    topology_template: Topology,
    /// Broken-world reports (name + attributed culprit rank) from every
    /// node, drained into the controller once it exists (workers spawn
    /// before the controller).
    broken_tx: Sender<(String, Option<usize>)>,
}

impl SpawnerInner {
    /// Start one worker thread that joins exactly the worlds in
    /// `worlds` it is a member of. The PJRT engine and stage executable
    /// are created *inside* the thread.
    fn spawn_node(&self, node: NodeId, worlds: Vec<WorldDef>) -> anyhow::Result<()> {
        let NodeId::Worker { stage, .. } = node else {
            anyhow::bail!("can only spawn workers");
        };
        let stage_src = if self.forward_only {
            None
        } else {
            let spec = self
                .manifest
                .stages
                .get(stage)
                .cloned()
                .ok_or_else(|| anyhow::anyhow!("no stage {stage} in manifest"))?;
            let hlo_path = self.manifest.hlo_path(&spec);
            Some((hlo_path, spec))
        };
        let stop = Arc::new(AtomicBool::new(false));
        let (ctrl_tx, ctrl_rx) = std::sync::mpsc::channel();
        // A private topology containing only this node's worlds.
        let mut topo = Topology {
            replicas: self.topology_template.replicas.clone(),
            tp: self.topology_template.tp.clone(),
            worlds,
            prefix: self.topology_template.prefix.clone(),
            generation: 0,
            hosts: self.topology_template.hosts.clone(),
        };
        topo.worlds.retain(|w| w.rank_of(node).is_some());
        let opts = self.opts.clone();
        let wd_cfg = self.wd_cfg.clone();
        let stop2 = stop.clone();
        let broken_tx = self.broken_tx.clone();
        let thread = std::thread::Builder::new()
            .name(format!("worker-{node}"))
            .spawn(move || -> anyhow::Result<WorkerStats> {
                // Per-worker PJRT client, like a real worker process
                // (skipped entirely in forward-only mode).
                let stage_runner = match stage_src {
                    Some((hlo_path, spec)) => {
                        let engine = Engine::cpu()?;
                        Some(Arc::new(engine.load_stage(&hlo_path, &spec)?))
                    }
                    None => None,
                };
                let mgr =
                    WorldManager::with_options(StatePolicy::Kv, wd_cfg, Clock::system());
                // Forward this worker's broken-world events to the shared
                // report channel (mid-pipeline failures are invisible to
                // the leader otherwise); the cluster drains it into the
                // controller.
                {
                    let events = mgr.subscribe();
                    std::thread::Builder::new()
                        .name(format!("evt-fwd-{node}"))
                        .spawn(move || {
                            while let Ok(evt) = events.recv() {
                                if let WorldEvent::Broken { world, culprit, .. } = evt {
                                    if broken_tx.send((world, culprit)).is_err() {
                                        return;
                                    }
                                }
                            }
                        })?;
                }
                crate::serving::stage_worker::init_node_worlds(&mgr, &topo, node, &opts)?;
                run_stage_worker(
                    mgr,
                    StageWorkerConfig {
                        node,
                        topology: topo,
                        stage: stage_runner,
                        opts,
                        control: Some(ctrl_rx),
                        stop: stop2,
                    },
                )
            })?;
        self.workers.lock().unwrap().insert(
            node,
            WorkerHandle { stop, ctrl: ctrl_tx, thread: Some(thread) },
        );
        Ok(())
    }
}

/// Spawner that launches worker threads inside this cluster.
struct ThreadSpawner {
    inner: Arc<SpawnerInner>,
}

impl Spawner for ThreadSpawner {
    fn spawn(&self, node: NodeId, worlds: Vec<WorldDef>) -> anyhow::Result<()> {
        self.inner.spawn_node(node, worlds)?;
        // Register the fresh worker's control channel with the controller.
        if let Some(ctl) = self.inner.controller.lock().unwrap().clone() {
            if let Some(h) = self.inner.workers.lock().unwrap().get(&node) {
                ctl.register_worker(node, h.ctrl.clone());
            }
        }
        Ok(())
    }
}

impl InProcCluster {
    /// Bring up leader + all workers of `topo` with PJRT-compiled stage
    /// executables, wire the controller, and wait until every world is
    /// established.
    pub fn start(
        topo: Topology,
        artifacts: PathBuf,
        opts: WorldOptions,
        policy: ScalingPolicy,
        serving_cfg: &ServingConfig,
    ) -> anyhow::Result<InProcCluster> {
        let manifest = ModelManifest::load(artifacts.join("model.json"))?;
        Self::start_inner(topo, artifacts, manifest, false, opts, policy, serving_cfg)
    }

    /// Bring up a forward-only cluster: no artifacts, no PJRT — workers
    /// echo activations (sharded replicas still run the TP
    /// broadcast/all_reduce inner loop). `batch`/`seq_len`/`vocab`
    /// shape the leader's synthetic request tensors.
    pub fn start_forward_only(
        topo: Topology,
        opts: WorldOptions,
        policy: ScalingPolicy,
        serving_cfg: &ServingConfig,
        batch: usize,
        seq_len: usize,
        vocab: usize,
    ) -> anyhow::Result<InProcCluster> {
        let manifest = ModelManifest::synthetic(topo.n_stages(), batch, seq_len, vocab);
        Self::start_inner(topo, PathBuf::new(), manifest, true, opts, policy, serving_cfg)
    }

    #[allow(clippy::too_many_arguments)]
    fn start_inner(
        topo: Topology,
        artifacts: PathBuf,
        manifest: ModelManifest,
        forward_only: bool,
        opts: WorldOptions,
        policy: ScalingPolicy,
        serving_cfg: &ServingConfig,
    ) -> anyhow::Result<InProcCluster> {
        let wd_cfg = WatchdogConfig {
            heartbeat: Duration::from_millis(serving_cfg.heartbeat_ms),
            miss_threshold: serving_cfg.miss_threshold,
        };
        let workers = Arc::new(Mutex::new(HashMap::new()));
        let (broken_tx, broken_rx) = std::sync::mpsc::channel::<(String, Option<usize>)>();
        let spawner_inner = Arc::new(SpawnerInner {
            artifacts: artifacts.clone(),
            manifest: manifest.clone(),
            forward_only,
            opts: opts.clone(),
            wd_cfg: wd_cfg.clone(),
            workers: workers.clone(),
            controller: Mutex::new(None),
            topology_template: topo.clone(),
            broken_tx: broken_tx.clone(),
        });

        // Workers first (their world inits block until peers arrive, so
        // spawn all, then the leader joins and everything rendezvouses).
        for node in topo.workers() {
            let worlds: Vec<WorldDef> =
                topo.worlds_of(node).into_iter().cloned().collect();
            spawner_inner.spawn_node(node, worlds)?;
        }

        let leader_mgr =
            WorldManager::with_options(StatePolicy::Kv, wd_cfg, Clock::system());
        let leader = Leader::new(
            leader_mgr,
            &topo,
            &opts,
            manifest.batch,
            manifest.seq_len,
            manifest.vocab,
            serving_cfg,
        )?;

        // Controller wiring.
        let leader_for_join = leader.clone();
        let opts_for_join = opts.clone();
        let controller = Arc::new(Controller::new(
            topo.clone(),
            policy,
            Box::new(ThreadSpawner { inner: spawner_inner.clone() }),
            move |def| leader_for_join.join_world(def, &opts_for_join),
        ));
        *spawner_inner.controller.lock().unwrap() = Some(controller.clone());
        {
            let ws = workers.lock().unwrap();
            for (node, h) in ws.iter() {
                controller.register_worker(*node, h.ctrl.clone());
            }
        }

        // Leader's own broken-world events also feed the report channel…
        let events = leader.manager().subscribe();
        let leader_tx = broken_tx.clone();
        let fwd = std::thread::spawn(move || {
            while let Ok(evt) = events.recv() {
                if let WorldEvent::Broken { world, culprit, .. } = evt {
                    if leader_tx.send((world, culprit)).is_err() {
                        return;
                    }
                }
            }
        });
        // …and one drainer routes every report into the controller
        // (reports queued before the controller existed included; the
        // controller's own metrics/log_event make each report visible).
        let ctl2 = controller.clone();
        let drainer = std::thread::spawn(move || {
            while let Ok((world, culprit)) = broken_rx.recv() {
                if let Err(e) = ctl2.on_world_broken(&world, culprit) {
                    // Recovery failures must be visible, not swallowed —
                    // the controller already counted/logged specifics.
                    crate::metrics::log_event(
                        "cluster.recovery_error",
                        &[("world", world.as_str()), ("error", e.to_string().as_str())],
                    );
                }
            }
        });
        let _ = &spawner_inner.artifacts; // reserved for worlds-override spawns

        Ok(InProcCluster {
            leader,
            controller,
            manifest,
            opts,
            serving_cfg: serving_cfg.clone(),
            workers,
            forwarders: Mutex::new(vec![fwd, drainer]),
            autoscaler: Mutex::new(None),
        })
    }

    /// Start the closed-loop autoscaler: samples the leader's live load
    /// signals (queue depth, recent p99, replica liveness) and drives
    /// the controller's scale-out/in with hysteresis + cooldown.
    /// Idempotent per cluster: a second call replaces the loop.
    pub fn start_autoscaler(&self, policy: AutoscalePolicy) {
        self.leader.start_runtime();
        let signals: Arc<dyn LoadSignals> = self.leader.clone();
        let scaler = Autoscaler::new(self.controller.clone(), signals, policy);
        *self.autoscaler.lock().unwrap() = Some(scaler.start());
    }

    /// [`Self::start_autoscaler`] with the policy derived from the
    /// cluster's `ServingConfig` (so the `MW_SLO_MS` /
    /// `MW_AUTOSCALE_{INTERVAL,COOLDOWN}_MS` env knobs apply when the
    /// config came from `ServingConfig::from_env`).
    pub fn start_autoscaler_default(&self) {
        self.start_autoscaler(AutoscalePolicy::from_config(&self.serving_cfg));
    }

    /// Abruptly kill a worker: its thread exits without any goodbye, its
    /// manager drops (heartbeats stop, sockets close). Equivalent to
    /// SIGKILL at the transport level.
    pub fn kill(&self, node: NodeId) -> bool {
        let handle = self.workers.lock().unwrap().remove(&node);
        match handle {
            Some(h) => {
                h.stop.store(true, Ordering::Relaxed);
                if let Some(t) = h.thread {
                    let _ = t.join();
                }
                true
            }
            None => false,
        }
    }

    /// Graceful scale-in of a worker's replica (drain + retire).
    pub fn retire(&self, node: NodeId) -> anyhow::Result<()> {
        self.controller.scale_in(node)?;
        let NodeId::Worker { stage, replica, .. } = node else {
            return Ok(());
        };
        let shards: Vec<NodeId> = {
            let ws = self.workers.lock().unwrap();
            ws.keys()
                .filter(|n| n.in_replica(stage, replica))
                .copied()
                .collect()
        };
        for shard in shards {
            self.kill(shard);
        }
        Ok(())
    }

    /// Living worker nodes (every shard). Workers whose threads exited
    /// (graceful scale-in retirement) are reaped here.
    pub fn live_workers(&self) -> Vec<NodeId> {
        let mut ws = self.workers.lock().unwrap();
        let done: Vec<NodeId> = ws
            .iter()
            .filter(|(_, h)| match &h.thread {
                None => true,
                Some(t) => t.is_finished(),
            })
            .map(|(n, _)| *n)
            .collect();
        for n in done {
            if let Some(mut h) = ws.remove(&n) {
                if let Some(t) = h.thread.take() {
                    let _ = t.join();
                }
            }
        }
        let mut v: Vec<NodeId> = ws.keys().copied().collect();
        v.sort();
        v
    }

    pub fn world_options(&self) -> &WorldOptions {
        &self.opts
    }

    /// The runtime fault handle: inject/heal network faults on the
    /// cluster's **live** links mid-traffic (stalls, partitions, drops,
    /// truncations — see [`crate::mwccl::transport::fault`]). Links are
    /// only fault-controllable when the cluster's [`WorldOptions`]
    /// carry a [`crate::mwccl::FaultPlan`]
    /// (`WorldOptions::with_fault_plan`, or the `MW_FAULT_PLAN` /
    /// `MW_FAULT_SEED` env knobs); the registry itself is process-wide,
    /// exposed here so chaos drivers reach it through the cluster they
    /// are attacking.
    pub fn faults(&self) -> &'static crate::mwccl::FaultRegistry {
        crate::mwccl::fault_registry()
    }

    /// Stop everything (leader worlds drop with the Leader): autoscaler
    /// first (no scaling decisions against a dying cluster), then the
    /// leader's runtime threads, then the workers.
    pub fn shutdown(&self) {
        if let Some(h) = self.autoscaler.lock().unwrap().take() {
            h.stop();
        }
        self.leader.stop_runtime();
        let mut ws = self.workers.lock().unwrap();
        for (_, h) in ws.iter_mut() {
            h.stop.store(true, Ordering::Relaxed);
            let _ = h.ctrl.send(TopoUpdate::Shutdown);
        }
        for (_, h) in ws.iter_mut() {
            if let Some(t) = h.thread.take() {
                let _ = t.join();
            }
        }
        ws.clear();
        self.forwarders.lock().unwrap().clear();
    }
}

impl Drop for InProcCluster {
    fn drop(&mut self) {
        self.shutdown();
    }
}
