//! Cluster control plane: topology updates and failure reports over a
//! shared TCPStore, so worker *processes* learn about online
//! instantiation without any connection to the leader's address space.
//!
//! Keys:
//! ```text
//!   ctl/seq                  counter of published updates
//!   ctl/update/<n>           JSON: {"kind":"add_world"|"shutdown", world def…}
//!   ctl/broken/<world>       failure report (world name → reason)
//! ```

use crate::serving::stage_worker::TopoUpdate;
use crate::serving::topology::WorldDef;
use crate::serving::NodeId;
use crate::store::StoreClient;
use crate::util::json::Json;
use std::net::SocketAddr;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::Duration;

/// Publisher/subscriber over the cluster store.
pub struct ControlPlane {
    store: Arc<StoreClient>,
}

impl ControlPlane {
    pub fn connect(addr: SocketAddr, timeout: Duration) -> anyhow::Result<ControlPlane> {
        Ok(ControlPlane { store: Arc::new(StoreClient::connect(addr, timeout)?) })
    }

    pub fn from_store(store: Arc<StoreClient>) -> ControlPlane {
        ControlPlane { store }
    }

    /// Publish a world-add update (online instantiation). Every node
    /// sees it; nodes that aren't members ignore it.
    pub fn publish_add_world(&self, def: &WorldDef) -> anyhow::Result<()> {
        let j = Json::obj(vec![
            ("kind", Json::str("add_world")),
            ("name", Json::str(def.name.clone())),
            ("up", Json::str(def.members[0].to_string())),
            ("down", Json::str(def.members[1].to_string())),
            ("store_port", Json::num(def.store_port as f64)),
        ]);
        self.publish(&j.to_string())
    }

    /// Publish a shutdown for one node (scale-in) or all (`None`).
    pub fn publish_shutdown(&self, node: Option<NodeId>) -> anyhow::Result<()> {
        let target = node.map(|n| n.to_string()).unwrap_or_else(|| "*".into());
        let j = Json::obj(vec![
            ("kind", Json::str("shutdown")),
            ("node", Json::str(target)),
        ]);
        self.publish(&j.to_string())
    }

    fn publish(&self, payload: &str) -> anyhow::Result<()> {
        let n = self.store.add("ctl/seq", 1)?;
        self.store.set(&format!("ctl/update/{n}"), payload.as_bytes())?;
        Ok(())
    }

    /// Report a broken world (workers call this so the controller can
    /// see mid-pipeline failures it isn't a member of).
    pub fn report_broken(&self, world: &str, reason: &str) -> anyhow::Result<()> {
        self.store
            .set(&format!("ctl/broken/{world}"), reason.as_bytes())?;
        Ok(())
    }

    /// Broken worlds reported so far.
    pub fn broken_worlds(&self) -> anyhow::Result<Vec<String>> {
        Ok(self
            .store
            .keys("ctl/broken/")?
            .into_iter()
            .filter_map(|k| k.strip_prefix("ctl/broken/").map(|s| s.to_string()))
            .collect())
    }

    /// Spawn a listener thread translating published updates into
    /// `TopoUpdate`s for `node`, delivered on `tx`. Returns a stop flag.
    pub fn listen(
        &self,
        node: NodeId,
        tx: Sender<TopoUpdate>,
    ) -> Arc<AtomicBool> {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let store = self.store.clone();
        std::thread::Builder::new()
            .name(format!("ctl-listen-{node}"))
            .spawn(move || {
                let mut next: i64 = 1;
                while !stop2.load(Ordering::Relaxed) {
                    let key = format!("ctl/update/{next}");
                    match store.wait(&key, Duration::from_millis(200)) {
                        Ok(bytes) => {
                            next += 1;
                            let Ok(text) = String::from_utf8(bytes) else { continue };
                            let Ok(j) = Json::parse(&text) else { continue };
                            match j.get("kind").and_then(|v| v.as_str()) {
                                Some("add_world") => {
                                    if let Some(def) = parse_world(&j) {
                                        if def.rank_of(node).is_some()
                                            && tx.send(TopoUpdate::AddWorld(def)).is_err()
                                        {
                                            return;
                                        }
                                    }
                                }
                                Some("shutdown") => {
                                    let target = j.get("node").and_then(|v| v.as_str());
                                    if target == Some("*")
                                        || target == Some(node.to_string().as_str())
                                    {
                                        let _ = tx.send(TopoUpdate::Shutdown);
                                        return;
                                    }
                                }
                                _ => {}
                            }
                        }
                        Err(_) => { /* timeout — loop to check stop */ }
                    }
                }
            })
            .expect("spawn control listener");
        stop
    }
}

fn parse_world(j: &Json) -> Option<WorldDef> {
    Some(WorldDef {
        name: j.get("name")?.as_str()?.to_string(),
        members: [
            NodeId::parse(j.get("up")?.as_str()?).ok()?,
            NodeId::parse(j.get("down")?.as_str()?).ok()?,
        ],
        store_port: j.get("store_port")?.as_usize()? as u16,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::StoreServer;

    fn plane() -> (StoreServer, ControlPlane) {
        let server = StoreServer::bind_any().unwrap();
        let cp = ControlPlane::connect(server.addr(), Duration::from_secs(2)).unwrap();
        (server, cp)
    }

    #[test]
    fn add_world_reaches_member_only() {
        let (server, cp) = plane();
        let member = NodeId::Worker { stage: 1, replica: 0 };
        let outsider = NodeId::Worker { stage: 2, replica: 5 };
        let (tx_m, rx_m) = std::sync::mpsc::channel();
        let (tx_o, rx_o) = std::sync::mpsc::channel();
        let cp_m = ControlPlane::connect(server.addr(), Duration::from_secs(2)).unwrap();
        let cp_o = ControlPlane::connect(server.addr(), Duration::from_secs(2)).unwrap();
        let stop_m = cp_m.listen(member, tx_m);
        let stop_o = cp_o.listen(outsider, tx_o);
        let def = WorldDef {
            name: "w-new".into(),
            members: [NodeId::Leader, member],
            store_port: 12345,
        };
        cp.publish_add_world(&def).unwrap();
        match rx_m.recv_timeout(Duration::from_secs(2)).unwrap() {
            TopoUpdate::AddWorld(got) => assert_eq!(got, def),
            other => panic!("{other:?}"),
        }
        assert!(rx_o.recv_timeout(Duration::from_millis(300)).is_err());
        stop_m.store(true, Ordering::Relaxed);
        stop_o.store(true, Ordering::Relaxed);
    }

    #[test]
    fn shutdown_targets_node_or_all() {
        let (server, cp) = plane();
        let a = NodeId::Worker { stage: 0, replica: 0 };
        let (tx, rx) = std::sync::mpsc::channel();
        let cp_a = ControlPlane::connect(server.addr(), Duration::from_secs(2)).unwrap();
        let _stop = cp_a.listen(a, tx);
        cp.publish_shutdown(Some(NodeId::Worker { stage: 9, replica: 9 }))
            .unwrap();
        cp.publish_shutdown(Some(a)).unwrap();
        // The targeted shutdown must arrive (the other is ignored).
        match rx.recv_timeout(Duration::from_secs(2)).unwrap() {
            TopoUpdate::Shutdown => {}
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn broken_world_reports_accumulate() {
        let (_server, cp) = plane();
        cp.report_broken("w1", "remote error").unwrap();
        cp.report_broken("w2", "watchdog").unwrap();
        let mut got = cp.broken_worlds().unwrap();
        got.sort();
        assert_eq!(got, vec!["w1".to_string(), "w2".to_string()]);
    }
}
