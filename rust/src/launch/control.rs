//! Cluster control plane: topology updates and failure reports over a
//! shared TCPStore, so worker *processes* learn about online
//! instantiation without any connection to the leader's address space.
//!
//! Keys:
//! ```text
//!   ctl/seq                  counter of published updates
//!   ctl/update/<n>           JSON: {"kind":"add_world"|"shutdown", world def…}
//!   ctl/broken/<world>       failure report (world name → reason)
//!   ctl/load/<stage>         live load sample (queue depth, p99, liveness)
//! ```

use crate::serving::stage_worker::TopoUpdate;
use crate::serving::topology::WorldDef;
use crate::serving::NodeId;
use crate::store::StoreClient;
use crate::util::json::Json;
use std::net::SocketAddr;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::Duration;

/// One live load sample published by the leader (see
/// [`ControlPlane::publish_load`]).
#[derive(Clone, Debug, PartialEq)]
pub struct LoadSample {
    pub queue_depth: usize,
    pub p99_ms: f64,
    pub alive_replicas: usize,
}

/// Publisher/subscriber over the cluster store.
pub struct ControlPlane {
    store: Arc<StoreClient>,
}

impl ControlPlane {
    pub fn connect(addr: SocketAddr, timeout: Duration) -> anyhow::Result<ControlPlane> {
        Ok(ControlPlane { store: Arc::new(StoreClient::connect(addr, timeout)?) })
    }

    pub fn from_store(store: Arc<StoreClient>) -> ControlPlane {
        ControlPlane { store }
    }

    /// Publish a world-add update (online instantiation). Every node
    /// sees it; nodes that aren't members ignore it. The world def
    /// (edge or multi-member TP world) rides in the shared JSON form.
    pub fn publish_add_world(&self, def: &WorldDef) -> anyhow::Result<()> {
        let j = Json::obj(vec![
            ("kind", Json::str("add_world")),
            ("world", def.to_json()),
        ]);
        self.publish(&j.to_string())
    }

    /// Publish a shutdown for one node (scale-in) or all (`None`).
    pub fn publish_shutdown(&self, node: Option<NodeId>) -> anyhow::Result<()> {
        let target = node.map(|n| n.to_string()).unwrap_or_else(|| "*".into());
        let j = Json::obj(vec![
            ("kind", Json::str("shutdown")),
            ("node", Json::str(target)),
        ]);
        self.publish(&j.to_string())
    }

    fn publish(&self, payload: &str) -> anyhow::Result<()> {
        let n = self.store.add("ctl/seq", 1)?;
        self.store.set(&format!("ctl/update/{n}"), payload.as_bytes())?;
        Ok(())
    }

    /// Report a broken world (workers call this so the controller can
    /// see mid-pipeline failures it isn't a member of). `culprit` is
    /// the attributed rank from `WorldEvent::Broken` — without it a
    /// controller can only strike-infer, which by design never convicts
    /// on TP-world-only evidence, so dropping it here would make
    /// non-head shard deaths unrecoverable across processes.
    pub fn report_broken(
        &self,
        world: &str,
        reason: &str,
        culprit: Option<usize>,
    ) -> anyhow::Result<()> {
        let j = Json::obj(vec![
            ("reason", Json::str(reason)),
            (
                "culprit",
                culprit.map(|c| Json::num(c as f64)).unwrap_or(Json::Null),
            ),
        ]);
        self.store
            .set(&format!("ctl/broken/{world}"), j.to_string().as_bytes())?;
        Ok(())
    }

    /// Publish the leader's live load sample for `stage` (queue depth,
    /// recent p99 latency, alive replicas). A process-mode autoscaler
    /// polls this instead of sharing the leader's address space — the
    /// cross-process twin of `serving::autoscaler::LoadSignals`.
    pub fn publish_load(&self, stage: usize, sample: &LoadSample) -> anyhow::Result<()> {
        let j = Json::obj(vec![
            ("queue_depth", Json::num(sample.queue_depth as f64)),
            ("p99_ms", Json::num(sample.p99_ms)),
            ("alive_replicas", Json::num(sample.alive_replicas as f64)),
        ]);
        self.store
            .set(&format!("ctl/load/{stage}"), j.to_string().as_bytes())?;
        Ok(())
    }

    /// The latest published load sample for `stage`, if any.
    pub fn load_report(&self, stage: usize) -> anyhow::Result<Option<LoadSample>> {
        let Some(bytes) = self.store.get(&format!("ctl/load/{stage}"))? else {
            return Ok(None);
        };
        let text = String::from_utf8(bytes)?;
        let j = Json::parse(&text)?;
        Ok(Some(LoadSample {
            queue_depth: j.get("queue_depth").and_then(|v| v.as_usize()).unwrap_or(0),
            p99_ms: j
                .get("p99_ms")
                .and_then(|v| v.as_f64())
                .unwrap_or(0.0),
            alive_replicas: j
                .get("alive_replicas")
                .and_then(|v| v.as_usize())
                .unwrap_or(0),
        }))
    }

    /// Broken worlds reported so far.
    pub fn broken_worlds(&self) -> anyhow::Result<Vec<String>> {
        Ok(self
            .store
            .keys("ctl/broken/")?
            .into_iter()
            .filter_map(|k| k.strip_prefix("ctl/broken/").map(|s| s.to_string()))
            .collect())
    }

    /// The (reason, attributed culprit rank) of a reported broken
    /// world, if any report landed.
    pub fn broken_report(&self, world: &str) -> anyhow::Result<Option<(String, Option<usize>)>> {
        let Some(bytes) = self.store.get(&format!("ctl/broken/{world}"))? else {
            return Ok(None);
        };
        let text = String::from_utf8(bytes)?;
        let j = Json::parse(&text)?;
        let reason = j
            .get("reason")
            .and_then(|v| v.as_str())
            .unwrap_or_default()
            .to_string();
        let culprit = j.get("culprit").and_then(|v| v.as_usize());
        Ok(Some((reason, culprit)))
    }

    /// Spawn a listener thread translating published updates into
    /// `TopoUpdate`s for `node`, delivered on `tx`. Returns a stop flag.
    pub fn listen(
        &self,
        node: NodeId,
        tx: Sender<TopoUpdate>,
    ) -> Arc<AtomicBool> {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let store = self.store.clone();
        std::thread::Builder::new()
            .name(format!("ctl-listen-{node}"))
            .spawn(move || {
                let mut next: i64 = 1;
                while !stop2.load(Ordering::Relaxed) {
                    let key = format!("ctl/update/{next}");
                    // Server-side waits are push-based: the store parks
                    // this wait and answers the instant the key lands,
                    // so the timeout only bounds how often we re-check
                    // the stop flag — not delivery latency.
                    match store.wait(&key, Duration::from_secs(1)) {
                        Ok(bytes) => {
                            next += 1;
                            let Ok(text) = String::from_utf8(bytes) else { continue };
                            let Ok(j) = Json::parse(&text) else { continue };
                            match j.get("kind").and_then(|v| v.as_str()) {
                                Some("add_world") => {
                                    if let Some(def) = parse_world(&j) {
                                        if def.rank_of(node).is_some()
                                            && tx.send(TopoUpdate::AddWorld(def)).is_err()
                                        {
                                            return;
                                        }
                                    }
                                }
                                Some("shutdown") => {
                                    let target = j.get("node").and_then(|v| v.as_str());
                                    if target == Some("*")
                                        || target == Some(node.to_string().as_str())
                                    {
                                        let _ = tx.send(TopoUpdate::Shutdown);
                                        return;
                                    }
                                }
                                _ => {}
                            }
                        }
                        Err(_) => { /* timeout — loop to check stop */ }
                    }
                }
            })
            .expect("spawn control listener");
        stop
    }
}

fn parse_world(j: &Json) -> Option<WorldDef> {
    WorldDef::from_json(j.get("world")?).ok()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::StoreServer;

    fn plane() -> (StoreServer, ControlPlane) {
        let server = StoreServer::bind_any().unwrap();
        let cp = ControlPlane::connect(server.addr(), Duration::from_secs(2)).unwrap();
        (server, cp)
    }

    #[test]
    fn add_world_reaches_member_only() {
        let (server, cp) = plane();
        let member = NodeId::worker(1, 0);
        let outsider = NodeId::worker(2, 5);
        let (tx_m, rx_m) = std::sync::mpsc::channel();
        let (tx_o, rx_o) = std::sync::mpsc::channel();
        let cp_m = ControlPlane::connect(server.addr(), Duration::from_secs(2)).unwrap();
        let cp_o = ControlPlane::connect(server.addr(), Duration::from_secs(2)).unwrap();
        let stop_m = cp_m.listen(member, tx_m);
        let stop_o = cp_o.listen(outsider, tx_o);
        let def = WorldDef::edge("w-new".into(), NodeId::Leader, member, 12345);
        cp.publish_add_world(&def).unwrap();
        match rx_m.recv_timeout(Duration::from_secs(2)).unwrap() {
            TopoUpdate::AddWorld(got) => assert_eq!(got, def),
            other => panic!("{other:?}"),
        }
        assert!(rx_o.recv_timeout(Duration::from_millis(300)).is_err());
        stop_m.store(true, Ordering::Relaxed);
        stop_o.store(true, Ordering::Relaxed);
    }

    #[test]
    fn shutdown_targets_node_or_all() {
        let (server, cp) = plane();
        let a = NodeId::worker(0, 0);
        let (tx, rx) = std::sync::mpsc::channel();
        let cp_a = ControlPlane::connect(server.addr(), Duration::from_secs(2)).unwrap();
        let _stop = cp_a.listen(a, tx);
        cp.publish_shutdown(Some(NodeId::worker(9, 9))).unwrap();
        cp.publish_shutdown(Some(a)).unwrap();
        // The targeted shutdown must arrive (the other is ignored).
        match rx.recv_timeout(Duration::from_secs(2)).unwrap() {
            TopoUpdate::Shutdown => {}
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn broken_world_reports_accumulate_with_culprits() {
        let (_server, cp) = plane();
        cp.report_broken("w1", "remote error", Some(1)).unwrap();
        cp.report_broken("w2", "watchdog", None).unwrap();
        let mut got = cp.broken_worlds().unwrap();
        got.sort();
        assert_eq!(got, vec!["w1".to_string(), "w2".to_string()]);
        assert_eq!(
            cp.broken_report("w1").unwrap(),
            Some(("remote error".to_string(), Some(1)))
        );
        assert_eq!(
            cp.broken_report("w2").unwrap(),
            Some(("watchdog".to_string(), None))
        );
        assert_eq!(cp.broken_report("w3").unwrap(), None);
    }

    #[test]
    fn load_samples_roundtrip_and_overwrite() {
        let (_server, cp) = plane();
        assert_eq!(cp.load_report(0).unwrap(), None);
        let s1 = LoadSample { queue_depth: 12, p99_ms: 8.5, alive_replicas: 2 };
        cp.publish_load(0, &s1).unwrap();
        assert_eq!(cp.load_report(0).unwrap(), Some(s1));
        // Latest sample wins (the autoscaler polls current state).
        let s2 = LoadSample { queue_depth: 0, p99_ms: 1.0, alive_replicas: 3 };
        cp.publish_load(0, &s2).unwrap();
        assert_eq!(cp.load_report(0).unwrap(), Some(s2));
        assert_eq!(cp.load_report(1).unwrap(), None, "per-stage keys");
    }

    #[test]
    fn tp_world_defs_travel_the_control_plane() {
        use crate::serving::topology::{WorldDef, WorldKind};
        let (server, cp) = plane();
        let shard1 = NodeId::Worker { stage: 1, replica: 0, shard: 1 };
        let (tx, rx) = std::sync::mpsc::channel();
        let cp_s = ControlPlane::connect(server.addr(), Duration::from_secs(2)).unwrap();
        let _stop = cp_s.listen(shard1, tx);
        let def = WorldDef {
            name: "tp-s1r0#g1".into(),
            members: vec![NodeId::worker(1, 0), shard1],
            store_port: 23456,
            kind: WorldKind::Tp,
        };
        cp.publish_add_world(&def).unwrap();
        match rx.recv_timeout(Duration::from_secs(2)).unwrap() {
            TopoUpdate::AddWorld(got) => assert_eq!(got, def),
            other => panic!("{other:?}"),
        }
    }
}
