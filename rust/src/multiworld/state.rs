//! Per-world state management (§3.2 "State management for multiple
//! worlds").
//!
//! PyTorch keeps one implicit "current" process-group state; supporting
//! many worlds means either
//!
//! 1. **Swap** — save/restore the state blob around every operation
//!    (time-multiplexing, "requires minimal changes on PyTorch"), or
//! 2. **Key-value** — keep each world's state addressable by name inside
//!    the library (the paper's choice: "simple and effective").
//!
//! The communicator calls [`StateManager::activate`] before every op.
//! [`KvStateManager`] makes that a hash lookup; [`SwapStateManager`]
//! pays a serialize-out + deserialize-in of the full state blob whenever
//! the active world changes — which is exactly the cost the paper's
//! design avoids, reproduced here for `benches/ablation_state_mgmt`.

use std::collections::HashMap;
use std::sync::Mutex;

/// What a CCL keeps per communicator: rank bookkeeping, peer endpoints,
/// channel cursors. Sized realistically (NCCL communicator state is tens
/// of KB per rank pair).
#[derive(Clone, Debug, PartialEq)]
pub struct WorldState {
    pub name: String,
    pub rank: usize,
    pub size: usize,
    /// Next collective sequence number (mirrors `WorldCore::seq`).
    pub op_seq: u64,
    /// Opaque communicator state blob (peer endpoints, ring cursors,
    /// buffer registrations…).
    pub comm_blob: Vec<u8>,
}

impl WorldState {
    pub fn new(name: &str, rank: usize, size: usize, blob_bytes: usize) -> Self {
        WorldState {
            name: name.to_string(),
            rank,
            size,
            op_seq: 0,
            comm_blob: vec![0xA5; blob_bytes],
        }
    }
}

/// Which manager the communicator uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StatePolicy {
    /// The paper's design: per-world key-value state inside the CCL.
    Kv,
    /// The rejected baseline: save/restore swapping on world switch.
    Swap,
}

/// Strategy interface. `activate` is on the hot path of every collective.
pub trait StateManager: Send + Sync {
    /// Register a world's state at init.
    fn insert(&self, state: WorldState);

    /// Make `world` current and run `f` against its state.
    /// Returns `None` if the world is unknown.
    fn with_state<'a>(
        &'a self,
        world: &str,
        f: &mut dyn FnMut(&mut WorldState),
    ) -> Option<()>;

    /// Drop a world's state (world removal).
    fn remove(&self, world: &str) -> bool;

    /// Number of registered worlds.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Convenience: bump and return the op sequence for `world`.
    fn next_seq(&self, world: &str) -> Option<u64> {
        let mut out = None;
        self.with_state(world, &mut |st| {
            out = Some(st.op_seq);
            st.op_seq += 1;
        })?;
        out
    }
}

/// The paper's approach: every world's state lives in a map, `activate`
/// is a lookup. O(1) in the number of worlds.
#[derive(Default)]
pub struct KvStateManager {
    states: Mutex<HashMap<String, WorldState>>,
}

impl KvStateManager {
    pub fn new() -> Self {
        Self::default()
    }
}

impl StateManager for KvStateManager {
    fn insert(&self, state: WorldState) {
        self.states.lock().unwrap().insert(state.name.clone(), state);
    }

    fn with_state<'a>(
        &'a self,
        world: &str,
        f: &mut dyn FnMut(&mut WorldState),
    ) -> Option<()> {
        let mut map = self.states.lock().unwrap();
        let st = map.get_mut(world)?;
        f(st);
        Some(())
    }

    fn remove(&self, world: &str) -> bool {
        self.states.lock().unwrap().remove(world).is_some()
    }

    fn len(&self) -> usize {
        self.states.lock().unwrap().len()
    }
}

/// The time-multiplexing baseline: one *active* slot; switching worlds
/// serializes the outgoing state into its save area and deserializes the
/// incoming one — cost proportional to the blob size, paid on every
/// world switch.
pub struct SwapStateManager {
    inner: Mutex<SwapInner>,
}

struct SwapInner {
    /// Serialized save areas, keyed by world.
    saved: HashMap<String, Vec<u8>>,
    /// The one live state (as PyTorch's implicit current group).
    active: Option<WorldState>,
}

impl Default for SwapStateManager {
    fn default() -> Self {
        SwapStateManager {
            inner: Mutex::new(SwapInner { saved: HashMap::new(), active: None }),
        }
    }
}

impl SwapStateManager {
    pub fn new() -> Self {
        Self::default()
    }

    /// Serialize a state to its save-area representation. Deliberately a
    /// real byte-level encode (length-prefixed fields + blob copy) so the
    /// ablation measures genuine marshalling work, not a pointer move.
    fn serialize(st: &WorldState) -> Vec<u8> {
        let mut out = Vec::with_capacity(32 + st.name.len() + st.comm_blob.len());
        out.extend_from_slice(&(st.name.len() as u32).to_le_bytes());
        out.extend_from_slice(st.name.as_bytes());
        out.extend_from_slice(&(st.rank as u64).to_le_bytes());
        out.extend_from_slice(&(st.size as u64).to_le_bytes());
        out.extend_from_slice(&st.op_seq.to_le_bytes());
        out.extend_from_slice(&(st.comm_blob.len() as u64).to_le_bytes());
        out.extend_from_slice(&st.comm_blob);
        out
    }

    fn deserialize(bytes: &[u8]) -> Option<WorldState> {
        let mut off = 0usize;
        let take = |off: &mut usize, n: usize| -> Option<&[u8]> {
            if *off + n > bytes.len() {
                return None;
            }
            let s = &bytes[*off..*off + n];
            *off += n;
            Some(s)
        };
        let name_len = u32::from_le_bytes(take(&mut off, 4)?.try_into().ok()?) as usize;
        let name = String::from_utf8(take(&mut off, name_len)?.to_vec()).ok()?;
        let rank = u64::from_le_bytes(take(&mut off, 8)?.try_into().ok()?) as usize;
        let size = u64::from_le_bytes(take(&mut off, 8)?.try_into().ok()?) as usize;
        let op_seq = u64::from_le_bytes(take(&mut off, 8)?.try_into().ok()?);
        let blob_len = u64::from_le_bytes(take(&mut off, 8)?.try_into().ok()?) as usize;
        let comm_blob = take(&mut off, blob_len)?.to_vec();
        Some(WorldState { name, rank, size, op_seq, comm_blob })
    }
}

impl StateManager for SwapStateManager {
    fn insert(&self, state: WorldState) {
        let mut inner = self.inner.lock().unwrap();
        inner.saved.insert(state.name.clone(), Self::serialize(&state));
    }

    fn with_state<'a>(
        &'a self,
        world: &str,
        f: &mut dyn FnMut(&mut WorldState),
    ) -> Option<()> {
        let mut inner = self.inner.lock().unwrap();
        let needs_switch = inner.active.as_ref().map(|a| a.name != world).unwrap_or(true);
        if needs_switch {
            // Save the incumbent…
            if let Some(prev) = inner.active.take() {
                let blob = Self::serialize(&prev);
                inner.saved.insert(prev.name.clone(), blob);
            }
            // …and restore the requested world.
            let blob = inner.saved.remove(world)?;
            inner.active = Some(Self::deserialize(&blob)?);
        }
        let st = inner.active.as_mut()?;
        if st.name != world {
            return None;
        }
        f(st);
        Some(())
    }

    fn remove(&self, world: &str) -> bool {
        let mut inner = self.inner.lock().unwrap();
        let was_active = inner.active.as_ref().map(|a| a.name == world).unwrap_or(false);
        if was_active {
            inner.active = None;
            return true;
        }
        inner.saved.remove(world).is_some()
    }

    fn len(&self) -> usize {
        let inner = self.inner.lock().unwrap();
        inner.saved.len() + inner.active.iter().count()
    }
}

/// Build a manager per policy.
pub fn make_state_manager(policy: StatePolicy) -> Box<dyn StateManager> {
    match policy {
        StatePolicy::Kv => Box::new(KvStateManager::new()),
        StatePolicy::Swap => Box::new(SwapStateManager::new()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn managers() -> Vec<(&'static str, Box<dyn StateManager>)> {
        vec![
            ("kv", make_state_manager(StatePolicy::Kv)),
            ("swap", make_state_manager(StatePolicy::Swap)),
        ]
    }

    #[test]
    fn insert_activate_mutate_all_policies() {
        for (label, m) in managers() {
            m.insert(WorldState::new("w1", 0, 2, 128));
            m.insert(WorldState::new("w2", 1, 3, 128));
            assert_eq!(m.len(), 2, "{label}");
            // Mutations must persist across switches.
            assert_eq!(m.next_seq("w1"), Some(0), "{label}");
            assert_eq!(m.next_seq("w2"), Some(0), "{label}");
            assert_eq!(m.next_seq("w1"), Some(1), "{label}");
            assert_eq!(m.next_seq("w2"), Some(1), "{label}");
            let mut seen = None;
            m.with_state("w2", &mut |st| seen = Some((st.rank, st.size)));
            assert_eq!(seen, Some((1, 3)), "{label}");
        }
    }

    #[test]
    fn unknown_world_is_none() {
        for (label, m) in managers() {
            assert!(m.with_state("ghost", &mut |_| {}).is_none(), "{label}");
            assert_eq!(m.next_seq("ghost"), None, "{label}");
        }
    }

    #[test]
    fn remove_frees_state() {
        for (label, m) in managers() {
            m.insert(WorldState::new("w1", 0, 2, 16));
            assert!(m.remove("w1"), "{label}");
            assert!(!m.remove("w1"), "{label}");
            assert!(m.with_state("w1", &mut |_| {}).is_none(), "{label}");
            assert_eq!(m.len(), 0, "{label}");
        }
    }

    #[test]
    fn swap_remove_active_world() {
        let m = SwapStateManager::new();
        m.insert(WorldState::new("w1", 0, 2, 16));
        m.with_state("w1", &mut |_| {}).unwrap(); // make active
        assert!(m.remove("w1"));
        assert_eq!(m.len(), 0);
    }

    #[test]
    fn swap_roundtrip_preserves_blob() {
        let mut st = WorldState::new("blob", 2, 5, 1024);
        st.op_seq = 42;
        st.comm_blob[512] = 0x17;
        let bytes = SwapStateManager::serialize(&st);
        let back = SwapStateManager::deserialize(&bytes).unwrap();
        assert_eq!(back, st);
    }

    #[test]
    fn swap_switch_costs_more_than_kv_lookup() {
        // Micro-check of the ablation's premise: alternating between two
        // worlds with large blobs is measurably slower under swap.
        let blob = 256 * 1024;
        let kv = KvStateManager::new();
        let sw = SwapStateManager::new();
        for m in [&kv as &dyn StateManager, &sw as &dyn StateManager] {
            m.insert(WorldState::new("a", 0, 2, blob));
            m.insert(WorldState::new("b", 0, 2, blob));
        }
        let time = |m: &dyn StateManager| {
            let t0 = std::time::Instant::now();
            for i in 0..200 {
                let w = if i % 2 == 0 { "a" } else { "b" };
                m.next_seq(w).unwrap();
            }
            t0.elapsed()
        };
        let t_kv = time(&kv);
        let t_sw = time(&sw);
        assert!(
            t_sw > t_kv,
            "swap ({t_sw:?}) should cost more than kv ({t_kv:?})"
        );
    }
}
