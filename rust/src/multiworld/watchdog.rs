//! The watchdog (§3.3): a threaded daemon that publishes this worker's
//! liveness into each world's TCPStore and checks every peer's
//! heartbeat. Missing updates for longer than the threshold — or losing
//! the store itself (the leader hosting it died) — marks the world
//! broken and notifies the manager.
//!
//! This is the *only* failure signal on the shared-memory transport,
//! where peer death is silent; on TCP it complements `RemoteError` (a
//! peer that wedges without closing its socket is also caught here).

use crate::store::StoreClient;
use crate::util::time::Clock;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Watchdog tuning.
#[derive(Clone, Debug)]
pub struct WatchdogConfig {
    /// Heartbeat publish/check period.
    pub heartbeat: Duration,
    /// Consecutive missed periods before a peer is declared dead
    /// (paper example: updates missed "for a certain duration (e.g., 3
    /// seconds)" at ~1 s heartbeats ⇒ 3 misses).
    pub miss_threshold: u32,
}

impl Default for WatchdogConfig {
    fn default() -> Self {
        WatchdogConfig { heartbeat: Duration::from_millis(250), miss_threshold: 3 }
    }
}

/// One world under watch.
struct Watched {
    world: String,
    rank: usize,
    size: usize,
    store: Arc<StoreClient>,
    /// Wall-clock (ms) when each peer's heartbeat was last seen fresh.
    last_seen: HashMap<usize, u64>,
    /// First heartbeat grace: peers may not have published yet.
    started_at: u64,
}

/// Callback invoked when a watched world is declared broken:
/// `(world, reason, culprit rank)`. The culprit is the peer whose
/// heartbeats went missing — `None` when the *store* died (the world
/// leader's fault, but indistinguishable from a network partition
/// here). Rank-level attribution is what lets the serving controller
/// recover exactly the dead shard of a multi-member TP world instead
/// of inferring (and possibly misattributing) from world-level
/// evidence.
pub type BrokenCallback = Arc<dyn Fn(&str, &str, Option<usize>) + Send + Sync>;

/// See module docs.
pub struct Watchdog {
    cfg: WatchdogConfig,
    clock: Clock,
    watched: Arc<Mutex<HashMap<String, Watched>>>,
    on_broken: BrokenCallback,
    stop: Arc<AtomicBool>,
    thread: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl Watchdog {
    /// Create and start the daemon thread.
    pub fn start(cfg: WatchdogConfig, clock: Clock, on_broken: BrokenCallback) -> Arc<Watchdog> {
        let wd = Arc::new(Watchdog {
            cfg,
            clock,
            watched: Arc::new(Mutex::new(HashMap::new())),
            on_broken,
            stop: Arc::new(AtomicBool::new(false)),
            thread: Mutex::new(None),
        });
        let wd2 = wd.clone();
        let handle = std::thread::Builder::new()
            .name("mw-watchdog".into())
            .spawn(move || wd2.run())
            .expect("spawn watchdog");
        *wd.thread.lock().unwrap() = Some(handle);
        wd
    }

    /// Begin watching a world (called by the manager at world init).
    pub fn watch(&self, world: &str, rank: usize, size: usize, store: Arc<StoreClient>) {
        let now = self.clock.now_millis();
        self.watched.lock().unwrap().insert(
            world.to_string(),
            Watched {
                world: world.to_string(),
                rank,
                size,
                store,
                last_seen: HashMap::new(),
                started_at: now,
            },
        );
    }

    /// Stop watching (world removed).
    pub fn unwatch(&self, world: &str) {
        self.watched.lock().unwrap().remove(world);
    }

    /// Worlds currently under watch.
    pub fn watched_worlds(&self) -> Vec<String> {
        self.watched.lock().unwrap().keys().cloned().collect()
    }

    /// One watchdog pass: publish own heartbeat, check peers. Public so
    /// deterministic tests can drive it with a manual clock instead of
    /// sleeping.
    pub fn tick(&self) {
        let now = self.clock.now_millis();
        let deadline_ms = self.cfg.heartbeat.as_millis() as u64 * self.cfg.miss_threshold as u64;
        let mut broken: Vec<(String, String, Option<usize>)> = Vec::new();
        {
            let mut watched = self.watched.lock().unwrap();
            for w in watched.values_mut() {
                // 1. Publish my liveness.
                let my_key = format!("mw/{}/hb/{}", w.world, w.rank);
                if let Err(e) = w.store.set(&my_key, now.to_string().as_bytes()) {
                    // The store is gone — its host (the world leader) is
                    // dead. That breaks the world for everyone.
                    broken.push((w.world.clone(), format!("store unreachable: {e}"), None));
                    continue;
                }
                // 2. Check the peers — one batched `mget` per world per
                // tick instead of a round trip per peer, so the sweep
                // cost is O(1) in member count on the wire.
                let peers: Vec<usize> = (0..w.size).filter(|&p| p != w.rank).collect();
                let keys: Vec<String> =
                    peers.iter().map(|p| format!("mw/{}/hb/{p}", w.world)).collect();
                let key_refs: Vec<&str> = keys.iter().map(String::as_str).collect();
                let stamps = match w.store.mget(&key_refs) {
                    Ok(vals) => vals,
                    Err(e) => {
                        broken.push((w.world.clone(), format!("store unreachable: {e}"), None));
                        continue;
                    }
                };
                for (&peer, val) in peers.iter().zip(stamps) {
                    let stamp = val
                        .and_then(|v| String::from_utf8(v).ok())
                        .and_then(|s| s.parse::<u64>().ok());
                    let last = match stamp {
                        // Stamps from other processes use the same wall
                        // clock; a manual test clock sees its own writes.
                        Some(ts) => {
                            let e = w.last_seen.entry(peer).or_insert(ts);
                            if ts > *e {
                                *e = ts;
                            }
                            *e
                        }
                        // Never heartbeated: grace period from watch start.
                        None => *w.last_seen.entry(peer).or_insert(w.started_at),
                    };
                    if now.saturating_sub(last) > deadline_ms {
                        broken.push((
                            w.world.clone(),
                            format!(
                                "rank {peer} missed heartbeats for {} ms (> {deadline_ms} ms)",
                                now.saturating_sub(last)
                            ),
                            Some(peer),
                        ));
                        break;
                    }
                }
            }
            for (world, _, _) in &broken {
                watched.remove(world);
            }
        }
        for (world, reason, culprit) in broken {
            // Broken-world events must be observable without MW_DEBUG:
            // a counter for dashboards/assertions plus one structured
            // line that benches and CI logs can grep.
            crate::metrics::global().counter("watchdog.worlds_broken").inc();
            let culprit_s = culprit.map(|c| c.to_string()).unwrap_or_else(|| "-".into());
            crate::metrics::log_event(
                "watchdog.world_broken",
                &[
                    ("world", world.as_str()),
                    ("reason", reason.as_str()),
                    ("culprit_rank", culprit_s.as_str()),
                ],
            );
            (self.on_broken)(&world, &reason, culprit);
        }
    }

    fn run(&self) {
        while !self.stop.load(Ordering::Relaxed) {
            self.tick();
            std::thread::sleep(self.cfg.heartbeat);
        }
    }

    /// Stop the daemon (joined on drop as well).
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::Relaxed);
    }
}

impl Drop for Watchdog {
    fn drop(&mut self) {
        self.shutdown();
        if let Some(t) = self.thread.lock().unwrap().take() {
            // The daemon thread itself may hold the last Arc (it exits
            // right after shutdown); joining ourselves would deadlock.
            if t.thread().id() != std::thread::current().id() {
                let _ = t.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::StoreServer;
    use crate::util::time::Clock;
    use std::sync::atomic::AtomicUsize;

    struct Fixture {
        _server: StoreServer,
        store: Arc<StoreClient>,
        broken: Arc<Mutex<Vec<(String, String, Option<usize>)>>>,
        calls: Arc<AtomicUsize>,
    }

    fn fixture() -> Fixture {
        let server = StoreServer::bind_any().unwrap();
        let store =
            Arc::new(StoreClient::connect(server.addr(), Duration::from_secs(2)).unwrap());
        Fixture {
            _server: server,
            store,
            broken: Arc::new(Mutex::new(Vec::new())),
            calls: Arc::new(AtomicUsize::new(0)),
        }
    }

    fn watchdog_with(fx: &Fixture, clock: Clock) -> Arc<Watchdog> {
        let broken = fx.broken.clone();
        let calls = fx.calls.clone();
        Watchdog::start(
            WatchdogConfig { heartbeat: Duration::from_millis(3600_000), miss_threshold: 3 },
            clock,
            Arc::new(move |w, r, c| {
                broken.lock().unwrap().push((w.to_string(), r.to_string(), c));
                calls.fetch_add(1, Ordering::SeqCst);
            }),
        )
    }

    #[test]
    fn healthy_peers_stay_healthy() {
        let fx = fixture();
        let clock = Clock::manual();
        let wd = watchdog_with(&fx, clock.clone());
        wd.watch("w1", 0, 2, fx.store.clone());
        // Peer 1 heartbeats via the same store.
        for step in 0..5 {
            clock.advance(Duration::from_secs(1));
            fx.store
                .set("mw/w1/hb/1", clock.now_millis().to_string().as_bytes())
                .unwrap();
            wd.tick();
            assert!(fx.broken.lock().unwrap().is_empty(), "step {step}");
        }
        wd.shutdown();
    }

    #[test]
    fn missed_heartbeats_break_world() {
        let fx = fixture();
        let clock = Clock::manual();
        let wd = watchdog_with(&fx, clock.clone());
        let broken_counter = crate::metrics::global().counter("watchdog.worlds_broken");
        let broken_before = broken_counter.get();
        // heartbeat period is effectively ∞ for the daemon; we drive ticks.
        wd.watch("w1", 0, 2, fx.store.clone());
        fx.store
            .set("mw/w1/hb/1", clock.now_millis().to_string().as_bytes())
            .unwrap();
        wd.tick(); // sees fresh stamp
        assert!(fx.broken.lock().unwrap().is_empty());
        // Peer goes quiet; threshold is 3 × 3600s on the manual clock.
        clock.advance(Duration::from_secs(3 * 3600 + 10));
        wd.tick();
        let broken = fx.broken.lock().unwrap();
        assert_eq!(broken.len(), 1);
        assert_eq!(broken[0].0, "w1");
        assert!(broken[0].1.contains("rank 1"), "{}", broken[0].1);
        assert_eq!(broken[0].2, Some(1), "alert attributes the silent rank");
        assert!(
            broken_counter.get() > broken_before,
            "alert must increment the global watchdog.worlds_broken counter"
        );
    }

    #[test]
    fn peer_that_never_heartbeats_gets_grace_then_breaks() {
        let fx = fixture();
        let clock = Clock::manual();
        let wd = watchdog_with(&fx, clock.clone());
        wd.watch("w1", 0, 2, fx.store.clone());
        wd.tick();
        assert!(fx.broken.lock().unwrap().is_empty(), "grace period holds");
        clock.advance(Duration::from_secs(4 * 3600));
        wd.tick();
        assert_eq!(fx.broken.lock().unwrap().len(), 1);
    }

    #[test]
    fn broken_world_reported_once_and_unwatched() {
        let fx = fixture();
        let clock = Clock::manual();
        let wd = watchdog_with(&fx, clock.clone());
        wd.watch("w1", 0, 2, fx.store.clone());
        clock.advance(Duration::from_secs(4 * 3600));
        wd.tick();
        wd.tick();
        wd.tick();
        assert_eq!(fx.calls.load(Ordering::SeqCst), 1, "no duplicate alerts");
        assert!(wd.watched_worlds().is_empty());
    }

    #[test]
    fn store_death_breaks_world() {
        // The store's host (world leader) dying must break the world.
        let server = StoreServer::bind_any().unwrap();
        let store =
            Arc::new(StoreClient::connect(server.addr(), Duration::from_secs(2)).unwrap());
        let broken = Arc::new(Mutex::new(Vec::new()));
        let b2 = broken.clone();
        let clock = Clock::manual();
        let wd = Watchdog::start(
            WatchdogConfig { heartbeat: Duration::from_millis(3600_000), miss_threshold: 3 },
            clock.clone(),
            Arc::new(move |w: &str, r: &str, c: Option<usize>| {
                b2.lock().unwrap().push((w.to_string(), r.to_string(), c))
            }),
        );
        wd.watch("w9", 1, 2, store);
        drop(server);
        std::thread::sleep(Duration::from_millis(50));
        wd.tick();
        let broken = broken.lock().unwrap();
        assert_eq!(broken.len(), 1);
        assert!(broken[0].1.contains("store unreachable"), "{}", broken[0].1);
    }

    #[test]
    fn simultaneous_missed_heartbeats_convict_lowest_rank_once() {
        // Two ranks go silent in the same tick (e.g. a rack partition):
        // the world breaks exactly once, and the attribution is
        // *deterministic* — the lowest silent rank — never a
        // timing-dependent coin flip between the two. (The controller
        // re-mints the world either way; what matters is that repeated
        // runs blame the same rank and that no second alert fires for
        // the same world.)
        let fx = fixture();
        let clock = Clock::manual();
        let wd = watchdog_with(&fx, clock.clone());
        wd.watch("w1", 0, 4, fx.store.clone());
        // All three peers heartbeat once…
        for peer in 1..4 {
            fx.store
                .set(&format!("mw/w1/hb/{peer}"), clock.now_millis().to_string().as_bytes())
                .unwrap();
        }
        wd.tick();
        assert!(fx.broken.lock().unwrap().is_empty());
        // …then ranks 2 and 3 both go silent while rank 1 stays fresh.
        clock.advance(Duration::from_secs(4 * 3600));
        fx.store
            .set("mw/w1/hb/1", clock.now_millis().to_string().as_bytes())
            .unwrap();
        wd.tick();
        wd.tick();
        let broken = fx.broken.lock().unwrap();
        assert_eq!(broken.len(), 1, "one alert per world, not one per silent rank");
        assert_eq!(
            broken[0].2,
            Some(2),
            "deterministic attribution: the lowest silent rank"
        );
        assert!(wd.watched_worlds().is_empty(), "broken world unwatched");
    }

    #[test]
    fn heartbeat_resuming_at_the_threshold_boundary_is_not_convicted() {
        // A peer that misses heartbeats for *exactly* the deadline (3 ×
        // period) and then resumes must never be declared dead: the rule
        // is strictly-greater-than, so gray slowness right at the
        // boundary stays alive.
        let fx = fixture();
        let clock = Clock::manual();
        let wd = watchdog_with(&fx, clock.clone());
        wd.watch("w1", 0, 2, fx.store.clone());
        fx.store
            .set("mw/w1/hb/1", clock.now_millis().to_string().as_bytes())
            .unwrap();
        wd.tick();
        // Silent for exactly deadline_ms = 3 × 3600s — not beyond.
        clock.advance(Duration::from_secs(3 * 3600));
        wd.tick();
        assert!(
            fx.broken.lock().unwrap().is_empty(),
            "exactly-at-threshold must not convict"
        );
        // The peer resumes; later ticks stay healthy.
        fx.store
            .set("mw/w1/hb/1", clock.now_millis().to_string().as_bytes())
            .unwrap();
        clock.advance(Duration::from_secs(3600));
        wd.tick();
        assert!(fx.broken.lock().unwrap().is_empty());
        assert_eq!(wd.watched_worlds(), vec!["w1".to_string()]);
    }

    #[test]
    fn heartbeat_resuming_just_after_conviction_does_not_unbreak() {
        // The inverse boundary: the peer resumes one tick *after* the
        // threshold passed. The conviction stands (the world is already
        // broken and unwatched) and no duplicate or contradictory alert
        // fires — a resurrection is the controller's business (fresh
        // worlds), never the watchdog's.
        let fx = fixture();
        let clock = Clock::manual();
        let wd = watchdog_with(&fx, clock.clone());
        wd.watch("w1", 0, 2, fx.store.clone());
        fx.store
            .set("mw/w1/hb/1", clock.now_millis().to_string().as_bytes())
            .unwrap();
        wd.tick();
        clock.advance(Duration::from_secs(3 * 3600 + 1));
        wd.tick();
        assert_eq!(fx.broken.lock().unwrap().len(), 1, "just past threshold convicts");
        // Heartbeat returns — too late.
        fx.store
            .set("mw/w1/hb/1", clock.now_millis().to_string().as_bytes())
            .unwrap();
        wd.tick();
        wd.tick();
        assert_eq!(
            fx.calls.load(Ordering::SeqCst),
            1,
            "late resumption must not produce further alerts"
        );
        assert!(wd.watched_worlds().is_empty());
    }

    #[test]
    fn store_death_attributes_no_culprit() {
        // `Broken { culprit: None }` path: losing the store (the world
        // leader's host died — indistinguishable from a partition to
        // it) must alert with *no* culprit rank, so the layer above
        // falls back to strike inference instead of convicting an
        // arbitrary member.
        let fx = fixture();
        let clock = Clock::manual();
        let wd = watchdog_with(&fx, clock.clone());
        wd.watch("w1", 1, 3, fx.store.clone());
        drop(fx._server);
        std::thread::sleep(Duration::from_millis(50));
        wd.tick();
        let broken = fx.broken.lock().unwrap();
        assert_eq!(broken.len(), 1);
        assert!(broken[0].1.contains("store unreachable"), "{}", broken[0].1);
        assert_eq!(broken[0].2, None, "store loss must not be attributed to a rank");
    }

    #[test]
    fn unwatch_stops_monitoring() {
        let fx = fixture();
        let clock = Clock::manual();
        let wd = watchdog_with(&fx, clock.clone());
        wd.watch("w1", 0, 2, fx.store.clone());
        wd.unwatch("w1");
        clock.advance(Duration::from_secs(10 * 3600));
        wd.tick();
        assert!(fx.broken.lock().unwrap().is_empty());
    }

    #[test]
    fn multiple_worlds_fail_independently() {
        let fx = fixture();
        let clock = Clock::manual();
        let wd = watchdog_with(&fx, clock.clone());
        wd.watch("wa", 0, 2, fx.store.clone());
        wd.watch("wb", 0, 2, fx.store.clone());
        // wb's peer stays alive, wa's never shows up.
        for _ in 0..5 {
            clock.advance(Duration::from_secs(3600));
            fx.store
                .set("mw/wb/hb/1", clock.now_millis().to_string().as_bytes())
                .unwrap();
            wd.tick();
        }
        let broken = fx.broken.lock().unwrap();
        assert_eq!(broken.len(), 1);
        assert_eq!(broken[0].0, "wa");
        assert_eq!(wd.watched_worlds(), vec!["wb".to_string()]);
    }
}
