//! The World Communicator (§3.3): fault-tolerant collective operations
//! addressed by world *name*, non-blocking by construction, with the
//! busy-wait completion poller.
//!
//! The paper's API promise — "When PyTorch's distributed collective
//! operations are used, including a world name as a function argument
//! suffices" — is mirrored here: every method takes the world name first
//! and otherwise looks like the CCL op.
//!
//! Completion across *many* worlds is the crux: a blocking wait on one
//! world's op would stall every other world (the deadlock scenario of
//! §3.2). [`WorldCommunicator::wait_any`] polls a set of [`Work`]s under
//! a selectable [`PollStrategy`]; the default busy-waits (paper: "We
//! mitigate the throughput loss of polling via busy waiting" at the cost
//! of one dedicated CPU core) while still letting other tasks run by
//! spinning only between completion probes.

use super::manager::WorldManager;
use super::{MwError, MwResult};
use crate::mwccl::{ReduceOp, Work};
use crate::tensor::Tensor;
use std::time::{Duration, Instant};

/// How [`WorldCommunicator::wait_any`] burns the gap between probes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PollStrategy {
    /// Pure busy wait — lowest latency, one core at 100% (the paper's
    /// choice: "We trade one CPU core for fault tolerance and online
    /// scaling").
    BusyWait,
    /// Spin a bounded number of iterations, then `yield_now`, so
    /// co-located tasks can be scheduled immediately when ops are
    /// pending (§3.2's requirement).
    SpinYield,
    /// Sleep between scans — minimal CPU, highest latency (ablation
    /// point showing why naive polling loses throughput).
    Sleep(Duration),
}

impl Default for PollStrategy {
    fn default() -> Self {
        PollStrategy::SpinYield
    }
}

/// Fault-tolerant, multi-world collective API. Cheap to clone.
#[derive(Clone)]
pub struct WorldCommunicator {
    mgr: WorldManager,
    strategy: PollStrategy,
}

impl WorldCommunicator {
    pub(crate) fn new(mgr: WorldManager) -> Self {
        WorldCommunicator { mgr, strategy: PollStrategy::default() }
    }

    /// Override the completion-poll strategy.
    pub fn with_strategy(mut self, s: PollStrategy) -> Self {
        self.strategy = s;
        self
    }

    pub fn strategy(&self) -> PollStrategy {
        self.strategy
    }

    // ------------------------------------------------------- collectives

    /// Async send on `world` (world name + the usual op arguments).
    pub fn send(&self, world: &str, t: Tensor, dst: usize, tag: u64) -> MwResult<Work> {
        let w = self.mgr.world(world)?;
        self.mgr.activate_state(world)?;
        Ok(w.isend(t, dst, tag))
    }

    /// Async receive on `world`.
    pub fn recv(&self, world: &str, src: usize, tag: u64) -> MwResult<Work> {
        let w = self.mgr.world(world)?;
        self.mgr.activate_state(world)?;
        Ok(w.irecv(src, tag))
    }

    /// Async broadcast on `world`.
    pub fn broadcast(&self, world: &str, t: Option<Tensor>, root: usize) -> MwResult<Work> {
        let w = self.mgr.world(world)?;
        self.mgr.activate_state(world)?;
        Ok(w.ibroadcast(t, root))
    }

    /// Async all-reduce on `world`.
    pub fn all_reduce(&self, world: &str, t: Tensor, op: ReduceOp) -> MwResult<Work> {
        let w = self.mgr.world(world)?;
        self.mgr.activate_state(world)?;
        Ok(w.iall_reduce(t, op))
    }

    /// Async reduce on `world`.
    pub fn reduce(&self, world: &str, t: Tensor, root: usize, op: ReduceOp) -> MwResult<Work> {
        let w = self.mgr.world(world)?;
        self.mgr.activate_state(world)?;
        Ok(w.ireduce(t, root, op))
    }

    /// Async all-gather on `world`.
    pub fn all_gather(&self, world: &str, t: Tensor) -> MwResult<Work> {
        let w = self.mgr.world(world)?;
        self.mgr.activate_state(world)?;
        Ok(w.iall_gather(t))
    }

    /// Async gather on `world`.
    pub fn gather(&self, world: &str, t: Tensor, root: usize) -> MwResult<Work> {
        let w = self.mgr.world(world)?;
        self.mgr.activate_state(world)?;
        Ok(w.igather(t, root))
    }

    /// Async scatter on `world`.
    pub fn scatter(&self, world: &str, parts: Option<Vec<Tensor>>, root: usize) -> MwResult<Work> {
        let w = self.mgr.world(world)?;
        self.mgr.activate_state(world)?;
        Ok(w.iscatter(parts, root))
    }

    // -------------------------------------------------------- completion

    /// Wait for the completion of *any* of `works`; returns its index.
    /// Uses the communicator's poll strategy. Completed-with-error works
    /// count as completed (the caller inspects the result).
    pub fn wait_any(&self, works: &[Work]) -> Option<usize> {
        self.wait_any_deadline(works, None)
    }

    /// `wait_any` with a deadline; `None` on timeout or empty set.
    pub fn wait_any_deadline(&self, works: &[Work], timeout: Option<Duration>) -> Option<usize> {
        if works.is_empty() {
            return None;
        }
        let deadline = timeout.map(|t| Instant::now() + t);
        let mut spins = 0u32;
        loop {
            for (i, w) in works.iter().enumerate() {
                if w.is_completed() {
                    return Some(i);
                }
            }
            if let Some(d) = deadline {
                if Instant::now() >= d {
                    return None;
                }
            }
            match self.strategy {
                PollStrategy::BusyWait => std::hint::spin_loop(),
                PollStrategy::SpinYield => {
                    spins += 1;
                    if spins < 64 {
                        std::hint::spin_loop();
                    } else {
                        spins = 0;
                        std::thread::yield_now();
                    }
                }
                PollStrategy::Sleep(d) => std::thread::sleep(d),
            }
        }
    }

    /// Drain: wait until *all* works complete, returning each result in
    /// order. Fault-tolerant — failures are collected, not propagated
    /// mid-way, so one broken world can't hide results from healthy
    /// ones.
    pub fn wait_all(&self, works: &[Work]) -> Vec<Result<Option<Tensor>, crate::mwccl::CclError>> {
        let mut done = vec![false; works.len()];
        let mut out: Vec<Option<Result<Option<Tensor>, crate::mwccl::CclError>>> =
            (0..works.len()).map(|_| None).collect();
        let mut remaining = works.len();
        while remaining > 0 {
            for (i, w) in works.iter().enumerate() {
                if !done[i] {
                    if let Some(res) = w.poll() {
                        out[i] = Some(res);
                        done[i] = true;
                        remaining -= 1;
                    }
                }
            }
            if remaining > 0 {
                match self.strategy {
                    PollStrategy::BusyWait => std::hint::spin_loop(),
                    PollStrategy::SpinYield => std::thread::yield_now(),
                    PollStrategy::Sleep(d) => std::thread::sleep(d),
                }
            }
        }
        out.into_iter().map(|r| r.unwrap()).collect()
    }

    /// Blocking helper: issue a receive and wait for the tensor.
    pub fn recv_blocking(&self, world: &str, src: usize, tag: u64) -> MwResult<Tensor> {
        let work = self.recv(world, src, tag)?;
        match work.wait() {
            Ok(Some(t)) => Ok(t),
            Ok(None) => Err(MwError::Ccl(crate::mwccl::CclError::Transport(
                "recv resolved without tensor".into(),
            ))),
            Err(e) => {
                // Fault-tolerance contract: a failed op quarantines its
                // world but leaves every other world untouched.
                if e.is_fatal_to_world() {
                    self.mgr.break_world(world, &e.to_string());
                }
                Err(MwError::Ccl(e))
            }
        }
    }

    /// Blocking helper: issue a send and wait for completion.
    pub fn send_blocking(&self, world: &str, t: Tensor, dst: usize, tag: u64) -> MwResult<()> {
        let work = self.send(world, t, dst, tag)?;
        match work.wait() {
            Ok(_) => Ok(()),
            Err(e) => {
                if e.is_fatal_to_world() {
                    self.mgr.break_world(world, &e.to_string());
                }
                Err(MwError::Ccl(e))
            }
        }
    }

    /// The manager backing this communicator.
    pub fn manager(&self) -> &WorldManager {
        &self.mgr
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wait_any_empty_is_none() {
        let mgr = WorldManager::new();
        let comm = mgr.communicator();
        assert_eq!(comm.wait_any(&[]), None);
    }

    #[test]
    fn unknown_world_error() {
        let mgr = WorldManager::new();
        let comm = mgr.communicator();
        let err = comm
            .send("ghost", Tensor::from_f32(&[1], &[0.0]), 1, 0)
            .unwrap_err();
        assert!(matches!(err, MwError::UnknownWorld(_)));
    }

    #[test]
    fn poll_strategy_default_spin_yield() {
        let mgr = WorldManager::new();
        let comm = mgr.communicator();
        assert_eq!(comm.strategy(), PollStrategy::SpinYield);
        let comm = comm.with_strategy(PollStrategy::BusyWait);
        assert_eq!(comm.strategy(), PollStrategy::BusyWait);
    }
}
