//! MultiWorld — the paper's contribution (§3).
//!
//! The CCL below ([`crate::mwccl`]) gives us worlds that are static,
//! single-fault-domain process groups. This layer makes a *worker*
//! elastic by letting it hold **many worlds at once** and by managing
//! their lifecycle:
//!
//! * [`manager::WorldManager`] — `initialize_world` / `remove_world` /
//!   `communicator` (§3.3 "World Manager"). World initialization is a
//!   blocking collective, so it can run on a separate thread
//!   ([`manager::WorldManager::initialize_world_async`]) — this is what
//!   keeps existing worlds' traffic flowing while a new worker joins
//!   (Fig. 5: no impact on W1's throughput while the leader waits for
//!   W2-R1).
//! * [`communicator::WorldCommunicator`] — fault-tolerant, non-blocking
//!   collectives addressed by world *name* (§3.3 "World Communicator";
//!   "including a world name as a function argument suffices"), plus the
//!   busy-wait polling loop over many worlds' pending works.
//! * [`watchdog::Watchdog`] — the threaded daemon heart-beating through
//!   each world's TCPStore and flagging worlds whose members go quiet
//!   (§3.3 "Watchdog"); the only failure signal on the shared-memory
//!   path.
//! * [`state::StateManager`] — per-world state kept as key-value entries
//!   (our design) vs. save/restore swapping (the naive baseline the
//!   paper rejects; kept for the ablation bench).

pub mod communicator;
pub mod manager;
pub mod state;
pub mod watchdog;

pub use communicator::{PollStrategy, WorldCommunicator};
pub use manager::{WorldEvent, WorldManager};
pub use state::{KvStateManager, StateManager, StatePolicy, SwapStateManager};
pub use watchdog::{Watchdog, WatchdogConfig};

use crate::mwccl::CclError;

/// Errors from the MultiWorld layer.
#[derive(Clone, Debug, thiserror::Error)]
pub enum MwError {
    /// No world with that name is registered with the manager.
    #[error("unknown world '{0}'")]
    UnknownWorld(String),

    /// `initialize_world` for a name that already exists.
    #[error("world '{0}' already exists")]
    AlreadyExists(String),

    /// The world exists but was broken (watchdog or remote error) and is
    /// quarantined pending cleanup.
    #[error("world '{0}' is broken: {1}")]
    Broken(String, String),

    /// Underlying CCL failure.
    #[error(transparent)]
    Ccl(#[from] CclError),
}

pub type MwResult<T> = Result<T, MwError>;
