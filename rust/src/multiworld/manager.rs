//! The World Manager (§3.3): initialization and termination of worlds,
//! quarantine of broken worlds, and the cleanup pipeline driven by
//! watchdog alerts.

use super::state::{make_state_manager, StateManager, StatePolicy, WorldState};
use super::watchdog::{Watchdog, WatchdogConfig};
use super::{MwError, MwResult, WorldCommunicator};
use crate::mwccl::{World, WorldOptions};
use crate::util::time::Clock;
use std::collections::HashMap;
use std::net::SocketAddr;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Mutex, RwLock};

/// Size of the simulated communicator blob registered per world (what a
/// real CCL would keep per communicator: peer endpoints, channel state).
const COMM_BLOB_BYTES: usize = 16 * 1024;

/// Lifecycle notifications delivered to subscribers.
#[derive(Clone, Debug, PartialEq)]
pub enum WorldEvent {
    Added(String),
    /// World broke (watchdog alert or remote error) and was cleaned up.
    /// `culprit` is the rank whose death broke it, when the failure
    /// signal attributes one (watchdog missed-heartbeat alerts and TCP
    /// `RemoteError`s do; local aborts don't) — the serving controller
    /// uses it for shard-granularity recovery inside multi-member TP
    /// worlds.
    Broken { world: String, reason: String, culprit: Option<usize> },
    Removed(String),
}

type WorldMap = Arc<RwLock<HashMap<String, World>>>;
type Subscribers = Arc<Mutex<Vec<Sender<WorldEvent>>>>;
type Tombstones = Arc<Mutex<HashMap<String, String>>>;

/// Stops the watchdog daemon when the last manager clone drops. Without
/// this, the daemon's self-`Arc` would keep it heart-beating after its
/// owner died — a zombie that makes dead workers look alive to peers.
struct WatchdogGuard(Arc<Watchdog>);

impl Drop for WatchdogGuard {
    fn drop(&mut self) {
        self.0.shutdown();
    }
}

/// The manager. Cheap to clone (all state shared).
#[derive(Clone)]
pub struct WorldManager {
    worlds: WorldMap,
    state: Arc<dyn StateManager>,
    subscribers: Subscribers,
    /// Worlds that broke, with the reason — so the communicator can
    /// answer `Broken` rather than `UnknownWorld` after cleanup.
    tombstones: Tombstones,
    watchdog: Arc<Watchdog>,
    _wd_guard: Arc<WatchdogGuard>,
}

impl WorldManager {
    /// Create a manager with the paper's key-value state management and
    /// a running watchdog.
    pub fn new() -> WorldManager {
        Self::with_options(StatePolicy::Kv, WatchdogConfig::default(), Clock::system())
    }

    /// Full-control constructor (state policy for the ablation, manual
    /// clock for deterministic tests).
    pub fn with_options(
        policy: StatePolicy,
        wd_cfg: WatchdogConfig,
        clock: Clock,
    ) -> WorldManager {
        let worlds: WorldMap = Arc::new(RwLock::new(HashMap::new()));
        let subscribers: Subscribers = Arc::new(Mutex::new(Vec::new()));
        let tombstones: Tombstones = Arc::new(Mutex::new(HashMap::new()));
        let state: Arc<dyn StateManager> = Arc::from(make_state_manager(policy));

        // Watchdog alert → quarantine & clean up the world.
        let cb_worlds = worlds.clone();
        let cb_subs = subscribers.clone();
        let cb_tombs = tombstones.clone();
        let cb_state = state.clone();
        let watchdog = Watchdog::start(
            wd_cfg,
            clock,
            Arc::new(move |world: &str, reason: &str, culprit: Option<usize>| {
                Self::break_world_impl(
                    &cb_worlds,
                    &cb_subs,
                    &cb_tombs,
                    cb_state.as_ref(),
                    world,
                    reason,
                    culprit,
                );
            }),
        );

        let guard = Arc::new(WatchdogGuard(watchdog.clone()));
        WorldManager { worlds, state, subscribers, tombstones, watchdog, _wd_guard: guard }
    }

    /// Initialize (join) a world and put it under management. Blocking:
    /// returns once every member has arrived (see
    /// [`Self::initialize_world_async`] for the non-disruptive form).
    pub fn initialize_world(
        &self,
        name: &str,
        rank: usize,
        size: usize,
        store_addr: SocketAddr,
        opts: WorldOptions,
    ) -> MwResult<()> {
        if self.worlds.read().unwrap().contains_key(name) {
            return Err(MwError::AlreadyExists(name.to_string()));
        }
        let world = World::init(name, rank, size, store_addr, opts)?;
        self.adopt(world)
    }

    /// Put an externally initialized world under management (used by the
    /// launcher, and by tests that build worlds directly).
    pub fn adopt(&self, world: World) -> MwResult<()> {
        let name = world.name().to_string();
        let (rank, size) = (world.rank(), world.size());
        {
            let mut map = self.worlds.write().unwrap();
            if map.contains_key(&name) {
                return Err(MwError::AlreadyExists(name));
            }
            self.state
                .insert(WorldState::new(&name, rank, size, COMM_BLOB_BYTES));
            if let Some(store) = world.store() {
                self.watchdog.watch(&name, rank, size, store);
            }
            map.insert(name.clone(), world);
        }
        self.tombstones.lock().unwrap().remove(&name);
        self.emit(WorldEvent::Added(name));
        Ok(())
    }

    /// Fig. 5's mechanism: run the blocking `initialize_world` on a
    /// separate thread so in-flight traffic on existing worlds is never
    /// stalled while waiting for a joiner. Returns a handle to await.
    pub fn initialize_world_async(
        &self,
        name: &str,
        rank: usize,
        size: usize,
        store_addr: SocketAddr,
        opts: WorldOptions,
    ) -> InitHandle {
        let mgr = self.clone();
        let name = name.to_string();
        let result: Arc<Mutex<Option<MwResult<()>>>> = Arc::new(Mutex::new(None));
        let r2 = result.clone();
        let thread = std::thread::Builder::new()
            .name(format!("mw-init-{name}"))
            .spawn(move || {
                let res = mgr.initialize_world(&name, rank, size, store_addr, opts);
                *r2.lock().unwrap() = Some(res);
            })
            .expect("spawn init thread");
        InitHandle { result, thread: Some(thread) }
    }

    /// Gracefully terminate a world: unwatch, abort pending collectives,
    /// drop links and state.
    pub fn remove_world(&self, name: &str) -> MwResult<()> {
        let world = {
            let mut map = self.worlds.write().unwrap();
            map.remove(name)
        };
        let world = world.ok_or_else(|| MwError::UnknownWorld(name.to_string()))?;
        self.watchdog.unwatch(name);
        self.state.remove(name);
        // Best-effort heartbeat-key cleanup while the store is still up.
        if let Some(store) = world.store() {
            if let Ok(keys) = store.keys(&format!("mw/{name}/hb/")) {
                for k in keys {
                    let _ = store.delete(&k);
                }
            }
        }
        world.abort("world removed");
        self.tombstones.lock().unwrap().remove(name);
        self.emit(WorldEvent::Removed(name.to_string()));
        Ok(())
    }

    /// The communicator façade for issuing collectives by world name.
    pub fn communicator(&self) -> WorldCommunicator {
        WorldCommunicator::new(self.clone())
    }

    /// Resolve a live world. Detects worlds that broke via remote error
    /// (progress thread marked them) and routes them through cleanup.
    pub fn world(&self, name: &str) -> MwResult<World> {
        let world = {
            let map = self.worlds.read().unwrap();
            map.get(name).cloned()
        };
        match world {
            Some(w) if w.is_broken() => {
                let reason = w
                    .broken_reason()
                    .map(|e| e.to_string())
                    .unwrap_or_else(|| "unknown".into());
                self.break_world(name, &reason);
                Err(MwError::Broken(name.to_string(), reason))
            }
            Some(w) => Ok(w),
            None => {
                if let Some(reason) = self.tombstones.lock().unwrap().get(name) {
                    return Err(MwError::Broken(name.to_string(), reason.clone()));
                }
                Err(MwError::UnknownWorld(name.to_string()))
            }
        }
    }

    /// Per-op state activation (see `state.rs`); also where the kv-vs-
    /// swap ablation cost lands on the hot path.
    pub(crate) fn activate_state(&self, name: &str) -> MwResult<u64> {
        self.state
            .next_seq(name)
            .ok_or_else(|| MwError::UnknownWorld(name.to_string()))
    }

    /// Names of live worlds.
    pub fn world_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.worlds.read().unwrap().keys().cloned().collect();
        v.sort();
        v
    }

    /// Subscribe to lifecycle events.
    pub fn subscribe(&self) -> Receiver<WorldEvent> {
        let (tx, rx) = std::sync::mpsc::channel();
        self.subscribers.lock().unwrap().push(tx);
        rx
    }

    /// Declare a world broken (watchdog path calls the impl directly;
    /// this is for the remote-error path and tests). The culprit rank is
    /// recovered from the world's broken reason when the transport
    /// attributed one (`CclError::RemoteError { peer, .. }`) — but only
    /// on two-member worlds, where the erroring peer *is* the other
    /// member. On larger worlds a local abort cascade closes every
    /// link, so a survivor's `RemoteError` may name an innocent peer
    /// that merely aborted first; those worlds rely on the watchdog's
    /// per-rank heartbeat attribution instead.
    pub fn break_world(&self, name: &str, reason: &str) {
        let culprit = {
            let map = self.worlds.read().unwrap();
            map.get(name).and_then(|w| match w.broken_reason() {
                Some(crate::mwccl::CclError::RemoteError { peer, .. }) if w.size() == 2 => {
                    Some(peer)
                }
                _ => None,
            })
        };
        Self::break_world_impl(
            &self.worlds,
            &self.subscribers,
            &self.tombstones,
            self.state.as_ref(),
            name,
            reason,
            culprit,
        );
    }

    #[allow(clippy::too_many_arguments)]
    fn break_world_impl(
        worlds: &WorldMap,
        subscribers: &Subscribers,
        tombstones: &Tombstones,
        state: &dyn StateManager,
        name: &str,
        reason: &str,
        culprit: Option<usize>,
    ) {
        let world = {
            let mut map = worlds.write().unwrap();
            map.remove(name)
        };
        let Some(world) = world else {
            return; // already cleaned up
        };
        // Observable without MW_DEBUG: a global counter plus one
        // structured line greppable in bench output and CI logs
        // (mirrors the watchdog's own alert instrumentation).
        crate::metrics::global().counter("manager.worlds_broken").inc();
        let culprit_s = culprit.map(|c| c.to_string()).unwrap_or_else(|| "-".into());
        crate::metrics::log_event(
            "manager.world_broken",
            &[
                ("world", name),
                ("reason", reason),
                ("culprit_rank", culprit_s.as_str()),
            ],
        );
        // Abort pending collective ops so the application unblocks with
        // an exception it can handle (§3.3). Announced: this break is a
        // *decision* (watchdog verdict, timeout, explicit report), so
        // peers get a GOODBYE and see `Aborted` — never a `RemoteError`
        // that would convict this still-alive rank as dead. Process
        // death skips this path entirely (nothing announces), keeping
        // crash semantics intact.
        world.abort_announced(reason);
        state.remove(name);
        tombstones
            .lock()
            .unwrap()
            .insert(name.to_string(), reason.to_string());
        let event = WorldEvent::Broken {
            world: name.to_string(),
            reason: reason.to_string(),
            culprit,
        };
        let mut subs = subscribers.lock().unwrap();
        subs.retain(|tx| tx.send(event.clone()).is_ok());
    }

    fn emit(&self, event: WorldEvent) {
        let mut subs = self.subscribers.lock().unwrap();
        subs.retain(|tx| tx.send(event.clone()).is_ok());
    }

    /// Access to the watchdog (benches tune it; tests drive ticks).
    pub fn watchdog(&self) -> &Arc<Watchdog> {
        &self.watchdog
    }
}

impl Default for WorldManager {
    fn default() -> Self {
        Self::new()
    }
}

/// Handle returned by [`WorldManager::initialize_world_async`].
pub struct InitHandle {
    result: Arc<Mutex<Option<MwResult<()>>>>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl InitHandle {
    /// Non-blocking completion check.
    pub fn is_done(&self) -> bool {
        self.result.lock().unwrap().is_some()
    }

    /// Block until initialization finishes and return its result.
    pub fn wait(mut self) -> MwResult<()> {
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
        self.result
            .lock()
            .unwrap()
            .take()
            .unwrap_or_else(|| Err(MwError::Ccl(crate::mwccl::CclError::InitFailure(
                "init thread vanished".into(),
            ))))
    }
}

impl Drop for InitHandle {
    fn drop(&mut self) {
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}
