//! A criterion-free benchmark harness.
//!
//! `cargo bench` targets in this repo are `harness = false` binaries that
//! use [`BenchRunner`] for timing (warmup + measured iterations, robust
//! stats) and [`Table`] to print the paper-figure rows. Results are also
//! dumped as CSV under `target/bench-results/` so EXPERIMENTS.md can
//! reference exact numbers.

pub mod scenarios;
pub mod stats;

pub use stats::Summary;

use std::fmt::Write as _;
use std::path::PathBuf;
use std::time::{Duration, Instant};

/// Timing harness: run a closure for `warmup` then `iters` measured
/// passes and summarize.
pub struct BenchRunner {
    pub warmup: usize,
    pub iters: usize,
    /// Hard cap on measurement wallclock; stops early if exceeded.
    pub max_time: Duration,
}

impl Default for BenchRunner {
    fn default() -> Self {
        BenchRunner { warmup: 3, iters: 10, max_time: Duration::from_secs(30) }
    }
}

impl BenchRunner {
    pub fn quick() -> Self {
        BenchRunner { warmup: 1, iters: 5, max_time: Duration::from_secs(10) }
    }

    /// Honor `MW_BENCH_QUICK=1` for CI-speed runs.
    pub fn from_env() -> Self {
        if std::env::var("MW_BENCH_QUICK").as_deref() == Ok("1") {
            Self::quick()
        } else {
            Self::default()
        }
    }

    /// Time `f` (which should perform one complete unit of work and may
    /// return a per-iteration byte count for throughput summaries).
    pub fn run<F: FnMut() -> u64>(&self, mut f: F) -> Summary {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::with_capacity(self.iters);
        let mut bytes = 0u64;
        let start = Instant::now();
        for _ in 0..self.iters {
            let t0 = Instant::now();
            bytes = f();
            samples.push(t0.elapsed().as_secs_f64());
            if start.elapsed() > self.max_time {
                break;
            }
        }
        Summary::from_samples(&samples, bytes)
    }
}

/// A printable results table, matching the rows of one paper figure.
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells.to_vec());
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "\n=== {} ===", self.title);
        let line = |cells: &[String], widths: &[usize]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(s, "{:<w$}  ", c, w = widths[i]);
            }
            s.trim_end().to_string()
        };
        let _ = writeln!(out, "{}", line(&self.headers, &widths));
        let _ = writeln!(out, "{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        for row in &self.rows {
            let _ = writeln!(out, "{}", line(row, &widths));
        }
        out
    }

    /// Print to stdout and persist CSV under `target/bench-results/`.
    pub fn emit(&self, csv_name: &str) {
        print!("{}", self.render());
        let mut csv = self.headers.join(",") + "\n";
        for row in &self.rows {
            csv.push_str(&row.join(","));
            csv.push('\n');
        }
        let dir = results_dir();
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join(format!("{csv_name}.csv"));
        if std::fs::write(&path, &csv).is_ok() {
            println!("[csv] {}", path.display());
        }
    }
}

/// Where bench CSVs land.
pub fn results_dir() -> PathBuf {
    PathBuf::from("target/bench-results")
}

/// `git <args>` → trimmed stdout, or `""` off a checkout/without git.
fn git_out(args: &[&str]) -> String {
    std::process::Command::new("git")
        .args(args)
        .output()
        .ok()
        .filter(|o| o.status.success())
        .map(|o| String::from_utf8_lossy(&o.stdout).trim().to_string())
        .unwrap_or_default()
}

/// Provenance block stamped into every `BENCH_*.json` artifact under
/// the `"meta"` key: which commit/branch/CI run produced the numbers,
/// and the knob settings (collective algorithm, host map, spare pool,
/// quick mode) that shaped them. Tools that trend artifacts across
/// commits (`tools/check_crossover.py`, `tools/check_mttr.py`) read the
/// identity fields and skip the key when comparing sections. Prefers
/// the GitHub Actions envs; falls back to asking `git` directly so
/// local runs are attributable too.
pub fn bench_meta() -> crate::util::json::Json {
    use crate::util::json::Json;
    let env_or = |name: &str, fallback: &dyn Fn() -> String| {
        std::env::var(name).ok().filter(|v| !v.is_empty()).unwrap_or_else(|| fallback())
    };
    let sha = env_or("GITHUB_SHA", &|| git_out(&["rev-parse", "HEAD"]));
    let branch =
        env_or("GITHUB_REF_NAME", &|| git_out(&["rev-parse", "--abbrev-ref", "HEAD"]));
    let run_id = std::env::var("GITHUB_RUN_ID").unwrap_or_default();
    let envs = ["MW_COLL_ALGO", "MW_HOSTMAP", "MW_SPARES", "MW_WEIGHT_CACHE",
        "MW_FAULT_SEED", "MW_BENCH_QUICK"];
    let config = envs
        .iter()
        .filter_map(|k| std::env::var(k).ok().map(|v| (*k, Json::str(v))))
        .collect::<Vec<_>>();
    Json::obj(vec![
        ("sha", Json::str(sha)),
        ("branch", Json::str(branch)),
        ("run_id", Json::str(run_id)),
        ("config", Json::obj(config)),
    ])
}

/// Persist a machine-readable trajectory artifact (the `BENCH_*.json`
/// files CI uploads so collective/serving numbers are comparable
/// across commits).
pub fn write_json(name: &str, json: &crate::util::json::Json) {
    let dir = results_dir();
    let _ = std::fs::create_dir_all(&dir);
    let path = dir.join(format!("{name}.json"));
    if std::fs::write(&path, json.to_string()).is_ok() {
        println!("[json] {}", path.display());
    }
}

/// Persist an arbitrary CSV (used by the timeline figures).
pub fn write_csv(name: &str, content: &str) {
    let dir = results_dir();
    let _ = std::fs::create_dir_all(&dir);
    let path = dir.join(format!("{name}.csv"));
    if std::fs::write(&path, content).is_ok() {
        println!("[csv] {}", path.display());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runner_collects_samples() {
        let r = BenchRunner { warmup: 1, iters: 4, max_time: Duration::from_secs(5) };
        let s = r.run(|| {
            std::thread::sleep(Duration::from_millis(2));
            1024
        });
        assert_eq!(s.n, 4);
        assert!(s.mean >= 0.002);
        assert!(s.throughput_bps(1024) > 0.0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Fig X", &["size", "MW", "SW"]);
        t.row(&["4K".into(), "1.00".into(), "1.02".into()]);
        t.row(&["4M".into(), "15.40".into(), "15.90".into()]);
        let s = t.render();
        assert!(s.contains("=== Fig X ==="));
        assert!(s.contains("4M"));
    }

    #[test]
    fn bench_meta_is_a_well_formed_object() {
        let m = bench_meta();
        assert!(m.get("sha").and_then(|s| s.as_str()).is_some());
        assert!(m.get("branch").and_then(|s| s.as_str()).is_some());
        assert!(m.get("run_id").and_then(|s| s.as_str()).is_some());
        assert!(m.get("config").and_then(|c| c.as_obj()).is_some());
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_rejects_bad_arity() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
