//! Summary statistics for benchmark samples.

/// Robust summary of a set of duration samples (seconds).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    /// Bytes moved per iteration (0 if not a throughput bench).
    pub bytes_per_iter: u64,
}

impl Summary {
    pub fn from_samples(samples: &[f64], bytes_per_iter: u64) -> Self {
        if samples.is_empty() {
            return Summary::default();
        }
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|s| (s - mean).powi(2)).sum::<f64>() / n as f64;
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.total_cmp(b));
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile(&sorted, 0.50),
            p90: percentile(&sorted, 0.90),
            p99: percentile(&sorted, 0.99),
            bytes_per_iter,
        }
    }

    /// Mean throughput for `bytes` per iteration.
    pub fn throughput_bps(&self, bytes: u64) -> f64 {
        if self.mean <= 0.0 {
            0.0
        } else {
            bytes as f64 / self.mean
        }
    }

    /// Throughput using the recorded per-iteration byte count.
    pub fn throughput(&self) -> f64 {
        self.throughput_bps(self.bytes_per_iter)
    }
}

/// Linear-interpolated percentile of pre-sorted data.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_known_samples() {
        let s = Summary::from_samples(&[1.0, 2.0, 3.0, 4.0], 100);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.p50 - 2.5).abs() < 1e-12);
        assert!((s.throughput_bps(100) - 40.0).abs() < 1e-9);
        assert!((s.throughput() - 40.0).abs() < 1e-9);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [10.0, 20.0, 30.0, 40.0, 50.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 1.0), 50.0);
        assert!((percentile(&xs, 0.5) - 30.0).abs() < 1e-12);
        assert!((percentile(&xs, 0.25) - 20.0).abs() < 1e-12);
    }

    #[test]
    fn empty_is_zero() {
        let s = Summary::from_samples(&[], 0);
        assert_eq!(s.n, 0);
        assert_eq!(s.throughput_bps(100), 0.0);
    }

    #[test]
    fn std_is_zero_for_constant() {
        let s = Summary::from_samples(&[2.0, 2.0, 2.0], 0);
        assert!(s.std.abs() < 1e-12);
    }
}
