//! Reusable measurement scenarios for the paper-figure benches.
//!
//! Every §4.3 experiment is some variant of *N senders → one receiver,
//! tensors of size S, architecture ∈ {SW, MW, MP}*. These helpers build
//! the deployment (threads for SW/MW ranks, subprocesses for MP), move
//! `msgs` tensors of `elems` f32 each and return the aggregate receiver
//! throughput in bytes/sec, timed from first to last tensor.

use crate::baselines::multiproc::MpEndpoint;
use crate::config::ServingConfig;
use crate::launch::InProcCluster;
use crate::multiworld::{PollStrategy, StatePolicy, WatchdogConfig, WorldManager};
use crate::mwccl::{EdgePattern, FaultKind, FaultPlan, FaultRule, Rendezvous, WorldOptions};
use crate::serving::autoscaler::AutoscalePolicy;
use crate::serving::controller::{Action, ScalingPolicy};
use crate::serving::topology::Topology;
use crate::serving::{LeaderReport, Outcome, RequestGen, StreamEvent};
use crate::tensor::Tensor;
use crate::util::prng::Rng;
use crate::util::time::Clock;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

fn uniq(prefix: &str) -> String {
    static N: AtomicU64 = AtomicU64::new(0);
    format!(
        "{prefix}-{}-{}",
        std::process::id(),
        N.fetch_add(1, Ordering::Relaxed)
    )
}

/// Paper tensor sizes (f32 element counts): 1K…1M = 4 KB…4 MB.
pub const PAPER_SIZES: [(usize, &str); 4] =
    [(1_000, "4K"), (10_000, "40K"), (100_000, "400K"), (1_000_000, "4M")];

/// Single-world fan-in: one world of `n_senders + 1` ranks (rank 0
/// receives), vanilla CCL ops, no MultiWorld layer.
pub fn sw_fanin_throughput(
    n_senders: usize,
    elems: usize,
    msgs: usize,
    opts: WorldOptions,
) -> f64 {
    let worlds = Rendezvous::single_process(&uniq("swf"), n_senders + 1, opts)
        .expect("sw rendezvous");
    let mut it = worlds.into_iter();
    let receiver = it.next().unwrap();
    let senders: Vec<_> = it.collect();
    let handles: Vec<_> = senders
        .into_iter()
        .map(|w| {
            std::thread::spawn(move || {
                let mut rng = Rng::new(w.rank() as u64);
                let t = Tensor::f32_1d(elems, &mut rng);
                for k in 0..msgs {
                    w.send(t.clone(), 0, k as u64).unwrap();
                }
                w // keep alive until all sends complete
            })
        })
        .collect();
    let total = n_senders * msgs;
    let bytes = (elems * 4 * total) as f64;
    let t0 = Instant::now();
    // Harvest: post one irecv per sender, refill as they land.
    let mut pending: Vec<(usize, crate::mwccl::Work, usize)> = (1..=n_senders)
        .map(|src| (src, receiver.irecv(src, 0), 1usize))
        .collect();
    let mut received = 0usize;
    while received < total {
        let idx = {
            let mut spins = 0u32;
            loop {
                if let Some(i) = pending.iter().position(|(_, w, _)| w.is_completed()) {
                    break i;
                }
                spins += 1;
                if spins < 64 {
                    std::hint::spin_loop();
                } else {
                    // On small core counts a pure spin starves the
                    // senders; yield like the MW poller does.
                    spins = 0;
                    std::thread::yield_now();
                }
            }
        };
        let (src, work, next_k) = pending.swap_remove(idx);
        work.wait().unwrap();
        received += 1;
        if next_k < msgs {
            pending.push((src, receiver.irecv(src, next_k as u64), next_k + 1));
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    for h in handles {
        h.join().unwrap();
    }
    bytes / dt
}

/// MultiWorld fan-in: one two-member world per sender, a WorldManager
/// with watchdog + kv state on the receiver, completion via the
/// communicator's poller — the full §3.3 stack on the hot path.
pub fn mw_fanin_throughput(
    n_senders: usize,
    elems: usize,
    msgs: usize,
    opts: WorldOptions,
    policy: StatePolicy,
    strategy: PollStrategy,
) -> f64 {
    // Long watchdog period: the senders are raw Worlds that don't
    // heartbeat, and liveness is not what a throughput scenario measures
    // (fig4/fig5 exercise the watchdog explicitly).
    let wd = WatchdogConfig { heartbeat: std::time::Duration::from_secs(600), miss_threshold: 1000 };
    let mgr = WorldManager::with_options(policy, wd, Clock::system());
    let comm = mgr.communicator().with_strategy(strategy);
    let mut names = Vec::new();
    let mut handles = Vec::new();
    for s in 0..n_senders {
        let name = uniq(&format!("mwf{s}"));
        let worlds =
            Rendezvous::single_process(&name, 2, opts.clone()).expect("mw rendezvous");
        let mut it = worlds.into_iter();
        mgr.adopt(it.next().unwrap()).expect("adopt");
        let sender = it.next().unwrap();
        names.push(name);
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(s as u64);
            let t = Tensor::f32_1d(elems, &mut rng);
            for k in 0..msgs {
                sender.send(t.clone(), 0, k as u64).unwrap();
            }
            sender
        }));
    }
    let total = n_senders * msgs;
    let bytes = (elems * 4 * total) as f64;
    let t0 = Instant::now();
    let mut pending: Vec<(usize, crate::mwccl::Work, usize)> = names
        .iter()
        .enumerate()
        .map(|(i, n)| (i, comm.recv(n, 1, 0).unwrap(), 1usize))
        .collect();
    let mut received = 0usize;
    while received < total {
        let works: Vec<crate::mwccl::Work> =
            pending.iter().map(|(_, w, _)| w.clone()).collect();
        let idx = comm.wait_any(&works).expect("wait_any");
        let (world_idx, work, next_k) = pending.swap_remove(idx);
        work.wait().unwrap();
        received += 1;
        if next_k < msgs {
            pending.push((
                world_idx,
                comm.recv(&names[world_idx], 1, next_k as u64).unwrap(),
                next_k + 1,
            ));
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    for h in handles {
        h.join().unwrap();
    }
    bytes / dt
}

/// MP point-to-point: sender main → proxy subprocess → CCL → proxy
/// subprocess → receiver main, tensors serialized over pipes both ways
/// (§4.3's MultiProcessing architecture; one sender only, as in Fig 6).
pub fn mp_p2p_throughput(elems: usize, msgs: usize, transport: &str) -> anyhow::Result<f64> {
    let world = uniq("mp");
    let port = crate::util::free_port();
    let mut sender = MpEndpoint::spawn(&world, 0, port, transport)?;
    let mut receiver = MpEndpoint::spawn(&world, 1, port, transport)?;
    let mut rng = Rng::new(1);
    let t = Tensor::f32_1d(elems, &mut rng);
    let bytes = (elems * 4 * msgs) as f64;
    // Warm the path (NCCL-style lazy communicator creation analogue).
    sender.send_tensor(&t)?;
    receiver.recv_tensor()?;
    let t0 = Instant::now();
    let feeder = std::thread::spawn(move || -> anyhow::Result<MpEndpoint> {
        for _ in 0..msgs {
            sender.send_tensor(&t)?;
        }
        Ok(sender)
    });
    for _ in 0..msgs {
        let got = receiver.recv_tensor()?;
        debug_assert_eq!(got.elems(), elems);
    }
    let dt = t0.elapsed().as_secs_f64();
    let sender = feeder.join().unwrap()?;
    sender.shutdown()?;
    receiver.shutdown()?;
    Ok(bytes / dt)
}

/// TP×replica serving scenario: a forward-only pipeline of `stages`
/// stages, each with `replicas` replicas of `tp` shards, serving
/// `n_requests` end to end through the leader (dynamic batching,
/// least-inflight routing, and — for `tp > 1` — the intra-replica
/// broadcast/all_reduce inner loop on every batch). Returns the
/// leader's report; `report.completed == n_requests` on success.
///
/// `base_port` seeds the store ports (the caller spaces ranges like the
/// integration tests do). Forward-only workers echo activations, so
/// the measurement isolates transport + collective + elasticity
/// machinery from PJRT compute.
pub fn tp_pipeline_serve(
    stages: usize,
    replicas: usize,
    tp: usize,
    n_requests: usize,
    opts: WorldOptions,
    base_port: u16,
) -> anyhow::Result<LeaderReport> {
    const BATCH: usize = 4;
    const SEQ_LEN: usize = 8;
    const VOCAB: usize = 32;
    let topo = Topology::pipeline_tp(
        &uniq("tpbench"),
        &vec![replicas; stages],
        &vec![tp; stages],
        base_port,
    );
    let cfg = ServingConfig { batch_timeout_ms: 2, ..Default::default() };
    let cluster = InProcCluster::start_forward_only(
        topo,
        opts,
        ScalingPolicy { recover: false, ..Default::default() },
        &cfg,
        BATCH,
        SEQ_LEN,
        VOCAB,
    )?;
    let mut gen = RequestGen::new(0xBEEF, SEQ_LEN, VOCAB, None);
    let report = cluster
        .leader
        .serve(gen.take(n_requests), None, std::time::Duration::from_secs(120));
    cluster.shutdown();
    Ok(report)
}

/// What a [`streaming_serve`] run measured. TTFT/ITL are sampled
/// **client-side** — wall time between `submit` and each
/// [`StreamEvent::Token`] arrival at the handle — so one report covers
/// exactly one leg (the leader's `serving.ttft_ms`/`serving.itl_ms`
/// windows are global and would mix back-to-back legs).
#[derive(Clone, Debug)]
pub struct StreamReport {
    pub completed: usize,
    pub dropped: usize,
    pub total_tokens: usize,
    pub elapsed_s: f64,
    pub requests_per_s: f64,
    pub tokens_per_s: f64,
    pub ttft_p50_ms: f64,
    pub ttft_p99_ms: f64,
    pub itl_p50_ms: f64,
    pub itl_p99_ms: f64,
}

/// Continuous-batching scenario: a forward-only single-stage pipeline
/// saturated with multi-token (streaming) requests of mixed decode
/// budgets — every `heavy_every`-th request generates `heavy_budget`
/// tokens, the rest `light_budget` — all submitted up front so the
/// decode loop runs at capacity for the whole measurement.
///
/// The budget mix is the whole point: under iteration-level scheduling
/// a finished light request's slot is re-filled on the very next decode
/// step, while `gang = true` (`MW_DECODE_GANG`) holds every slot until
/// the batch's heavy straggler retires — run-to-completion semantics
/// over the identical streaming wire. The two legs differ only in that
/// admission rule, so their throughput ratio isolates exactly what
/// continuous batching buys (structurally ≈ the iteration-count ratio,
/// robust to box speed: each iteration is one leader↔worker RTT in both
/// legs).
pub fn streaming_serve(
    n_requests: usize,
    heavy_every: usize,
    heavy_budget: u32,
    light_budget: u32,
    gang: bool,
    opts: WorldOptions,
    base_port: u16,
) -> anyhow::Result<StreamReport> {
    const BATCH: usize = 4;
    const SEQ_LEN: usize = 8;
    const VOCAB: usize = 32;
    let topo = Topology::pipeline(&uniq("cbatch"), &[1], base_port);
    let cfg = ServingConfig { batch_timeout_ms: 2, decode_gang: gang, ..Default::default() };
    let cluster = InProcCluster::start_forward_only(
        topo,
        opts,
        ScalingPolicy { recover: false, ..Default::default() },
        &cfg,
        BATCH,
        SEQ_LEN,
        VOCAB,
    )?;
    let mut gen = RequestGen::new(0x5EED, SEQ_LEN, VOCAB, None);
    let t0 = Instant::now();
    let mut consumers = Vec::with_capacity(n_requests);
    for i in 0..n_requests {
        let (req, _) = gen.next();
        let budget = if heavy_every > 0 && i % heavy_every == 0 {
            heavy_budget
        } else {
            light_budget
        };
        let submitted = Instant::now();
        let h = cluster.leader.submit_blocking(req.with_max_tokens(budget));
        consumers.push(std::thread::spawn(move || {
            let deadline = Instant::now() + Duration::from_secs(120);
            let mut ttft_ms: Option<f64> = None;
            let mut gaps_ms: Vec<f64> = Vec::new();
            let mut last = submitted;
            let mut tokens = 0usize;
            loop {
                match h.next_event(deadline) {
                    Some(StreamEvent::Token(_)) => {
                        let now = Instant::now();
                        let gap = now.duration_since(last).as_secs_f64() * 1e3;
                        if ttft_ms.is_none() {
                            ttft_ms = Some(gap);
                        } else {
                            gaps_ms.push(gap);
                        }
                        last = now;
                        tokens += 1;
                    }
                    Some(StreamEvent::Done(o)) => {
                        return (ttft_ms, gaps_ms, tokens, matches!(o, Outcome::Response(_)))
                    }
                    None => return (ttft_ms, gaps_ms, tokens, false),
                }
            }
        }));
    }
    let (mut completed, mut total_tokens) = (0usize, 0usize);
    let mut ttfts = Vec::new();
    let mut itls = Vec::new();
    for c in consumers {
        let (ttft, gaps, tokens, ok) = c.join().unwrap();
        completed += ok as usize;
        total_tokens += tokens;
        ttfts.extend(ttft);
        itls.extend(gaps);
    }
    let elapsed_s = t0.elapsed().as_secs_f64();
    cluster.shutdown();
    ttfts.sort_by(|a, b| a.total_cmp(b));
    itls.sort_by(|a, b| a.total_cmp(b));
    Ok(StreamReport {
        completed,
        dropped: n_requests - completed,
        total_tokens,
        elapsed_s,
        requests_per_s: completed as f64 / elapsed_s,
        tokens_per_s: total_tokens as f64 / elapsed_s,
        ttft_p50_ms: quantile(&ttfts, 0.50),
        ttft_p99_ms: quantile(&ttfts, 0.99),
        itl_p50_ms: quantile(&itls, 0.50),
        itl_p99_ms: quantile(&itls, 0.99),
    })
}

/// Open-loop arrival-rate curve for the autoscale scenario.
#[derive(Clone, Copy, Debug)]
pub enum ArrivalCurve {
    /// `high_rps` for the first `burst_frac` of the run, `low_rps`
    /// afterwards — the scale-out-then-idle shape.
    Burst { high_rps: f64, low_rps: f64, burst_frac: f64 },
    /// Sinusoidal day/night curve: `cycles` full periods between
    /// `trough_rps` and `peak_rps` across the run.
    Diurnal { peak_rps: f64, trough_rps: f64, cycles: f64 },
}

impl ArrivalCurve {
    /// Instantaneous request rate at run progress `x` ∈ [0, 1].
    pub fn rate_at(&self, x: f64) -> f64 {
        match *self {
            ArrivalCurve::Burst { high_rps, low_rps, burst_frac } => {
                if x < burst_frac {
                    high_rps
                } else {
                    low_rps
                }
            }
            ArrivalCurve::Diurnal { peak_rps, trough_rps, cycles } => {
                let mid = (peak_rps + trough_rps) / 2.0;
                let amp = (peak_rps - trough_rps) / 2.0;
                mid + amp * (x * cycles * std::f64::consts::TAU).sin()
            }
        }
    }
}

/// What an [`autoscale_serve`] run did.
#[derive(Clone, Debug)]
pub struct AutoscaleReport {
    pub submitted: usize,
    pub completed: usize,
    pub rejected: usize,
    pub dropped: usize,
    /// `ScaledOut` / `ScaledIn` actions the controller logged.
    pub scaled_out: usize,
    pub scaled_in: usize,
    pub p99_ms: f64,
}

/// Open-loop autoscaling scenario: a forward-only single-stage pipeline
/// starting at one replica, requests submitted through the always-on
/// `Leader::submit` ingress at the instantaneous rate of `curve`, and
/// the cluster's [`Autoscaler`](crate::serving::Autoscaler) making real
/// scale-out/in decisions from live queue-depth signals — no hand-fed
/// depths anywhere. Returns per-outcome counts plus the controller's
/// scaling action totals.
pub fn autoscale_serve(
    curve: ArrivalCurve,
    duration: Duration,
    opts: WorldOptions,
    base_port: u16,
) -> anyhow::Result<AutoscaleReport> {
    const BATCH: usize = 4;
    const SEQ_LEN: usize = 8;
    const VOCAB: usize = 32;
    let topo = Topology::pipeline(&uniq("autoscale"), &[1], base_port);
    let cfg = ServingConfig {
        batch_timeout_ms: 2,
        admission_depth: 512,
        ..Default::default()
    };
    let cluster = InProcCluster::start_forward_only(
        topo,
        opts,
        ScalingPolicy { scale_up_depth: 8.0, max_replicas: 3, recover: true },
        &cfg,
        BATCH,
        SEQ_LEN,
        VOCAB,
    )?;
    cluster.start_autoscaler(AutoscalePolicy {
        high_depth: 8.0,
        high_samples: 2,
        low_samples: 8,
        interval: Duration::from_millis(25),
        cooldown: Duration::from_millis(500),
        min_replicas: 1,
        drain_timeout: Duration::from_secs(2),
        ..Default::default()
    });
    let mut gen = RequestGen::new(0xA5CA1E, SEQ_LEN, VOCAB, None);
    let mut rng = Rng::new(0x0DD5);
    let t0 = Instant::now();
    let mut handles = Vec::new();
    while t0.elapsed() < duration {
        let x = t0.elapsed().as_secs_f64() / duration.as_secs_f64();
        let rate = curve.rate_at(x).max(1.0);
        let (req, _) = gen.next();
        handles.push(cluster.leader.submit(req));
        std::thread::sleep(Duration::from_secs_f64(rng.exp(rate)));
    }
    let grace = Instant::now() + Duration::from_secs(60);
    let (mut completed, mut rejected, mut dropped) = (0usize, 0usize, 0usize);
    for h in &handles {
        match h.wait_deadline(grace) {
            Some(Outcome::Response(_)) => completed += 1,
            Some(Outcome::Rejected(_)) => rejected += 1,
            Some(Outcome::Dropped(_)) | None => dropped += 1,
        }
    }
    let actions = cluster.controller.actions();
    let scaled_out = actions
        .iter()
        .filter(|a| matches!(a, Action::ScaledOut { .. }))
        .count();
    let scaled_in = actions
        .iter()
        .filter(|a| matches!(a, Action::ScaledIn { .. }))
        .count();
    let p99_ms = cluster.leader.latency.quantile_us(0.99) as f64 / 1e3;
    cluster.shutdown();
    Ok(AutoscaleReport {
        submitted: handles.len(),
        completed,
        rejected,
        dropped,
        scaled_out,
        scaled_in,
        p99_ms,
    })
}

/// What a [`chaos_serve`] run did.
#[derive(Clone, Debug)]
pub struct ChaosReport {
    pub completed: usize,
    pub retries: u64,
    /// `Recovered` actions the controller logged.
    pub recovered: usize,
    /// Mean time to repair: wall-clock ms from the scripted replica
    /// kill to the controller's first `Recovered` action (0 when the
    /// run never recovered — `recovered == 0` flags that case).
    pub mttr_ms: f64,
    /// `fault.injected.<kind>` counter deltas over the run (kinds with
    /// at least one injection).
    pub injected: Vec<(String, u64)>,
}

/// Chaos-serving scenario: a forward-only single-stage pipeline with
/// two replicas serving a closed loop of requests while a scripted
/// chaos driver composes **gray network faults** with the existing
/// kill/recovery machinery — the timeline is: a one-way partition of
/// replica 0's forward edge (silent loss, no error anywhere), then a
/// hard kill of replica 1 mid-traffic (detectable death → exactly one
/// recovery), then the partition heals. Static faults ride `plan`
/// (seeded, replayable); the scripted partition is injected through the
/// runtime [`crate::mwccl::fault_registry`]. A correct run completes
/// every request: silent losses are re-dispatched on retry timeout,
/// the kill is re-minted by the controller, and the healed edge serves
/// again — `report.completed == n_requests` is the zero-loss proof.
pub fn chaos_serve(
    plan: FaultPlan,
    n_requests: usize,
    opts: WorldOptions,
    base_port: u16,
) -> anyhow::Result<ChaosReport> {
    const BATCH: usize = 4;
    const SEQ_LEN: usize = 8;
    const VOCAB: usize = 32;
    const KINDS: [&str; 6] =
        ["delay", "drop", "truncate", "stall", "partition", "bandwidth"];
    let g = crate::metrics::global();
    let before: Vec<u64> = KINDS
        .iter()
        .map(|k| g.counter(&format!("fault.injected.{k}")).get())
        .collect();
    let topo = Topology::pipeline(&uniq("chaos"), &[2], base_port);
    let cfg = ServingConfig {
        batch_timeout_ms: 2,
        retry_timeout_ms: 300,
        retry_max_attempts: 50,
        ..Default::default()
    };
    let cluster = InProcCluster::start_forward_only(
        topo,
        opts.with_fault_plan(plan),
        ScalingPolicy { recover: true, ..Default::default() },
        &cfg,
        BATCH,
        SEQ_LEN,
        VOCAB,
    )?;
    let victim = crate::serving::topology::NodeId::worker(0, 1);
    let cluster_ref = &cluster;
    let (report, mttr_ms) = std::thread::scope(|s| {
        let chaos = s.spawn(move || {
            // Phase 1 (gray): one-way partition of replica 0's forward
            // edge — the leader's sends vanish silently.
            std::thread::sleep(Duration::from_millis(50));
            let id = cluster_ref.faults().inject(FaultRule::always(
                EdgePattern::new("*-in-s0r0*", Some(0), Some(1)),
                FaultKind::Partition,
            ));
            // Phase 2 (hard): kill replica 1 mid-traffic — the clean
            // death path the gray faults must compose with.
            std::thread::sleep(Duration::from_millis(100));
            let recovered_count = || {
                cluster_ref
                    .controller
                    .actions()
                    .iter()
                    .filter(|a| matches!(a, Action::Recovered { .. }))
                    .count()
            };
            let recovered_before = recovered_count();
            let killed_at = Instant::now();
            cluster_ref.kill(victim);
            // Phase 3: the partition heals 200 ms after the kill. The
            // same loop watches for the controller's Recovered action so
            // MTTR is sampled at ~2 ms resolution without perturbing the
            // scripted heal timing.
            let deadline = killed_at + Duration::from_secs(60);
            let mut mttr_ms = 0.0f64;
            let mut healed = false;
            while Instant::now() < deadline && (mttr_ms == 0.0 || !healed) {
                if !healed && killed_at.elapsed() >= Duration::from_millis(200) {
                    cluster_ref.faults().heal(id);
                    healed = true;
                }
                if mttr_ms == 0.0 && recovered_count() > recovered_before {
                    mttr_ms = killed_at.elapsed().as_secs_f64() * 1e3;
                }
                std::thread::sleep(Duration::from_millis(2));
            }
            if !healed {
                cluster_ref.faults().heal(id);
            }
            mttr_ms
        });
        let mut gen = RequestGen::new(0xC8A05, SEQ_LEN, VOCAB, None);
        let report = cluster_ref
            .leader
            .serve(gen.take(n_requests), Some(80.0), Duration::from_secs(120));
        (report, chaos.join().unwrap())
    });
    let recovered = cluster
        .controller
        .actions()
        .iter()
        .filter(|a| matches!(a, Action::Recovered { .. }))
        .count();
    let injected = KINDS
        .iter()
        .zip(before)
        .filter_map(|(k, b)| {
            let d = g.counter(&format!("fault.injected.{k}")).get() - b;
            (d > 0).then(|| (k.to_string(), d))
        })
        .collect();
    cluster.shutdown();
    Ok(ChaosReport {
        completed: report.completed,
        retries: report.retries,
        recovered,
        mttr_ms,
        injected,
    })
}

/// What a [`recovery_mttr`] run measured.
#[derive(Clone, Debug)]
pub struct MttrReport {
    /// Kill→`Recovered` wall-time per incident, in kill order (ms).
    pub samples_ms: Vec<f64>,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
    /// Spare promotions / pool backfills over the run (0/0 when
    /// `spares == 0`).
    pub promoted: u64,
    pub backfilled: u64,
}

/// Exact quantile over a sorted sample set (0 when empty).
fn quantile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() as f64 * q).ceil() as usize)
        .saturating_sub(1)
        .min(sorted.len() - 1);
    sorted[idx]
}

/// Recovery-latency distribution scenario: a forward-only single-stage
/// pipeline with two replicas is killed `kills` times in sequence (the
/// newest replica each round, so one survivor anchors the pipeline),
/// and every incident's kill→`Recovered` wall time is sampled at ~1 ms
/// resolution. `stage_params` sizes the host→device weight load each
/// cold spawn pays (the [`crate::serving::WeightCache`] elides it for
/// promoted spares and cached respawns), so the spares>0 /
/// weight-cache-on leg isolates exactly the cost the pool exists to
/// remove. Detection latency (watchdog heartbeat × miss threshold) is
/// identical across legs; the recovery path is the variable.
pub fn recovery_mttr(
    kills: usize,
    spares: usize,
    weight_cache: bool,
    stage_params: u64,
    opts: WorldOptions,
    base_port: u16,
) -> anyhow::Result<MttrReport> {
    const BATCH: usize = 4;
    const SEQ_LEN: usize = 8;
    const VOCAB: usize = 32;
    let g = crate::metrics::global();
    let promoted0 = g.counter("serving.spares.promoted").get();
    let backfilled0 = g.counter("serving.spares.backfilled").get();
    let topo = Topology::pipeline(&uniq("mttr"), &[2], base_port);
    let mut manifest =
        crate::config::ModelManifest::synthetic(1, BATCH, SEQ_LEN, VOCAB);
    for spec in &mut manifest.stages {
        spec.params = stage_params;
    }
    let cfg = ServingConfig {
        batch_timeout_ms: 2,
        heartbeat_ms: 25,
        miss_threshold: 2,
        spares,
        weight_cache,
        ..Default::default()
    };
    let cluster = InProcCluster::start_forward_only_with_manifest(
        topo,
        manifest,
        opts,
        ScalingPolicy { recover: true, ..Default::default() },
        &cfg,
    )?;
    let recovered_count = || {
        cluster
            .controller
            .actions()
            .iter()
            .filter(|a| matches!(a, Action::Recovered { .. }))
            .count()
    };
    let mut samples_ms = Vec::with_capacity(kills);
    for _ in 0..kills {
        // Every incident starts from a warm pool (spares leg) so each
        // sample measures promotion, not a mid-backfill race.
        if spares > 0 {
            let warm_by = Instant::now() + Duration::from_secs(10);
            while cluster.spare_count() == 0 && Instant::now() < warm_by {
                std::thread::sleep(Duration::from_millis(2));
            }
        }
        let victim_replica = *cluster
            .controller
            .topology()
            .live_replicas(0)
            .last()
            .ok_or_else(|| anyhow::anyhow!("no live replica to kill"))?;
        let victim = crate::serving::topology::NodeId::worker(0, victim_replica);
        let before = recovered_count();
        let killed_at = Instant::now();
        cluster.kill(victim);
        let deadline = killed_at + Duration::from_secs(30);
        while recovered_count() == before && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(1));
        }
        anyhow::ensure!(
            recovered_count() > before,
            "kill #{} was never recovered",
            samples_ms.len()
        );
        samples_ms.push(killed_at.elapsed().as_secs_f64() * 1e3);
        // Let the fresh replica finish joining before the next incident.
        std::thread::sleep(Duration::from_millis(30));
    }
    let promoted = g.counter("serving.spares.promoted").get() - promoted0;
    let backfilled = g.counter("serving.spares.backfilled").get() - backfilled0;
    cluster.shutdown();
    let mut sorted = samples_ms.clone();
    sorted.sort_by(|a, b| a.total_cmp(b));
    Ok(MttrReport {
        p50_ms: quantile(&sorted, 0.50),
        p99_ms: quantile(&sorted, 0.99),
        max_ms: quantile(&sorted, 1.0),
        samples_ms,
        promoted,
        backfilled,
    })
}

/// What a [`multi_tenant_serve`] run measured: the steady tenant's
/// latency solo vs. under a co-resident flood, plus the burster's fate.
#[derive(Clone, Debug)]
pub struct TenantReport {
    /// Steady tenant alone on the deployment (its baseline).
    pub solo_p50_ms: f64,
    pub solo_p99_ms: f64,
    pub solo_rps: f64,
    /// Steady tenant with the burster flooding at ~10× the steady
    /// tenant's solo service rate. Isolation = these staying close to
    /// the solo numbers.
    pub steady_p50_ms: f64,
    pub steady_p99_ms: f64,
    pub steady_rps: f64,
    pub steady_completed: usize,
    pub steady_shed: usize,
    /// The burster completes at whatever share is spare and sheds the
    /// rest at its own per-tenant admission bound — never into the
    /// steady tenant's queue.
    pub burst_submitted: usize,
    pub burst_completed: usize,
    pub burst_shed: usize,
}

/// One phase of the multi-tenant scenario: a closed-loop concurrency-1
/// "steady" client (per-request latency sampled client-side,
/// submit→outcome), optionally sharing the deployment with a paced
/// open-loop "burst" flood.
struct TenantPhase {
    /// Sorted steady-request latencies (ms), completed requests only.
    latencies_ms: Vec<f64>,
    elapsed_s: f64,
    steady_completed: usize,
    steady_shed: usize,
    burst_submitted: usize,
    burst_completed: usize,
    burst_shed: usize,
}

fn tenant_phase(
    n_steady: usize,
    burst_interval: Option<Duration>,
    tenants: &[crate::config::TenantSpec],
    opts: &WorldOptions,
    base_port: u16,
) -> anyhow::Result<TenantPhase> {
    const BATCH: usize = 4;
    const SEQ_LEN: usize = 8;
    const VOCAB: usize = 32;
    let topo = Topology::pipeline(&uniq("tenant"), &[1], base_port);
    let cfg = ServingConfig {
        batch_timeout_ms: 2,
        admission_depth: 256,
        tenants: tenants.to_vec(),
        ..Default::default()
    };
    let cluster = InProcCluster::start_forward_only(
        topo,
        opts.clone(),
        ScalingPolicy { recover: false, ..Default::default() },
        &cfg,
        BATCH,
        SEQ_LEN,
        VOCAB,
    )?;
    let stop = std::sync::atomic::AtomicBool::new(false);
    let cluster_ref = &cluster;
    let stop_ref = &stop;
    let (phase, burst_handles) = std::thread::scope(|s| {
        let burster = burst_interval.map(|interval| {
            s.spawn(move || {
                // Each tick submits a spike of 4× the burster's own
                // admission bound back-to-back: the instantaneous
                // overflow sheds at the per-tenant depth no matter how
                // fast the box drains, while `interval` paces the
                // average offered rate. Ids offset far past the steady
                // generator's range so the two submitters never collide
                // in the leader's outstanding map.
                const SPIKE: usize = 64;
                let mut gen = RequestGen::new(0xB0257, SEQ_LEN, VOCAB, None);
                let mut handles = Vec::new();
                while !stop_ref.load(Ordering::Relaxed) {
                    for _ in 0..SPIKE {
                        let (mut req, _) = gen.next();
                        req.id += 1_000_000;
                        handles.push(cluster_ref.leader.submit(req.with_tenant("burst")));
                    }
                    std::thread::sleep(interval);
                }
                handles
            })
        });
        let mut gen = RequestGen::new(0x7E4A47, SEQ_LEN, VOCAB, None);
        let mut latencies_ms = Vec::with_capacity(n_steady);
        let mut steady_shed = 0usize;
        let t0 = Instant::now();
        for _ in 0..n_steady {
            let (req, _) = gen.next();
            let submitted = Instant::now();
            let h = cluster_ref.leader.submit(req.with_tenant("steady"));
            match h.wait_deadline(submitted + Duration::from_secs(30)) {
                Some(Outcome::Response(_)) => {
                    latencies_ms.push(submitted.elapsed().as_secs_f64() * 1e3);
                }
                Some(Outcome::Rejected(_)) => steady_shed += 1,
                _ => {}
            }
        }
        let elapsed_s = t0.elapsed().as_secs_f64();
        stop.store(true, Ordering::Relaxed);
        let burst_handles = burster.map(|b| b.join().unwrap()).unwrap_or_default();
        (
            TenantPhase {
                steady_completed: latencies_ms.len(),
                latencies_ms,
                elapsed_s,
                steady_shed,
                burst_submitted: burst_handles.len(),
                burst_completed: 0,
                burst_shed: 0,
            },
            burst_handles,
        )
    });
    let mut phase = phase;
    let grace = Instant::now() + Duration::from_secs(30);
    for h in &burst_handles {
        match h.wait_deadline(grace) {
            Some(Outcome::Response(_)) => phase.burst_completed += 1,
            Some(Outcome::Rejected(_)) => phase.burst_shed += 1,
            _ => {}
        }
    }
    cluster.shutdown();
    phase.latencies_ms.sort_by(|a, b| a.total_cmp(b));
    Ok(phase)
}

/// Multi-tenant isolation scenario: a forward-only single-stage
/// pipeline with two tenant classes — `steady` (weight 4) and `burst`
/// (weight 1, per-tenant depth 16) — measured in two phases on fresh
/// deployments. Phase 1 runs the steady tenant alone (closed loop,
/// concurrency 1) to establish its solo latency baseline; phase 2 runs
/// the identical steady loop while the burster floods open-loop at
/// ~10× the steady tenant's solo service rate. Weighted-fair admission
/// plus the burster's own depth bound should keep the steady tenant's
/// p99 near its solo baseline while the burster sheds — the property
/// `tools/check_tenant_isolation.py` checks from the emitted artifact.
pub fn multi_tenant_serve(
    n_steady: usize,
    opts: WorldOptions,
    base_port: u16,
) -> anyhow::Result<TenantReport> {
    use crate::config::TenantSpec;
    let tenants = vec![
        TenantSpec { weight: 4, depth: 64, ..TenantSpec::named("steady") },
        TenantSpec { weight: 1, depth: 16, ..TenantSpec::named("burst") },
    ];
    let solo = tenant_phase(n_steady, None, &tenants, &opts, base_port)?;
    anyhow::ensure!(solo.steady_completed > 0, "solo phase completed nothing");
    let solo_rps = solo.steady_completed as f64 / solo.elapsed_s.max(1e-9);
    // Pace the flood's *average* at ~10× the measured solo service rate
    // (clamped so a very fast box can't spin the submitter into
    // millions of handles, or a very slow one into no flood at all);
    // the spike shape inside `tenant_phase` guarantees instantaneous
    // overflow of the burster's own bound on every tick.
    let burst_rps = (solo_rps * 10.0).clamp(200.0, 20_000.0);
    let mixed = tenant_phase(
        n_steady,
        Some(Duration::from_secs_f64(64.0 / burst_rps)),
        &tenants,
        &opts,
        base_port + 16,
    )?;
    Ok(TenantReport {
        solo_p50_ms: quantile(&solo.latencies_ms, 0.50),
        solo_p99_ms: quantile(&solo.latencies_ms, 0.99),
        solo_rps,
        steady_p50_ms: quantile(&mixed.latencies_ms, 0.50),
        steady_p99_ms: quantile(&mixed.latencies_ms, 0.99),
        steady_rps: mixed.steady_completed as f64 / mixed.elapsed_s.max(1e-9),
        steady_completed: mixed.steady_completed,
        steady_shed: mixed.steady_shed,
        burst_submitted: mixed.burst_submitted,
        burst_completed: mixed.burst_completed,
        burst_shed: mixed.burst_shed,
    })
}

/// Run a throughput measurement `reps` times and keep the best — the
/// standard way to strip scheduler noise from a saturation benchmark on
/// a small shared box.
pub fn best_of(reps: usize, mut f: impl FnMut() -> f64) -> f64 {
    (0..reps.max(1)).map(|_| f()).fold(0.0, f64::max)
}

/// Pick a message count that keeps one measurement around a second on
/// this machine: fewer messages for big tensors.
pub fn msgs_for(elems: usize) -> usize {
    match elems {
        n if n >= 1_000_000 => 64,
        n if n >= 100_000 => 256,
        n if n >= 10_000 => 1024,
        _ => 4096,
    }
    .max(8)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sw_and_mw_move_the_same_bytes() {
        let sw = sw_fanin_throughput(1, 1_000, 64, WorldOptions::shm());
        let mw = mw_fanin_throughput(
            1,
            1_000,
            64,
            WorldOptions::shm(),
            StatePolicy::Kv,
            PollStrategy::SpinYield,
        );
        assert!(sw > 0.0 && mw > 0.0);
        // MW should be within an order of magnitude of SW even on a
        // loaded CI box (the paper's gap is 1.4–4.3%).
        assert!(mw > sw / 10.0, "mw {mw} vs sw {sw}");
    }

    #[test]
    fn multi_sender_aggregates() {
        let one = sw_fanin_throughput(1, 10_000, 32, WorldOptions::shm());
        let three = sw_fanin_throughput(3, 10_000, 32, WorldOptions::shm());
        assert!(three > 0.0 && one > 0.0);
    }

    #[test]
    fn arrival_curves_shape() {
        let b = ArrivalCurve::Burst { high_rps: 100.0, low_rps: 10.0, burst_frac: 0.3 };
        assert_eq!(b.rate_at(0.0), 100.0);
        assert_eq!(b.rate_at(0.29), 100.0);
        assert_eq!(b.rate_at(0.31), 10.0);
        let d = ArrivalCurve::Diurnal { peak_rps: 100.0, trough_rps: 20.0, cycles: 1.0 };
        assert!((d.rate_at(0.25) - 100.0).abs() < 1e-6, "peak at quarter cycle");
        assert!((d.rate_at(0.75) - 20.0).abs() < 1e-6, "trough at three quarters");
        for x in [0.0, 0.1, 0.5, 0.9, 1.0] {
            let r = d.rate_at(x);
            assert!((20.0..=100.0).contains(&r), "rate {r} in band");
        }
    }

    #[test]
    fn autoscale_scenario_accounts_for_every_request() {
        let base = 55_000 + (std::process::id() % 83) as u16 * 24;
        let report = autoscale_serve(
            ArrivalCurve::Burst { high_rps: 300.0, low_rps: 20.0, burst_frac: 0.5 },
            Duration::from_millis(1_500),
            WorldOptions::shm().with_init_timeout(Duration::from_secs(120)),
            base,
        )
        .unwrap();
        assert!(report.submitted > 0);
        assert_eq!(
            report.completed + report.rejected + report.dropped,
            report.submitted,
            "every submitted request resolves to exactly one outcome"
        );
        assert!(report.completed > 0);
    }

    #[test]
    fn chaos_serve_scenario_survives_partition_and_kill() {
        // The fault registry is process-global: hold its test lock so
        // the fault.rs unit tests can't reset our dynamic rules mid-run.
        let _serial = crate::mwccl::transport::fault::TEST_SERIAL
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let base = 52_000 + (std::process::id() % 80) as u16 * 24;
        let report = chaos_serve(
            FaultPlan::empty(7),
            24,
            WorldOptions::tcp().with_init_timeout(Duration::from_secs(120)),
            base,
        )
        .unwrap();
        assert_eq!(
            report.completed, 24,
            "zero request loss through partition + kill: {report:?}"
        );
        assert!(
            report.injected.iter().any(|(k, n)| k == "partition" && *n > 0),
            "the partition must demonstrably fire: {report:?}"
        );
        assert!(report.recovered >= 1, "the killed replica recovers: {report:?}");
        assert!(report.mttr_ms > 0.0, "MTTR is measured when recovery happens: {report:?}");
    }

    #[test]
    fn recovery_mttr_scenario_samples_every_kill() {
        let base = 50_000 + (std::process::id() % 70) as u16 * 24;
        let report = recovery_mttr(
            2,
            1,
            true,
            200_000,
            WorldOptions::shm().with_init_timeout(Duration::from_secs(120)),
            base,
        )
        .unwrap();
        assert_eq!(report.samples_ms.len(), 2, "one sample per kill: {report:?}");
        assert!(
            report.p50_ms <= report.p99_ms && report.p99_ms <= report.max_ms,
            "quantiles are ordered: {report:?}"
        );
        // The pool is re-warmed before each kill, so both recoveries
        // promote (global counters, so concurrent tests can only
        // inflate the delta, never shrink it).
        assert!(report.promoted >= 2, "spare promotion on every kill: {report:?}");
    }

    #[test]
    fn streaming_scenario_streams_every_token() {
        let base = 61_000 + (std::process::id() % 80) as u16 * 24;
        let r = streaming_serve(
            12,
            4,
            6,
            2,
            false,
            WorldOptions::shm().with_init_timeout(Duration::from_secs(120)),
            base,
        )
        .unwrap();
        assert_eq!(r.completed, 12, "every streaming request finishes: {r:?}");
        // 3 heavy × 6 tokens + 9 light × 2 — the decode loop emits each
        // request's full budget, no more, no less.
        assert_eq!(r.total_tokens, 3 * 6 + 9 * 2, "{r:?}");
        assert!(r.ttft_p50_ms > 0.0, "client-side TTFT sampled: {r:?}");
        assert!(r.tokens_per_s > 0.0);
    }

    #[test]
    fn multi_tenant_scenario_isolates_the_steady_tenant() {
        let base = 63_000 + (std::process::id() % 60) as u16 * 40;
        let r = multi_tenant_serve(
            24,
            WorldOptions::shm().with_init_timeout(Duration::from_secs(120)),
            base,
        )
        .unwrap();
        assert_eq!(r.steady_completed, 24, "steady tenant never loses a request: {r:?}");
        assert_eq!(r.steady_shed, 0, "steady tenant never sheds: {r:?}");
        assert!(
            r.burst_submitted > 0 && r.burst_shed > 0,
            "the flood overflows the burster's own bound: {r:?}"
        );
        assert!(r.burst_completed > 0, "the burster still gets its share: {r:?}");
        assert!(r.solo_p99_ms > 0.0 && r.steady_p99_ms > 0.0, "{r:?}");
        // The hard isolation tolerance lives in tests/serving_tenancy.rs
        // and the fail-soft CI check; here just pin that the numbers are
        // sane (no order-of-magnitude blowup on a loaded test box).
        assert!(
            r.steady_p99_ms < r.solo_p99_ms * 20.0 + 100.0,
            "steady p99 collapsed under the flood: {r:?}"
        );
    }

    #[test]
    fn tp_pipeline_scenario_completes() {
        // 2 stages × 1 replica × 2 shards: the smallest topology whose
        // hot path runs the TP inner loop on every batch.
        let base = 58_000 + (std::process::id() % 89) as u16 * 20;
        let report = tp_pipeline_serve(
            2,
            1,
            2,
            8,
            WorldOptions::shm().with_init_timeout(std::time::Duration::from_secs(120)),
            base,
        )
        .unwrap();
        assert_eq!(report.completed, 8);
        assert!(report.throughput_rps > 0.0);
    }
}
