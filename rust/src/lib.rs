//! # MultiWorld — elastic model serving with multi-world collective communication
//!
//! Reproduction of *"Enabling Elastic Model Serving with MultiWorld"*
//! (Lee, Jajoo, Kompella — Cisco Research, CS.DC 2024) as a three-layer
//! Rust + JAX + Pallas stack.
//!
//! Classic collective communication libraries (CCLs) build a *world*: a
//! process group with fixed membership that forms a single fault domain.
//! One worker failure breaks the whole world, and a world can never grow.
//! MultiWorld lifts both limits by letting a single worker belong to
//! **many worlds at once** — each pipeline edge becomes its own small
//! world, so failures are isolated per-edge and new workers join by
//! creating fresh worlds instead of re-initializing everything.
//!
//! ## Layer map
//!
//! * [`mwccl`] — the CCL substrate built from scratch: worlds, rendezvous,
//!   the eight collectives (`send`, `recv`, `broadcast`, `all_reduce`,
//!   `reduce`, `all_gather`, `gather`, `scatter`), shared-memory and TCP
//!   transports, and asynchronous [`mwccl::work::Work`] handles.
//! * [`store`] — a PyTorch-style `TCPStore` (blocking KV over TCP) used
//!   for rendezvous and watchdog heartbeats.
//! * [`multiworld`] — the paper's contribution: `WorldManager`,
//!   `WorldCommunicator` (fault-tolerant async collectives + busy-wait
//!   poller), `Watchdog`, and per-world state management.
//! * [`serving`] — the model-serving framework on top: stage pipeline,
//!   router, dynamic batcher, online-instantiation controller.
//! * [`baselines`] — single-world (vanilla CCL), MultiProcessing (a
//!   subprocess per world + pipe IPC) and the Kafka-like message bus.
//! * [`runtime`] — PJRT execution of AOT-compiled JAX/Pallas stages
//!   (HLO text → `xla` crate → CPU client); python is never on the
//!   request path.
//! * [`launch`] — process topology: spawn workers, kill them, recover.
//!
//! Substrates that would normally be crates ([`util::args`],
//! [`util::json`], [`util::prop`], [`bench`], [`config`], [`metrics`])
//! are implemented in-tree: the build is fully offline.

pub mod baselines;
pub mod bench;
pub mod config;
pub mod launch;
pub mod metrics;
pub mod multiworld;
pub mod mwccl;
pub mod runtime;
pub mod serving;
pub mod store;
pub mod tensor;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
