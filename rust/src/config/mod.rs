//! Configuration: the AOT model manifest (written by
//! `python/compile/aot.py`) and the serving topology spec consumed by the
//! launcher. Both are JSON parsed with [`crate::util::json`] — no serde
//! in the offline registry.

use crate::tensor::DType;
use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// Collective algorithm selection for a world's six collectives
/// (`broadcast`, `reduce`, `all_reduce`, `gather`, `all_gather`,
/// `scatter`).
///
/// * `Flat` — star through the root: optimal for the paper's 2–3 rank
///   worlds and for small messages (fewest hops).
/// * `Ring` — bandwidth-optimal pipelined ring: each rank sends
///   `O(size / world)` bytes per NIC instead of the root sending
///   `(world-1) × size`, so large tensors in large worlds scale.
/// * `Hier` — two-level hierarchical family for multi-host worlds
///   (`MW_HOSTMAP` / `WorldOptions::with_hostmap`): intra-host
///   fan-in/fan-out to a per-host leader over the cheap local path,
///   plus a leader-only inter-host exchange reusing the ring machinery,
///   so each host's NIC carries `O(size)` bytes instead of
///   `O(local_ranks × size)`. Exists for `broadcast`, `reduce`,
///   `all_reduce` and `all_gather`; forced `Hier` on the other ops (or
///   on a single-host world, where there is no hierarchy) degenerates
///   to the ring, and past [`CollAlgo::RING_MAX_WORLD`] *hosts* to flat.
/// * `Auto` — per-op choice driven by the [`CollPolicy`] threshold
///   table: hier once the world spans multiple hosts and clears the
///   thresholds, ring when big enough on one host, flat otherwise.
///   Where only the root knows the payload size, the root resolves the
///   choice and announces it in a flat-sent prologue frame (see
///   [`CollPolicy::decide`] returning [`AlgoDecision::Negotiate`]).
///
/// The choice must be identical on every rank of a world (the wire tags
/// differ between algorithms); the prologue negotiation exists exactly
/// so that size-aware choices stay rank-consistent even when non-roots
/// cannot see the size.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum CollAlgo {
    Flat,
    Ring,
    Hier,
    #[default]
    Auto,
}

/// The six collectives the per-op policy table keys on (p2p send/recv
/// have no algorithm choice).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CollOp {
    Broadcast,
    Reduce,
    AllReduce,
    Gather,
    AllGather,
    Scatter,
}

impl CollOp {
    /// All six, in table order.
    pub const ALL: [CollOp; 6] = [
        CollOp::Broadcast,
        CollOp::Reduce,
        CollOp::AllReduce,
        CollOp::Gather,
        CollOp::AllGather,
        CollOp::Scatter,
    ];

    /// Stable index into per-op tables.
    #[inline]
    pub fn index(self) -> usize {
        self as usize
    }

    /// Lowercase name, matching the bench CSV's `op` column.
    pub fn name(self) -> &'static str {
        match self {
            CollOp::Broadcast => "broadcast",
            CollOp::Reduce => "reduce",
            CollOp::AllReduce => "all_reduce",
            CollOp::Gather => "gather",
            CollOp::AllGather => "all_gather",
            CollOp::Scatter => "scatter",
        }
    }

    /// Whether a hierarchical (intra-host star + leader-ring) variant of
    /// this op exists. Gather and scatter keep flat/ring only: their
    /// payloads are per-rank-distinct, so a leader relay saves no
    /// cross-host bytes over the plain ring.
    pub fn has_hier(self) -> bool {
        !matches!(self, CollOp::Gather | CollOp::Scatter)
    }

    /// Environment-variable suffix for per-op overrides
    /// (`MW_RING_MIN_BYTES_ALL_REDUCE`, …).
    fn env_suffix(self) -> &'static str {
        match self {
            CollOp::Broadcast => "BROADCAST",
            CollOp::Reduce => "REDUCE",
            CollOp::AllReduce => "ALL_REDUCE",
            CollOp::Gather => "GATHER",
            CollOp::AllGather => "ALL_GATHER",
            CollOp::Scatter => "SCATTER",
        }
    }
}

/// Ring-eligibility thresholds for one collective under [`CollAlgo::Auto`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RingThreshold {
    /// Smallest world size where the ring is considered.
    pub min_world: usize,
    /// Smallest payload (bytes) where the ring is considered.
    pub min_bytes: usize,
}

impl Default for RingThreshold {
    fn default() -> Self {
        RingThreshold {
            min_world: CollAlgo::RING_MIN_WORLD,
            min_bytes: CollAlgo::RING_MIN_BYTES,
        }
    }
}

/// What a rank should run for one collective invocation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AlgoDecision {
    Flat,
    Ring,
    /// Two-level hierarchical algorithm: intra-host star to a per-host
    /// leader, leader-only inter-host ring (see [`CollAlgo::Hier`]).
    Hier,
    /// The size needed for an `Auto` choice is only known at the op's
    /// root: the root must resolve the algorithm from the real byte
    /// count and announce the verdict in a flat-sent prologue frame
    /// before the data moves. Only returned when a non-flat algorithm
    /// is actually selectable for *some* byte count — a row that can
    /// only ever pick flat skips the prologue round entirely.
    Negotiate,
}

/// Per-op algorithm policy: a forced/auto selector plus one
/// [`RingThreshold`] row per collective, overridable via environment:
///
/// * `MW_COLL_ALGO` — `flat` / `ring` / `auto` (the selector);
/// * `MW_RING_MIN_WORLD`, `MW_RING_MIN_BYTES` — all-ops defaults;
/// * `MW_RING_MIN_WORLD_<OP>`, `MW_RING_MIN_BYTES_<OP>` — per-op rows,
///   `<OP>` ∈ `BROADCAST`, `REDUCE`, `ALL_REDUCE`, `GATHER`,
///   `ALL_GATHER`, `SCATTER`.
///
/// Defaults mirror the crossover measured by
/// `benches/ablation_collectives.rs`; CI's `crossover-matrix` job
/// re-measures the knee on every push and warns when the defaults drift
/// from the hardware (see `tools/check_crossover.py`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CollPolicy {
    /// Forced algorithm or auto selection.
    pub algo: CollAlgo,
    thresholds: [RingThreshold; 6],
}

impl Default for CollPolicy {
    fn default() -> Self {
        CollPolicy {
            algo: CollAlgo::default(),
            thresholds: [RingThreshold::default(); 6],
        }
    }
}

impl CollPolicy {
    /// Policy with the given selector and default thresholds.
    pub fn new(algo: CollAlgo) -> Self {
        CollPolicy { algo, ..Default::default() }
    }

    /// The threshold row for one op.
    pub fn threshold(&self, op: CollOp) -> RingThreshold {
        self.thresholds[op.index()]
    }

    /// Builder-style per-op threshold override.
    pub fn with_threshold(mut self, op: CollOp, th: RingThreshold) -> Self {
        self.thresholds[op.index()] = th;
        self
    }

    /// Policy from the process environment (see type docs for the
    /// variable set).
    pub fn from_env() -> Self {
        Self::from_lookup(|k| std::env::var(k).ok())
    }

    /// Testable core of [`CollPolicy::from_env`]: `get` plays the role
    /// of `std::env::var`.
    pub fn from_lookup(get: impl Fn(&str) -> Option<String>) -> Self {
        let parse = |k: &str| get(k).and_then(|s| s.parse::<usize>().ok());
        let base = RingThreshold {
            min_world: parse("MW_RING_MIN_WORLD").unwrap_or(CollAlgo::RING_MIN_WORLD),
            min_bytes: parse("MW_RING_MIN_BYTES").unwrap_or(CollAlgo::RING_MIN_BYTES),
        };
        let mut thresholds = [base; 6];
        for op in CollOp::ALL {
            let row = &mut thresholds[op.index()];
            if let Some(w) = parse(&format!("MW_RING_MIN_WORLD_{}", op.env_suffix())) {
                row.min_world = w;
            }
            if let Some(b) = parse(&format!("MW_RING_MIN_BYTES_{}", op.env_suffix())) {
                row.min_bytes = b;
            }
        }
        let algo = get("MW_COLL_ALGO")
            .and_then(|s| CollAlgo::from_name(&s))
            .unwrap_or_default();
        CollPolicy { algo, thresholds }
    }

    /// Resolve the algorithm for one collective invocation.
    ///
    /// `n_hosts` is the number of distinct hosts the world's ranks are
    /// placed on ([`crate::mwccl::hostmap::HostMap::n_hosts`]; 1 when no
    /// host map is configured). `bytes` is the payload size when the
    /// caller's rank knows it *and* every rank is guaranteed to compute
    /// the same value (all_reduce and reduce, where the CCL contract
    /// makes all contributions identically shaped); `None` when only
    /// the op's root can know (broadcast, gather, all_gather, scatter)
    /// — in which case an `Auto` world whose row can select a non-flat
    /// algorithm returns [`AlgoDecision::Negotiate`] and the root
    /// settles it over a prologue frame. Broadcast/scatter roots
    /// resolve from the *real* byte count; gather/all_gather roots
    /// estimate it as their own contribution × N, clamped from below by
    /// the largest contribution observed on any earlier invocation of
    /// the same op on the world, so skewed per-rank sizes can mis-pick
    /// flat at most once per world (the clamp warms up on the first
    /// round).
    ///
    /// `Auto` picks `Hier` only when the world actually spans multiple
    /// hosts (and the op has a hierarchical variant); the thresholds
    /// gating ring-vs-flat gate hier identically. The ring's
    /// [`CollAlgo::RING_MAX_WORLD`] rank cap applies to the *leader*
    /// ring only under hier, so multi-host worlds stay non-flat past
    /// 128 ranks as long as the host count fits.
    pub fn decide(
        &self,
        op: CollOp,
        world_size: usize,
        n_hosts: usize,
        bytes: Option<usize>,
    ) -> AlgoDecision {
        if world_size < 2 {
            return AlgoDecision::Flat;
        }
        let ring_ok = world_size <= CollAlgo::RING_MAX_WORLD;
        let hier_ok = op.has_hier() && n_hosts > 1 && n_hosts <= CollAlgo::RING_MAX_WORLD;
        match self.algo {
            CollAlgo::Flat => AlgoDecision::Flat,
            CollAlgo::Ring => {
                if ring_ok {
                    AlgoDecision::Ring
                } else {
                    AlgoDecision::Flat
                }
            }
            CollAlgo::Hier => {
                // Forced hier degenerates gracefully: single-host worlds
                // and ops without a hierarchical variant fall back to the
                // ring (then to flat past the ring's rank cap).
                if hier_ok {
                    AlgoDecision::Hier
                } else if ring_ok {
                    AlgoDecision::Ring
                } else {
                    AlgoDecision::Flat
                }
            }
            CollAlgo::Auto => {
                let th = self.threshold(op);
                if world_size < th.min_world {
                    return AlgoDecision::Flat;
                }
                if !ring_ok && !hier_ok {
                    // No non-flat algorithm is selectable for any byte
                    // count: never negotiate (the prologue round would
                    // be pure overhead — see the regression test in
                    // tests/collectives_scale.rs).
                    return AlgoDecision::Flat;
                }
                match bytes {
                    Some(b) if b >= th.min_bytes => {
                        if hier_ok {
                            AlgoDecision::Hier
                        } else {
                            AlgoDecision::Ring
                        }
                    }
                    Some(_) => AlgoDecision::Flat,
                    None => AlgoDecision::Negotiate,
                }
            }
        }
    }

    /// Root-side resolution of [`AlgoDecision::Negotiate`]: the final
    /// verdict once the real (or root-estimated) byte count is in hand.
    /// Never returns `Negotiate`.
    pub fn resolve_bytes(
        &self,
        op: CollOp,
        world_size: usize,
        n_hosts: usize,
        bytes: usize,
    ) -> AlgoDecision {
        self.decide(op, world_size, n_hosts, Some(bytes))
    }
}

impl CollAlgo {
    /// Smallest world where `Auto` switches to ring. Below this the flat
    /// star is at most 2 sequential root transfers — not worth the ring's
    /// extra latency hops.
    pub const RING_MIN_WORLD: usize = 4;
    /// Smallest message (bytes) where `Auto` rings when the size is known
    /// on all ranks. Matches the flat→ring crossover measured by
    /// `benches/ablation_collectives.rs`.
    pub const RING_MIN_BYTES: usize = 1 << 20;
    /// Ring step indices ride in 8 tag bits (2·(size−1) steps), so rings
    /// are capped; worlds past this fall back to flat.
    pub const RING_MAX_WORLD: usize = 128;

    /// Parse a `MW_COLL_ALGO`-style name.
    pub fn from_name(s: &str) -> Option<CollAlgo> {
        match s.to_ascii_lowercase().as_str() {
            "flat" => Some(CollAlgo::Flat),
            "ring" => Some(CollAlgo::Ring),
            "hier" => Some(CollAlgo::Hier),
            "auto" => Some(CollAlgo::Auto),
            _ => None,
        }
    }

    /// Default algorithm, honoring the `MW_COLL_ALGO` env override.
    pub fn from_env() -> CollAlgo {
        std::env::var("MW_COLL_ALGO")
            .ok()
            .and_then(|s| CollAlgo::from_name(&s))
            .unwrap_or_default()
    }

}

/// One AOT-compiled pipeline stage.
#[derive(Clone, Debug, PartialEq)]
pub struct StageSpec {
    pub name: String,
    /// Path to the HLO text artifact, relative to the manifest dir.
    pub hlo: PathBuf,
    pub in_shape: Vec<usize>,
    pub out_shape: Vec<usize>,
    pub in_dtype: DType,
    pub out_dtype: DType,
    /// Parameter count (for logs/roofline estimates).
    pub params: u64,
}

/// The model manifest: an ordered list of stages plus model metadata.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelManifest {
    pub model: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub vocab: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub stages: Vec<StageSpec>,
    /// Directory the manifest was loaded from (artifact paths resolve
    /// against this).
    pub base_dir: PathBuf,
}

impl ModelManifest {
    pub fn load(path: impl AsRef<Path>) -> anyhow::Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
        let base_dir = path.parent().unwrap_or(Path::new(".")).to_path_buf();
        Self::parse(&text, base_dir)
    }

    pub fn parse(text: &str, base_dir: PathBuf) -> anyhow::Result<Self> {
        let j = Json::parse(text)?;
        let req_num = |j: &Json, k: &str| -> anyhow::Result<usize> {
            j.get(k)
                .and_then(|v| v.as_usize())
                .ok_or_else(|| anyhow::anyhow!("manifest missing numeric '{k}'"))
        };
        let req_str = |j: &Json, k: &str| -> anyhow::Result<String> {
            j.get(k)
                .and_then(|v| v.as_str())
                .map(|s| s.to_string())
                .ok_or_else(|| anyhow::anyhow!("manifest missing string '{k}'"))
        };
        let shape_of = |j: &Json, k: &str| -> anyhow::Result<Vec<usize>> {
            j.get(k)
                .and_then(|v| v.as_arr())
                .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                .ok_or_else(|| anyhow::anyhow!("stage missing shape '{k}'"))
        };
        let stages_json = j
            .get("stages")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow::anyhow!("manifest missing 'stages'"))?;
        let mut stages = Vec::with_capacity(stages_json.len());
        for s in stages_json {
            stages.push(StageSpec {
                name: req_str(s, "name")?,
                hlo: PathBuf::from(req_str(s, "hlo")?),
                in_shape: shape_of(s, "in_shape")?,
                out_shape: shape_of(s, "out_shape")?,
                in_dtype: DType::from_name(&req_str(s, "in_dtype")?)?,
                out_dtype: DType::from_name(&req_str(s, "out_dtype")?)?,
                params: s.get("params").and_then(|v| v.as_i64()).unwrap_or(0) as u64,
            });
        }
        anyhow::ensure!(!stages.is_empty(), "manifest has no stages");
        // Adjacent stages must agree on the activation shape.
        for w in stages.windows(2) {
            anyhow::ensure!(
                w[0].out_shape == w[1].in_shape && w[0].out_dtype == w[1].in_dtype,
                "stage boundary mismatch: {} out {:?} vs {} in {:?}",
                w[0].name,
                w[0].out_shape,
                w[1].name,
                w[1].in_shape
            );
        }
        Ok(ModelManifest {
            model: req_str(&j, "model")?,
            d_model: req_num(&j, "d_model")?,
            n_layers: req_num(&j, "n_layers")?,
            vocab: req_num(&j, "vocab")?,
            seq_len: req_num(&j, "seq_len")?,
            batch: req_num(&j, "batch")?,
            stages,
            base_dir,
        })
    }

    /// A synthetic manifest for forward-only deployments (no PJRT, no
    /// artifacts on disk): every stage echoes `[batch, seq_len]` i32
    /// activations through, which is exactly what forward-only workers
    /// do. Used by the artifact-less serving tests, the TP bench
    /// scenario and `examples/tensor_parallel.rs`.
    pub fn synthetic(
        n_stages: usize,
        batch: usize,
        seq_len: usize,
        vocab: usize,
    ) -> ModelManifest {
        assert!(n_stages >= 1);
        let stages = (0..n_stages)
            .map(|i| StageSpec {
                name: format!("echo_stage_{i}"),
                hlo: PathBuf::from(format!("echo_stage_{i}.hlo.txt")),
                in_shape: vec![batch, seq_len],
                out_shape: vec![batch, seq_len],
                in_dtype: DType::I32,
                out_dtype: DType::I32,
                params: 0,
            })
            .collect();
        ModelManifest {
            model: "forward-only".into(),
            d_model: 1,
            n_layers: n_stages,
            vocab,
            seq_len,
            batch,
            stages,
            base_dir: PathBuf::new(),
        }
    }

    /// Absolute path of a stage's HLO artifact.
    pub fn hlo_path(&self, stage: &StageSpec) -> PathBuf {
        if stage.hlo.is_absolute() {
            stage.hlo.clone()
        } else {
            self.base_dir.join(&stage.hlo)
        }
    }

    pub fn total_params(&self) -> u64 {
        self.stages.iter().map(|s| s.params).sum()
    }
}

/// One tenant's SLO class and admission share, parsed from the
/// `MW_TENANTS` grammar:
///
/// ```text
/// MW_TENANTS='gold:weight=4,slo_ms=50;free:weight=1,slo_ms=500'
/// ```
///
/// Entries are `;`-separated; each is `name[:key=val[,key=val]*]` with
/// keys `weight` (deficit-round-robin admission share, default 1),
/// `slo_ms` / `slo_ttft_ms` / `slo_itl_ms` (per-tenant SLO deadlines;
/// 0 inherits the global `MW_SLO_*` value) and `depth` (per-tenant
/// admission-queue bound; 0 inherits `MW_ADMISSION_DEPTH`). An empty
/// tenant table (`MW_TENANTS` unset) keeps the single-tenant runtime
/// byte-identical: one FIFO queue, global SLOs, unlabelled metrics.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TenantSpec {
    pub name: String,
    /// Weighted-fair admission share (DRR quantum). Clamped to ≥ 1.
    pub weight: u32,
    /// Per-tenant request SLO (ms); 0 = inherit the global `slo_ms`.
    pub slo_ms: u64,
    /// Per-tenant TTFT SLO (ms); 0 = inherit the global `slo_ttft_ms`.
    pub slo_ttft_ms: u64,
    /// Per-tenant inter-token SLO (ms); 0 = inherit `slo_itl_ms`.
    pub slo_itl_ms: u64,
    /// Per-tenant admission-queue bound; 0 = inherit `admission_depth`.
    /// A tenant at its bound sheds *its own* traffic — other tenants'
    /// sub-queues are unaffected.
    pub depth: usize,
}

impl TenantSpec {
    /// A tenant with the default share (weight 1) and inherited SLOs.
    pub fn named(name: &str) -> Self {
        TenantSpec {
            name: name.to_string(),
            weight: 1,
            slo_ms: 0,
            slo_ttft_ms: 0,
            slo_itl_ms: 0,
            depth: 0,
        }
    }
}

/// Parse the `MW_TENANTS` grammar (see [`TenantSpec`]). Errors on an
/// empty tenant name, a duplicate name, an unknown key, or an
/// unparsable value — `from_env` logs and ignores a bad table rather
/// than guessing at a partial one.
pub fn parse_tenants(spec: &str) -> Result<Vec<TenantSpec>, String> {
    let mut out: Vec<TenantSpec> = Vec::new();
    for entry in spec.split(';').map(str::trim).filter(|e| !e.is_empty()) {
        let (name, kvs) = match entry.split_once(':') {
            Some((n, rest)) => (n.trim(), rest),
            None => (entry, ""),
        };
        if name.is_empty() {
            return Err(format!("empty tenant name in {entry:?}"));
        }
        if out.iter().any(|t| t.name == name) {
            return Err(format!("duplicate tenant {name:?}"));
        }
        let mut t = TenantSpec::named(name);
        for kv in kvs.split(',').map(str::trim).filter(|kv| !kv.is_empty()) {
            let (k, v) = kv
                .split_once('=')
                .ok_or_else(|| format!("tenant {name:?}: expected key=val, got {kv:?}"))?;
            let parse = |v: &str| -> Result<u64, String> {
                v.trim()
                    .parse()
                    .map_err(|_| format!("tenant {name:?}: bad value {v:?} for {k:?}"))
            };
            match k.trim() {
                "weight" => t.weight = (parse(v)? as u32).max(1),
                "slo_ms" => t.slo_ms = parse(v)?,
                "slo_ttft_ms" => t.slo_ttft_ms = parse(v)?,
                "slo_itl_ms" => t.slo_itl_ms = parse(v)?,
                "depth" => t.depth = parse(v)? as usize,
                other => return Err(format!("tenant {name:?}: unknown key {other:?}")),
            }
        }
        out.push(t);
    }
    Ok(out)
}

/// Serving/runtime knobs with environment overrides, shared by examples
/// and benches.
#[derive(Clone, Debug)]
pub struct ServingConfig {
    /// Max requests fused into one batch by the dynamic batcher.
    pub max_batch: usize,
    /// How long the batcher waits to fill a batch.
    pub batch_timeout_ms: u64,
    /// Watchdog heartbeat period.
    pub heartbeat_ms: u64,
    /// Heartbeats missed before a world is declared broken (paper: ~3 s
    /// at 1 Hz ⇒ 3 misses).
    pub miss_threshold: u32,
    /// Per-replica inflight cap before the router backpressures.
    pub replica_inflight: usize,
    /// Scale-out trigger: queue depth per healthy replica.
    pub scale_up_queue_depth: usize,
    /// Scale-in trigger: utilization below this for `scale_window_ms`.
    pub scale_down_util: f64,
    /// Sliding window (ms) for the autoscaler's recent-latency signal
    /// and the scale-in idle observation.
    pub scale_window_ms: u64,
    /// Per-request SLO deadline (ms) stamped at admission; requests
    /// still queued past it are dropped before dispatch. 0 = no SLO.
    pub slo_ms: u64,
    /// Streaming SLO, first half: time-to-first-token budget (ms) for
    /// multi-token requests. A request that has not produced its first
    /// token this long after arrival is evicted from its decode slot.
    /// 0 = no TTFT SLO.
    pub slo_ttft_ms: u64,
    /// Streaming SLO, second half: inter-token gap budget (ms). A
    /// decoding request whose *next* token is this late after its
    /// previous one is evicted. 0 = no ITL SLO.
    pub slo_itl_ms: u64,
    /// Default decode budget: tokens generated per request when the
    /// request itself does not carry one. 1 (the default) keeps the
    /// legacy one-shot path — no decode loop ever starts and the wire
    /// protocol is byte-identical to the pre-streaming runtime.
    pub max_tokens: u32,
    /// Gang scheduling for the decode loop (diagnostics/baseline only):
    /// admit a fresh batch only when *every* slot has retired, i.e.
    /// run-to-completion semantics over the streaming wire. Off by
    /// default — iteration-level admission is the point.
    pub decode_gang: bool,
    /// Admission queue bound: `submit` load-sheds once this many
    /// requests are queued. 0 = unbounded (legacy behavior).
    pub admission_depth: usize,
    /// How long a dispatched batch may stay unanswered before the
    /// leader re-dispatches it (lost to a dead worker).
    pub retry_timeout_ms: u64,
    /// Dispatch attempts per batch before its requests are dropped as
    /// failed.
    pub retry_max_attempts: u32,
    /// Autoscaler sampling period (ms).
    pub autoscale_interval_ms: u64,
    /// Minimum quiet time (ms) between autoscaler actions.
    pub autoscale_cooldown_ms: u64,
    /// Pre-warmed spare workers the launcher keeps on standby
    /// (promoted into a dead worker's identity on recovery, used as
    /// scale-out headroom by the autoscaler, asynchronously
    /// backfilled). 0 = no pool; recovery cold-spawns as before.
    pub spares: usize,
    /// Host-side weight cache: spares (and respawned workers on the
    /// same host) reuse already-materialized stage weights instead of
    /// reloading them. On by default; recovery still works with it off,
    /// it just pays the full load on every spawn.
    pub weight_cache: bool,
    /// Per-tenant SLO classes and admission shares (`MW_TENANTS`).
    /// Empty (the default) keeps the single-tenant runtime — one FIFO
    /// admission queue, global SLOs, and exactly the pre-tenancy metric
    /// names.
    pub tenants: Vec<TenantSpec>,
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig {
            max_batch: 8,
            batch_timeout_ms: 5,
            heartbeat_ms: 250,
            miss_threshold: 3,
            replica_inflight: 4,
            scale_up_queue_depth: 16,
            scale_down_util: 0.2,
            scale_window_ms: 2_000,
            slo_ms: 0,
            slo_ttft_ms: 0,
            slo_itl_ms: 0,
            max_tokens: 1,
            decode_gang: false,
            admission_depth: 0,
            retry_timeout_ms: 2_000,
            retry_max_attempts: 5,
            autoscale_interval_ms: 100,
            autoscale_cooldown_ms: 2_000,
            spares: 0,
            weight_cache: true,
            tenants: Vec::new(),
        }
    }
}

impl ServingConfig {
    /// Apply `MW_*` environment overrides.
    pub fn from_env() -> Self {
        let mut c = Self::default();
        let get = |k: &str| std::env::var(k).ok();
        if let Some(v) = get("MW_MAX_BATCH").and_then(|s| s.parse().ok()) {
            c.max_batch = v;
        }
        if let Some(v) = get("MW_BATCH_TIMEOUT_MS").and_then(|s| s.parse().ok()) {
            c.batch_timeout_ms = v;
        }
        if let Some(v) = get("MW_HEARTBEAT_MS").and_then(|s| s.parse().ok()) {
            c.heartbeat_ms = v;
        }
        if let Some(v) = get("MW_MISS_THRESHOLD").and_then(|s| s.parse().ok()) {
            c.miss_threshold = v;
        }
        if let Some(v) = get("MW_SLO_MS").and_then(|s| s.parse().ok()) {
            c.slo_ms = v;
        }
        if let Some(v) = get("MW_SLO_TTFT_MS").and_then(|s| s.parse().ok()) {
            c.slo_ttft_ms = v;
        }
        if let Some(v) = get("MW_SLO_ITL_MS").and_then(|s| s.parse().ok()) {
            c.slo_itl_ms = v;
        }
        if let Some(v) = get("MW_MAX_TOKENS").and_then(|s| s.parse().ok()) {
            c.max_tokens = v;
        }
        if let Some(v) = get("MW_DECODE_GANG") {
            c.decode_gang = v != "0";
        }
        if let Some(v) = get("MW_ADMISSION_DEPTH").and_then(|s| s.parse().ok()) {
            c.admission_depth = v;
        }
        if let Some(v) = get("MW_RETRY_TIMEOUT_MS").and_then(|s| s.parse().ok()) {
            c.retry_timeout_ms = v;
        }
        if let Some(v) = get("MW_RETRY_MAX_ATTEMPTS").and_then(|s| s.parse().ok()) {
            c.retry_max_attempts = v;
        }
        if let Some(v) = get("MW_AUTOSCALE_INTERVAL_MS").and_then(|s| s.parse().ok()) {
            c.autoscale_interval_ms = v;
        }
        if let Some(v) = get("MW_AUTOSCALE_COOLDOWN_MS").and_then(|s| s.parse().ok()) {
            c.autoscale_cooldown_ms = v;
        }
        if let Some(v) = get("MW_SPARES").and_then(|s| s.parse().ok()) {
            c.spares = v;
        }
        if let Some(v) = get("MW_WEIGHT_CACHE") {
            c.weight_cache = v != "0";
        }
        if let Some(v) = get("MW_TENANTS") {
            match parse_tenants(&v) {
                Ok(t) => c.tenants = t,
                // A bad table is ignored wholesale (single-tenant
                // fallback) rather than half-applied.
                Err(e) => crate::metrics::log_event(
                    "config.tenants_invalid",
                    &[("error", e.as_str())],
                ),
            }
        }
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MANIFEST: &str = r#"{
      "model": "tiny-transformer",
      "d_model": 64, "n_layers": 4, "vocab": 256, "seq_len": 16, "batch": 8,
      "stages": [
        {"name": "stage_0", "hlo": "stage_0.hlo.txt",
         "in_shape": [8, 16], "out_shape": [8, 16, 64],
         "in_dtype": "i32", "out_dtype": "f32", "params": 16384},
        {"name": "stage_1", "hlo": "stage_1.hlo.txt",
         "in_shape": [8, 16, 64], "out_shape": [8, 16, 64],
         "in_dtype": "f32", "out_dtype": "f32", "params": 99000},
        {"name": "stage_2", "hlo": "stage_2.hlo.txt",
         "in_shape": [8, 16, 64], "out_shape": [8, 16, 256],
         "in_dtype": "f32", "out_dtype": "f32", "params": 16640}
      ]
    }"#;

    #[test]
    fn parses_manifest() {
        let m = ModelManifest::parse(MANIFEST, PathBuf::from("/tmp/a")).unwrap();
        assert_eq!(m.model, "tiny-transformer");
        assert_eq!(m.stages.len(), 3);
        assert_eq!(m.stages[0].in_dtype, DType::I32);
        assert_eq!(m.total_params(), 16384 + 99000 + 16640);
        assert_eq!(m.hlo_path(&m.stages[1]), PathBuf::from("/tmp/a/stage_1.hlo.txt"));
    }

    #[test]
    fn rejects_boundary_mismatch() {
        let bad = MANIFEST.replace("\"out_shape\": [8, 16, 64],\n         \"in_dtype\": \"i32\"", "\"out_shape\": [8, 16, 32],\n         \"in_dtype\": \"i32\"");
        assert!(bad.contains("[8, 16, 32]"), "test setup: replacement applied");
        assert!(ModelManifest::parse(&bad, PathBuf::new()).is_err());
    }

    #[test]
    fn rejects_empty_stages() {
        let bad = r#"{"model":"m","d_model":1,"n_layers":1,"vocab":1,"seq_len":1,"batch":1,"stages":[]}"#;
        assert!(ModelManifest::parse(bad, PathBuf::new()).is_err());
    }

    #[test]
    fn serving_config_defaults() {
        let c = ServingConfig::default();
        assert_eq!(c.miss_threshold, 3);
        assert!(c.max_batch >= 1);
        // New runtime knobs default to legacy behavior: no SLO, an
        // unbounded admission queue, and the historical retry policy.
        assert_eq!(c.slo_ms, 0);
        assert_eq!(c.admission_depth, 0);
        assert_eq!(c.retry_timeout_ms, 2_000);
        assert_eq!(c.retry_max_attempts, 5);
        assert!(c.autoscale_interval_ms > 0);
        // Streaming knobs default to the legacy one-shot path: a single
        // decode token, no TTFT/ITL SLOs, iteration-level (non-gang)
        // admission once the loop does run.
        assert_eq!(c.max_tokens, 1);
        assert_eq!(c.slo_ttft_ms, 0);
        assert_eq!(c.slo_itl_ms, 0);
        assert!(!c.decode_gang);
    }

    #[test]
    fn tenants_parse_full_grammar() {
        let t = parse_tenants("gold:weight=4,slo_ms=50;free:weight=1,slo_ms=500").unwrap();
        assert_eq!(t.len(), 2);
        assert_eq!(t[0].name, "gold");
        assert_eq!(t[0].weight, 4);
        assert_eq!(t[0].slo_ms, 50);
        assert_eq!(t[1].name, "free");
        assert_eq!(t[1].weight, 1);
        assert_eq!(t[1].slo_ms, 500);
        // Unset keys inherit (0 = global fallback at the consumer).
        assert_eq!(t[0].slo_ttft_ms, 0);
        assert_eq!(t[0].depth, 0);
        let t = parse_tenants(
            "a:weight=2,slo_ms=10,slo_ttft_ms=5,slo_itl_ms=3,depth=64; b ;",
        )
        .unwrap();
        assert_eq!(t[0].slo_ttft_ms, 5);
        assert_eq!(t[0].slo_itl_ms, 3);
        assert_eq!(t[0].depth, 64);
        assert_eq!(t[1], TenantSpec::named("b"), "bare name = default class");
        // Weight 0 would starve the tenant forever: clamp to 1.
        assert_eq!(parse_tenants("z:weight=0").unwrap()[0].weight, 1);
    }

    #[test]
    fn tenants_parse_rejects_malformed() {
        assert!(parse_tenants(":weight=1").is_err(), "empty name");
        assert!(parse_tenants("a;a").is_err(), "duplicate name");
        assert!(parse_tenants("a:rate=9").is_err(), "unknown key");
        assert!(parse_tenants("a:weight=fast").is_err(), "bad number");
        assert!(parse_tenants("a:weight").is_err(), "missing =val");
        assert_eq!(parse_tenants("").unwrap(), vec![], "empty spec = single-tenant");
    }

    #[test]
    fn serving_config_defaults_single_tenant() {
        // The tenant table is strictly opt-in: the default config (and
        // any config without MW_TENANTS) is the single-tenant runtime.
        assert!(ServingConfig::default().tenants.is_empty());
    }

    #[test]
    fn coll_algo_parse() {
        assert_eq!(CollAlgo::from_name("ring"), Some(CollAlgo::Ring));
        assert_eq!(CollAlgo::from_name("FLAT"), Some(CollAlgo::Flat));
        assert_eq!(CollAlgo::from_name("hier"), Some(CollAlgo::Hier));
        assert_eq!(CollAlgo::from_name("auto"), Some(CollAlgo::Auto));
        assert_eq!(CollAlgo::from_name("star"), None);
    }

    #[test]
    fn coll_policy_decides_per_op() {
        let p = CollPolicy::default();
        // Known-size ops decide locally on every rank.
        assert_eq!(p.decide(CollOp::AllReduce, 8, 1, Some(4 << 20)), AlgoDecision::Ring);
        assert_eq!(p.decide(CollOp::AllReduce, 8, 1, Some(1024)), AlgoDecision::Flat);
        assert_eq!(
            p.decide(CollOp::Reduce, 4, 1, Some(CollAlgo::RING_MIN_BYTES)),
            AlgoDecision::Ring
        );
        // Multi-host placement upgrades the big-payload pick to hier…
        assert_eq!(p.decide(CollOp::AllReduce, 8, 2, Some(4 << 20)), AlgoDecision::Hier);
        // …but never below the byte threshold, and never for ops without
        // a hierarchical variant.
        assert_eq!(p.decide(CollOp::AllReduce, 8, 2, Some(1024)), AlgoDecision::Flat);
        assert_eq!(p.decide(CollOp::Gather, 8, 2, None), AlgoDecision::Negotiate);
        // Root-only-size ops negotiate once the world is ring-eligible…
        assert_eq!(p.decide(CollOp::Broadcast, 4, 1, None), AlgoDecision::Negotiate);
        assert_eq!(p.decide(CollOp::AllGather, 8, 1, None), AlgoDecision::Negotiate);
        assert_eq!(p.decide(CollOp::Scatter, 8, 1, None), AlgoDecision::Negotiate);
        // …and stay flat below the world threshold with no prologue.
        assert_eq!(p.decide(CollOp::Broadcast, 3, 1, None), AlgoDecision::Flat);
        // Past the ring rank cap, a multi-host world still negotiates
        // (hier is selectable); a single-host one cannot pick anything
        // but flat, so it must not pay the prologue round.
        assert_eq!(p.decide(CollOp::Broadcast, 200, 4, None), AlgoDecision::Negotiate);
        assert_eq!(p.decide(CollOp::Broadcast, 200, 1, None), AlgoDecision::Flat);
        assert_eq!(p.decide(CollOp::Gather, 200, 4, None), AlgoDecision::Flat);
        // Forced selectors never negotiate.
        let ring = CollPolicy::new(CollAlgo::Ring);
        let flat = CollPolicy::new(CollAlgo::Flat);
        let hier = CollPolicy::new(CollAlgo::Hier);
        assert_eq!(ring.decide(CollOp::Gather, 8, 1, None), AlgoDecision::Ring);
        assert_eq!(flat.decide(CollOp::Gather, 8, 1, None), AlgoDecision::Flat);
        assert_eq!(hier.decide(CollOp::AllReduce, 8, 2, None), AlgoDecision::Hier);
        // Forced hier degenerates: single host → ring; no hier variant →
        // ring; past the ring cap on one host → flat.
        assert_eq!(hier.decide(CollOp::AllReduce, 8, 1, None), AlgoDecision::Ring);
        assert_eq!(hier.decide(CollOp::Scatter, 8, 4, None), AlgoDecision::Ring);
        assert_eq!(hier.decide(CollOp::AllReduce, 1000, 1, None), AlgoDecision::Flat);
        assert_eq!(hier.decide(CollOp::AllReduce, 1000, 4, None), AlgoDecision::Hier);
        // Degenerate / oversized worlds are always flat.
        assert_eq!(ring.decide(CollOp::Broadcast, 1, 1, None), AlgoDecision::Flat);
        assert_eq!(ring.decide(CollOp::Broadcast, 1000, 1, None), AlgoDecision::Flat);
        // Root-side resolution of Negotiate never itself negotiates.
        assert_eq!(
            p.resolve_bytes(CollOp::Broadcast, 4, 1, CollAlgo::RING_MIN_BYTES),
            AlgoDecision::Ring
        );
        assert_eq!(p.resolve_bytes(CollOp::Broadcast, 4, 1, 1024), AlgoDecision::Flat);
        assert_eq!(
            p.resolve_bytes(CollOp::Broadcast, 8, 2, CollAlgo::RING_MIN_BYTES),
            AlgoDecision::Hier
        );
    }

    #[test]
    fn coll_policy_env_overrides() {
        let env = |k: &str| -> Option<String> {
            match k {
                "MW_COLL_ALGO" => Some("auto".into()),
                "MW_RING_MIN_BYTES" => Some("2048".into()),
                "MW_RING_MIN_WORLD_SCATTER" => Some("16".into()),
                "MW_RING_MIN_BYTES_ALL_REDUCE" => Some("65536".into()),
                _ => None,
            }
        };
        let p = CollPolicy::from_lookup(env);
        assert_eq!(p.algo, CollAlgo::Auto);
        // Global byte override applies to every op without its own row…
        assert_eq!(p.threshold(CollOp::Broadcast).min_bytes, 2048);
        assert_eq!(p.threshold(CollOp::Broadcast).min_world, CollAlgo::RING_MIN_WORLD);
        // …and per-op rows override the global default.
        assert_eq!(p.threshold(CollOp::AllReduce).min_bytes, 65536);
        assert_eq!(p.threshold(CollOp::Scatter).min_world, 16);
        assert_eq!(p.decide(CollOp::Scatter, 8, 1, None), AlgoDecision::Flat);
        assert_eq!(p.decide(CollOp::AllReduce, 8, 1, Some(65536)), AlgoDecision::Ring);
    }

    #[test]
    fn coll_op_table_order_is_stable() {
        for (i, op) in CollOp::ALL.iter().enumerate() {
            assert_eq!(op.index(), i);
        }
        assert_eq!(CollOp::AllReduce.name(), "all_reduce");
    }

}
