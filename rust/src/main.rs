//! `multiworld` — the CLI: worker processes, the MP proxy, the
//! end-to-end serve demo and artifact verification.
//!
//! The leader/launcher side typically lives in examples and benches;
//! this binary is what they spawn.

use multiworld::launch::ControlPlane;
use multiworld::multiworld::{StatePolicy, WatchdogConfig, WorldEvent, WorldManager};
use multiworld::mwccl::WorldOptions;
use multiworld::runtime::ModelRuntime;
use multiworld::serving::stage_worker::{run_stage_worker, StageWorkerConfig};
use multiworld::serving::topology::{NodeId, Topology};
use multiworld::util::args::Command;
use multiworld::util::time::Clock;
use std::sync::atomic::AtomicBool;
use std::sync::Arc;
use std::time::Duration;

fn cli() -> Command {
    Command::new("multiworld", "elastic model serving with multi-world CCL")
        .sub(
            Command::new("worker", "run one pipeline stage worker")
                .req("topology", "topology JSON file")
                .opt("node", "node id, e.g. s1r0", None)
                .opt("spare-id", "pre-warm, then wait for an assignment", None)
                .opt("artifacts", "AOT artifacts dir", Some("artifacts"))
                .opt("cluster-port", "control-plane store port", None)
                .opt("transport", "shm|tcp", Some("shm"))
                .opt("worlds-override", "join only the worlds in this file", None)
                .opt("heartbeat-ms", "watchdog heartbeat", Some("250"))
                .opt("miss-threshold", "heartbeats missed before broken", Some("3")),
        )
        .sub(
            Command::new("mp-proxy", "MP-baseline world proxy (stdin/stdout IPC)")
                .req("world", "world name")
                .req("rank", "rank in the 2-member world")
                .req("store-port", "per-world store port")
                .opt("transport", "shm|tcp", Some("shm")),
        )
        .sub(
            Command::new("verify", "load artifacts and check numerics vs the JAX golden")
                .opt("artifacts", "AOT artifacts dir", Some("artifacts")),
        )
        .sub(Command::new("info", "print build/runtime info"))
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let matches = match cli().parse(&argv) {
        Ok(m) => m,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
    };
    let Some(sub) = matches.sub else {
        eprintln!("{}", cli().help_text());
        std::process::exit(2);
    };
    let result = match sub.command.as_str() {
        "worker" => cmd_worker(&sub),
        "mp-proxy" => cmd_mp_proxy(&sub),
        "verify" => cmd_verify(&sub),
        "info" => cmd_info(),
        other => {
            eprintln!("unknown command {other}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn world_opts(transport: &str) -> anyhow::Result<WorldOptions> {
    Ok(match transport {
        "shm" => WorldOptions::shm(),
        "tcp" => WorldOptions::tcp(),
        other => anyhow::bail!("unknown transport {other:?}"),
    })
}

/// Spare mode (`--spare-id`): the runtime is already warm; block on the
/// cluster store until the leader publishes this spare's node identity
/// (and, for replacement spawns, a fresh-worlds override file) under
/// `spare/{id}/assign`.
fn wait_for_assignment(
    m: &multiworld::util::args::Matches,
    spare_id: &str,
    topo_path: &str,
) -> anyhow::Result<(NodeId, Topology)> {
    let port = m
        .get("cluster-port")
        .ok_or_else(|| anyhow::anyhow!("--spare-id needs --cluster-port"))?;
    let addr: std::net::SocketAddr = format!("127.0.0.1:{port}").parse()?;
    let client = multiworld::store::StoreClient::connect(addr, Duration::from_secs(10))?;
    let key = format!("spare/{spare_id}/assign");
    eprintln!("[spare {spare_id}] pre-warmed, waiting for assignment");
    let payload = loop {
        match client.wait(&key, Duration::from_secs(5)) {
            Ok(v) => break v,
            // Timeouts are routine; exit only when the cluster is gone.
            Err(_) => {
                if client.ping().is_err() {
                    anyhow::bail!("cluster store went away; spare {spare_id} exiting");
                }
            }
        }
    };
    let text = String::from_utf8(payload)?;
    let mut lines = text.lines();
    let node = NodeId::parse(lines.next().unwrap_or_default())?;
    let override_path = lines.next().unwrap_or_default().trim();
    let topo = if override_path.is_empty() {
        Topology::load(std::path::Path::new(topo_path))?
    } else {
        Topology::load(std::path::Path::new(override_path))?
    };
    eprintln!("[spare {spare_id}] promoted to {node}");
    Ok((node, topo))
}

fn cmd_worker(m: &multiworld::util::args::Matches) -> anyhow::Result<()> {
    let topo_path = m.get("topology").unwrap();
    let opts = world_opts(&m.get_or("transport", "shm"))?;
    let wd = WatchdogConfig {
        heartbeat: Duration::from_millis(m.u64("heartbeat-ms").map_err(anyhow::Error::msg)?),
        miss_threshold: m.usize("miss-threshold").map_err(anyhow::Error::msg)? as u32,
    };

    // Load the runtime before we have (or wait for) an identity: for a
    // spare this *is* the pre-warm — every stage AOT-compiled and its
    // weights host-resident before any assignment arrives, so promotion
    // pays none of it.
    let runtime = ModelRuntime::load(m.get_or("artifacts", "artifacts"))?;

    let (node, topo) = match (m.get("node"), m.get("spare-id")) {
        (Some(n), None) => {
            let node = NodeId::parse(n)?;
            let topo = match m.get("worlds-override") {
                Some(p) => Topology::load(std::path::Path::new(p))?,
                None => Topology::load(std::path::Path::new(topo_path))?,
            };
            (node, topo)
        }
        (None, Some(id)) => wait_for_assignment(m, id, topo_path)?,
        _ => anyhow::bail!("worker needs exactly one of --node / --spare-id"),
    };
    let mgr = WorldManager::with_options(StatePolicy::Kv, wd, Clock::system());

    // Stage executable.
    let NodeId::Worker { stage, .. } = node else {
        anyhow::bail!("worker command needs a worker node id");
    };
    let stage_runner = runtime
        .stages
        .get(stage)
        .cloned()
        .ok_or_else(|| anyhow::anyhow!("stage {stage} not in artifacts"))?;

    // Control plane (process mode): updates + failure reporting.
    let control = if let Some(port) = m.get("cluster-port") {
        let addr: std::net::SocketAddr = format!("127.0.0.1:{port}").parse()?;
        let cp = ControlPlane::connect(addr, Duration::from_secs(10))?;
        let (tx, rx) = std::sync::mpsc::channel();
        let _listener_stop = cp.listen(node, tx);
        // Forward broken-world events to the control plane.
        let cp2 = ControlPlane::connect(addr, Duration::from_secs(10))?;
        let events = mgr.subscribe();
        std::thread::spawn(move || {
            while let Ok(evt) = events.recv() {
                if let WorldEvent::Broken { world, reason, culprit } = evt {
                    let _ = cp2.report_broken(&world, &reason, culprit);
                }
            }
        });
        Some(rx)
    } else {
        None
    };
    let stop = Arc::new(AtomicBool::new(false));

    multiworld::serving::stage_worker::init_node_worlds(&mgr, &topo, node, &opts)?;
    eprintln!("[worker {node}] worlds up: {:?}", mgr.world_names());
    let stats = run_stage_worker(
        mgr,
        StageWorkerConfig {
            node,
            topology: topo,
            stage: Some(stage_runner),
            opts,
            control,
            stop,
        },
    )?;
    eprintln!("[worker {node}] done: {stats:?}");
    Ok(())
}

fn cmd_mp_proxy(m: &multiworld::util::args::Matches) -> anyhow::Result<()> {
    multiworld::baselines::multiproc::run_proxy(
        m.get("world").unwrap(),
        m.usize("rank").map_err(anyhow::Error::msg)?,
        m.u64("store-port").map_err(anyhow::Error::msg)? as u16,
        &m.get_or("transport", "shm"),
    )
}

fn cmd_verify(m: &multiworld::util::args::Matches) -> anyhow::Result<()> {
    let dir = m.get_or("artifacts", "artifacts");
    let rt = ModelRuntime::load(&dir)?;
    rt.verify_golden(&dir)?;
    println!(
        "OK: {} ({} stages, {} params) matches the JAX golden output",
        rt.manifest.model,
        rt.manifest.stages.len(),
        rt.manifest.total_params()
    );
    Ok(())
}

fn cmd_info() -> anyhow::Result<()> {
    println!("multiworld {} — CS.DC 2024 reproduction", env!("CARGO_PKG_VERSION"));
    let engine = multiworld::runtime::Engine::cpu()?;
    println!("pjrt platform: {}", engine.platform());
    println!("shm dir: {}", multiworld::mwccl::transport::shm::shm_dir().display());
    Ok(())
}
