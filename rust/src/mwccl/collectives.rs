//! The eight collective operations (§3.3: "We support 8 collective
//! operations: send, recv, broadcast, all-reduce, reduce, all-gather,
//! gather, and scatter.").
//!
//! Every op exists in asynchronous form (`i*` prefixed, returning
//! [`Work`]) plus a blocking convenience wrapper.
//!
//! ## Algorithm selector
//!
//! The bandwidth-bound collectives (`all_reduce`, `broadcast`,
//! `all_gather`) run one of two algorithms, chosen per op by the world's
//! [`crate::config::CollAlgo`] policy (`WorldOptions::coll_algo`, env
//! `MW_COLL_ALGO`):
//!
//! * **Flat** — a star through the root: the root performs `size − 1`
//!   sequential full-size transfers. Optimal for the paper's 2–3 rank
//!   worlds and for small messages (fewest hops, no pipeline fill).
//! * **Ring** — bandwidth-optimal pipelined rings. All-reduce is a
//!   reduce-scatter followed by an all-gather over [`SEG_MAX`]-sized
//!   chunks: each rank moves `2·(N−1)/N` of the tensor through its own
//!   NIC instead of the root moving `(N−1)×` the tensor through one,
//!   and chunk `k+1` is on the wire while chunk `k` is being reduced
//!   (the receiver threads drain into unbounded inboxes, so sends never
//!   wait for the reducer). Broadcast forwards chunks hop-by-hop down
//!   the ring — a non-root forwards chunk `k` *before* folding it into
//!   its buffer, so the pipeline depth is one chunk, not one tensor.
//!   All-gather circulates each rank's serialized contribution `N−1`
//!   hops.
//! * **Auto** — ring for worlds of ≥ `CollAlgo::RING_MIN_WORLD` ranks
//!   (and, for all_reduce where every rank knows the size up front,
//!   messages ≥ `CollAlgo::RING_MIN_BYTES`); flat otherwise. The
//!   thresholds match the crossover measured by
//!   `benches/ablation_collectives.rs`.
//!
//! Both algorithms produce identical bytes for broadcast/all_gather; for
//! all_reduce they fold in different orders, so f32 rounding may differ
//! in the last ulp (exactly like NCCL's tree vs ring). The algorithm
//! choice is deterministic from (policy, world size, message size), so
//! every rank of a world picks the same one — required, because the two
//! use different wire tags (ring ops tag each (step, chunk), see
//! [`make_chunk_tag`]).
//!
//! Root-centric ops stay flat but are arrival-order: `reduce` posts all
//! peer receives up front and folds contributions as they land rather
//! than blocking peer-by-peer, so one slow peer no longer serializes the
//! fold behind it.
//!
//! Deadlock-freedom: receiver threads always drain transports into
//! unbounded inboxes, so a send never blocks on the peer's op order —
//! within one world, ops still execute in submission order on the
//! progress thread (CCL contract: all ranks issue collectives in the
//! same order).

use super::error::{CclError, CclResult};
use super::wire::{make_chunk_tag, make_tag, TagKind, SEG_MAX};
use super::work::Work;
use super::world::{ReduceOp, World, WorldCore};
use crate::tensor::serialize::encode_header;
use crate::tensor::{read_tensor, write_tensor, DType, Tensor};

/// Payload bytes per ring chunk message — one transport segment, so a
/// chunk is the unit of both pipelining and cut-through.
const RING_CHUNK: usize = SEG_MAX;

impl World {
    // ---------------------------------------------------------------- p2p

    /// Async point-to-point send. `tag` is user-chosen (48-bit).
    pub fn isend(&self, t: Tensor, dst: usize, tag: u64) -> Work {
        let desc = format!("isend dst={dst} tag={tag} world={}", self.name());
        if dst == self.rank() || dst >= self.size() {
            return Work::failed(desc, CclError::InvalidUsage(format!("bad dst {dst}")));
        }
        let wire = make_tag(TagKind::P2p, tag);
        self.submit(desc, move |core| {
            core.send_tensor(dst, wire, &t)?;
            Ok(None)
        })
    }

    /// Async point-to-point receive; the Work resolves to the tensor.
    ///
    /// Unlike collectives, `irecv`s go to the world's p2p *poller*, so
    /// receives from different peers complete in arrival order, not
    /// submission order — a leader can post receives to all its senders
    /// and harvest whichever lands first (the Fig. 4 pattern).
    pub fn irecv(&self, src: usize, tag: u64) -> Work {
        let desc = format!("irecv src={src} tag={tag} world={}", self.name());
        if src == self.rank() || src >= self.size() {
            return Work::failed(desc, CclError::InvalidUsage(format!("bad src {src}")));
        }
        if let Err(e) = self.core().check_healthy() {
            return Work::failed(desc, e);
        }
        let wire = make_tag(TagKind::P2p, tag);
        let work = Work::pending(desc);
        work.set_running();
        self.core().register_recv(src, wire, work.clone());
        work
    }

    /// Blocking send.
    pub fn send(&self, t: Tensor, dst: usize, tag: u64) -> CclResult<()> {
        self.isend(t, dst, tag).wait().map(|_| ())
    }

    /// Blocking receive.
    pub fn recv(&self, src: usize, tag: u64) -> CclResult<Tensor> {
        self.irecv(src, tag)
            .wait()?
            .ok_or_else(|| CclError::Transport("recv returned no tensor".into()))
    }

    // --------------------------------------------------------- broadcast

    /// Async broadcast: root's tensor is delivered to every rank. Root
    /// passes `Some(tensor)`, non-roots pass `None` (shape travels on
    /// the wire, so receivers need no pre-allocation). Resolves to the
    /// broadcast tensor on every rank.
    pub fn ibroadcast(&self, t: Option<Tensor>, root: usize) -> Work {
        let desc = format!("broadcast root={root} world={}", self.name());
        if root >= self.size() {
            return Work::failed(desc, CclError::InvalidUsage(format!("bad root {root}")));
        }
        let me = self.rank();
        if me == root && t.is_none() {
            return Work::failed(desc, CclError::InvalidUsage("root must supply tensor".into()));
        }
        if self.size() == 1 {
            return Work::done(desc, t);
        }
        let seq = self.core().next_seq();
        // Message size is unknown on non-roots, so Auto decides from the
        // world size alone (the choice must match on every rank).
        if self.core().coll_algo.use_ring(self.size(), None) {
            return self.submit(desc, move |core| {
                ring_broadcast(core, t, root, seq).map(Some)
            });
        }
        let wire = make_tag(TagKind::Broadcast, seq);
        self.submit(desc, move |core| broadcast_impl(core, t, root, wire).map(Some))
    }

    /// Blocking broadcast.
    pub fn broadcast(&self, t: Option<Tensor>, root: usize) -> CclResult<Tensor> {
        self.ibroadcast(t, root)
            .wait()?
            .ok_or_else(|| CclError::Transport("broadcast returned no tensor".into()))
    }

    // ------------------------------------------------------------ reduce

    /// Async reduce: every rank contributes `t`; the root's Work
    /// resolves to the reduction, other ranks' resolve to `None`.
    /// Contributions fold in arrival order.
    pub fn ireduce(&self, t: Tensor, root: usize, op: ReduceOp) -> Work {
        let desc = format!("reduce root={root} {op:?} world={}", self.name());
        if root >= self.size() {
            return Work::failed(desc, CclError::InvalidUsage(format!("bad root {root}")));
        }
        if self.size() == 1 {
            return Work::done(desc, Some(t));
        }
        let seq = self.core().next_seq();
        let wire = make_tag(TagKind::Reduce, seq);
        self.submit(desc, move |core| reduce_impl(core, t, root, op, wire))
    }

    /// Blocking reduce (returns the reduction at root, `None` elsewhere).
    pub fn reduce(&self, t: Tensor, root: usize, op: ReduceOp) -> CclResult<Option<Tensor>> {
        self.ireduce(t, root, op).wait()
    }

    // -------------------------------------------------------- all_reduce

    /// Async all-reduce. Flat = reduce to rank 0 then broadcast; ring =
    /// pipelined reduce-scatter + all-gather. Resolves to the reduced
    /// tensor on every rank.
    ///
    /// All ranks must contribute identically-shaped f32 tensors (CCL
    /// contract). Violating it is detected where possible (shape check
    /// at the flat root, chunk-length check on the ring), but under
    /// `Auto` a size mismatch can also make ranks pick different
    /// algorithms, which — like NCCL with mismatched collective calls —
    /// stalls until `op_timeout` (set one to get a clean error).
    pub fn iall_reduce(&self, t: Tensor, op: ReduceOp) -> Work {
        let desc = format!("all_reduce {op:?} world={}", self.name());
        if self.size() == 1 {
            return Work::done(desc, Some(t));
        }
        let seq = self.core().next_seq();
        // All ranks must supply identically-shaped tensors (CCL
        // contract), so byte_len is the same everywhere and Auto's
        // choice is consistent across the world.
        if self
            .core()
            .coll_algo
            .use_ring(self.size(), Some(t.byte_len()))
        {
            return self.submit(desc, move |core| {
                ring_all_reduce(core, t, op, seq).map(Some)
            });
        }
        let rtag = make_tag(TagKind::AllReduce, seq * 2);
        let btag = make_tag(TagKind::AllReduce, seq * 2 + 1);
        self.submit(desc, move |core| {
            let reduced = reduce_impl(core, t, 0, op, rtag)?;
            broadcast_impl(core, reduced, 0, btag).map(Some)
        })
    }

    /// Blocking all-reduce.
    pub fn all_reduce(&self, t: Tensor, op: ReduceOp) -> CclResult<Tensor> {
        self.iall_reduce(t, op)
            .wait()?
            .ok_or_else(|| CclError::Transport("all_reduce returned no tensor".into()))
    }

    // ------------------------------------------------------------ gather

    /// Async gather: root's Work resolves to the rank-order concatenation
    /// along axis 0; contributions must share trailing dims.
    pub fn igather(&self, t: Tensor, root: usize) -> Work {
        let desc = format!("gather root={root} world={}", self.name());
        if root >= self.size() {
            return Work::failed(desc, CclError::InvalidUsage(format!("bad root {root}")));
        }
        if self.size() == 1 {
            return Work::done(desc, Some(t));
        }
        let seq = self.core().next_seq();
        let wire = make_tag(TagKind::Gather, seq);
        self.submit(desc, move |core| gather_impl(core, t, root, wire))
    }

    /// Blocking gather.
    pub fn gather(&self, t: Tensor, root: usize) -> CclResult<Option<Tensor>> {
        self.igather(t, root).wait()
    }

    // -------------------------------------------------------- all_gather

    /// Async all-gather: every rank resolves to the rank-order
    /// concatenation. Flat = gather to rank 0 then broadcast; ring =
    /// each contribution circulates `size − 1` hops.
    pub fn iall_gather(&self, t: Tensor) -> Work {
        let desc = format!("all_gather world={}", self.name());
        if self.size() == 1 {
            return Work::done(desc, Some(t));
        }
        let seq = self.core().next_seq();
        // Contributions may differ in size per rank, so Auto decides
        // from the world size alone (the choice must match everywhere).
        if self.core().coll_algo.use_ring(self.size(), None) {
            return self.submit(desc, move |core| {
                ring_all_gather(core, t, seq).map(Some)
            });
        }
        let gtag = make_tag(TagKind::AllGather, seq * 2);
        let btag = make_tag(TagKind::AllGather, seq * 2 + 1);
        self.submit(desc, move |core| {
            let gathered = gather_impl(core, t, 0, gtag)?;
            broadcast_impl(core, gathered, 0, btag).map(Some)
        })
    }

    /// Blocking all-gather.
    pub fn all_gather(&self, t: Tensor) -> CclResult<Tensor> {
        self.iall_gather(t)
            .wait()?
            .ok_or_else(|| CclError::Transport("all_gather returned no tensor".into()))
    }

    // ----------------------------------------------------------- scatter

    /// Async scatter: root supplies one tensor per rank (in rank order);
    /// every rank's Work resolves to its part. Non-roots pass `None`.
    pub fn iscatter(&self, parts: Option<Vec<Tensor>>, root: usize) -> Work {
        let desc = format!("scatter root={root} world={}", self.name());
        if root >= self.size() {
            return Work::failed(desc, CclError::InvalidUsage(format!("bad root {root}")));
        }
        let me = self.rank();
        if me == root {
            match &parts {
                Some(p) if p.len() == self.size() => {}
                Some(p) => {
                    return Work::failed(
                        desc,
                        CclError::InvalidUsage(format!(
                            "scatter needs {} parts, got {}",
                            self.size(),
                            p.len()
                        )),
                    )
                }
                None => {
                    return Work::failed(
                        desc,
                        CclError::InvalidUsage("root must supply parts".into()),
                    )
                }
            }
        }
        if self.size() == 1 {
            return Work::done(desc, parts.map(|mut p| p.remove(0)));
        }
        let seq = self.core().next_seq();
        let wire = make_tag(TagKind::Scatter, seq);
        self.submit(desc, move |core| scatter_impl(core, parts, root, wire).map(Some))
    }

    /// Blocking scatter.
    pub fn scatter(&self, parts: Option<Vec<Tensor>>, root: usize) -> CclResult<Tensor> {
        self.iscatter(parts, root)
            .wait()?
            .ok_or_else(|| CclError::Transport("scatter returned no tensor".into()))
    }
}

// ------------------------------------------------------------- flat impls

fn broadcast_impl(
    core: &WorldCore,
    t: Option<Tensor>,
    root: usize,
    wire: u64,
) -> CclResult<Tensor> {
    if core.rank == root {
        let t = t.ok_or_else(|| CclError::InvalidUsage("root must supply tensor".into()))?;
        for peer in 0..core.size {
            if peer != root {
                core.send_tensor(peer, wire, &t)?;
            }
        }
        Ok(t)
    } else {
        core.recv_tensor(root, wire)
    }
}

/// Root-side fold is arrival-order: all peer receives are outstanding at
/// once (the receiver threads are always draining into the per-link
/// inboxes) and whichever contribution lands next is folded next, so a
/// straggler delays only itself, not every peer queued behind it.
///
/// Idle waiting parks on one pending link's inbox condvar (rotating
/// through them with a short timeout) rather than busy-polling — an
/// arrival on the parked link wakes the fold immediately; arrivals
/// elsewhere are picked up on the next rotation sweep.
fn reduce_impl(
    core: &WorldCore,
    t: Tensor,
    root: usize,
    op: ReduceOp,
    wire: u64,
) -> CclResult<Option<Tensor>> {
    if core.rank != root {
        core.send_tensor(root, wire, &t)?;
        return Ok(None);
    }
    let mut acc = t;
    if acc.dtype() != DType::F32 {
        return Err(CclError::InvalidUsage("reduce requires f32 tensors".into()));
    }
    let fold = |peer: usize, bytes: Vec<u8>, acc: &mut Tensor| -> CclResult<()> {
        let part = read_tensor(&mut bytes.as_slice()).map_err(|e| {
            CclError::Transport(format!("bad tensor frame from {peer}: {e}"))
        })?;
        core.recycle(peer, bytes);
        if part.shape() != acc.shape() || part.dtype() != acc.dtype() {
            return Err(CclError::InvalidUsage(format!(
                "reduce shape mismatch: {:?} vs {:?} from rank {peer}",
                acc.shape(),
                part.shape()
            )));
        }
        match op {
            ReduceOp::Sum | ReduceOp::Avg => acc.add_assign(&part),
            ReduceOp::Max => acc.max_assign(&part),
        }
        Ok(())
    };
    const PARK: std::time::Duration = std::time::Duration::from_millis(1);
    let mut pending: Vec<usize> = (0..core.size).filter(|&p| p != root).collect();
    let deadline = core.op_timeout.map(|d| std::time::Instant::now() + d);
    while !pending.is_empty() {
        // Sweep: fold everything that has already arrived, any order.
        let mut progressed = false;
        let mut i = 0;
        while i < pending.len() {
            let peer = pending[i];
            match core.link(peer)?.try_recv(wire)? {
                Some(bytes) => {
                    fold(peer, bytes, &mut acc)?;
                    pending.swap_remove(i);
                    progressed = true;
                }
                None => i += 1,
            }
        }
        if progressed || pending.is_empty() {
            continue;
        }
        if let Some(d) = deadline {
            if std::time::Instant::now() >= d {
                return Err(CclError::Timeout(format!(
                    "reduce: still waiting on ranks {pending:?}"
                )));
            }
        }
        // Nothing ready: park briefly on one pending link's condvar.
        let peer = pending[0];
        match core.link(peer)?.recv(wire, Some(PARK)) {
            Ok(bytes) => {
                fold(peer, bytes, &mut acc)?;
                pending.remove(0);
            }
            Err(CclError::Timeout(_)) => pending.rotate_left(1),
            Err(e) => return Err(e),
        }
    }
    if op == ReduceOp::Avg {
        acc.scale(1.0 / core.size as f32);
    }
    Ok(Some(acc))
}

fn gather_impl(
    core: &WorldCore,
    t: Tensor,
    root: usize,
    wire: u64,
) -> CclResult<Option<Tensor>> {
    if core.rank == root {
        let mut parts: Vec<Option<Tensor>> = (0..core.size).map(|_| None).collect();
        parts[root] = Some(t);
        for peer in 0..core.size {
            if peer == root {
                continue;
            }
            parts[peer] = Some(core.recv_tensor(peer, wire)?);
        }
        let parts: Vec<Tensor> = parts.into_iter().map(|p| p.unwrap()).collect();
        let cat = Tensor::concat(&parts)
            .map_err(|e| CclError::InvalidUsage(format!("gather concat: {e}")))?;
        Ok(Some(cat))
    } else {
        core.send_tensor(root, wire, &t)?;
        Ok(None)
    }
}

fn scatter_impl(
    core: &WorldCore,
    parts: Option<Vec<Tensor>>,
    root: usize,
    wire: u64,
) -> CclResult<Tensor> {
    if core.rank == root {
        let mut parts = parts.unwrap(); // validated at submit
        for peer in 0..core.size {
            if peer == root {
                continue;
            }
            core.send_tensor(peer, wire, &parts[peer])?;
        }
        // Take the root's part out of the vec — no tensor clone.
        Ok(parts.swap_remove(root))
    } else {
        core.recv_tensor(root, wire)
    }
}

// ------------------------------------------------------------- ring impls

/// Successor on the ring.
#[inline]
fn ring_next(core: &WorldCore) -> usize {
    (core.rank + 1) % core.size
}

/// Predecessor on the ring.
#[inline]
fn ring_prev(core: &WorldCore) -> usize {
    (core.rank + core.size - 1) % core.size
}

/// Number of [`RING_CHUNK`] messages covering `len` bytes (0 for 0).
#[inline]
fn chunks_of(len: usize) -> usize {
    len.div_ceil(RING_CHUNK)
}

/// Byte bounds of chunk `c` within `[off, off + len)`.
#[inline]
fn chunk_bounds(off: usize, len: usize, c: usize) -> (usize, usize) {
    let lo = off + c * RING_CHUNK;
    let hi = off + len.min((c + 1) * RING_CHUNK);
    (lo, hi)
}

/// Element-wise fold of little-endian f32 words: `dst ← dst ⊕ src`.
/// Operates on byte slices so pooled (byte-aligned) wire buffers need no
/// alignment guarantees.
fn fold_f32(dst: &mut [u8], src: &[u8], op: ReduceOp) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, s) in dst.chunks_exact_mut(4).zip(src.chunks_exact(4)) {
        let a = f32::from_le_bytes(d.try_into().unwrap());
        let b = f32::from_le_bytes(s.try_into().unwrap());
        let v = match op {
            ReduceOp::Sum | ReduceOp::Avg => a + b,
            ReduceOp::Max => a.max(b),
        };
        d.copy_from_slice(&v.to_le_bytes());
    }
}

/// Bandwidth-optimal ring all-reduce: reduce-scatter then all-gather,
/// `2·(N−1)` steps, each moving one per-rank slice as a train of
/// [`RING_CHUNK`] messages. Receives fold chunk `k` while chunk `k+1`
/// is still in flight (the link reader threads never stop draining).
///
/// After the reduce-scatter, rank `r` owns the fully-reduced slice
/// `(r+1) mod N`; the all-gather circulates the owned slices until every
/// rank has the whole tensor.
fn ring_all_reduce(core: &WorldCore, mut t: Tensor, op: ReduceOp, seq: u64) -> CclResult<Tensor> {
    if t.dtype() != DType::F32 {
        return Err(CclError::InvalidUsage("all_reduce requires f32 tensors".into()));
    }
    let n = core.size;
    let next = ring_next(core);
    let prev = ring_prev(core);
    let elems = t.elems();
    let (base, extra) = (elems / n, elems % n);
    // Slice i covers elements [start, start+len): first `extra` slices
    // get one extra element, so any size divides cleanly.
    let slice_bytes = |i: usize| -> (usize, usize) {
        let start = i * base + i.min(extra);
        let len = base + usize::from(i < extra);
        (start * 4, len * 4)
    };

    // One ring step: send the outgoing slice as a chunk train, then
    // receive the incoming slice's chunks in order — folding them when
    // `fold` is set (reduce-scatter) or overwriting (all-gather). The
    // sends never block on the peer's op order (its reader thread always
    // drains), so chunk c+1 is in flight while chunk c is applied.
    let ring_step = |t: &mut Tensor,
                     step: usize,
                     send_slice: usize,
                     recv_slice: usize,
                     fold: Option<ReduceOp>|
     -> CclResult<()> {
        let (so, sl) = slice_bytes(send_slice);
        let (ro, rl) = slice_bytes(recv_slice);
        for c in 0..chunks_of(sl) {
            let (lo, hi) = chunk_bounds(so, sl, c);
            let tag = make_chunk_tag(TagKind::AllReduce, seq, step, c);
            core.send_bytes(next, tag, &[&t.bytes()[lo..hi]])?;
        }
        for c in 0..chunks_of(rl) {
            let tag = make_chunk_tag(TagKind::AllReduce, seq, step, c);
            let buf = core.recv_bytes(prev, tag)?;
            let (lo, hi) = chunk_bounds(ro, rl, c);
            if buf.len() != hi - lo {
                return Err(CclError::InvalidUsage(format!(
                    "all_reduce chunk length mismatch from rank {prev}: {} vs {} \
                     (peers must pass identically-shaped tensors)",
                    buf.len(),
                    hi - lo
                )));
            }
            match fold {
                Some(op) => fold_f32(&mut t.bytes_mut()[lo..hi], &buf, op),
                None => t.bytes_mut()[lo..hi].copy_from_slice(&buf),
            }
            core.recycle(prev, buf);
        }
        Ok(())
    };

    // ---- phase 1: reduce-scatter (steps 0 .. N-1) ----
    for s in 0..n - 1 {
        let send_slice = (core.rank + n - s) % n;
        let recv_slice = (core.rank + n - s - 1) % n;
        ring_step(&mut t, s, send_slice, recv_slice, Some(op))?;
    }

    // Averaging divides the owned (fully-reduced) slice only — the other
    // slices are overwritten by already-averaged data in phase 2.
    if op == ReduceOp::Avg {
        let owned = (core.rank + 1) % n;
        let (oo, ol) = slice_bytes(owned);
        let inv = 1.0 / n as f32;
        for d in t.bytes_mut()[oo..oo + ol].chunks_exact_mut(4) {
            let v = f32::from_le_bytes(d.try_into().unwrap()) * inv;
            d.copy_from_slice(&v.to_le_bytes());
        }
    }

    // ---- phase 2: all-gather (steps N-1 .. 2N-3) ----
    for s in 0..n - 1 {
        let send_slice = (core.rank + 1 + n - s) % n;
        let recv_slice = (core.rank + n - s) % n;
        ring_step(&mut t, (n - 1) + s, send_slice, recv_slice, None)?;
    }
    Ok(t)
}

/// Pipelined ring broadcast: the serialized tensor travels the ring
/// root → root+1 → … → root+N−1 as [`RING_CHUNK`]-sized chunk messages.
/// Every non-terminal rank forwards chunk `k` *before* appending it
/// locally, so all hops stream concurrently and the added latency per
/// extra rank is one chunk, not one tensor. Chunk 0 is an 8-byte
/// prologue carrying the total length so receivers preallocate once and
/// know the chunk count up front.
fn ring_broadcast(
    core: &WorldCore,
    t: Option<Tensor>,
    root: usize,
    seq: u64,
) -> CclResult<Tensor> {
    let n = core.size;
    let next = ring_next(core);
    let prev = ring_prev(core);
    // Position along the chain measured from the root; the last rank
    // (pos == n-1) must not forward back into the root.
    let pos = (core.rank + n - root) % n;
    let tag = |c: usize| make_chunk_tag(TagKind::Broadcast, seq, 0, c);

    if core.rank == root {
        let t = t.ok_or_else(|| CclError::InvalidUsage("root must supply tensor".into()))?;
        let hdr = encode_header(&t)
            .map_err(|e| CclError::InvalidUsage(format!("unserializable tensor: {e}")))?;
        let total = hdr.len() + t.byte_len();
        core.send_bytes(next, tag(0), &[&(total as u64).to_le_bytes()])?;
        // Chunk the virtual stream [header | payload] without copying.
        for c in 0..chunks_of(total) {
            let (lo, hi) = chunk_bounds(0, total, c);
            let h = hdr.len();
            if hi <= h {
                core.send_bytes(next, tag(c + 1), &[&hdr[lo..hi]])?;
            } else if lo >= h {
                core.send_bytes(next, tag(c + 1), &[&t.bytes()[lo - h..hi - h]])?;
            } else {
                core.send_bytes(next, tag(c + 1), &[&hdr[lo..], &t.bytes()[..hi - h]])?;
            }
        }
        return Ok(t);
    }

    let forward = pos != n - 1;
    let meta = core.recv_bytes(prev, tag(0))?;
    if meta.len() != 8 {
        return Err(CclError::Transport(format!(
            "broadcast prologue: expected 8 bytes, got {}",
            meta.len()
        )));
    }
    let total = u64::from_le_bytes(meta.as_slice().try_into().unwrap()) as usize;
    if forward {
        core.send_bytes(next, tag(0), &[&meta])?;
    }
    core.recycle(prev, meta);
    let mut buf = Vec::with_capacity(total);
    for c in 0..chunks_of(total) {
        let chunk = core.recv_bytes(prev, tag(c + 1))?;
        if forward {
            // Forward first: downstream starts on chunk k while we are
            // still assembling it.
            core.send_bytes(next, tag(c + 1), &[&chunk])?;
        }
        buf.extend_from_slice(&chunk);
        core.recycle(prev, chunk);
    }
    if buf.len() != total {
        return Err(CclError::Transport(format!(
            "broadcast stream truncated: {} of {total} bytes",
            buf.len()
        )));
    }
    read_tensor(&mut buf.as_slice())
        .map_err(|e| CclError::Transport(format!("bad broadcast tensor: {e}")))
}

/// Ring all-gather: each rank's serialized contribution circulates
/// `N−1` hops (store-and-forward per hop, all ranks transferring
/// concurrently each step), then parts concatenate in rank order —
/// byte-identical to the flat gather+broadcast result, including
/// per-rank contributions of differing axis-0 lengths.
fn ring_all_gather(core: &WorldCore, t: Tensor, seq: u64) -> CclResult<Tensor> {
    let n = core.size;
    let next = ring_next(core);
    let prev = ring_prev(core);
    let mut parts: Vec<Option<Vec<u8>>> = (0..n).map(|_| None).collect();
    let mut mine = Vec::with_capacity(crate::tensor::HEADER_LEN + t.byte_len());
    write_tensor(&mut mine, &t)
        .map_err(|e| CclError::InvalidUsage(format!("unserializable tensor: {e}")))?;
    parts[core.rank] = Some(mine);
    for s in 0..n - 1 {
        let send_idx = (core.rank + n - s) % n;
        let recv_idx = (core.rank + n - s - 1) % n;
        let tag = make_chunk_tag(TagKind::AllGather, seq, s, 0);
        core.send_bytes(next, tag, &[parts[send_idx].as_deref().unwrap()])?;
        parts[recv_idx] = Some(core.recv_bytes(prev, tag)?);
    }
    let mut tensors = Vec::with_capacity(n);
    for (i, p) in parts.iter().enumerate() {
        let bytes = p.as_deref().unwrap();
        tensors.push(read_tensor(&mut &*bytes).map_err(|e| {
            CclError::Transport(format!("bad all_gather tensor from rank {i}: {e}"))
        })?);
    }
    let cat = Tensor::concat(&tensors)
        .map_err(|e| CclError::InvalidUsage(format!("all_gather concat: {e}")))?;
    // Everything except our own serialization came off the wire; give
    // those buffers back to the inbound link's pool.
    for (i, p) in parts.into_iter().enumerate() {
        if i == core.rank {
            continue;
        }
        if let Some(b) = p {
            core.recycle(prev, b);
        }
    }
    Ok(cat)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_arithmetic() {
        assert_eq!(chunks_of(0), 0);
        assert_eq!(chunks_of(1), 1);
        assert_eq!(chunks_of(RING_CHUNK), 1);
        assert_eq!(chunks_of(RING_CHUNK + 1), 2);
        let (lo, hi) = chunk_bounds(100, RING_CHUNK + 7, 1);
        assert_eq!(lo, 100 + RING_CHUNK);
        assert_eq!(hi, 100 + RING_CHUNK + 7);
    }

    #[test]
    fn fold_f32_ops() {
        let a: Vec<u8> = [1.0f32, -2.0, 3.5]
            .iter()
            .flat_map(|v| v.to_le_bytes())
            .collect();
        let b: Vec<u8> = [10.0f32, 5.0, -1.0]
            .iter()
            .flat_map(|v| v.to_le_bytes())
            .collect();
        let mut sum = a.clone();
        fold_f32(&mut sum, &b, ReduceOp::Sum);
        let got: Vec<f32> = sum
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        assert_eq!(got, vec![11.0, 3.0, 2.5]);
        let mut mx = a;
        fold_f32(&mut mx, &b, ReduceOp::Max);
        let got: Vec<f32> = mx
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        assert_eq!(got, vec![10.0, 5.0, 3.5]);
    }
}
