//! The eight collective operations (§3.3: "We support 8 collective
//! operations: send, recv, broadcast, all-reduce, reduce, all-gather,
//! gather, and scatter.").
//!
//! Every op exists in asynchronous form (`i*` prefixed, returning
//! [`Work`]) plus a blocking convenience wrapper.
//!
//! ## Algorithm selector
//!
//! Every collective with an algorithm choice (all six: `broadcast`,
//! `reduce`, `all_reduce`, `gather`, `all_gather`, `scatter`) runs one
//! of up to three algorithms, chosen per op by the world's
//! [`crate::config::CollPolicy`] (`WorldOptions::coll_policy`, env
//! `MW_COLL_ALGO` + `MW_RING_MIN_*` threshold table) and the world's
//! host placement ([`crate::mwccl::hostmap::HostMap`], env
//! `MW_HOSTMAP`):
//!
//! * **Flat** — a star through the root: the root performs `size − 1`
//!   sequential full-size transfers. Optimal for the paper's 2–3 rank
//!   worlds and for small messages (fewest hops, no pipeline fill).
//! * **Ring** — bandwidth-optimal pipelined rings. All-reduce is a
//!   reduce-scatter followed by an all-gather over [`SEG_MAX`]-sized
//!   chunks: each rank moves `2·(N−1)/N` of the tensor through its own
//!   NIC instead of the root moving `(N−1)×` the tensor through one,
//!   and chunk `k+1` is on the wire while chunk `k` is being reduced
//!   (the receiver threads drain into unbounded inboxes, so sends never
//!   wait for the reducer). Reduce runs the *same* reduce-scatter, then
//!   every rank ships its fully-reduced slice straight to the root, so
//!   the root's NIC ingests ~S instead of (N−1)·S. Broadcast forwards
//!   chunks hop-by-hop down the ring — a non-root forwards chunk `k`
//!   *before* folding it into its buffer, so the pipeline depth is one
//!   chunk, not one tensor. All-gather circulates each rank's
//!   serialized contribution `N−1` hops; gather circulates
//!   contributions hop-by-hop *toward* the root, and scatter streams
//!   the root's parts hop-by-hop away from it (each rank peels off its
//!   own part and forwards the rest), replacing `N−1` separate root
//!   streams with one pipelined neighbour stream per rank.
//! * **Hier** — two-level, for worlds spanning multiple hosts:
//!   `broadcast`, `reduce`, `all_reduce`, and `all_gather` first fan in
//!   over the cheap intra-host links to one *leader* rank per host
//!   (lowest rank on the host; reserved tag steps [`STEP_UP`] /
//!   [`STEP_DOWN`]), then the leaders alone run the pipelined ring
//!   between hosts (the same ring machinery, instantiated over the
//!   leader list via [`RingCtx`]), then each leader fans the result
//!   back out — so each payload crosses every host boundary once,
//!   instead of once per rank on the remote host. `gather`/`scatter`
//!   have no hier variant: their payloads are per-rank-distinct, so a
//!   leader relay saves no cross-host bytes over the plain ring
//!   ([`CollOp::has_hier`]). The leader ring is capped at
//!   `CollAlgo::RING_MAX_WORLD` *hosts*; the world itself may exceed
//!   the flat ring's rank cap (hier is how >128-rank worlds stay
//!   non-flat).
//! * **Auto** — ring once both the world and the payload clear the
//!   per-op [`crate::config::RingThreshold`] row, upgraded to hier when
//!   the world additionally spans more than one host. For ops where every
//!   rank knows the payload size up front (`all_reduce`, `reduce` — the
//!   CCL contract makes contributions identically shaped) the choice is
//!   computed locally and identically everywhere. For ops where only
//!   the root can know (`broadcast`, `gather`, `all_gather`, `scatter`)
//!   the policy returns `Negotiate`: the root resolves the algorithm
//!   from the real (or root-estimated) byte count and announces the
//!   verdict in a one-byte *prologue* frame fanned out flat on the op
//!   tag's prologue lane (see [`crate::mwccl::wire::FLAG_PROLOGUE`]),
//!   so tiny control messages keep the flat fast path instead of paying
//!   `N−1` sequential hops. Gather/all_gather roots can only *estimate*
//!   (contributions may differ per rank): the estimate is own
//!   contribution × N, clamped from below by the largest contribution
//!   observed on any earlier invocation of the same op on this world
//!   (`WorldCore::max_contrib`), so a small-contribution root stops
//!   under-picking flat under skewed per-rank sizes after the first
//!   round. Thresholds match the crossover measured by
//!   `benches/ablation_collectives.rs` (re-checked by CI's
//!   `crossover-matrix` job).
//!
//! All algorithms produce identical bytes for the data-movement ops
//! (broadcast, gather, all_gather, scatter); for all_reduce/reduce they
//! fold in different orders, so f32 rounding may differ in the last
//! ulp (exactly like NCCL's tree vs ring). The algorithm choice is
//! rank-consistent by construction — computed from inputs all ranks
//! share (size, bytes, host map), or received from the root's prologue
//! — which is required because the algorithms use different wire tags
//! (ring ops tag each (step, chunk), see [`make_chunk_tag`]; hier
//! reserves steps [`STEP_UP`]/[`STEP_DOWN`] for its intra-host phases).
//! The choice each op actually ran is observable via
//! `World::last_algo`. A `Negotiate` prologue is only ever requested
//! when the policy row could actually pick a non-flat algorithm — a
//! world that can only ever go flat (e.g. 2 ranks under `Auto`) skips
//! the prologue round entirely.
//!
//! Flat `reduce` receives in arrival order but folds in **rank order**:
//! contributions land in a rank-indexed slot table as they arrive (one
//! slow peer never serializes the receives behind it), and the fold
//! pointer advances through ranks `0, 1, …, N−1` as its next slot
//! fills. The f32 result is therefore bitwise-deterministic for a given
//! input set, however adversarially the network reorders arrivals —
//! non-commutative-in-float ops (Sum/Avg) no longer round differently
//! run to run. The price is holding up to `N−1` undelivered tensors
//! when arrivals are exactly reversed; worlds large enough to care
//! cross the ring threshold anyway.
//!
//! Deadlock-freedom: receiver threads always drain transports into
//! unbounded inboxes, so a send never blocks on the peer's op order —
//! within one world, ops still execute in submission order on the
//! progress thread (CCL contract: all ranks issue collectives in the
//! same order). The prologue negotiation obeys the same ordering: it
//! runs on the progress thread as the first phase of its op.

use super::error::{CclError, CclResult};
use super::wire::{make_chunk_tag, make_tag, TagKind, SEG_MAX};
use super::work::Work;
use super::world::{ReduceOp, World, WorldCore, ALGO_FLAT, ALGO_HIER, ALGO_RING};
use crate::config::{AlgoDecision, CollOp};
use crate::tensor::serialize::encode_header;
use crate::tensor::{read_tensor, write_tensor, DType, Tensor};

/// Payload bytes per ring chunk message — one transport segment, so a
/// chunk is the unit of both pipelining and cut-through.
const RING_CHUNK: usize = SEG_MAX;

/// Reserved chunk-tag *step* for the hierarchical intra-host fan-in
/// (member → host leader; the chunk field carries the sender's rank).
/// Leader rings use steps `0..=2·(H−1)−1 ≤ 253`, so 255/254 can never
/// collide with a ring step.
pub(crate) const STEP_UP: usize = 255;

/// Reserved chunk-tag *step* for the hierarchical intra-host fan-out
/// (host leader → member; the chunk field carries the receiver's rank).
pub(crate) const STEP_DOWN: usize = 254;

impl World {
    // ---------------------------------------------------------------- p2p

    /// Async point-to-point send. `tag` is user-chosen (48-bit).
    pub fn isend(&self, t: Tensor, dst: usize, tag: u64) -> Work {
        let desc = format!("isend dst={dst} tag={tag} world={}", self.name());
        if dst == self.rank() || dst >= self.size() {
            return Work::failed(desc, CclError::InvalidUsage(format!("bad dst {dst}")));
        }
        let wire = make_tag(TagKind::P2p, tag);
        self.submit(desc, move |core| {
            core.send_tensor(dst, wire, &t)?;
            Ok(None)
        })
    }

    /// Async point-to-point receive; the Work resolves to the tensor.
    ///
    /// Unlike collectives, `irecv`s go to the world's p2p *poller*, so
    /// receives from different peers complete in arrival order, not
    /// submission order — a leader can post receives to all its senders
    /// and harvest whichever lands first (the Fig. 4 pattern).
    pub fn irecv(&self, src: usize, tag: u64) -> Work {
        let desc = format!("irecv src={src} tag={tag} world={}", self.name());
        if src == self.rank() || src >= self.size() {
            return Work::failed(desc, CclError::InvalidUsage(format!("bad src {src}")));
        }
        if let Err(e) = self.core().check_healthy() {
            return Work::failed(desc, e);
        }
        let wire = make_tag(TagKind::P2p, tag);
        let work = Work::pending(desc);
        work.set_running();
        self.core().register_recv(src, wire, work.clone());
        work
    }

    /// Blocking send.
    pub fn send(&self, t: Tensor, dst: usize, tag: u64) -> CclResult<()> {
        self.isend(t, dst, tag).wait().map(|_| ())
    }

    /// Blocking receive.
    pub fn recv(&self, src: usize, tag: u64) -> CclResult<Tensor> {
        self.irecv(src, tag)
            .wait()?
            .ok_or_else(|| CclError::Transport("recv returned no tensor".into()))
    }

    // --------------------------------------------------------- broadcast

    /// Async broadcast: root's tensor is delivered to every rank. Root
    /// passes `Some(tensor)`, non-roots pass `None` (shape travels on
    /// the wire, so receivers need no pre-allocation). Resolves to the
    /// broadcast tensor on every rank.
    pub fn ibroadcast(&self, t: Option<Tensor>, root: usize) -> Work {
        let desc = format!("broadcast root={root} world={}", self.name());
        if root >= self.size() {
            return Work::failed(desc, CclError::InvalidUsage(format!("bad root {root}")));
        }
        let me = self.rank();
        if me == root && t.is_none() {
            return Work::failed(desc, CclError::InvalidUsage("root must supply tensor".into()));
        }
        if self.size() == 1 {
            return Work::done(desc, t);
        }
        let seq = self.core().next_seq();
        // Only the root knows the size, so under Auto the policy asks
        // for a prologue negotiation (resolved on the progress thread).
        let decision = self.core().coll_policy.decide(
            CollOp::Broadcast,
            self.size(),
            self.core().hosts.n_hosts(),
            None,
        );
        let root_bytes = t.as_ref().map(|t| t.byte_len());
        self.submit(desc, move |core| {
            let algo = resolve_algo(
                core,
                CollOp::Broadcast,
                TagKind::Broadcast,
                seq,
                root,
                decision,
                root_bytes,
            )?;
            match algo {
                ALGO_HIER => hier_broadcast(core, t, root, seq).map(Some),
                ALGO_RING => ring_broadcast(core, t, root, seq).map(Some),
                _ => broadcast_impl(core, t, root, make_tag(TagKind::Broadcast, seq)).map(Some),
            }
        })
    }

    /// Blocking broadcast.
    pub fn broadcast(&self, t: Option<Tensor>, root: usize) -> CclResult<Tensor> {
        self.ibroadcast(t, root)
            .wait()?
            .ok_or_else(|| CclError::Transport("broadcast returned no tensor".into()))
    }

    // ------------------------------------------------------------ reduce

    /// Async reduce: every rank contributes `t`; the root's Work
    /// resolves to the reduction, other ranks' resolve to `None`. Flat =
    /// star into the root — received in arrival order, folded in rank
    /// order (bitwise-deterministic; see [`reduce_impl`]); ring = the
    /// all-reduce's chunked reduce-scatter, then each rank ships its
    /// fully-reduced slice to the root (the root's NIC ingests ~S
    /// instead of (N−1)·S).
    pub fn ireduce(&self, t: Tensor, root: usize, op: ReduceOp) -> Work {
        let desc = format!("reduce root={root} {op:?} world={}", self.name());
        if root >= self.size() {
            return Work::failed(desc, CclError::InvalidUsage(format!("bad root {root}")));
        }
        if self.size() == 1 {
            return Work::done(desc, Some(t));
        }
        let seq = self.core().next_seq();
        // Contributions are identically shaped (CCL contract), so every
        // rank computes the same size-aware choice locally.
        let decision = self.core().coll_policy.decide(
            CollOp::Reduce,
            self.size(),
            self.core().hosts.n_hosts(),
            Some(t.byte_len()),
        );
        self.submit(desc, move |core| {
            let algo = resolve_algo(
                core,
                CollOp::Reduce,
                TagKind::Reduce,
                seq,
                root,
                decision,
                None,
            )?;
            match algo {
                ALGO_HIER => hier_reduce(core, t, root, op, seq),
                ALGO_RING => ring_reduce(core, t, root, op, seq),
                _ => reduce_impl(core, t, root, op, make_tag(TagKind::Reduce, seq)),
            }
        })
    }

    /// Blocking reduce (returns the reduction at root, `None` elsewhere).
    pub fn reduce(&self, t: Tensor, root: usize, op: ReduceOp) -> CclResult<Option<Tensor>> {
        self.ireduce(t, root, op).wait()
    }

    // -------------------------------------------------------- all_reduce

    /// Async all-reduce. Flat = reduce to rank 0 then broadcast; ring =
    /// pipelined reduce-scatter + all-gather. Resolves to the reduced
    /// tensor on every rank.
    ///
    /// All ranks must contribute identically-shaped f32 tensors (CCL
    /// contract). Violating it is detected where possible (shape check
    /// at the flat root, chunk-length check on the ring), but under
    /// `Auto` a size mismatch can also make ranks pick different
    /// algorithms, which — like NCCL with mismatched collective calls —
    /// stalls until `op_timeout` (set one to get a clean error).
    pub fn iall_reduce(&self, t: Tensor, op: ReduceOp) -> Work {
        let desc = format!("all_reduce {op:?} world={}", self.name());
        if self.size() == 1 {
            return Work::done(desc, Some(t));
        }
        let seq = self.core().next_seq();
        // All ranks must supply identically-shaped tensors (CCL
        // contract), so byte_len is the same everywhere and Auto's
        // choice is consistent across the world.
        let decision = self.core().coll_policy.decide(
            CollOp::AllReduce,
            self.size(),
            self.core().hosts.n_hosts(),
            Some(t.byte_len()),
        );
        self.submit(desc, move |core| {
            let algo = resolve_algo(
                core,
                CollOp::AllReduce,
                TagKind::AllReduce,
                seq,
                0,
                decision,
                None,
            )?;
            match algo {
                ALGO_HIER => return hier_all_reduce(core, t, op, seq).map(Some),
                ALGO_RING => return ring_all_reduce(core, t, op, seq).map(Some),
                _ => {}
            }
            let rtag = make_tag(TagKind::AllReduce, seq * 2);
            let btag = make_tag(TagKind::AllReduce, seq * 2 + 1);
            let reduced = reduce_impl(core, t, 0, op, rtag)?;
            broadcast_impl(core, reduced, 0, btag).map(Some)
        })
    }

    /// Blocking all-reduce.
    pub fn all_reduce(&self, t: Tensor, op: ReduceOp) -> CclResult<Tensor> {
        self.iall_reduce(t, op)
            .wait()?
            .ok_or_else(|| CclError::Transport("all_reduce returned no tensor".into()))
    }

    // ------------------------------------------------------------ gather

    /// Async gather: root's Work resolves to the rank-order concatenation
    /// along axis 0; contributions must share trailing dims. Flat =
    /// `N−1` streams into the root; ring = contributions circulate
    /// hop-by-hop toward the root.
    pub fn igather(&self, t: Tensor, root: usize) -> Work {
        let desc = format!("gather root={root} world={}", self.name());
        if root >= self.size() {
            return Work::failed(desc, CclError::InvalidUsage(format!("bad root {root}")));
        }
        if self.size() == 1 {
            return Work::done(desc, Some(t));
        }
        let seq = self.core().next_seq();
        // Contributions may differ per rank, so no rank can compute a
        // size-aware choice alone; the root estimates the gathered total
        // from its own contribution — clamped by the largest
        // contribution seen on a previous gather of this world, so a
        // small-contribution root stops under-estimating skewed loads
        // after the first invocation — and negotiates.
        let decision = self.core().coll_policy.decide(
            CollOp::Gather,
            self.size(),
            self.core().hosts.n_hosts(),
            None,
        );
        let root_bytes = Some(
            t.byte_len()
                .max(self.core().max_contrib(CollOp::Gather))
                .saturating_mul(self.size()),
        );
        self.submit(desc, move |core| {
            core.note_contrib(CollOp::Gather, t.byte_len());
            let algo = resolve_algo(
                core,
                CollOp::Gather,
                TagKind::Gather,
                seq,
                root,
                decision,
                root_bytes,
            )?;
            if algo == ALGO_RING {
                ring_gather(core, t, root, seq)
            } else {
                gather_impl(core, t, root, make_tag(TagKind::Gather, seq), CollOp::Gather)
            }
        })
    }

    /// Blocking gather.
    pub fn gather(&self, t: Tensor, root: usize) -> CclResult<Option<Tensor>> {
        self.igather(t, root).wait()
    }

    // -------------------------------------------------------- all_gather

    /// Async all-gather: every rank resolves to the rank-order
    /// concatenation. Flat = gather to rank 0 then broadcast; ring =
    /// each contribution circulates `size − 1` hops.
    pub fn iall_gather(&self, t: Tensor) -> Work {
        let desc = format!("all_gather world={}", self.name());
        if self.size() == 1 {
            return Work::done(desc, Some(t));
        }
        let seq = self.core().next_seq();
        // Contributions may differ in size per rank; rank 0 acts as the
        // negotiation root, estimating the gathered total from its own
        // contribution clamped by the largest contribution seen on a
        // previous all_gather of this world (skewed-size protection,
        // same as gather).
        let decision = self.core().coll_policy.decide(
            CollOp::AllGather,
            self.size(),
            self.core().hosts.n_hosts(),
            None,
        );
        let root_bytes = Some(
            t.byte_len()
                .max(self.core().max_contrib(CollOp::AllGather))
                .saturating_mul(self.size()),
        );
        self.submit(desc, move |core| {
            core.note_contrib(CollOp::AllGather, t.byte_len());
            let algo = resolve_algo(
                core,
                CollOp::AllGather,
                TagKind::AllGather,
                seq,
                0,
                decision,
                root_bytes,
            )?;
            match algo {
                ALGO_HIER => return hier_all_gather(core, t, seq).map(Some),
                ALGO_RING => return ring_all_gather(core, t, seq).map(Some),
                _ => {}
            }
            let gtag = make_tag(TagKind::AllGather, seq * 2);
            let btag = make_tag(TagKind::AllGather, seq * 2 + 1);
            let gathered = gather_impl(core, t, 0, gtag, CollOp::AllGather)?;
            broadcast_impl(core, gathered, 0, btag).map(Some)
        })
    }

    /// Blocking all-gather.
    pub fn all_gather(&self, t: Tensor) -> CclResult<Tensor> {
        self.iall_gather(t)
            .wait()?
            .ok_or_else(|| CclError::Transport("all_gather returned no tensor".into()))
    }

    // ----------------------------------------------------------- scatter

    /// Async scatter: root supplies one tensor per rank (in rank order);
    /// every rank's Work resolves to its part. Non-roots pass `None`.
    pub fn iscatter(&self, parts: Option<Vec<Tensor>>, root: usize) -> Work {
        let desc = format!("scatter root={root} world={}", self.name());
        if root >= self.size() {
            return Work::failed(desc, CclError::InvalidUsage(format!("bad root {root}")));
        }
        let me = self.rank();
        if me == root {
            match &parts {
                Some(p) if p.len() == self.size() => {}
                Some(p) => {
                    return Work::failed(
                        desc,
                        CclError::InvalidUsage(format!(
                            "scatter needs {} parts, got {}",
                            self.size(),
                            p.len()
                        )),
                    )
                }
                None => {
                    return Work::failed(
                        desc,
                        CclError::InvalidUsage("root must supply parts".into()),
                    )
                }
            }
        }
        if self.size() == 1 {
            return Work::done(desc, parts.map(|mut p| p.remove(0)));
        }
        let seq = self.core().next_seq();
        // Only the root holds the parts, so it resolves the size-aware
        // choice from the real total and announces it in the prologue.
        let decision = self.core().coll_policy.decide(
            CollOp::Scatter,
            self.size(),
            self.core().hosts.n_hosts(),
            None,
        );
        let root_bytes = parts
            .as_ref()
            .map(|p| p.iter().map(|t| t.byte_len()).sum::<usize>());
        self.submit(desc, move |core| {
            let algo = resolve_algo(
                core,
                CollOp::Scatter,
                TagKind::Scatter,
                seq,
                root,
                decision,
                root_bytes,
            )?;
            if algo == ALGO_RING {
                ring_scatter(core, parts, root, seq).map(Some)
            } else {
                scatter_impl(core, parts, root, make_tag(TagKind::Scatter, seq)).map(Some)
            }
        })
    }

    /// Blocking scatter.
    pub fn scatter(&self, parts: Option<Vec<Tensor>>, root: usize) -> CclResult<Tensor> {
        self.iscatter(parts, root)
            .wait()?
            .ok_or_else(|| CclError::Transport("scatter returned no tensor".into()))
    }
}

// ------------------------------------------------------- algo negotiation

/// Turn a policy decision into the concrete algorithm code
/// (`ALGO_FLAT` / `ALGO_RING` / `ALGO_HIER`) for one invocation, and
/// record it for `World::last_algo`.
///
/// `Flat`/`Ring`/`Hier` pass straight through (every rank computed the
/// same decision from shared inputs). `Negotiate` means only the root
/// can size the payload: the root resolves the algorithm from
/// `root_bytes` (its real or estimated byte count) and fans the
/// one-byte verdict out flat on the op tag's *prologue* lane —
/// `size − 1` 18-byte frames, cheap even when the verdict is "stay
/// flat" — and every other rank blocks for it (under `op_timeout`)
/// before touching the data path. `Negotiate` is only produced when a
/// non-flat pick is actually possible, so worlds that can only go flat
/// never pay the round (see `CollPolicy::decide`).
fn resolve_algo(
    core: &WorldCore,
    op: CollOp,
    kind: TagKind,
    seq: u64,
    root: usize,
    decision: AlgoDecision,
    root_bytes: Option<usize>,
) -> CclResult<u8> {
    let algo = match decision {
        AlgoDecision::Flat => ALGO_FLAT,
        AlgoDecision::Ring => ALGO_RING,
        AlgoDecision::Hier => ALGO_HIER,
        AlgoDecision::Negotiate => {
            let tag = make_tag(kind, seq);
            if core.rank == root {
                let bytes = root_bytes.ok_or_else(|| {
                    CclError::InvalidUsage("negotiated op missing root payload size".into())
                })?;
                let algo = match core.coll_policy.resolve_bytes(
                    op,
                    core.size,
                    core.hosts.n_hosts(),
                    bytes,
                ) {
                    AlgoDecision::Hier => ALGO_HIER,
                    AlgoDecision::Ring => ALGO_RING,
                    _ => ALGO_FLAT,
                };
                for peer in 0..core.size {
                    if peer != root {
                        core.send_algo_prologue(peer, tag, algo)?;
                    }
                }
                algo
            } else {
                core.recv_algo_prologue(root, tag)?
            }
        }
    };
    core.note_algo(op, algo);
    Ok(algo)
}

// ------------------------------------------------------------- flat impls

fn broadcast_impl(
    core: &WorldCore,
    t: Option<Tensor>,
    root: usize,
    wire: u64,
) -> CclResult<Tensor> {
    if core.rank == root {
        let t = t.ok_or_else(|| CclError::InvalidUsage("root must supply tensor".into()))?;
        for peer in 0..core.size {
            if peer != root {
                core.send_tensor(peer, wire, &t)?;
            }
        }
        Ok(t)
    } else {
        core.recv_tensor(root, wire)
    }
}

/// Root-side receives are arrival-order, the fold is **rank-order**:
/// all peer receives are outstanding at once (the receiver threads are
/// always draining into the per-link inboxes) and whichever
/// contribution lands next is parked in its rank's slot, so a straggler
/// delays only itself — but the accumulator only ever advances through
/// ranks `0, 1, …, N−1` as the next-in-order slot fills. Floating-point
/// reduction order is thus a function of the *inputs*, never of network
/// timing: the flat result is bitwise-reproducible run to run (the
/// regression in `tests/collectives_scale.rs` pins this under
/// adversarial, fault-injected arrival orders).
///
/// Idle waiting parks on one pending link's inbox condvar (rotating
/// through them with a short timeout) rather than busy-polling — an
/// arrival on the parked link wakes the sweep immediately; arrivals
/// elsewhere are picked up on the next rotation.
fn reduce_impl(
    core: &WorldCore,
    t: Tensor,
    root: usize,
    op: ReduceOp,
    wire: u64,
) -> CclResult<Option<Tensor>> {
    if core.rank != root {
        core.send_tensor(root, wire, &t)?;
        return Ok(None);
    }
    if t.dtype() != DType::F32 {
        return Err(CclError::InvalidUsage("reduce requires f32 tensors".into()));
    }
    let n = core.size;
    let (shape, dtype) = (t.shape().to_vec(), t.dtype());
    // Rank-indexed slot table; the root's own contribution pre-fills its
    // slot so the fold order is plain rank order, root included.
    let mut slots: Vec<Option<Tensor>> = (0..n).map(|_| None).collect();
    slots[root] = Some(t);
    let mut acc: Option<Tensor> = None;
    let mut next_fold = 0usize;
    let mut fold_ready = |slots: &mut [Option<Tensor>], acc: &mut Option<Tensor>| {
        while next_fold < n {
            let Some(part) = slots[next_fold].take() else { break };
            match acc {
                None => *acc = Some(part),
                Some(a) => match op {
                    ReduceOp::Sum | ReduceOp::Avg => a.add_assign(&part),
                    ReduceOp::Max => a.max_assign(&part),
                },
            }
            next_fold += 1;
        }
    };
    fold_ready(&mut slots, &mut acc);
    let park = |peer: usize, bytes: Vec<u8>| -> CclResult<Tensor> {
        let part = read_tensor(&mut bytes.as_slice()).map_err(|e| {
            CclError::Transport(format!("bad tensor frame from {peer}: {e}"))
        })?;
        core.recycle(peer, bytes);
        if part.shape() != shape.as_slice() || part.dtype() != dtype {
            return Err(CclError::InvalidUsage(format!(
                "reduce shape mismatch: {:?} vs {:?} from rank {peer}",
                shape,
                part.shape()
            )));
        }
        Ok(part)
    };
    const PARK: std::time::Duration = std::time::Duration::from_millis(1);
    let mut pending: Vec<usize> = (0..n).filter(|&p| p != root).collect();
    let deadline = core.op_timeout.map(|d| std::time::Instant::now() + d);
    while !pending.is_empty() {
        // Sweep: slot everything that has already arrived, any order.
        let mut progressed = false;
        let mut i = 0;
        while i < pending.len() {
            let peer = pending[i];
            match core.link(peer)?.try_recv(wire)? {
                Some(bytes) => {
                    slots[peer] = Some(park(peer, bytes)?);
                    pending.swap_remove(i);
                    progressed = true;
                }
                None => i += 1,
            }
        }
        if progressed {
            fold_ready(&mut slots, &mut acc);
            continue;
        }
        if let Some(d) = deadline {
            if std::time::Instant::now() >= d {
                return Err(CclError::Timeout(format!(
                    "reduce: still waiting on ranks {pending:?}"
                )));
            }
        }
        // Nothing ready: park briefly on one pending link's condvar.
        let peer = pending[0];
        match core.link(peer)?.recv(wire, Some(PARK)) {
            Ok(bytes) => {
                slots[peer] = Some(park(peer, bytes)?);
                pending.remove(0);
                fold_ready(&mut slots, &mut acc);
            }
            Err(CclError::Timeout(_)) => pending.rotate_left(1),
            Err(e) => return Err(e),
        }
    }
    fold_ready(&mut slots, &mut acc);
    let mut acc = acc.expect("every slot folded");
    if op == ReduceOp::Avg {
        acc.scale(1.0 / n as f32);
    }
    Ok(Some(acc))
}

/// `op` names the collective this gather serves (gather itself, or the
/// flat all_gather's gather phase) so the root can record the observed
/// per-rank contribution sizes for the next invocation's Auto estimate.
fn gather_impl(
    core: &WorldCore,
    t: Tensor,
    root: usize,
    wire: u64,
    op: CollOp,
) -> CclResult<Option<Tensor>> {
    if core.rank == root {
        let mut parts: Vec<Option<Tensor>> = (0..core.size).map(|_| None).collect();
        parts[root] = Some(t);
        for peer in 0..core.size {
            if peer == root {
                continue;
            }
            let part = core.recv_tensor(peer, wire)?;
            core.note_contrib(op, part.byte_len());
            parts[peer] = Some(part);
        }
        let parts: Vec<Tensor> = parts.into_iter().map(|p| p.unwrap()).collect();
        let cat = Tensor::concat(&parts)
            .map_err(|e| CclError::InvalidUsage(format!("gather concat: {e}")))?;
        Ok(Some(cat))
    } else {
        core.send_tensor(root, wire, &t)?;
        Ok(None)
    }
}

fn scatter_impl(
    core: &WorldCore,
    parts: Option<Vec<Tensor>>,
    root: usize,
    wire: u64,
) -> CclResult<Tensor> {
    if core.rank == root {
        let mut parts = parts.unwrap(); // validated at submit
        for peer in 0..core.size {
            if peer == root {
                continue;
            }
            core.send_tensor(peer, wire, &parts[peer])?;
        }
        // Take the root's part out of the vec — no tensor clone.
        Ok(parts.swap_remove(root))
    } else {
        core.recv_tensor(root, wire)
    }
}

// ------------------------------------------------------------- ring impls

/// A pipelined ring over an arbitrary subset of the world's ranks: the
/// whole world for the classic single-level algorithms, or the per-host
/// leader set for the hierarchical family's inter-host exchange. Slice
/// and step schedules are computed over ring *positions* (indices into
/// `members`), which coincide with ranks when the ring is the whole
/// world.
struct RingCtx<'a> {
    core: &'a WorldCore,
    /// Participating ranks; ring order is list order.
    members: &'a [usize],
    /// Our position in `members`.
    me: usize,
}

impl<'a> RingCtx<'a> {
    fn new(core: &'a WorldCore, members: &'a [usize]) -> RingCtx<'a> {
        let me = members
            .iter()
            .position(|&r| r == core.rank)
            .expect("caller must be a ring member");
        RingCtx { core, members, me }
    }

    #[inline]
    fn n(&self) -> usize {
        self.members.len()
    }

    /// Rank of our ring successor.
    #[inline]
    fn next(&self) -> usize {
        self.members[(self.me + 1) % self.n()]
    }

    /// Rank of our ring predecessor.
    #[inline]
    fn prev(&self) -> usize {
        self.members[(self.me + self.n() - 1) % self.n()]
    }
}

/// The full-world member list for the single-level ring entry points.
fn all_ranks(core: &WorldCore) -> Vec<usize> {
    (0..core.size).collect()
}

/// Successor on the full-world ring.
#[inline]
fn ring_next(core: &WorldCore) -> usize {
    (core.rank + 1) % core.size
}

/// Predecessor on the full-world ring.
#[inline]
fn ring_prev(core: &WorldCore) -> usize {
    (core.rank + core.size - 1) % core.size
}

/// Number of [`RING_CHUNK`] messages covering `len` bytes (0 for 0).
#[inline]
fn chunks_of(len: usize) -> usize {
    len.div_ceil(RING_CHUNK)
}

/// Byte bounds of chunk `c` within `[off, off + len)`.
#[inline]
fn chunk_bounds(off: usize, len: usize, c: usize) -> (usize, usize) {
    let lo = off + c * RING_CHUNK;
    let hi = off + len.min((c + 1) * RING_CHUNK);
    (lo, hi)
}

/// Element-wise fold of little-endian f32 words: `dst ← dst ⊕ src`.
/// Operates on byte slices so pooled (byte-aligned) wire buffers need no
/// alignment guarantees.
fn fold_f32(dst: &mut [u8], src: &[u8], op: ReduceOp) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, s) in dst.chunks_exact_mut(4).zip(src.chunks_exact(4)) {
        let a = f32::from_le_bytes(d.try_into().unwrap());
        let b = f32::from_le_bytes(s.try_into().unwrap());
        let v = match op {
            ReduceOp::Sum | ReduceOp::Avg => a + b,
            ReduceOp::Max => a.max(b),
        };
        d.copy_from_slice(&v.to_le_bytes());
    }
}

/// Byte bounds `(offset, len)` of per-rank slice `i` when `elems` f32
/// elements are cut into `n` contiguous slices: the first `elems % n`
/// slices get one extra element, so any size divides cleanly.
#[inline]
fn rank_slice_bytes(elems: usize, n: usize, i: usize) -> (usize, usize) {
    let (base, extra) = (elems / n, elems % n);
    let start = i * base + i.min(extra);
    let len = base + usize::from(i < extra);
    (start * 4, len * 4)
}

/// One ring step: send the outgoing byte slice to the ring successor as
/// a [`RING_CHUNK`] train, then receive the incoming slice's chunks in
/// order — folding them when `fold` is set (reduce-scatter) or
/// overwriting (all-gather). The sends never block on the peer's op
/// order (its reader thread always drains), so chunk c+1 is in flight
/// while chunk c is applied.
#[allow(clippy::too_many_arguments)]
fn ring_step(
    ctx: &RingCtx,
    t: &mut Tensor,
    kind: TagKind,
    seq: u64,
    step: usize,
    send_slice: (usize, usize),
    recv_slice: (usize, usize),
    fold: Option<ReduceOp>,
) -> CclResult<()> {
    let core = ctx.core;
    let next = ctx.next();
    let prev = ctx.prev();
    let (so, sl) = send_slice;
    let (ro, rl) = recv_slice;
    for c in 0..chunks_of(sl) {
        let (lo, hi) = chunk_bounds(so, sl, c);
        let tag = make_chunk_tag(kind, seq, step, c);
        core.send_bytes(next, tag, &[&t.bytes()[lo..hi]])?;
    }
    for c in 0..chunks_of(rl) {
        let tag = make_chunk_tag(kind, seq, step, c);
        let buf = core.recv_bytes(prev, tag)?;
        let (lo, hi) = chunk_bounds(ro, rl, c);
        if buf.len() != hi - lo {
            return Err(CclError::InvalidUsage(format!(
                "ring chunk length mismatch from rank {prev}: {} vs {} \
                 (peers must pass identically-shaped tensors)",
                buf.len(),
                hi - lo
            )));
        }
        match fold {
            Some(op) => fold_f32(&mut t.bytes_mut()[lo..hi], &buf, op),
            None => t.bytes_mut()[lo..hi].copy_from_slice(&buf),
        }
        core.recycle(prev, buf);
    }
    Ok(())
}

/// The chunked reduce-scatter phase shared by ring all-reduce and ring
/// reduce: `N−1` steps, each folding one incoming per-position slice.
/// On return, the member at ring position `p` holds the fully-reduced
/// slice `(p+1) mod N` (Avg scaling still pending — see
/// [`scale_slice`]).
fn ring_reduce_scatter(
    ctx: &RingCtx,
    t: &mut Tensor,
    op: ReduceOp,
    kind: TagKind,
    seq: u64,
) -> CclResult<()> {
    let n = ctx.n();
    let elems = t.elems();
    for s in 0..n - 1 {
        let send_slice = (ctx.me + n - s) % n;
        let recv_slice = (ctx.me + n - s - 1) % n;
        ring_step(
            ctx,
            t,
            kind,
            seq,
            s,
            rank_slice_bytes(elems, n, send_slice),
            rank_slice_bytes(elems, n, recv_slice),
            Some(op),
        )?;
    }
    Ok(())
}

/// Scale the f32 words in `t.bytes_mut()[off..off+len]` by `factor`
/// (Avg's divide-by-N, applied to the owned slice only).
fn scale_slice(t: &mut Tensor, off: usize, len: usize, factor: f32) {
    for d in t.bytes_mut()[off..off + len].chunks_exact_mut(4) {
        let v = f32::from_le_bytes(d.try_into().unwrap()) * factor;
        d.copy_from_slice(&v.to_le_bytes());
    }
}

/// Bandwidth-optimal ring all-reduce: reduce-scatter then all-gather,
/// `2·(N−1)` steps, each moving one per-rank slice as a train of
/// [`RING_CHUNK`] messages. Receives fold chunk `k` while chunk `k+1`
/// is still in flight (the link reader threads never stop draining).
///
/// After the reduce-scatter, rank `r` owns the fully-reduced slice
/// `(r+1) mod N`; the all-gather circulates the owned slices until every
/// rank has the whole tensor.
fn ring_all_reduce(core: &WorldCore, t: Tensor, op: ReduceOp, seq: u64) -> CclResult<Tensor> {
    let members = all_ranks(core);
    ring_all_reduce_ctx(&RingCtx::new(core, &members), t, op, TagKind::AllReduce, seq)
}

/// Ring all-reduce over an arbitrary member ring (see
/// [`ring_all_reduce`]; the hierarchical family runs this over the host
/// leaders). `Avg` divides by the *ring* size — hier callers pass `Sum`
/// and scale by the world size themselves.
fn ring_all_reduce_ctx(
    ctx: &RingCtx,
    mut t: Tensor,
    op: ReduceOp,
    kind: TagKind,
    seq: u64,
) -> CclResult<Tensor> {
    if t.dtype() != DType::F32 {
        return Err(CclError::InvalidUsage("all_reduce requires f32 tensors".into()));
    }
    let n = ctx.n();
    let elems = t.elems();

    // ---- phase 1: reduce-scatter (steps 0 .. N-1) ----
    ring_reduce_scatter(ctx, &mut t, op, kind, seq)?;

    // Averaging divides the owned (fully-reduced) slice only — the other
    // slices are overwritten by already-averaged data in phase 2.
    if op == ReduceOp::Avg {
        let owned = (ctx.me + 1) % n;
        let (oo, ol) = rank_slice_bytes(elems, n, owned);
        scale_slice(&mut t, oo, ol, 1.0 / n as f32);
    }

    // ---- phase 2: all-gather (steps N-1 .. 2N-3) ----
    for s in 0..n - 1 {
        let send_slice = (ctx.me + 1 + n - s) % n;
        let recv_slice = (ctx.me + n - s) % n;
        ring_step(
            ctx,
            &mut t,
            kind,
            seq,
            (n - 1) + s,
            rank_slice_bytes(elems, n, send_slice),
            rank_slice_bytes(elems, n, recv_slice),
            None,
        )?;
    }
    Ok(t)
}

/// Ring reduce: the same chunked reduce-scatter as ring all-reduce —
/// fold work and bytes spread across every NIC — then each rank ships
/// its fully-reduced slice straight to the root (step `N−1`, reusing
/// the chunk-tag scheme), so the root's NIC ingests `~S/N` from each of
/// `N−1` peers concurrently (≈ S total) instead of the flat star's
/// `(N−1)·S`.
fn ring_reduce(
    core: &WorldCore,
    t: Tensor,
    root: usize,
    op: ReduceOp,
    seq: u64,
) -> CclResult<Option<Tensor>> {
    let members = all_ranks(core);
    // Full-world ring: rank == ring position, so `root` is its index.
    ring_reduce_ctx(&RingCtx::new(core, &members), t, root, op, TagKind::Reduce, seq)
}

/// Ring reduce over an arbitrary member ring (see [`ring_reduce`]).
/// `root_idx` is the root's ring *position*. `Avg` divides by the ring
/// size — hier callers pass `Sum` and scale by the world size
/// themselves.
fn ring_reduce_ctx(
    ctx: &RingCtx,
    mut t: Tensor,
    root_idx: usize,
    op: ReduceOp,
    kind: TagKind,
    seq: u64,
) -> CclResult<Option<Tensor>> {
    if t.dtype() != DType::F32 {
        return Err(CclError::InvalidUsage("reduce requires f32 tensors".into()));
    }
    let core = ctx.core;
    let n = ctx.n();
    let elems = t.elems();
    ring_reduce_scatter(ctx, &mut t, op, kind, seq)?;
    let owned = (ctx.me + 1) % n;
    let (oo, ol) = rank_slice_bytes(elems, n, owned);
    if op == ReduceOp::Avg {
        scale_slice(&mut t, oo, ol, 1.0 / n as f32);
    }
    // Slice hand-off to the root: a step index past the reduce-scatter's
    // 0..N-2 keeps the tags disjoint; per-link inboxes keep the same tag
    // distinct across peers.
    let handoff = n - 1;
    if ctx.me != root_idx {
        let root_rank = ctx.members[root_idx];
        for c in 0..chunks_of(ol) {
            let (lo, hi) = chunk_bounds(oo, ol, c);
            let tag = make_chunk_tag(kind, seq, handoff, c);
            core.send_bytes(root_rank, tag, &[&t.bytes()[lo..hi]])?;
        }
        return Ok(None);
    }
    for pos in 0..n {
        if pos == root_idx {
            continue;
        }
        let peer = ctx.members[pos];
        let (ro, rl) = rank_slice_bytes(elems, n, (pos + 1) % n);
        for c in 0..chunks_of(rl) {
            let tag = make_chunk_tag(kind, seq, handoff, c);
            let buf = core.recv_bytes(peer, tag)?;
            let (lo, hi) = chunk_bounds(ro, rl, c);
            if buf.len() != hi - lo {
                return Err(CclError::InvalidUsage(format!(
                    "reduce slice length mismatch from rank {peer}: {} vs {} \
                     (peers must pass identically-shaped tensors)",
                    buf.len(),
                    hi - lo
                )));
            }
            t.bytes_mut()[lo..hi].copy_from_slice(&buf);
            core.recycle(peer, buf);
        }
    }
    Ok(Some(t))
}

/// Pipelined ring broadcast: the serialized tensor travels the ring
/// root → root+1 → … → root+N−1 as [`RING_CHUNK`]-sized chunk messages.
/// Every non-terminal rank forwards chunk `k` *before* appending it
/// locally, so all hops stream concurrently and the added latency per
/// extra rank is one chunk, not one tensor. Chunk 0 is an 8-byte
/// prologue carrying the total length so receivers preallocate once and
/// know the chunk count up front.
fn ring_broadcast(
    core: &WorldCore,
    t: Option<Tensor>,
    root: usize,
    seq: u64,
) -> CclResult<Tensor> {
    let members = all_ranks(core);
    // Full-world ring: rank == ring position, so `root` is its index.
    ring_broadcast_ctx(&RingCtx::new(core, &members), t, root, TagKind::Broadcast, seq)
}

/// Ring broadcast over an arbitrary member ring (see [`ring_broadcast`];
/// the hierarchical family runs this over the host leaders). `root_idx`
/// is the sending member's ring *position*.
fn ring_broadcast_ctx(
    ctx: &RingCtx,
    t: Option<Tensor>,
    root_idx: usize,
    kind: TagKind,
    seq: u64,
) -> CclResult<Tensor> {
    let core = ctx.core;
    let n = ctx.n();
    let next = ctx.next();
    let prev = ctx.prev();
    // Position along the chain measured from the root; the last member
    // (pos == n-1) must not forward back into the root.
    let pos = (ctx.me + n - root_idx) % n;
    let tag = |c: usize| make_chunk_tag(kind, seq, 0, c);

    if ctx.me == root_idx {
        let t = t.ok_or_else(|| CclError::InvalidUsage("root must supply tensor".into()))?;
        let hdr = encode_header(&t)
            .map_err(|e| CclError::InvalidUsage(format!("unserializable tensor: {e}")))?;
        let total = hdr.len() + t.byte_len();
        core.send_bytes(next, tag(0), &[&(total as u64).to_le_bytes()])?;
        // Chunk the virtual stream [header | payload] without copying.
        for c in 0..chunks_of(total) {
            let (lo, hi) = chunk_bounds(0, total, c);
            let h = hdr.len();
            if hi <= h {
                core.send_bytes(next, tag(c + 1), &[&hdr[lo..hi]])?;
            } else if lo >= h {
                core.send_bytes(next, tag(c + 1), &[&t.bytes()[lo - h..hi - h]])?;
            } else {
                core.send_bytes(next, tag(c + 1), &[&hdr[lo..], &t.bytes()[..hi - h]])?;
            }
        }
        return Ok(t);
    }

    let forward = pos != n - 1;
    let meta = core.recv_bytes(prev, tag(0))?;
    if meta.len() != 8 {
        return Err(CclError::Transport(format!(
            "broadcast prologue: expected 8 bytes, got {}",
            meta.len()
        )));
    }
    let total = u64::from_le_bytes(meta.as_slice().try_into().unwrap()) as usize;
    if forward {
        core.send_bytes(next, tag(0), &[&meta])?;
    }
    core.recycle(prev, meta);
    let mut buf = Vec::with_capacity(total);
    for c in 0..chunks_of(total) {
        let chunk = core.recv_bytes(prev, tag(c + 1))?;
        if forward {
            // Forward first: downstream starts on chunk k while we are
            // still assembling it.
            core.send_bytes(next, tag(c + 1), &[&chunk])?;
        }
        buf.extend_from_slice(&chunk);
        core.recycle(prev, chunk);
    }
    if buf.len() != total {
        return Err(CclError::Transport(format!(
            "broadcast stream truncated: {} of {total} bytes",
            buf.len()
        )));
    }
    read_tensor(&mut buf.as_slice())
        .map_err(|e| CclError::Transport(format!("bad broadcast tensor: {e}")))
}

/// Ring all-gather: each rank's serialized contribution circulates
/// `N−1` hops (store-and-forward per hop, all ranks transferring
/// concurrently each step), then parts concatenate in rank order —
/// byte-identical to the flat gather+broadcast result, including
/// per-rank contributions of differing axis-0 lengths.
fn ring_all_gather(core: &WorldCore, t: Tensor, seq: u64) -> CclResult<Tensor> {
    let n = core.size;
    let next = ring_next(core);
    let prev = ring_prev(core);
    let mut parts: Vec<Option<Vec<u8>>> = (0..n).map(|_| None).collect();
    let mut mine = Vec::with_capacity(crate::tensor::HEADER_LEN + t.byte_len());
    write_tensor(&mut mine, &t)
        .map_err(|e| CclError::InvalidUsage(format!("unserializable tensor: {e}")))?;
    parts[core.rank] = Some(mine);
    for s in 0..n - 1 {
        let send_idx = (core.rank + n - s) % n;
        let recv_idx = (core.rank + n - s - 1) % n;
        let tag = make_chunk_tag(TagKind::AllGather, seq, s, 0);
        core.send_bytes(next, tag, &[parts[send_idx].as_deref().unwrap()])?;
        parts[recv_idx] = Some(core.recv_bytes(prev, tag)?);
    }
    let mut tensors = Vec::with_capacity(n);
    for (i, p) in parts.iter().enumerate() {
        let bytes = p.as_deref().unwrap();
        let t = read_tensor(&mut &*bytes).map_err(|e| {
            CclError::Transport(format!("bad all_gather tensor from rank {i}: {e}"))
        })?;
        core.note_contrib(CollOp::AllGather, t.byte_len());
        tensors.push(t);
    }
    let cat = Tensor::concat(&tensors)
        .map_err(|e| CclError::InvalidUsage(format!("all_gather concat: {e}")))?;
    // Everything except our own serialization came off the wire; give
    // those buffers back to the inbound link's pool.
    for (i, p) in parts.into_iter().enumerate() {
        if i == core.rank {
            continue;
        }
        if let Some(b) = p {
            core.recycle(prev, b);
        }
    }
    Ok(cat)
}

/// Ring gather: serialized contributions hop rank → rank *toward* the
/// root (every non-root sends to its ring predecessor and relays what
/// its successor hands it), so the root drains one pipelined stream
/// from its successor — every hop transferring concurrently each step —
/// instead of `N−1` separate root streams. Per-rank contributions may
/// differ in size (same contract as flat gather); transports segment
/// each hop into [`SEG_MAX`] frames.
///
/// Step schedule: the rank at ring position `p` (distance from the
/// root) relays the contributions of positions `p..N-1`, own first; its
/// step-`s` send carries position `p+s`, so the root's step-`s` receive
/// is position `1+s`.
fn ring_gather(core: &WorldCore, t: Tensor, root: usize, seq: u64) -> CclResult<Option<Tensor>> {
    let n = core.size;
    let next = ring_next(core);
    let prev = ring_prev(core);
    let pos = (core.rank + n - root) % n;
    let tag = |s: usize| make_chunk_tag(TagKind::Gather, seq, s, 0);

    if core.rank == root {
        let mut parts: Vec<Option<Tensor>> = (0..n).map(|_| None).collect();
        parts[root] = Some(t);
        for s in 0..n - 1 {
            let from_rank = (root + 1 + s) % n;
            let bytes = core.recv_bytes(next, tag(s))?;
            let part = read_tensor(&mut bytes.as_slice()).map_err(|e| {
                CclError::Transport(format!("bad gather tensor from rank {from_rank}: {e}"))
            })?;
            core.recycle(next, bytes);
            core.note_contrib(CollOp::Gather, part.byte_len());
            parts[from_rank] = Some(part);
        }
        let parts: Vec<Tensor> = parts.into_iter().map(|p| p.unwrap()).collect();
        let cat = Tensor::concat(&parts)
            .map_err(|e| CclError::InvalidUsage(format!("gather concat: {e}")))?;
        return Ok(Some(cat));
    }

    let mut mine = Vec::with_capacity(crate::tensor::HEADER_LEN + t.byte_len());
    write_tensor(&mut mine, &t)
        .map_err(|e| CclError::InvalidUsage(format!("unserializable tensor: {e}")))?;
    let sends = n - pos; // own contribution + everything upstream of us
    let mut carry = mine;
    for s in 0..sends {
        core.send_bytes(prev, tag(s), &[&carry])?;
        let spent = std::mem::take(&mut carry);
        if s > 0 {
            // Everything after our own serialization came off the wire;
            // give it back to the inbound link's pool.
            core.recycle(next, spent);
        }
        if s + 1 < sends {
            carry = core.recv_bytes(next, tag(s))?;
        }
    }
    Ok(None)
}

/// Ring scatter: the root streams its serialized parts into the ring —
/// furthest destination first — and each rank peels off its own part
/// and forwards the rest (forward-before-parse, so downstream hops
/// overlap), replacing the flat star's `N−1` separate root streams with
/// one pipelined neighbour stream per rank.
///
/// Step schedule mirrors [`ring_gather`] in reverse: the root's step-`s`
/// send carries the part for ring position `N−1−s`; the rank at
/// position `p` receives `N−p` messages, keeps the last (its own part),
/// and forwards the rest under its own step counter.
fn ring_scatter(
    core: &WorldCore,
    parts: Option<Vec<Tensor>>,
    root: usize,
    seq: u64,
) -> CclResult<Tensor> {
    let n = core.size;
    let next = ring_next(core);
    let prev = ring_prev(core);
    let pos = (core.rank + n - root) % n;
    let tag = |s: usize| make_chunk_tag(TagKind::Scatter, seq, s, 0);

    if core.rank == root {
        let mut parts = parts.unwrap(); // validated at submit
        for s in 0..n - 1 {
            let dest = (root + (n - 1 - s)) % n;
            let hdr = encode_header(&parts[dest])
                .map_err(|e| CclError::InvalidUsage(format!("unserializable tensor: {e}")))?;
            core.send_bytes(next, tag(s), &[&hdr, parts[dest].bytes()])?;
        }
        // Take the root's part out of the vec — no tensor clone.
        return Ok(parts.swap_remove(root));
    }

    let recvs = n - pos;
    for s in 0..recvs {
        let buf = core.recv_bytes(prev, tag(s))?;
        if s + 1 < recvs {
            // Not ours: forward first so downstream starts immediately.
            core.send_bytes(next, tag(s), &[&buf])?;
            core.recycle(prev, buf);
        } else {
            let part = read_tensor(&mut buf.as_slice()).map_err(|e| {
                CclError::Transport(format!("bad scatter tensor from rank {prev}: {e}"))
            })?;
            core.recycle(prev, buf);
            return Ok(part);
        }
    }
    unreachable!("non-root ring position receives at least one part")
}

// ------------------------------------------------------------- hier impls
//
// Two-level algorithms for multi-host worlds: an intra-host phase over
// the cheap local links between each host's members and its leader
// (lowest rank on the host), and an inter-host phase restricted to the
// leaders, which reuse the pipelined ring machinery over a `RingCtx`
// whose member list is the leader set. Intra-host traffic rides the
// reserved tag steps `STEP_UP` (member → leader, chunk = sender rank)
// and `STEP_DOWN` (leader → member, chunk = receiver rank); leader-ring
// steps stay ≤ 253, so the tag spaces never collide within one seq.

/// Member → leader fan-in tag.
#[inline]
fn up_tag(kind: TagKind, seq: u64, rank: usize) -> u64 {
    make_chunk_tag(kind, seq, STEP_UP, rank)
}

/// Leader → member fan-out tag.
#[inline]
fn down_tag(kind: TagKind, seq: u64, rank: usize) -> u64 {
    make_chunk_tag(kind, seq, STEP_DOWN, rank)
}

/// Hierarchical all-reduce: rank-order intra-host fold at each leader,
/// ring all-reduce among the leaders, intra-host fan-out. `Avg` runs as
/// `Sum` end to end and divides once by the world size, so the result
/// matches the flat/ring semantics (mean over *ranks*, not hosts).
fn hier_all_reduce(core: &WorldCore, mut t: Tensor, op: ReduceOp, seq: u64) -> CclResult<Tensor> {
    if t.dtype() != DType::F32 {
        return Err(CclError::InvalidUsage("all_reduce requires f32 tensors".into()));
    }
    let kind = TagKind::AllReduce;
    let hosts = &core.hosts;
    let me = core.rank;
    let leader = hosts.leader(hosts.host(me));
    let fold_op = if op == ReduceOp::Avg { ReduceOp::Sum } else { op };

    if me != leader {
        core.send_bytes(leader, up_tag(kind, seq, me), &[t.bytes()])?;
        let buf = core.recv_bytes(leader, down_tag(kind, seq, me))?;
        if buf.len() != t.byte_len() {
            return Err(CclError::Transport(format!(
                "all_reduce fan-out length mismatch from leader {leader}: {} vs {}",
                buf.len(),
                t.byte_len()
            )));
        }
        t.bytes_mut().copy_from_slice(&buf);
        core.recycle(leader, buf);
        return Ok(t);
    }

    // Leader: fold host members in rank order (we are the lowest rank on
    // the host, so our own contribution seeds the fold) — deterministic
    // for a fixed host map, like the flat root's rank-order fold.
    for m in hosts.members(hosts.host(me)) {
        if m == me {
            continue;
        }
        let buf = core.recv_bytes(m, up_tag(kind, seq, m))?;
        if buf.len() != t.byte_len() {
            return Err(CclError::InvalidUsage(format!(
                "all_reduce length mismatch from rank {m}: {} vs {} \
                 (peers must pass identically-shaped tensors)",
                buf.len(),
                t.byte_len()
            )));
        }
        fold_f32(t.bytes_mut(), &buf, fold_op);
        core.recycle(m, buf);
    }

    let leaders = hosts.leaders();
    t = ring_all_reduce_ctx(&RingCtx::new(core, &leaders), t, fold_op, kind, seq)?;
    if op == ReduceOp::Avg {
        let len = t.byte_len();
        scale_slice(&mut t, 0, len, 1.0 / core.size as f32);
    }
    for m in hosts.members(hosts.host(me)) {
        if m != me {
            core.send_bytes(m, down_tag(kind, seq, m), &[t.bytes()])?;
        }
    }
    Ok(t)
}

/// Hierarchical broadcast: the root hands its tensor to its host's
/// leader, the leaders ring-broadcast it between hosts, and each leader
/// fans it out to its members (skipping the root, which already holds
/// it).
fn hier_broadcast(
    core: &WorldCore,
    t: Option<Tensor>,
    root: usize,
    seq: u64,
) -> CclResult<Tensor> {
    let kind = TagKind::Broadcast;
    let hosts = &core.hosts;
    let me = core.rank;
    let my_leader = hosts.leader(hosts.host(me));
    let origin_leader = hosts.leader(hosts.host(root));

    if me != my_leader {
        if me == root {
            let t = t.ok_or_else(|| CclError::InvalidUsage("root must supply tensor".into()))?;
            core.send_tensor(my_leader, up_tag(kind, seq, me), &t)?;
            return Ok(t);
        }
        return core.recv_tensor(my_leader, down_tag(kind, seq, me));
    }

    // Leader. Source the tensor: our own if we are the root, pulled from
    // the root if it lives on our host, or from the leader ring.
    let seed = if me == root {
        t
    } else if me == origin_leader {
        Some(core.recv_tensor(root, up_tag(kind, seq, root))?)
    } else {
        None
    };
    let leaders = hosts.leaders();
    let root_idx = leaders
        .iter()
        .position(|&l| l == origin_leader)
        .expect("origin leader is in the leader list");
    let result = ring_broadcast_ctx(&RingCtx::new(core, &leaders), seed, root_idx, kind, seq)?;
    for m in hosts.members(hosts.host(me)) {
        if m != me && m != root {
            core.send_tensor(m, down_tag(kind, seq, m), &result)?;
        }
    }
    Ok(result)
}

/// Hierarchical reduce: rank-order intra-host fold at each leader, ring
/// reduce among the leaders toward the root's host leader, then a final
/// intra-host hand-off to the root. `Avg` runs as `Sum` and divides
/// once by the world size at the origin leader.
fn hier_reduce(
    core: &WorldCore,
    mut t: Tensor,
    root: usize,
    op: ReduceOp,
    seq: u64,
) -> CclResult<Option<Tensor>> {
    if t.dtype() != DType::F32 {
        return Err(CclError::InvalidUsage("reduce requires f32 tensors".into()));
    }
    let kind = TagKind::Reduce;
    let hosts = &core.hosts;
    let me = core.rank;
    let my_leader = hosts.leader(hosts.host(me));
    let fold_op = if op == ReduceOp::Avg { ReduceOp::Sum } else { op };

    if me != my_leader {
        core.send_bytes(my_leader, up_tag(kind, seq, me), &[t.bytes()])?;
        if me != root {
            return Ok(None);
        }
        // Non-leader root: the origin leader (our host's leader) hands
        // the finished reduction back down.
        let buf = core.recv_bytes(my_leader, down_tag(kind, seq, me))?;
        if buf.len() != t.byte_len() {
            return Err(CclError::Transport(format!(
                "reduce hand-off length mismatch from leader {my_leader}: {} vs {}",
                buf.len(),
                t.byte_len()
            )));
        }
        t.bytes_mut().copy_from_slice(&buf);
        core.recycle(my_leader, buf);
        return Ok(Some(t));
    }

    for m in hosts.members(hosts.host(me)) {
        if m == me {
            continue;
        }
        let buf = core.recv_bytes(m, up_tag(kind, seq, m))?;
        if buf.len() != t.byte_len() {
            return Err(CclError::InvalidUsage(format!(
                "reduce length mismatch from rank {m}: {} vs {} \
                 (peers must pass identically-shaped tensors)",
                buf.len(),
                t.byte_len()
            )));
        }
        fold_f32(t.bytes_mut(), &buf, fold_op);
        core.recycle(m, buf);
    }

    let leaders = hosts.leaders();
    let origin_leader = hosts.leader(hosts.host(root));
    let root_idx = leaders
        .iter()
        .position(|&l| l == origin_leader)
        .expect("origin leader is in the leader list");
    let reduced =
        ring_reduce_ctx(&RingCtx::new(core, &leaders), t, root_idx, fold_op, kind, seq)?;
    let Some(mut t) = reduced else {
        return Ok(None); // non-origin leader: slice shipped, nothing to hold
    };
    if op == ReduceOp::Avg {
        let len = t.byte_len();
        scale_slice(&mut t, 0, len, 1.0 / core.size as f32);
    }
    if me == root {
        return Ok(Some(t));
    }
    core.send_bytes(root, down_tag(kind, seq, root), &[t.bytes()])?;
    Ok(None)
}

/// Hierarchical all-gather: members ship their serialized contributions
/// to their leader, leaders ring-exchange per-host *blobs* (rank-tagged
/// entry lists, so asymmetric hosts and per-rank sizes survive), each
/// leader assembles the world-rank-order concatenation, and fans it
/// out. Cross-host traffic is one blob per host pair instead of one
/// message per remote rank.
fn hier_all_gather(core: &WorldCore, t: Tensor, seq: u64) -> CclResult<Tensor> {
    let kind = TagKind::AllGather;
    let hosts = &core.hosts;
    let me = core.rank;
    let leader = hosts.leader(hosts.host(me));
    core.note_contrib(CollOp::AllGather, t.byte_len());

    if me != leader {
        let mut mine = Vec::with_capacity(crate::tensor::HEADER_LEN + t.byte_len());
        write_tensor(&mut mine, &t)
            .map_err(|e| CclError::InvalidUsage(format!("unserializable tensor: {e}")))?;
        core.send_bytes(leader, up_tag(kind, seq, me), &[&mine])?;
        return core.recv_tensor(leader, down_tag(kind, seq, me));
    }

    // Leader: build this host's blob — `rank:u64 len:u64 bytes` entries
    // in ascending rank order.
    let members = hosts.members(hosts.host(me));
    let mut blob = Vec::new();
    for &m in &members {
        let bytes = if m == me {
            let mut mine = Vec::with_capacity(crate::tensor::HEADER_LEN + t.byte_len());
            write_tensor(&mut mine, &t)
                .map_err(|e| CclError::InvalidUsage(format!("unserializable tensor: {e}")))?;
            mine
        } else {
            core.recv_bytes(m, up_tag(kind, seq, m))?
        };
        blob.extend_from_slice(&(m as u64).to_le_bytes());
        blob.extend_from_slice(&(bytes.len() as u64).to_le_bytes());
        blob.extend_from_slice(&bytes);
        if m != me {
            core.recycle(m, bytes);
        }
    }

    // Ring-exchange blobs among the leaders (store-and-forward per hop,
    // same schedule as the single-level ring all-gather).
    let leaders = hosts.leaders();
    let nl = leaders.len();
    let my_idx = leaders
        .iter()
        .position(|&l| l == me)
        .expect("we are a leader");
    let next = leaders[(my_idx + 1) % nl];
    let prev = leaders[(my_idx + nl - 1) % nl];
    let mut blobs: Vec<Option<Vec<u8>>> = (0..nl).map(|_| None).collect();
    blobs[my_idx] = Some(blob);
    for s in 0..nl - 1 {
        let send_idx = (my_idx + nl - s) % nl;
        let recv_idx = (my_idx + nl - s - 1) % nl;
        let tag = make_chunk_tag(kind, seq, s, 0);
        core.send_bytes(next, tag, &[blobs[send_idx].as_deref().unwrap()])?;
        blobs[recv_idx] = Some(core.recv_bytes(prev, tag)?);
    }

    // Parse every blob into world-rank slots and concatenate in order.
    let mut parts: Vec<Option<Tensor>> = (0..core.size).map(|_| None).collect();
    for (idx, b) in blobs.iter().enumerate() {
        let mut sl: &[u8] = b.as_deref().unwrap();
        while !sl.is_empty() {
            if sl.len() < 16 {
                return Err(CclError::Transport(format!(
                    "all_gather blob from host {idx} truncated"
                )));
            }
            let rank = u64::from_le_bytes(sl[0..8].try_into().unwrap()) as usize;
            let len = u64::from_le_bytes(sl[8..16].try_into().unwrap()) as usize;
            if sl.len() < 16 + len || rank >= core.size {
                return Err(CclError::Transport(format!(
                    "all_gather blob from host {idx}: bad entry (rank {rank}, len {len})"
                )));
            }
            let part = read_tensor(&mut &sl[16..16 + len]).map_err(|e| {
                CclError::Transport(format!("bad all_gather tensor from rank {rank}: {e}"))
            })?;
            core.note_contrib(CollOp::AllGather, part.byte_len());
            parts[rank] = Some(part);
            sl = &sl[16 + len..];
        }
    }
    for b in blobs.into_iter().flatten() {
        core.recycle(prev, b);
    }
    let parts: Vec<Tensor> = parts
        .into_iter()
        .enumerate()
        .map(|(r, p)| {
            p.ok_or_else(|| {
                CclError::Transport(format!("all_gather: no contribution for rank {r}"))
            })
        })
        .collect::<CclResult<_>>()?;
    let cat = Tensor::concat(&parts)
        .map_err(|e| CclError::InvalidUsage(format!("all_gather concat: {e}")))?;
    for &m in &members {
        if m != me {
            core.send_tensor(m, down_tag(kind, seq, m), &cat)?;
        }
    }
    Ok(cat)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_arithmetic() {
        assert_eq!(chunks_of(0), 0);
        assert_eq!(chunks_of(1), 1);
        assert_eq!(chunks_of(RING_CHUNK), 1);
        assert_eq!(chunks_of(RING_CHUNK + 1), 2);
        let (lo, hi) = chunk_bounds(100, RING_CHUNK + 7, 1);
        assert_eq!(lo, 100 + RING_CHUNK);
        assert_eq!(hi, 100 + RING_CHUNK + 7);
    }

    #[test]
    fn rank_slices_partition_exactly() {
        for (elems, n) in [(10usize, 4usize), (7, 3), (3, 4), (0, 2), (100_003, 8)] {
            let mut covered = 0usize;
            for i in 0..n {
                let (off, len) = rank_slice_bytes(elems, n, i);
                assert_eq!(off, covered, "slices must be contiguous");
                covered += len;
            }
            assert_eq!(covered, elems * 4, "slices must cover the tensor");
        }
    }

    #[test]
    fn fold_f32_ops() {
        let a: Vec<u8> = [1.0f32, -2.0, 3.5]
            .iter()
            .flat_map(|v| v.to_le_bytes())
            .collect();
        let b: Vec<u8> = [10.0f32, 5.0, -1.0]
            .iter()
            .flat_map(|v| v.to_le_bytes())
            .collect();
        let mut sum = a.clone();
        fold_f32(&mut sum, &b, ReduceOp::Sum);
        let got: Vec<f32> = sum
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        assert_eq!(got, vec![11.0, 3.0, 2.5]);
        let mut mx = a;
        fold_f32(&mut mx, &b, ReduceOp::Max);
        let got: Vec<f32> = mx
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        assert_eq!(got, vec![10.0, 5.0, 3.5]);
    }
}
