//! The eight collective operations (§3.3: "We support 8 collective
//! operations: send, recv, broadcast, all-reduce, reduce, all-gather,
//! gather, and scatter.").
//!
//! Every op exists in asynchronous form (`i*` prefixed, returning
//! [`Work`]) plus a blocking convenience wrapper. Algorithms are flat
//! (star through the root) — the paper's worlds are 2–3 ranks, where
//! flat is optimal; ring variants are a perf-pass option behind the same
//! API.
//!
//! Deadlock-freedom: receiver threads always drain transports into
//! unbounded inboxes, so a send never blocks on the peer's op order —
//! within one world, ops still execute in submission order on the
//! progress thread (CCL contract: all ranks issue collectives in the
//! same order).

use super::error::{CclError, CclResult};
use super::wire::{make_tag, TagKind};
use super::work::Work;
use super::world::{ReduceOp, World, WorldCore};
use crate::tensor::Tensor;

impl World {
    // ---------------------------------------------------------------- p2p

    /// Async point-to-point send. `tag` is user-chosen (48-bit).
    pub fn isend(&self, t: Tensor, dst: usize, tag: u64) -> Work {
        let desc = format!("isend dst={dst} tag={tag} world={}", self.name());
        if dst == self.rank() || dst >= self.size() {
            return Work::failed(desc, CclError::InvalidUsage(format!("bad dst {dst}")));
        }
        let wire = make_tag(TagKind::P2p, tag);
        self.submit(desc, move |core| {
            core.send_tensor(dst, wire, &t)?;
            Ok(None)
        })
    }

    /// Async point-to-point receive; the Work resolves to the tensor.
    ///
    /// Unlike collectives, `irecv`s go to the world's p2p *poller*, so
    /// receives from different peers complete in arrival order, not
    /// submission order — a leader can post receives to all its senders
    /// and harvest whichever lands first (the Fig. 4 pattern).
    pub fn irecv(&self, src: usize, tag: u64) -> Work {
        let desc = format!("irecv src={src} tag={tag} world={}", self.name());
        if src == self.rank() || src >= self.size() {
            return Work::failed(desc, CclError::InvalidUsage(format!("bad src {src}")));
        }
        if let Err(e) = self.core().check_healthy() {
            return Work::failed(desc, e);
        }
        let wire = make_tag(TagKind::P2p, tag);
        let work = Work::pending(desc);
        work.set_running();
        self.core().register_recv(src, wire, work.clone());
        work
    }

    /// Blocking send.
    pub fn send(&self, t: Tensor, dst: usize, tag: u64) -> CclResult<()> {
        self.isend(t, dst, tag).wait().map(|_| ())
    }

    /// Blocking receive.
    pub fn recv(&self, src: usize, tag: u64) -> CclResult<Tensor> {
        self.irecv(src, tag)
            .wait()?
            .ok_or_else(|| CclError::Transport("recv returned no tensor".into()))
    }

    // --------------------------------------------------------- broadcast

    /// Async broadcast: root's tensor is delivered to every rank. Root
    /// passes `Some(tensor)`, non-roots pass `None` (shape travels on
    /// the wire, so receivers need no pre-allocation). Resolves to the
    /// broadcast tensor on every rank.
    pub fn ibroadcast(&self, t: Option<Tensor>, root: usize) -> Work {
        let desc = format!("broadcast root={root} world={}", self.name());
        if root >= self.size() {
            return Work::failed(desc, CclError::InvalidUsage(format!("bad root {root}")));
        }
        let me = self.rank();
        if me == root && t.is_none() {
            return Work::failed(desc, CclError::InvalidUsage("root must supply tensor".into()));
        }
        if self.size() == 1 {
            return Work::done(desc, t);
        }
        let seq = self.core().next_seq();
        let wire = make_tag(TagKind::Broadcast, seq);
        self.submit(desc, move |core| broadcast_impl(core, t, root, wire).map(Some))
    }

    /// Blocking broadcast.
    pub fn broadcast(&self, t: Option<Tensor>, root: usize) -> CclResult<Tensor> {
        self.ibroadcast(t, root)
            .wait()?
            .ok_or_else(|| CclError::Transport("broadcast returned no tensor".into()))
    }

    // ------------------------------------------------------------ reduce

    /// Async reduce: every rank contributes `t`; the root's Work
    /// resolves to the reduction, other ranks' resolve to `None`.
    pub fn ireduce(&self, t: Tensor, root: usize, op: ReduceOp) -> Work {
        let desc = format!("reduce root={root} {op:?} world={}", self.name());
        if root >= self.size() {
            return Work::failed(desc, CclError::InvalidUsage(format!("bad root {root}")));
        }
        if self.size() == 1 {
            return Work::done(desc, Some(t));
        }
        let seq = self.core().next_seq();
        let wire = make_tag(TagKind::Reduce, seq);
        self.submit(desc, move |core| reduce_impl(core, t, root, op, wire))
    }

    /// Blocking reduce (returns the reduction at root, `None` elsewhere).
    pub fn reduce(&self, t: Tensor, root: usize, op: ReduceOp) -> CclResult<Option<Tensor>> {
        self.ireduce(t, root, op).wait()
    }

    // -------------------------------------------------------- all_reduce

    /// Async all-reduce (reduce to rank 0, then broadcast). Resolves to
    /// the reduced tensor on every rank.
    pub fn iall_reduce(&self, t: Tensor, op: ReduceOp) -> Work {
        let desc = format!("all_reduce {op:?} world={}", self.name());
        if self.size() == 1 {
            return Work::done(desc, Some(t));
        }
        let seq = self.core().next_seq();
        let rtag = make_tag(TagKind::AllReduce, seq * 2);
        let btag = make_tag(TagKind::AllReduce, seq * 2 + 1);
        self.submit(desc, move |core| {
            let reduced = reduce_impl(core, t, 0, op, rtag)?;
            broadcast_impl(core, reduced, 0, btag).map(Some)
        })
    }

    /// Blocking all-reduce.
    pub fn all_reduce(&self, t: Tensor, op: ReduceOp) -> CclResult<Tensor> {
        self.iall_reduce(t, op)
            .wait()?
            .ok_or_else(|| CclError::Transport("all_reduce returned no tensor".into()))
    }

    // ------------------------------------------------------------ gather

    /// Async gather: root's Work resolves to the rank-order concatenation
    /// along axis 0; contributions must share trailing dims.
    pub fn igather(&self, t: Tensor, root: usize) -> Work {
        let desc = format!("gather root={root} world={}", self.name());
        if root >= self.size() {
            return Work::failed(desc, CclError::InvalidUsage(format!("bad root {root}")));
        }
        if self.size() == 1 {
            return Work::done(desc, Some(t));
        }
        let seq = self.core().next_seq();
        let wire = make_tag(TagKind::Gather, seq);
        self.submit(desc, move |core| gather_impl(core, t, root, wire))
    }

    /// Blocking gather.
    pub fn gather(&self, t: Tensor, root: usize) -> CclResult<Option<Tensor>> {
        self.igather(t, root).wait()
    }

    // -------------------------------------------------------- all_gather

    /// Async all-gather: every rank resolves to the concatenation
    /// (gather to rank 0, broadcast back).
    pub fn iall_gather(&self, t: Tensor) -> Work {
        let desc = format!("all_gather world={}", self.name());
        if self.size() == 1 {
            return Work::done(desc, Some(t));
        }
        let seq = self.core().next_seq();
        let gtag = make_tag(TagKind::AllGather, seq * 2);
        let btag = make_tag(TagKind::AllGather, seq * 2 + 1);
        self.submit(desc, move |core| {
            let gathered = gather_impl(core, t, 0, gtag)?;
            broadcast_impl(core, gathered, 0, btag).map(Some)
        })
    }

    /// Blocking all-gather.
    pub fn all_gather(&self, t: Tensor) -> CclResult<Tensor> {
        self.iall_gather(t)
            .wait()?
            .ok_or_else(|| CclError::Transport("all_gather returned no tensor".into()))
    }

    // ----------------------------------------------------------- scatter

    /// Async scatter: root supplies one tensor per rank (in rank order);
    /// every rank's Work resolves to its part. Non-roots pass `None`.
    pub fn iscatter(&self, parts: Option<Vec<Tensor>>, root: usize) -> Work {
        let desc = format!("scatter root={root} world={}", self.name());
        if root >= self.size() {
            return Work::failed(desc, CclError::InvalidUsage(format!("bad root {root}")));
        }
        let me = self.rank();
        if me == root {
            match &parts {
                Some(p) if p.len() == self.size() => {}
                Some(p) => {
                    return Work::failed(
                        desc,
                        CclError::InvalidUsage(format!(
                            "scatter needs {} parts, got {}",
                            self.size(),
                            p.len()
                        )),
                    )
                }
                None => {
                    return Work::failed(desc, CclError::InvalidUsage("root must supply parts".into()))
                }
            }
        }
        if self.size() == 1 {
            return Work::done(desc, parts.map(|mut p| p.remove(0)));
        }
        let seq = self.core().next_seq();
        let wire = make_tag(TagKind::Scatter, seq);
        self.submit(desc, move |core| scatter_impl(core, parts, root, wire).map(Some))
    }

    /// Blocking scatter.
    pub fn scatter(&self, parts: Option<Vec<Tensor>>, root: usize) -> CclResult<Tensor> {
        self.iscatter(parts, root)
            .wait()?
            .ok_or_else(|| CclError::Transport("scatter returned no tensor".into()))
    }
}

// ------------------------------------------------------------------ impls

fn broadcast_impl(
    core: &WorldCore,
    t: Option<Tensor>,
    root: usize,
    wire: u64,
) -> CclResult<Tensor> {
    if core.rank == root {
        let t = t.ok_or_else(|| CclError::InvalidUsage("root must supply tensor".into()))?;
        for peer in 0..core.size {
            if peer != root {
                core.send_tensor(peer, wire, &t)?;
            }
        }
        Ok(t)
    } else {
        core.recv_tensor(root, wire)
    }
}

fn reduce_impl(
    core: &WorldCore,
    t: Tensor,
    root: usize,
    op: ReduceOp,
    wire: u64,
) -> CclResult<Option<Tensor>> {
    if core.rank == root {
        let mut acc = t;
        if acc.dtype() != crate::tensor::DType::F32 {
            return Err(CclError::InvalidUsage("reduce requires f32 tensors".into()));
        }
        for peer in 0..core.size {
            if peer == root {
                continue;
            }
            let part = core.recv_tensor(peer, wire)?;
            if part.shape() != acc.shape() || part.dtype() != acc.dtype() {
                return Err(CclError::InvalidUsage(format!(
                    "reduce shape mismatch: {:?} vs {:?} from rank {peer}",
                    acc.shape(),
                    part.shape()
                )));
            }
            match op {
                ReduceOp::Sum | ReduceOp::Avg => acc.add_assign(&part),
                ReduceOp::Max => acc.max_assign(&part),
            }
        }
        if op == ReduceOp::Avg {
            acc.scale(1.0 / core.size as f32);
        }
        Ok(Some(acc))
    } else {
        core.send_tensor(root, wire, &t)?;
        Ok(None)
    }
}

fn gather_impl(
    core: &WorldCore,
    t: Tensor,
    root: usize,
    wire: u64,
) -> CclResult<Option<Tensor>> {
    if core.rank == root {
        let mut parts: Vec<Option<Tensor>> = (0..core.size).map(|_| None).collect();
        parts[root] = Some(t);
        for peer in 0..core.size {
            if peer == root {
                continue;
            }
            parts[peer] = Some(core.recv_tensor(peer, wire)?);
        }
        let parts: Vec<Tensor> = parts.into_iter().map(|p| p.unwrap()).collect();
        let cat = Tensor::concat(&parts)
            .map_err(|e| CclError::InvalidUsage(format!("gather concat: {e}")))?;
        Ok(Some(cat))
    } else {
        core.send_tensor(root, wire, &t)?;
        Ok(None)
    }
}

fn scatter_impl(
    core: &WorldCore,
    parts: Option<Vec<Tensor>>,
    root: usize,
    wire: u64,
) -> CclResult<Tensor> {
    if core.rank == root {
        let mut parts = parts.unwrap(); // validated at submit
        // Send in reverse so removal by index stays cheap and rank order
        // on the wire is immaterial (distinct links).
        let mine = parts[root].clone();
        for peer in (0..core.size).rev() {
            if peer == root {
                continue;
            }
            core.send_tensor(peer, wire, &parts[peer])?;
        }
        parts.clear();
        Ok(mine)
    } else {
        core.recv_tensor(root, wire)
    }
}
