//! The eight collective operations (§3.3: "We support 8 collective
//! operations: send, recv, broadcast, all-reduce, reduce, all-gather,
//! gather, and scatter.").
//!
//! Every op exists in asynchronous form (`i*` prefixed, returning
//! [`Work`]) plus a blocking convenience wrapper.
//!
//! ## Algorithm selector
//!
//! Every collective with an algorithm choice (all six: `broadcast`,
//! `reduce`, `all_reduce`, `gather`, `all_gather`, `scatter`) runs one
//! of two algorithms, chosen per op by the world's
//! [`crate::config::CollPolicy`] (`WorldOptions::coll_policy`, env
//! `MW_COLL_ALGO` + `MW_RING_MIN_*` threshold table):
//!
//! * **Flat** — a star through the root: the root performs `size − 1`
//!   sequential full-size transfers. Optimal for the paper's 2–3 rank
//!   worlds and for small messages (fewest hops, no pipeline fill).
//! * **Ring** — bandwidth-optimal pipelined rings. All-reduce is a
//!   reduce-scatter followed by an all-gather over [`SEG_MAX`]-sized
//!   chunks: each rank moves `2·(N−1)/N` of the tensor through its own
//!   NIC instead of the root moving `(N−1)×` the tensor through one,
//!   and chunk `k+1` is on the wire while chunk `k` is being reduced
//!   (the receiver threads drain into unbounded inboxes, so sends never
//!   wait for the reducer). Reduce runs the *same* reduce-scatter, then
//!   every rank ships its fully-reduced slice straight to the root, so
//!   the root's NIC ingests ~S instead of (N−1)·S. Broadcast forwards
//!   chunks hop-by-hop down the ring — a non-root forwards chunk `k`
//!   *before* folding it into its buffer, so the pipeline depth is one
//!   chunk, not one tensor. All-gather circulates each rank's
//!   serialized contribution `N−1` hops; gather circulates
//!   contributions hop-by-hop *toward* the root, and scatter streams
//!   the root's parts hop-by-hop away from it (each rank peels off its
//!   own part and forwards the rest), replacing `N−1` separate root
//!   streams with one pipelined neighbour stream per rank.
//! * **Auto** — ring once both the world and the payload clear the
//!   per-op [`crate::config::RingThreshold`] row. For ops where every
//!   rank knows the payload size up front (`all_reduce`, `reduce` — the
//!   CCL contract makes contributions identically shaped) the choice is
//!   computed locally and identically everywhere. For ops where only
//!   the root can know (`broadcast`, `gather`, `all_gather`, `scatter`)
//!   the policy returns `Negotiate`: the root resolves flat-vs-ring
//!   from the real (or root-estimated) byte count and announces the
//!   verdict in a one-byte *prologue* frame fanned out flat on the op
//!   tag's prologue lane (see [`crate::mwccl::wire::FLAG_PROLOGUE`]),
//!   so tiny control messages keep the flat fast path instead of paying
//!   `N−1` sequential hops. Gather/all_gather roots can only *estimate*
//!   (contributions may differ per rank): the estimate is own
//!   contribution × N, clamped from below by the largest contribution
//!   observed on any earlier invocation of the same op on this world
//!   (`WorldCore::max_contrib`), so a small-contribution root stops
//!   under-picking flat under skewed per-rank sizes after the first
//!   round. Thresholds match the crossover measured by
//!   `benches/ablation_collectives.rs` (re-checked by CI's
//!   `crossover-matrix` job).
//!
//! Both algorithms produce identical bytes for the data-movement ops
//! (broadcast, gather, all_gather, scatter); for all_reduce/reduce the
//! two fold in different orders, so f32 rounding may differ in the last
//! ulp (exactly like NCCL's tree vs ring). The algorithm choice is
//! rank-consistent by construction — computed from inputs all ranks
//! share, or received from the root's prologue — which is required
//! because the two algorithms use different wire tags (ring ops tag
//! each (step, chunk), see [`make_chunk_tag`]). The choice each op
//! actually ran is observable via `World::last_algo`.
//!
//! Flat `reduce` receives in arrival order but folds in **rank order**:
//! contributions land in a rank-indexed slot table as they arrive (one
//! slow peer never serializes the receives behind it), and the fold
//! pointer advances through ranks `0, 1, …, N−1` as its next slot
//! fills. The f32 result is therefore bitwise-deterministic for a given
//! input set, however adversarially the network reorders arrivals —
//! non-commutative-in-float ops (Sum/Avg) no longer round differently
//! run to run. The price is holding up to `N−1` undelivered tensors
//! when arrivals are exactly reversed; worlds large enough to care
//! cross the ring threshold anyway.
//!
//! Deadlock-freedom: receiver threads always drain transports into
//! unbounded inboxes, so a send never blocks on the peer's op order —
//! within one world, ops still execute in submission order on the
//! progress thread (CCL contract: all ranks issue collectives in the
//! same order). The prologue negotiation obeys the same ordering: it
//! runs on the progress thread as the first phase of its op.

use super::error::{CclError, CclResult};
use super::wire::{make_chunk_tag, make_tag, TagKind, SEG_MAX};
use super::work::Work;
use super::world::{ReduceOp, World, WorldCore};
use crate::config::{AlgoDecision, CollOp};
use crate::tensor::serialize::encode_header;
use crate::tensor::{read_tensor, write_tensor, DType, Tensor};

/// Payload bytes per ring chunk message — one transport segment, so a
/// chunk is the unit of both pipelining and cut-through.
const RING_CHUNK: usize = SEG_MAX;

impl World {
    // ---------------------------------------------------------------- p2p

    /// Async point-to-point send. `tag` is user-chosen (48-bit).
    pub fn isend(&self, t: Tensor, dst: usize, tag: u64) -> Work {
        let desc = format!("isend dst={dst} tag={tag} world={}", self.name());
        if dst == self.rank() || dst >= self.size() {
            return Work::failed(desc, CclError::InvalidUsage(format!("bad dst {dst}")));
        }
        let wire = make_tag(TagKind::P2p, tag);
        self.submit(desc, move |core| {
            core.send_tensor(dst, wire, &t)?;
            Ok(None)
        })
    }

    /// Async point-to-point receive; the Work resolves to the tensor.
    ///
    /// Unlike collectives, `irecv`s go to the world's p2p *poller*, so
    /// receives from different peers complete in arrival order, not
    /// submission order — a leader can post receives to all its senders
    /// and harvest whichever lands first (the Fig. 4 pattern).
    pub fn irecv(&self, src: usize, tag: u64) -> Work {
        let desc = format!("irecv src={src} tag={tag} world={}", self.name());
        if src == self.rank() || src >= self.size() {
            return Work::failed(desc, CclError::InvalidUsage(format!("bad src {src}")));
        }
        if let Err(e) = self.core().check_healthy() {
            return Work::failed(desc, e);
        }
        let wire = make_tag(TagKind::P2p, tag);
        let work = Work::pending(desc);
        work.set_running();
        self.core().register_recv(src, wire, work.clone());
        work
    }

    /// Blocking send.
    pub fn send(&self, t: Tensor, dst: usize, tag: u64) -> CclResult<()> {
        self.isend(t, dst, tag).wait().map(|_| ())
    }

    /// Blocking receive.
    pub fn recv(&self, src: usize, tag: u64) -> CclResult<Tensor> {
        self.irecv(src, tag)
            .wait()?
            .ok_or_else(|| CclError::Transport("recv returned no tensor".into()))
    }

    // --------------------------------------------------------- broadcast

    /// Async broadcast: root's tensor is delivered to every rank. Root
    /// passes `Some(tensor)`, non-roots pass `None` (shape travels on
    /// the wire, so receivers need no pre-allocation). Resolves to the
    /// broadcast tensor on every rank.
    pub fn ibroadcast(&self, t: Option<Tensor>, root: usize) -> Work {
        let desc = format!("broadcast root={root} world={}", self.name());
        if root >= self.size() {
            return Work::failed(desc, CclError::InvalidUsage(format!("bad root {root}")));
        }
        let me = self.rank();
        if me == root && t.is_none() {
            return Work::failed(desc, CclError::InvalidUsage("root must supply tensor".into()));
        }
        if self.size() == 1 {
            return Work::done(desc, t);
        }
        let seq = self.core().next_seq();
        // Only the root knows the size, so under Auto the policy asks
        // for a prologue negotiation (resolved on the progress thread).
        let decision = self.core().coll_policy.decide(CollOp::Broadcast, self.size(), None);
        let root_bytes = t.as_ref().map(|t| t.byte_len());
        self.submit(desc, move |core| {
            let ring = resolve_algo(
                core,
                CollOp::Broadcast,
                TagKind::Broadcast,
                seq,
                root,
                decision,
                root_bytes,
            )?;
            if ring {
                ring_broadcast(core, t, root, seq).map(Some)
            } else {
                broadcast_impl(core, t, root, make_tag(TagKind::Broadcast, seq)).map(Some)
            }
        })
    }

    /// Blocking broadcast.
    pub fn broadcast(&self, t: Option<Tensor>, root: usize) -> CclResult<Tensor> {
        self.ibroadcast(t, root)
            .wait()?
            .ok_or_else(|| CclError::Transport("broadcast returned no tensor".into()))
    }

    // ------------------------------------------------------------ reduce

    /// Async reduce: every rank contributes `t`; the root's Work
    /// resolves to the reduction, other ranks' resolve to `None`. Flat =
    /// star into the root — received in arrival order, folded in rank
    /// order (bitwise-deterministic; see [`reduce_impl`]); ring = the
    /// all-reduce's chunked reduce-scatter, then each rank ships its
    /// fully-reduced slice to the root (the root's NIC ingests ~S
    /// instead of (N−1)·S).
    pub fn ireduce(&self, t: Tensor, root: usize, op: ReduceOp) -> Work {
        let desc = format!("reduce root={root} {op:?} world={}", self.name());
        if root >= self.size() {
            return Work::failed(desc, CclError::InvalidUsage(format!("bad root {root}")));
        }
        if self.size() == 1 {
            return Work::done(desc, Some(t));
        }
        let seq = self.core().next_seq();
        // Contributions are identically shaped (CCL contract), so every
        // rank computes the same size-aware choice locally.
        let decision =
            self.core()
                .coll_policy
                .decide(CollOp::Reduce, self.size(), Some(t.byte_len()));
        self.submit(desc, move |core| {
            let ring = resolve_algo(
                core,
                CollOp::Reduce,
                TagKind::Reduce,
                seq,
                root,
                decision,
                None,
            )?;
            if ring {
                ring_reduce(core, t, root, op, seq)
            } else {
                reduce_impl(core, t, root, op, make_tag(TagKind::Reduce, seq))
            }
        })
    }

    /// Blocking reduce (returns the reduction at root, `None` elsewhere).
    pub fn reduce(&self, t: Tensor, root: usize, op: ReduceOp) -> CclResult<Option<Tensor>> {
        self.ireduce(t, root, op).wait()
    }

    // -------------------------------------------------------- all_reduce

    /// Async all-reduce. Flat = reduce to rank 0 then broadcast; ring =
    /// pipelined reduce-scatter + all-gather. Resolves to the reduced
    /// tensor on every rank.
    ///
    /// All ranks must contribute identically-shaped f32 tensors (CCL
    /// contract). Violating it is detected where possible (shape check
    /// at the flat root, chunk-length check on the ring), but under
    /// `Auto` a size mismatch can also make ranks pick different
    /// algorithms, which — like NCCL with mismatched collective calls —
    /// stalls until `op_timeout` (set one to get a clean error).
    pub fn iall_reduce(&self, t: Tensor, op: ReduceOp) -> Work {
        let desc = format!("all_reduce {op:?} world={}", self.name());
        if self.size() == 1 {
            return Work::done(desc, Some(t));
        }
        let seq = self.core().next_seq();
        // All ranks must supply identically-shaped tensors (CCL
        // contract), so byte_len is the same everywhere and Auto's
        // choice is consistent across the world.
        let decision =
            self.core()
                .coll_policy
                .decide(CollOp::AllReduce, self.size(), Some(t.byte_len()));
        self.submit(desc, move |core| {
            let ring = resolve_algo(
                core,
                CollOp::AllReduce,
                TagKind::AllReduce,
                seq,
                0,
                decision,
                None,
            )?;
            if ring {
                return ring_all_reduce(core, t, op, seq).map(Some);
            }
            let rtag = make_tag(TagKind::AllReduce, seq * 2);
            let btag = make_tag(TagKind::AllReduce, seq * 2 + 1);
            let reduced = reduce_impl(core, t, 0, op, rtag)?;
            broadcast_impl(core, reduced, 0, btag).map(Some)
        })
    }

    /// Blocking all-reduce.
    pub fn all_reduce(&self, t: Tensor, op: ReduceOp) -> CclResult<Tensor> {
        self.iall_reduce(t, op)
            .wait()?
            .ok_or_else(|| CclError::Transport("all_reduce returned no tensor".into()))
    }

    // ------------------------------------------------------------ gather

    /// Async gather: root's Work resolves to the rank-order concatenation
    /// along axis 0; contributions must share trailing dims. Flat =
    /// `N−1` streams into the root; ring = contributions circulate
    /// hop-by-hop toward the root.
    pub fn igather(&self, t: Tensor, root: usize) -> Work {
        let desc = format!("gather root={root} world={}", self.name());
        if root >= self.size() {
            return Work::failed(desc, CclError::InvalidUsage(format!("bad root {root}")));
        }
        if self.size() == 1 {
            return Work::done(desc, Some(t));
        }
        let seq = self.core().next_seq();
        // Contributions may differ per rank, so no rank can compute a
        // size-aware choice alone; the root estimates the gathered total
        // from its own contribution — clamped by the largest
        // contribution seen on a previous gather of this world, so a
        // small-contribution root stops under-estimating skewed loads
        // after the first invocation — and negotiates.
        let decision = self.core().coll_policy.decide(CollOp::Gather, self.size(), None);
        let root_bytes = Some(
            t.byte_len()
                .max(self.core().max_contrib(CollOp::Gather))
                .saturating_mul(self.size()),
        );
        self.submit(desc, move |core| {
            core.note_contrib(CollOp::Gather, t.byte_len());
            let ring = resolve_algo(
                core,
                CollOp::Gather,
                TagKind::Gather,
                seq,
                root,
                decision,
                root_bytes,
            )?;
            if ring {
                ring_gather(core, t, root, seq)
            } else {
                gather_impl(core, t, root, make_tag(TagKind::Gather, seq), CollOp::Gather)
            }
        })
    }

    /// Blocking gather.
    pub fn gather(&self, t: Tensor, root: usize) -> CclResult<Option<Tensor>> {
        self.igather(t, root).wait()
    }

    // -------------------------------------------------------- all_gather

    /// Async all-gather: every rank resolves to the rank-order
    /// concatenation. Flat = gather to rank 0 then broadcast; ring =
    /// each contribution circulates `size − 1` hops.
    pub fn iall_gather(&self, t: Tensor) -> Work {
        let desc = format!("all_gather world={}", self.name());
        if self.size() == 1 {
            return Work::done(desc, Some(t));
        }
        let seq = self.core().next_seq();
        // Contributions may differ in size per rank; rank 0 acts as the
        // negotiation root, estimating the gathered total from its own
        // contribution clamped by the largest contribution seen on a
        // previous all_gather of this world (skewed-size protection,
        // same as gather).
        let decision = self.core().coll_policy.decide(CollOp::AllGather, self.size(), None);
        let root_bytes = Some(
            t.byte_len()
                .max(self.core().max_contrib(CollOp::AllGather))
                .saturating_mul(self.size()),
        );
        self.submit(desc, move |core| {
            core.note_contrib(CollOp::AllGather, t.byte_len());
            let ring = resolve_algo(
                core,
                CollOp::AllGather,
                TagKind::AllGather,
                seq,
                0,
                decision,
                root_bytes,
            )?;
            if ring {
                return ring_all_gather(core, t, seq).map(Some);
            }
            let gtag = make_tag(TagKind::AllGather, seq * 2);
            let btag = make_tag(TagKind::AllGather, seq * 2 + 1);
            let gathered = gather_impl(core, t, 0, gtag, CollOp::AllGather)?;
            broadcast_impl(core, gathered, 0, btag).map(Some)
        })
    }

    /// Blocking all-gather.
    pub fn all_gather(&self, t: Tensor) -> CclResult<Tensor> {
        self.iall_gather(t)
            .wait()?
            .ok_or_else(|| CclError::Transport("all_gather returned no tensor".into()))
    }

    // ----------------------------------------------------------- scatter

    /// Async scatter: root supplies one tensor per rank (in rank order);
    /// every rank's Work resolves to its part. Non-roots pass `None`.
    pub fn iscatter(&self, parts: Option<Vec<Tensor>>, root: usize) -> Work {
        let desc = format!("scatter root={root} world={}", self.name());
        if root >= self.size() {
            return Work::failed(desc, CclError::InvalidUsage(format!("bad root {root}")));
        }
        let me = self.rank();
        if me == root {
            match &parts {
                Some(p) if p.len() == self.size() => {}
                Some(p) => {
                    return Work::failed(
                        desc,
                        CclError::InvalidUsage(format!(
                            "scatter needs {} parts, got {}",
                            self.size(),
                            p.len()
                        )),
                    )
                }
                None => {
                    return Work::failed(
                        desc,
                        CclError::InvalidUsage("root must supply parts".into()),
                    )
                }
            }
        }
        if self.size() == 1 {
            return Work::done(desc, parts.map(|mut p| p.remove(0)));
        }
        let seq = self.core().next_seq();
        // Only the root holds the parts, so it resolves the size-aware
        // choice from the real total and announces it in the prologue.
        let decision = self.core().coll_policy.decide(CollOp::Scatter, self.size(), None);
        let root_bytes = parts
            .as_ref()
            .map(|p| p.iter().map(|t| t.byte_len()).sum::<usize>());
        self.submit(desc, move |core| {
            let ring = resolve_algo(
                core,
                CollOp::Scatter,
                TagKind::Scatter,
                seq,
                root,
                decision,
                root_bytes,
            )?;
            if ring {
                ring_scatter(core, parts, root, seq).map(Some)
            } else {
                scatter_impl(core, parts, root, make_tag(TagKind::Scatter, seq)).map(Some)
            }
        })
    }

    /// Blocking scatter.
    pub fn scatter(&self, parts: Option<Vec<Tensor>>, root: usize) -> CclResult<Tensor> {
        self.iscatter(parts, root)
            .wait()?
            .ok_or_else(|| CclError::Transport("scatter returned no tensor".into()))
    }
}

// ------------------------------------------------------- algo negotiation

/// Turn a policy decision into the concrete flat-vs-ring choice for one
/// invocation, and record it for `World::last_algo`.
///
/// `Flat`/`Ring` pass straight through (every rank computed the same
/// decision from shared inputs). `Negotiate` means only the root can
/// size the payload: the root resolves flat-vs-ring from `root_bytes`
/// (its real or estimated byte count) and fans the one-byte verdict out
/// flat on the op tag's *prologue* lane — `size − 1` 18-byte frames,
/// cheap even when the verdict is "stay flat" — and every other rank
/// blocks for it (under `op_timeout`) before touching the data path.
fn resolve_algo(
    core: &WorldCore,
    op: CollOp,
    kind: TagKind,
    seq: u64,
    root: usize,
    decision: AlgoDecision,
    root_bytes: Option<usize>,
) -> CclResult<bool> {
    let ring = match decision {
        AlgoDecision::Flat => false,
        AlgoDecision::Ring => true,
        AlgoDecision::Negotiate => {
            let tag = make_tag(kind, seq);
            if core.rank == root {
                let bytes = root_bytes.ok_or_else(|| {
                    CclError::InvalidUsage("negotiated op missing root payload size".into())
                })?;
                let ring = core.coll_policy.ring_for_bytes(op, core.size, bytes);
                for peer in 0..core.size {
                    if peer != root {
                        core.send_algo_prologue(peer, tag, ring)?;
                    }
                }
                ring
            } else {
                core.recv_algo_prologue(root, tag)?
            }
        }
    };
    core.note_algo(op, ring);
    Ok(ring)
}

// ------------------------------------------------------------- flat impls

fn broadcast_impl(
    core: &WorldCore,
    t: Option<Tensor>,
    root: usize,
    wire: u64,
) -> CclResult<Tensor> {
    if core.rank == root {
        let t = t.ok_or_else(|| CclError::InvalidUsage("root must supply tensor".into()))?;
        for peer in 0..core.size {
            if peer != root {
                core.send_tensor(peer, wire, &t)?;
            }
        }
        Ok(t)
    } else {
        core.recv_tensor(root, wire)
    }
}

/// Root-side receives are arrival-order, the fold is **rank-order**:
/// all peer receives are outstanding at once (the receiver threads are
/// always draining into the per-link inboxes) and whichever
/// contribution lands next is parked in its rank's slot, so a straggler
/// delays only itself — but the accumulator only ever advances through
/// ranks `0, 1, …, N−1` as the next-in-order slot fills. Floating-point
/// reduction order is thus a function of the *inputs*, never of network
/// timing: the flat result is bitwise-reproducible run to run (the
/// regression in `tests/collectives_scale.rs` pins this under
/// adversarial, fault-injected arrival orders).
///
/// Idle waiting parks on one pending link's inbox condvar (rotating
/// through them with a short timeout) rather than busy-polling — an
/// arrival on the parked link wakes the sweep immediately; arrivals
/// elsewhere are picked up on the next rotation.
fn reduce_impl(
    core: &WorldCore,
    t: Tensor,
    root: usize,
    op: ReduceOp,
    wire: u64,
) -> CclResult<Option<Tensor>> {
    if core.rank != root {
        core.send_tensor(root, wire, &t)?;
        return Ok(None);
    }
    if t.dtype() != DType::F32 {
        return Err(CclError::InvalidUsage("reduce requires f32 tensors".into()));
    }
    let n = core.size;
    let (shape, dtype) = (t.shape().to_vec(), t.dtype());
    // Rank-indexed slot table; the root's own contribution pre-fills its
    // slot so the fold order is plain rank order, root included.
    let mut slots: Vec<Option<Tensor>> = (0..n).map(|_| None).collect();
    slots[root] = Some(t);
    let mut acc: Option<Tensor> = None;
    let mut next_fold = 0usize;
    let mut fold_ready = |slots: &mut [Option<Tensor>], acc: &mut Option<Tensor>| {
        while next_fold < n {
            let Some(part) = slots[next_fold].take() else { break };
            match acc {
                None => *acc = Some(part),
                Some(a) => match op {
                    ReduceOp::Sum | ReduceOp::Avg => a.add_assign(&part),
                    ReduceOp::Max => a.max_assign(&part),
                },
            }
            next_fold += 1;
        }
    };
    fold_ready(&mut slots, &mut acc);
    let park = |peer: usize, bytes: Vec<u8>| -> CclResult<Tensor> {
        let part = read_tensor(&mut bytes.as_slice()).map_err(|e| {
            CclError::Transport(format!("bad tensor frame from {peer}: {e}"))
        })?;
        core.recycle(peer, bytes);
        if part.shape() != shape.as_slice() || part.dtype() != dtype {
            return Err(CclError::InvalidUsage(format!(
                "reduce shape mismatch: {:?} vs {:?} from rank {peer}",
                shape,
                part.shape()
            )));
        }
        Ok(part)
    };
    const PARK: std::time::Duration = std::time::Duration::from_millis(1);
    let mut pending: Vec<usize> = (0..n).filter(|&p| p != root).collect();
    let deadline = core.op_timeout.map(|d| std::time::Instant::now() + d);
    while !pending.is_empty() {
        // Sweep: slot everything that has already arrived, any order.
        let mut progressed = false;
        let mut i = 0;
        while i < pending.len() {
            let peer = pending[i];
            match core.link(peer)?.try_recv(wire)? {
                Some(bytes) => {
                    slots[peer] = Some(park(peer, bytes)?);
                    pending.swap_remove(i);
                    progressed = true;
                }
                None => i += 1,
            }
        }
        if progressed {
            fold_ready(&mut slots, &mut acc);
            continue;
        }
        if let Some(d) = deadline {
            if std::time::Instant::now() >= d {
                return Err(CclError::Timeout(format!(
                    "reduce: still waiting on ranks {pending:?}"
                )));
            }
        }
        // Nothing ready: park briefly on one pending link's condvar.
        let peer = pending[0];
        match core.link(peer)?.recv(wire, Some(PARK)) {
            Ok(bytes) => {
                slots[peer] = Some(park(peer, bytes)?);
                pending.remove(0);
                fold_ready(&mut slots, &mut acc);
            }
            Err(CclError::Timeout(_)) => pending.rotate_left(1),
            Err(e) => return Err(e),
        }
    }
    fold_ready(&mut slots, &mut acc);
    let mut acc = acc.expect("every slot folded");
    if op == ReduceOp::Avg {
        acc.scale(1.0 / n as f32);
    }
    Ok(Some(acc))
}

/// `op` names the collective this gather serves (gather itself, or the
/// flat all_gather's gather phase) so the root can record the observed
/// per-rank contribution sizes for the next invocation's Auto estimate.
fn gather_impl(
    core: &WorldCore,
    t: Tensor,
    root: usize,
    wire: u64,
    op: CollOp,
) -> CclResult<Option<Tensor>> {
    if core.rank == root {
        let mut parts: Vec<Option<Tensor>> = (0..core.size).map(|_| None).collect();
        parts[root] = Some(t);
        for peer in 0..core.size {
            if peer == root {
                continue;
            }
            let part = core.recv_tensor(peer, wire)?;
            core.note_contrib(op, part.byte_len());
            parts[peer] = Some(part);
        }
        let parts: Vec<Tensor> = parts.into_iter().map(|p| p.unwrap()).collect();
        let cat = Tensor::concat(&parts)
            .map_err(|e| CclError::InvalidUsage(format!("gather concat: {e}")))?;
        Ok(Some(cat))
    } else {
        core.send_tensor(root, wire, &t)?;
        Ok(None)
    }
}

fn scatter_impl(
    core: &WorldCore,
    parts: Option<Vec<Tensor>>,
    root: usize,
    wire: u64,
) -> CclResult<Tensor> {
    if core.rank == root {
        let mut parts = parts.unwrap(); // validated at submit
        for peer in 0..core.size {
            if peer == root {
                continue;
            }
            core.send_tensor(peer, wire, &parts[peer])?;
        }
        // Take the root's part out of the vec — no tensor clone.
        Ok(parts.swap_remove(root))
    } else {
        core.recv_tensor(root, wire)
    }
}

// ------------------------------------------------------------- ring impls

/// Successor on the ring.
#[inline]
fn ring_next(core: &WorldCore) -> usize {
    (core.rank + 1) % core.size
}

/// Predecessor on the ring.
#[inline]
fn ring_prev(core: &WorldCore) -> usize {
    (core.rank + core.size - 1) % core.size
}

/// Number of [`RING_CHUNK`] messages covering `len` bytes (0 for 0).
#[inline]
fn chunks_of(len: usize) -> usize {
    len.div_ceil(RING_CHUNK)
}

/// Byte bounds of chunk `c` within `[off, off + len)`.
#[inline]
fn chunk_bounds(off: usize, len: usize, c: usize) -> (usize, usize) {
    let lo = off + c * RING_CHUNK;
    let hi = off + len.min((c + 1) * RING_CHUNK);
    (lo, hi)
}

/// Element-wise fold of little-endian f32 words: `dst ← dst ⊕ src`.
/// Operates on byte slices so pooled (byte-aligned) wire buffers need no
/// alignment guarantees.
fn fold_f32(dst: &mut [u8], src: &[u8], op: ReduceOp) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, s) in dst.chunks_exact_mut(4).zip(src.chunks_exact(4)) {
        let a = f32::from_le_bytes(d.try_into().unwrap());
        let b = f32::from_le_bytes(s.try_into().unwrap());
        let v = match op {
            ReduceOp::Sum | ReduceOp::Avg => a + b,
            ReduceOp::Max => a.max(b),
        };
        d.copy_from_slice(&v.to_le_bytes());
    }
}

/// Byte bounds `(offset, len)` of per-rank slice `i` when `elems` f32
/// elements are cut into `n` contiguous slices: the first `elems % n`
/// slices get one extra element, so any size divides cleanly.
#[inline]
fn rank_slice_bytes(elems: usize, n: usize, i: usize) -> (usize, usize) {
    let (base, extra) = (elems / n, elems % n);
    let start = i * base + i.min(extra);
    let len = base + usize::from(i < extra);
    (start * 4, len * 4)
}

/// One ring step: send the outgoing byte slice to the ring successor as
/// a [`RING_CHUNK`] train, then receive the incoming slice's chunks in
/// order — folding them when `fold` is set (reduce-scatter) or
/// overwriting (all-gather). The sends never block on the peer's op
/// order (its reader thread always drains), so chunk c+1 is in flight
/// while chunk c is applied.
#[allow(clippy::too_many_arguments)]
fn ring_step(
    core: &WorldCore,
    t: &mut Tensor,
    kind: TagKind,
    seq: u64,
    step: usize,
    send_slice: (usize, usize),
    recv_slice: (usize, usize),
    fold: Option<ReduceOp>,
) -> CclResult<()> {
    let next = ring_next(core);
    let prev = ring_prev(core);
    let (so, sl) = send_slice;
    let (ro, rl) = recv_slice;
    for c in 0..chunks_of(sl) {
        let (lo, hi) = chunk_bounds(so, sl, c);
        let tag = make_chunk_tag(kind, seq, step, c);
        core.send_bytes(next, tag, &[&t.bytes()[lo..hi]])?;
    }
    for c in 0..chunks_of(rl) {
        let tag = make_chunk_tag(kind, seq, step, c);
        let buf = core.recv_bytes(prev, tag)?;
        let (lo, hi) = chunk_bounds(ro, rl, c);
        if buf.len() != hi - lo {
            return Err(CclError::InvalidUsage(format!(
                "ring chunk length mismatch from rank {prev}: {} vs {} \
                 (peers must pass identically-shaped tensors)",
                buf.len(),
                hi - lo
            )));
        }
        match fold {
            Some(op) => fold_f32(&mut t.bytes_mut()[lo..hi], &buf, op),
            None => t.bytes_mut()[lo..hi].copy_from_slice(&buf),
        }
        core.recycle(prev, buf);
    }
    Ok(())
}

/// The chunked reduce-scatter phase shared by ring all-reduce and ring
/// reduce: `N−1` steps, each folding one incoming per-rank slice. On
/// return, rank `r` holds the fully-reduced slice `(r+1) mod N` (Avg
/// scaling still pending — see [`scale_slice`]).
fn ring_reduce_scatter(
    core: &WorldCore,
    t: &mut Tensor,
    op: ReduceOp,
    kind: TagKind,
    seq: u64,
) -> CclResult<()> {
    let n = core.size;
    let elems = t.elems();
    for s in 0..n - 1 {
        let send_slice = (core.rank + n - s) % n;
        let recv_slice = (core.rank + n - s - 1) % n;
        ring_step(
            core,
            t,
            kind,
            seq,
            s,
            rank_slice_bytes(elems, n, send_slice),
            rank_slice_bytes(elems, n, recv_slice),
            Some(op),
        )?;
    }
    Ok(())
}

/// Scale the f32 words in `t.bytes_mut()[off..off+len]` by `factor`
/// (Avg's divide-by-N, applied to the owned slice only).
fn scale_slice(t: &mut Tensor, off: usize, len: usize, factor: f32) {
    for d in t.bytes_mut()[off..off + len].chunks_exact_mut(4) {
        let v = f32::from_le_bytes(d.try_into().unwrap()) * factor;
        d.copy_from_slice(&v.to_le_bytes());
    }
}

/// Bandwidth-optimal ring all-reduce: reduce-scatter then all-gather,
/// `2·(N−1)` steps, each moving one per-rank slice as a train of
/// [`RING_CHUNK`] messages. Receives fold chunk `k` while chunk `k+1`
/// is still in flight (the link reader threads never stop draining).
///
/// After the reduce-scatter, rank `r` owns the fully-reduced slice
/// `(r+1) mod N`; the all-gather circulates the owned slices until every
/// rank has the whole tensor.
fn ring_all_reduce(core: &WorldCore, mut t: Tensor, op: ReduceOp, seq: u64) -> CclResult<Tensor> {
    if t.dtype() != DType::F32 {
        return Err(CclError::InvalidUsage("all_reduce requires f32 tensors".into()));
    }
    let n = core.size;
    let elems = t.elems();

    // ---- phase 1: reduce-scatter (steps 0 .. N-1) ----
    ring_reduce_scatter(core, &mut t, op, TagKind::AllReduce, seq)?;

    // Averaging divides the owned (fully-reduced) slice only — the other
    // slices are overwritten by already-averaged data in phase 2.
    if op == ReduceOp::Avg {
        let owned = (core.rank + 1) % n;
        let (oo, ol) = rank_slice_bytes(elems, n, owned);
        scale_slice(&mut t, oo, ol, 1.0 / n as f32);
    }

    // ---- phase 2: all-gather (steps N-1 .. 2N-3) ----
    for s in 0..n - 1 {
        let send_slice = (core.rank + 1 + n - s) % n;
        let recv_slice = (core.rank + n - s) % n;
        ring_step(
            core,
            &mut t,
            TagKind::AllReduce,
            seq,
            (n - 1) + s,
            rank_slice_bytes(elems, n, send_slice),
            rank_slice_bytes(elems, n, recv_slice),
            None,
        )?;
    }
    Ok(t)
}

/// Ring reduce: the same chunked reduce-scatter as ring all-reduce —
/// fold work and bytes spread across every NIC — then each rank ships
/// its fully-reduced slice straight to the root (step `N−1`, reusing
/// the chunk-tag scheme), so the root's NIC ingests `~S/N` from each of
/// `N−1` peers concurrently (≈ S total) instead of the flat star's
/// `(N−1)·S`.
fn ring_reduce(
    core: &WorldCore,
    mut t: Tensor,
    root: usize,
    op: ReduceOp,
    seq: u64,
) -> CclResult<Option<Tensor>> {
    if t.dtype() != DType::F32 {
        return Err(CclError::InvalidUsage("reduce requires f32 tensors".into()));
    }
    let n = core.size;
    let elems = t.elems();
    ring_reduce_scatter(core, &mut t, op, TagKind::Reduce, seq)?;
    let owned = (core.rank + 1) % n;
    let (oo, ol) = rank_slice_bytes(elems, n, owned);
    if op == ReduceOp::Avg {
        scale_slice(&mut t, oo, ol, 1.0 / n as f32);
    }
    // Slice hand-off to the root: a step index past the reduce-scatter's
    // 0..N-2 keeps the tags disjoint; per-link inboxes keep the same tag
    // distinct across peers.
    let handoff = n - 1;
    if core.rank != root {
        for c in 0..chunks_of(ol) {
            let (lo, hi) = chunk_bounds(oo, ol, c);
            let tag = make_chunk_tag(TagKind::Reduce, seq, handoff, c);
            core.send_bytes(root, tag, &[&t.bytes()[lo..hi]])?;
        }
        return Ok(None);
    }
    for peer in 0..n {
        if peer == root {
            continue;
        }
        let (ro, rl) = rank_slice_bytes(elems, n, (peer + 1) % n);
        for c in 0..chunks_of(rl) {
            let tag = make_chunk_tag(TagKind::Reduce, seq, handoff, c);
            let buf = core.recv_bytes(peer, tag)?;
            let (lo, hi) = chunk_bounds(ro, rl, c);
            if buf.len() != hi - lo {
                return Err(CclError::InvalidUsage(format!(
                    "reduce slice length mismatch from rank {peer}: {} vs {} \
                     (peers must pass identically-shaped tensors)",
                    buf.len(),
                    hi - lo
                )));
            }
            t.bytes_mut()[lo..hi].copy_from_slice(&buf);
            core.recycle(peer, buf);
        }
    }
    Ok(Some(t))
}

/// Pipelined ring broadcast: the serialized tensor travels the ring
/// root → root+1 → … → root+N−1 as [`RING_CHUNK`]-sized chunk messages.
/// Every non-terminal rank forwards chunk `k` *before* appending it
/// locally, so all hops stream concurrently and the added latency per
/// extra rank is one chunk, not one tensor. Chunk 0 is an 8-byte
/// prologue carrying the total length so receivers preallocate once and
/// know the chunk count up front.
fn ring_broadcast(
    core: &WorldCore,
    t: Option<Tensor>,
    root: usize,
    seq: u64,
) -> CclResult<Tensor> {
    let n = core.size;
    let next = ring_next(core);
    let prev = ring_prev(core);
    // Position along the chain measured from the root; the last rank
    // (pos == n-1) must not forward back into the root.
    let pos = (core.rank + n - root) % n;
    let tag = |c: usize| make_chunk_tag(TagKind::Broadcast, seq, 0, c);

    if core.rank == root {
        let t = t.ok_or_else(|| CclError::InvalidUsage("root must supply tensor".into()))?;
        let hdr = encode_header(&t)
            .map_err(|e| CclError::InvalidUsage(format!("unserializable tensor: {e}")))?;
        let total = hdr.len() + t.byte_len();
        core.send_bytes(next, tag(0), &[&(total as u64).to_le_bytes()])?;
        // Chunk the virtual stream [header | payload] without copying.
        for c in 0..chunks_of(total) {
            let (lo, hi) = chunk_bounds(0, total, c);
            let h = hdr.len();
            if hi <= h {
                core.send_bytes(next, tag(c + 1), &[&hdr[lo..hi]])?;
            } else if lo >= h {
                core.send_bytes(next, tag(c + 1), &[&t.bytes()[lo - h..hi - h]])?;
            } else {
                core.send_bytes(next, tag(c + 1), &[&hdr[lo..], &t.bytes()[..hi - h]])?;
            }
        }
        return Ok(t);
    }

    let forward = pos != n - 1;
    let meta = core.recv_bytes(prev, tag(0))?;
    if meta.len() != 8 {
        return Err(CclError::Transport(format!(
            "broadcast prologue: expected 8 bytes, got {}",
            meta.len()
        )));
    }
    let total = u64::from_le_bytes(meta.as_slice().try_into().unwrap()) as usize;
    if forward {
        core.send_bytes(next, tag(0), &[&meta])?;
    }
    core.recycle(prev, meta);
    let mut buf = Vec::with_capacity(total);
    for c in 0..chunks_of(total) {
        let chunk = core.recv_bytes(prev, tag(c + 1))?;
        if forward {
            // Forward first: downstream starts on chunk k while we are
            // still assembling it.
            core.send_bytes(next, tag(c + 1), &[&chunk])?;
        }
        buf.extend_from_slice(&chunk);
        core.recycle(prev, chunk);
    }
    if buf.len() != total {
        return Err(CclError::Transport(format!(
            "broadcast stream truncated: {} of {total} bytes",
            buf.len()
        )));
    }
    read_tensor(&mut buf.as_slice())
        .map_err(|e| CclError::Transport(format!("bad broadcast tensor: {e}")))
}

/// Ring all-gather: each rank's serialized contribution circulates
/// `N−1` hops (store-and-forward per hop, all ranks transferring
/// concurrently each step), then parts concatenate in rank order —
/// byte-identical to the flat gather+broadcast result, including
/// per-rank contributions of differing axis-0 lengths.
fn ring_all_gather(core: &WorldCore, t: Tensor, seq: u64) -> CclResult<Tensor> {
    let n = core.size;
    let next = ring_next(core);
    let prev = ring_prev(core);
    let mut parts: Vec<Option<Vec<u8>>> = (0..n).map(|_| None).collect();
    let mut mine = Vec::with_capacity(crate::tensor::HEADER_LEN + t.byte_len());
    write_tensor(&mut mine, &t)
        .map_err(|e| CclError::InvalidUsage(format!("unserializable tensor: {e}")))?;
    parts[core.rank] = Some(mine);
    for s in 0..n - 1 {
        let send_idx = (core.rank + n - s) % n;
        let recv_idx = (core.rank + n - s - 1) % n;
        let tag = make_chunk_tag(TagKind::AllGather, seq, s, 0);
        core.send_bytes(next, tag, &[parts[send_idx].as_deref().unwrap()])?;
        parts[recv_idx] = Some(core.recv_bytes(prev, tag)?);
    }
    let mut tensors = Vec::with_capacity(n);
    for (i, p) in parts.iter().enumerate() {
        let bytes = p.as_deref().unwrap();
        let t = read_tensor(&mut &*bytes).map_err(|e| {
            CclError::Transport(format!("bad all_gather tensor from rank {i}: {e}"))
        })?;
        core.note_contrib(CollOp::AllGather, t.byte_len());
        tensors.push(t);
    }
    let cat = Tensor::concat(&tensors)
        .map_err(|e| CclError::InvalidUsage(format!("all_gather concat: {e}")))?;
    // Everything except our own serialization came off the wire; give
    // those buffers back to the inbound link's pool.
    for (i, p) in parts.into_iter().enumerate() {
        if i == core.rank {
            continue;
        }
        if let Some(b) = p {
            core.recycle(prev, b);
        }
    }
    Ok(cat)
}

/// Ring gather: serialized contributions hop rank → rank *toward* the
/// root (every non-root sends to its ring predecessor and relays what
/// its successor hands it), so the root drains one pipelined stream
/// from its successor — every hop transferring concurrently each step —
/// instead of `N−1` separate root streams. Per-rank contributions may
/// differ in size (same contract as flat gather); transports segment
/// each hop into [`SEG_MAX`] frames.
///
/// Step schedule: the rank at ring position `p` (distance from the
/// root) relays the contributions of positions `p..N-1`, own first; its
/// step-`s` send carries position `p+s`, so the root's step-`s` receive
/// is position `1+s`.
fn ring_gather(core: &WorldCore, t: Tensor, root: usize, seq: u64) -> CclResult<Option<Tensor>> {
    let n = core.size;
    let next = ring_next(core);
    let prev = ring_prev(core);
    let pos = (core.rank + n - root) % n;
    let tag = |s: usize| make_chunk_tag(TagKind::Gather, seq, s, 0);

    if core.rank == root {
        let mut parts: Vec<Option<Tensor>> = (0..n).map(|_| None).collect();
        parts[root] = Some(t);
        for s in 0..n - 1 {
            let from_rank = (root + 1 + s) % n;
            let bytes = core.recv_bytes(next, tag(s))?;
            let part = read_tensor(&mut bytes.as_slice()).map_err(|e| {
                CclError::Transport(format!("bad gather tensor from rank {from_rank}: {e}"))
            })?;
            core.recycle(next, bytes);
            core.note_contrib(CollOp::Gather, part.byte_len());
            parts[from_rank] = Some(part);
        }
        let parts: Vec<Tensor> = parts.into_iter().map(|p| p.unwrap()).collect();
        let cat = Tensor::concat(&parts)
            .map_err(|e| CclError::InvalidUsage(format!("gather concat: {e}")))?;
        return Ok(Some(cat));
    }

    let mut mine = Vec::with_capacity(crate::tensor::HEADER_LEN + t.byte_len());
    write_tensor(&mut mine, &t)
        .map_err(|e| CclError::InvalidUsage(format!("unserializable tensor: {e}")))?;
    let sends = n - pos; // own contribution + everything upstream of us
    let mut carry = mine;
    for s in 0..sends {
        core.send_bytes(prev, tag(s), &[&carry])?;
        let spent = std::mem::take(&mut carry);
        if s > 0 {
            // Everything after our own serialization came off the wire;
            // give it back to the inbound link's pool.
            core.recycle(next, spent);
        }
        if s + 1 < sends {
            carry = core.recv_bytes(next, tag(s))?;
        }
    }
    Ok(None)
}

/// Ring scatter: the root streams its serialized parts into the ring —
/// furthest destination first — and each rank peels off its own part
/// and forwards the rest (forward-before-parse, so downstream hops
/// overlap), replacing the flat star's `N−1` separate root streams with
/// one pipelined neighbour stream per rank.
///
/// Step schedule mirrors [`ring_gather`] in reverse: the root's step-`s`
/// send carries the part for ring position `N−1−s`; the rank at
/// position `p` receives `N−p` messages, keeps the last (its own part),
/// and forwards the rest under its own step counter.
fn ring_scatter(
    core: &WorldCore,
    parts: Option<Vec<Tensor>>,
    root: usize,
    seq: u64,
) -> CclResult<Tensor> {
    let n = core.size;
    let next = ring_next(core);
    let prev = ring_prev(core);
    let pos = (core.rank + n - root) % n;
    let tag = |s: usize| make_chunk_tag(TagKind::Scatter, seq, s, 0);

    if core.rank == root {
        let mut parts = parts.unwrap(); // validated at submit
        for s in 0..n - 1 {
            let dest = (root + (n - 1 - s)) % n;
            let hdr = encode_header(&parts[dest])
                .map_err(|e| CclError::InvalidUsage(format!("unserializable tensor: {e}")))?;
            core.send_bytes(next, tag(s), &[&hdr, parts[dest].bytes()])?;
        }
        // Take the root's part out of the vec — no tensor clone.
        return Ok(parts.swap_remove(root));
    }

    let recvs = n - pos;
    for s in 0..recvs {
        let buf = core.recv_bytes(prev, tag(s))?;
        if s + 1 < recvs {
            // Not ours: forward first so downstream starts immediately.
            core.send_bytes(next, tag(s), &[&buf])?;
            core.recycle(prev, buf);
        } else {
            let part = read_tensor(&mut buf.as_slice()).map_err(|e| {
                CclError::Transport(format!("bad scatter tensor from rank {prev}: {e}"))
            })?;
            core.recycle(prev, buf);
            return Ok(part);
        }
    }
    unreachable!("non-root ring position receives at least one part")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_arithmetic() {
        assert_eq!(chunks_of(0), 0);
        assert_eq!(chunks_of(1), 1);
        assert_eq!(chunks_of(RING_CHUNK), 1);
        assert_eq!(chunks_of(RING_CHUNK + 1), 2);
        let (lo, hi) = chunk_bounds(100, RING_CHUNK + 7, 1);
        assert_eq!(lo, 100 + RING_CHUNK);
        assert_eq!(hi, 100 + RING_CHUNK + 7);
    }

    #[test]
    fn rank_slices_partition_exactly() {
        for (elems, n) in [(10usize, 4usize), (7, 3), (3, 4), (0, 2), (100_003, 8)] {
            let mut covered = 0usize;
            for i in 0..n {
                let (off, len) = rank_slice_bytes(elems, n, i);
                assert_eq!(off, covered, "slices must be contiguous");
                covered += len;
            }
            assert_eq!(covered, elems * 4, "slices must cover the tensor");
        }
    }

    #[test]
    fn fold_f32_ops() {
        let a: Vec<u8> = [1.0f32, -2.0, 3.5]
            .iter()
            .flat_map(|v| v.to_le_bytes())
            .collect();
        let b: Vec<u8> = [10.0f32, 5.0, -1.0]
            .iter()
            .flat_map(|v| v.to_le_bytes())
            .collect();
        let mut sum = a.clone();
        fold_f32(&mut sum, &b, ReduceOp::Sum);
        let got: Vec<f32> = sum
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        assert_eq!(got, vec![11.0, 3.0, 2.5]);
        let mut mx = a;
        fold_f32(&mut mx, &b, ReduceOp::Max);
        let got: Vec<f32> = mx
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        assert_eq!(got, vec![10.0, 5.0, 3.5]);
    }
}
