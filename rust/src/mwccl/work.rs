//! Asynchronous operation handles.
//!
//! Every collective returns a [`Work`] immediately (like
//! `torch.distributed.isend`/`irecv` with `async_op=True`). The paper's
//! design (§3.2) requires non-blocking CCL operations so one process can
//! service many worlds; `Work` is the unit the MultiWorld communicator's
//! busy-wait poller checks.

use super::error::CclError;
use crate::tensor::Tensor;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Lifecycle of an async op.
#[derive(Clone, Debug)]
pub enum WorkState {
    /// Queued behind earlier ops of the same world.
    Pending,
    /// Executing on the world's progress thread.
    Running,
    /// Finished; receives carry the tensor, sends carry `None`.
    Done(Option<Tensor>),
    /// Failed (remote error, abort, misuse).
    Failed(CclError),
}

struct Inner {
    state: Mutex<WorkState>,
    cv: Condvar,
    desc: String,
}

/// Cloneable handle to one asynchronous collective operation.
#[derive(Clone)]
pub struct Work {
    inner: Arc<Inner>,
}

impl Work {
    /// New pending work (crate-internal: worlds create these).
    pub(crate) fn pending(desc: impl Into<String>) -> Work {
        Work {
            inner: Arc::new(Inner {
                state: Mutex::new(WorkState::Pending),
                cv: Condvar::new(),
                desc: desc.into(),
            }),
        }
    }

    /// A work that is already failed (ops issued on broken worlds).
    pub(crate) fn failed(desc: impl Into<String>, err: CclError) -> Work {
        let w = Work::pending(desc);
        w.fail(err);
        w
    }

    /// A work that is already complete (degenerate ops, e.g. broadcast
    /// in a world of size 1).
    pub(crate) fn done(desc: impl Into<String>, t: Option<Tensor>) -> Work {
        let w = Work::pending(desc);
        w.complete(t);
        w
    }

    pub(crate) fn set_running(&self) {
        let mut st = self.inner.state.lock().unwrap();
        if matches!(*st, WorkState::Pending) {
            *st = WorkState::Running;
        }
    }

    pub(crate) fn complete(&self, t: Option<Tensor>) {
        let mut st = self.inner.state.lock().unwrap();
        if !matches!(*st, WorkState::Done(_) | WorkState::Failed(_)) {
            *st = WorkState::Done(t);
            self.inner.cv.notify_all();
        }
    }

    pub(crate) fn fail(&self, err: CclError) {
        let mut st = self.inner.state.lock().unwrap();
        if !matches!(*st, WorkState::Done(_) | WorkState::Failed(_)) {
            *st = WorkState::Failed(err);
            self.inner.cv.notify_all();
        }
    }

    /// Human-readable description ("irecv src=2 tag=7 world=W3").
    pub fn desc(&self) -> &str {
        &self.inner.desc
    }

    /// True once the op is Done or Failed. This is the cheap probe the
    /// busy-wait poll loop uses.
    pub fn is_completed(&self) -> bool {
        matches!(
            *self.inner.state.lock().unwrap(),
            WorkState::Done(_) | WorkState::Failed(_)
        )
    }

    /// Non-blocking result check: `None` while in flight.
    pub fn poll(&self) -> Option<Result<Option<Tensor>, CclError>> {
        match &*self.inner.state.lock().unwrap() {
            WorkState::Done(t) => Some(Ok(t.clone())),
            WorkState::Failed(e) => Some(Err(e.clone())),
            _ => None,
        }
    }

    /// Block until completion.
    pub fn wait(&self) -> Result<Option<Tensor>, CclError> {
        let mut st = self.inner.state.lock().unwrap();
        loop {
            match &*st {
                WorkState::Done(t) => return Ok(t.clone()),
                WorkState::Failed(e) => return Err(e.clone()),
                _ => {
                    st = self.inner.cv.wait(st).unwrap();
                }
            }
        }
    }

    /// Block with a deadline; `None` on timeout (op still in flight).
    pub fn wait_timeout(&self, timeout: Duration) -> Option<Result<Option<Tensor>, CclError>> {
        let deadline = Instant::now() + timeout;
        let mut st = self.inner.state.lock().unwrap();
        loop {
            match &*st {
                WorkState::Done(t) => return Some(Ok(t.clone())),
                WorkState::Failed(e) => return Some(Err(e.clone())),
                _ => {
                    let now = Instant::now();
                    if now >= deadline {
                        return None;
                    }
                    let (guard, _) = self.inner.cv.wait_timeout(st, deadline - now).unwrap();
                    st = guard;
                }
            }
        }
    }

    /// The failure, if the op failed (PyTorch's `Work.exception()`).
    pub fn exception(&self) -> Option<CclError> {
        match &*self.inner.state.lock().unwrap() {
            WorkState::Failed(e) => Some(e.clone()),
            _ => None,
        }
    }

    /// Snapshot of the current state.
    pub fn state(&self) -> WorkState {
        self.inner.state.lock().unwrap().clone()
    }
}

impl std::fmt::Debug for Work {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Work({} — {:?})", self.desc(), self.state())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle() {
        let w = Work::pending("isend dst=1");
        assert!(!w.is_completed());
        assert!(w.poll().is_none());
        w.set_running();
        assert!(!w.is_completed());
        w.complete(None);
        assert!(w.is_completed());
        assert!(matches!(w.poll(), Some(Ok(None))));
        assert!(w.exception().is_none());
    }

    #[test]
    fn failure_path() {
        let w = Work::pending("irecv src=0");
        w.fail(CclError::WorldBroken("w1".into()));
        assert!(w.is_completed());
        assert!(matches!(w.exception(), Some(CclError::WorldBroken(_))));
        assert!(w.wait().is_err());
    }

    #[test]
    fn terminal_state_is_sticky() {
        let w = Work::pending("op");
        w.complete(None);
        w.fail(CclError::Aborted("late".into()));
        assert!(matches!(w.poll(), Some(Ok(None))), "Done must not be overwritten");
        let w2 = Work::pending("op2");
        w2.fail(CclError::Aborted("first".into()));
        w2.complete(None);
        assert!(w2.exception().is_some(), "Failed must not be overwritten");
    }

    #[test]
    fn wait_blocks_until_complete() {
        let w = Work::pending("op");
        let w2 = w.clone();
        let t = std::thread::spawn(move || w2.wait());
        std::thread::sleep(Duration::from_millis(30));
        assert!(!w.is_completed());
        let tensor = Tensor::from_f32(&[2], &[1.0, 2.0]);
        w.complete(Some(tensor.clone()));
        let got = t.join().unwrap().unwrap().unwrap();
        assert_eq!(got, tensor);
    }

    #[test]
    fn wait_timeout_returns_none_in_flight() {
        let w = Work::pending("op");
        assert!(w.wait_timeout(Duration::from_millis(40)).is_none());
        w.complete(None);
        assert!(w.wait_timeout(Duration::from_millis(10)).is_some());
    }
}
