//! Deterministic network fault injection: the [`FaultLink`] transport
//! wrapper and its seeded chaos plan.
//!
//! The existing chaos tests kill workers *cleanly* — sockets close,
//! `RemoteError` fires, the watchdog converges. The failures that break
//! serving systems in practice are **gray**: a link that stalls but
//! does not die, a frame that vanishes, a sender that crashes
//! mid-message, a one-way partition. This module makes those failures
//! first-class and — crucially — *replayable*: every injection decision
//! is drawn from a [`crate::util::prng::Rng`] seeded by
//! `MW_FAULT_SEED` and the edge's rank pair, so the same seed + plan
//! reproduces the identical injection sequence on every run, regardless
//! of thread scheduling.
//!
//! ## Pieces
//!
//! * [`FaultPlan`] — a list of per-edge [`FaultRule`]s plus the seed.
//!   Installed via [`crate::mwccl::WorldOptions::with_fault_plan`] or
//!   the `MW_FAULT_PLAN` / `MW_FAULT_SEED` environment knobs (grammar
//!   below). When a plan is present, world init wraps every link in a
//!   [`FaultLink`]; without one, the transport stack is untouched (zero
//!   overhead in non-chaos runs).
//! * [`FaultLink`] — implements [`Link`] around any inner link (tcp and
//!   shm alike). Faults apply on the *send* path: what leaves a wrapped
//!   link is delayed, dropped, truncated mid-message, held (stall), or
//!   bandwidth-capped; receivers observe the consequences through the
//!   ordinary transport machinery (timeouts, corrupt-frame detection,
//!   silence).
//! * [`FaultRegistry`] (one per process, [`registry`]) — the runtime
//!   handle: inject/heal rules on **live** links mid-traffic, release
//!   stalls, and read the structured injection event log that tests
//!   assert against (`fault.injected.<kind>` counters carry the same
//!   information as metrics).
//!
//! ## Plan grammar (`MW_FAULT_PLAN`)
//!
//! ```text
//! plan  := rule (';' rule)*
//! rule  := 'edge=' world ':' src '->' dst  item*
//! item  := 'kind=' (delay|drop|truncate|stall|partition|bandwidth)
//!        | 'ms=' u64 | 'bytes=' usize | 'bps=' f64
//!        | 'prob=' f64 | 'after=' u64 | 'count=' u64
//! world := exact name, or glob with leading/trailing '*'
//! src, dst := rank number or '*'
//! ```
//!
//! Example: `edge=*tp-s1r1*:0->1 kind=stall; edge=*:*->* kind=delay
//! ms=2 prob=0.1` — stall the head→shard-1 direction of replica (1,1)'s
//! TP world, and delay 10% of all other sends by 2 ms.
//!
//! ## The store pseudo-edge
//!
//! The per-world TCP store (heartbeats, rendezvous, control keys) is a
//! fault target too: the pseudo-edge `edge=store:*->*` injects the
//! client side of every store request in the process (see
//! [`store_channel_action`]). Matching is **exact-name only** — the
//! `*` world glob (and any other glob) never reaches the store channel,
//! so blanket data-plane chaos plans keep their two-run determinism
//! without surprise watchdog-timed store events; you opt the control
//! plane into chaos by naming it. Kind semantics shift to fit a
//! reliable request/response stream: `delay`/`bandwidth` sleep before
//! the request is written; `drop`/`truncate` model a lost segment — the
//! client pauses one RTO (~200 ms) and then transmits, so the call
//! survives unless its deadline passes; `stall`/`partition` wedge every
//! request until the rule is healed (or stalls released), after which
//! traffic resumes — an unhealed wedge surfaces as store-op timeouts,
//! i.e. a dead-looking store.
//!
//! **Multi-rule semantics: first match wins.** Several rules may match
//! the same directed edge; per send, rules are evaluated in plan order
//! and the *first* one whose `after`/`count`/`prob` gates all pass
//! supplies the verdict — at most one fault applies per message. A
//! later matching rule is *shadowed* for that send: it does **not**
//! burn its `count` budget (though its probability draw *is* consumed —
//! see the determinism contract below), so it takes over intact once
//! every earlier matching rule's budget is exhausted. The one exception
//! is `kind=stall`, which wins categorically regardless of plan
//! position: a wedged link is wedged, whatever else the plan says.
//! Order therefore encodes priority — `kind=drop count=1; kind=delay
//! ms=2` on one edge drops the first send and delays the rest, while
//! the reverse order delays every send and never drops.
//!
//! ## Determinism contract
//!
//! Per-edge decisions depend only on `(seed, src, dst, send index)`:
//! the per-edge RNG is seeded without the world name (so renamed worlds
//! replay identically) and the static rule pass runs **unconditionally
//! on every send** — probability draws are consumed in rule order
//! whether or not a runtime-injected rule overrides the verdict, so
//! dynamic injection can never desynchronize the static stream. Two
//! runs with the same seed and the same *static* plan produce identical
//! per-edge static event sequences — the repeatability the gray-failure
//! suite asserts by comparing two runs' event logs. Dynamic rules fire
//! unconditionally (their `prob` is ignored; no RNG involved).
//!
//! One deliberate modeling choice: [`Link::farewell`] passes through
//! even on stalled/partitioned edges. The farewell stands in for the
//! out-of-band control plane (the per-world store), which stays healthy
//! in these scenarios — suppressing it would conflate data-plane and
//! control-plane failure domains. Store-channel faults are their own
//! explicitly-named pseudo-edge (above) for exactly that reason.

use super::Link;
use crate::mwccl::error::{CclError, CclResult};
use crate::mwccl::wire::{FLAG_LAST, SEG_MAX};
use crate::util::prng::{splitmix64, Rng};
use once_cell::sync::Lazy;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, Weak};
use std::time::Duration;

/// What to do to a matching send.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultKind {
    /// Hold the message for `ms` before forwarding it (slow link).
    Delay { ms: u64 },
    /// Silently discard the message (lost frame: the receiver sees
    /// nothing — no error, no data).
    Drop,
    /// Put a *truncated* message on the wire: `keep` payload bytes
    /// under headers claiming the full length, LAST flag set (a sender
    /// crashing mid-message). `keep == 0` keeps half. The receiver's
    /// inbox detects the contradiction and raises an edge-attributed
    /// `RemoteError`.
    Truncate { keep: usize },
    /// Hold this and every subsequent message on the edge (FIFO) until
    /// the stall is released ([`FaultRegistry::release_stalls`] or the
    /// rule is healed) — a wedged-but-alive link.
    Stall,
    /// Silently discard everything while the rule is active (one-way
    /// partition; configure both directions for a full partition).
    Partition,
    /// Sleep `bytes / bps` seconds per message before forwarding
    /// (bandwidth cap).
    Bandwidth { bps: f64 },
}

impl FaultKind {
    /// Counter/event suffix.
    pub fn name(self) -> &'static str {
        match self {
            FaultKind::Delay { .. } => "delay",
            FaultKind::Drop => "drop",
            FaultKind::Truncate { .. } => "truncate",
            FaultKind::Stall => "stall",
            FaultKind::Partition => "partition",
            FaultKind::Bandwidth { .. } => "bandwidth",
        }
    }
}

/// Which directed edges a rule applies to.
#[derive(Clone, Debug, PartialEq)]
pub struct EdgePattern {
    /// World name: exact, or a glob with leading and/or trailing `*`.
    pub world: String,
    /// Sender rank (`None` = any).
    pub src: Option<usize>,
    /// Receiver rank (`None` = any).
    pub dst: Option<usize>,
}

impl EdgePattern {
    pub fn new(world: &str, src: Option<usize>, dst: Option<usize>) -> EdgePattern {
        EdgePattern { world: world.to_string(), src, dst }
    }

    /// Does this pattern cover the directed edge `src -> dst` of `world`?
    pub fn matches(&self, world: &str, src: usize, dst: usize) -> bool {
        if self.src.is_some_and(|s| s != src) || self.dst.is_some_and(|d| d != dst) {
            return false;
        }
        let p = self.world.as_str();
        if p == "*" {
            return true;
        }
        let (starts, ends) = (p.starts_with('*'), p.ends_with('*'));
        let core = p.trim_start_matches('*').trim_end_matches('*');
        match (starts, ends) {
            (true, true) => world.contains(core),
            (true, false) => world.ends_with(core),
            (false, true) => world.starts_with(core),
            (false, false) => world == core,
        }
    }
}

/// One fault rule: edge pattern + kind + applicability knobs.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultRule {
    pub pattern: EdgePattern,
    pub kind: FaultKind,
    /// Probability a matching send is hit (static rules only; dynamic
    /// rules always fire — see module docs).
    pub prob: f64,
    /// Skip the first `after` sends on the edge.
    pub after: u64,
    /// At most this many injections (ignored by `Stall`, where the
    /// held-queue FIFO governs).
    pub count: u64,
}

impl FaultRule {
    /// A rule that always fires on every matching send.
    pub fn always(pattern: EdgePattern, kind: FaultKind) -> FaultRule {
        FaultRule { pattern, kind, prob: 1.0, after: 0, count: u64::MAX }
    }

    pub fn with_prob(mut self, p: f64) -> FaultRule {
        self.prob = p;
        self
    }

    pub fn with_after(mut self, n: u64) -> FaultRule {
        self.after = n;
        self
    }

    pub fn with_count(mut self, n: u64) -> FaultRule {
        self.count = n;
        self
    }
}

/// The full plan: rules + the seed every per-edge RNG derives from.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    pub rules: Vec<FaultRule>,
    pub seed: u64,
}

impl FaultPlan {
    pub fn new(rules: Vec<FaultRule>, seed: u64) -> FaultPlan {
        FaultPlan { rules, seed }
    }

    /// No static rules, but link wrapping *enabled* — the hook for
    /// purely runtime-driven chaos via [`registry`].
    pub fn empty(seed: u64) -> FaultPlan {
        FaultPlan { rules: Vec::new(), seed }
    }

    /// Parse the `MW_FAULT_PLAN` grammar (see module docs).
    pub fn parse(text: &str, seed: u64) -> Result<FaultPlan, String> {
        let mut rules = Vec::new();
        for rule_s in text.split(';') {
            let rule_s = rule_s.trim();
            if rule_s.is_empty() {
                continue;
            }
            rules.push(Self::parse_rule(rule_s)?);
        }
        Ok(FaultPlan { rules, seed })
    }

    fn parse_rule(s: &str) -> Result<FaultRule, String> {
        let mut pattern: Option<EdgePattern> = None;
        let mut kind_s: Option<String> = None;
        let (mut ms, mut bytes, mut bps) = (10u64, 0usize, 1.0e6f64);
        let (mut prob, mut after, mut count) = (1.0f64, 0u64, u64::MAX);
        for item in s.split_whitespace() {
            let (key, value) = item
                .split_once('=')
                .ok_or_else(|| format!("bad item '{item}' (want key=value)"))?;
            match key {
                "edge" => {
                    let (world, ranks) = value
                        .rsplit_once(':')
                        .ok_or_else(|| format!("bad edge '{value}' (want world:src->dst)"))?;
                    let (src_s, dst_s) = ranks
                        .split_once("->")
                        .ok_or_else(|| format!("bad edge ranks '{ranks}' (want src->dst)"))?;
                    let rank = |t: &str| -> Result<Option<usize>, String> {
                        if t == "*" {
                            Ok(None)
                        } else {
                            t.parse().map(Some).map_err(|_| format!("bad rank '{t}'"))
                        }
                    };
                    pattern = Some(EdgePattern::new(world, rank(src_s)?, rank(dst_s)?));
                }
                "kind" => kind_s = Some(value.to_string()),
                "ms" => ms = value.parse().map_err(|_| format!("bad ms '{value}'"))?,
                "bytes" => bytes = value.parse().map_err(|_| format!("bad bytes '{value}'"))?,
                "bps" => bps = value.parse().map_err(|_| format!("bad bps '{value}'"))?,
                "prob" => prob = value.parse().map_err(|_| format!("bad prob '{value}'"))?,
                "after" => after = value.parse().map_err(|_| format!("bad after '{value}'"))?,
                "count" => count = value.parse().map_err(|_| format!("bad count '{value}'"))?,
                other => return Err(format!("unknown key '{other}'")),
            }
        }
        let pattern = pattern.ok_or_else(|| format!("rule '{s}' missing edge="))?;
        let kind = match kind_s.as_deref() {
            Some("delay") => FaultKind::Delay { ms },
            Some("drop") => FaultKind::Drop,
            Some("truncate") => FaultKind::Truncate { keep: bytes },
            Some("stall") => FaultKind::Stall,
            Some("partition") => FaultKind::Partition,
            Some("bandwidth") => FaultKind::Bandwidth { bps },
            Some(other) => return Err(format!("unknown kind '{other}'")),
            None => return Err(format!("rule '{s}' missing kind=")),
        };
        Ok(FaultRule { pattern, kind, prob, after, count })
    }

    /// Plan from `MW_FAULT_PLAN` + `MW_FAULT_SEED`. `None` when neither
    /// variable is set (no wrapping, zero overhead). A present-but-empty
    /// or unparsable plan still enables wrapping (runtime injection
    /// stays available); parse errors are logged, never fatal.
    pub fn from_env() -> Option<FaultPlan> {
        let plan_s = std::env::var("MW_FAULT_PLAN").ok();
        let seed_s = std::env::var("MW_FAULT_SEED").ok();
        if plan_s.is_none() && seed_s.is_none() {
            return None;
        }
        let seed = seed_s.and_then(|s| s.parse().ok()).unwrap_or(0x5EED);
        match FaultPlan::parse(plan_s.as_deref().unwrap_or(""), seed) {
            Ok(p) => Some(p),
            Err(e) => {
                crate::metrics::log_event("fault.plan_error", &[("error", e.as_str())]);
                Some(FaultPlan::empty(seed))
            }
        }
    }
}

/// One recorded injection. `op` is the edge-local send index the fault
/// hit — the unit of the determinism contract.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FaultEvent {
    pub world: String,
    pub src: usize,
    pub dst: usize,
    pub op: u64,
    pub kind: &'static str,
}

impl FaultEvent {
    /// World-agnostic identity, for comparing runs whose worlds were
    /// named differently (the RNG is world-agnostic too).
    pub fn canon(&self) -> (usize, usize, u64, &'static str) {
        (self.src, self.dst, self.op, self.kind)
    }
}

impl std::fmt::Display for FaultEvent {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "world={} src={} dst={} op={} kind={}",
            self.world, self.src, self.dst, self.op, self.kind
        )
    }
}

/// A message held by a stall, in FIFO order.
enum Held {
    Data { tag: u64, bytes: Vec<u8> },
    Prologue { tag: u64, bytes: Vec<u8> },
}

/// Per-edge deterministic decision state.
struct EdgeRand {
    /// Sends issued on this edge so far (the `op` index).
    sends: u64,
    rng: Rng,
    /// Injections per *static* rule index (enforces `count`).
    injected: Vec<u64>,
}

/// The shared core of one wrapped link (the registry holds a `Weak` so
/// it can flush stalls on live links).
struct FaultLinkShared {
    world: String,
    src: usize,
    dst: usize,
    plan: Arc<FaultPlan>,
    inner: Box<dyn Link>,
    rand: Mutex<EdgeRand>,
    held: Mutex<Vec<Held>>,
    aborted: AtomicBool,
}

/// What `decide` told the send path to do.
enum Verdict {
    Forward,
    Suppress(&'static str),
    Delay(u64),
    Throttle(f64),
    Truncate(usize),
    Hold,
}

impl FaultLinkShared {
    /// Resolve the fault verdict for send `n` of `len` bytes.
    ///
    /// The **static pass runs first and unconditionally**: every
    /// matching static rule's probability draw is consumed on every
    /// send, whether or not a dynamic rule later overrides the verdict
    /// — so the static RNG stream is a pure function of
    /// `(seed, src, dst, n)` and runtime injection can never
    /// desynchronize it (the determinism contract). Dynamic rules then
    /// override: a stall wedges the edge outright; any other kind
    /// replaces the static verdict for this send.
    fn decide(&self, len: usize) -> (u64, Verdict) {
        let reg = registry();
        let (dynamic, stalls_released) = reg.snapshot();
        let mut rand = self.rand.lock().unwrap();
        if rand.injected.len() < self.plan.rules.len() {
            rand.injected.resize(self.plan.rules.len(), 0);
        }
        let n = rand.sends;
        rand.sends += 1;

        let matches =
            |r: &FaultRule| r.pattern.matches(&self.world, self.src, self.dst) && n >= r.after;

        let verdict_of = |kind: FaultKind| match kind {
            FaultKind::Delay { ms } => Verdict::Delay(ms),
            FaultKind::Drop => Verdict::Suppress("drop"),
            FaultKind::Partition => Verdict::Suppress("partition"),
            FaultKind::Bandwidth { bps } => Verdict::Throttle(bps),
            FaultKind::Truncate { keep } => {
                let keep = if keep == 0 { len / 2 } else { keep };
                Verdict::Truncate(keep.min(len.saturating_sub(1)))
            }
            FaultKind::Stall => Verdict::Hold,
        };

        // 1. Static pass — every matching rule is evaluated (and every
        //    probability draw consumed) on every send; the first
        //    non-stall rule that fires supplies the static verdict and
        //    its `count` bookkeeping, identical whether or not dynamic
        //    rules exist. Stall is tracked separately because it wins
        //    categorically below (matching `stall_active`, the flush
        //    predicate — FIFO would invert otherwise).
        let mut static_stall = false;
        let mut static_verdict: Option<Verdict> = None;
        for (i, rule) in self.plan.rules.iter().enumerate() {
            if !matches(rule) {
                continue;
            }
            if rule.kind == FaultKind::Stall {
                static_stall |= !stalls_released;
                continue;
            }
            if rand.injected[i] >= rule.count {
                continue;
            }
            if rule.prob < 1.0 && !rand.rng.chance(rule.prob) {
                continue;
            }
            if static_verdict.is_none() {
                rand.injected[i] += 1;
                static_verdict = Some(verdict_of(rule.kind));
            }
        }

        // 2. A stall (static or dynamic) wins over everything: a wedged
        //    link is wedged.
        let dynamic_stall = !stalls_released
            && dynamic
                .iter()
                .any(|(_, r)| r.kind == FaultKind::Stall && matches(r));
        if static_stall || dynamic_stall {
            return (n, Verdict::Hold);
        }
        // 3. Dynamic (runtime-injected) rules override the static
        //    verdict for this send.
        for (id, rule) in &dynamic {
            if rule.kind != FaultKind::Stall && matches(rule) && reg.try_consume(*id) {
                return (n, verdict_of(rule.kind));
            }
        }
        (n, static_verdict.unwrap_or(Verdict::Forward))
    }

    /// Is a stall currently pinning this edge's held queue?
    fn stall_active(&self) -> bool {
        let reg = registry();
        let (dynamic, stalls_released) = reg.snapshot();
        if stalls_released {
            return false;
        }
        let sends = self.rand.lock().unwrap().sends;
        dynamic
            .iter()
            .map(|(_, r)| r)
            .chain(self.plan.rules.iter())
            .any(|r| {
                r.kind == FaultKind::Stall
                    && r.pattern.matches(&self.world, self.src, self.dst)
                    && sends >= r.after
            })
    }

    /// Deliver held messages in order once no stall pins the edge.
    ///
    /// The queue lock is held across drain *and* forward: a concurrent
    /// sender's backlog check serializes on the same lock, so fresh
    /// traffic can never slip onto the wire between a drained held
    /// message and its actual send (same-tag FIFO would hand the wrong
    /// payload to the wrong receive otherwise). The held forwards may
    /// block on transport backpressure while holding the lock — that is
    /// the point: everything behind them must wait.
    fn flush_if_unstalled(&self) {
        if self.aborted.load(Ordering::Acquire) || self.stall_active() {
            return;
        }
        let mut held = self.held.lock().unwrap();
        for msg in held.drain(..) {
            let _ = match msg {
                Held::Data { tag, bytes } => self.inner.send(tag, &[&bytes]),
                Held::Prologue { tag, bytes } => self.inner.send_prologue(tag, &bytes),
            };
        }
    }

    fn record(&self, op: u64, kind: &'static str) {
        registry().record(FaultEvent {
            world: self.world.clone(),
            src: self.src,
            dst: self.dst,
            op,
            kind,
        });
    }

    /// Shared verdict dispatch for both send paths ([`Link::send`] and
    /// [`Link::send_prologue`] differ only in their forward / hold /
    /// truncate leaves). Keeping this in one place also keeps the
    /// stall-FIFO and race-closing rules identical for data and control
    /// traffic.
    fn dispatch(
        &self,
        len: usize,
        forward: impl FnOnce() -> CclResult<()>,
        hold: impl FnOnce() -> Held,
        truncate: impl FnOnce(usize) -> CclResult<()>,
    ) -> CclResult<()> {
        if self.aborted.load(Ordering::Acquire) {
            return Err(CclError::Aborted("fault link aborted".into()));
        }
        let (n, verdict) = self.decide(len);
        // FIFO: traffic behind a stall queues behind it (head-of-line),
        // and a cleared stall flushes before fresh traffic moves.
        let backlog = !self.held.lock().unwrap().is_empty();
        if backlog && !matches!(verdict, Verdict::Hold) {
            self.flush_if_unstalled();
        }
        match verdict {
            Verdict::Forward => forward(),
            Verdict::Suppress(kind) => {
                self.record(n, kind);
                Ok(())
            }
            Verdict::Delay(ms) => {
                self.record(n, "delay");
                std::thread::sleep(Duration::from_millis(ms));
                forward()
            }
            Verdict::Throttle(bps) => {
                self.record(n, "bandwidth");
                std::thread::sleep(Duration::from_secs_f64(len as f64 / bps.max(1.0)));
                forward()
            }
            Verdict::Truncate(keep) => {
                self.record(n, "truncate");
                truncate(keep)
            }
            Verdict::Hold => {
                self.record(n, "stall");
                self.held.lock().unwrap().push(hold());
                // Close the decide→push window against a concurrent
                // heal()/release_stalls(): their flush may have drained
                // an *empty* queue just before this push, and nothing
                // else would ever deliver the message. Re-checking here
                // guarantees a healed edge cannot strand traffic
                // (flush_if_unstalled no-ops while the stall holds).
                self.flush_if_unstalled();
                Ok(())
            }
        }
    }

    /// Put a truncated rendition of `parts` on the wire: `keep` payload
    /// bytes under headers claiming the full length, final frame
    /// LAST-flagged — indistinguishable on the wire from a sender that
    /// died mid-message. Falls back to a silent drop on transports
    /// without raw-frame support.
    fn send_truncated(&self, tag: u64, parts: &[&[u8]], keep: usize) -> CclResult<()> {
        let total: usize = parts.iter().map(|p| p.len()).sum();
        let mut prefix = Vec::with_capacity(keep);
        for part in parts {
            if prefix.len() >= keep {
                break;
            }
            let take = (keep - prefix.len()).min(part.len());
            prefix.extend_from_slice(&part[..take]);
        }
        let mut off = 0usize;
        while off < prefix.len() || (prefix.is_empty() && off == 0) {
            let hi = (off + SEG_MAX).min(prefix.len());
            let last = hi == prefix.len();
            let flags = if last { FLAG_LAST } else { 0 };
            let sent =
                self.inner
                    .send_raw_frame(tag, &prefix[off..hi], total as u32, flags);
            match sent {
                Ok(()) => {}
                // Transport without raw frames: degrade to a drop (the
                // message is still lost; only the detectability differs).
                Err(CclError::InvalidUsage(_)) => return Ok(()),
                Err(e) => return Err(e),
            }
            if last {
                break;
            }
            off = hi;
        }
        Ok(())
    }
}

/// See module docs.
pub struct FaultLink {
    shared: Arc<FaultLinkShared>,
}

impl FaultLink {
    /// Wrap `inner` as the `src -> dst` direction of `world`'s link and
    /// register it with the process [`registry`] for runtime control.
    pub fn wrap(
        plan: Arc<FaultPlan>,
        world: &str,
        src: usize,
        dst: usize,
        inner: Box<dyn Link>,
    ) -> FaultLink {
        // World-agnostic seeding: decisions replay across runs whose
        // worlds are named differently (see module docs).
        let mut mix = plan
            .seed
            .wrapping_add((src as u64) << 32)
            .wrapping_add(dst as u64);
        let rng = Rng::new(splitmix64(&mut mix));
        let shared = Arc::new(FaultLinkShared {
            world: world.to_string(),
            src,
            dst,
            plan,
            inner,
            rand: Mutex::new(EdgeRand { sends: 0, rng, injected: Vec::new() }),
            held: Mutex::new(Vec::new()),
            aborted: AtomicBool::new(false),
        });
        registry().register_link(Arc::downgrade(&shared));
        FaultLink { shared }
    }
}

/// Wrap every link of a freshly initialized world (rendezvous calls
/// this when the options carry a plan). `my_rank` is the local rank —
/// each wrapped link covers the outgoing `my_rank -> peer` direction.
pub fn wrap_links(
    plan: &Arc<FaultPlan>,
    world: &str,
    my_rank: usize,
    links: HashMap<usize, Box<dyn Link>>,
) -> HashMap<usize, Box<dyn Link>> {
    links
        .into_iter()
        .map(|(peer, inner)| {
            let wrapped = FaultLink::wrap(plan.clone(), world, my_rank, peer, inner);
            (peer, Box::new(wrapped) as Box<dyn Link>)
        })
        .collect()
}

impl Link for FaultLink {
    fn send(&self, tag: u64, parts: &[&[u8]]) -> CclResult<()> {
        let sh = &self.shared;
        let len: usize = parts.iter().map(|p| p.len()).sum();
        sh.dispatch(
            len,
            || sh.inner.send(tag, parts),
            || {
                let mut bytes = Vec::with_capacity(len);
                for p in parts {
                    bytes.extend_from_slice(p);
                }
                Held::Data { tag, bytes }
            },
            |keep| sh.send_truncated(tag, parts, keep),
        )
    }

    fn send_prologue(&self, tag: u64, payload: &[u8]) -> CclResult<()> {
        let sh = &self.shared;
        sh.dispatch(
            payload.len(),
            || sh.inner.send_prologue(tag, payload),
            || Held::Prologue { tag, bytes: payload.to_vec() },
            // A prologue cannot be meaningfully truncated (single
            // frame); losing it is the equivalent failure.
            |_keep| Ok(()),
        )
    }

    fn recv_prologue(&self, tag: u64, timeout: Option<Duration>) -> CclResult<Vec<u8>> {
        self.shared.inner.recv_prologue(tag, timeout)
    }

    fn recv(&self, tag: u64, timeout: Option<Duration>) -> CclResult<Vec<u8>> {
        self.shared.inner.recv(tag, timeout)
    }

    fn try_recv(&self, tag: u64) -> CclResult<Option<Vec<u8>>> {
        self.shared.inner.try_recv(tag)
    }

    fn recycle(&self, buf: Vec<u8>) {
        self.shared.inner.recycle(buf);
    }

    fn send_raw_frame(&self, tag: u64, payload: &[u8], msg_len: u32, flags: u8) -> CclResult<()> {
        self.shared.inner.send_raw_frame(tag, payload, msg_len, flags)
    }

    fn farewell(&self, reason: &str) {
        // Control-plane signal: passes through even on stalled or
        // partitioned edges (see module docs).
        self.shared.inner.farewell(reason);
    }

    fn abort(&self, reason: &str) {
        if self.shared.aborted.swap(true, Ordering::AcqRel) {
            return;
        }
        self.shared.held.lock().unwrap().clear();
        self.shared.inner.abort(reason);
    }

    fn kind(&self) -> &'static str {
        self.shared.inner.kind()
    }

    fn peer(&self) -> usize {
        self.shared.inner.peer()
    }
}

/// Upper bound on retained events (counters keep exact totals past it).
const MAX_EVENTS: usize = 1 << 16;

struct RegistryInner {
    /// Runtime-injected rules: (id, rule, remaining budget).
    dynamic: Vec<(u64, FaultRule, u64)>,
    next_id: u64,
    /// `release_stalls` disables every Stall rule process-wide.
    stalls_released: bool,
    links: Vec<Weak<FaultLinkShared>>,
    events: Vec<FaultEvent>,
}

/// The process-wide runtime fault handle — see module docs and
/// [`registry`]. All operations act on every *wrapped* live link
/// (worlds initialized with a [`FaultPlan`] in their options).
pub struct FaultRegistry {
    inner: Mutex<RegistryInner>,
}

/// Serialization lock for tests that mutate the process-global registry
/// (reset, dynamic rules, stall release): cargo runs tests of one
/// binary in parallel, and two registry-mutating tests interleaving
/// would clear each other's rules mid-run. Production code never takes
/// this.
#[doc(hidden)]
pub static TEST_SERIAL: Mutex<()> = Mutex::new(());

/// The process-wide registry.
pub fn registry() -> &'static FaultRegistry {
    static REGISTRY: Lazy<FaultRegistry> = Lazy::new(|| FaultRegistry {
        inner: Mutex::new(RegistryInner {
            dynamic: Vec::new(),
            next_id: 1,
            stalls_released: false,
            links: Vec::new(),
            events: Vec::new(),
        }),
    });
    &REGISTRY
}

impl FaultRegistry {
    /// Install a rule on live links mid-traffic. Dynamic rules fire
    /// unconditionally on matching sends (prob ignored — determinism of
    /// the static stream, see module docs). Returns an id for
    /// [`FaultRegistry::heal`].
    pub fn inject(&self, rule: FaultRule) -> u64 {
        if rule.pattern.world == STORE_EDGE {
            // Arm the store-channel fast path (stays armed: a healed
            // store rule costs one registry snapshot per store op).
            STORE_DYNAMIC_ARMED.store(true, Ordering::Release);
        }
        let mut inner = self.inner.lock().unwrap();
        let id = inner.next_id;
        inner.next_id += 1;
        let budget = rule.count;
        crate::metrics::log_event(
            "fault.rule_injected",
            &[
                ("edge", rule.pattern.world.as_str()),
                ("kind", rule.kind.name()),
            ],
        );
        inner.dynamic.push((id, rule, budget));
        drop(inner);
        self.flush_links();
        id
    }

    /// Remove a dynamic rule (the fault heals); stalled traffic it was
    /// pinning flushes in order.
    pub fn heal(&self, id: u64) {
        self.inner.lock().unwrap().dynamic.retain(|(i, _, _)| *i != id);
        self.flush_links();
    }

    /// Remove every dynamic rule.
    pub fn clear(&self) {
        self.inner.lock().unwrap().dynamic.clear();
        self.flush_links();
    }

    /// Release every stall (static and dynamic): held traffic flushes
    /// in order and Stall rules stop matching until [`Self::reset`].
    pub fn release_stalls(&self) {
        self.inner.lock().unwrap().stalls_released = true;
        self.flush_links();
    }

    /// Test-run hygiene: drop dynamic rules, the stall release latch and
    /// the event log (live links and their static plans are untouched).
    pub fn reset(&self) {
        let mut inner = self.inner.lock().unwrap();
        inner.dynamic.clear();
        inner.stalls_released = false;
        inner.events.clear();
    }

    /// Events recorded so far (clone; see [`FaultEvent`]).
    pub fn events(&self) -> Vec<FaultEvent> {
        self.inner.lock().unwrap().events.clone()
    }

    /// Drain the event log.
    pub fn take_events(&self) -> Vec<FaultEvent> {
        std::mem::take(&mut self.inner.lock().unwrap().events)
    }

    /// One event per line — what the chaos CI job uploads on failure.
    pub fn render_events(&self) -> String {
        let mut out = String::new();
        for e in self.inner.lock().unwrap().events.iter() {
            out.push_str(&format!("{e}\n"));
        }
        out
    }

    fn snapshot(&self) -> (Vec<(u64, FaultRule)>, bool) {
        let inner = self.inner.lock().unwrap();
        let rules = inner
            .dynamic
            .iter()
            .filter(|(_, _, remaining)| *remaining > 0)
            .map(|(id, r, _)| (*id, r.clone()))
            .collect();
        (rules, inner.stalls_released)
    }

    /// Spend one unit of a dynamic rule's budget.
    fn try_consume(&self, id: u64) -> bool {
        let mut inner = self.inner.lock().unwrap();
        match inner.dynamic.iter_mut().find(|(i, _, _)| *i == id) {
            Some((_, _, remaining)) if *remaining == u64::MAX => true,
            Some((_, _, remaining)) if *remaining > 0 => {
                *remaining -= 1;
                true
            }
            _ => false,
        }
    }

    fn register_link(&self, link: Weak<FaultLinkShared>) {
        let mut inner = self.inner.lock().unwrap();
        inner.links.retain(|w| w.strong_count() > 0);
        inner.links.push(link);
    }

    fn flush_links(&self) {
        let links: Vec<Arc<FaultLinkShared>> = {
            let mut inner = self.inner.lock().unwrap();
            inner.links.retain(|w| w.strong_count() > 0);
            inner.links.iter().filter_map(|w| w.upgrade()).collect()
        };
        for l in links {
            l.flush_if_unstalled();
        }
    }

    fn record(&self, event: FaultEvent) {
        crate::metrics::global()
            .counter(&format!("fault.injected.{}", event.kind))
            .inc();
        crate::metrics::log_event(
            "fault.injected",
            &[
                ("world", event.world.as_str()),
                ("src", event.src.to_string().as_str()),
                ("dst", event.dst.to_string().as_str()),
                ("op", event.op.to_string().as_str()),
                ("kind", event.kind),
            ],
        );
        let mut inner = self.inner.lock().unwrap();
        if inner.events.len() < MAX_EVENTS {
            inner.events.push(event);
        }
    }
}

// ---------------------------------------------------------------------
// Store-channel injection (the `store` pseudo-edge — see module docs).
// ---------------------------------------------------------------------

/// The exact world name a rule must carry to hit the store channel.
pub const STORE_EDGE: &str = "store";

/// What the store client must do with one outgoing request.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum StoreAction {
    /// No fault: write the request.
    Forward,
    /// Sleep this long, then write (delay / bandwidth).
    Sleep(Duration),
    /// The request "segment" was lost: pause one RTO, then write — the
    /// reliable stream retransmits, so the call survives unless its
    /// deadline passes first (drop / truncate).
    Retransmit(Duration),
    /// Stall / partition: hold the request until the rule heals (poll
    /// [`store_channel_wedged`]) or the caller's deadline passes.
    Wedge,
}

/// TCP-ish retransmission timeout modeled for a dropped store segment.
const STORE_RTO: Duration = Duration::from_millis(200);

/// Set once any dynamic rule ever names the store edge; lets the common
/// no-chaos case skip the registry snapshot entirely.
static STORE_DYNAMIC_ARMED: AtomicBool = AtomicBool::new(false);

/// The process-wide static plan as seen by the store channel, plus its
/// decision state. Seeded from the plan seed alone (there is one store
/// channel per process, not one per rank pair).
static STORE_STATE: Lazy<Option<Mutex<EdgeRand>>> = Lazy::new(|| {
    let plan = STORE_PLAN.as_ref()?;
    if !plan.rules.iter().any(is_store_rule) {
        return None;
    }
    let mut mix = plan.seed ^ 0x53_54_4F_52_45; // "STORE"
    Some(Mutex::new(EdgeRand {
        sends: 0,
        rng: Rng::new(splitmix64(&mut mix)),
        injected: Vec::new(),
    }))
});

static STORE_PLAN: Lazy<Option<FaultPlan>> = Lazy::new(FaultPlan::from_env);

/// Exact-name match only: the `*` glob (or any other glob) never
/// reaches the store channel. Rank patterns apply to the fixed edge
/// `0 -> 0`.
fn is_store_rule(r: &FaultRule) -> bool {
    r.pattern.world == STORE_EDGE
        && !r.pattern.src.is_some_and(|s| s != 0)
        && !r.pattern.dst.is_some_and(|d| d != 0)
}

/// Decide the fault action for one outgoing store request of `len`
/// bytes. Events and `fault.injected.<kind>` counters are recorded here
/// (with `world = "store"`); the caller just applies the action. Cheap
/// when no store rule exists anywhere: one atomic load + one `Lazy`
/// deref.
pub fn store_channel_action(len: usize) -> StoreAction {
    let dynamic_armed = STORE_DYNAMIC_ARMED.load(Ordering::Acquire);
    if STORE_STATE.is_none() && !dynamic_armed {
        return StoreAction::Forward;
    }
    let reg = registry();
    let (dynamic, stalls_released) = reg.snapshot();

    let action_of = |kind: FaultKind| match kind {
        FaultKind::Delay { ms } => StoreAction::Sleep(Duration::from_millis(ms)),
        FaultKind::Bandwidth { bps } => {
            StoreAction::Sleep(Duration::from_secs_f64(len as f64 / bps.max(1.0)))
        }
        FaultKind::Drop | FaultKind::Truncate { .. } => StoreAction::Retransmit(STORE_RTO),
        FaultKind::Stall | FaultKind::Partition => StoreAction::Wedge,
    };
    let wedges = |k: FaultKind| {
        matches!(k, FaultKind::Partition) || (matches!(k, FaultKind::Stall) && !stalls_released)
    };

    let mut record_kind: Option<&'static str> = None;
    let mut action = StoreAction::Forward;

    // Static pass (mirrors FaultLinkShared::decide): every matching
    // rule's probability draw is consumed per request; stall/partition
    // win categorically, otherwise first firing rule supplies the
    // verdict and its count bookkeeping.
    let mut n = 0u64;
    if let Some(state) = STORE_STATE.as_ref() {
        let plan = STORE_PLAN.as_ref().expect("store state implies plan");
        let mut rand = state.lock().unwrap();
        if rand.injected.len() < plan.rules.len() {
            rand.injected.resize(plan.rules.len(), 0);
        }
        n = rand.sends;
        rand.sends += 1;
        let mut static_wedge: Option<&'static str> = None;
        for (i, rule) in plan.rules.iter().enumerate() {
            if !is_store_rule(rule) || n < rule.after {
                continue;
            }
            if wedges(rule.kind) {
                static_wedge.get_or_insert(rule.kind.name());
                continue;
            }
            if rand.injected[i] >= rule.count {
                continue;
            }
            if rule.prob < 1.0 && !rand.rng.chance(rule.prob) {
                continue;
            }
            if record_kind.is_none() {
                rand.injected[i] += 1;
                record_kind = Some(rule.kind.name());
                action = action_of(rule.kind);
            }
        }
        if let Some(kind) = static_wedge {
            record_kind = Some(kind);
            action = StoreAction::Wedge;
        }
    }

    // Dynamic overrides, wedges first (categorical, no budget), then
    // the first consumable non-wedge rule.
    if let Some((_, rule)) = dynamic
        .iter()
        .find(|(_, r)| is_store_rule(r) && wedges(r.kind))
    {
        record_kind = Some(rule.kind.name());
        action = StoreAction::Wedge;
    } else if !matches!(action, StoreAction::Wedge) {
        for (id, rule) in &dynamic {
            if is_store_rule(rule) && !wedges(rule.kind) && reg.try_consume(*id) {
                record_kind = Some(rule.kind.name());
                action = action_of(rule.kind);
                break;
            }
        }
    }

    if let Some(kind) = record_kind {
        reg.record(FaultEvent { world: STORE_EDGE.to_string(), src: 0, dst: 0, op: n, kind });
    }
    action
}

/// Is the store channel still wedged? Polled by a client whose request
/// got [`StoreAction::Wedge`]; healing the rule (or releasing stalls)
/// lets the request proceed. `after` gates only the initial decision —
/// once wedged, healing is the only exit.
pub fn store_channel_wedged() -> bool {
    let (dynamic, stalls_released) = registry().snapshot();
    let wedges = |k: FaultKind| {
        matches!(k, FaultKind::Partition) || (matches!(k, FaultKind::Stall) && !stalls_released)
    };
    if dynamic.iter().any(|(_, r)| is_store_rule(r) && wedges(r.kind)) {
        return true;
    }
    STORE_PLAN
        .as_ref()
        .is_some_and(|p| p.rules.iter().any(|r| is_store_rule(r) && wedges(r.kind)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mwccl::transport::tcp::TcpLink;
    use std::net::{TcpListener, TcpStream};

    /// Registry state is process-global: serialize the tests that use it.
    use super::TEST_SERIAL as SERIAL;

    fn tcp_pair() -> (TcpLink, TcpLink) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || listener.accept().unwrap().0);
        let a_stream = TcpStream::connect(addr).unwrap();
        let b_stream = t.join().unwrap();
        (
            TcpLink::new(1, a_stream, None).unwrap(),
            TcpLink::new(0, b_stream, None).unwrap(),
        )
    }

    fn wrapped(world: &str, plan: FaultPlan) -> (FaultLink, TcpLink) {
        let (a, b) = tcp_pair();
        let fl = FaultLink::wrap(Arc::new(plan), world, 0, 1, Box::new(a));
        (fl, b)
    }

    #[test]
    fn plan_grammar_parses() {
        let p = FaultPlan::parse(
            "edge=*tp-s1r1*:0->1 kind=stall; \
             edge=w:*->* kind=delay ms=7 prob=0.25 after=2 count=9; \
             edge=*:3->* kind=truncate bytes=16; \
             edge=x*:0->2 kind=bandwidth bps=1000",
            42,
        )
        .unwrap();
        assert_eq!(p.seed, 42);
        assert_eq!(p.rules.len(), 4);
        assert_eq!(p.rules[0].kind, FaultKind::Stall);
        assert_eq!(p.rules[0].pattern.src, Some(0));
        assert_eq!(p.rules[1].kind, FaultKind::Delay { ms: 7 });
        assert_eq!(p.rules[1].prob, 0.25);
        assert_eq!(p.rules[1].after, 2);
        assert_eq!(p.rules[1].count, 9);
        assert_eq!(p.rules[2].kind, FaultKind::Truncate { keep: 16 });
        assert_eq!(p.rules[2].pattern.dst, None);
        assert_eq!(p.rules[3].kind, FaultKind::Bandwidth { bps: 1000.0 });
        assert!(FaultPlan::parse("edge=w:0->1", 0).is_err(), "missing kind");
        assert!(FaultPlan::parse("kind=drop", 0).is_err(), "missing edge");
        assert!(FaultPlan::parse("edge=w:0->1 kind=meteor", 0).is_err());
        assert_eq!(FaultPlan::parse("", 9).unwrap().rules.len(), 0);
    }

    #[test]
    fn edge_pattern_globs() {
        let contains = EdgePattern::new("*tp-s1r1*", None, None);
        assert!(contains.matches("px-tp-s1r1#g2", 0, 1));
        assert!(!contains.matches("px-tp-s1r0", 0, 1));
        let prefix = EdgePattern::new("in-*", None, None);
        assert!(prefix.matches("in-s0r0", 4, 2));
        assert!(!prefix.matches("x-in-s0r0", 4, 2));
        let suffix = EdgePattern::new("*-out", None, None);
        assert!(suffix.matches("w-out", 0, 0));
        let exact = EdgePattern::new("w1", Some(0), Some(1));
        assert!(exact.matches("w1", 0, 1));
        assert!(!exact.matches("w1", 1, 0), "direction respected");
        assert!(EdgePattern::new("*", None, None).matches("anything", 9, 9));
    }

    #[test]
    fn drop_suppresses_and_counts() {
        let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
        registry().reset();
        let before = crate::metrics::global().counter("fault.injected.drop").get();
        let plan = FaultPlan::new(
            vec![FaultRule::always(EdgePattern::new("dropw", None, None), FaultKind::Drop)
                .with_count(1)],
            7,
        );
        let (a, b) = wrapped("dropw", plan);
        a.send(1, &[b"lost"]).unwrap(); // dropped
        a.send(2, &[b"kept"]).unwrap(); // count exhausted
        assert_eq!(
            b.recv(2, Some(Duration::from_secs(2))).unwrap(),
            b"kept",
            "later sends pass once the budget is spent"
        );
        assert!(matches!(
            b.recv(1, Some(Duration::from_millis(80))),
            Err(CclError::Timeout(_))
        ));
        assert_eq!(
            crate::metrics::global().counter("fault.injected.drop").get(),
            before + 1
        );
        let events = registry().events();
        assert!(events.iter().any(|e| e.world == "dropw" && e.kind == "drop" && e.op == 0));
    }

    #[test]
    fn first_match_wins_and_shadowed_rules_keep_their_budget() {
        let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
        registry().reset();
        // drop(count=1) before delay: the first send burns the drop
        // budget; the shadowed delay rule takes over on the second send
        // with its own budget intact.
        let plan = FaultPlan::new(
            vec![
                FaultRule::always(EdgePattern::new("fmw", None, None), FaultKind::Drop)
                    .with_count(1),
                FaultRule::always(
                    EdgePattern::new("fmw", None, None),
                    FaultKind::Delay { ms: 40 },
                ),
            ],
            7,
        );
        let (a, b) = wrapped("fmw", plan);
        a.send(1, &[b"lost"]).unwrap();
        let t0 = std::time::Instant::now();
        a.send(2, &[b"late"]).unwrap();
        assert_eq!(b.recv(2, Some(Duration::from_secs(2))).unwrap(), b"late");
        assert!(t0.elapsed() >= Duration::from_millis(35), "second rule's delay applied");
        assert!(matches!(
            b.recv(1, Some(Duration::from_millis(80))),
            Err(CclError::Timeout(_))
        ), "first rule's drop applied");
        let kinds: Vec<_> = registry()
            .events()
            .into_iter()
            .filter(|e| e.world == "fmw")
            .map(|e| e.kind)
            .collect();
        assert_eq!(kinds, vec!["drop", "delay"], "exactly one fault per send, in rule order");
    }

    #[test]
    fn earlier_always_rule_shadows_later_rules_forever() {
        let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
        registry().reset();
        // Reversed order: an unbounded delay rule ahead of drop(count=1)
        // wins every send, so the drop never fires — order is priority.
        let plan = FaultPlan::new(
            vec![
                FaultRule::always(
                    EdgePattern::new("fmw2", None, None),
                    FaultKind::Delay { ms: 5 },
                ),
                FaultRule::always(EdgePattern::new("fmw2", None, None), FaultKind::Drop)
                    .with_count(1),
            ],
            7,
        );
        let (a, b) = wrapped("fmw2", plan);
        a.send(1, &[b"one"]).unwrap();
        a.send(2, &[b"two"]).unwrap();
        assert_eq!(b.recv(1, Some(Duration::from_secs(2))).unwrap(), b"one");
        assert_eq!(b.recv(2, Some(Duration::from_secs(2))).unwrap(), b"two");
        let events = registry().events();
        assert!(
            events.iter().filter(|e| e.world == "fmw2").all(|e| e.kind == "delay"),
            "shadowed drop rule never fires"
        );
    }

    #[test]
    fn truncate_is_detected_by_the_receiver() {
        let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
        registry().reset();
        let plan = FaultPlan::new(
            vec![FaultRule::always(
                EdgePattern::new("truncw", None, None),
                FaultKind::Truncate { keep: 8 },
            )
            .with_count(1)],
            7,
        );
        let (a, b) = wrapped("truncw", plan);
        a.send(5, &[&[3u8; 64]]).unwrap();
        let err = b.recv(5, Some(Duration::from_secs(2))).unwrap_err();
        assert!(
            matches!(err, CclError::RemoteError { peer: 0, .. }),
            "truncation must surface as an edge-attributed RemoteError, got {err:?}"
        );
    }

    #[test]
    fn stall_holds_until_released_then_flushes_in_order() {
        let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
        registry().reset();
        let (a, b) = wrapped("stallw", FaultPlan::empty(7));
        let id = registry().inject(FaultRule::always(
            EdgePattern::new("stallw", Some(0), Some(1)),
            FaultKind::Stall,
        ));
        a.send(1, &[b"first"]).unwrap();
        a.send(1, &[b"second"]).unwrap();
        assert!(matches!(
            b.recv(1, Some(Duration::from_millis(100))),
            Err(CclError::Timeout(_))
        ), "stalled traffic must not arrive");
        registry().heal(id);
        assert_eq!(b.recv(1, Some(Duration::from_secs(2))).unwrap(), b"first");
        assert_eq!(b.recv(1, Some(Duration::from_secs(2))).unwrap(), b"second");
        let stalls: Vec<_> =
            registry().events().into_iter().filter(|e| e.kind == "stall").collect();
        assert_eq!(stalls.len(), 2, "one stall event per held message");
    }

    #[test]
    fn same_seed_same_decisions_regardless_of_world_name() {
        let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
        registry().reset();
        let plan_for = |_run: &str| {
            FaultPlan::new(
                vec![
                    FaultRule::always(EdgePattern::new("*", None, None), FaultKind::Drop)
                        .with_prob(0.3),
                ],
                1234,
            )
        };
        let run = |world: &str| -> Vec<(usize, usize, u64, &'static str)> {
            registry().take_events();
            let (a, _b) = wrapped(world, plan_for(world));
            for k in 0..40u64 {
                a.send(k, &[b"x"]).unwrap();
            }
            registry()
                .take_events()
                .into_iter()
                .map(|e| e.canon())
                .collect()
        };
        let first = run("det-a");
        let second = run("det-b");
        assert!(!first.is_empty(), "prob 0.3 over 40 sends must fire");
        assert_eq!(first, second, "same seed + plan ⇒ identical injection sequence");
    }

    #[test]
    fn delay_slows_but_delivers() {
        let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
        registry().reset();
        let plan = FaultPlan::new(
            vec![FaultRule::always(
                EdgePattern::new("delayw", None, None),
                FaultKind::Delay { ms: 40 },
            )
            .with_count(1)],
            7,
        );
        let (a, b) = wrapped("delayw", plan);
        let t0 = std::time::Instant::now();
        a.send(1, &[b"late"]).unwrap();
        assert_eq!(b.recv(1, Some(Duration::from_secs(2))).unwrap(), b"late");
        assert!(t0.elapsed() >= Duration::from_millis(35), "delay applied");
    }

    #[test]
    fn store_edge_requires_exact_name() {
        let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
        registry().reset();
        // A blanket glob must never reach the store channel.
        let glob = registry().inject(FaultRule::always(
            EdgePattern::new("*", None, None),
            FaultKind::Delay { ms: 5 },
        ));
        assert_eq!(store_channel_action(64), StoreAction::Forward);
        // An exact `store` rule does.
        let exact = registry().inject(FaultRule::always(
            EdgePattern::new(STORE_EDGE, None, None),
            FaultKind::Delay { ms: 5 },
        ));
        assert_eq!(store_channel_action(64), StoreAction::Sleep(Duration::from_millis(5)));
        let events = registry().events();
        assert!(
            events.iter().any(|e| e.world == STORE_EDGE && e.kind == "delay"),
            "store injection recorded: {events:?}"
        );
        registry().heal(glob);
        registry().heal(exact);
        assert_eq!(store_channel_action(64), StoreAction::Forward);
    }

    #[test]
    fn store_wedge_holds_until_healed() {
        let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
        registry().reset();
        let id = registry().inject(FaultRule::always(
            EdgePattern::new(STORE_EDGE, None, None),
            FaultKind::Partition,
        ));
        assert_eq!(store_channel_action(8), StoreAction::Wedge);
        assert!(store_channel_wedged());
        registry().heal(id);
        assert!(!store_channel_wedged());
        assert_eq!(store_channel_action(8), StoreAction::Forward);
        // Drop models a lost segment: retransmit, not an error.
        let id = registry().inject(FaultRule::always(
            EdgePattern::new(STORE_EDGE, None, None),
            FaultKind::Drop,
        ));
        assert!(matches!(store_channel_action(8), StoreAction::Retransmit(_)));
        registry().heal(id);
    }
}
