//! Tag-keyed reassembly buffer shared by the transports.
//!
//! Receiver threads push frames; `recv(tag)` blocks until a *complete*
//! message for that tag exists. A failed link wakes every waiter with
//! the error; an aborted link wakes them with `Aborted`.
//!
//! The receive path is pooled and allocation-free in steady state:
//! every frame carries the total message length (see
//! [`crate::mwccl::wire`]), so the first frame of a message grabs a
//! buffer of the right capacity from the link's free-list and later
//! frames append without reallocating. Consumers hand buffers back via
//! [`Inbox::recycle`] (plumbed through `Link::recycle`) once the payload
//! has been parsed, closing the loop — large-tensor traffic reuses the
//! same few buffers instead of exercising the allocator per message.

use crate::mwccl::error::{CclError, CclResult};
use crate::mwccl::wire::{FLAG_LAST, FLAG_PROLOGUE};
use std::collections::{HashMap, VecDeque};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Free-list of reusable message buffers, shared by one link's reader
/// thread (producer side) and its consumers (via [`Inbox::recycle`]).
#[derive(Default)]
pub struct BufferPool {
    free: Mutex<Vec<Vec<u8>>>,
}

impl BufferPool {
    /// Buffers retained at most; beyond this, returned buffers are freed.
    const MAX_POOLED: usize = 32;
    /// Largest capacity worth hoarding (one pathological 1 GiB tensor
    /// must not pin its buffer forever).
    const MAX_POOLED_CAP: usize = 32 << 20;

    /// Take a cleared buffer with at least `capacity` bytes reserved.
    pub fn take(&self, capacity: usize) -> Vec<u8> {
        let recycled = self.free.lock().unwrap().pop();
        match recycled {
            Some(mut buf) => {
                buf.clear();
                if buf.capacity() < capacity {
                    buf.reserve_exact(capacity);
                }
                buf
            }
            None => Vec::with_capacity(capacity),
        }
    }

    /// Return a buffer for reuse (dropped if the pool is full or the
    /// buffer is outsized).
    pub fn put(&self, buf: Vec<u8>) {
        if buf.capacity() == 0 || buf.capacity() > Self::MAX_POOLED_CAP {
            return;
        }
        let mut free = self.free.lock().unwrap();
        if free.len() < Self::MAX_POOLED {
            free.push(buf);
        }
    }

    /// Number of buffers currently pooled (diagnostics/tests).
    pub fn pooled(&self) -> usize {
        self.free.lock().unwrap().len()
    }
}

/// A message mid-reassembly: the accumulating buffer plus the total
/// length every frame of the message claimed in its header, so the
/// `LAST` frame can be cross-checked against the bytes that actually
/// arrived (truncation / corruption detection).
struct Partial {
    buf: Vec<u8>,
    expect: usize,
}

#[derive(Default)]
struct State {
    /// Complete messages, FIFO per tag.
    ready: HashMap<u64, VecDeque<Vec<u8>>>,
    /// Partially reassembled message per tag.
    partial: HashMap<u64, Partial>,
    /// Complete *prologue* (control) messages, FIFO per tag — a lane
    /// separate from `ready` so a negotiation byte and the data message
    /// that follows can share one wire tag without racing each other.
    prologue: HashMap<u64, VecDeque<Vec<u8>>>,
    /// Terminal error (RemoteError from TCP reader, or Aborted).
    error: Option<CclError>,
}

/// See module docs.
#[derive(Default)]
pub struct Inbox {
    state: Mutex<State>,
    cv: Condvar,
    pool: BufferPool,
    /// Peer rank this inbox receives from, when known — corrupt-frame
    /// errors are then attributed as `RemoteError {{ peer }}` (the edge
    /// is named), not an anonymous transport error.
    peer: Option<usize>,
}

impl Inbox {
    pub fn new() -> Self {
        Self::default()
    }

    /// An inbox whose corrupt-frame errors are attributed to `peer`
    /// (what the real links use; [`Inbox::new`] keeps the anonymous
    /// form for tests).
    pub fn for_peer(peer: usize) -> Self {
        Inbox { peer: Some(peer), ..Default::default() }
    }

    /// Largest up-front reservation honored from a frame's `msg_len`
    /// hint. The buffer still grows as real bytes arrive, so bigger
    /// messages stay correct — but a corrupt or hostile header cannot
    /// make us allocate gigabytes before a single payload byte lands.
    const MAX_SIZE_HINT: usize = 64 << 20;

    /// Append one frame; completes the message when the `LAST` flag is
    /// set. `msg_len` is the total payload length of the whole message
    /// (from the frame header) — used to preallocate the reassembly
    /// buffer exactly once, on the first frame (clamped to
    /// [`Self::MAX_SIZE_HINT`]), and cross-checked on the `LAST` frame:
    /// a message whose bytes don't add up to what every frame claimed
    /// (a sender that died mid-message, a chaos-injected truncation) is
    /// *never* delivered short — the partial buffer is recycled and the
    /// inbox fails with an edge-attributed `RemoteError` (see
    /// [`Inbox::for_peer`]). Frames flagged `PROLOGUE` are single-frame
    /// control messages dispatched to their own lane (see
    /// [`Inbox::recv_prologue`]).
    pub fn push_frame(&self, tag: u64, payload: &[u8], msg_len: usize, flags: u8) {
        let corrupt_detail: Option<String> = {
            let mut st = self.state.lock().unwrap();
            if flags & FLAG_PROLOGUE != 0 {
                // Prologues are complete by construction (senders emit
                // them as one LAST-flagged frame); no reassembly state.
                st.prologue.entry(tag).or_default().push_back(payload.to_vec());
                self.cv.notify_all();
                return;
            }
            let hint = msg_len.min(Self::MAX_SIZE_HINT);
            let entry = st
                .partial
                .entry(tag)
                .or_insert_with(|| Partial { buf: self.pool.take(hint), expect: msg_len });
            if entry.expect != msg_len {
                Some(format!(
                    "message length changed mid-reassembly ({} then {msg_len})",
                    entry.expect
                ))
            } else {
                entry.buf.extend_from_slice(payload);
                let (got, expect) = (entry.buf.len(), entry.expect);
                if got > expect {
                    Some(format!("message overflows its header: {got} > {expect} bytes"))
                } else if flags & FLAG_LAST == 0 {
                    None
                } else if got != expect {
                    Some(format!("truncated message: {got} of {expect} bytes"))
                } else {
                    let msg = st.partial.remove(&tag).map(|p| p.buf).unwrap_or_default();
                    st.ready.entry(tag).or_default().push_back(msg);
                    self.cv.notify_all();
                    None
                }
            }
        };
        if let Some(detail) = corrupt_detail {
            self.corrupt(tag, &detail);
        }
    }

    /// A frame contradicted its message's own headers (truncation,
    /// overflow, length flip-flop): recycle the partial buffer, count
    /// and log the corruption, and fail the inbox with the edge
    /// attributed — the reader thread above must keep running (or exit
    /// cleanly), never unwind.
    fn corrupt(&self, tag: u64, detail: &str) {
        {
            let mut st = self.state.lock().unwrap();
            if let Some(p) = st.partial.remove(&tag) {
                self.pool.put(p.buf);
            }
        }
        crate::metrics::global().counter("transport.corrupt_frames").inc();
        let peer_s = self.peer.map(|p| p.to_string()).unwrap_or_else(|| "-".into());
        crate::metrics::log_event(
            "transport.corrupt_frame",
            &[
                ("peer", peer_s.as_str()),
                ("tag", format!("{tag:#x}").as_str()),
                ("detail", detail),
            ],
        );
        let err = match self.peer {
            Some(peer) => CclError::RemoteError {
                peer,
                detail: format!("corrupt frame on tag {tag:#x}: {detail}"),
            },
            None => CclError::Transport(format!("corrupt frame on tag {tag:#x}: {detail}")),
        };
        self.fail(err);
    }

    /// Terminal failure: every current and future `recv` gets `err`.
    /// First error wins (an abort after a remote error keeps the remote
    /// error, which is the more informative of the two).
    pub fn fail(&self, err: CclError) {
        let mut st = self.state.lock().unwrap();
        if st.error.is_none() {
            st.error = Some(err);
        }
        self.cv.notify_all();
    }

    /// Current terminal error, if any.
    pub fn error(&self) -> Option<CclError> {
        self.state.lock().unwrap().error.clone()
    }

    /// Hand a consumed message buffer back to the link's free-list so
    /// the next message reuses its allocation.
    pub fn recycle(&self, buf: Vec<u8>) {
        self.pool.put(buf);
    }

    /// Number of buffers waiting in the pool (diagnostics/tests).
    pub fn pool_len(&self) -> usize {
        self.pool.pooled()
    }

    /// Blocking receive of one complete message with `tag`.
    ///
    /// With `timeout: None` this parks on the condvar until
    /// [`Inbox::push_frame`] completes a message or [`Inbox::fail`]
    /// fires — no periodic wakeups. A bounded wait only ever wakes at
    /// the deadline or on a notification.
    pub fn recv(&self, tag: u64, timeout: Option<Duration>) -> CclResult<Vec<u8>> {
        let deadline = timeout.map(|t| Instant::now() + t);
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(q) = st.ready.get_mut(&tag) {
                if let Some(msg) = q.pop_front() {
                    if q.is_empty() {
                        st.ready.remove(&tag);
                    }
                    return Ok(msg);
                }
            }
            if let Some(e) = &st.error {
                return Err(e.clone());
            }
            st = match deadline {
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        return Err(CclError::Timeout(format!("recv tag {tag:#x}")));
                    }
                    self.cv.wait_timeout(st, d - now).unwrap().0
                }
                None => self.cv.wait(st).unwrap(),
            };
        }
    }

    /// Blocking receive of one *prologue* (control) message with `tag`.
    /// Prologues never mix with data messages of the same tag — each
    /// lane has its own FIFO — so a root can send `algo byte` then
    /// `payload` under one tag and the receiver reads them in type
    /// order, not arrival order.
    pub fn recv_prologue(&self, tag: u64, timeout: Option<Duration>) -> CclResult<Vec<u8>> {
        let deadline = timeout.map(|t| Instant::now() + t);
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(q) = st.prologue.get_mut(&tag) {
                if let Some(msg) = q.pop_front() {
                    if q.is_empty() {
                        st.prologue.remove(&tag);
                    }
                    return Ok(msg);
                }
            }
            if let Some(e) = &st.error {
                return Err(e.clone());
            }
            st = match deadline {
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        return Err(CclError::Timeout(format!("recv_prologue tag {tag:#x}")));
                    }
                    self.cv.wait_timeout(st, d - now).unwrap().0
                }
                None => self.cv.wait(st).unwrap(),
            };
        }
    }

    /// Non-blocking poll.
    pub fn try_recv(&self, tag: u64) -> CclResult<Option<Vec<u8>>> {
        let mut st = self.state.lock().unwrap();
        if let Some(q) = st.ready.get_mut(&tag) {
            if let Some(msg) = q.pop_front() {
                if q.is_empty() {
                    st.ready.remove(&tag);
                }
                return Ok(Some(msg));
            }
        }
        if let Some(e) = &st.error {
            return Err(e.clone());
        }
        Ok(None)
    }

    /// Number of complete undelivered messages (diagnostics).
    pub fn backlog(&self) -> usize {
        self.state
            .lock()
            .unwrap()
            .ready
            .values()
            .map(|q| q.len())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn single_frame_message() {
        let ib = Inbox::new();
        ib.push_frame(7, b"hello", 5, FLAG_LAST);
        assert_eq!(ib.recv(7, None).unwrap(), b"hello");
    }

    #[test]
    fn multi_frame_reassembly() {
        let ib = Inbox::new();
        ib.push_frame(1, b"ab", 6, 0);
        ib.push_frame(1, b"cd", 6, 0);
        assert_eq!(ib.try_recv(1).unwrap(), None, "incomplete stays hidden");
        ib.push_frame(1, b"ef", 6, FLAG_LAST);
        assert_eq!(ib.recv(1, None).unwrap(), b"abcdef");
    }

    #[test]
    fn size_hint_preallocates_once() {
        let ib = Inbox::new();
        ib.push_frame(4, &[0u8; 100], 300, 0);
        ib.push_frame(4, &[1u8; 100], 300, 0);
        ib.push_frame(4, &[2u8; 100], 300, FLAG_LAST);
        let msg = ib.recv(4, None).unwrap();
        assert_eq!(msg.len(), 300);
        assert!(
            msg.capacity() >= 300,
            "first frame must reserve the whole message"
        );
    }

    #[test]
    fn recycled_buffers_are_reused() {
        let ib = Inbox::new();
        ib.push_frame(1, &[7u8; 64], 64, FLAG_LAST);
        let msg = ib.recv(1, None).unwrap();
        let cap = msg.capacity();
        ib.recycle(msg);
        assert_eq!(ib.pool_len(), 1);
        ib.push_frame(1, &[8u8; 32], 32, FLAG_LAST);
        let again = ib.recv(1, None).unwrap();
        assert_eq!(again, vec![8u8; 32]);
        assert_eq!(ib.pool_len(), 0, "pooled buffer was taken");
        assert!(again.capacity() >= cap.min(32));
    }

    #[test]
    fn tags_are_independent_fifo() {
        let ib = Inbox::new();
        ib.push_frame(1, b"x1", 2, FLAG_LAST);
        ib.push_frame(2, b"y", 1, FLAG_LAST);
        ib.push_frame(1, b"x2", 2, FLAG_LAST);
        assert_eq!(ib.recv(2, None).unwrap(), b"y");
        assert_eq!(ib.recv(1, None).unwrap(), b"x1");
        assert_eq!(ib.recv(1, None).unwrap(), b"x2");
        assert_eq!(ib.backlog(), 0);
    }

    #[test]
    fn recv_timeout() {
        let ib = Inbox::new();
        let err = ib.recv(9, Some(Duration::from_millis(60))).unwrap_err();
        assert!(matches!(err, CclError::Timeout(_)));
    }

    #[test]
    fn untimed_recv_parks_until_notified() {
        // Regression for the old 50 ms poll cap: an untimed recv must be
        // woken by push_frame alone, promptly.
        let ib = Arc::new(Inbox::new());
        let ib2 = ib.clone();
        let t = std::thread::spawn(move || ib2.recv(11, None));
        std::thread::sleep(Duration::from_millis(20));
        let t0 = Instant::now();
        ib.push_frame(11, b"wake", 4, FLAG_LAST);
        let got = t.join().unwrap().unwrap();
        assert_eq!(got, b"wake");
        assert!(
            t0.elapsed() < Duration::from_millis(45),
            "receiver must wake on notify, not on a poll tick"
        );
    }

    #[test]
    fn fail_wakes_blocked_receiver() {
        let ib = Arc::new(Inbox::new());
        let ib2 = ib.clone();
        let t = std::thread::spawn(move || ib2.recv(5, None));
        std::thread::sleep(Duration::from_millis(30));
        ib.fail(CclError::RemoteError { peer: 1, detail: "reset".into() });
        let res = t.join().unwrap();
        assert!(matches!(res, Err(CclError::RemoteError { peer: 1, .. })));
    }

    #[test]
    fn first_error_wins() {
        let ib = Inbox::new();
        ib.fail(CclError::RemoteError { peer: 2, detail: "reset".into() });
        ib.fail(CclError::Aborted("later".into()));
        assert!(matches!(ib.error(), Some(CclError::RemoteError { .. })));
    }

    #[test]
    fn messages_delivered_before_error_are_not_lost() {
        let ib = Inbox::new();
        ib.push_frame(3, b"data", 4, FLAG_LAST);
        ib.fail(CclError::Aborted("shutdown".into()));
        // Already-complete message still deliverable…
        assert_eq!(ib.recv(3, None).unwrap(), b"data");
        // …then the error surfaces.
        assert!(ib.recv(3, Some(Duration::from_millis(10))).is_err());
    }

    #[test]
    fn prologue_lane_is_separate_from_data() {
        let ib = Inbox::new();
        // Data message arrives FIRST, then the prologue, same tag: the
        // prologue lane must still deliver the control byte, and the
        // data recv must still see the data, regardless of order.
        ib.push_frame(9, b"payload", 7, FLAG_LAST);
        ib.push_frame(9, &[1u8], 1, FLAG_LAST | FLAG_PROLOGUE);
        assert_eq!(ib.recv_prologue(9, None).unwrap(), vec![1u8]);
        assert_eq!(ib.recv(9, None).unwrap(), b"payload");
        assert_eq!(ib.backlog(), 0);
    }

    #[test]
    fn prologue_does_not_disturb_partial_reassembly() {
        let ib = Inbox::new();
        ib.push_frame(3, b"ab", 4, 0); // partial data under tag 3
        ib.push_frame(3, &[0u8], 1, FLAG_LAST | FLAG_PROLOGUE);
        ib.push_frame(3, b"cd", 4, FLAG_LAST);
        assert_eq!(ib.recv_prologue(3, None).unwrap(), vec![0u8]);
        assert_eq!(ib.recv(3, None).unwrap(), b"abcd");
    }

    #[test]
    fn prologue_recv_times_out_and_sees_errors() {
        let ib = Inbox::new();
        let err = ib
            .recv_prologue(5, Some(Duration::from_millis(30)))
            .unwrap_err();
        assert!(matches!(err, CclError::Timeout(_)));
        ib.fail(CclError::Aborted("shutdown".into()));
        let err = ib.recv_prologue(5, None).unwrap_err();
        assert!(matches!(err, CclError::Aborted(_)));
    }

    #[test]
    fn truncated_message_errors_and_recycles_buffer() {
        // A LAST frame arriving before the header-claimed byte count is
        // in (sender crashed mid-message / chaos truncation) must never
        // deliver a short message: the partial buffer goes back to the
        // pool and the inbox fails with the peer attributed.
        let ib = Inbox::for_peer(3);
        ib.push_frame(9, &[1u8; 100], 300, 0);
        ib.push_frame(9, &[2u8; 50], 300, FLAG_LAST); // 150 of 300 bytes
        let err = ib.recv(9, Some(Duration::from_millis(50))).unwrap_err();
        assert!(
            matches!(err, CclError::RemoteError { peer: 3, .. }),
            "truncation must raise an edge-attributed RemoteError, got {err:?}"
        );
        assert_eq!(ib.pool_len(), 1, "partial buffer recycled, not leaked");
    }

    #[test]
    fn message_overflowing_its_header_errors() {
        let ib = Inbox::for_peer(1);
        ib.push_frame(2, &[0u8; 80], 100, 0);
        ib.push_frame(2, &[0u8; 80], 100, 0); // 160 > 100 claimed
        assert!(matches!(
            ib.recv(2, Some(Duration::from_millis(50))),
            Err(CclError::RemoteError { peer: 1, .. })
        ));
    }

    #[test]
    fn msg_len_flip_flop_mid_reassembly_errors() {
        let ib = Inbox::for_peer(2);
        ib.push_frame(5, &[0u8; 10], 40, 0);
        ib.push_frame(5, &[0u8; 10], 99, 0); // header disagrees with itself
        assert!(matches!(
            ib.recv(5, Some(Duration::from_millis(50))),
            Err(CclError::RemoteError { peer: 2, .. })
        ));
    }

    #[test]
    fn truncation_without_peer_is_a_transport_error() {
        let ib = Inbox::new();
        ib.push_frame(1, &[0u8; 4], 8, FLAG_LAST);
        assert!(matches!(
            ib.recv(1, Some(Duration::from_millis(50))),
            Err(CclError::Transport(_))
        ));
    }

    #[test]
    fn concurrent_producers_consumers() {
        let ib = Arc::new(Inbox::new());
        let producers: Vec<_> = (0..4u64)
            .map(|tag| {
                let ib = ib.clone();
                std::thread::spawn(move || {
                    for i in 0..50u32 {
                        ib.push_frame(tag, &i.to_le_bytes(), 4, FLAG_LAST);
                    }
                })
            })
            .collect();
        let consumers: Vec<_> = (0..4u64)
            .map(|tag| {
                let ib = ib.clone();
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    for _ in 0..50 {
                        let m = ib.recv(tag, Some(Duration::from_secs(5))).unwrap();
                        got.push(u32::from_le_bytes(m.as_slice().try_into().unwrap()));
                        ib.recycle(m);
                    }
                    got
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        for c in consumers {
            let got = c.join().unwrap();
            assert_eq!(got, (0..50).collect::<Vec<_>>(), "per-tag FIFO preserved");
        }
    }
}
