//! Tag-keyed reassembly buffer shared by the transports.
//!
//! Receiver threads push frames; `recv(tag)` blocks until a *complete*
//! message for that tag exists. A failed link wakes every waiter with
//! the error; an aborted link wakes them with `Aborted`.

use crate::mwccl::error::{CclError, CclResult};
use std::collections::{HashMap, VecDeque};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

#[derive(Default)]
struct State {
    /// Complete messages, FIFO per tag.
    ready: HashMap<u64, VecDeque<Vec<u8>>>,
    /// Partially reassembled message per tag.
    partial: HashMap<u64, Vec<u8>>,
    /// Terminal error (RemoteError from TCP reader, or Aborted).
    error: Option<CclError>,
}

/// See module docs.
#[derive(Default)]
pub struct Inbox {
    state: Mutex<State>,
    cv: Condvar,
}

impl Inbox {
    pub fn new() -> Self {
        Self::default()
    }

    /// Append one frame; completes the message when `last` is set.
    pub fn push_frame(&self, tag: u64, payload: &[u8], last: bool) {
        let mut st = self.state.lock().unwrap();
        let buf = st.partial.entry(tag).or_default();
        buf.extend_from_slice(payload);
        if last {
            let msg = st.partial.remove(&tag).unwrap_or_default();
            st.ready.entry(tag).or_default().push_back(msg);
            self.cv.notify_all();
        }
    }

    /// Terminal failure: every current and future `recv` gets `err`.
    /// First error wins (an abort after a remote error keeps the remote
    /// error, which is the more informative of the two).
    pub fn fail(&self, err: CclError) {
        let mut st = self.state.lock().unwrap();
        if st.error.is_none() {
            st.error = Some(err);
        }
        self.cv.notify_all();
    }

    /// Current terminal error, if any.
    pub fn error(&self) -> Option<CclError> {
        self.state.lock().unwrap().error.clone()
    }

    /// Blocking receive of one complete message with `tag`.
    pub fn recv(&self, tag: u64, timeout: Option<Duration>) -> CclResult<Vec<u8>> {
        let deadline = timeout.map(|t| Instant::now() + t);
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(q) = st.ready.get_mut(&tag) {
                if let Some(msg) = q.pop_front() {
                    if q.is_empty() {
                        st.ready.remove(&tag);
                    }
                    return Ok(msg);
                }
            }
            if let Some(e) = &st.error {
                return Err(e.clone());
            }
            let wait = match deadline {
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        return Err(CclError::Timeout(format!("recv tag {tag:#x}")));
                    }
                    (d - now).min(Duration::from_millis(50))
                }
                None => Duration::from_millis(50),
            };
            let (guard, _) = self.cv.wait_timeout(st, wait).unwrap();
            st = guard;
        }
    }

    /// Non-blocking poll.
    pub fn try_recv(&self, tag: u64) -> CclResult<Option<Vec<u8>>> {
        let mut st = self.state.lock().unwrap();
        if let Some(q) = st.ready.get_mut(&tag) {
            if let Some(msg) = q.pop_front() {
                if q.is_empty() {
                    st.ready.remove(&tag);
                }
                return Ok(Some(msg));
            }
        }
        if let Some(e) = &st.error {
            return Err(e.clone());
        }
        Ok(None)
    }

    /// Number of complete undelivered messages (diagnostics).
    pub fn backlog(&self) -> usize {
        self.state
            .lock()
            .unwrap()
            .ready
            .values()
            .map(|q| q.len())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn single_frame_message() {
        let ib = Inbox::new();
        ib.push_frame(7, b"hello", true);
        assert_eq!(ib.recv(7, None).unwrap(), b"hello");
    }

    #[test]
    fn multi_frame_reassembly() {
        let ib = Inbox::new();
        ib.push_frame(1, b"ab", false);
        ib.push_frame(1, b"cd", false);
        assert_eq!(ib.try_recv(1).unwrap(), None, "incomplete stays hidden");
        ib.push_frame(1, b"ef", true);
        assert_eq!(ib.recv(1, None).unwrap(), b"abcdef");
    }

    #[test]
    fn tags_are_independent_fifo() {
        let ib = Inbox::new();
        ib.push_frame(1, b"x1", true);
        ib.push_frame(2, b"y", true);
        ib.push_frame(1, b"x2", true);
        assert_eq!(ib.recv(2, None).unwrap(), b"y");
        assert_eq!(ib.recv(1, None).unwrap(), b"x1");
        assert_eq!(ib.recv(1, None).unwrap(), b"x2");
        assert_eq!(ib.backlog(), 0);
    }

    #[test]
    fn recv_timeout() {
        let ib = Inbox::new();
        let err = ib.recv(9, Some(Duration::from_millis(60))).unwrap_err();
        assert!(matches!(err, CclError::Timeout(_)));
    }

    #[test]
    fn fail_wakes_blocked_receiver() {
        let ib = Arc::new(Inbox::new());
        let ib2 = ib.clone();
        let t = std::thread::spawn(move || ib2.recv(5, None));
        std::thread::sleep(Duration::from_millis(30));
        ib.fail(CclError::RemoteError { peer: 1, detail: "reset".into() });
        let res = t.join().unwrap();
        assert!(matches!(res, Err(CclError::RemoteError { peer: 1, .. })));
    }

    #[test]
    fn first_error_wins() {
        let ib = Inbox::new();
        ib.fail(CclError::RemoteError { peer: 2, detail: "reset".into() });
        ib.fail(CclError::Aborted("later".into()));
        assert!(matches!(ib.error(), Some(CclError::RemoteError { .. })));
    }

    #[test]
    fn messages_delivered_before_error_are_not_lost() {
        let ib = Inbox::new();
        ib.push_frame(3, b"data", true);
        ib.fail(CclError::Aborted("shutdown".into()));
        // Already-complete message still deliverable…
        assert_eq!(ib.recv(3, None).unwrap(), b"data");
        // …then the error surfaces.
        assert!(ib.recv(3, Some(Duration::from_millis(10))).is_err());
    }

    #[test]
    fn concurrent_producers_consumers() {
        let ib = Arc::new(Inbox::new());
        let producers: Vec<_> = (0..4u64)
            .map(|tag| {
                let ib = ib.clone();
                std::thread::spawn(move || {
                    for i in 0..50u32 {
                        ib.push_frame(tag, &i.to_le_bytes(), true);
                    }
                })
            })
            .collect();
        let consumers: Vec<_> = (0..4u64)
            .map(|tag| {
                let ib = ib.clone();
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    for _ in 0..50 {
                        let m = ib.recv(tag, Some(Duration::from_secs(5))).unwrap();
                        got.push(u32::from_le_bytes(m.try_into().unwrap()));
                    }
                    got
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        for c in consumers {
            let got = c.join().unwrap();
            assert_eq!(got, (0..50).collect::<Vec<_>>(), "per-tag FIFO preserved");
        }
    }
}
