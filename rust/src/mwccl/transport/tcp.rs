//! TCP link — the host-to-host transport.
//!
//! Peer death is *detectable* here: the reader thread sees EOF or
//! ECONNRESET and fails the inbox with [`CclError::RemoteError`], the
//! analogue of `ncclRemoteError` in §3.2 of the paper. An optional
//! shared [`RateLimiter`] emulates the testbed's 10 Gbps NIC.

use super::inbox::Inbox;
use super::ratelimit::RateLimiter;
use super::Link;
use crate::mwccl::error::{CclError, CclResult};
use crate::mwccl::wire::{
    decode_frame_hdr, encode_frame_hdr, FLAG_GOODBYE, FLAG_LAST, FLAG_PROLOGUE, FRAME_HDR,
    SEG_MAX,
};
use std::io::{IoSlice, Read, Write};
use std::net::{Shutdown, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// See module docs.
pub struct TcpLink {
    peer: usize,
    writer: Mutex<TcpStream>,
    stream: TcpStream, // kept for shutdown() on abort
    inbox: Arc<Inbox>,
    limiter: Option<Arc<RateLimiter>>,
    aborted: AtomicBool,
    reader: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl TcpLink {
    /// Wrap an established, already-identified stream.
    pub fn new(
        peer: usize,
        stream: TcpStream,
        limiter: Option<Arc<RateLimiter>>,
    ) -> CclResult<Self> {
        stream
            .set_nodelay(true)
            .map_err(|e| CclError::Transport(format!("nodelay: {e}")))?;
        let writer = stream
            .try_clone()
            .map_err(|e| CclError::Transport(format!("clone: {e}")))?;
        let read_half = stream
            .try_clone()
            .map_err(|e| CclError::Transport(format!("clone: {e}")))?;
        let inbox = Arc::new(Inbox::for_peer(peer));
        let inbox2 = inbox.clone();
        let reader = std::thread::Builder::new()
            .name(format!("tcp-rx-peer{peer}"))
            .spawn(move || reader_loop(read_half, inbox2, peer))
            .map_err(|e| CclError::Transport(format!("spawn: {e}")))?;
        Ok(TcpLink {
            peer,
            writer: Mutex::new(writer),
            stream,
            inbox,
            limiter,
            aborted: AtomicBool::new(false),
            reader: Mutex::new(Some(reader)),
        })
    }

    fn check_aborted(&self) -> CclResult<()> {
        if self.aborted.load(Ordering::Acquire) {
            Err(CclError::Aborted("tcp link aborted".into()))
        } else {
            Ok(())
        }
    }
}

fn reader_loop(mut stream: TcpStream, inbox: Arc<Inbox>, peer: usize) {
    let mut hdr = [0u8; FRAME_HDR];
    let mut payload = vec![0u8; SEG_MAX];
    loop {
        if let Err(e) = stream.read_exact(&mut hdr) {
            // EOF or reset: the remote side is gone. This is the
            // ncclRemoteError analogue — detectable on this path only.
            inbox.fail(CclError::RemoteError { peer, detail: e.to_string() });
            return;
        }
        let (tag, len, msg_len, flags) = decode_frame_hdr(&hdr);
        let len = len as usize;
        if len > SEG_MAX {
            // Corrupt header: same edge attribution and observability as
            // every other corruption class (transport.corrupt_frames is
            // THE signal dashboards and the chaos tests key on).
            crate::metrics::global().counter("transport.corrupt_frames").inc();
            crate::metrics::log_event(
                "transport.corrupt_frame",
                &[
                    ("peer", peer.to_string().as_str()),
                    ("tag", format!("{tag:#x}").as_str()),
                    ("detail", format!("oversized frame {len}").as_str()),
                ],
            );
            inbox.fail(CclError::RemoteError {
                peer,
                detail: format!("oversized frame {len}"),
            });
            return;
        }
        if let Err(e) = stream.read_exact(&mut payload[..len]) {
            inbox.fail(CclError::RemoteError { peer, detail: e.to_string() });
            return;
        }
        if flags & FLAG_GOODBYE != 0 {
            // The peer announced a deliberate teardown: it is alive and
            // chose to break the world (timeout, watchdog verdict).
            // Surface `Aborted`, not the death-implying `RemoteError`,
            // so failure attribution upstairs never convicts a live
            // rank on teardown evidence. (TCP goodbyes carry no reason
            // payload — tear-proofing; see `TcpLink::farewell`.)
            let reason = if len == 0 {
                "announced teardown".to_string()
            } else {
                String::from_utf8_lossy(&payload[..len]).into_owned()
            };
            inbox.fail(CclError::Aborted(format!("peer {peer} closed: {reason}")));
            return;
        }
        inbox.push_frame(tag, &payload[..len], msg_len as usize, flags);
    }
}

/// Write every byte of `pieces` with as few syscalls as possible:
/// one `write_vectored` covers header + payload fragments of a frame,
/// with a retry loop for short writes (vectored writes, like plain
/// `write`, may stop at any byte boundary).
fn write_all_vectored(w: &mut TcpStream, pieces: &[&[u8]], peer: usize) -> CclResult<()> {
    let io_err = |e: std::io::Error| CclError::RemoteError { peer, detail: e.to_string() };
    let mut idx = 0usize; // first piece not fully written
    let mut off = 0usize; // bytes of pieces[idx] already written
    loop {
        while idx < pieces.len() && off == pieces[idx].len() {
            idx += 1;
            off = 0;
        }
        if idx == pieces.len() {
            return Ok(());
        }
        let slices: Vec<IoSlice> = std::iter::once(IoSlice::new(&pieces[idx][off..]))
            .chain(pieces[idx + 1..].iter().map(|p| IoSlice::new(p)))
            .collect();
        let n = match w.write_vectored(&slices) {
            Ok(0) => {
                return Err(CclError::RemoteError {
                    peer,
                    detail: "write returned 0 (connection closed)".into(),
                })
            }
            Ok(n) => n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(io_err(e)),
        };
        let mut rem = n;
        while rem > 0 {
            let avail = pieces[idx].len() - off;
            if rem >= avail {
                rem -= avail;
                idx += 1;
                off = 0;
            } else {
                off += rem;
                rem = 0;
            }
        }
    }
}

impl Link for TcpLink {
    fn send(&self, tag: u64, parts: &[&[u8]]) -> CclResult<()> {
        self.check_aborted()?;
        let total: usize = parts.iter().map(|p| p.len()).sum();
        if total > u32::MAX as usize {
            return Err(CclError::InvalidUsage(format!(
                "message of {total} bytes exceeds the 4 GiB wire cap"
            )));
        }
        // Hold the writer for the whole logical message so frames of two
        // concurrent sends never interleave (reassembly contract).
        let mut w = self.writer.lock().unwrap();
        // Iterate the logical message in SEG_MAX slices that may span
        // `parts` boundaries; each frame goes out as one vectored write
        // (header + payload fragments), halving syscalls on the hot path
        // versus separate header/payload write_alls.
        let mut remaining = total;
        let mut part_idx = 0usize;
        let mut part_off = 0usize;
        if total == 0 {
            let mut hdr = [0u8; FRAME_HDR];
            encode_frame_hdr(&mut hdr, tag, 0, 0, FLAG_LAST);
            return write_all_vectored(&mut w, &[&hdr], self.peer);
        }
        while remaining > 0 {
            let seg = remaining.min(SEG_MAX);
            if let Some(rl) = &self.limiter {
                rl.acquire(seg + FRAME_HDR);
            }
            let flags = if seg == remaining { FLAG_LAST } else { 0 };
            let mut hdr = [0u8; FRAME_HDR];
            encode_frame_hdr(&mut hdr, tag, seg as u32, total as u32, flags);
            let mut pieces: Vec<&[u8]> = Vec::with_capacity(parts.len() + 1);
            pieces.push(&hdr);
            let mut seg_left = seg;
            while seg_left > 0 {
                let part = parts[part_idx];
                let avail = part.len() - part_off;
                let take = avail.min(seg_left);
                pieces.push(&part[part_off..part_off + take]);
                part_off += take;
                seg_left -= take;
                if part_off == part.len() {
                    part_idx += 1;
                    part_off = 0;
                }
            }
            write_all_vectored(&mut w, &pieces, self.peer)?;
            remaining -= seg;
        }
        Ok(())
    }

    fn send_prologue(&self, tag: u64, payload: &[u8]) -> CclResult<()> {
        self.check_aborted()?;
        if payload.len() > SEG_MAX {
            return Err(CclError::InvalidUsage(format!(
                "prologue of {} bytes exceeds one frame",
                payload.len()
            )));
        }
        let mut w = self.writer.lock().unwrap();
        if let Some(rl) = &self.limiter {
            rl.acquire(payload.len() + FRAME_HDR);
        }
        let mut hdr = [0u8; FRAME_HDR];
        encode_frame_hdr(
            &mut hdr,
            tag,
            payload.len() as u32,
            payload.len() as u32,
            FLAG_LAST | FLAG_PROLOGUE,
        );
        write_all_vectored(&mut w, &[&hdr, payload], self.peer)
    }

    fn recv_prologue(&self, tag: u64, timeout: Option<Duration>) -> CclResult<Vec<u8>> {
        self.inbox.recv_prologue(tag, timeout)
    }

    fn recv(&self, tag: u64, timeout: Option<Duration>) -> CclResult<Vec<u8>> {
        self.inbox.recv(tag, timeout)
    }

    fn try_recv(&self, tag: u64) -> CclResult<Option<Vec<u8>>> {
        self.inbox.try_recv(tag)
    }

    fn recycle(&self, buf: Vec<u8>) {
        self.inbox.recycle(buf);
    }

    fn send_raw_frame(&self, tag: u64, payload: &[u8], msg_len: u32, flags: u8) -> CclResult<()> {
        self.check_aborted()?;
        if payload.len() > SEG_MAX {
            return Err(CclError::InvalidUsage(format!(
                "raw frame of {} bytes exceeds one segment",
                payload.len()
            )));
        }
        let mut w = self.writer.lock().unwrap();
        if let Some(rl) = &self.limiter {
            rl.acquire(payload.len() + FRAME_HDR);
        }
        let mut hdr = [0u8; FRAME_HDR];
        encode_frame_hdr(&mut hdr, tag, payload.len() as u32, msg_len, flags);
        write_all_vectored(&mut w, &[&hdr, payload], self.peer)
    }

    fn farewell(&self, _reason: &str) {
        if self.aborted.load(Ordering::Acquire) {
            return;
        }
        // Best-effort only: a writer held by a stuck send must not make
        // the teardown path block — skip the goodbye and let the peer
        // see the socket close instead.
        let Ok(mut w) = self.writer.try_lock() else { return };
        // And a *wedged* peer must not either: earlier sends may have
        // filled the kernel send buffer and completed (releasing the
        // writer lock), so an unbounded write here could park the break
        // path forever — exactly the thread that was about to unblock
        // the application. Bound the write, keep the frame to a bare
        // header (no reason payload — it lives in the breaker's logs),
        // and make exactly ONE write attempt: a retry loop after a
        // partial write would widen the window for a torn frame, and a
        // torn goodbye followed by the close reads as peer death — the
        // misattribution this frame exists to prevent.
        let _ = w.set_write_timeout(Some(Duration::from_millis(50)));
        let mut hdr = [0u8; FRAME_HDR];
        encode_frame_hdr(&mut hdr, 0, 0, 0, FLAG_LAST | FLAG_GOODBYE);
        let _ = w.write(&hdr);
        let _ = w.set_write_timeout(None);
    }

    fn abort(&self, reason: &str) {
        if self.aborted.swap(true, Ordering::AcqRel) {
            return;
        }
        self.inbox.fail(CclError::Aborted(reason.to_string()));
        let _ = self.stream.shutdown(Shutdown::Both);
    }

    fn kind(&self) -> &'static str {
        "tcp"
    }

    fn peer(&self) -> usize {
        self.peer
    }
}

impl Drop for TcpLink {
    fn drop(&mut self) {
        self.abort("link dropped");
        if let Some(t) = self.reader.lock().unwrap().take() {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{read_tensor, write_tensor, Tensor};
    use crate::util::prng::Rng;
    use std::net::TcpListener;

    /// Build a connected pair of links over loopback.
    fn link_pair(limiter: Option<Arc<RateLimiter>>) -> (TcpLink, TcpLink) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || listener.accept().unwrap().0);
        let a_stream = TcpStream::connect(addr).unwrap();
        let b_stream = t.join().unwrap();
        let a = TcpLink::new(1, a_stream, limiter.clone()).unwrap();
        let b = TcpLink::new(0, b_stream, limiter).unwrap();
        (a, b)
    }

    #[test]
    fn small_message_roundtrip() {
        let (a, b) = link_pair(None);
        a.send(42, &[b"hello ", b"world"]).unwrap();
        assert_eq!(b.recv(42, Some(Duration::from_secs(2))).unwrap(), b"hello world");
    }

    #[test]
    fn large_message_segments_and_reassembles() {
        let (a, b) = link_pair(None);
        let mut rng = Rng::new(77);
        let t = Tensor::f32_1d(1_000_000, &mut rng); // 4 MB > SEG_MAX
        let mut framed = Vec::new();
        write_tensor(&mut framed, &t).unwrap();
        a.send(7, &[&framed]).unwrap();
        let got = b.recv(7, Some(Duration::from_secs(10))).unwrap();
        let back = read_tensor(&mut got.as_slice()).unwrap();
        assert_eq!(back.checksum(), t.checksum());
    }

    #[test]
    fn prologue_rides_its_own_lane() {
        let (a, b) = link_pair(None);
        // Data first, prologue second, same tag: both must be readable
        // from their own lanes in either order.
        a.send(6, &[b"data"]).unwrap();
        a.send_prologue(6, &[1]).unwrap();
        assert_eq!(
            b.recv_prologue(6, Some(Duration::from_secs(2))).unwrap(),
            vec![1]
        );
        assert_eq!(b.recv(6, Some(Duration::from_secs(2))).unwrap(), b"data");
    }

    #[test]
    fn empty_message() {
        let (a, b) = link_pair(None);
        a.send(1, &[]).unwrap();
        assert_eq!(b.recv(1, Some(Duration::from_secs(2))).unwrap(), b"");
    }

    #[test]
    fn many_fragments_one_vectored_message() {
        // Exercises the vectored-write path with a frame gathered from
        // many small parts, including empty ones.
        let (a, b) = link_pair(None);
        let parts: Vec<Vec<u8>> = (0..40u8).map(|i| vec![i; (i as usize) % 7]).collect();
        let refs: Vec<&[u8]> = parts.iter().map(|p| p.as_slice()).collect();
        let want: Vec<u8> = parts.iter().flatten().copied().collect();
        a.send(13, &refs).unwrap();
        assert_eq!(b.recv(13, Some(Duration::from_secs(2))).unwrap(), want);
    }

    #[test]
    fn recv_buffers_recycle_through_pool() {
        let (a, b) = link_pair(None);
        a.send(21, &[&[5u8; 4096]]).unwrap();
        let m = b.recv(21, Some(Duration::from_secs(2))).unwrap();
        assert_eq!(m.len(), 4096);
        b.recycle(m);
        // Next message lands in the recycled buffer without reallocating.
        a.send(22, &[&[6u8; 2048]]).unwrap();
        let m2 = b.recv(22, Some(Duration::from_secs(2))).unwrap();
        assert_eq!(m2.len(), 2048);
        assert!(m2.capacity() >= 2048);
    }

    #[test]
    fn bidirectional_concurrent() {
        let (a, b) = link_pair(None);
        let a = Arc::new(a);
        let b = Arc::new(b);
        let a2 = a.clone();
        let b2 = b.clone();
        let t1 = std::thread::spawn(move || {
            for i in 0..100u32 {
                a2.send(1, &[&i.to_le_bytes()]).unwrap();
            }
        });
        let t2 = std::thread::spawn(move || {
            for i in 0..100u32 {
                b2.send(2, &[&(i * 2).to_le_bytes()]).unwrap();
            }
        });
        for i in 0..100u32 {
            let m = b.recv(1, Some(Duration::from_secs(5))).unwrap();
            assert_eq!(u32::from_le_bytes(m.try_into().unwrap()), i);
            let m = a.recv(2, Some(Duration::from_secs(5))).unwrap();
            assert_eq!(u32::from_le_bytes(m.try_into().unwrap()), i * 2);
        }
        t1.join().unwrap();
        t2.join().unwrap();
    }

    #[test]
    fn peer_death_raises_remote_error() {
        let (a, b) = link_pair(None);
        drop(a); // "kill" the peer process
        let err = b.recv(9, Some(Duration::from_secs(2))).unwrap_err();
        assert!(
            matches!(err, CclError::RemoteError { .. }),
            "expected RemoteError (ncclRemoteError analogue), got {err:?}"
        );
    }

    #[test]
    fn abort_wakes_pending_recv() {
        let (_a, b) = link_pair(None);
        let b = Arc::new(b);
        let b2 = b.clone();
        let t = std::thread::spawn(move || b2.recv(3, None));
        std::thread::sleep(Duration::from_millis(30));
        b.abort("watchdog");
        assert!(matches!(t.join().unwrap(), Err(CclError::Aborted(_))));
    }

    #[test]
    fn farewell_turns_teardown_into_aborted_not_remote_error() {
        let (a, b) = link_pair(None);
        a.farewell("op timeout, breaking world");
        a.abort("breaking world");
        let err = b.recv(4, Some(Duration::from_secs(2))).unwrap_err();
        assert!(
            matches!(err, CclError::Aborted(_)),
            "announced teardown must not read as peer death, got {err:?}"
        );
    }

    #[test]
    fn truncated_raw_frame_is_detected_not_delivered() {
        let (a, b) = link_pair(None);
        // Claim 64 bytes, deliver 16 with LAST — a crash mid-message.
        a.send_raw_frame(7, &[9u8; 16], 64, FLAG_LAST).unwrap();
        let err = b.recv(7, Some(Duration::from_secs(2))).unwrap_err();
        assert!(
            matches!(err, CclError::RemoteError { peer: 0, .. }),
            "truncation must be edge-attributed, got {err:?}"
        );
    }

    #[test]
    fn rate_limiter_caps_throughput() {
        // 40 MB/s cap; send 2 MB => ≥ ~50 ms wall.
        let rl = Arc::new(RateLimiter::new(40.0e6));
        let (a, b) = link_pair(Some(rl));
        let payload = vec![0u8; 2_000_000];
        let t0 = std::time::Instant::now();
        a.send(5, &[&payload]).unwrap();
        let got = b.recv(5, Some(Duration::from_secs(10))).unwrap();
        let dt = t0.elapsed().as_secs_f64();
        assert_eq!(got.len(), payload.len());
        assert!(dt > 0.03, "rate limit not applied: {dt}s");
    }

    #[test]
    fn try_recv_nonblocking() {
        let (a, b) = link_pair(None);
        assert_eq!(b.try_recv(11).unwrap(), None);
        a.send(11, &[b"x"]).unwrap();
        // Poll until the reader thread lands it.
        let deadline = std::time::Instant::now() + Duration::from_secs(2);
        loop {
            if let Some(m) = b.try_recv(11).unwrap() {
                assert_eq!(m, b"x");
                break;
            }
            assert!(std::time::Instant::now() < deadline, "message never arrived");
            std::thread::yield_now();
        }
    }
}
