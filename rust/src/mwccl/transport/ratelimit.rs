//! Token-bucket rate limiter used to emulate the paper's 10 Gbps
//! host-to-host link on loopback TCP.
//!
//! Shareable (`Arc`) so several links on one simulated NIC contend for
//! the same bandwidth, as real senders on one host would.

use std::sync::Mutex;
use std::time::{Duration, Instant};

/// 10 Gbps in bytes/sec — the paper's inter-VM bandwidth.
pub const RATE_10GBPS: f64 = 10.0e9 / 8.0;

struct BucketState {
    tokens: f64,
    last: Instant,
}

/// Token bucket: `acquire(n)` blocks until `n` byte-tokens are available.
pub struct RateLimiter {
    rate_bps: f64,
    burst: f64,
    state: Mutex<BucketState>,
}

impl RateLimiter {
    /// `rate_bps` is bytes per second. Burst defaults to 4 ms of traffic
    /// (small enough that sub-second throughput measurements see the
    /// configured rate, large enough to amortize syscall jitter).
    pub fn new(rate_bps: f64) -> Self {
        assert!(rate_bps > 0.0);
        let burst = (rate_bps * 0.004).max(64.0 * 1024.0);
        RateLimiter {
            rate_bps,
            burst,
            state: Mutex::new(BucketState { tokens: burst, last: Instant::now() }),
        }
    }

    pub fn rate_bps(&self) -> f64 {
        self.rate_bps
    }

    /// Block until `n` bytes of budget are available, then consume them.
    /// Requests larger than the burst are drained in burst-sized bites.
    pub fn acquire(&self, n: usize) {
        let mut remaining = n as f64;
        while remaining > 0.0 {
            let bite = remaining.min(self.burst);
            self.acquire_bite(bite);
            remaining -= bite;
        }
    }

    fn acquire_bite(&self, bite: f64) {
        loop {
            let wait = {
                let mut st = self.state.lock().unwrap();
                let now = Instant::now();
                st.tokens = (st.tokens + now.duration_since(st.last).as_secs_f64() * self.rate_bps)
                    .min(self.burst);
                st.last = now;
                if st.tokens >= bite {
                    st.tokens -= bite;
                    return;
                }
                // Sleep just long enough for the deficit to refill.
                Duration::from_secs_f64((bite - st.tokens) / self.rate_bps)
            };
            std::thread::sleep(wait.min(Duration::from_millis(5)));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn enforces_rate_approximately() {
        // 100 MB/s, move 2 MB beyond the burst => ≥ ~16 ms.
        let rl = RateLimiter::new(100.0e6);
        let total = 2_000_000 + rl.burst as usize;
        let t0 = Instant::now();
        rl.acquire(total);
        let dt = t0.elapsed().as_secs_f64();
        let expect = 2_000_000.0 / 100.0e6;
        assert!(dt >= expect * 0.8, "too fast: {dt}s vs {expect}s");
        assert!(dt <= expect * 3.0 + 0.05, "too slow: {dt}s");
    }

    #[test]
    fn burst_passes_instantly() {
        let rl = RateLimiter::new(1.0e6);
        let t0 = Instant::now();
        rl.acquire(1024); // well under burst
        assert!(t0.elapsed() < Duration::from_millis(5));
    }

    #[test]
    fn shared_across_threads_sums_to_rate() {
        let rl = Arc::new(RateLimiter::new(50.0e6));
        // Drain the initial burst so the measurement starts cold.
        rl.acquire(rl.burst as usize);
        let t0 = Instant::now();
        let per_thread = 500_000usize;
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let rl = rl.clone();
                std::thread::spawn(move || rl.acquire(per_thread))
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let dt = t0.elapsed().as_secs_f64();
        let expect = (4.0 * per_thread as f64) / 50.0e6;
        assert!(dt >= expect * 0.7, "4 threads shared one bucket: {dt}s vs {expect}s");
    }
}
