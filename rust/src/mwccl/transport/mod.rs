//! Point-to-point transports beneath the collectives.
//!
//! Two real transports exist, chosen per world at rendezvous:
//!
//! * [`tcp::TcpLink`] — the *host-to-host* path. Peer death is visible:
//!   the kernel returns EOF/ECONNRESET and the link fails all pending
//!   receives with [`CclError::RemoteError`] (NCCL's `ncclRemoteError`).
//! * [`shm::ShmLink`] — the *intra-host* path (stands in for
//!   NVLink/shared-memory). A lock-free SPSC ring in an mmap'd file.
//!   Peer death is **silent**: no error, no wakeup — a pending receive
//!   waits forever until something above (the MultiWorld watchdog)
//!   aborts the link. This reproduces the failure-detection gap that
//!   motivates the paper's watchdog design.
//!
//! Both push received frames into a shared [`inbox::Inbox`] keyed by
//! tag, so `recv` order is decoupled from arrival order (needed for the
//! paper's "P4 must receive from P2 and P3 in arbitrary order" case).
//!
//! With a multi-host placement ([`crate::mwccl::HostMap`]), cross-host
//! edges do not get sockets of their own: they ride one shared
//! per-host-pair connection as independently flow-controlled *lanes*
//! ([`mux::LaneLink`]) — O(1) sockets per host pair no matter how many
//! worlds are minted.

pub mod fault;
pub mod inbox;
pub mod mux;
pub mod ratelimit;
pub mod shm;
pub mod tcp;

use super::error::{CclError, CclResult};
use std::time::Duration;

/// A bidirectional point-to-point channel to one peer rank.
pub trait Link: Send + Sync {
    /// Send one logical message (gathered from `parts`) under `tag`.
    /// Blocks only on transport backpressure.
    fn send(&self, tag: u64, parts: &[&[u8]]) -> CclResult<()>;

    /// Send a small control *prologue* under `tag`: one wire frame
    /// flagged `PROLOGUE`, delivered on the receiver's prologue lane so
    /// it can never be confused with a data message of the same tag
    /// (collectives negotiate e.g. the root's flat-vs-ring algorithm
    /// byte this way before the payload moves). `payload` must fit one
    /// frame.
    fn send_prologue(&self, tag: u64, payload: &[u8]) -> CclResult<()>;

    /// Block until a prologue with `tag` arrives (see
    /// [`Link::send_prologue`]).
    fn recv_prologue(&self, tag: u64, timeout: Option<Duration>) -> CclResult<Vec<u8>>;

    /// Block until a message with `tag` arrives; `timeout=None` waits
    /// until the link errors or is aborted.
    fn recv(&self, tag: u64, timeout: Option<Duration>) -> CclResult<Vec<u8>>;

    /// Non-blocking poll for a message with `tag`.
    fn try_recv(&self, tag: u64) -> CclResult<Option<Vec<u8>>>;

    /// Return a buffer obtained from `recv`/`try_recv` to the link's
    /// receive pool once its payload has been parsed, so the next
    /// message reuses the allocation. Optional — the default drops it.
    fn recycle(&self, _buf: Vec<u8>) {}

    /// Emit exactly one wire frame with caller-controlled header fields
    /// (`msg_len` and `flags` are written verbatim). This is the
    /// chaos-injection hook: [`fault::FaultLink`]'s truncate rule uses
    /// it to put a message on the wire whose `LAST` frame arrives short
    /// of the length every header claimed — the receiver's inbox must
    /// detect the contradiction (see [`inbox::Inbox::push_frame`]).
    /// Optional; transports without it refuse.
    fn send_raw_frame(
        &self,
        _tag: u64,
        _payload: &[u8],
        _msg_len: u32,
        _flags: u8,
    ) -> CclResult<()> {
        Err(CclError::InvalidUsage("raw frames unsupported on this transport".into()))
    }

    /// Best-effort *deliberate-teardown* announcement: write one
    /// `GOODBYE` frame so the peer's reader fails pending receives with
    /// [`CclError::Aborted`] (an alive rank said goodbye) instead of
    /// [`CclError::RemoteError`] (the rank died). Called by the world
    /// layer right before an announced break; must never block on a
    /// congested link (skip instead) and never error. Default: no-op.
    fn farewell(&self, _reason: &str) {}

    /// Abort everything pending on this link (local decision — watchdog
    /// or world teardown). Idempotent.
    fn abort(&self, reason: &str);

    /// Transport name for diagnostics ("tcp" / "shm").
    fn kind(&self) -> &'static str;

    /// Peer rank this link talks to.
    fn peer(&self) -> usize;
}
