//! Per-host-pair connection multiplexing: many world edges, one socket.
//!
//! Without this layer every cross-host world edge is its own TCP
//! connection, so minting N small worlds between two hosts (the
//! `fig5_online_instantiation` pattern — one edge world per new
//! replica) costs O(N) sockets and O(N) reader threads per host pair.
//! A [`MuxConn`] instead carries **all** worlds' edges between one pair
//! of hosts over a single shared socket:
//!
//! ```text
//! mux frame := lane:u64  ||  tag:u64 seg_len:u32 msg_len:u32 flags:u8 payload
//!              └ LANE_HDR ┘  └──────────── standard wire frame ──────────────┘
//! ```
//!
//! * A **lane** is one direction of one world edge: `lane =
//!   fnv1a(world, src_rank, dst_rank)` (remapped away from 0). Both
//!   directions of an edge get distinct ids, which is what lets an
//!   *intra-host* self-connection (`intra_over_mux`) share one loopback
//!   socket among all local pairs without cross-talk.
//! * Lane `0` is the **control lane**: credit-return records
//!   `[lane:u64, bytes:u64]`, nothing else.
//! * **Per-lane credit flow control**: each sending lane starts with
//!   [`LANE_WINDOW`] bytes of credit, spends payload bytes per frame
//!   *before* taking the shared writer lock, and earns them back when
//!   the receiver's consumer actually `recv`s the message. A world
//!   whose consumer wedges therefore stops *its own lane* after one
//!   window — the shared socket, and every sibling world on it, keeps
//!   flowing (no head-of-line blocking; asserted by the gray-failure
//!   suite).
//! * One reader thread per connection demultiplexes frames into
//!   per-lane [`Inbox`]es. Frames for a lane that has not registered
//!   yet (world init racing in the two processes) are parked and
//!   replayed on registration; the sender's credit window bounds the
//!   parked bytes per lane.
//!
//! Connections are process-global, keyed `(domain, my_host,
//! peer_host)` — the first world that needs a host pair establishes the
//! socket (lower host id listens, higher dials; the listen address is
//! announced through an in-process rendezvous map, mirroring how the
//! per-world store publishes per-rank addresses) and every later world
//! reuses it: socket count per host pair is O(1) in the number of
//! worlds (see [`stats`]). Establishment during world init walks host
//! pairs in ascending `(lo, hi)` order on every rank, which makes the
//! accept/dial graph acyclic — the smallest outstanding pair always has
//! both sides working on it.
//!
//! Failure semantics match [`super::tcp::TcpLink`] per lane: a
//! `GOODBYE` frame fails that lane with [`CclError::Aborted`]
//! (deliberate teardown), connection death fails **every** lane with
//! [`CclError::RemoteError`] — the whole host is the fault domain, which
//! is exactly the blast radius a real NIC/host failure has.

use super::inbox::Inbox;
use super::ratelimit::RateLimiter;
use super::Link;
use crate::mwccl::error::{CclError, CclResult};
use crate::mwccl::wire::{
    decode_frame_hdr, encode_frame_hdr, FLAG_GOODBYE, FLAG_LAST, FLAG_PROLOGUE, FRAME_HDR,
    LANE_HDR, SEG_MAX,
};
use once_cell::sync::{Lazy, OnceCell};
use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Outer framing prefix: the 8-byte lane id before each wire frame.
pub const MUX_LANE_HDR: usize = LANE_HDR;

/// Reserved control lane carrying credit returns.
pub const CONTROL_LANE: u64 = 0;

/// Per-lane send window: payload bytes that may be in flight (sent but
/// not yet consumed by the receiver's `recv`).
pub const LANE_WINDOW: usize = 4 << 20;

/// Directional lane id for the `src -> dst` edge of `world`. FNV-1a,
/// remapped off the control lane.
pub fn lane_id(world: &str, src: usize, dst: usize) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut eat = |b: u8| {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    };
    for b in world.as_bytes() {
        eat(*b);
    }
    for b in (src as u64).to_le_bytes() {
        eat(b);
    }
    for b in (dst as u64).to_le_bytes() {
        eat(b);
    }
    if h == CONTROL_LANE {
        1
    } else {
        h
    }
}

/// Sender-side credit window of one lane. The abort flag lives here —
/// shared by every [`LaneLink`] handle of the lane — so aborting through
/// any handle releases a sender blocked in `acquire`.
struct Credit {
    avail: Mutex<usize>,
    cv: Condvar,
    aborted: AtomicBool,
}

impl Credit {
    fn new() -> Credit {
        Credit {
            avail: Mutex::new(LANE_WINDOW),
            cv: Condvar::new(),
            aborted: AtomicBool::new(false),
        }
    }

    /// Spend `n` bytes of window, blocking until available. Gives up
    /// when the connection dies or the lane is aborted.
    fn acquire(&self, n: usize, dead: &AtomicBool) -> CclResult<()> {
        if n == 0 {
            return Ok(());
        }
        debug_assert!(n <= LANE_WINDOW, "frame larger than the lane window");
        let mut avail = self.avail.lock().unwrap();
        loop {
            if dead.load(Ordering::Acquire) {
                return Err(CclError::Transport("mux connection lost".into()));
            }
            if self.aborted.load(Ordering::Acquire) {
                return Err(CclError::Aborted("mux lane aborted".into()));
            }
            if *avail >= n {
                *avail -= n;
                return Ok(());
            }
            // Woken by credit returns; the timeout only bounds how long
            // a death/abort can go unnoticed.
            avail = self.cv.wait_timeout(avail, Duration::from_millis(50)).unwrap().0;
        }
    }

    fn release(&self, n: usize) {
        *self.avail.lock().unwrap() += n;
        self.cv.notify_all();
    }

    fn kick(&self) {
        self.cv.notify_all();
    }
}

/// A frame that arrived before its lane registered.
struct Parked {
    tag: u64,
    payload: Vec<u8>,
    msg_len: usize,
    flags: u8,
}

/// One endpoint of a shared per-host-pair connection (see module docs).
pub struct MuxConn {
    peer_host: usize,
    writer: Mutex<TcpStream>,
    /// Receiving lanes: lane id -> (peer rank, inbox).
    recv_lanes: Mutex<HashMap<u64, (usize, Arc<Inbox>)>>,
    /// Sending lanes' credit windows.
    send_credits: Mutex<HashMap<u64, Arc<Credit>>>,
    /// Frames for lanes not yet registered (bounded per lane by the
    /// sender's credit window).
    parked: Mutex<HashMap<u64, Vec<Parked>>>,
    /// Per-host egress NIC model (cross-host connections only).
    limiter: Option<Arc<RateLimiter>>,
    dead: AtomicBool,
    dead_detail: Mutex<Option<String>>,
}

impl MuxConn {
    /// Wrap an established stream pair (`writer` and `reader` are the
    /// two directions — the same socket for a host pair, the two ends
    /// of a loopback socket for an intra-host self-connection) and
    /// start the demux reader thread.
    fn spawn(
        peer_host: usize,
        writer: TcpStream,
        reader: TcpStream,
        limiter: Option<Arc<RateLimiter>>,
    ) -> CclResult<Arc<MuxConn>> {
        let _ = writer.set_nodelay(true);
        let conn = Arc::new(MuxConn {
            peer_host,
            writer: Mutex::new(writer),
            recv_lanes: Mutex::new(HashMap::new()),
            send_credits: Mutex::new(HashMap::new()),
            parked: Mutex::new(HashMap::new()),
            limiter,
            dead: AtomicBool::new(false),
            dead_detail: Mutex::new(None),
        });
        let c = conn.clone();
        std::thread::Builder::new()
            .name(format!("mux-rx-h{peer_host}"))
            .spawn(move || c.reader_loop(reader))
            .map_err(|e| CclError::InitFailure(format!("mux reader spawn: {e}")))?;
        Ok(conn)
    }

    /// Demultiplex frames into per-lane inboxes until the socket dies.
    fn reader_loop(&self, mut stream: TcpStream) {
        let mut hdr = [0u8; LANE_HDR + FRAME_HDR];
        loop {
            if stream.read_exact(&mut hdr).is_err() {
                self.fail("mux connection closed");
                return;
            }
            let lane = u64::from_le_bytes(hdr[0..8].try_into().unwrap());
            let (tag, seg, msg_len, flags) = decode_frame_hdr(&hdr[LANE_HDR..]);
            if seg as usize > SEG_MAX {
                crate::metrics::global().counter("transport.corrupt_frames").inc();
                self.fail(&format!("mux frame oversize: {seg} bytes"));
                return;
            }
            let mut payload = vec![0u8; seg as usize];
            if stream.read_exact(&mut payload).is_err() {
                self.fail("mux connection died mid-frame");
                return;
            }
            if lane == CONTROL_LANE {
                // Credit return: [lane:u64, bytes:u64].
                if payload.len() == 16 {
                    let l = u64::from_le_bytes(payload[0..8].try_into().unwrap());
                    let b = u64::from_le_bytes(payload[8..16].try_into().unwrap());
                    let credit = self.send_credits.lock().unwrap().get(&l).cloned();
                    if let Some(c) = credit {
                        c.release(b as usize);
                    }
                }
                continue;
            }
            // The parked lock serializes this check-and-park against
            // `lane_link`'s register-and-drain (lock order: parked, then
            // recv_lanes) — without it a frame could slip between a
            // failed lookup and a racing registration's drain.
            let mut parked = self.parked.lock().unwrap();
            let entry = self.recv_lanes.lock().unwrap().get(&lane).cloned();
            match entry {
                Some((_, inbox)) => {
                    drop(parked);
                    deliver(&inbox, tag, &payload, msg_len as usize, flags);
                }
                None => parked.entry(lane).or_default().push(Parked {
                    tag,
                    payload,
                    msg_len: msg_len as usize,
                    flags,
                }),
            }
        }
    }

    /// Terminal connection failure: every lane (current and future) sees
    /// `RemoteError` — host death takes every world on the pair down.
    fn fail(&self, detail: &str) {
        if self.dead.swap(true, Ordering::AcqRel) {
            return;
        }
        *self.dead_detail.lock().unwrap() = Some(detail.to_string());
        crate::metrics::log_event(
            "mux.conn_failed",
            &[("peer_host", self.peer_host.to_string().as_str()), ("detail", detail)],
        );
        for (peer, inbox) in self.recv_lanes.lock().unwrap().values() {
            inbox.fail(CclError::RemoteError { peer: *peer, detail: detail.to_string() });
        }
        for credit in self.send_credits.lock().unwrap().values() {
            credit.kick();
        }
        self.parked.lock().unwrap().clear();
    }

    fn dead_error(&self) -> CclError {
        let detail = self
            .dead_detail
            .lock()
            .unwrap()
            .clone()
            .unwrap_or_else(|| "mux connection lost".into());
        CclError::Transport(detail)
    }

    /// Write one mux frame. Credit (when given) is spent *before* the
    /// writer lock, so a window-starved lane blocks outside the shared
    /// socket and never holds siblings up.
    fn write_frame(
        &self,
        lane: u64,
        tag: u64,
        payload: &[u8],
        msg_len: u32,
        flags: u8,
        credit: Option<&Credit>,
    ) -> CclResult<()> {
        if self.dead.load(Ordering::Acquire) {
            return Err(self.dead_error());
        }
        if let Some(c) = credit {
            c.acquire(payload.len(), &self.dead)?;
        }
        if let Some(rl) = &self.limiter {
            rl.acquire(LANE_HDR + FRAME_HDR + payload.len());
        }
        let mut hdr = [0u8; LANE_HDR + FRAME_HDR];
        hdr[0..8].copy_from_slice(&lane.to_le_bytes());
        encode_frame_hdr(&mut hdr[LANE_HDR..], tag, payload.len() as u32, msg_len, flags);
        let mut w = self.writer.lock().unwrap();
        w.write_all(&hdr)
            .and_then(|_| w.write_all(payload))
            .map_err(|e| CclError::Transport(format!("mux write: {e}")))
    }

    /// Return `bytes` of credit for `lane` to the peer (consumption
    /// notification on the control lane).
    fn return_credit(&self, lane: u64, bytes: usize) {
        if bytes == 0 || self.dead.load(Ordering::Acquire) {
            return;
        }
        let mut payload = [0u8; 16];
        payload[0..8].copy_from_slice(&lane.to_le_bytes());
        payload[8..16].copy_from_slice(&(bytes as u64).to_le_bytes());
        let _ = self.write_frame(CONTROL_LANE, 0, &payload, 16, FLAG_LAST, None);
    }
}

/// Dispatch one frame into a lane's inbox (goodbye = deliberate
/// teardown of that lane only).
fn deliver(inbox: &Inbox, tag: u64, payload: &[u8], msg_len: usize, flags: u8) {
    if flags & FLAG_GOODBYE != 0 {
        let reason = String::from_utf8_lossy(payload).to_string();
        let detail = if reason.is_empty() { "peer said goodbye".to_string() } else { reason };
        inbox.fail(CclError::Aborted(detail));
    } else {
        inbox.push_frame(tag, payload, msg_len, flags);
    }
}

/// One world edge riding a shared [`MuxConn`] — the mux counterpart of
/// [`super::tcp::TcpLink`], implementing [`Link`] 1:1.
pub struct LaneLink {
    conn: Arc<MuxConn>,
    peer: usize,
    send_lane: u64,
    recv_lane: u64,
    inbox: Arc<Inbox>,
    credit: Arc<Credit>,
    /// Serializes whole logical messages on this lane (frames of two
    /// same-tag messages must not interleave); frames of *different*
    /// lanes interleave freely on the shared socket.
    msg_lock: Mutex<()>,
}

/// Open the `my_rank <-> peer_rank` edge of `world` over `conn`:
/// registers the receive lane (replaying any parked frames) and creates
/// the send-side credit window.
pub fn lane_link(
    conn: &Arc<MuxConn>,
    world: &str,
    my_rank: usize,
    peer_rank: usize,
) -> CclResult<Box<dyn Link>> {
    let send_lane = lane_id(world, my_rank, peer_rank);
    let recv_lane = lane_id(world, peer_rank, my_rank);
    let inbox = Arc::new(Inbox::for_peer(peer_rank));
    // Register, then drain anything that raced ahead — all under the
    // parked lock (same order as the reader: parked, then recv_lanes),
    // so no frame can land between the lookup miss and our drain.
    let parked = {
        let mut parked = conn.parked.lock().unwrap();
        conn.recv_lanes.lock().unwrap().insert(recv_lane, (peer_rank, inbox.clone()));
        parked.remove(&recv_lane)
    };
    if let Some(frames) = parked {
        for p in frames {
            deliver(&inbox, p.tag, &p.payload, p.msg_len, p.flags);
        }
    }
    if conn.dead.load(Ordering::Acquire) {
        inbox.fail(conn.dead_error());
    }
    let credit = conn
        .send_credits
        .lock()
        .unwrap()
        .entry(send_lane)
        .or_insert_with(|| Arc::new(Credit::new()))
        .clone();
    Ok(Box::new(LaneLink {
        conn: conn.clone(),
        peer: peer_rank,
        send_lane,
        recv_lane,
        inbox,
        credit,
        msg_lock: Mutex::new(()),
    }))
}

impl Link for LaneLink {
    fn send(&self, tag: u64, parts: &[&[u8]]) -> CclResult<()> {
        let total: usize = parts.iter().map(|p| p.len()).sum();
        if total > u32::MAX as usize {
            return Err(CclError::InvalidUsage(format!("message too large: {total}")));
        }
        let _msg = self.msg_lock.lock().unwrap();
        if total == 0 {
            return self.conn.write_frame(
                self.send_lane,
                tag,
                &[],
                0,
                FLAG_LAST,
                Some(&self.credit),
            );
        }
        // Gather `parts` into SEG_MAX segments (one copy per segment —
        // the frame needs contiguous payload behind the shared socket).
        let mut seg = Vec::with_capacity(SEG_MAX.min(total));
        let mut sent = 0usize;
        for part in parts {
            let mut off = 0usize;
            while off < part.len() {
                let take = (SEG_MAX - seg.len()).min(part.len() - off);
                seg.extend_from_slice(&part[off..off + take]);
                off += take;
                sent += take;
                if seg.len() == SEG_MAX || sent == total {
                    let flags = if sent == total { FLAG_LAST } else { 0 };
                    self.conn.write_frame(
                        self.send_lane,
                        tag,
                        &seg,
                        total as u32,
                        flags,
                        Some(&self.credit),
                    )?;
                    seg.clear();
                }
            }
        }
        Ok(())
    }

    fn send_prologue(&self, tag: u64, payload: &[u8]) -> CclResult<()> {
        if payload.len() > SEG_MAX {
            return Err(CclError::InvalidUsage("prologue exceeds one frame".into()));
        }
        let _msg = self.msg_lock.lock().unwrap();
        self.conn.write_frame(
            self.send_lane,
            tag,
            payload,
            payload.len() as u32,
            FLAG_LAST | FLAG_PROLOGUE,
            Some(&self.credit),
        )
    }

    fn recv_prologue(&self, tag: u64, timeout: Option<Duration>) -> CclResult<Vec<u8>> {
        let buf = self.inbox.recv_prologue(tag, timeout)?;
        self.conn.return_credit(self.recv_lane, buf.len());
        Ok(buf)
    }

    fn recv(&self, tag: u64, timeout: Option<Duration>) -> CclResult<Vec<u8>> {
        let buf = self.inbox.recv(tag, timeout)?;
        self.conn.return_credit(self.recv_lane, buf.len());
        Ok(buf)
    }

    fn try_recv(&self, tag: u64) -> CclResult<Option<Vec<u8>>> {
        match self.inbox.try_recv(tag)? {
            Some(buf) => {
                self.conn.return_credit(self.recv_lane, buf.len());
                Ok(Some(buf))
            }
            None => Ok(None),
        }
    }

    fn recycle(&self, buf: Vec<u8>) {
        self.inbox.recycle(buf);
    }

    fn send_raw_frame(&self, tag: u64, payload: &[u8], msg_len: u32, flags: u8) -> CclResult<()> {
        // Chaos hook (truncate injection): header fields pass verbatim.
        self.conn.write_frame(
            self.send_lane,
            tag,
            payload,
            msg_len,
            flags,
            Some(&self.credit),
        )
    }

    fn farewell(&self, reason: &str) {
        // Best-effort, never blocking behind a congested lane: skip if
        // the shared writer is busy (the store-side teardown signal
        // still lands). Bare GOODBYE header + short reason; no credit
        // spend (the peer fails the lane instead of consuming).
        if self.conn.dead.load(Ordering::Acquire) {
            return;
        }
        let Ok(w) = self.conn.writer.try_lock() else {
            return;
        };
        let reason = &reason.as_bytes()[..reason.len().min(128)];
        let mut hdr = [0u8; LANE_HDR + FRAME_HDR];
        hdr[0..8].copy_from_slice(&self.send_lane.to_le_bytes());
        encode_frame_hdr(
            &mut hdr[LANE_HDR..],
            0,
            reason.len() as u32,
            reason.len() as u32,
            FLAG_LAST | FLAG_GOODBYE,
        );
        let mut w = w;
        let _ = w.set_write_timeout(Some(Duration::from_millis(50)));
        let _ = w.write_all(&hdr).and_then(|_| w.write_all(reason));
        let _ = w.set_write_timeout(None);
    }

    fn abort(&self, reason: &str) {
        if self.credit.aborted.swap(true, Ordering::AcqRel) {
            return;
        }
        self.inbox.fail(CclError::Aborted(reason.to_string()));
        self.credit.kick();
    }

    fn kind(&self) -> &'static str {
        "mux"
    }

    fn peer(&self) -> usize {
        self.peer
    }
}

impl Drop for LaneLink {
    fn drop(&mut self) {
        // The connection outlives the world; only this edge's lane state
        // is retired.
        self.conn.recv_lanes.lock().unwrap().remove(&self.recv_lane);
        self.conn.send_credits.lock().unwrap().remove(&self.send_lane);
        self.conn.parked.lock().unwrap().remove(&self.recv_lane);
    }
}

type ConnKey = (String, usize, usize);

/// Established (or establishing) connections, one per `(domain,
/// my_host, peer_host)` endpoint. The `OnceCell` serializes racing
/// establishers: one rank does the socket work, siblings block until
/// the connection exists.
static CONNS: Lazy<Mutex<HashMap<ConnKey, Arc<OnceCell<Arc<MuxConn>>>>>> =
    Lazy::new(|| Mutex::new(HashMap::new()));

/// In-process rendezvous for listen addresses, keyed `(domain, lo, hi)`.
static ADDRS: Lazy<Mutex<HashMap<ConnKey, SocketAddr>>> = Lazy::new(|| Mutex::new(HashMap::new()));

/// Per-host egress NIC limiters, keyed `(domain, host)` — every
/// cross-host connection of one host shares its NIC.
static LIMITERS: Lazy<Mutex<HashMap<(String, usize), Arc<RateLimiter>>>> =
    Lazy::new(|| Mutex::new(HashMap::new()));

/// Get (establishing on first use) the shared connection from `my_host`
/// to `peer_host` in `domain`. `egress_bps`, when set, models each
/// host's NIC: all of `my_host`'s *cross-host* connections in the
/// domain share one rate limiter (the first rate given wins).
pub fn ensure_conn(
    domain: &str,
    my_host: usize,
    peer_host: usize,
    egress_bps: Option<f64>,
    timeout: Duration,
) -> CclResult<Arc<MuxConn>> {
    let cell = CONNS
        .lock()
        .unwrap()
        .entry((domain.to_string(), my_host, peer_host))
        .or_default()
        .clone();
    cell.get_or_try_init(|| establish(domain, my_host, peer_host, egress_bps, timeout))
        .cloned()
}

fn establish(
    domain: &str,
    my_host: usize,
    peer_host: usize,
    egress_bps: Option<f64>,
    timeout: Duration,
) -> CclResult<Arc<MuxConn>> {
    let limiter = match egress_bps {
        Some(bps) if my_host != peer_host => Some(
            LIMITERS
                .lock()
                .unwrap()
                .entry((domain.to_string(), my_host))
                .or_insert_with(|| Arc::new(RateLimiter::new(bps)))
                .clone(),
        ),
        _ => None,
    };
    crate::metrics::log_event(
        "mux.conn_established",
        &[
            ("domain", domain),
            ("host", my_host.to_string().as_str()),
            ("peer_host", peer_host.to_string().as_str()),
        ],
    );
    if my_host == peer_host {
        // Intra-host self-connection (`intra_over_mux`): one loopback
        // socket whose two ends are this endpoint's writer and reader —
        // directional lane ids keep local pairs apart.
        let listener = TcpListener::bind("127.0.0.1:0")
            .map_err(|e| CclError::InitFailure(format!("mux self bind: {e}")))?;
        let addr = listener.local_addr().map_err(|e| CclError::InitFailure(e.to_string()))?;
        let writer = TcpStream::connect(addr)
            .map_err(|e| CclError::InitFailure(format!("mux self dial: {e}")))?;
        let (reader, _) = listener
            .accept()
            .map_err(|e| CclError::InitFailure(format!("mux self accept: {e}")))?;
        return MuxConn::spawn(peer_host, writer, reader, limiter);
    }
    let pair = (domain.to_string(), my_host.min(peer_host), my_host.max(peer_host));
    let stream = if my_host < peer_host {
        let listener = TcpListener::bind("127.0.0.1:0")
            .map_err(|e| CclError::InitFailure(format!("mux bind: {e}")))?;
        let addr = listener.local_addr().map_err(|e| CclError::InitFailure(e.to_string()))?;
        ADDRS.lock().unwrap().insert(pair, addr);
        accept_deadline(&listener, timeout)?
    } else {
        let deadline = Instant::now() + timeout;
        let addr = loop {
            if let Some(a) = ADDRS.lock().unwrap().get(&pair).copied() {
                break a;
            }
            if Instant::now() >= deadline {
                return Err(CclError::InitFailure(format!(
                    "mux: no listener for host pair {}-{} in domain {domain:?}",
                    pair.1, pair.2
                )));
            }
            std::thread::sleep(Duration::from_millis(1));
        };
        TcpStream::connect_timeout(&addr, timeout)
            .map_err(|e| CclError::InitFailure(format!("mux dial host {peer_host}: {e}")))?
    };
    let reader = stream
        .try_clone()
        .map_err(|e| CclError::InitFailure(format!("mux clone: {e}")))?;
    MuxConn::spawn(peer_host, stream, reader, limiter)
}

fn accept_deadline(listener: &TcpListener, timeout: Duration) -> CclResult<TcpStream> {
    // Kernel-blocking accept with a deadline — no sleep-poll loop.
    crate::util::accept_deadline(listener, Instant::now() + timeout)
        .map_err(|e| CclError::InitFailure(format!("mux accept: {e}")))
}

/// Socket-scaling observability for one mux domain.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MuxStats {
    /// Established connection *endpoints* in the domain (each host pair
    /// within one process contributes two — one per side).
    pub conns: usize,
    /// Currently registered receive lanes across those connections.
    pub lanes: usize,
}

/// Count the domain's established connections and live lanes — the
/// world-mint scaling assertion (`conns` must stay flat while worlds,
/// and therefore `lanes`, grow).
pub fn stats(domain: &str) -> MuxStats {
    let conns: Vec<Arc<MuxConn>> = CONNS
        .lock()
        .unwrap()
        .iter()
        .filter(|((d, _, _), _)| d == domain)
        .filter_map(|(_, cell)| cell.get().cloned())
        .collect();
    MuxStats {
        conns: conns.len(),
        lanes: conns.iter().map(|c| c.recv_lanes.lock().unwrap().len()).sum(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pair(domain: &str) -> (Arc<MuxConn>, Arc<MuxConn>) {
        let d = domain.to_string();
        let t = {
            let d = d.clone();
            std::thread::spawn(move || ensure_conn(&d, 0, 1, None, Duration::from_secs(5)))
        };
        let b = ensure_conn(&d, 1, 0, None, Duration::from_secs(5)).unwrap();
        (t.join().unwrap().unwrap(), b)
    }

    #[test]
    fn lane_ids_directional_and_nonzero() {
        let ab = lane_id("w", 0, 1);
        let ba = lane_id("w", 1, 0);
        assert_ne!(ab, ba, "directions must not share a lane");
        assert_ne!(ab, CONTROL_LANE);
        assert_ne!(lane_id("w", 0, 1), lane_id("w2", 0, 1), "worlds must not share a lane");
    }

    #[test]
    fn roundtrip_and_sibling_isolation() {
        let (a, b) = pair("mux-test-rt");
        let a1 = lane_link(&a, "w1", 0, 1).unwrap();
        let b1 = lane_link(&b, "w1", 1, 0).unwrap();
        let a2 = lane_link(&a, "w2", 0, 1).unwrap();
        let b2 = lane_link(&b, "w2", 1, 0).unwrap();
        a1.send(7, &[b"hello ", b"world"]).unwrap();
        a2.send(7, &[b"other"]).unwrap();
        b2.send(9, &[b"back"]).unwrap();
        assert_eq!(b1.recv(7, Some(Duration::from_secs(2))).unwrap(), b"hello world");
        assert_eq!(b2.recv(7, Some(Duration::from_secs(2))).unwrap(), b"other");
        assert_eq!(a2.recv(9, Some(Duration::from_secs(2))).unwrap(), b"back");
        let s = stats("mux-test-rt");
        assert_eq!(s.conns, 2, "one endpoint per side, shared by both worlds");
        assert_eq!(s.lanes, 4);
    }

    #[test]
    fn large_message_segments() {
        let (a, b) = pair("mux-test-large");
        let tx = lane_link(&a, "big", 0, 1).unwrap();
        let rx = lane_link(&b, "big", 1, 0).unwrap();
        let payload: Vec<u8> = (0..3 * SEG_MAX + 123).map(|i| (i % 251) as u8).collect();
        let p = payload.clone();
        let t = std::thread::spawn(move || tx.send(42, &[&p]));
        let got = rx.recv(42, Some(Duration::from_secs(5))).unwrap();
        t.join().unwrap().unwrap();
        assert_eq!(got, payload);
    }

    #[test]
    fn parked_frames_replay_on_late_registration() {
        let (a, b) = pair("mux-test-park");
        let tx = lane_link(&a, "early", 0, 1).unwrap();
        tx.send(3, &[b"raced ahead"]).unwrap();
        tx.send_prologue(4, &[9]).unwrap();
        std::thread::sleep(Duration::from_millis(50));
        let rx = lane_link(&b, "early", 1, 0).unwrap();
        assert_eq!(rx.recv(3, Some(Duration::from_secs(2))).unwrap(), b"raced ahead");
        assert_eq!(rx.recv_prologue(4, Some(Duration::from_secs(2))).unwrap(), vec![9]);
    }

    #[test]
    fn credit_starved_lane_does_not_block_siblings() {
        let (a, b) = pair("mux-test-credit");
        let slow_tx = lane_link(&a, "slow", 0, 1).unwrap();
        let _slow_rx = lane_link(&b, "slow", 1, 0).unwrap(); // never recvs
        let fast_tx = lane_link(&a, "fast", 0, 1).unwrap();
        let fast_rx = lane_link(&b, "fast", 1, 0).unwrap();
        // Exhaust the slow lane's window from a background thread; it
        // must block in credit acquisition, not on the shared socket.
        let blocked = Arc::new(AtomicBool::new(false));
        let flag = blocked.clone();
        let t = std::thread::spawn(move || {
            let chunk = vec![0u8; 1 << 20];
            for _ in 0..(LANE_WINDOW / (1 << 20)) {
                slow_tx.send(1, &[&chunk]).unwrap();
            }
            flag.store(true, Ordering::Release);
            // One past the window: parks in Credit::acquire until abort.
            let _ = slow_tx.send(2, &[&chunk]);
        });
        let deadline = Instant::now() + Duration::from_secs(5);
        while !blocked.load(Ordering::Acquire) && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(blocked.load(Ordering::Acquire), "window never filled");
        std::thread::sleep(Duration::from_millis(50)); // let the extra send hit the wall
        // The sibling lane flows while the slow lane is starved.
        fast_tx.send(5, &[b"unblocked"]).unwrap();
        assert_eq!(
            fast_rx.recv(5, Some(Duration::from_secs(2))).unwrap(),
            b"unblocked",
            "sibling lane must not be head-of-line blocked"
        );
        // Cleanup: abort the starved sender so its thread exits.
        // (abort is on the Link impl; reach it through a fresh handle's
        // credit — the lane's credit object is shared.)
        let again = lane_link(&a, "slow", 0, 1).unwrap();
        again.abort("test cleanup");
        t.join().unwrap();
    }

    #[test]
    fn goodbye_aborts_one_lane_conn_death_fails_all() {
        let (a, b) = pair("mux-test-bye");
        let a1 = lane_link(&a, "bye1", 0, 1).unwrap();
        let b1 = lane_link(&b, "bye1", 1, 0).unwrap();
        let b2 = lane_link(&b, "bye2", 1, 0).unwrap();
        a1.farewell("done here");
        let err = b1.recv(1, Some(Duration::from_secs(2))).unwrap_err();
        assert!(matches!(err, CclError::Aborted(_)), "goodbye => Aborted, got {err:?}");
        // Sibling lane is untouched by the goodbye.
        assert!(matches!(
            b2.recv(1, Some(Duration::from_millis(50))),
            Err(CclError::Timeout(_))
        ));
        // Now kill the whole connection: every lane sees RemoteError.
        a.fail("host down");
        // a's writer is dead from b's perspective once the socket drops;
        // emulate by failing b's endpoint directly too (single-process
        // registry shares no kernel-level teardown ordering guarantee).
        b.fail("host down");
        assert!(matches!(
            b2.recv(1, Some(Duration::from_secs(2))),
            Err(CclError::RemoteError { peer: 0, .. })
        ));
    }

    #[test]
    fn self_connection_multiplexes_local_pairs() {
        let conn = ensure_conn("mux-test-self", 3, 3, None, Duration::from_secs(5)).unwrap();
        let l01 = lane_link(&conn, "lw", 0, 1).unwrap();
        let l10 = lane_link(&conn, "lw", 1, 0).unwrap();
        l01.send(2, &[b"down"]).unwrap();
        l10.send(2, &[b"up"]).unwrap();
        assert_eq!(l10.recv(2, Some(Duration::from_secs(2))).unwrap(), b"down");
        assert_eq!(l01.recv(2, Some(Duration::from_secs(2))).unwrap(), b"up");
    }
}
