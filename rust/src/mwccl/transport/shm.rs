//! Shared-memory ring transport — the intra-host path (the analogue of
//! NCCL's shared-memory/NVLink channel).
//!
//! One `ShmLink` owns a *pair* of SPSC rings in mmap'd files (one per
//! direction). The ring is a classic head/tail byte ring: the producer
//! advances `head`, the consumer advances `tail`, frames wrap around the
//! capacity.
//!
//! **Deliberate semantics: peer death is silent.** There is no liveness
//! word in the ring and no I/O event when the peer exits — a pending
//! `recv` just waits, exactly like NCCL over shared memory ("the
//! communication via shared memory does not raise any exception even in
//! the presence of a failure", §3.2). The only ways out are local
//! [`Link::abort`] — which is what the MultiWorld watchdog calls — or a
//! caller-supplied timeout.

use super::inbox::Inbox;
use super::Link;
use crate::mwccl::error::{CclError, CclResult};
use crate::mwccl::wire::{
    decode_frame_hdr, encode_frame_hdr, FLAG_GOODBYE, FLAG_LAST, FLAG_PROLOGUE, FRAME_HDR,
    SEG_MAX,
};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Default ring capacity (per direction).
pub const DEFAULT_RING_BYTES: usize = 4 * 1024 * 1024;

const MAGIC: u64 = 0x4D57_52494E4731; // "MWRING1"
const HDR_BYTES: usize = 64;

/// A single mmap'd SPSC ring. `head`/`tail` are free-running cursors
/// (never wrapped) so fill level is simply `head - tail`.
struct Ring {
    ptr: *mut u8,
    map_len: usize,
    capacity: usize,
    path: PathBuf,
    owner: bool,
}

// The raw pointer is to MAP_SHARED memory; synchronization is done via
// the atomic cursors, single-producer/single-consumer per direction.
unsafe impl Send for Ring {}
unsafe impl Sync for Ring {}

impl Ring {
    fn create(path: &Path, capacity: usize) -> CclResult<Ring> {
        let map_len = HDR_BYTES + capacity;
        let file = open_shm(path, true, map_len)?;
        let ptr = map_shm(file, map_len)?;
        let ring = Ring { ptr, map_len, capacity, path: path.to_path_buf(), owner: true };
        // Initialize cursors before publishing the magic.
        ring.cap_slot().store(capacity as u64, Ordering::Relaxed);
        ring.head().store(0, Ordering::Relaxed);
        ring.tail().store(0, Ordering::Relaxed);
        ring.magic().store(MAGIC, Ordering::Release);
        Ok(ring)
    }

    fn attach(path: &Path, timeout: Duration) -> CclResult<Ring> {
        let deadline = std::time::Instant::now() + timeout;
        loop {
            if path.exists() {
                if let Ok(meta) = std::fs::metadata(path) {
                    let map_len = meta.len() as usize;
                    if map_len > HDR_BYTES {
                        let file = open_shm(path, false, map_len)?;
                        let ptr = map_shm(file, map_len)?;
                        let ring = Ring {
                            ptr,
                            map_len,
                            capacity: map_len - HDR_BYTES,
                            path: path.to_path_buf(),
                            owner: false,
                        };
                        if ring.magic().load(Ordering::Acquire) == MAGIC {
                            let cap = ring.cap_slot().load(Ordering::Relaxed) as usize;
                            if cap != ring.capacity {
                                return Err(CclError::InitFailure(format!(
                                    "ring capacity mismatch: file says {cap}, mapped {}",
                                    ring.capacity
                                )));
                            }
                            return Ok(ring);
                        }
                        // Not initialized yet; unmap and retry.
                        drop(ring);
                    }
                }
            }
            if std::time::Instant::now() >= deadline {
                return Err(CclError::InitFailure(format!(
                    "shm ring {} never appeared",
                    path.display()
                )));
            }
            std::thread::sleep(Duration::from_millis(5));
        }
    }

    #[inline]
    fn magic(&self) -> &AtomicU64 {
        unsafe { &*(self.ptr as *const AtomicU64) }
    }

    #[inline]
    fn cap_slot(&self) -> &AtomicU64 {
        unsafe { &*(self.ptr.add(8) as *const AtomicU64) }
    }

    #[inline]
    fn head(&self) -> &AtomicU64 {
        unsafe { &*(self.ptr.add(16) as *const AtomicU64) }
    }

    #[inline]
    fn tail(&self) -> &AtomicU64 {
        unsafe { &*(self.ptr.add(24) as *const AtomicU64) }
    }

    #[inline]
    fn data(&self) -> *mut u8 {
        unsafe { self.ptr.add(HDR_BYTES) }
    }

    /// Copy `src` into the ring at free-running offset `at` (wrapping).
    fn write_at(&self, at: u64, src: &[u8]) {
        let cap = self.capacity;
        let off = (at % cap as u64) as usize;
        let first = src.len().min(cap - off);
        unsafe {
            std::ptr::copy_nonoverlapping(src.as_ptr(), self.data().add(off), first);
            if first < src.len() {
                std::ptr::copy_nonoverlapping(
                    src.as_ptr().add(first),
                    self.data(),
                    src.len() - first,
                );
            }
        }
    }

    /// Copy out of the ring at free-running offset `at` (wrapping).
    fn read_at(&self, at: u64, dst: &mut [u8]) {
        let cap = self.capacity;
        let off = (at % cap as u64) as usize;
        let first = dst.len().min(cap - off);
        unsafe {
            std::ptr::copy_nonoverlapping(self.data().add(off), dst.as_mut_ptr(), first);
            if first < dst.len() {
                std::ptr::copy_nonoverlapping(
                    self.data(),
                    dst.as_mut_ptr().add(first),
                    dst.len() - first,
                );
            }
        }
    }
}

impl Drop for Ring {
    fn drop(&mut self) {
        unsafe {
            libc::munmap(self.ptr as *mut libc::c_void, self.map_len);
        }
        if self.owner {
            let _ = std::fs::remove_file(&self.path);
        }
    }
}

fn open_shm(path: &Path, create: bool, len: usize) -> CclResult<i32> {
    use std::os::unix::ffi::OsStrExt;
    let cstr = std::ffi::CString::new(path.as_os_str().as_bytes())
        .map_err(|e| CclError::InitFailure(format!("bad path: {e}")))?;
    let flags = if create { libc::O_RDWR | libc::O_CREAT } else { libc::O_RDWR };
    let fd = unsafe { libc::open(cstr.as_ptr(), flags, 0o600) };
    if fd < 0 {
        return Err(CclError::InitFailure(format!(
            "open {} failed: {}",
            path.display(),
            std::io::Error::last_os_error()
        )));
    }
    if create {
        let rc = unsafe { libc::ftruncate(fd, len as libc::off_t) };
        if rc != 0 {
            unsafe { libc::close(fd) };
            return Err(CclError::InitFailure(format!(
                "ftruncate: {}",
                std::io::Error::last_os_error()
            )));
        }
    }
    Ok(fd)
}

fn map_shm(fd: i32, len: usize) -> CclResult<*mut u8> {
    let ptr = unsafe {
        libc::mmap(
            std::ptr::null_mut(),
            len,
            libc::PROT_READ | libc::PROT_WRITE,
            libc::MAP_SHARED,
            fd,
            0,
        )
    };
    unsafe { libc::close(fd) };
    if ptr == libc::MAP_FAILED {
        return Err(CclError::InitFailure(format!(
            "mmap: {}",
            std::io::Error::last_os_error()
        )));
    }
    Ok(ptr as *mut u8)
}

/// The bidirectional shared-memory link (a TX ring and an RX ring).
pub struct ShmLink {
    peer: usize,
    tx: Arc<Ring>,
    rx: Arc<Ring>,
    inbox: Arc<Inbox>,
    aborted: Arc<AtomicBool>,
    send_lock: Mutex<()>,
    reader: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl ShmLink {
    /// Create or attach the ring pair for (me, peer) under `dir`.
    ///
    /// File naming is symmetric: the i→j direction lives in
    /// `mw-<world>-<i>to<j>.ring`. The *creator* side makes both files;
    /// the other side attaches. Creator is the lower rank.
    pub fn connect(
        dir: &Path,
        world: &str,
        me: usize,
        peer: usize,
        ring_bytes: usize,
        timeout: Duration,
    ) -> CclResult<Self> {
        let name = |from: usize, to: usize| dir.join(format!("mw-{world}-{from}to{to}.ring"));
        let (tx, rx) = if me < peer {
            let tx = Ring::create(&name(me, peer), ring_bytes)?;
            let rx = Ring::create(&name(peer, me), ring_bytes)?;
            (tx, rx)
        } else {
            let tx = Ring::attach(&name(me, peer), timeout)?;
            let rx = Ring::attach(&name(peer, me), timeout)?;
            (tx, rx)
        };
        let tx = Arc::new(tx);
        let rx = Arc::new(rx);
        let inbox = Arc::new(Inbox::for_peer(peer));
        let aborted = Arc::new(AtomicBool::new(false));
        let reader = {
            let rx = rx.clone();
            let inbox = inbox.clone();
            let aborted = aborted.clone();
            std::thread::Builder::new()
                .name(format!("shm-rx-peer{peer}"))
                .spawn(move || reader_loop(rx, inbox, aborted, peer))
                .map_err(|e| CclError::Transport(format!("spawn: {e}")))?
        };
        Ok(ShmLink {
            peer,
            tx,
            rx,
            inbox,
            aborted,
            send_lock: Mutex::new(()),
            reader: Mutex::new(Some(reader)),
        })
    }

    /// Free bytes in the TX ring.
    fn tx_free(&self) -> usize {
        let head = self.tx.head().load(Ordering::Acquire);
        let tail = self.tx.tail().load(Ordering::Acquire);
        self.tx.capacity - (head - tail) as usize
    }

    /// Largest single-frame payload this ring accepts: segments must fit
    /// with room for ≥2 frames in flight, or a message bigger than the
    /// ring would wait forever for space that can never exist. The one
    /// definition every send path (send, prologue, raw frame) shares.
    fn max_seg(&self) -> usize {
        SEG_MAX
            .min((self.tx.capacity.saturating_sub(2 * FRAME_HDR)) / 2)
            .max(1024)
    }

    /// Write one frame with caller-controlled header fields. Caller
    /// holds the send lock. `may_block` waits for ring space (aborting
    /// breaks the wait); otherwise a full ring skips the frame.
    fn ring_frame(
        &self,
        tag: u64,
        payload: &[u8],
        msg_len: u32,
        flags: u8,
        may_block: bool,
    ) -> CclResult<()> {
        let max_seg = self.max_seg();
        if payload.len() > max_seg {
            return Err(CclError::InvalidUsage(format!(
                "raw frame of {} bytes exceeds one segment (max {max_seg})",
                payload.len()
            )));
        }
        let need = FRAME_HDR + payload.len();
        let mut spins = 0u32;
        while self.tx_free() < need {
            if !may_block {
                return Err(CclError::Transport("shm ring full".into()));
            }
            if self.aborted.load(Ordering::Acquire) {
                return Err(CclError::Aborted("shm link aborted".into()));
            }
            spins += 1;
            if spins < 256 {
                std::hint::spin_loop();
            } else {
                std::thread::sleep(Duration::from_micros(50));
            }
        }
        let head = self.tx.head().load(Ordering::Relaxed);
        let mut hdr = [0u8; FRAME_HDR];
        encode_frame_hdr(&mut hdr, tag, payload.len() as u32, msg_len, flags);
        self.tx.write_at(head, &hdr);
        self.tx.write_at(head + FRAME_HDR as u64, payload);
        self.tx.head().store(head + need as u64, Ordering::Release);
        Ok(())
    }
}

/// Consumer loop: drain frames from the RX ring into the inbox.
///
/// Spin-then-yield: busy-poll briefly (latency), then sleep 50 µs bites
/// (CPU). **No peer-liveness check on purpose** — see module docs.
fn reader_loop(rx: Arc<Ring>, inbox: Arc<Inbox>, aborted: Arc<AtomicBool>, peer: usize) {
    let mut hdr = [0u8; FRAME_HDR];
    let mut payload = vec![0u8; SEG_MAX];
    let mut idle_spins = 0u32;
    loop {
        if aborted.load(Ordering::Acquire) {
            return;
        }
        let head = rx.head().load(Ordering::Acquire);
        let tail = rx.tail().load(Ordering::Acquire);
        let avail = (head - tail) as usize;
        if avail < FRAME_HDR {
            idle_spins += 1;
            if idle_spins < 256 {
                std::hint::spin_loop();
            } else {
                std::thread::sleep(Duration::from_micros(50));
            }
            continue;
        }
        idle_spins = 0;
        rx.read_at(tail, &mut hdr);
        let (tag, len, msg_len, flags) = decode_frame_hdr(&hdr);
        let len = len as usize;
        if len > SEG_MAX {
            // A corrupt header must error the link, never index past the
            // reader's segment buffer (release builds used to rely on a
            // debug_assert here — an unwinding reader thread is exactly
            // the failure mode the gray-failure work hardens against).
            // Same observability as every other corruption class: the
            // transport.corrupt_frames counter is THE signal dashboards
            // and the chaos tests key on.
            crate::metrics::global().counter("transport.corrupt_frames").inc();
            crate::metrics::log_event(
                "transport.corrupt_frame",
                &[
                    ("peer", peer.to_string().as_str()),
                    ("tag", format!("{tag:#x}").as_str()),
                    ("detail", format!("oversized frame {len} on shm ring").as_str()),
                ],
            );
            inbox.fail(CclError::RemoteError {
                peer,
                detail: format!("oversized frame {len} on shm ring"),
            });
            return;
        }
        let need = FRAME_HDR + len;
        // The producer publishes head only after the whole frame is
        // in the ring, so avail >= FRAME_HDR implies we must wait for
        // the rest if the header says more.
        while ((rx.head().load(Ordering::Acquire) - tail) as usize) < need {
            if aborted.load(Ordering::Acquire) {
                return;
            }
            std::hint::spin_loop();
        }
        rx.read_at(tail + FRAME_HDR as u64, &mut payload[..len]);
        rx.tail().store(tail + need as u64, Ordering::Release);
        if flags & FLAG_GOODBYE != 0 {
            // Deliberate teardown announced by a live peer (see tcp.rs):
            // surface Aborted. Silent *death* stays silent — nothing
            // writes a goodbye when a process just dies.
            let reason = String::from_utf8_lossy(&payload[..len]).into_owned();
            inbox.fail(CclError::Aborted(format!("peer {peer} closed: {reason}")));
            return;
        }
        inbox.push_frame(tag, &payload[..len], msg_len as usize, flags);
    }
}

impl Link for ShmLink {
    fn send(&self, tag: u64, parts: &[&[u8]]) -> CclResult<()> {
        if self.aborted.load(Ordering::Acquire) {
            return Err(CclError::Aborted("shm link aborted".into()));
        }
        let _guard = self.send_lock.lock().unwrap();
        let total: usize = parts.iter().map(|p| p.len()).sum();
        if total > u32::MAX as usize {
            return Err(CclError::InvalidUsage(format!(
                "message of {total} bytes exceeds the 4 GiB wire cap"
            )));
        }
        let mut hdr = [0u8; FRAME_HDR];
        let mut remaining = total;
        let mut part_idx = 0usize;
        let mut part_off = 0usize;
        let max_seg = self.max_seg();
        loop {
            let seg = remaining.min(max_seg);
            let need = FRAME_HDR + seg;
            // Wait for ring space. Peer death leaves the ring full forever;
            // only a local abort (the watchdog) breaks the wait. Faithful
            // to NCCL-over-shm.
            let mut spins = 0u32;
            while self.tx_free() < need {
                if self.aborted.load(Ordering::Acquire) {
                    return Err(CclError::Aborted("shm link aborted".into()));
                }
                spins += 1;
                if spins < 256 {
                    std::hint::spin_loop();
                } else {
                    std::thread::sleep(Duration::from_micros(50));
                }
            }
            let head = self.tx.head().load(Ordering::Relaxed);
            let flags = if seg == remaining { FLAG_LAST } else { 0 };
            encode_frame_hdr(&mut hdr, tag, seg as u32, total as u32, flags);
            self.tx.write_at(head, &hdr);
            // Gather `seg` bytes from parts.
            let mut written = 0usize;
            while written < seg {
                let part = parts[part_idx];
                let avail = part.len() - part_off;
                let take = avail.min(seg - written);
                self.tx.write_at(
                    head + (FRAME_HDR + written) as u64,
                    &part[part_off..part_off + take],
                );
                written += take;
                part_off += take;
                if part_off == part.len() {
                    part_idx += 1;
                    part_off = 0;
                }
            }
            // Publish the whole frame at once.
            self.tx.head().store(head + need as u64, Ordering::Release);
            remaining -= seg;
            if remaining == 0 {
                return Ok(());
            }
        }
    }

    fn send_prologue(&self, tag: u64, payload: &[u8]) -> CclResult<()> {
        if self.aborted.load(Ordering::Acquire) {
            return Err(CclError::Aborted("shm link aborted".into()));
        }
        let _guard = self.send_lock.lock().unwrap();
        // One frame only (it must fit the ring alongside at least one
        // other in-flight frame) — exactly the contract `ring_frame`
        // enforces.
        self.ring_frame(
            tag,
            payload,
            payload.len() as u32,
            FLAG_LAST | FLAG_PROLOGUE,
            /*may_block=*/ true,
        )
    }

    fn recv_prologue(&self, tag: u64, timeout: Option<Duration>) -> CclResult<Vec<u8>> {
        self.inbox.recv_prologue(tag, timeout)
    }

    fn recv(&self, tag: u64, timeout: Option<Duration>) -> CclResult<Vec<u8>> {
        self.inbox.recv(tag, timeout)
    }

    fn try_recv(&self, tag: u64) -> CclResult<Option<Vec<u8>>> {
        self.inbox.try_recv(tag)
    }

    fn recycle(&self, buf: Vec<u8>) {
        self.inbox.recycle(buf);
    }

    fn send_raw_frame(&self, tag: u64, payload: &[u8], msg_len: u32, flags: u8) -> CclResult<()> {
        if self.aborted.load(Ordering::Acquire) {
            return Err(CclError::Aborted("shm link aborted".into()));
        }
        let _guard = self.send_lock.lock().unwrap();
        self.ring_frame(tag, payload, msg_len, flags, /*may_block=*/ true)
    }

    fn farewell(&self, reason: &str) {
        if self.aborted.load(Ordering::Acquire) {
            return;
        }
        // Best-effort, never blocking: skip the goodbye when the send
        // lock is held (a stuck send) or the ring has no room.
        let Ok(_guard) = self.send_lock.try_lock() else { return };
        let bytes = reason.as_bytes();
        let n = bytes.len().min(1024);
        let _ = self.ring_frame(
            0,
            &bytes[..n],
            n as u32,
            FLAG_LAST | FLAG_GOODBYE,
            /*may_block=*/ false,
        );
    }

    fn abort(&self, reason: &str) {
        if self.aborted.swap(true, Ordering::AcqRel) {
            return;
        }
        self.inbox.fail(CclError::Aborted(reason.to_string()));
    }

    fn kind(&self) -> &'static str {
        "shm"
    }

    fn peer(&self) -> usize {
        self.peer
    }
}

impl Drop for ShmLink {
    fn drop(&mut self) {
        self.abort("link dropped");
        if let Some(t) = self.reader.lock().unwrap().take() {
            let _ = t.join();
        }
        let _ = self.rx; // rings unmap in their own Drop
    }
}

/// Directory for ring files: `$MW_SHM_DIR`, else `/dev/shm` if present,
/// else the system temp dir.
pub fn shm_dir() -> PathBuf {
    if let Ok(d) = std::env::var("MW_SHM_DIR") {
        return PathBuf::from(d);
    }
    let dev_shm = Path::new("/dev/shm");
    if dev_shm.is_dir() {
        dev_shm.to_path_buf()
    } else {
        std::env::temp_dir()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{read_tensor, write_tensor, Tensor};
    use crate::util::prng::Rng;

    fn unique_world(tag: &str) -> String {
        format!(
            "t{}-{}-{}",
            std::process::id(),
            tag,
            crate::util::time::unix_millis()
        )
    }

    fn link_pair(tag: &str, ring_bytes: usize) -> (ShmLink, ShmLink) {
        let dir = shm_dir();
        let world = unique_world(tag);
        let w2 = world.clone();
        let d2 = dir.clone();
        let t = std::thread::spawn(move || {
            ShmLink::connect(&d2, &w2, 1, 0, ring_bytes, Duration::from_secs(5)).unwrap()
        });
        let a = ShmLink::connect(&dir, &world, 0, 1, ring_bytes, Duration::from_secs(5)).unwrap();
        let b = t.join().unwrap();
        (a, b)
    }

    #[test]
    fn small_roundtrip() {
        let (a, b) = link_pair("small", 64 * 1024);
        a.send(1, &[b"ping"]).unwrap();
        assert_eq!(b.recv(1, Some(Duration::from_secs(2))).unwrap(), b"ping");
        b.send(2, &[b"pong"]).unwrap();
        assert_eq!(a.recv(2, Some(Duration::from_secs(2))).unwrap(), b"pong");
    }

    #[test]
    fn message_larger_than_ring() {
        // 4 MB tensor through 256 KiB rings forces cut-through streaming.
        let (a, b) = link_pair("big", 256 * 1024);
        let mut rng = Rng::new(3);
        let t = Tensor::f32_1d(1_000_000, &mut rng);
        let mut framed = Vec::new();
        write_tensor(&mut framed, &t).unwrap();
        let checksum = t.checksum();
        let sender = std::thread::spawn(move || {
            a.send(9, &[&framed]).unwrap();
            a // keep alive until send completes
        });
        let got = b.recv(9, Some(Duration::from_secs(20))).unwrap();
        sender.join().unwrap();
        let back = read_tensor(&mut got.as_slice()).unwrap();
        assert_eq!(back.checksum(), checksum);
    }

    #[test]
    fn prologue_rides_its_own_lane() {
        let (a, b) = link_pair("prologue", 64 * 1024);
        a.send(6, &[b"data"]).unwrap();
        a.send_prologue(6, &[1]).unwrap();
        assert_eq!(
            b.recv_prologue(6, Some(Duration::from_secs(2))).unwrap(),
            vec![1]
        );
        assert_eq!(b.recv(6, Some(Duration::from_secs(2))).unwrap(), b"data");
    }

    #[test]
    fn wraparound_many_messages() {
        let (a, b) = link_pair("wrap", 16 * 1024);
        let payload = vec![0xABu8; 3000];
        for i in 0..64u64 {
            a.send(i, &[&payload]).unwrap();
            let got = b.recv(i, Some(Duration::from_secs(5))).unwrap();
            assert_eq!(got.len(), 3000);
            assert!(got.iter().all(|&x| x == 0xAB));
        }
    }

    #[test]
    fn farewell_announces_deliberate_teardown() {
        // An *announced* break is the one exception to shm silence: the
        // aborter is alive and says so. Plain drop (process death) stays
        // silent — see `peer_death_is_silent` below.
        let (a, b) = link_pair("farewell", 64 * 1024);
        a.farewell("watchdog verdict");
        let err = b.recv(5, Some(Duration::from_secs(2))).unwrap_err();
        assert!(matches!(err, CclError::Aborted(_)), "got {err:?}");
    }

    #[test]
    fn truncated_raw_frame_is_detected() {
        let (a, b) = link_pair("trunc", 64 * 1024);
        a.send_raw_frame(3, &[7u8; 8], 32, FLAG_LAST).unwrap();
        let err = b.recv(3, Some(Duration::from_secs(2))).unwrap_err();
        assert!(
            matches!(err, CclError::RemoteError { peer: 0, .. }),
            "truncation must be edge-attributed, got {err:?}"
        );
    }

    #[test]
    fn peer_death_is_silent() {
        // THE key semantic: dropping the peer does NOT error the recv.
        let (a, b) = link_pair("silent", 64 * 1024);
        drop(a);
        let res = b.recv(5, Some(Duration::from_millis(200)));
        assert!(
            matches!(res, Err(CclError::Timeout(_))),
            "shm peer death must be silent (timeout), got {res:?}"
        );
    }

    #[test]
    fn abort_unblocks_silent_wait() {
        let (_a, b) = link_pair("abort", 64 * 1024);
        let b = Arc::new(b);
        let b2 = b.clone();
        let t = std::thread::spawn(move || b2.recv(5, None));
        std::thread::sleep(Duration::from_millis(30));
        b.abort("watchdog says peer is dead");
        assert!(matches!(t.join().unwrap(), Err(CclError::Aborted(_))));
    }

    #[test]
    fn interleaved_tags() {
        let (a, b) = link_pair("tags", 64 * 1024);
        a.send(10, &[b"ten"]).unwrap();
        a.send(20, &[b"twenty"]).unwrap();
        a.send(10, &[b"ten2"]).unwrap();
        assert_eq!(b.recv(20, Some(Duration::from_secs(2))).unwrap(), b"twenty");
        assert_eq!(b.recv(10, Some(Duration::from_secs(2))).unwrap(), b"ten");
        assert_eq!(b.recv(10, Some(Duration::from_secs(2))).unwrap(), b"ten2");
    }

    #[test]
    fn ring_files_cleaned_up_by_owner() {
        let dir = shm_dir();
        let world = unique_world("cleanup");
        let path = dir.join(format!("mw-{world}-0to1.ring"));
        {
            let (_a, _b) = {
                let w2 = world.clone();
                let d2 = dir.clone();
                let t = std::thread::spawn(move || {
                    ShmLink::connect(&d2, &w2, 1, 0, 8192, Duration::from_secs(5)).unwrap()
                });
                let a =
                    ShmLink::connect(&dir, &world, 0, 1, 8192, Duration::from_secs(5)).unwrap();
                (a, t.join().unwrap())
            };
            assert!(path.exists());
        }
        assert!(!path.exists(), "owner drop must unlink ring files");
    }
}
