//! Frame layout shared by both transports.
//!
//! A logical message (one tensor or control payload) is segmented into
//! frames of at most [`SEG_MAX`] payload bytes so the fixed-size shm ring
//! never has to hold a whole 4 MB tensor, and so the receiver can start
//! draining while the sender is still writing (cut-through, not
//! store-and-forward).
//!
//! ```text
//! frame := tag:u64  seg_len:u32  flags:u8   payload[seg_len]
//! flags bit0 = LAST segment of this message
//! ```
//!
//! Frames of one message are contiguous on a link (senders hold the link
//! writer lock for the whole message), so reassembly is a simple
//! accumulator per tag.

/// Maximum payload bytes per frame.
pub const SEG_MAX: usize = 256 * 1024;

/// Frame header length: tag(8) + len(4) + flags(1).
pub const FRAME_HDR: usize = 13;

/// Flag: final segment of the message.
pub const FLAG_LAST: u8 = 1;

/// Encode a frame header into `out[0..FRAME_HDR]`.
#[inline]
pub fn encode_frame_hdr(out: &mut [u8], tag: u64, seg_len: u32, flags: u8) {
    out[0..8].copy_from_slice(&tag.to_le_bytes());
    out[8..12].copy_from_slice(&seg_len.to_le_bytes());
    out[12] = flags;
}

/// Decode a frame header.
#[inline]
pub fn decode_frame_hdr(h: &[u8]) -> (u64, u32, u8) {
    let tag = u64::from_le_bytes(h[0..8].try_into().unwrap());
    let len = u32::from_le_bytes(h[8..12].try_into().unwrap());
    (tag, len, h[12])
}

/// Tag namespace. User p2p tags live in the low 48 bits; collective ops
/// get a distinct kind so internal traffic can never collide with user
/// tags.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum TagKind {
    P2p = 0,
    Broadcast = 1,
    Reduce = 2,
    AllReduce = 3,
    Gather = 4,
    AllGather = 5,
    Scatter = 6,
    Control = 7,
}

/// Compose a wire tag from kind and a 48-bit id (sequence number or user
/// tag).
#[inline]
pub fn make_tag(kind: TagKind, id: u64) -> u64 {
    debug_assert!(id < (1 << 48), "tag id overflow");
    ((kind as u64) << 48) | (id & ((1 << 48) - 1))
}

/// Split a wire tag back into (kind byte, id).
#[inline]
pub fn split_tag(tag: u64) -> (u8, u64) {
    ((tag >> 48) as u8, tag & ((1 << 48) - 1))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_hdr_roundtrip() {
        let mut buf = [0u8; FRAME_HDR];
        encode_frame_hdr(&mut buf, 0xDEADBEEF, 4096, FLAG_LAST);
        let (tag, len, flags) = decode_frame_hdr(&buf);
        assert_eq!(tag, 0xDEADBEEF);
        assert_eq!(len, 4096);
        assert_eq!(flags, FLAG_LAST);
    }

    #[test]
    fn tag_namespace_disjoint() {
        let user = make_tag(TagKind::P2p, 7);
        let bcast = make_tag(TagKind::Broadcast, 7);
        assert_ne!(user, bcast);
        assert_eq!(split_tag(user), (0, 7));
        assert_eq!(split_tag(bcast), (1, 7));
    }

    #[test]
    fn seg_max_sane() {
        assert!(SEG_MAX >= 64 * 1024);
        assert!(SEG_MAX % 4096 == 0);
    }
}
