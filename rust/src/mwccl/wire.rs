//! Frame layout shared by both transports.
//!
//! A logical message (one tensor or control payload) is segmented into
//! frames of at most [`SEG_MAX`] payload bytes so the fixed-size shm ring
//! never has to hold a whole 4 MB tensor, and so the receiver can start
//! draining while the sender is still writing (cut-through, not
//! store-and-forward).
//!
//! ```text
//! frame := tag:u64  seg_len:u32  msg_len:u32  flags:u8  payload[seg_len]
//! flags bit0 = LAST segment of this message
//! flags bit1 = PROLOGUE (control) frame — own inbox lane, single frame
//! ```
//!
//! `msg_len` is the total payload length of the whole logical message;
//! it rides in every frame so the receiver's [`super::transport::inbox::Inbox`]
//! can preallocate the reassembly buffer once, from the first frame,
//! instead of growing a `Vec` segment by segment (4 GiB message cap).
//!
//! Frames of one message are contiguous on a link (senders hold the link
//! writer lock for the whole message), so reassembly is a simple
//! accumulator per tag.

/// Maximum payload bytes per frame.
pub const SEG_MAX: usize = 256 * 1024;

/// Frame header length: tag(8) + seg_len(4) + msg_len(4) + flags(1).
pub const FRAME_HDR: usize = 17;

/// Outer lane-id prefix on multiplexed connections: frames on a shared
/// per-host-pair socket are `lane:u64 || frame` (see
/// [`super::transport::mux`]); the lane routes the standard frame to
/// its world edge's inbox. Point-to-point transports (one socket per
/// edge) omit it.
pub const LANE_HDR: usize = 8;

/// Flag: final segment of the message.
pub const FLAG_LAST: u8 = 1;

/// Flag: control prologue frame. Prologue frames are single-frame
/// messages (always sent with [`FLAG_LAST`] too) delivered on a lane of
/// the inbox *separate* from data messages of the same tag, so a
/// collective can negotiate (e.g. the root's flat-vs-ring algorithm
/// byte for size-aware `Auto`) under its own wire tag without the
/// verdict ever being confused with the payload that follows.
pub const FLAG_PROLOGUE: u8 = 2;

/// Flag: goodbye frame — a *deliberate* teardown announcement. Written
/// best-effort by [`super::transport::Link::farewell`] when a world is
/// broken on purpose (watchdog verdict, op timeout, explicit
/// `break_world`), so the peer's reader fails its inbox with
/// [`crate::mwccl::error::CclError::Aborted`] instead of mistaking the
/// subsequent socket close for peer *death* (`RemoteError`). That
/// distinction is what keeps failure attribution honest under gray
/// failures: a rank that aborts a stuck collective must not be convicted
/// as dead by its surviving neighbors. The payload may carry the reason
/// string (shm, where ring publication is atomic) or be empty (tcp,
/// where a bare header minimizes the torn-frame window).
pub const FLAG_GOODBYE: u8 = 4;

/// Encode a frame header into `out[0..FRAME_HDR]`.
#[inline]
pub fn encode_frame_hdr(out: &mut [u8], tag: u64, seg_len: u32, msg_len: u32, flags: u8) {
    out[0..8].copy_from_slice(&tag.to_le_bytes());
    out[8..12].copy_from_slice(&seg_len.to_le_bytes());
    out[12..16].copy_from_slice(&msg_len.to_le_bytes());
    out[16] = flags;
}

/// Decode a frame header: (tag, seg_len, msg_len, flags).
#[inline]
pub fn decode_frame_hdr(h: &[u8]) -> (u64, u32, u32, u8) {
    let tag = u64::from_le_bytes(h[0..8].try_into().unwrap());
    let seg = u32::from_le_bytes(h[8..12].try_into().unwrap());
    let msg = u32::from_le_bytes(h[12..16].try_into().unwrap());
    (tag, seg, msg, h[16])
}

/// Tag namespace. User p2p tags live in the low 48 bits; collective ops
/// get a distinct kind so internal traffic can never collide with user
/// tags.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum TagKind {
    P2p = 0,
    Broadcast = 1,
    Reduce = 2,
    AllReduce = 3,
    Gather = 4,
    AllGather = 5,
    Scatter = 6,
    Control = 7,
}

/// Compose a wire tag from kind and a 48-bit id (sequence number or user
/// tag).
#[inline]
pub fn make_tag(kind: TagKind, id: u64) -> u64 {
    debug_assert!(id < (1 << 48), "tag id overflow");
    ((kind as u64) << 48) | (id & ((1 << 48) - 1))
}

/// Split a wire tag back into (kind byte, id).
#[inline]
pub fn split_tag(tag: u64) -> (u8, u64) {
    ((tag >> 48) as u8, tag & ((1 << 48) - 1))
}

/// Compose a wire tag for one chunk of a *ring* collective. Ring
/// algorithms move many independent messages per op — one per (ring
/// step, chunk) — so the 48-bit id is split:
///
/// ```text
/// id := seq:16 | step:8 | chunk:24
/// ```
///
/// 16 bits of sequence are plenty (only a handful of collectives are in
/// flight per world; matching is also gated by the per-op step/chunk),
/// 8 step bits cap rings at 128 ranks (2·(N−1) steps — enforced by
/// `CollAlgo::RING_MAX_WORLD`), and 24 chunk bits allow 16M chunks of
/// [`SEG_MAX`] ≈ 4 TiB per slice.
#[inline]
pub fn make_chunk_tag(kind: TagKind, seq: u64, step: usize, chunk: usize) -> u64 {
    debug_assert!(step < (1 << 8), "ring step overflow");
    debug_assert!(chunk < (1 << 24), "ring chunk overflow");
    make_tag(
        kind,
        ((seq & 0xFFFF) << 32) | ((step as u64) << 24) | chunk as u64,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_hdr_roundtrip() {
        let mut buf = [0u8; FRAME_HDR];
        encode_frame_hdr(&mut buf, 0xDEADBEEF, 4096, 1 << 20, FLAG_LAST);
        let (tag, seg, msg, flags) = decode_frame_hdr(&buf);
        assert_eq!(tag, 0xDEADBEEF);
        assert_eq!(seg, 4096);
        assert_eq!(msg, 1 << 20);
        assert_eq!(flags, FLAG_LAST);
    }

    #[test]
    fn tag_namespace_disjoint() {
        let user = make_tag(TagKind::P2p, 7);
        let bcast = make_tag(TagKind::Broadcast, 7);
        assert_ne!(user, bcast);
        assert_eq!(split_tag(user), (0, 7));
        assert_eq!(split_tag(bcast), (1, 7));
    }

    #[test]
    fn seg_max_sane() {
        assert!(SEG_MAX >= 64 * 1024);
        assert!(SEG_MAX % 4096 == 0);
    }

    #[test]
    fn chunk_tags_distinct_per_step_and_chunk() {
        let a = make_chunk_tag(TagKind::AllReduce, 3, 0, 0);
        let b = make_chunk_tag(TagKind::AllReduce, 3, 0, 1);
        let c = make_chunk_tag(TagKind::AllReduce, 3, 1, 0);
        let d = make_chunk_tag(TagKind::AllReduce, 4, 0, 0);
        assert!(a != b && a != c && a != d && b != c);
        // Kind byte survives.
        assert_eq!(split_tag(a).0, TagKind::AllReduce as u8);
    }

    #[test]
    fn chunk_tag_seq_wraps_at_16_bits() {
        let a = make_chunk_tag(TagKind::Broadcast, 5, 2, 9);
        let b = make_chunk_tag(TagKind::Broadcast, 5 + (1 << 16), 2, 9);
        assert_eq!(a, b, "seq occupies exactly 16 bits");
    }
}
