//! CCL error taxonomy, mirroring NCCL's where it matters.

/// Errors surfaced by collective operations.
#[derive(Clone, Debug, thiserror::Error, PartialEq)]
pub enum CclError {
    /// The remote side of a connection failed — analogue of
    /// `ncclRemoteError`. Only the *TCP* transport can raise this; the
    /// shared-memory transport cannot detect peer death (by design,
    /// reproducing NCCL's intra-host behaviour).
    #[error("remote error (peer {peer}): {detail}")]
    RemoteError { peer: usize, detail: String },

    /// The op was aborted locally (watchdog broke the world, or the
    /// world was removed while the op was pending).
    #[error("operation aborted: {0}")]
    Aborted(String),

    /// The world this op was issued on is broken; no further collectives
    /// may run on it.
    #[error("world '{0}' is broken")]
    WorldBroken(String),

    /// Rendezvous or membership problem.
    #[error("init failure: {0}")]
    InitFailure(String),

    /// Caller misuse (bad rank, shape mismatch between peers, …).
    #[error("invalid usage: {0}")]
    InvalidUsage(String),

    /// Deadline exceeded on a bounded wait.
    #[error("timeout: {0}")]
    Timeout(String),

    /// Underlying I/O failure not attributable to the peer.
    #[error("transport error: {0}")]
    Transport(String),
}

impl CclError {
    /// True when the error means the *world* (not just this op) is dead
    /// and must be cleaned up by the layer above.
    pub fn is_fatal_to_world(&self) -> bool {
        matches!(
            self,
            CclError::RemoteError { .. }
                | CclError::WorldBroken(_)
                | CclError::Aborted(_)
                | CclError::Transport(_)
        )
    }
}

pub type CclResult<T> = Result<T, CclError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fatality_classification() {
        assert!(CclError::RemoteError { peer: 1, detail: "reset".into() }.is_fatal_to_world());
        assert!(CclError::WorldBroken("w1".into()).is_fatal_to_world());
        assert!(CclError::Aborted("watchdog".into()).is_fatal_to_world());
        assert!(!CclError::InvalidUsage("bad rank".into()).is_fatal_to_world());
        assert!(!CclError::Timeout("t".into()).is_fatal_to_world());
    }

    #[test]
    fn display_mentions_peer() {
        let e = CclError::RemoteError { peer: 3, detail: "connection reset".into() };
        assert!(e.to_string().contains("peer 3"));
    }
}
