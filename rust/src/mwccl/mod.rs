//! `mwccl` — a from-scratch collective communication library with NCCL's
//! *semantics*, including the limitations the paper works around.
//!
//! A [`World`] is a process group: fixed membership decided at
//! [`World::init`] rendezvous, a single fault domain, and no way to grow
//! or shrink — exactly the CCL constraint motivating MultiWorld. On top
//! of point-to-point transports it offers the paper's eight collectives
//! (`send`, `recv`, `broadcast`, `all_reduce`, `reduce`, `all_gather`,
//! `gather`, `scatter`), each available in asynchronous form returning a
//! [`Work`] handle (mirroring `torch.distributed`'s `isend`/`irecv`).
//!
//! Failure semantics are modeled on NCCL:
//!
//! * **TCP transport** (host-to-host): peer death surfaces as
//!   [`CclError::RemoteError`] — the analogue of `ncclRemoteError`.
//! * **SHM transport** (intra-host, the NVLink/shared-memory path): peer
//!   death raises *no event whatsoever*; a pending `recv` simply never
//!   completes. This is the exact gap §3.2 of the paper describes, and
//!   why the MultiWorld layer adds a watchdog.
//!
//! Ops within one world are serialized by a per-world progress thread
//! (like NCCL's per-communicator stream ordering); ops in *different*
//! worlds proceed concurrently — which is what lets MultiWorld's
//! communicator poll many worlds without deadlock.
//!
//! All six collectives select between a flat star, a pipelined ring,
//! and a hierarchical two-level family per op, governed by a per-op
//! threshold table with a root-negotiated prologue where only the root
//! can size the payload (see [`collectives`] and
//! [`crate::config::CollPolicy`]); the receive path reassembles into
//! pooled, size-hinted buffers (see [`transport::inbox::Inbox`]).
//!
//! # Topology awareness: `MW_HOSTMAP` and the `Hier` family
//!
//! Setting `MW_HOSTMAP` (or `WorldOptions::with_hostmap`) places each
//! rank on a host (see [`hostmap::HostMap`] for the spec grammar).
//! When a world spans more than one host, `broadcast`, `reduce`,
//! `all_reduce`, and `all_gather` gain hierarchical variants
//! ([`CollAlgo::Hier`]): an intra-host fan-in over the cheap local
//! links to one *leader* rank per host, a leader-only inter-host
//! exchange that reuses the pipelined-ring machinery among leaders,
//! then an intra-host fan-out — so each payload crosses the host
//! boundary once per host pair instead of once per rank pair. `Auto`
//! picks hier only when host count > 1 and the payload clears the same
//! byte threshold that gates the ring; `gather`/`scatter` keep
//! flat/ring (their payloads are per-rank-distinct, so a leader relay
//! saves no cross-host bytes).
//!
//! # Connection multiplexing
//!
//! With a multi-host map, cross-host links ride a single multiplexed
//! TCP connection per host pair ([`transport::mux`]): each world edge
//! is a *lane*, framed on the shared socket as an 8-byte lane id
//! followed by the standard wire frame, with per-lane credit-based flow
//! control so one stalled world cannot head-of-line-block siblings.
//! Minting N worlds between two hosts therefore costs O(1) sockets,
//! not O(N) (see [`transport::mux::stats`]).

pub mod collectives;
pub mod error;
pub mod hostmap;
pub mod rendezvous;
pub mod transport;
pub mod wire;
pub mod work;
pub mod world;

pub use crate::config::{AlgoDecision, CollAlgo, CollOp, CollPolicy, RingThreshold};
pub use error::{CclError, CclResult};
pub use hostmap::HostMap;
pub use rendezvous::{Rendezvous, TransportKind, WorldOptions};
pub use transport::fault::{
    registry as fault_registry, EdgePattern, FaultKind, FaultPlan, FaultRegistry, FaultRule,
};
pub use work::{Work, WorkState};
pub use world::{ReduceOp, World};
