//! World initialization: rendezvous through a per-world TCPStore.
//!
//! Mirrors `torch.distributed.init_process_group`: rank 0 hosts the
//! store at a pre-agreed address (PyTorch's MASTER_ADDR/MASTER_PORT);
//! every rank registers its transport endpoint, links are established
//! pairwise, and a store barrier makes the world usable only once every
//! member is wired. The same store instance later carries the
//! MultiWorld watchdog's heartbeats (§3.3: "One TCPStore instance is
//! associated with one world").
//!
//! Minting is batched: each member publishes its address with one `SET`
//! and collects *all* peers' with one `WAIT_MANY`, so the store round
//! trips per member are constant in world size (publish + collect +
//! barrier add + barrier wait ≈ 4) — the property the control-plane
//! regression test pins via the `store.client.ops` counter.

use super::error::{CclError, CclResult};
use super::hostmap::HostMap;
use super::transport::fault::{self, FaultPlan};
use super::transport::mux;
use super::transport::ratelimit::RateLimiter;
use super::transport::shm::{shm_dir, ShmLink, DEFAULT_RING_BYTES};
use super::transport::tcp::TcpLink;
use super::transport::Link;
use super::world::World;
use crate::config::{CollAlgo, CollPolicy};
use crate::store::{StoreClient, StoreServer};
use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Duration;

/// Which transport a world runs over.
#[derive(Clone)]
pub enum TransportKind {
    /// Host-to-host path: real sockets, failures detectable, optional
    /// shared bandwidth cap (the paper's 10 Gbps inter-VM link). The
    /// one limiter is shared by **every** link in the world — a single
    /// NIC the whole (in-process) world contends for.
    Tcp { limiter: Option<Arc<RateLimiter>> },
    /// Host-to-host path with a *per-rank* NIC: each rank builds its own
    /// limiter at init, so every member has `rate_bps` of egress of its
    /// own — the multi-host topology where ring collectives shine (the
    /// root of a flat star bottlenecks on one NIC; a ring spreads the
    /// same bytes across all of them).
    TcpNic { rate_bps: f64 },
    /// Intra-host path: mmap ring pairs, failures silent (NVLink/shm
    /// analogue).
    Shm { ring_bytes: usize },
}

impl std::fmt::Debug for TransportKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TransportKind::Tcp { limiter } => write!(
                f,
                "Tcp{{limit={}}}",
                limiter.as_ref().map(|l| l.rate_bps()).unwrap_or(f64::INFINITY)
            ),
            TransportKind::TcpNic { rate_bps } => write!(f, "TcpNic{{limit={rate_bps}}}"),
            TransportKind::Shm { ring_bytes } => write!(f, "Shm{{ring={ring_bytes}}}"),
        }
    }
}

/// Options for [`World::init`].
#[derive(Clone, Debug)]
pub struct WorldOptions {
    pub transport: TransportKind,
    /// Rendezvous deadline (how long to wait for peers to arrive).
    pub init_timeout: Duration,
    /// Per-collective blocking-wait deadline; `None` waits until the
    /// link errors or is aborted (NCCL default behaviour).
    pub op_timeout: Option<Duration>,
    /// Collective algorithm policy (selector + per-op ring threshold
    /// table). Must be identical on every rank: ring and flat use
    /// different wire tags, and both the selector and the `min_world`
    /// rows are evaluated locally on each rank — a divergent row makes
    /// ranks disagree on whether a prologue is even sent and the op
    /// stalls until `op_timeout`. (Only the `min_bytes` row of a
    /// negotiated op is root-decided.) Defaults to
    /// [`CollPolicy::from_env`] (`MW_COLL_ALGO`, `MW_RING_MIN_*`).
    pub coll_policy: CollPolicy,
    /// Deterministic fault-injection plan. When present, every link of
    /// the world is wrapped in a
    /// [`fault::FaultLink`](crate::mwccl::transport::fault::FaultLink)
    /// driven by the plan's seeded per-edge RNG, and the process
    /// [`fault::registry`](crate::mwccl::transport::fault::registry)
    /// can flip faults on the live links mid-traffic. `None` (the
    /// default unless `MW_FAULT_PLAN` / `MW_FAULT_SEED` are set) leaves
    /// the transport stack untouched.
    pub fault_plan: Option<Arc<FaultPlan>>,
    /// Per-rank host placement spec (see [`HostMap`] for the grammar).
    /// `None` falls back to `MW_HOSTMAP`, and an absent/empty spec
    /// means single-host: every existing configuration behaves exactly
    /// as before. With more than one host, a tcp-family transport
    /// routes cross-host edges over the shared per-host-pair mux
    /// connection and same-host edges over shm, and `Auto` may select
    /// the hierarchical collective family. Must be identical on every
    /// rank (like `coll_policy`).
    pub hostmap: Option<String>,
    /// Mux connection namespace: worlds sharing a domain share per-host-
    /// pair sockets. Defaults to `"mw"` — one set of host-pair
    /// connections per process, the production shape.
    pub mux_domain: Option<String>,
    /// Route *same-host* edges of a multi-host tcp-family world over a
    /// loopback mux self-connection instead of pairwise shm rings. For
    /// very wide hosts (a 256-rank bench split 8 ways is ~500 shm ring
    /// pairs per host) this keeps the file/thread count O(hosts).
    pub intra_over_mux: bool,
}

impl Default for WorldOptions {
    fn default() -> Self {
        WorldOptions {
            transport: TransportKind::Shm { ring_bytes: DEFAULT_RING_BYTES },
            init_timeout: Duration::from_secs(30),
            op_timeout: None,
            coll_policy: CollPolicy::from_env(),
            fault_plan: FaultPlan::from_env().map(Arc::new),
            hostmap: None,
            mux_domain: None,
            intra_over_mux: false,
        }
    }
}

impl WorldOptions {
    pub fn tcp() -> Self {
        WorldOptions {
            transport: TransportKind::Tcp { limiter: None },
            ..Default::default()
        }
    }

    /// Host-to-host transport where every rank gets its *own* NIC of
    /// `rate_bps` bytes/sec (built at init) — the multi-host model the
    /// ring collectives are benchmarked against.
    pub fn tcp_per_rank_limited(rate_bps: f64) -> Self {
        WorldOptions {
            transport: TransportKind::TcpNic { rate_bps },
            ..Default::default()
        }
    }

    /// Force the collective algorithm selector, keeping the threshold
    /// table (env-derived) as-is.
    pub fn with_coll_algo(mut self, algo: CollAlgo) -> Self {
        self.coll_policy.algo = algo;
        self
    }

    /// Replace the whole per-op collective policy.
    pub fn with_coll_policy(mut self, policy: CollPolicy) -> Self {
        self.coll_policy = policy;
        self
    }

    pub fn tcp_limited(limiter: Arc<RateLimiter>) -> Self {
        WorldOptions {
            transport: TransportKind::Tcp { limiter: Some(limiter) },
            ..Default::default()
        }
    }

    pub fn shm() -> Self {
        Self::default()
    }

    pub fn with_op_timeout(mut self, t: Duration) -> Self {
        self.op_timeout = Some(t);
        self
    }

    /// Raise the rendezvous deadline (slow CI machines compiling many
    /// PJRT executables before joining worlds).
    pub fn with_init_timeout(mut self, t: Duration) -> Self {
        self.init_timeout = t;
        self
    }

    /// Install a deterministic fault-injection plan: every link of
    /// worlds built with these options is wrapped in a `FaultLink`
    /// (chaos tests; see [`crate::mwccl::transport::fault`]). Pass
    /// [`FaultPlan::empty`] to enable runtime-only fault flipping with
    /// no static rules.
    pub fn with_fault_plan(mut self, plan: FaultPlan) -> Self {
        self.fault_plan = Some(Arc::new(plan));
        self
    }

    /// Place the world's ranks on hosts (overrides `MW_HOSTMAP`; see
    /// [`HostMap`] for the spec grammar). More than one host enables
    /// the hierarchical collective family and, on tcp-family
    /// transports, the per-host-pair mux connection for cross-host
    /// edges.
    pub fn with_hostmap(mut self, spec: &str) -> Self {
        self.hostmap = Some(spec.to_string());
        self
    }

    /// Namespace the mux connections (tests isolating their socket
    /// counts; production leaves the shared default).
    pub fn with_mux_domain(mut self, domain: &str) -> Self {
        self.mux_domain = Some(domain.to_string());
        self
    }

    /// Carry same-host edges over a loopback mux self-connection
    /// instead of pairwise shm rings (see [`WorldOptions::intra_over_mux`]).
    pub fn with_intra_over_mux(mut self) -> Self {
        self.intra_over_mux = true;
        self
    }
}

/// Namespace helper for store keys of one world.
fn key(world: &str, suffix: &str) -> String {
    format!("mw/{world}/{suffix}")
}

impl World {
    /// Initialize (join) the world `name` as `rank` of `size`.
    ///
    /// Rank 0 hosts the store server on `store_addr`; everyone else
    /// connects to it. Blocks until all `size` members have arrived and
    /// all pairwise links are up — this is the collective, blocking init
    /// the paper works around by running it in a separate thread at the
    /// MultiWorld layer.
    pub fn init(
        name: &str,
        rank: usize,
        size: usize,
        store_addr: SocketAddr,
        opts: WorldOptions,
    ) -> CclResult<World> {
        if size == 0 || rank >= size {
            return Err(CclError::InvalidUsage(format!("bad rank {rank} of {size}")));
        }
        // 0. Host placement: explicit spec wins, else `MW_HOSTMAP`,
        // else everything on one host (the historical behavior).
        let hosts = match &opts.hostmap {
            Some(spec) => HostMap::parse(spec, size)?,
            None => HostMap::from_env(size)?,
        };
        // 1. Store: leader hosts, members connect.
        let server = if rank == 0 {
            Some(Arc::new(StoreServer::bind(&store_addr.to_string()).map_err(
                |e| CclError::InitFailure(format!("store bind {store_addr}: {e}")),
            )?))
        } else {
            None
        };
        let store = Arc::new(
            StoreClient::connect(store_addr, opts.init_timeout)
                .map_err(|e| CclError::InitFailure(format!("store connect: {e}")))?,
        );

        if size == 1 {
            return Ok(World::from_parts(
                name.to_string(),
                rank,
                size,
                HashMap::new(),
                Some(store),
                server,
                opts.op_timeout,
                opts.coll_policy,
                hosts,
            ));
        }

        // 2. Links. A multi-host placement reroutes tcp-family worlds:
        // cross-host edges share the per-host-pair mux connection
        // (per-host NIC modeling included), same-host edges take shm —
        // the paper's intra-host NVLink / inter-host TCP split. A
        // single-host map (the default) leaves every transport exactly
        // as before. Shm-transport worlds keep their full shm mesh even
        // under a hostmap: placement then only steers algorithm choice,
        // which is what the hier correctness tests exercise.
        let multi_host = hosts.n_hosts() > 1;
        let links: HashMap<usize, Box<dyn Link>> = match &opts.transport {
            TransportKind::Tcp { limiter } if multi_host => {
                let egress = limiter.as_ref().map(|l| l.rate_bps());
                mux_links(name, rank, &hosts, &opts, egress)?
            }
            TransportKind::TcpNic { rate_bps } if multi_host => {
                mux_links(name, rank, &hosts, &opts, Some(*rate_bps))?
            }
            TransportKind::Tcp { limiter } => {
                tcp_links(name, rank, size, &store, limiter.clone(), opts.init_timeout)?
            }
            TransportKind::TcpNic { rate_bps } => {
                // One limiter per rank: all of this rank's links share it
                // (its NIC); other ranks build their own.
                let nic = Some(Arc::new(RateLimiter::new(*rate_bps)));
                tcp_links(name, rank, size, &store, nic, opts.init_timeout)?
            }
            TransportKind::Shm { ring_bytes } => {
                shm_links(name, rank, size, *ring_bytes, opts.init_timeout)?
            }
        };
        // 2b. Chaos: wrap every link in the deterministic fault injector
        // when a plan is installed (no-op otherwise).
        let links = match &opts.fault_plan {
            Some(plan) => fault::wrap_links(plan, name, rank, links),
            None => links,
        };

        // 3. Barrier: the world exists only when everyone is wired.
        barrier(&store, &key(name, "ready"), size, opts.init_timeout)?;

        Ok(World::from_parts(
            name.to_string(),
            rank,
            size,
            links,
            Some(store),
            server,
            opts.op_timeout,
            opts.coll_policy,
            hosts,
        ))
    }
}

/// Build a multi-host world's links: shared mux connections across
/// hosts, shm within a host (or the loopback self-connection when
/// `intra_over_mux` is set).
///
/// Connection establishment walks the needed host pairs in ascending
/// `(lo, hi)` order on **every** rank before any per-peer link work, so
/// the accept/dial dependency graph is acyclic: the smallest
/// outstanding pair always has both its listener and its dialer
/// actively working on it (see [`mux`] module docs).
fn mux_links(
    world: &str,
    rank: usize,
    hosts: &HostMap,
    opts: &WorldOptions,
    egress_bps: Option<f64>,
) -> CclResult<HashMap<usize, Box<dyn Link>>> {
    let size = hosts.size();
    let my_host = hosts.host(rank);
    let domain = opts.mux_domain.as_deref().unwrap_or("mw");

    // Establishment pre-pass, globally sorted.
    let mut pairs: Vec<(usize, usize, usize)> = (0..hosts.n_hosts())
        .filter(|&h| h != my_host || opts.intra_over_mux)
        .map(|h| (my_host.min(h), my_host.max(h), h))
        .collect();
    pairs.sort_unstable();
    let mut conns = HashMap::new();
    for (_, _, h) in pairs {
        conns.insert(h, mux::ensure_conn(domain, my_host, h, egress_bps, opts.init_timeout)?);
    }

    let mut links: HashMap<usize, Box<dyn Link>> = HashMap::new();
    for peer in 0..size {
        if peer == rank {
            continue;
        }
        let peer_host = hosts.host(peer);
        if peer_host == my_host && !opts.intra_over_mux {
            let link = ShmLink::connect(
                &shm_dir(),
                world,
                rank,
                peer,
                DEFAULT_RING_BYTES,
                opts.init_timeout,
            )?;
            links.insert(peer, Box::new(link));
        } else {
            links.insert(peer, mux::lane_link(&conns[&peer_host], world, rank, peer)?);
        }
    }
    Ok(links)
}

/// Store-based barrier: increment a counter; the last arriver publishes
/// the go key; everyone waits for it.
pub fn barrier(
    store: &StoreClient,
    counter_key: &str,
    size: usize,
    timeout: Duration,
) -> CclResult<()> {
    let n = store
        .add(counter_key, 1)
        .map_err(|e| CclError::InitFailure(format!("barrier add: {e}")))?;
    let go_key = format!("{counter_key}/go");
    if n as usize == size {
        store
            .set(&go_key, b"1")
            .map_err(|e| CclError::InitFailure(format!("barrier set: {e}")))?;
    }
    store
        .wait(&go_key, timeout)
        .map_err(|e| CclError::InitFailure(format!("barrier wait: {e}")))?;
    Ok(())
}

/// Establish full-mesh TCP links: every rank listens; the higher rank of
/// each pair dials the lower; a 8-byte hello (`rank:u32 || magic:u32`)
/// identifies the dialer.
///
/// Address exchange is **O(1) store round trips in the member count**:
/// one `SET` publishes our endpoint, one `WAIT_MANY` collects every
/// peer's (the store answers when the last address lands — no per-peer
/// wait chain). Accepts block in the kernel with a deadline
/// ([`crate::util::accept_deadline`]) instead of a sleep-poll loop.
fn tcp_links(
    world: &str,
    rank: usize,
    size: usize,
    store: &StoreClient,
    limiter: Option<Arc<RateLimiter>>,
    timeout: Duration,
) -> CclResult<HashMap<usize, Box<dyn Link>>> {
    const HELLO_MAGIC: u32 = 0x4D57_4C4B; // "MWLK"
    let listener = TcpListener::bind("127.0.0.1:0")
        .map_err(|e| CclError::InitFailure(format!("listener: {e}")))?;
    let my_addr = listener
        .local_addr()
        .map_err(|e| CclError::InitFailure(e.to_string()))?;
    store
        .set(&key(world, &format!("addr/{rank}")), my_addr.to_string().as_bytes())
        .map_err(|e| CclError::InitFailure(format!("publish addr: {e}")))?;

    // All peer addresses in one batched round trip.
    let addr_keys: Vec<String> =
        (0..size).map(|p| key(world, &format!("addr/{p}"))).collect();
    let addr_refs: Vec<&str> = addr_keys.iter().map(|s| s.as_str()).collect();
    let addr_vals = store
        .wait_many(&addr_refs, timeout)
        .map_err(|e| CclError::InitFailure(format!("peer addrs: {e}")))?;

    let mut links: HashMap<usize, Box<dyn Link>> = HashMap::new();

    // Dial every lower rank.
    for peer in 0..rank {
        let addr: SocketAddr = std::str::from_utf8(&addr_vals[peer])
            .ok()
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| CclError::InitFailure(format!("bad addr for {peer}")))?;
        let mut stream = TcpStream::connect_timeout(&addr, timeout)
            .map_err(|e| CclError::InitFailure(format!("dial {peer}: {e}")))?;
        let mut hello = [0u8; 8];
        hello[0..4].copy_from_slice(&(rank as u32).to_le_bytes());
        hello[4..8].copy_from_slice(&HELLO_MAGIC.to_le_bytes());
        stream
            .write_all(&hello)
            .map_err(|e| CclError::InitFailure(format!("hello to {peer}: {e}")))?;
        links.insert(peer, Box::new(TcpLink::new(peer, stream, limiter.clone())?));
    }

    // Accept every higher rank (deadline-bounded blocking accepts).
    let expect_accepts = size - rank - 1;
    let deadline = std::time::Instant::now() + timeout;
    for _ in 0..expect_accepts {
        let mut s = crate::util::accept_deadline(&listener, deadline)
            .map_err(|e| CclError::InitFailure(format!("accept: {e}")))?;
        let mut hello = [0u8; 8];
        s.read_exact(&mut hello)
            .map_err(|e| CclError::InitFailure(format!("hello read: {e}")))?;
        let peer = u32::from_le_bytes(hello[0..4].try_into().unwrap()) as usize;
        let magic = u32::from_le_bytes(hello[4..8].try_into().unwrap());
        if magic != HELLO_MAGIC || peer <= rank || peer >= size {
            return Err(CclError::InitFailure(format!(
                "bad hello: peer={peer} magic={magic:#x}"
            )));
        }
        links.insert(peer, Box::new(TcpLink::new(peer, s, limiter.clone())?));
    }
    Ok(links)
}

/// Establish full-mesh shm ring links (pair files created by the lower
/// rank of each pair).
fn shm_links(
    world: &str,
    rank: usize,
    size: usize,
    ring_bytes: usize,
    timeout: Duration,
) -> CclResult<HashMap<usize, Box<dyn Link>>> {
    let dir = shm_dir();
    let mut links: HashMap<usize, Box<dyn Link>> = HashMap::new();
    for peer in 0..size {
        if peer == rank {
            continue;
        }
        let link = ShmLink::connect(&dir, world, rank, peer, ring_bytes, timeout)?;
        links.insert(peer, Box::new(link));
    }
    Ok(links)
}

/// Test/bench helper: bring up all `size` ranks of a world on threads in
/// this process and return them in rank order. Transports behave exactly
/// as across processes (same sockets / mmap files).
pub struct Rendezvous;

impl Rendezvous {
    pub fn single_process(name: &str, size: usize, opts: WorldOptions) -> CclResult<Vec<World>> {
        let port = crate::util::free_port();
        let addr: SocketAddr = format!("127.0.0.1:{port}").parse().unwrap();
        let handles: Vec<_> = (0..size)
            .map(|rank| {
                let name = name.to_string();
                let opts = opts.clone();
                std::thread::spawn(move || World::init(&name, rank, size, addr, opts))
            })
            .collect();
        let mut worlds = Vec::with_capacity(size);
        for h in handles {
            worlds.push(h.join().map_err(|_| {
                CclError::InitFailure("rendezvous thread panicked".into())
            })??);
        }
        Ok(worlds)
    }
}
