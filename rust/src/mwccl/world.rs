//! The `World` — one fixed process group, one fault domain.
//!
//! Membership is decided at init and can never change (that is the CCL
//! property the paper lifts at the layer above by giving a worker *many*
//! worlds). All collectives of a world are serialized on its dedicated
//! *progress thread*, like NCCL serializes per-communicator ops on a
//! stream; collectives of different worlds run concurrently because each
//! world has its own thread.
//!
//! When any op hits a fatal error (remote peer death on TCP, local
//! abort), the world transitions to **broken**: links abort, pending and
//! future works fail with [`CclError::WorldBroken`], and the layer above
//! is expected to clean up (`WorldManager::remove_world`).

use super::error::{CclError, CclResult};
use super::hostmap::HostMap;
use super::transport::Link;
use super::work::Work;
use crate::config::{CollAlgo, CollOp, CollPolicy};
use crate::tensor::{read_tensor, serialize::encode_header, Tensor};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Algorithm trace / prologue codes (0 in the trace means "never ran").
pub(crate) const ALGO_FLAT: u8 = 1;
pub(crate) const ALGO_RING: u8 = 2;
pub(crate) const ALGO_HIER: u8 = 3;

/// Reduction operator for `reduce`/`all_reduce`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReduceOp {
    Sum,
    Max,
    Avg,
}

/// A queued operation: runs on the progress thread.
pub(crate) struct Job {
    pub work: Work,
    pub run: Box<dyn FnOnce(&WorldCore) -> CclResult<Option<Tensor>> + Send>,
}

/// Internals shared between the handle, the progress thread and the
/// MultiWorld layer.
pub struct WorldCore {
    pub name: String,
    pub rank: usize,
    pub size: usize,
    links: HashMap<usize, Box<dyn Link>>,
    broken: AtomicBool,
    broken_reason: Mutex<Option<CclError>>,
    /// Collective sequence number; all ranks issue collectives in the
    /// same order (CCL contract), so sequence numbers align across ranks
    /// and serve as matching tags.
    seq: AtomicU64,
    /// Default timeout applied to blocking waits inside collectives.
    pub op_timeout: Option<Duration>,
    /// Collective algorithm policy: flat star / pipelined ring /
    /// hierarchical / auto, plus the per-op ring threshold table.
    pub coll_policy: CollPolicy,
    /// Per-rank host placement (from `MW_HOSTMAP` or
    /// `WorldOptions::with_hostmap`; single-host when unset). Drives the
    /// hierarchical collectives and `Auto`'s host-count input.
    pub hosts: HostMap,
    /// Last algorithm actually run per collective (0 = none yet,
    /// 1 = flat, 2 = ring, 3 = hier) — observability for tests, benches
    /// and the CI quick-ablation step; negotiated `Auto` choices land
    /// here too.
    algo_trace: [AtomicU8; 6],
    /// Largest single contribution (bytes) ever observed per collective
    /// on this world. Roots of size-negotiated ops whose payload they
    /// cannot fully know (`gather`, `all_gather`) clamp their
    /// own-contribution-×-N estimate with this, so skewed per-rank
    /// sizes stop mis-picking flat after the first invocation on the
    /// tag lane (see `CollPolicy::decide`).
    max_contrib: [AtomicU64; 6],
    /// One-shot latch: set when a forced-`Hier` world first runs a
    /// non-hierarchical algorithm (see [`WorldCore::note_algo`]).
    hier_degraded: AtomicBool,
    /// Point-to-point receives pending on the p2p poller thread.
    /// Unlike collectives (strictly ordered on the progress thread),
    /// `irecv`s from *different peers* complete concurrently — the
    /// property Fig. 4's leader (one world, two senders) relies on.
    pending_recvs: Mutex<Vec<PendingRecv>>,
}

pub(crate) struct PendingRecv {
    pub peer: usize,
    pub wire_tag: u64,
    pub work: Work,
}

impl WorldCore {
    pub(crate) fn link(&self, peer: usize) -> CclResult<&dyn Link> {
        self.links
            .get(&peer)
            .map(|b| b.as_ref())
            .ok_or_else(|| CclError::InvalidUsage(format!("no link to rank {peer}")))
    }

    pub(crate) fn next_seq(&self) -> u64 {
        self.seq.fetch_add(1, Ordering::Relaxed)
    }

    pub(crate) fn check_healthy(&self) -> CclResult<()> {
        if self.broken.load(Ordering::Acquire) {
            Err(CclError::WorldBroken(self.name.clone()))
        } else {
            Ok(())
        }
    }

    /// Serialize a tensor into (header, payload-view) and send to `peer`.
    pub(crate) fn send_tensor(&self, peer: usize, tag: u64, t: &Tensor) -> CclResult<()> {
        let hdr = encode_header(t)
            .map_err(|e| CclError::InvalidUsage(format!("unserializable tensor: {e}")))?;
        self.link(peer)?.send(tag, &[&hdr, t.bytes()])
    }

    /// Receive a tensor from `peer` under `tag`. The wire buffer goes
    /// back to the link's pool once parsed.
    pub(crate) fn recv_tensor(&self, peer: usize, tag: u64) -> CclResult<Tensor> {
        let link = self.link(peer)?;
        let bytes = link.recv(tag, self.op_timeout)?;
        let t = read_tensor(&mut bytes.as_slice())
            .map_err(|e| CclError::Transport(format!("bad tensor frame from {peer}: {e}")))?;
        link.recycle(bytes);
        Ok(t)
    }

    /// Raw-byte send to `peer` (ring collectives move naked chunk
    /// payloads, not serialized tensors).
    pub(crate) fn send_bytes(&self, peer: usize, tag: u64, parts: &[&[u8]]) -> CclResult<()> {
        self.link(peer)?.send(tag, parts)
    }

    /// Raw-byte receive from `peer` under the world's op timeout.
    pub(crate) fn recv_bytes(&self, peer: usize, tag: u64) -> CclResult<Vec<u8>> {
        self.link(peer)?.recv(tag, self.op_timeout)
    }

    /// Return a consumed wire buffer to `peer`'s link pool.
    pub(crate) fn recycle(&self, peer: usize, buf: Vec<u8>) {
        if let Ok(link) = self.link(peer) {
            link.recycle(buf);
        }
    }

    /// Send the root's one-byte algorithm verdict for a negotiated
    /// `Auto` collective (prologue lane of `tag`; see `wire.rs`). The
    /// wire byte is `code - 1`: 0 = flat, 1 = ring, 2 = hier.
    pub(crate) fn send_algo_prologue(&self, peer: usize, tag: u64, code: u8) -> CclResult<()> {
        debug_assert!((ALGO_FLAT..=ALGO_HIER).contains(&code));
        crate::metrics::global().counter("coll_prologue_rounds").inc();
        self.link(peer)?.send_prologue(tag, &[code - 1])
    }

    /// Receive the root's algorithm verdict (counterpart of
    /// [`WorldCore::send_algo_prologue`]); returns an `ALGO_*` code.
    pub(crate) fn recv_algo_prologue(&self, peer: usize, tag: u64) -> CclResult<u8> {
        let b = self.link(peer)?.recv_prologue(tag, self.op_timeout)?;
        match b.as_slice() {
            [w @ 0..=2] => Ok(w + 1),
            other => Err(CclError::Transport(format!(
                "bad algo prologue from rank {peer}: {other:?}"
            ))),
        }
    }

    /// Record the algorithm a collective actually ran, as an `ALGO_*`
    /// code (see [`World::last_algo`]).
    pub(crate) fn note_algo(&self, op: CollOp, code: u8) {
        self.algo_trace[op.index()].store(code, Ordering::Relaxed);
        // A forced-`Hier` policy degrades silently in two cases:
        // gather/scatter have no hierarchical variant (per-rank-distinct
        // payloads — see `CollOp::has_hier`), and single-host worlds
        // have no leader ring. `decide` falls back to ring (then flat)
        // by design, but an operator who pinned `MW_COLL_ALGO=hier`
        // should learn the pin isn't running — once per world, not once
        // per op, so steady-state traffic can't flood the log.
        if code != ALGO_HIER
            && self.coll_policy.algo == CollAlgo::Hier
            && self.size >= 2
            && !self.hier_degraded.swap(true, Ordering::Relaxed)
        {
            crate::metrics::global().counter("coll.hier_degraded").inc();
            let ran = if code == ALGO_RING { "ring" } else { "flat" };
            crate::metrics::log_event(
                "coll.hier_degraded",
                &[("world", self.name.as_str()), ("op", op.name()), ("ran", ran)],
            );
        }
    }

    /// Record one rank's observed contribution size for `op` (the
    /// estimate clamp for negotiated gather/all_gather roots).
    pub(crate) fn note_contrib(&self, op: CollOp, bytes: usize) {
        self.max_contrib[op.index()].fetch_max(bytes as u64, Ordering::Relaxed);
    }

    /// Largest contribution seen so far for `op` (0 before the first).
    pub(crate) fn max_contrib(&self, op: CollOp) -> usize {
        self.max_contrib[op.index()].load(Ordering::Relaxed) as usize
    }

    /// Queue a p2p receive for the poller.
    pub(crate) fn register_recv(&self, peer: usize, wire_tag: u64, work: Work) {
        self.pending_recvs
            .lock()
            .unwrap()
            .push(PendingRecv { peer, wire_tag, work });
    }

    fn break_world(&self, err: &CclError) {
        if self.broken.swap(true, Ordering::AcqRel) {
            return;
        }
        *self.broken_reason.lock().unwrap() = Some(err.clone());
        for link in self.links.values() {
            link.abort(&format!("world {} broken: {err}", self.name));
        }
    }

    /// Break the world *and announce it first*: a best-effort GOODBYE
    /// frame on every link tells still-alive peers this is a deliberate
    /// teardown (watchdog verdict, op timeout, explicit `break_world`),
    /// so their transports surface [`CclError::Aborted`] instead of the
    /// death-implying `RemoteError` — the failure-attribution layer must
    /// never convict a live rank on teardown evidence. The plain drop
    /// path keeps the silent [`WorldCore::break_world`]: process death
    /// announces nothing, exactly like a real crash.
    fn break_world_announced(&self, err: &CclError) {
        if self.broken.load(Ordering::Acquire) {
            return; // already broken; links are gone — nothing to announce
        }
        let reason = format!("world {} broken: {err}", self.name);
        for link in self.links.values() {
            link.farewell(&reason);
        }
        self.break_world(err);
    }
}

/// Handle to one world. Clone freely; dropping the last handle shuts the
/// progress thread down and aborts the links.
pub struct World {
    core: Arc<WorldCore>,
    job_tx: Sender<Job>,
    /// Progress thread join handle (shared; joined by the last drop).
    progress: Arc<Mutex<Option<std::thread::JoinHandle<()>>>>,
    /// p2p poller thread + its stop flag (shared like `progress`).
    poller: Arc<Mutex<Option<std::thread::JoinHandle<()>>>>,
    poller_stop: Arc<AtomicBool>,
    /// Keep the rendezvous store client alive (the watchdog reuses it).
    store: Option<Arc<crate::store::StoreClient>>,
    /// Rank-0 hosts the per-world store server; its lifetime is tied to
    /// the world's (PyTorch behaviour: TCPStore dies with the leader).
    _store_server: Option<Arc<crate::store::StoreServer>>,
}

impl std::fmt::Debug for World {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "World({} rank {}/{}{})",
            self.core.name,
            self.core.rank,
            self.core.size,
            if self.is_broken() { " BROKEN" } else { "" }
        )
    }
}

impl Clone for World {
    fn clone(&self) -> Self {
        World {
            core: self.core.clone(),
            job_tx: self.job_tx.clone(),
            progress: self.progress.clone(),
            poller: self.poller.clone(),
            poller_stop: self.poller_stop.clone(),
            store: self.store.clone(),
            _store_server: self._store_server.clone(),
        }
    }
}

impl World {
    /// Assemble a world from already-established links (rendezvous calls
    /// this; tests may call it directly with in-memory pairs).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn from_parts(
        name: String,
        rank: usize,
        size: usize,
        links: HashMap<usize, Box<dyn Link>>,
        store: Option<Arc<crate::store::StoreClient>>,
        store_server: Option<Arc<crate::store::StoreServer>>,
        op_timeout: Option<Duration>,
        coll_policy: CollPolicy,
        hosts: HostMap,
    ) -> World {
        debug_assert_eq!(links.len(), size - 1, "need a link to every peer");
        debug_assert_eq!(hosts.size(), size.max(1), "host map must cover the world");
        let core = Arc::new(WorldCore {
            name: name.clone(),
            rank,
            size,
            links,
            broken: AtomicBool::new(false),
            broken_reason: Mutex::new(None),
            seq: AtomicU64::new(0),
            op_timeout,
            coll_policy,
            hosts,
            algo_trace: Default::default(),
            max_contrib: Default::default(),
            hier_degraded: AtomicBool::new(false),
            pending_recvs: Mutex::new(Vec::new()),
        });
        let (job_tx, job_rx) = std::sync::mpsc::channel::<Job>();
        let core2 = core.clone();
        let progress = std::thread::Builder::new()
            .name(format!("mw-progress-{name}-r{rank}"))
            .spawn(move || progress_loop(core2, job_rx))
            .expect("spawn progress thread");
        let poller_stop = Arc::new(AtomicBool::new(false));
        let core3 = core.clone();
        let stop3 = poller_stop.clone();
        let poller = std::thread::Builder::new()
            .name(format!("mw-p2p-{name}-r{rank}"))
            .spawn(move || p2p_poll_loop(core3, stop3))
            .expect("spawn p2p poller");
        World {
            core,
            job_tx,
            progress: Arc::new(Mutex::new(Some(progress))),
            poller: Arc::new(Mutex::new(Some(poller))),
            poller_stop,
            store,
            _store_server: store_server,
        }
    }

    pub fn name(&self) -> &str {
        &self.core.name
    }

    pub fn rank(&self) -> usize {
        self.core.rank
    }

    pub fn size(&self) -> usize {
        self.core.size
    }

    /// The per-world store client (heartbeat channel for the watchdog).
    pub fn store(&self) -> Option<Arc<crate::store::StoreClient>> {
        self.store.clone()
    }

    pub fn is_broken(&self) -> bool {
        self.core.broken.load(Ordering::Acquire)
    }

    /// The algorithm the last completed `op` on this world actually ran
    /// (`"flat"` / `"ring"` / `"hier"`), `None` if the op never ran. For
    /// negotiated `Auto` collectives this reflects the root's prologue
    /// verdict — the observable proof that e.g. a sub-threshold
    /// broadcast kept the flat fast path, or that a multi-host world
    /// went hierarchical.
    pub fn last_algo(&self, op: CollOp) -> Option<&'static str> {
        match self.core.algo_trace[op.index()].load(Ordering::Relaxed) {
            ALGO_FLAT => Some("flat"),
            ALGO_RING => Some("ring"),
            ALGO_HIER => Some("hier"),
            _ => None,
        }
    }

    /// Why the world broke, once broken.
    pub fn broken_reason(&self) -> Option<CclError> {
        self.core.broken_reason.lock().unwrap().clone()
    }

    /// Locally break the world: abort links, fail pending and future
    /// ops. Idempotent. The watchdog calls this on missed heartbeats.
    pub fn abort(&self, reason: &str) {
        self.core
            .break_world(&CclError::Aborted(reason.to_string()));
    }

    /// [`World::abort`] preceded by a farewell to every peer (see
    /// [`WorldCore::break_world_announced`]): the manager's deliberate
    /// break path, so surviving peers observe `Aborted`, not a
    /// misattributable `RemoteError`.
    pub fn abort_announced(&self, reason: &str) {
        self.core
            .break_world_announced(&CclError::Aborted(reason.to_string()));
    }

    /// Submit an op closure to the progress thread.
    pub(crate) fn submit(
        &self,
        desc: String,
        run: impl FnOnce(&WorldCore) -> CclResult<Option<Tensor>> + Send + 'static,
    ) -> Work {
        if let Err(e) = self.core.check_healthy() {
            return Work::failed(desc, e);
        }
        let work = Work::pending(desc);
        let job = Job { work: work.clone(), run: Box::new(run) };
        if self.job_tx.send(job).is_err() {
            work.fail(CclError::WorldBroken(self.core.name.clone()));
        }
        work
    }

    /// Direct access for the collectives module.
    pub(crate) fn core(&self) -> &Arc<WorldCore> {
        &self.core
    }
}

impl Drop for World {
    fn drop(&mut self) {
        // Only tear down with the last external handle (core is also held
        // by the progress and poller threads, hence the +2).
        if Arc::strong_count(&self.core) <= 3 {
            self.core
                .break_world(&CclError::Aborted("world dropped".into()));
            self.poller_stop.store(true, Ordering::Release);
            // Closing the channel ends the progress loop.
            let (dead_tx, _) = std::sync::mpsc::channel::<Job>();
            let _ = std::mem::replace(&mut self.job_tx, dead_tx);
            if let Some(h) = self.progress.lock().unwrap().take() {
                let _ = h.join();
            }
            if let Some(h) = self.poller.lock().unwrap().take() {
                let _ = h.join();
            }
        }
    }
}

/// The p2p poller: completes pending `irecv`s as their messages land,
/// regardless of order or peer — a non-blocking complement to the
/// strictly-ordered progress thread. On a fatal link error it breaks the
/// world and fails everything registered.
fn p2p_poll_loop(core: Arc<WorldCore>, stop: Arc<AtomicBool>) {
    let mut idle_spins = 0u32;
    loop {
        if stop.load(Ordering::Acquire) {
            fail_pending(&core, CclError::Aborted("world dropped".into()));
            return;
        }
        if core.broken.load(Ordering::Acquire) {
            let reason = core
                .broken_reason
                .lock()
                .unwrap()
                .clone()
                .unwrap_or_else(|| CclError::WorldBroken(core.name.clone()));
            fail_pending(&core, reason);
            // Stay alive to fail future registrations promptly (irecv
            // also checks health at submit, so this is belt-and-braces).
            std::thread::sleep(Duration::from_millis(5));
            continue;
        }
        let mut made_progress = false;
        let mut fatal: Option<CclError> = None;
        {
            let mut pending = core.pending_recvs.lock().unwrap();
            let mut i = 0;
            while i < pending.len() {
                let pr = &pending[i];
                let link = match core.link(pr.peer) {
                    Ok(l) => l,
                    Err(e) => {
                        pending.swap_remove(i).work.fail(e);
                        continue;
                    }
                };
                match link.try_recv(pr.wire_tag) {
                    Ok(Some(bytes)) => {
                        let pr = pending.swap_remove(i);
                        match read_tensor(&mut bytes.as_slice()) {
                            Ok(t) => pr.work.complete(Some(t)),
                            Err(e) => pr.work.fail(CclError::Transport(format!(
                                "bad tensor frame: {e}"
                            ))),
                        }
                        link.recycle(bytes);
                        made_progress = true;
                    }
                    Ok(None) => {
                        i += 1;
                    }
                    Err(e) => {
                        let pr = pending.swap_remove(i);
                        if e.is_fatal_to_world() && fatal.is_none() {
                            fatal = Some(e.clone());
                        }
                        pr.work.fail(e);
                        made_progress = true;
                    }
                }
            }
        }
        if let Some(e) = fatal {
            core.break_world(&e);
            continue;
        }
        if made_progress {
            idle_spins = 0;
        } else {
            idle_spins += 1;
            if idle_spins < 64 {
                std::hint::spin_loop();
            } else {
                std::thread::sleep(Duration::from_micros(50));
            }
        }
    }
}

fn fail_pending(core: &WorldCore, err: CclError) {
    let mut pending = core.pending_recvs.lock().unwrap();
    for pr in pending.drain(..) {
        pr.work.fail(err.clone());
    }
}

/// Runs ops strictly in submission order; a fatal error breaks the world
/// and fails everything still queued.
fn progress_loop(core: Arc<WorldCore>, rx: Receiver<Job>) {
    while let Ok(job) = rx.recv() {
        if let Err(e) = core.check_healthy() {
            job.work.fail(e);
            continue;
        }
        job.work.set_running();
        match (job.run)(&core) {
            Ok(t) => job.work.complete(t),
            Err(e) => {
                if e.is_fatal_to_world() {
                    core.break_world(&e);
                }
                job.work.fail(e);
            }
        }
    }
}
