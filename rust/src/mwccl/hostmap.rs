//! Per-rank host placement for topology-aware collectives.
//!
//! A [`HostMap`] assigns every rank of a world to a *host* — the unit of
//! shared-memory locality. The hierarchical collective family
//! ([`crate::config::CollAlgo::Hier`]) uses it to split each op into an
//! intra-host phase (cheap, shm-backed links) and an inter-host phase
//! restricted to one *leader* rank per host, and the rendezvous layer
//! uses it to route cross-host links over the shared per-host-pair
//! multiplexed connection ([`crate::mwccl::transport::mux`]).
//!
//! Placement comes from the `MW_HOSTMAP` env var (or
//! `WorldOptions::with_hostmap`). Three spec forms are accepted:
//!
//! * a comma list of per-rank host ids — `"0,0,1,1"` puts ranks 0–1 on
//!   host 0 and ranks 2–3 on host 1; ids are renumbered densely in
//!   order of first appearance, so `"7,7,3"` is the same as `"0,0,1"`;
//! * `"<H>x<L>"` — `H` hosts of `L` consecutive ranks each (blocked),
//!   e.g. `"2x4"` for an 8-rank world split 4+4; the last host may be
//!   short when `H·L` exceeds the world size;
//! * `"rr:<H>"` — round-robin over `H` hosts, rank `r` on host `r % H`.
//!
//! An absent/empty spec means all ranks share one host — the historical
//! single-host behavior, under which `Auto` never picks `Hier` and link
//! construction is unchanged.

use super::error::{CclError, CclResult};

/// Dense per-rank host assignment. Host ids are `0..n_hosts`, renumbered
/// from the spec in order of first appearance; each host's *leader* is
/// its lowest-numbered rank.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HostMap {
    /// `host_of[rank]` — always dense (every id in `0..n_hosts` occurs).
    host_of: Vec<u16>,
    n_hosts: usize,
}

impl HostMap {
    /// All `size` ranks on one host (the no-`MW_HOSTMAP` default).
    pub fn single_host(size: usize) -> HostMap {
        HostMap { host_of: vec![0; size.max(1)], n_hosts: 1 }
    }

    /// Parse a placement spec (see the module docs for the grammar) for
    /// a world of `size` ranks.
    pub fn parse(spec: &str, size: usize) -> CclResult<HostMap> {
        let spec = spec.trim();
        if spec.is_empty() {
            return Ok(HostMap::single_host(size));
        }
        let raw: Vec<usize> = if let Some(h) = spec.strip_prefix("rr:") {
            let hosts: usize = h
                .trim()
                .parse()
                .map_err(|_| bad_spec(spec, "rr:<H> needs an integer host count"))?;
            if hosts == 0 {
                return Err(bad_spec(spec, "host count must be >= 1"));
            }
            (0..size).map(|r| r % hosts).collect()
        } else if let Some((h, l)) = spec.split_once('x') {
            let hosts: usize =
                h.trim().parse().map_err(|_| bad_spec(spec, "<H>x<L> needs integers"))?;
            let per: usize =
                l.trim().parse().map_err(|_| bad_spec(spec, "<H>x<L> needs integers"))?;
            if hosts == 0 || per == 0 {
                return Err(bad_spec(spec, "<H>x<L> terms must be >= 1"));
            }
            if hosts * per < size {
                return Err(bad_spec(spec, "HxL covers fewer ranks than the world"));
            }
            (0..size).map(|r| r / per).collect()
        } else {
            let ids: CclResult<Vec<usize>> = spec
                .split(',')
                .map(|t| {
                    t.trim()
                        .parse::<usize>()
                        .map_err(|_| bad_spec(spec, "comma list entries must be integers"))
                })
                .collect();
            let ids = ids?;
            if ids.len() != size {
                return Err(bad_spec(
                    spec,
                    &format!("comma list has {} entries for a {}-rank world", ids.len(), size),
                ));
            }
            ids
        };
        // Renumber densely in order of first appearance.
        let mut dense: Vec<usize> = Vec::new();
        let mut host_of = Vec::with_capacity(size.max(1));
        for id in raw {
            let h = match dense.iter().position(|&d| d == id) {
                Some(h) => h,
                None => {
                    dense.push(id);
                    dense.len() - 1
                }
            };
            host_of.push(h as u16);
        }
        if host_of.is_empty() {
            host_of.push(0);
            dense.push(0);
        }
        Ok(HostMap { host_of, n_hosts: dense.len() })
    }

    /// Resolve from `MW_HOSTMAP`; missing or empty means single-host.
    pub fn from_env(size: usize) -> CclResult<HostMap> {
        match std::env::var("MW_HOSTMAP") {
            Ok(s) => HostMap::parse(&s, size),
            Err(_) => Ok(HostMap::single_host(size)),
        }
    }

    /// Number of distinct hosts (>= 1).
    pub fn n_hosts(&self) -> usize {
        self.n_hosts
    }

    /// World size this map covers.
    pub fn size(&self) -> usize {
        self.host_of.len()
    }

    /// Host id of `rank`.
    pub fn host(&self, rank: usize) -> usize {
        self.host_of[rank] as usize
    }

    /// Leader (lowest rank) of `host`.
    pub fn leader(&self, host: usize) -> usize {
        self.host_of
            .iter()
            .position(|&h| h as usize == host)
            .expect("dense host ids: every id in 0..n_hosts occurs")
    }

    /// Whether `rank` is its host's leader.
    pub fn is_leader(&self, rank: usize) -> bool {
        self.leader(self.host(rank)) == rank
    }

    /// Ranks on `host`, ascending.
    pub fn members(&self, host: usize) -> Vec<usize> {
        (0..self.host_of.len()).filter(|&r| self.host_of[r] as usize == host).collect()
    }

    /// One leader rank per host, ordered by host id.
    pub fn leaders(&self) -> Vec<usize> {
        (0..self.n_hosts).map(|h| self.leader(h)).collect()
    }

    /// Whether two ranks share a host.
    pub fn same_host(&self, a: usize, b: usize) -> bool {
        self.host_of[a] == self.host_of[b]
    }
}

fn bad_spec(spec: &str, why: &str) -> CclError {
    CclError::InvalidUsage(format!("bad MW_HOSTMAP spec {spec:?}: {why}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_host_default() {
        let m = HostMap::single_host(4);
        assert_eq!(m.n_hosts(), 1);
        assert!(m.is_leader(0));
        assert!(!m.is_leader(3));
        assert_eq!(m.leaders(), vec![0]);
        assert_eq!(m.members(0), vec![0, 1, 2, 3]);
    }

    #[test]
    fn comma_list_renumbers_densely() {
        let m = HostMap::parse("7,7,3,3", 4).unwrap();
        assert_eq!(m.n_hosts(), 2);
        assert_eq!(m.host(0), 0);
        assert_eq!(m.host(2), 1);
        assert_eq!(m.leader(1), 2);
        assert_eq!(m.leaders(), vec![0, 2]);
        assert!(m.same_host(0, 1));
        assert!(!m.same_host(1, 2));
    }

    #[test]
    fn blocked_shorthand() {
        let m = HostMap::parse("2x4", 8).unwrap();
        assert_eq!(m.n_hosts(), 2);
        assert_eq!(m.members(0), vec![0, 1, 2, 3]);
        assert_eq!(m.members(1), vec![4, 5, 6, 7]);
        // Ragged tail: 3x3 over 7 ranks -> 3+3+1.
        let m = HostMap::parse("3x3", 7).unwrap();
        assert_eq!(m.n_hosts(), 3);
        assert_eq!(m.members(2), vec![6]);
    }

    #[test]
    fn round_robin() {
        let m = HostMap::parse("rr:3", 7).unwrap();
        assert_eq!(m.n_hosts(), 3);
        assert_eq!(m.members(0), vec![0, 3, 6]);
        assert_eq!(m.members(1), vec![1, 4]);
        assert_eq!(m.leaders(), vec![0, 1, 2]);
    }

    #[test]
    fn asymmetric_layout() {
        let m = HostMap::parse("0,0,0,1", 4).unwrap();
        assert_eq!(m.n_hosts(), 2);
        assert_eq!(m.members(0), vec![0, 1, 2]);
        assert_eq!(m.members(1), vec![3]);
        assert!(m.is_leader(3));
    }

    #[test]
    fn bad_specs_rejected() {
        assert!(HostMap::parse("0,1", 3).is_err());
        assert!(HostMap::parse("0x4", 4).is_err());
        assert!(HostMap::parse("1x2", 4).is_err());
        assert!(HostMap::parse("rr:0", 4).is_err());
        assert!(HostMap::parse("zebra", 4).is_err());
        assert!(HostMap::parse("", 4).unwrap().n_hosts() == 1);
    }
}
