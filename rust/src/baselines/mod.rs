//! The paper's comparison systems, implemented for real:
//!
//! * [`singleworld`] — "SW": vanilla CCL usage, one world for the whole
//!   job. No MultiWorld layer, no watchdog, no per-op state activation —
//!   the lowest-overhead datapoint in Figs 6/7, and the architecture
//!   whose single fault domain Fig 4 (left) exposes.
//! * [`multiproc`] — "MP": the alternative MultiWorld architecture the
//!   paper implements and rejects: a main process with one *subprocess
//!   per world*, tensors crossing the process boundary over pipe IPC
//!   with serialization both ways (Fig 6's worst line at small sizes).
//! * [`msgbus`] — the Kafka-style message bus of Fig 1: a broker
//!   process, produce/consume over TCP, mandatory serialize +
//!   (simulated) GPU↔CPU staging copies.

pub mod msgbus;
pub mod multiproc;
pub mod singleworld;
